#!/usr/bin/env python3
"""Compare two BENCH_fourier.json snapshots and fail on regression.

    python3 scripts/bench_compare.py <old> <new> [--tolerance 0.10]

<old>/<new> are snapshot paths; an argument that is not an existing
file is treated as a git revision and BENCH_fourier.json is read from
it (e.g. `HEAD`, `main~2`).  Typical PR gate:

    python3 scripts/bench_compare.py HEAD BENCH_fourier.json

Rules:
  * the NEW snapshot must say "measured": true — a stub or partial
    snapshot can never pass the gate;
  * every `speedup_*` row present in BOTH snapshots must not regress by
    more than the tolerance (default 10%): these rows carry ratios
    (bigger = better), so new < (1 - tol) * old fails;
  * rows that appear only in one snapshot are reported but never fail
    the gate (benches legitimately come and go across PRs).

Exit status: 0 clean, 1 regression or invalid snapshot, 2 usage/IO.
"""

import json
import os
import subprocess
import sys


def load(spec):
    """Load a snapshot from a path, or from `git show <rev>:BENCH...`."""
    if os.path.exists(spec):
        with open(spec) as f:
            return json.load(f), spec
    try:
        blob = subprocess.run(
            ["git", "show", f"{spec}:BENCH_fourier.json"],
            capture_output=True, text=True, check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError) as e:
        print(f"error: {spec!r} is neither a file nor a git revision "
              f"holding BENCH_fourier.json ({e})", file=sys.stderr)
        sys.exit(2)
    return json.loads(blob), f"{spec}:BENCH_fourier.json"


def speedup_rows(doc):
    """{(bench, row name): ratio} for every speedup_* row."""
    out = {}
    for bench, rows in doc.get("benches", {}).items():
        for row in rows:
            if row["name"].startswith("speedup_"):
                out[(bench, row["name"])] = float(row["median_ns"])
    return out


def main(argv):
    args = []
    tol = 0.10
    i = 0
    while i < len(argv):
        a = argv[i]
        if a.startswith("--tolerance"):
            if "=" in a:
                tol = float(a.split("=", 1)[1])
            else:
                i += 1
                tol = float(argv[i])
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2

    old_doc, old_src = load(args[0])
    new_doc, new_src = load(args[1])

    if not new_doc.get("measured", False):
        print(f"FAIL: {new_src} has measured != true — refusing to gate "
              "on stub or partial numbers")
        return 1

    old = speedup_rows(old_doc)
    new = speedup_rows(new_doc)
    if not old_doc.get("measured", False):
        print(f"note: {old_src} is an unmeasured stub; nothing to compare "
              "against — gate passes on the new snapshot's validity alone")
        return 0

    shared = sorted(set(old) & set(new))
    gone = sorted(set(old) - set(new))
    fresh = sorted(set(new) - set(old))
    failures = []
    print(f"comparing {len(shared)} shared speedup rows "
          f"({old_src} -> {new_src}, tolerance {tol:.0%})")
    for key in shared:
        bench, name = key
        o, n = old[key], new[key]
        verdict = "ok"
        if n < (1.0 - tol) * o:
            verdict = "REGRESSION"
            failures.append((bench, name, o, n))
        print(f"  [{bench}] {name:<44} {o:8.2f}x -> {n:8.2f}x  {verdict}")
    for bench, name in gone:
        print(f"  [{bench}] {name:<44} (dropped in new snapshot)")
    for bench, name in fresh:
        print(f"  [{bench}] {name:<44} (new row: {new[(bench, name)]:.2f}x)")

    if failures:
        print(f"\nFAIL: {len(failures)} speedup row(s) regressed more "
              f"than {tol:.0%}:")
        for bench, name, o, n in failures:
            print(f"  [{bench}] {name}: {o:.2f}x -> {n:.2f}x "
                  f"({(1 - n / o):.0%} slower)")
        return 1
    print("\nbench-compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
