#!/usr/bin/env bash
# Tier-1 verification, runnable from a clean offline checkout:
#   cargo build --release && cargo test -q
# No network, no crate registry, no Python artifacts required — tests that
# need AOT artifacts print an explicit SKIP line and pass.
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "verify: OK"
