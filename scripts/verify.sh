#!/usr/bin/env bash
# Tier-1 verification, runnable from a clean offline checkout:
#   cargo build --release && cargo test -q
# No network, no crate registry, no Python artifacts required — tests that
# need AOT artifacts print an explicit SKIP line and pass.
#
# After the test suite, every figure/table bench binary runs one tiny
# size (`-- --smoke`, 1 ms budgets, no TSV output) so a broken bench
# fails here instead of only at figure-generation time.
set -euo pipefail

cd "$(dirname "$0")/../rust"

# Once `make artifacts` has run (so3_golden.json is its witness), EVERY
# golden is expected: missing ones — including a stale artifacts dir
# lacking the newer model_golden.json — become hard failures instead of
# printed skips.  Export GOLDENS_REQUIRED=1 yourself to force the strict
# mode anywhere.
if [ -f artifacts/golden/so3_golden.json ]; then
    export GOLDENS_REQUIRED=1
    echo "== goldens present: GOLDENS_REQUIRED=1 (skips become failures) =="
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== EquivariantOp conformance harness (smoke mode) =="
# the full harness already ran inside `cargo test -q`; this re-runs it
# in its fast CONFORMANCE_SMOKE configuration as an explicitly named
# gate, so a contract regression is pinpointed even when the full suite
# is skipped or trimmed
CONFORMANCE_SMOKE=1 cargo test -q --test op_conformance

echo "== serving-protocol conformance suite (SERVE_SMOKE fast mode) =="
# same idea for the typed serving protocol: every Task variant, typed
# deadline/cancel errors, reply-on-drop under injected worker failure,
# tear-free hot swap, and the bucketed-vs-global padding guarantee, at
# reduced workload sizes
SERVE_SMOKE=1 cargo test -q --test service_conformance

echo "== chaos conformance suite (CHAOS_SMOKE fast mode) =="
# fault-injection gate: every failpoint site fired under live traffic —
# typed errors only, no hang, no lost reply, supervisor respawn after
# worker death, quarantine of non-finite rows, overload shedding — at
# reduced workload sizes
CHAOS_SMOKE=1 cargo test -q --test chaos_conformance

echo "== socket serving conformance suite (NET_SMOKE fast mode) =="
# the multi-process gate: every task kind over Unix + TCP sockets,
# deadline/cancel propagation across the wire, client-hangup releasing
# replica-side work, front-door failover, and the real N-process
# loadtest (ledger reconciliation + replica-kill recovery)
NET_SMOKE=1 cargo test -q --test net_conformance

echo "== bench --smoke (one tiny size per bench binary) =="
# fig1c is the one figure bench the snapshot pipeline below doesn't run
for b in fig1c_many_body; do
    echo "-- $b --smoke --"
    cargo bench --bench "$b" -- --smoke
done

echo "== SMOKE=1 bench snapshot (the committed BENCH_fourier.json path) =="
# runs fig1a/fig1b/table2/simd_kernels/model_inference/serving/
# md_neighbor/fig_vector through the REAL snapshot script, so a broken
# bench OR broken snapshot
# plumbing fails tier-1 instead of only when someone regenerates the
# committed baseline (smoke mode leaves BENCH_fourier.json untouched)
cd ..
SMOKE=1 bash scripts/bench_snapshot.sh

echo "verify: OK"
