#!/usr/bin/env bash
# Bench snapshot: run the fig1a / fig1b / table2 benches and write a
# machine-readable BENCH_fourier.json at the repo root, so the perf
# trajectory of the Fourier hot path is tracked PR over PR.
#
#   make bench-snapshot          # full measurement (minutes)
#   SMOKE=1 make bench-snapshot  # 1 ms budgets — plumbing check only;
#                                # BENCH_fourier.json is left untouched
#
# The JSON carries every TSV row the benches emit (name, median_ns,
# mad_ns, iters).  The before/after story is IN the row names:
#   fig1a:  gaunt_fft_legacy (before) vs gaunt_fft (after)
#   fig1b:  gaunt_conv (direct sweep) vs gaunt_conv_fft (cached spectra)
#   table2: gaunt_fft_legacy/gaunt_fft_planned/gaunt_direct/gaunt_fft_f32
#           per L, plus speedup_* ratio rows and the measured Auto
#           crossover.
#   simd:   each vectorized Fourier kernel (fft butterflies, pointwise
#           product, f2sh contraction, blocked column pass) vs its
#           scalar oracle, with speedup_* ratio rows.
#   model:  full learned-force-field inference (energy+forces through
#           every planned Gaunt plan), 1 thread vs all cores.
#   multi_channel: the same inference at 1 / 8 / 32 feature channels
#           (atoms/sec scaling of the Irreps multi-channel model).
#   serving: p50/p99 request latency, structures/sec, and atom-slot
#           fill of the typed serving protocol, single worst-case-width
#           queue vs shape-bucketed batching at 1 and N workers.
#   resilience: p99 / success rate / shed fraction of a small-queue
#           service under polite vs ~2x oversubscribed load (admission
#           control sheds typed Overloaded instead of queueing forever).
#   socket: the wire-hop tax — the same closed-loop load through the
#           in-process client, one replica over a Unix socket, one over
#           TCP loopback, and a front door sharding N replicas.
#   md_neighbor: open vs periodic cell-list builds, Verlet rebuild vs
#           reuse, and ns/step of a 10^5-atom periodic LJ rollout.
#   vector_tp: the three vector-signal Gaunt operators (sv / dot /
#           cross) per L — planned direct + FFT vs the dense O(L^6)
#           Gaunt-tensor contraction.
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT="$PWD"
OUT="$ROOT/BENCH_fourier.json"
RESULTS="$ROOT/rust/target/bench-results"

SMOKE="${SMOKE:-}"
ARGS=()
if [ -n "$SMOKE" ]; then
    ARGS=(-- --smoke)
    echo "== bench snapshot (SMOKE: plumbing check, no TSVs) =="
else
    echo "== bench snapshot (full measurement) =="
fi

if [ -z "$SMOKE" ]; then
    # a full run must harvest ONLY its own TSVs: stale results from an
    # earlier (possibly partial) run would silently masquerade as fresh
    # measurements in the committed snapshot
    rm -rf "$RESULTS"
fi

cd rust
for b in fig1a_feature_interaction fig1b_equivariant_convolution \
         table2_speed_memory simd_kernels model_inference serving \
         md_neighbor fig_vector; do
    echo "== cargo bench --bench $b =="
    cargo bench --bench "$b" "${ARGS[@]+"${ARGS[@]}"}"
done
cd "$ROOT"

if [ -n "$SMOKE" ]; then
    # smoke runs write no TSVs; harvesting would repackage whatever a
    # PREVIOUS full run left in $RESULTS as if it were this run's data.
    # Leave BENCH_fourier.json untouched.
    echo "[smoke] benches OK; BENCH_fourier.json left untouched"
    exit 0
fi

python3 - "$OUT" "$RESULTS" <<'EOF'
import json, os, sys, time

out_path, results = sys.argv[1], sys.argv[2]

# bench key -> TSV stems that feed it.  Stems marked optional may
# legitimately be absent (artifact-dependent benches on a checkout with
# no compiled artifacts); every other stem missing is a hard error —
# a silently skipped stem would commit a snapshot that LOOKS complete.
wanted = {
    "fig1a": ["fig1a"],
    "fig1b": ["fig1b"],
    "table2": ["table2_fourier_plan", "table2_tp_scaling", "table2_speed"],
    "simd": ["simd_kernels"],
    "model": ["model_inference"],
    "multi_channel": ["multi_channel"],
    "serving": ["serving"],
    "resilience": ["resilience"],
    "socket": ["socket"],
    "md_neighbor": ["md_neighbor"],
    "vector_tp": ["fig_vector"],
}

benches = {}
missing = []
for bench, stems in wanted.items():
    rows = []
    for stem in stems:
        path = os.path.join(results, stem + ".tsv")
        if not os.path.exists(path):
            missing.append(stem)
            continue
        with open(path) as f:
            header = f.readline().strip().split("\t")
            for line in f:
                parts = line.rstrip("\n").split("\t")
                if len(parts) != len(header):
                    continue
                row = dict(zip(header, parts))
                rows.append({
                    "source": stem,
                    "name": row["name"],
                    "median_ns": float(row["median_ns"]),
                    "mad_ns": float(row["mad_ns"]),
                    "iters": int(row["iters"]),
                })
    benches[bench] = rows

if missing:
    print(f"error: expected TSVs never materialized: {', '.join(missing)}",
          file=sys.stderr)
    print("       (bench crashed mid-run, or a bench stopped writing its "
          "stem — refusing to commit a partial snapshot)", file=sys.stderr)
    sys.exit(1)

doc = {
    "schema": 1,
    "generated_unix": int(time.time()),
    "measured": all(benches.values()),
    "note": ("medians in nanoseconds; speedup_* rows carry a ratio in "
             "median_ns (iters = 0 marks derived rows)"),
    "before_after": {
        "fig1a": ["gaunt_fft_legacy (before)", "gaunt_fft (after)"],
        "fig1b": ["gaunt_conv (direct sweep)",
                  "gaunt_conv_fft (cached filter spectra)"],
        "table2": ["gaunt_fft_legacy (before)",
                   "gaunt_fft_planned (after)",
                   "speedup_legacy_over_planned (ratio)",
                   "gaunt_fft_f32 (serving precision mode); "
                   "speedup_f64_over_f32 (ratio)"],
        "simd": ["fft_scalar/pointwise_scalar/f2sh_scalar/fft2_colx1 "
                 "(scalar oracles, before)",
                 "fft_simd/pointwise_simd/f2sh_simd/fft2_colx8 (after); "
                 "speedup_* rows carry the ratio"],
        "model": ["model_batch 1 thread (before)",
                  "model_batch all cores (after)"],
        "multi_channel": ["model_batch C=1 (baseline)",
                          "model_batch C=8 / C=32 (multi-channel scaling)"],
        "serving": ["serving_global_q_* (single worst-case-width queue)",
                    "serving_bucketed_* (shape-bucketed batching); "
                    "*_p50/*_p99 in ns, *_rate in structures/sec, "
                    "*_atom_fill a ratio (iters = 0 marks derived rows)"],
        "resilience": ["resilience_healthy_* (polite closed-loop load)",
                       "resilience_overload_* (~2x oversubscribed, typed "
                       "shedding); *_p99 in ns, *_success and *_shed_frac "
                       "ratios (iters = 0 marks derived rows)"],
        "socket": ["socket_inproc_* (in-process typed client, before)",
                   "socket_unix_r1_* / socket_tcp_r1_* (one replica over "
                   "a real socket — the wire-hop tax)",
                   "socket_unix_rN_fd_* (front door sharding N replicas); "
                   "*_p50/*_p99 in ns, *_rate in structures/sec "
                   "(iters = 0 marks derived rows)"],
        "md_neighbor": ["open_cell_list / periodic_cell_list / "
                        "periodic_par_all_cores (build cost per size)",
                        "verlet_rebuild (before) vs verlet_reuse (after); "
                        "periodic_lj_rollout_step is ns per MD step at "
                        "10^5 atoms"],
        "vector_tp": ["naive_dense sv/dot/cross (O(L^6) Gaunt-tensor "
                      "contraction, before)",
                      "plan_direct / plan_fft sv/dot/cross (planned "
                      "O(L^3) Cartesian-component route, after)"],
    },
    "benches": benches,
}
with open(out_path, "w") as f:
    json.dump(doc, f, indent=1)
    f.write("\n")
print(f"[json] {out_path} "
      f"({sum(len(v) for v in benches.values())} rows, "
      f"measured={doc['measured']})")
EOF
