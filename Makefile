# Top-level driver for the gaunt-tp repo.
#
#   make verify     - the tier-1 gate: release build + full test suite,
#                     from a clean offline checkout (no network needed)
#   make build      - release build only
#   make test       - test suite only
#   make bench      - run every native bench target
#   make bench-snapshot - run the fig1a/fig1b/table2 benches and write
#                     machine-readable BENCH_fourier.json at the repo
#                     root (SMOKE=1 for a 1 ms plumbing check)
#   make artifacts  - (needs JAX) AOT-compile the Pallas/XLA artifacts
#                     with python/compile/aot.py into rust/artifacts/

RUST_DIR := rust

.PHONY: verify build test bench bench-snapshot artifacts clean

verify:
	bash scripts/verify.sh

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

bench:
	cd $(RUST_DIR) && cargo bench

bench-snapshot:
	bash scripts/bench_snapshot.sh

artifacts:
	cd python && python -m compile.aot --out ../$(RUST_DIR)/artifacts

clean:
	cd $(RUST_DIR) && cargo clean
