# Top-level driver for the gaunt-tp repo.
#
#   make verify     - the tier-1 gate: release build + full test suite,
#                     from a clean offline checkout (no network needed)
#   make build      - release build only
#   make test       - test suite only
#   make bench      - run every native bench target
#   make bench-snapshot - run the fig1a/fig1b/table2/model benches and
#                     write machine-readable BENCH_fourier.json at the
#                     repo root, including the multi_channel section
#                     (atoms/sec at 1/8/32 feature channels); SMOKE=1
#                     for a 1 ms plumbing check
#   make bench-compare - diff the working-tree BENCH_fourier.json against
#                     the one at OLD (default HEAD); fails if any
#                     speedup_* ratio row regressed by more than 10%
#   make artifacts  - (needs JAX) AOT-compile the Pallas/XLA artifacts
#                     with python/compile/aot.py into rust/artifacts/
#   make model-golden - (numpy only, no JAX) regenerate the frozen-weights
#                     model energy/forces golden for the cross-language test
#   make vector-golden - (numpy only, no JAX) run the vector-signal mirror
#                     checks and regenerate the VSH / vector-plan / dipole
#                     golden for the cross-language test
#   make loadtest   - drive the typed serving Client with concurrent
#                     mixed-size traffic through the shape-bucketed
#                     native service (offline; p50/p99 + atom_fill)
#   make loadtest-net - the TRUE multi-process loadtest: 2 replica
#                     processes + 1 front door + 2 client processes over
#                     Unix sockets, one replica SIGKILLed mid-load; the
#                     aggregated ledger must reconcile
#   make serve-cluster - stand up a local cluster (1 front door + 2
#                     self-spawned replicas over Unix sockets) and leave
#                     it serving until Ctrl-C
#   make chaos      - full fault-injection conformance run: every
#                     failpoint site fired under live traffic, then the
#                     mixed-traffic schedule again under a fixed
#                     FAILPOINTS env program (delay + error policies)
#   make ci         - the full gate: tier-1 (which runs every test file,
#                     model_symmetries/grad_check/alloc_regression/
#                     golden_cross_validation included) + every --smoke
#                     bench, all chained inside scripts/verify.sh

RUST_DIR := rust

.PHONY: verify build test bench bench-snapshot bench-compare artifacts \
        model-golden vector-golden loadtest loadtest-net serve-cluster \
        chaos ci clean

OLD ?= HEAD

verify:
	bash scripts/verify.sh

ci: verify

build:
	cd $(RUST_DIR) && cargo build --release

test:
	cd $(RUST_DIR) && cargo test -q

bench:
	cd $(RUST_DIR) && cargo bench

bench-snapshot:
	bash scripts/bench_snapshot.sh

bench-compare:
	python3 scripts/bench_compare.py $(OLD) BENCH_fourier.json

loadtest:
	cd $(RUST_DIR) && cargo run --release -- loadtest --requests 256 \
		--clients 4 --workers 2

loadtest-net:
	cd $(RUST_DIR) && cargo run --release -- loadtest --net --replicas 2 \
		--clients 2 --requests 40 --workers 2 --kill-one

serve-cluster:
	cd $(RUST_DIR) && cargo run --release -- frontdoor \
		--listen unix:/tmp/gaunt-tp-frontdoor.sock --spawn-replicas 2

chaos:
	cd $(RUST_DIR) && cargo test --test chaos_conformance
	cd $(RUST_DIR) && FAILPOINTS="svc.worker.batch=every_nth(3):delay(2);backend.run=every_nth(5):error(injected by FAILPOINTS)" \
		cargo test --test chaos_conformance fixed_env_schedule

artifacts:
	cd python && python -m compile.aot --out ../$(RUST_DIR)/artifacts
	cd python && python -m compile.model_golden --out ../$(RUST_DIR)/artifacts
	cd python && python -m compile.vector_golden --out ../$(RUST_DIR)/artifacts

model-golden:
	cd python && python -m compile.model_golden --out ../$(RUST_DIR)/artifacts

vector-golden:
	cd python && python -m compile.vector_golden --check --out ../$(RUST_DIR)/artifacts

clean:
	cd $(RUST_DIR) && cargo clean
