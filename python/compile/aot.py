"""AOT compiler: lower every jitted computation to HLO text artifacts.

This is the one-shot build step (`make artifacts`).  After it runs, the
Rust coordinator is self-contained: it loads `artifacts/*.hlo.txt` with
`HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
executes — Python never appears on the request path.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids (see
/opt/xla-example/README.md).

Emitted artifact families (see artifacts/manifest.json):
  gaunt_tp_L{l}_B{b}   — the batched Gaunt TP kernel op (Pallas pipeline)
  cg_tp_L{l}_B{b}      — the O(L^6) Clebsch-Gordan baseline op
  ff_fwd_B{b}          — GauntNet force-field inference: (params, graphs)
                          -> (energy, forces); several batch variants for
                          the coordinator's router
  ff_train_step_{tp}   — one fused Adam step (params, opt, batch) ->
                          (params', opt', loss); gaunt + cg variants
  nbody_fwd_{tp} / nbody_train_{tp} — SEGNN-lite for the Fig. 1d sanity check

plus params_*.bin (initial state blobs) and golden/*.json (cross-language
test vectors for the native Rust implementation).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import fourier as fr
from . import model as M
from . import so3
from .kernels import cg_tp as ck
from .kernels import gaunt_tp as gk


# --------------------------------------------------------------------------
# lowering helpers
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as `{...}`,
    # which the downstream text parser silently reconstructs as zeros —
    # every coefficient table (CG tensors, sh2f/f2sh panels, SH monomial
    # tables) would be wiped.  Print with full constants.
    popt = xc._xla.HloPrintOptions()
    popt.print_large_constants = True
    # jax's printer emits source_end_line/... metadata the 0.5.1 text
    # parser does not know; strip it.
    popt.print_metadata = False
    return comp.as_hlo_module().to_string(popt)


def _spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "state_blobs": {}}
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    def lower(self, name: str, fn, example_args, input_names, output_names,
              meta=None):
        print(f"[aot] lowering {name} ...", flush=True)
        # keep_unused: inference artifacts take the full (params + opt)
        # state so serving and training share one tensor layout — the opt
        # tensors are unused by fwd and must NOT be pruned from the HLO
        # signature.
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example_args)
        flat_outs = jax.tree.leaves(outs)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, **_spec_of(a)}
                for n, a in zip(input_names, jax.tree.leaves(example_args))
            ],
            "outputs": [
                {"name": n, **_spec_of(o)} for n, o in zip(output_names, flat_outs)
            ],
            "meta": meta or {},
        }
        print(f"[aot]   -> {fname} ({len(text)} chars)", flush=True)

    def write_state_blob(self, name: str, named_arrays):
        """Concatenated little-endian blob + tensor directory."""
        fname = f"{name}.bin"
        tensors = []
        offset = 0
        with open(os.path.join(self.out_dir, fname), "wb") as f:
            for n, a in named_arrays:
                a = np.asarray(a)
                raw = a.astype("<f4" if a.dtype.kind == "f" else "<i4").tobytes()
                tensors.append(
                    {"name": n, "shape": list(a.shape), "dtype": str(a.dtype),
                     "offset": offset, "nbytes": len(raw)}
                )
                f.write(raw)
                offset += len(raw)
        self.manifest["state_blobs"][name] = {"file": fname, "tensors": tensors}

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"[aot] manifest with {len(self.manifest['artifacts'])} artifacts")


def flatten_state(state):
    """Deterministic (path-named) flatten of a pytree."""
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


# --------------------------------------------------------------------------
# artifact families
# --------------------------------------------------------------------------


def emit_tp_kernels(w: ArtifactWriter, degrees, batch: int):
    for L in degrees:
        n = so3.num_coeffs(L)
        spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
        gf = gk.make_gaunt_tp(L, L, L, "fft")
        w.lower(
            f"gaunt_tp_L{L}_B{batch}", lambda a, b, f=gf: (f(a, b),),
            (spec, spec), ["x1", "x2"], ["y"],
            meta={"L": L, "batch": batch, "op": "gaunt_tp", "method": "fft"},
        )
        cf = ck.make_cg_tp(L, L, L)
        w.lower(
            f"cg_tp_L{L}_B{batch}", lambda a, b, f=cf: (f(a, b),),
            (spec, spec), ["x1", "x2"], ["y"],
            meta={"L": L, "batch": batch, "op": "cg_tp"},
        )


def ff_config(tp: str = "gaunt") -> M.Config:
    return M.Config(L=2, channels=8, n_species=4, n_layers=2, n_bessel=8,
                    r_cut=4.0, n_atoms=32, n_edges=128, tp=tp)


def _ff_batch_specs(cfg: M.Config, b: int):
    return dict(
        pos=jax.ShapeDtypeStruct((b, cfg.n_atoms, 3), jnp.float32),
        species=jax.ShapeDtypeStruct((b, cfg.n_atoms), jnp.int32),
        edges=jax.ShapeDtypeStruct((b, cfg.n_edges, 2), jnp.int32),
        edge_mask=jax.ShapeDtypeStruct((b, cfg.n_edges), jnp.float32),
        atom_mask=jax.ShapeDtypeStruct((b, cfg.n_atoms), jnp.float32),
    )


def emit_forcefield(w: ArtifactWriter, batches, seed=0, tp="gaunt",
                    suffix=""):
    cfg = ff_config(tp)
    params = M.init_params(seed, cfg)
    state = {"params": params, "opt": M.adam_init(params)}
    named = flatten_state(state)
    state_names = [n for n, _ in named]
    w.write_state_blob(f"ff_state_init{suffix}", named)

    treedef = jax.tree.structure(state)

    for b in batches:
        bs = _ff_batch_specs(cfg, b)

        def fwd(*args, _b=b):
            k = len(state_names)
            st = jax.tree.unflatten(treedef, args[:k])
            pos, species, edges, em, am = args[k:]
            e, f = M.batched_energy_forces(
                st["params"], pos, species, edges, em, am, cfg
            )
            return e, f

        args = tuple(a for _, a in named) + (
            bs["pos"], bs["species"], bs["edges"], bs["edge_mask"],
            bs["atom_mask"],
        )
        w.lower(
            f"ff_fwd{suffix}_B{b}", fwd, args,
            state_names + ["pos", "species", "edges", "edge_mask", "atom_mask"],
            ["energy", "forces"],
            meta={"model": "gauntnet", "tp": tp, "batch": b,
                  "n_atoms": cfg.n_atoms, "n_edges": cfg.n_edges,
                  "n_species": cfg.n_species, "L": cfg.L,
                  "channels": cfg.channels, "r_cut": cfg.r_cut,
                  "n_state": len(state_names)},
        )


def emit_ff_train(w: ArtifactWriter, tps=("gaunt", "cg"), b=8, seed=0, lr=2e-3):
    for tp in tps:
        cfg = ff_config(tp)
        params = M.init_params(seed, cfg)
        state = {"params": params, "opt": M.adam_init(params)}
        named = flatten_state(state)
        state_names = [n for n, _ in named]
        w.write_state_blob(f"ff_state_init_{tp}", named)
        treedef = jax.tree.structure(state)
        bs = _ff_batch_specs(cfg, b)
        batch_specs = dict(
            **bs,
            energy=jax.ShapeDtypeStruct((b,), jnp.float32),
            forces=jax.ShapeDtypeStruct((b, cfg.n_atoms, 3), jnp.float32),
        )
        batch_names = list(batch_specs.keys())

        def step(*args, _cfg=cfg, _td=treedef, _k=len(state_names),
                 _bn=batch_names):
            st = jax.tree.unflatten(_td, args[:_k])
            batch = dict(zip(_bn, args[_k:]))
            p2, o2, loss = M.ff_train_step(st["params"], st["opt"], batch,
                                           _cfg, lr=lr)
            flat = [a for _, a in flatten_state({"params": p2, "opt": o2})]
            return tuple(flat) + (loss,)

        args = tuple(a for _, a in named) + tuple(batch_specs.values())
        w.lower(
            f"ff_train_step_{tp}", step, args,
            state_names + batch_names, state_names + ["loss"],
            meta={"model": "gauntnet", "tp": tp, "batch": b, "lr": lr,
                  "n_atoms": cfg.n_atoms, "n_edges": cfg.n_edges,
                  "n_state": len(state_names)},
        )


def nbody_config(tp: str) -> M.Config:
    return M.Config(L=1, channels=8, n_species=2, n_layers=2, n_bessel=8,
                    r_cut=20.0, n_atoms=5, n_edges=20, tp=tp,
                    readout="vector", vec_in=True)


def emit_nbody(w: ArtifactWriter, tps=("gaunt", "cg"), b=16, seed=1, lr=5e-3):
    for tp in tps:
        cfg = nbody_config(tp)
        params = M.init_params(seed, cfg)
        state = {"params": params, "opt": M.adam_init(params)}
        named = flatten_state(state)
        state_names = [n for n, _ in named]
        w.write_state_blob(f"nbody_state_init_{tp}", named)
        treedef = jax.tree.structure(state)
        batch_specs = dict(
            pos=jax.ShapeDtypeStruct((b, cfg.n_atoms, 3), jnp.float32),
            vel=jax.ShapeDtypeStruct((b, cfg.n_atoms, 3), jnp.float32),
            charge=jax.ShapeDtypeStruct((b, cfg.n_atoms), jnp.int32),
            edges=jax.ShapeDtypeStruct((b, cfg.n_edges, 2), jnp.int32),
            edge_mask=jax.ShapeDtypeStruct((b, cfg.n_edges), jnp.float32),
            atom_mask=jax.ShapeDtypeStruct((b, cfg.n_atoms), jnp.float32),
            target=jax.ShapeDtypeStruct((b, cfg.n_atoms, 3), jnp.float32),
        )
        batch_names = list(batch_specs.keys())

        def fwd(*args, _cfg=cfg, _td=treedef, _k=len(state_names)):
            st = jax.tree.unflatten(_td, args[:_k])
            pos, vel, charge, edges, em, am = args[_k:_k + 6]
            pred = jax.vmap(
                lambda p, v, c, e, m1, m2: M.nbody_forecast(
                    st["params"], p, v, c, e, m1, m2, _cfg)
            )(pos, vel, charge, edges, em, am)
            return (pred,)

        fargs = tuple(a for _, a in named) + tuple(
            batch_specs[k] for k in batch_names[:-1]
        )
        w.lower(
            f"nbody_fwd_{tp}", fwd, fargs,
            state_names + batch_names[:-1], ["pred"],
            meta={"model": "segnn_lite", "tp": tp, "batch": b,
                  "n_state": len(state_names)},
        )

        def step(*args, _cfg=cfg, _td=treedef, _k=len(state_names),
                 _bn=batch_names):
            st = jax.tree.unflatten(_td, args[:_k])
            batch = dict(zip(_bn, args[_k:]))
            p2, o2, loss = M.nbody_train_step(st["params"], st["opt"], batch,
                                              _cfg, lr=lr)
            flat = [a for _, a in flatten_state({"params": p2, "opt": o2})]
            return tuple(flat) + (loss,)

        args = tuple(a for _, a in named) + tuple(batch_specs.values())
        w.lower(
            f"nbody_train_{tp}", step, args,
            state_names + batch_names, state_names + ["loss"],
            meta={"model": "segnn_lite", "tp": tp, "batch": b, "lr": lr,
                  "n_state": len(state_names)},
        )


# --------------------------------------------------------------------------
# golden cross-language test vectors for the Rust implementation
# --------------------------------------------------------------------------


def emit_golden(out_dir: str):
    g = {}
    rng = np.random.default_rng(99)
    # Wigner 3j samples
    tj = []
    for (l1, l2, l3) in [(1, 1, 2), (2, 2, 2), (3, 2, 1), (2, 1, 1), (4, 3, 2)]:
        for m1 in range(-l1, l1 + 1):
            for m2 in range(-l2, l2 + 1):
                m3 = -(m1 + m2)
                if abs(m3) > l3:
                    continue
                tj.append([l1, l2, l3, m1, m2, m3,
                           so3.wigner_3j(l1, l2, l3, m1, m2, m3)])
    g["wigner3j"] = tj
    # real Gaunt tensor L=2
    g["gaunt_222"] = np.asarray(so3.gaunt_tensor_real(2, 2, 2)).ravel().tolist()
    g["cg_222"] = np.asarray(so3.cg_tensor_real(2, 2, 2)).ravel().tolist()
    # SH values at sample directions
    pts = rng.standard_normal((6, 3))
    g["sh_points"] = pts.ravel().tolist()
    g["sh_L3"] = so3.real_sh_xyz(3, pts).ravel().tolist()
    # sh2f panels L=3 (re/im split)
    p = np.asarray(fr.sh2f_panels(3))
    g["sh2f_panels_L3_re"] = p.real.ravel().tolist()
    g["sh2f_panels_L3_im"] = p.imag.ravel().tolist()
    t = np.asarray(fr.f2sh_panels(3, 6))
    g["f2sh_panels_L3_N6_re"] = t.real.ravel().tolist()
    g["f2sh_panels_L3_N6_im"] = t.imag.ravel().tolist()
    # gaunt TP I/O pairs
    x1 = rng.standard_normal((3, 16))
    x2 = rng.standard_normal((3, 16))
    y = fr.gaunt_tp(x1, 3, x2, 3, 3)
    g["tp_x1"] = x1.ravel().tolist()
    g["tp_x2"] = x2.ravel().tolist()
    g["tp_y_L3"] = y.ravel().tolist()
    yfull = fr.gaunt_tp(x1, 3, x2, 3, 6)
    g["tp_y_L6"] = yfull.ravel().tolist()
    # wigner D for a fixed rotation (alpha, beta, gamma) = (0.3, 1.1, -0.7)
    rot = so3.euler_zyz(0.3, 1.1, -0.7)
    g["rot"] = rot.ravel().tolist()
    g["wigner_d_block_L2"] = so3.wigner_d_real_block(2, rot).ravel().tolist()
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)
    with open(os.path.join(out_dir, "golden", "so3_golden.json"), "w") as f:
        json.dump(g, f)
    print("[aot] golden vectors written")


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="minimal artifact set (CI/tests)")
    ap.add_argument("--tp-degrees", default="1,2,3,4")
    ap.add_argument("--tp-batch", type=int, default=64)
    args = ap.parse_args()

    w = ArtifactWriter(args.out_dir)
    emit_golden(args.out_dir)
    if args.quick:
        emit_tp_kernels(w, [2], 8)
        emit_forcefield(w, [1])
    else:
        degrees = [int(d) for d in args.tp_degrees.split(",")]
        emit_tp_kernels(w, degrees, args.tp_batch)
        emit_forcefield(w, [1, 4, 8])
        emit_forcefield(w, [8], tp="cg", suffix="_cg")  # CG eval variant
        emit_ff_train(w)
        emit_nbody(w)
    w.finish()


if __name__ == "__main__":
    main()
