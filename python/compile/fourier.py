"""SH <-> 2D Fourier change of basis (paper Section 3.2), numpy build-time.

A feature x in R^{(L+1)^2} of real-SH coefficients represents the spherical
function F(theta, phi) = sum x_{lm} Y_m^l.  Every Y_m^l is a trigonometric
polynomial on the torus (theta, phi) in [0, 2pi)^2, so F extends to the
torus, and:

  sh2f:  x -> complex grid U[u, v] (|u|,|v| <= L) with
         F = sum U[u,v] e^{i(u theta + v phi)};  sparse: v = +-m only.
  multiplication of functions = 2D convolution of grids (Eqn. (5));
  f2sh:  project a band-limited torus function back onto SH coefficients,
         z^{l,m}_{u,v} = int_{S^2} e^{i(u theta + v phi)} Y_m^l dOmega
         (exact: trig-poly algebra x analytic int_0^pi e^{ik theta} dtheta);
         sparse: v = +-m only.

Grids are stored as (2N+1, 2N+1) complex arrays, index [N+u, N+v].

The packed "panel" tables (one dense matmul panel per |v|) are the form the
Pallas kernels and the Rust fast path consume — they turn the sparse
O(L^3) contraction into MXU-friendly dense matmuls.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

from . import so3

SQRT2_OVER_2 = math.sqrt(2.0) / 2.0


# --------------------------------------------------------------------------
# theta-Fourier expansion of SH theta-parts
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def theta_fourier(l: int, m: int) -> np.ndarray:
    """Complex coefficients c_u (u = -l..l, length 2l+1) of the signed torus
    extension of N_l^m P_l^m(cos theta):

      g(theta) = N P_l^m(cos theta) * sign(sin theta)^m

    g is a trig polynomial of degree l; sampled on 4l+8 points + FFT => exact.
    """
    assert 0 <= m <= l
    n = 4 * l + 8
    theta = np.arange(n) * (2.0 * math.pi / n)
    g = so3.assoc_legendre(l, m, np.cos(theta)) * so3.sh_norm(l, m)
    if m % 2 == 1:
        g = g * np.sign(np.sin(theta))
        # at theta = 0, pi the P factor is 0 for odd m, so sign() ambiguity
        # is harmless.
    c = np.fft.fft(g) / n
    out = np.zeros(2 * l + 1, dtype=np.complex128)
    for u in range(-l, l + 1):
        out[l + u] = c[u % n]
    # sanity: the trig polynomial reconstructs g
    return out


@lru_cache(maxsize=None)
def theta_projection(l: int, m: int, n_grid: int) -> np.ndarray:
    """t_u = int_0^pi e^{i u theta} N P_l^m(cos th) sin th dtheta  for
    u = -n_grid..n_grid (length 2*n_grid+1).

    h(theta) = N P sin(theta) extended to the torus is a trig polynomial of
    degree l+1 with coefficients d_k; then
    t_u = sum_k d_k I(u+k),  I(0)=pi, I(odd n)=2i/n, I(even n != 0)=0.
    """
    assert 0 <= m <= l
    n = 4 * (l + 1) + 8
    theta = np.arange(n) * (2.0 * math.pi / n)
    h = (
        so3.assoc_legendre(l, m, np.cos(theta))
        * so3.sh_norm(l, m)
        * np.sin(theta)
    )
    if m % 2 == 1:
        h = h * np.sign(np.sin(theta))
    c = np.fft.fft(h) / n
    deg = l + 1
    d = {k: c[k % n] for k in range(-deg, deg + 1)}

    def integral(nn: int) -> complex:
        if nn == 0:
            return math.pi
        if nn % 2 == 0:
            return 0.0
        return 2.0j / nn

    out = np.zeros(2 * n_grid + 1, dtype=np.complex128)
    for u in range(-n_grid, n_grid + 1):
        out[n_grid + u] = sum(dk * integral(u + k) for k, dk in d.items())
    return out


# --------------------------------------------------------------------------
# dense conversion tables
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def sh2f_dense(L: int) -> np.ndarray:
    """Y2F[i_{lm}, L+u, L+v]: x -> U = einsum('iuv,i->uv', Y2F, x)."""
    n = so3.num_coeffs(L)
    t = np.zeros((n, 2 * L + 1, 2 * L + 1), dtype=np.complex128)
    for l, m in so3.lm_iter(L):
        p = theta_fourier(l, abs(m))  # length 2l+1
        i = so3.lm_index(l, m)
        us = slice(L - l, L + l + 1)
        if m == 0:
            t[i, us, L] = p
        elif m > 0:
            t[i, us, L + m] = SQRT2_OVER_2 * p
            t[i, us, L - m] = SQRT2_OVER_2 * p
        else:  # m < 0: sqrt2 sin(|m| phi) = -i s e^{i|m|phi} + i s e^{-i|m|phi}
            a = -m
            t[i, us, L + a] = -1j * SQRT2_OVER_2 * p
            t[i, us, L - a] = 1j * SQRT2_OVER_2 * p
    return t


@lru_cache(maxsize=None)
def f2sh_dense(L_out: int, n_grid: int) -> np.ndarray:
    """Z[i_{lm}, N+u, N+v]: grid -> x = real(einsum('iuv,uv->i', Z, U))."""
    n = so3.num_coeffs(L_out)
    ng = 2 * n_grid + 1
    z = np.zeros((n, ng, ng), dtype=np.complex128)
    for l, m in so3.lm_iter(L_out):
        t = theta_projection(l, abs(m), n_grid)
        i = so3.lm_index(l, m)
        if m == 0:
            z[i, :, n_grid] = 2.0 * math.pi * t
        elif m > 0:
            z[i, :, n_grid + m] = math.sqrt(2.0) * math.pi * t
            z[i, :, n_grid - m] = math.sqrt(2.0) * math.pi * t
        else:
            a = -m
            z[i, :, n_grid + a] = 1j * math.sqrt(2.0) * math.pi * t
            z[i, :, n_grid - a] = -1j * math.sqrt(2.0) * math.pi * t
    return z


# --------------------------------------------------------------------------
# reference (numpy) pipeline
# --------------------------------------------------------------------------


def sh2f(x: np.ndarray, L: int) -> np.ndarray:
    """x[..., (L+1)^2] -> U[..., 2L+1, 2L+1] complex."""
    return np.einsum("iuv,...i->...uv", sh2f_dense(L), x)


def f2sh(grid: np.ndarray, L_out: int) -> np.ndarray:
    """U[..., 2N+1, 2N+1] -> x[..., (L_out+1)^2] real."""
    n_grid = (grid.shape[-1] - 1) // 2
    z = f2sh_dense(L_out, n_grid)
    return np.real(np.einsum("iuv,...uv->...i", z, grid))


def conv2d_full(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Full 2D convolution of (...,2N1+1,2N1+1) with (...,2N2+1,2N2+1)."""
    n1 = a.shape[-1]
    n2 = b.shape[-1]
    out_n = n1 + n2 - 1
    out = np.zeros(np.broadcast_shapes(a.shape[:-2], b.shape[:-2]) + (out_n, out_n),
                   dtype=np.result_type(a, b))
    for i in range(n1):
        for j in range(n1):
            out[..., i : i + n2, j : j + n2] += a[..., i : i + 1, j : j + 1] * b
    return out


def conv2d_fft(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Same as conv2d_full via FFT (zero-padded)."""
    n1, n2 = a.shape[-1], b.shape[-1]
    n = n1 + n2 - 1
    fa = np.fft.fft2(a, s=(n, n))
    fb = np.fft.fft2(b, s=(n, n))
    return np.fft.ifft2(fa * fb)


def gaunt_tp(x1: np.ndarray, L1: int, x2: np.ndarray, L2: int, L3: int,
             use_fft: bool = False) -> np.ndarray:
    """Reference Gaunt tensor product via the Fourier pipeline.

    x1[..., (L1+1)^2] (x) x2[..., (L2+1)^2] -> x3[..., (L3+1)^2], equal to
    the direct contraction with the real Gaunt tensor (tested).
    """
    u1 = sh2f(x1, L1)
    u2 = sh2f(x2, L2)
    u3 = (conv2d_fft if use_fft else conv2d_full)(u1, u2)
    return f2sh(u3, L3)


def gaunt_tp_direct(x1: np.ndarray, L1: int, x2: np.ndarray, L2: int,
                    L3: int) -> np.ndarray:
    """Direct O(L^6) contraction with the quadrature Gaunt tensor (oracle)."""
    g = so3.gaunt_tensor_real(L1, L2, L3)
    return np.einsum("kij,...i,...j->...k", g, x1, x2)


# --------------------------------------------------------------------------
# packed per-|v| panel tables (kernel/Rust format)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def sh2f_panels(L: int) -> np.ndarray:
    """P[s, u, l] complex, shape [L+1, 2L+1, L+1]; zero where l < s.

    With W[l, s] = x_{l,0} (s=0) or (sqrt2/2)(x_{l,s} - i x_{l,-s}) (s>0):
      U[u, L+s] = sum_l P[s, u, l] W[l, s]
      U[u, L-s] = sum_l P[s, u, l] conj(W[l, s])
    """
    p = np.zeros((L + 1, 2 * L + 1, L + 1), dtype=np.complex128)
    for s in range(L + 1):
        for l in range(s, L + 1):
            pf = theta_fourier(l, s)  # u = -l..l
            p[s, L - l : L + l + 1, l] = pf
    return p


@lru_cache(maxsize=None)
def f2sh_panels(L_out: int, n_grid: int) -> np.ndarray:
    """T[s, l, u] complex, shape [L_out+1, L_out+1, 2*n_grid+1].

    x3_{l,0}  = 2 pi      Re sum_u T[0,l,u] U[u, N]
    x3_{l,+s} = sqrt2 pi  Re sum_u T[s,l,u] (U[u, N+s] + U[u, N-s])
    x3_{l,-s} = sqrt2 pi  Re sum_u i T[s,l,u] (U[u, N+s] - U[u, N-s])
    (prefactors folded into the table here: see apply_f2sh_panels.)
    """
    t = np.zeros((L_out + 1, L_out + 1, 2 * n_grid + 1), dtype=np.complex128)
    for s in range(L_out + 1):
        for l in range(s, L_out + 1):
            t[s, l] = theta_projection(l, s, n_grid)
    return t


def apply_sh2f_panels(x: np.ndarray, L: int) -> np.ndarray:
    """O(L^3) panel form of sh2f; x[..., (L+1)^2] -> U[..., 2L+1, 2L+1]."""
    p = sh2f_panels(L)
    shp = x.shape[:-1]
    u = np.zeros(shp + (2 * L + 1, 2 * L + 1), dtype=np.complex128)
    w = np.zeros(shp + (L + 1, L + 1), dtype=np.complex128)  # [l, s]
    for l in range(L + 1):
        w[..., l, 0] = x[..., so3.lm_index(l, 0)]
        for s in range(1, l + 1):
            w[..., l, s] = SQRT2_OVER_2 * (
                x[..., so3.lm_index(l, s)] - 1j * x[..., so3.lm_index(l, -s)]
            )
    for s in range(L + 1):
        acc = np.einsum("ul,...l->...u", p[s], w[..., :, s])
        u[..., :, L + s] = acc
        if s > 0:
            u[..., :, L - s] = np.einsum(
                "ul,...l->...u", p[s], np.conj(w[..., :, s])
            )
    return u


def apply_f2sh_panels(grid: np.ndarray, L_out: int) -> np.ndarray:
    """O(L^3) panel form of f2sh."""
    n_grid = (grid.shape[-1] - 1) // 2
    t = f2sh_panels(L_out, n_grid)
    shp = grid.shape[:-2]
    x = np.zeros(shp + (so3.num_coeffs(L_out),))
    for s in range(L_out + 1):
        gp = grid[..., :, n_grid + s]
        gm = grid[..., :, n_grid - s]
        if s == 0:
            acc = 2.0 * math.pi * np.einsum("lu,...u->...l", t[0], gp)
            for l in range(L_out + 1):
                x[..., so3.lm_index(l, 0)] = np.real(acc[..., l])
        else:
            accp = math.sqrt(2.0) * math.pi * np.einsum(
                "lu,...u->...l", t[s], gp + gm
            )
            accm = math.sqrt(2.0) * math.pi * np.einsum(
                "lu,...u->...l", 1j * t[s], gp - gm
            )
            for l in range(s, L_out + 1):
                x[..., so3.lm_index(l, s)] = np.real(accp[..., l])
                x[..., so3.lm_index(l, -s)] = np.real(accm[..., l])
    return x


# --------------------------------------------------------------------------
# float32 re/im-packed tables exported to kernels and Rust
# --------------------------------------------------------------------------


def packed_tables_f32(L1: int, L2: int, L3: int):
    """Everything the Pallas kernels / Rust runtime need, float32, with the
    complex dimension split into a trailing re/im axis of size 2.

    Returns dict with:
      p1: [L1+1, 2L1+1, L1+1, 2]   sh2f panels for the left operand
      p2: [L2+1, 2L2+1, L2+1, 2]   sh2f panels for the right operand
      t3: [L3+1, L3+1, 2N+1, 2]    f2sh panels on the product grid,
                                   N = L1 + L2 (prefactors NOT folded)
    """

    def c2f(a):
        return np.stack([a.real, a.imag], axis=-1).astype(np.float32)

    n = L1 + L2
    return {
        "p1": c2f(sh2f_panels(L1)),
        "p2": c2f(sh2f_panels(L2)),
        "t3": c2f(f2sh_panels(L3, n)),
    }
