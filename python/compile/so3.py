"""SO(3) / O(3) representation-theory substrate (build-time, numpy).

Everything the Gaunt Tensor Product needs, implemented from scratch:

- associated Legendre functions (no Condon-Shortley phase),
- orthonormal **real** spherical harmonics (angular + differentiable
  Cartesian-polynomial forms),
- Wigner 3j symbols (Racah explicit sum, paper Eqn. (23)),
- Clebsch-Gordan coefficients (paper Eqn. (22)),
- **complex** Gaunt coefficients (3j product formula, paper Eqn. (24)),
- **real** Gaunt coefficients, by two independent routes that are
  cross-checked in tests:
    (a) exact Gauss-Legendre x trapezoid quadrature of the triple product,
    (b) unitary change of basis from the complex Gaunt tensor,
- real-basis Wigner 3j ("w3j", the tensor used by e3nn-style CG tensor
  products) via the same unitary transform,
- real Wigner-D matrices (numerically, from the equivariance of real SH),
  used by equivariance tests and by the eSCN rotation trick.

Conventions: real SH are orthonormal on S^2,
    Y_m^l(theta, phi) = N_l^{|m|} P_l^{|m|}(cos theta) * Phi_m(phi),
    Phi_m = sqrt(2) cos(m phi) [m>0], 1 [m=0], sqrt(2) sin(|m| phi) [m<0],
    N_l^m = sqrt((2l+1)/(4 pi) * (l-m)!/(l+m)!),
with *no* Condon-Shortley phase in P_l^m.

Flat irrep indexing: features of degree up to L are vectors of length
(L+1)^2 with entry (l, m) at index l*l + l + m (m = -l..l).
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

# --------------------------------------------------------------------------
# indexing helpers
# --------------------------------------------------------------------------


def lm_index(l: int, m: int) -> int:
    """Flat index of (l, m) in the (L+1)^2 irrep layout."""
    assert -l <= m <= l, (l, m)
    return l * l + l + m


def num_coeffs(L: int) -> int:
    """Dimension of a feature holding irreps of degree 0..L."""
    return (L + 1) ** 2


def lm_iter(L: int):
    """Iterate (l, m) pairs in flat order."""
    for l in range(L + 1):
        for m in range(-l, l + 1):
            yield l, m


# --------------------------------------------------------------------------
# factorials / associated Legendre
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return math.factorial(n) * 1.0 if n >= 0 else 0.0


def assoc_legendre(l: int, m: int, x: np.ndarray) -> np.ndarray:
    """P_l^m(x), 0 <= m <= l, WITHOUT the Condon-Shortley phase.

    Stable upward recurrence:
      P_m^m   = (2m-1)!! (1-x^2)^{m/2}
      P_{m+1}^m = x (2m+1) P_m^m
      (l-m) P_l^m = x (2l-1) P_{l-1}^m - (l+m-1) P_{l-2}^m
    """
    assert 0 <= m <= l
    x = np.asarray(x, dtype=np.float64)
    somx2 = np.sqrt(np.maximum(0.0, 1.0 - x * x))
    pmm = np.ones_like(x)
    fact = 1.0
    for _ in range(m):
        pmm = pmm * fact * somx2
        fact += 2.0
    if l == m:
        return pmm
    pmmp1 = x * (2 * m + 1) * pmm
    if l == m + 1:
        return pmmp1
    pll = pmmp1
    for ll in range(m + 2, l + 1):
        pll = (x * (2 * ll - 1) * pmmp1 - (ll + m - 1) * pmm) / (ll - m)
        pmm = pmmp1
        pmmp1 = pll
    return pll


def sh_norm(l: int, m: int) -> float:
    """Orthonormalization constant N_l^{|m|}."""
    m = abs(m)
    return math.sqrt((2 * l + 1) / (4.0 * math.pi) * _fact(l - m) / _fact(l + m))


# --------------------------------------------------------------------------
# real spherical harmonics (angular form)
# --------------------------------------------------------------------------


def real_sh_angular(l: int, m: int, theta: np.ndarray, phi: np.ndarray) -> np.ndarray:
    """Real orthonormal Y_m^l(theta, phi)."""
    p = assoc_legendre(l, abs(m), np.cos(theta)) * sh_norm(l, m)
    if m > 0:
        return p * math.sqrt(2.0) * np.cos(m * phi)
    if m < 0:
        return p * math.sqrt(2.0) * np.sin(-m * phi)
    return p


def real_sh_all(L: int, theta: np.ndarray, phi: np.ndarray) -> np.ndarray:
    """All real SH up to degree L, stacked last axis: shape (..., (L+1)^2)."""
    theta = np.asarray(theta, dtype=np.float64)
    out = np.zeros(theta.shape + (num_coeffs(L),))
    for l, m in lm_iter(L):
        out[..., lm_index(l, m)] = real_sh_angular(l, m, theta, phi)
    return out


def complex_sh(l: int, m: int, theta: np.ndarray, phi: np.ndarray) -> np.ndarray:
    """Complex orthonormal SH with Condon-Shortley phase (physics convention).

    Y_l^m = (-1)^m N_l^{|m|} P_l^{|m|}(cos th) e^{i m phi}  [m >= 0]
    Y_l^{-m} = (-1)^m conj(Y_l^m)
    """
    am = abs(m)
    p = assoc_legendre(l, am, np.cos(theta)) * sh_norm(l, am)
    if m >= 0:
        return ((-1.0) ** m) * p * np.exp(1j * m * phi)
    # Y_l^{-am} = (-1)^am conj(Y_l^am)
    return p * np.exp(-1j * am * phi)


# --------------------------------------------------------------------------
# quadrature on the sphere (exact for band-limited integrands)
# --------------------------------------------------------------------------


def sphere_quadrature(deg: int):
    """Nodes/weights exact for products of SH with total degree <= deg.

    Gauss-Legendre in cos(theta) (exact for poly degree <= 2n-1) x uniform
    trapezoid in phi (exact for trig polys of degree < n_phi).
    Returns (theta[K], phi[J], w[K]) with total weight sum_k w_k * (2 pi/J)
    integrating over S^2.
    """
    n_theta = deg // 2 + 2
    x, w = np.polynomial.legendre.leggauss(n_theta)
    theta = np.arccos(x)
    n_phi = deg + 2
    phi = np.arange(n_phi) * (2.0 * math.pi / n_phi)
    return theta, phi, w, 2.0 * math.pi / n_phi


def sphere_integral(f_vals: np.ndarray, w: np.ndarray, dphi: float) -> np.ndarray:
    """Integrate f over S^2 given values f[K_theta, J_phi, ...]."""
    return np.tensordot(w, f_vals.sum(axis=1), axes=(0, 0)) * dphi


# --------------------------------------------------------------------------
# Wigner 3j, Clebsch-Gordan (paper Eqns. 22-23)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def wigner_3j(l1: int, l2: int, l3: int, m1: int, m2: int, m3: int) -> float:
    """Wigner 3j symbol via the Racah explicit sum (paper Eqn. (23))."""
    if m1 + m2 + m3 != 0:
        return 0.0
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return 0.0
    if abs(m1) > l1 or abs(m2) > l2 or abs(m3) > l3:
        return 0.0
    pref = math.sqrt(
        _fact(l1 + l2 - l3)
        * _fact(l1 - l2 + l3)
        * _fact(-l1 + l2 + l3)
        / _fact(l1 + l2 + l3 + 1)
    )
    pref *= math.sqrt(
        _fact(l1 - m1)
        * _fact(l1 + m1)
        * _fact(l2 - m2)
        * _fact(l2 + m2)
        * _fact(l3 - m3)
        * _fact(l3 + m3)
    )
    k_min = max(0, l2 - l3 - m1, l1 - l3 + m2)
    k_max = min(l1 + l2 - l3, l1 - m1, l2 + m2)
    s = 0.0
    for k in range(k_min, k_max + 1):
        den = (
            _fact(k)
            * _fact(l1 + l2 - l3 - k)
            * _fact(l1 - m1 - k)
            * _fact(l2 + m2 - k)
            * _fact(l3 - l2 + m1 + k)
            * _fact(l3 - l1 - m2 + k)
        )
        s += ((-1.0) ** k) / den
    return ((-1.0) ** (l1 - l2 - m3)) * pref * s


def clebsch_gordan(l1: int, m1: int, l2: int, m2: int, l: int, m: int) -> float:
    """C^{(l,m)}_{(l1,m1)(l2,m2)} from the 3j symbol (paper Eqn. (22))."""
    if m1 + m2 != m:
        return 0.0
    return ((-1.0) ** (-l1 + l2 - m)) * math.sqrt(2 * l + 1) * wigner_3j(
        l1, l2, l, m1, m2, -m
    )


def gaunt_complex(l1: int, m1: int, l2: int, m2: int, l3: int, m3: int) -> float:
    """Complex Gaunt coefficient: integral of three complex SH (Eqn. (24))."""
    return (
        math.sqrt(
            (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1) / (4.0 * math.pi)
        )
        * wigner_3j(l1, l2, l3, 0, 0, 0)
        * wigner_3j(l1, l2, l3, m1, m2, m3)
    )


# --------------------------------------------------------------------------
# real <-> complex SH unitary and real Gaunt / real w3j tensors
# --------------------------------------------------------------------------


def real_to_complex_u(l: int) -> np.ndarray:
    """U with Y^R_m = sum_mu U[m, mu] Y^C_mu (rows m=-l..l, cols mu=-l..l)."""
    dim = 2 * l + 1
    u = np.zeros((dim, dim), dtype=np.complex128)
    c = l  # center offset
    u[c + 0, c + 0] = 1.0
    s = math.sqrt(0.5)
    for m in range(1, l + 1):
        # Y^R_m  = s * ((-1)^m Y^C_m + Y^C_{-m})
        u[c + m, c + m] = s * ((-1.0) ** m)
        u[c + m, c - m] = s
        # Y^R_{-m} = -i s * ((-1)^m Y^C_m - Y^C_{-m})
        u[c - m, c + m] = -1j * s * ((-1.0) ** m)
        u[c - m, c - m] = 1j * s
    return u


@lru_cache(maxsize=None)
def gaunt_tensor_real(L1: int, L2: int, L3: int) -> np.ndarray:
    """Real Gaunt tensor G[i3, i1, i2] = int Y^R_{i3} Y^R_{i1} Y^R_{i2} dOmega.

    Computed by exact quadrature (Gauss-Legendre x trapezoid); the complex
    3j route is cross-checked against this in tests.
    Shape: [(L3+1)^2, (L1+1)^2, (L2+1)^2].
    """
    deg = L1 + L2 + L3
    theta, phi, w, dphi = sphere_quadrature(deg)
    th, ph = np.meshgrid(theta, phi, indexing="ij")
    y1 = real_sh_all(L1, th, ph)  # [K, J, n1]
    y2 = real_sh_all(L2, th, ph)
    y3 = real_sh_all(L3, th, ph)
    # integral of y3 * y1 * y2 over the sphere
    wgrid = w[:, None] * dphi
    t = np.einsum("kja,kjb,kjc,kj->abc", y3, y1, y2, wgrid, optimize=True)
    t[np.abs(t) < 1e-12] = 0.0
    return t


@lru_cache(maxsize=None)
def gaunt_tensor_real_from_3j(L1: int, L2: int, L3: int) -> np.ndarray:
    """Real Gaunt tensor via U-transform of the complex Gaunt tensor."""
    n1, n2, n3 = num_coeffs(L1), num_coeffs(L2), num_coeffs(L3)
    out = np.zeros((n3, n1, n2))
    for l1 in range(L1 + 1):
        u1 = real_to_complex_u(l1)
        for l2 in range(L2 + 1):
            u2 = real_to_complex_u(l2)
            for l3 in range(L3 + 1):
                if (l1 + l2 + l3) % 2 != 0:
                    continue  # complex Gaunt vanishes for odd sums
                if not (abs(l1 - l2) <= l3 <= l1 + l2):
                    continue
                u3 = real_to_complex_u(l3)
                gc = np.zeros((2 * l3 + 1, 2 * l1 + 1, 2 * l2 + 1))
                for m1 in range(-l1, l1 + 1):
                    for m2 in range(-l2, l2 + 1):
                        m3 = -(m1 + m2)
                        if abs(m3) > l3:
                            continue
                        # int Y^C_{m3'} with m3' index: G^C(l1 m1, l2 m2, l3 m3)
                        gc[l3 + m3, l1 + m1, l2 + m2] = gaunt_complex(
                            l1, m1, l2, m2, l3, m3
                        )
                blk = np.einsum("ax,by,cz,xyz->abc", u3, u1, u2, gc.astype(complex))
                assert np.abs(blk.imag).max() < 1e-10
                out[
                    lm_index(l3, -l3) : lm_index(l3, l3) + 1,
                    lm_index(l1, -l1) : lm_index(l1, l1) + 1,
                    lm_index(l2, -l2) : lm_index(l2, l2) + 1,
                ] = blk.real
    out[np.abs(out) < 1e-12] = 0.0
    return out


@lru_cache(maxsize=None)
def w3j_real(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis Wigner 3j tensor (the e3nn-style CG coupling tensor).

    Computed by U-transform of the complex 3j; for odd l1+l2+l3 the raw
    transform is purely imaginary and we keep the imaginary part (this is the
    standard phase choice making the tensor real and SO(3)-equivariant).
    Shape [2l1+1, 2l2+1, 2l3+1]; normalized so sum of squares = 1 when
    the triangle inequality holds.
    """
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    u1, u2, u3 = real_to_complex_u(l1), real_to_complex_u(l2), real_to_complex_u(l3)
    t = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1), dtype=np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = -(m1 + m2)
            if abs(m3) > l3:
                continue
            t[l1 + m1, l2 + m2, l3 + m3] = wigner_3j(l1, l2, l3, m1, m2, m3)
    out = np.einsum("ax,by,cz,xyz->abc", u1, u2, u3, t)
    if (l1 + l2 + l3) % 2 == 0:
        assert np.abs(out.imag).max() < 1e-10
        res = out.real
    else:
        assert np.abs(out.real).max() < 1e-10
        res = out.imag
    res[np.abs(res) < 1e-12] = 0.0
    return res


@lru_cache(maxsize=None)
def cg_tensor_real(L1: int, L2: int, L3: int) -> np.ndarray:
    """Full real CG coupling tensor C[i3, i1, i2] for the CG-TP baseline.

    Uses the real-basis w3j with the sqrt(2l3+1) CG normalization, summing
    all (l1, l2) -> l3 paths with unit path weights (the paper's *full*
    tensor product of Eqn. (1)).
    """
    n1, n2, n3 = num_coeffs(L1), num_coeffs(L2), num_coeffs(L3)
    out = np.zeros((n3, n1, n2))
    for l1 in range(L1 + 1):
        for l2 in range(L2 + 1):
            for l3 in range(abs(l1 - l2), min(L3, l1 + l2) + 1):
                w = w3j_real(l1, l2, l3) * math.sqrt(2 * l3 + 1)
                out[
                    lm_index(l3, -l3) : lm_index(l3, l3) + 1,
                    lm_index(l1, -l1) : lm_index(l1, l1) + 1,
                    lm_index(l2, -l2) : lm_index(l2, l2) + 1,
                ] += np.transpose(w, (2, 0, 1))
    return out


# --------------------------------------------------------------------------
# rotations, real Wigner-D
# --------------------------------------------------------------------------


def rot_z(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])


def rot_y(a: float) -> np.ndarray:
    c, s = math.cos(a), math.sin(a)
    return np.array([[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]])


def euler_zyz(alpha: float, beta: float, gamma: float) -> np.ndarray:
    """Rotation matrix R = Rz(alpha) Ry(beta) Rz(gamma)."""
    return rot_z(alpha) @ rot_y(beta) @ rot_z(gamma)


def random_rotation(rng: np.random.Generator) -> np.ndarray:
    """Haar-ish random rotation via QR of a Gaussian matrix."""
    a = rng.standard_normal((3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def xyz_to_angles(r: np.ndarray):
    """(theta, phi) of unit vectors r[..., 3]; theta from +z, phi from +x."""
    r = np.asarray(r, dtype=np.float64)
    n = np.linalg.norm(r, axis=-1, keepdims=True)
    u = r / np.maximum(n, 1e-30)
    theta = np.arccos(np.clip(u[..., 2], -1.0, 1.0))
    phi = np.arctan2(u[..., 1], u[..., 0])
    return theta, phi


def real_sh_xyz(L: int, r: np.ndarray) -> np.ndarray:
    """Real SH of unit vectors given in Cartesian form: shape (..., (L+1)^2)."""
    theta, phi = xyz_to_angles(r)
    return real_sh_all(L, theta, phi)


@lru_cache(maxsize=None)
def _wigner_d_lstsq_points(l: int) -> np.ndarray:
    rng = np.random.default_rng(12345 + l)
    pts = rng.standard_normal((max(64, 8 * (2 * l + 1)), 3))
    return pts / np.linalg.norm(pts, axis=1, keepdims=True)


def wigner_d_real(l: int, rot: np.ndarray) -> np.ndarray:
    """Real Wigner-D matrix D^l(R) with Y^l(R r) = D^l(R) Y^l(r).

    Solved exactly (machine precision) by least squares over sample points —
    SH equivariance makes the system consistent.
    """
    pts = _wigner_d_lstsq_points(l)
    y = real_sh_xyz(l, pts)[:, lm_index(l, -l) : lm_index(l, l) + 1]
    yr = real_sh_xyz(l, pts @ rot.T)[:, lm_index(l, -l) : lm_index(l, l) + 1]
    d, *_ = np.linalg.lstsq(y, yr, rcond=None)
    return d.T


def wigner_d_real_block(L: int, rot: np.ndarray) -> np.ndarray:
    """Block-diagonal real Wigner-D acting on a full (L+1)^2 feature."""
    n = num_coeffs(L)
    out = np.zeros((n, n))
    for l in range(L + 1):
        sl = slice(lm_index(l, -l), lm_index(l, l) + 1)
        out[sl, sl] = wigner_d_real(l, rot)
    return out


def align_to_y(r: np.ndarray) -> np.ndarray:
    """Rotation R with R r/||r|| = (0, 1, 0) — the eSCN alignment trick."""
    u = np.asarray(r, dtype=np.float64)
    u = u / np.linalg.norm(u)
    y = np.array([0.0, 1.0, 0.0])
    v = np.cross(u, y)
    c = float(u @ y)
    if c < -1.0 + 1e-12:  # antiparallel: rotate pi about x
        return np.diag([1.0, -1.0, -1.0])
    vx = np.array([[0, -v[2], v[1]], [v[2], 0, -v[0]], [-v[1], v[0], 0]])
    return np.eye(3) + vx + vx @ vx / (1.0 + c)


# --------------------------------------------------------------------------
# Cartesian polynomial form of real SH (differentiable evaluation tables)
# --------------------------------------------------------------------------


@lru_cache(maxsize=None)
def sh_monomial_table(L: int):
    """Coefficients expressing each real SH of degree l as a homogeneous
    degree-l polynomial in (x, y, z) on the unit sphere.

    Returns (exps, coefs): exps[l] is an int array [n_mono_l, 3] of
    (a, b, c) exponents with a+b+c = l; coefs[l] is [2l+1, n_mono_l] with
    Y_m^l(r) = sum_k coefs[l][m+l, k] * x^a y^b z^c.  Solved to machine
    precision by least squares on oversampled random unit vectors.
    """
    rng = np.random.default_rng(777)
    exps, coefs = [], []
    for l in range(L + 1):
        e = np.array(
            [(a, b, l - a - b) for a in range(l + 1) for b in range(l - a + 1)],
            dtype=np.int64,
        ).reshape(-1, 3)
        npts = 6 * max(len(e), 2 * l + 1) + 16
        pts = rng.standard_normal((npts, 3))
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        mono = np.prod(pts[:, None, :] ** e[None, :, :], axis=2)  # [npts, nmono]
        ysh = real_sh_xyz(l, pts)[:, lm_index(l, -l) : lm_index(l, l) + 1]
        sol, *_ = np.linalg.lstsq(mono, ysh, rcond=None)  # [nmono, 2l+1]
        sol[np.abs(sol) < 1e-11] = 0.0
        exps.append(e)
        coefs.append(sol.T.copy())
    return exps, coefs


def real_sh_xyz_poly(L: int, r: np.ndarray) -> np.ndarray:
    """Evaluate real SH via the polynomial tables (numpy; pole-free)."""
    exps, coefs = sh_monomial_table(L)
    r = np.asarray(r, dtype=np.float64)
    u = r / np.linalg.norm(r, axis=-1, keepdims=True)
    out = np.zeros(r.shape[:-1] + (num_coeffs(L),))
    for l in range(L + 1):
        mono = np.prod(u[..., None, :] ** exps[l][None, :, :], axis=-1)
        out[..., lm_index(l, -l) : lm_index(l, l) + 1] = mono @ coefs[l].T
    return out
