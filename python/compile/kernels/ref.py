"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: dense einsum contractions against
the exact coefficient tables from so3.py / fourier.py.  Slow (O(L^4)/O(L^6))
but unambiguous.  pytest asserts kernel == oracle across shapes/dtypes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import fourier as fr
from .. import so3


def sh2f_ref(x: jnp.ndarray, L: int) -> jnp.ndarray:
    """x[..., (L+1)^2] -> complex grid [..., 2L+1, 2L+1] (dense table)."""
    t = jnp.asarray(fr.sh2f_dense(L), dtype=jnp.complex64 if x.dtype == jnp.float32
                    else jnp.complex128)
    return jnp.einsum("iuv,...i->...uv", t, x.astype(t.dtype))


def f2sh_ref(grid: jnp.ndarray, L_out: int) -> jnp.ndarray:
    """complex grid [..., 2N+1, 2N+1] -> x[..., (L_out+1)^2]."""
    n_grid = (grid.shape[-1] - 1) // 2
    z = np.asarray(fr.f2sh_dense(L_out, n_grid))
    zt = jnp.asarray(z, dtype=grid.dtype)
    return jnp.real(jnp.einsum("iuv,...uv->...i", zt, grid))


def conv2d_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Full 2D convolution, shift-and-accumulate."""
    n1 = a.shape[-1]
    n2 = b.shape[-1]
    out_n = n1 + n2 - 1
    shape = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2]) + (out_n, out_n)
    out = jnp.zeros(shape, dtype=jnp.result_type(a, b))
    for i in range(n1):
        for j in range(n1):
            out = out.at[..., i : i + n2, j : j + n2].add(
                a[..., i : i + 1, j : j + 1] * b
            )
    return out


def gaunt_tp_ref(x1: jnp.ndarray, x2: jnp.ndarray, L1: int, L2: int,
                 L3: int) -> jnp.ndarray:
    """Direct contraction with the exact real Gaunt tensor (independent of
    the Fourier pipeline entirely — quadrature ground truth)."""
    g = jnp.asarray(so3.gaunt_tensor_real(L1, L2, L3), dtype=x1.dtype)
    return jnp.einsum("kij,...i,...j->...k", g, x1, x2)


def gaunt_tp_fourier_ref(x1: jnp.ndarray, x2: jnp.ndarray, L1: int, L2: int,
                         L3: int) -> jnp.ndarray:
    """Fourier-pipeline reference built from the dense (unpacked) tables."""
    u1 = sh2f_ref(x1, L1)
    u2 = sh2f_ref(x2, L2)
    return f2sh_ref(conv2d_ref(u1, u2), L3)


def cg_tp_ref(x1: jnp.ndarray, x2: jnp.ndarray, L1: int, L2: int,
              L3: int) -> jnp.ndarray:
    """Full Clebsch-Gordan tensor product (paper Eqn. (1)), dense."""
    c = jnp.asarray(so3.cg_tensor_real(L1, L2, L3), dtype=x1.dtype)
    return jnp.einsum("kij,...i,...j->...k", c, x1, x2)


def scale_by_degree(x: jnp.ndarray, w: jnp.ndarray, L: int) -> jnp.ndarray:
    """Multiply each degree-l segment of x[..., (L+1)^2] by w[..., l] —
    the paper's w_{l1} * w_{l2} * w_l reparameterization (Sec 3.3)."""
    reps = np.concatenate([np.full(2 * l + 1, l) for l in range(L + 1)])
    return x * jnp.take(w, jnp.asarray(reps), axis=-1)


def many_body_ref(xs, L: int, L_out: int) -> jnp.ndarray:
    """nu-fold Gaunt product via repeated direct contraction (left fold)."""
    acc = xs[0]
    l_acc = L
    for x in xs[1:]:
        acc = gaunt_tp_ref(acc, x, l_acc, L, l_acc + L)
        l_acc += L
    n_out = so3.num_coeffs(L_out)
    return acc[..., :n_out]
