"""Layer-1 Pallas kernels for the paper's compute hot-spot."""
from . import cg_tp, gaunt_tp, ref  # noqa: F401
