"""Layer-1 Pallas kernel for the Clebsch-Gordan tensor product baseline.

The paper's O(L^6) reference point (Eqn. (1)): a dense contraction of the
full real CG coupling tensor C[k, i, j] with the two inputs.  Kept as a
kernel so the Fig. 1 efficiency comparison can run both paths through the
identical execution stack (same PJRT runtime, same batching).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import so3

# Perf pass #2 (EXPERIMENTS.md §Perf): interpret-mode pallas lowers the
# grid to an XLA while-loop that the CPU backend executes serially per
# block; a large default block makes typical calls single-block (grid=1)
# and lets XLA fuse the whole panel contraction.  On real TPU hardware the
# block size would instead be tiled to VMEM (see DESIGN.md §4).
DEFAULT_BLOCK_B = 4096


def _cg_tp_kernel(x1_ref, x2_ref, c_ref, o_ref):
    """o[b, k] = sum_{i,j} C[k,i,j] x1[b,i] x2[b,j].

    Contracted as (x1 . C) then (. x2): two matmul-shaped steps so the MXU
    sees dense panels rather than a 3D gather.
    """
    x1 = x1_ref[...]
    x2 = x2_ref[...]
    c = c_ref[...]
    # t[b, k, j] = sum_i x1[b, i] C[k, i, j]
    t = jnp.einsum("bi,kij->bkj", x1, c)
    o_ref[...] = jnp.einsum("bkj,bj->bk", t, x2)


@functools.lru_cache(maxsize=None)
def make_cg_tp(L1: int, L2: int, L3: int, block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = True):
    """Factory: batched full CG tensor product [B,(L1+1)^2] x [B,(L2+1)^2]
    -> [B,(L3+1)^2] (differentiable via custom VJP with the transposed
    contractions)."""
    c_np = so3.cg_tensor_real(L1, L2, L3)

    def run(x1, x2):
        dt = x1.dtype
        c = jnp.asarray(c_np, dt)
        b = x1.shape[0]
        pad = (-b) % block_b
        if pad:
            x1 = jnp.concatenate([x1, jnp.zeros((pad, x1.shape[1]), dt)], 0)
            x2 = jnp.concatenate([x2, jnp.zeros((pad, x2.shape[1]), dt)], 0)
        bp = x1.shape[0]
        n1, n2, n3 = x1.shape[1], x2.shape[1], c_np.shape[0]
        out = pl.pallas_call(
            _cg_tp_kernel,
            grid=(bp // block_b,),
            in_specs=[
                pl.BlockSpec((block_b, n1), lambda i: (i, 0)),
                pl.BlockSpec((block_b, n2), lambda i: (i, 0)),
                pl.BlockSpec((n3, n1, n2), lambda i: (0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((block_b, n3), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, n3), dt),
            interpret=interpret,
        )(x1, x2, c)
        return out[:b]

    @jax.custom_vjp
    def cg_tp(x1, x2):
        return run(x1, x2)

    def fwd(x1, x2):
        # call the *wrapped* op (not raw pallas) so nested differentiation
        # (grad-of-grad, as in force-matching losses) re-enters the
        # custom_vjp rule instead of trying to linearize pallas_call.
        return cg_tp(x1, x2), (x1, x2)

    def bwd(res, g):
        x1, x2 = res
        dt = x1.dtype
        c = jnp.asarray(c_np, dt)
        d1 = jnp.einsum("bk,kij,bj->bi", g, c, x2)
        d2 = jnp.einsum("bk,kij,bi->bj", g, c, x1)
        return d1, d2

    cg_tp.defvjp(fwd, bwd)
    return cg_tp
