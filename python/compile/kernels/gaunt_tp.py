"""Layer-1 Pallas kernels for the Gaunt Tensor Product (paper Section 3.2).

The O(L^3) pipeline is three stages:

  1. sh2f   — SH coefficients -> 2D Fourier grid, exploiting the m = +-v
              sparsity as dense per-|v| matmul *panels* (MXU-friendly);
  2. conv2d — multiplication of spherical functions == 2D convolution of
              the coefficient grids.  Two paths: a direct Pallas kernel
              (small L) and XLA's `fft` op (O(L^2 log L), large L);
  3. f2sh   — project the product grid back onto SH coefficients, again
              per-|v| panels.

All kernels use real arithmetic with an explicit re/im split (stacked
float planes): TPU Pallas has no complex registers, and this keeps the
inner loops pure MXU matmuls.  Kernels are lowered with interpret=True —
the CPU PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).

Differentiation: the Gaunt TP is bilinear with a *fully symmetric*
coupling tensor (the Gaunt integral is symmetric in all three SH), so the
VJP is again a Gaunt TP:  d/dx1 <g, G(x1,x2)> = G(g, x2) truncated to L1.
We register that as a custom_vjp so forces (-dE/dr) flow through the
Pallas kernels.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import fourier as fr
from .. import so3

# Perf pass #2 (EXPERIMENTS.md §Perf): interpret-mode pallas lowers the
# grid to an XLA while-loop that the CPU backend executes serially per
# block; a large default block makes typical calls single-block (grid=1)
# and lets XLA fuse the whole panel contraction.  On real TPU hardware the
# block size would instead be tiled to VMEM (see DESIGN.md §4).
DEFAULT_BLOCK_B = 4096


# --------------------------------------------------------------------------
# kernel bodies
# --------------------------------------------------------------------------


def _sh2f_kernel(w_re_ref, w_im_ref, p_re_ref, p_im_ref,
                 up_re_ref, up_im_ref, um_re_ref, um_im_ref):
    """Panel contraction: out[b,u,s] = sum_l P[s,u,l] * W[b,l,s].

    up = P * W (v = +s half), um = P * conj(W) (v = -s half).
    Shapes: W [B, L+1, L+1] (l, s), P [L+1, 2L+1, L+1] (s, u, l).
    """
    w_re = w_re_ref[...]
    w_im = w_im_ref[...]
    p_re = p_re_ref[...]
    p_im = p_im_ref[...]
    a = jnp.einsum("sul,bls->bus", p_re, w_re)
    b = jnp.einsum("sul,bls->bus", p_im, w_im)
    c = jnp.einsum("sul,bls->bus", p_re, w_im)
    d = jnp.einsum("sul,bls->bus", p_im, w_re)
    up_re_ref[...] = a - b
    up_im_ref[...] = c + d
    um_re_ref[...] = a + b
    um_im_ref[...] = d - c


def _f2sh_kernel(gp_re_ref, gp_im_ref, gm_re_ref, gm_im_ref,
                 t_re_ref, t_im_ref, xp_ref, xm_ref):
    """Panel back-projection.

    gp[b,u,s] = U3[b, u, N+s], gm[b,u,s] = U3[b, u, N-s].
    xp[b,s,l] = Re sum_u T[s,l,u] (gp+gm)   (-> m = +s, and m = 0 via s=0)
    xm[b,s,l] = Re sum_u i T[s,l,u] (gp-gm) (-> m = -s)
    Prefactors (pi, sqrt2 pi) are applied by the host-side glue.
    """
    gp_re = gp_re_ref[...]
    gp_im = gp_im_ref[...]
    gm_re = gm_re_ref[...]
    gm_im = gm_im_ref[...]
    t_re = t_re_ref[...]
    t_im = t_im_ref[...]
    sp_re = gp_re + gm_re
    sp_im = gp_im + gm_im
    sm_re = gp_re - gm_re
    sm_im = gp_im - gm_im
    xp_ref[...] = (
        jnp.einsum("slu,bus->bsl", t_re, sp_re)
        - jnp.einsum("slu,bus->bsl", t_im, sp_im)
    )
    xm_ref[...] = -(
        jnp.einsum("slu,bus->bsl", t_im, sm_re)
        + jnp.einsum("slu,bus->bsl", t_re, sm_im)
    )


def _conv2d_kernel(a_re_ref, a_im_ref, b_re_ref, b_im_ref, o_re_ref, o_im_ref):
    """Direct full 2D convolution (small-L path), complex via re/im planes."""
    a_re = a_re_ref[...]
    a_im = a_im_ref[...]
    b_re = b_re_ref[...]
    b_im = b_im_ref[...]
    n1 = a_re.shape[-1]
    n2 = b_re.shape[-1]
    if n1 == 1:  # degenerate L=0 grid: plain complex product
        o_re_ref[...] = a_re * b_re - a_im * b_im
        o_im_ref[...] = a_re * b_im + a_im * b_re
        return
    n = n1 + n2 - 1
    o_re = jnp.zeros(a_re.shape[:-2] + (n, n), a_re.dtype)
    o_im = jnp.zeros_like(o_re)
    for i in range(n1):
        for j in range(n1):
            ar = a_re[..., i : i + 1, j : j + 1]
            ai = a_im[..., i : i + 1, j : j + 1]
            o_re = o_re.at[..., i : i + n2, j : j + n2].add(ar * b_re - ai * b_im)
            o_im = o_im.at[..., i : i + n2, j : j + n2].add(ar * b_im + ai * b_re)
    o_re_ref[...] = o_re
    o_im_ref[...] = o_im


# --------------------------------------------------------------------------
# host-side glue (cheap O(L^2) reshuffles; jnp, differentiable)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _w_build_indices(L: int):
    """Index/scale arrays turning flat x[(L+1)^2] into W[l,s] re/im parts.

    w[l, 0] = x_{l,0};  w[l, s>0] = (sqrt2/2)(x_{l,s} - i x_{l,-s})
    Entries with s > l are zero (scale 0, index 0).
    """
    n = L + 1
    idx_re = np.zeros((n, n), dtype=np.int32)
    sc_re = np.zeros((n, n), dtype=np.float64)
    idx_im = np.zeros((n, n), dtype=np.int32)
    sc_im = np.zeros((n, n), dtype=np.float64)
    for l in range(n):
        idx_re[l, 0] = so3.lm_index(l, 0)
        sc_re[l, 0] = 1.0
        for s in range(1, l + 1):
            idx_re[l, s] = so3.lm_index(l, s)
            sc_re[l, s] = fr.SQRT2_OVER_2
            idx_im[l, s] = so3.lm_index(l, -s)
            sc_im[l, s] = -fr.SQRT2_OVER_2
    return idx_re, sc_re, idx_im, sc_im


def build_w(x: jnp.ndarray, L: int):
    """x[..., (L+1)^2] -> (w_re, w_im) of shape [..., L+1, L+1] (l, s)."""
    idx_re, sc_re, idx_im, sc_im = _w_build_indices(L)
    dt = x.dtype
    w_re = jnp.take(x, jnp.asarray(idx_re.ravel()), axis=-1) * jnp.asarray(
        sc_re.ravel(), dt
    )
    w_im = jnp.take(x, jnp.asarray(idx_im.ravel()), axis=-1) * jnp.asarray(
        sc_im.ravel(), dt
    )
    shape = x.shape[:-1] + (L + 1, L + 1)
    return w_re.reshape(shape), w_im.reshape(shape)


def assemble_grid(up_re, up_im, um_re, um_im):
    """(up, um)[..., u, s] -> complex-split grid [..., u, 2L+1] over v.

    v-axis layout: [L-s ... L ... L+s]; column v=L+s from up[:, :, s],
    column v=L-s from um[:, :, s]; center column is up s=0.
    """
    left_re = jnp.flip(um_re[..., 1:], axis=-1)
    left_im = jnp.flip(um_im[..., 1:], axis=-1)
    g_re = jnp.concatenate([left_re, up_re], axis=-1)
    g_im = jnp.concatenate([left_im, up_im], axis=-1)
    return g_re, g_im


def split_grid(g_re, g_im, S: int):
    """grid [..., u, 2N+1] -> gp, gm [..., u, S+1] (columns N+s / N-s)."""
    n = (g_re.shape[-1] - 1) // 2
    gp_re = g_re[..., n : n + S + 1]
    gp_im = g_im[..., n : n + S + 1]
    gm_re = jnp.flip(g_re[..., n - S : n + 1], axis=-1)
    gm_im = jnp.flip(g_im[..., n - S : n + 1], axis=-1)
    return gp_re, gp_im, gm_re, gm_im


@functools.lru_cache(maxsize=None)
def _scatter_indices(L3: int):
    """Flat (l,m) gather plan from xp/xm[s,l] planes."""
    n = so3.num_coeffs(L3)
    src = np.zeros(n, dtype=np.int32)
    use_m = np.zeros(n, dtype=np.float64)  # 1.0 -> take xm, 0.0 -> take xp
    scale = np.zeros(n, dtype=np.float64)
    for l, m in so3.lm_iter(L3):
        i = so3.lm_index(l, m)
        s = abs(m)
        src[i] = s * (L3 + 1) + l
        use_m[i] = 1.0 if m < 0 else 0.0
        scale[i] = math.pi if m == 0 else math.sqrt(2.0) * math.pi
    return src, use_m, scale


def scatter_flat(xp: jnp.ndarray, xm: jnp.ndarray, L3: int) -> jnp.ndarray:
    """xp, xm [..., S+1, L3+1] -> x3[..., (L3+1)^2] with prefactors.

    s=0 rows of xp already hold 2x the center column contribution (gp==gm),
    hence the pi (not 2 pi) prefactor from _scatter_indices.
    """
    src, use_m, scale = _scatter_indices(L3)
    dt = xp.dtype
    xpf = xp.reshape(xp.shape[:-2] + (-1,))
    xmf = xm.reshape(xm.shape[:-2] + (-1,))
    idx = jnp.asarray(src)
    sel = jnp.asarray(use_m, dt)
    sc = jnp.asarray(scale, dt)
    vp = jnp.take(xpf, idx, axis=-1)
    vm = jnp.take(xmf, idx, axis=-1)
    return (vp * (1.0 - sel) + vm * sel) * sc


# --------------------------------------------------------------------------
# pallas_call wrappers
# --------------------------------------------------------------------------


def _effective_block(b, block_b):
    """Single block when the batch fits (the common case); otherwise the
    configured tile."""
    return b if b <= block_b else block_b


def _pad_batch(x, block_b):
    b = x.shape[0]
    eb = _effective_block(b, block_b)
    pad = (-b) % eb
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x, b


def sh2f_pallas(x: jnp.ndarray, L: int, block_b: int = DEFAULT_BLOCK_B,
                interpret: bool = True):
    """Batched sh2f via the Pallas panel kernel.

    x[B, (L+1)^2] -> complex-split grid (g_re, g_im) [B, 2L+1, 2L+1].
    """
    p = fr.sh2f_panels(L)
    dt = x.dtype
    p_re = jnp.asarray(p.real, dt)
    p_im = jnp.asarray(p.imag, dt)
    w_re, w_im = build_w(x, L)
    w_re, b0 = _pad_batch(w_re, block_b)
    w_im, _ = _pad_batch(w_im, block_b)
    bp = w_re.shape[0]
    block_b = _effective_block(bp, block_b)
    n_s = L + 1
    n_u = 2 * L + 1
    grid = (bp // block_b,)
    blk_w = pl.BlockSpec((block_b, n_s, n_s), lambda i: (i, 0, 0))
    blk_p = pl.BlockSpec((n_s, n_u, n_s), lambda i: (0, 0, 0))
    blk_o = pl.BlockSpec((block_b, n_u, n_s), lambda i: (i, 0, 0))
    shp = jax.ShapeDtypeStruct((bp, n_u, n_s), dt)
    up_re, up_im, um_re, um_im = pl.pallas_call(
        _sh2f_kernel,
        grid=grid,
        in_specs=[blk_w, blk_w, blk_p, blk_p],
        out_specs=[blk_o, blk_o, blk_o, blk_o],
        out_shape=[shp, shp, shp, shp],
        interpret=interpret,
    )(w_re, w_im, p_re, p_im)
    g_re, g_im = assemble_grid(up_re, up_im, um_re, um_im)
    return g_re[:b0], g_im[:b0]


def f2sh_pallas(g_re: jnp.ndarray, g_im: jnp.ndarray, L3: int,
                block_b: int = DEFAULT_BLOCK_B, interpret: bool = True):
    """Batched f2sh via the Pallas panel kernel.

    grid [B, 2N+1, 2N+1] (complex split) -> x3 [B, (L3+1)^2].
    """
    n_grid = (g_re.shape[-1] - 1) // 2
    t = fr.f2sh_panels(L3, n_grid)
    dt = g_re.dtype
    t_re = jnp.asarray(t.real, dt)
    t_im = jnp.asarray(t.imag, dt)
    gp_re, gp_im, gm_re, gm_im = split_grid(g_re, g_im, L3)
    gp_re, b0 = _pad_batch(gp_re, block_b)
    gp_im, _ = _pad_batch(gp_im, block_b)
    gm_re, _ = _pad_batch(gm_re, block_b)
    gm_im, _ = _pad_batch(gm_im, block_b)
    bp = gp_re.shape[0]
    block_b = _effective_block(bp, block_b)
    n_s = L3 + 1
    n_u = 2 * n_grid + 1
    grid = (bp // block_b,)
    blk_g = pl.BlockSpec((block_b, n_u, n_s), lambda i: (i, 0, 0))
    blk_t = pl.BlockSpec((n_s, n_s, n_u), lambda i: (0, 0, 0))
    blk_o = pl.BlockSpec((block_b, n_s, n_s), lambda i: (i, 0, 0))
    shp = jax.ShapeDtypeStruct((bp, n_s, n_s), dt)
    xp, xm = pl.pallas_call(
        _f2sh_kernel,
        grid=grid,
        in_specs=[blk_g, blk_g, blk_g, blk_g, blk_t, blk_t],
        out_specs=[blk_o, blk_o],
        out_shape=[shp, shp],
        interpret=interpret,
    )(gp_re, gp_im, gm_re, gm_im, t_re, t_im)
    return scatter_flat(xp, xm, L3)[:b0]


def conv2d_pallas(a_re, a_im, b_re, b_im, block_b: int = DEFAULT_BLOCK_B,
                  interpret: bool = True):
    """Batched direct 2D convolution kernel (small-L path)."""
    n1, n2 = a_re.shape[-1], b_re.shape[-1]
    n = n1 + n2 - 1
    dt = a_re.dtype
    a_re, b0 = _pad_batch(a_re, block_b)
    a_im, _ = _pad_batch(a_im, block_b)
    b_re, _ = _pad_batch(b_re, block_b)
    b_im, _ = _pad_batch(b_im, block_b)
    bp = a_re.shape[0]
    block_b = _effective_block(bp, block_b)
    grid = (bp // block_b,)
    blk_a = pl.BlockSpec((block_b, n1, n1), lambda i: (i, 0, 0))
    blk_b = pl.BlockSpec((block_b, n2, n2), lambda i: (i, 0, 0))
    blk_o = pl.BlockSpec((block_b, n, n), lambda i: (i, 0, 0))
    shp = jax.ShapeDtypeStruct((bp, n, n), dt)
    o_re, o_im = pl.pallas_call(
        _conv2d_kernel,
        grid=grid,
        in_specs=[blk_a, blk_a, blk_b, blk_b],
        out_specs=[blk_o, blk_o],
        out_shape=[shp, shp],
        interpret=interpret,
    )(a_re, a_im, b_re, b_im)
    return o_re[:b0], o_im[:b0]


def conv2d_fft_xla(a_re, a_im, b_re, b_im):
    """FFT convolution path: XLA `fft` op between the two Pallas stages."""
    n1, n2 = a_re.shape[-1], b_re.shape[-1]
    n = n1 + n2 - 1
    a = (a_re + 1j * a_im).astype(jnp.complex64 if a_re.dtype == jnp.float32
                                  else jnp.complex128)
    b = (b_re + 1j * b_im).astype(a.dtype)
    fa = jnp.fft.fft2(a, s=(n, n))
    fb = jnp.fft.fft2(b, s=(n, n))
    o = jnp.fft.ifft2(fa * fb)
    return jnp.real(o).astype(a_re.dtype), jnp.imag(o).astype(a_re.dtype)


# --------------------------------------------------------------------------
# assembled Gaunt tensor product with custom VJP
# --------------------------------------------------------------------------


def _gaunt_tp_impl(x1, x2, L1: int, L2: int, L3: int, method: str,
                   block_b: int, interpret: bool):
    g1_re, g1_im = sh2f_pallas(x1, L1, block_b, interpret)
    g2_re, g2_im = sh2f_pallas(x2, L2, block_b, interpret)
    if method == "fft":
        o_re, o_im = conv2d_fft_xla(g1_re, g1_im, g2_re, g2_im)
    else:
        o_re, o_im = conv2d_pallas(g1_re, g1_im, g2_re, g2_im, block_b, interpret)
    return f2sh_pallas(o_re, o_im, L3, block_b, interpret)


@functools.lru_cache(maxsize=None)
def make_gaunt_tp(L1: int, L2: int, L3: int, method: str = "fft",
                  block_b: int = DEFAULT_BLOCK_B, interpret: bool = True):
    """Factory: differentiable batched Gaunt TP  [B,(L1+1)^2] x [B,(L2+1)^2]
    -> [B,(L3+1)^2].  The VJP reuses the same pipeline (full symmetry of the
    Gaunt tensor)."""

    @jax.custom_vjp
    def gaunt_tp(x1, x2):
        return _gaunt_tp_impl(x1, x2, L1, L2, L3, method, block_b, interpret)

    def fwd(x1, x2):
        return gaunt_tp(x1, x2), (x1, x2)

    def bwd(res, g):
        # The cotangent of a bilinear op with a fully symmetric coupling
        # tensor is the same op on (g, other input).  Resolving the wrapped
        # (custom_vjp) factories here — not the raw pallas impl — keeps the
        # backward pass itself differentiable, so force training (grad of a
        # loss on -dE/dr) composes to arbitrary order.
        x1, x2 = res
        d1 = make_gaunt_tp(L3, L2, L1, method, block_b, interpret)(g, x2)
        d2 = make_gaunt_tp(L3, L1, L2, method, block_b, interpret)(g, x1)
        return d1, d2

    gaunt_tp.defvjp(fwd, bwd)
    return gaunt_tp


def gaunt_tp_channelwise(x1, x2, L1, L2, L3, method="fft",
                         block_b=DEFAULT_BLOCK_B, interpret=True):
    """Channel-wise combination rule (paper Appendix C): inputs
    [B, C, (L+1)^2]; the C axis folds into the batch."""
    b, c = x1.shape[0], x1.shape[1]
    f = make_gaunt_tp(L1, L2, L3, method, block_b, interpret)
    out = f(x1.reshape(b * c, -1), x2.reshape(b * c, -1))
    return out.reshape(b, c, -1)
