"""Numpy mirror of the Rust `tp::vector` subsystem (vector-signal Gaunt
products over vector spherical harmonics).

This file is the *specification*: every convention the Rust side bakes in
(the real VSH basis, the Cartesian-component vector layout, the three
plan kinds and their VJP siblings, the parity laws under improper
rotations, the dipole readout head) is implemented here in numpy,
validated by exact quadrature / finite differences, and frozen into
`rust/artifacts/golden/vector_golden.json` for the Rust test suite
(`tests/golden_cross_validation.rs`) to cross-check.

Conventions
-----------

* A *vector signal* of degree <= L is stored as three Cartesian-component
  scalar SH signals in the crate's `Irreps::spherical(3, L)` layout:
  degree-major panels `[l][c][m]`, flat index `3 l^2 + c (2l+1) + (l+m)`.
  The component index c is in real l=1 irrep order: c=0 is the y
  component, c=1 is z, c=2 is x (so the constant field F(u) = u has
  coefficients sqrt(4 pi / 3) on the diagonal (c, m = c-1) of its l=1
  panel and nothing else).
* Real vector spherical harmonics:
      Y_{lm} rhat                    (radial,   parity (-1)^{l+1})
      Psi_{lm} = r grad Y / sqrt(l(l+1))   (gradient, parity (-1)^{l+1})
      Phi_{lm} = rhat x Psi_{lm}           (curl,     parity (-1)^l)
  all orthonormal under the S^2 inner product of vector fields.
* Plan kinds (pointwise products of fields, projected to degree l3):
      sv    : scalar (x) vector -> vector      out_c = P_l3(s v_c)
      dot   : vector (.) vector -> scalar      out   = sum_c P_l3(v_c w_c)
      cross : vector (x) vector -> pseudovector
* VJP siblings (the degree-rotation identity, closed under the family):
      sv(l1,l2,l3)    vjp_x1 = dot(l3,l2,l1) applied to (g, x2)
      dot(l1,l2,l3)   vjp_x1 = sv(l3,l2,l1)  applied to (g, x2)
      cross(l1,l2,l3) vjp_x1 = cross(l2,l3,l1) applied to (x2, g)

Run `python -m compile.vector_golden --check` to execute every assertion,
`--out DIR` to additionally write `DIR/golden/vector_golden.json`.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import numpy as np

from . import so3

# irrep component index -> xyz axis (c0 = y, c1 = z, c2 = x), and back
CART = (1, 2, 0)
IRR = (2, 0, 1)

SQRT_4PI = math.sqrt(4.0 * math.pi)


# --------------------------------------------------------------------------
# vector-signal layout (Irreps::spherical(3, L))
# --------------------------------------------------------------------------


def vec_dim(L: int) -> int:
    return 3 * so3.num_coeffs(L)


def vec_index(l: int, c: int, m: int) -> int:
    return 3 * l * l + c * (2 * l + 1) + (l + m)


def vec_panel(x: np.ndarray, l: int) -> np.ndarray:
    """View of the degree-l panel of a flat vector feature, shape [3, 2l+1]."""
    base = 3 * l * l
    return x[base : base + 3 * (2 * l + 1)].reshape(3, 2 * l + 1)


def vec_component(x: np.ndarray, L: int, c: int) -> np.ndarray:
    """Extract component c as a flat scalar SH feature of degree <= L."""
    out = np.zeros(so3.num_coeffs(L))
    for l in range(L + 1):
        out[so3.lm_index(l, -l) : so3.lm_index(l, l) + 1] = vec_panel(x, l)[c]
    return out


def vec_from_components(comps, L: int) -> np.ndarray:
    """Assemble a flat vector feature from 3 scalar features (irrep order)."""
    out = np.zeros(vec_dim(L))
    for l in range(L + 1):
        p = vec_panel(out, l)
        for c in range(3):
            p[c] = comps[c][so3.lm_index(l, -l) : so3.lm_index(l, l) + 1]
    return out


def rhat_signal() -> np.ndarray:
    """The constant degree-1 vector signal F(u) = u."""
    x = np.zeros(vec_dim(1))
    for c in range(3):
        x[vec_index(1, c, c - 1)] = SQRT_4PI / math.sqrt(3.0)
    return x


def field_eval(x: np.ndarray, L: int, u: np.ndarray) -> np.ndarray:
    """Evaluate the vector field (xyz components) at unit points u[N, 3]."""
    y = so3.real_sh_xyz(L, u)  # [N, (L+1)^2]
    out = np.zeros_like(u)
    for c in range(3):
        out[:, CART[c]] = y @ vec_component(x, L, c)
    return out


# --------------------------------------------------------------------------
# real vector spherical harmonics
# --------------------------------------------------------------------------


def sh_surface_grad(L: int, u: np.ndarray) -> np.ndarray:
    """Surface gradient of every real SH at unit points: [N, (L+1)^2, 3].

    Via the homogeneous monomial tables: Y_lm extends to a degree-l
    homogeneous polynomial P; on the sphere grad_S Y = grad P - l P u
    (already tangential by Euler's identity u . grad P = l P).
    """
    exps, coefs = so3.sh_monomial_table(L)
    n = so3.num_coeffs(L)
    u = np.asarray(u, dtype=np.float64)
    out = np.zeros((u.shape[0], n, 3))
    p_all = so3.real_sh_xyz_poly(L, u)
    for l in range(L + 1):
        e = exps[l]  # [nmono, 3]
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        grad = np.zeros((u.shape[0], 2 * l + 1, 3))
        for axis in range(3):
            de = e.copy()
            de[:, axis] = np.maximum(de[:, axis] - 1, 0)
            mono = np.prod(u[:, None, :] ** de[None, :, :], axis=2)
            mono = mono * e[:, axis][None, :]
            grad[:, :, axis] = mono @ coefs[l].T
        out[:, sl, :] = grad - l * p_all[:, sl, None] * u[:, None, :]
    return out


def vsh_eval(kind: str, l: int, m: int, u: np.ndarray) -> np.ndarray:
    """One real VSH at unit points u[N, 3] -> xyz vectors [N, 3]."""
    u = np.asarray(u, dtype=np.float64)
    i = so3.lm_index(l, m)
    if kind == "Y":
        return so3.real_sh_xyz_poly(l, u)[:, i, None] * u
    if l == 0:
        raise ValueError("Psi/Phi require l >= 1")
    psi = sh_surface_grad(l, u)[:, i, :] / math.sqrt(l * (l + 1))
    if kind == "Psi":
        return psi
    if kind == "Phi":
        return np.cross(u, psi)
    raise ValueError(f"unknown VSH kind {kind!r}")


def vsh_set(l_y: int, l_psi: int, l_phi: int):
    """The (kind, l, m) index list: Y to l_y, Psi/Phi from 1."""
    out = []
    for l in range(l_y + 1):
        for m in range(-l, l + 1):
            out.append(("Y", l, m))
    for l in range(1, l_psi + 1):
        for m in range(-l, l + 1):
            out.append(("Psi", l, m))
    for l in range(1, l_phi + 1):
        for m in range(-l, l + 1):
            out.append(("Phi", l, m))
    return out


def quad_points(deg: int):
    """Quadrature nodes as unit vectors [K*J, 3] with weights [K*J]."""
    theta, phi, w, dphi = so3.sphere_quadrature(deg)
    th, ph = np.meshgrid(theta, phi, indexing="ij")
    u = np.stack(
        [
            np.sin(th) * np.cos(ph),
            np.sin(th) * np.sin(ph),
            np.cos(th),
        ],
        axis=-1,
    ).reshape(-1, 3)
    wts = np.broadcast_to(w[:, None] * dphi, th.shape).reshape(-1)
    return u, wts


def vsh_dot_gaunt(L3: int, vset1, vset2, deg_margin: int = 4) -> np.ndarray:
    """T[k3, J1, J2] = int Y_{k3} (V_{J1} . V_{J2}) dOmega by quadrature."""
    lmax = max([l for _, l, _ in vset1] + [l for _, l, _ in vset2])
    u, w = quad_points(L3 + 2 * lmax + deg_margin)
    y3 = so3.real_sh_xyz(L3, u)  # [N, n3]
    v1 = np.stack([vsh_eval(k, l, m, u) for (k, l, m) in vset1])  # [J1, N, 3]
    v2 = np.stack([vsh_eval(k, l, m, u) for (k, l, m) in vset2])
    t = np.einsum("nk,anx,bnx,n->kab", y3, v1, v2, w, optimize=True)
    t[np.abs(t) < 1e-12] = 0.0
    return t


def vsh_project(F, vset, deg: int) -> np.ndarray:
    """Project a vector field (callable u -> [N,3]) onto a VSH set."""
    u, w = quad_points(deg)
    fv = F(u)
    return np.array(
        [np.einsum("nx,nx,n->", vsh_eval(k, l, m, u), fv, w) for (k, l, m) in vset]
    )


def cart_feature_from_vsh(coeffs: np.ndarray, vset, L_out: int) -> np.ndarray:
    """Convert VSH coefficients to the Cartesian-component layout (deg <= L_out)."""
    lmax = max(l for _, l, _ in vset)
    deg = L_out + lmax + 3
    u, w = quad_points(deg)
    fv = np.zeros((u.shape[0], 3))
    for a, (k, l, m) in enumerate(vset):
        fv += coeffs[a] * vsh_eval(k, l, m, u)
    y = so3.real_sh_xyz(L_out, u)
    comps = []
    for c in range(3):
        comps.append(np.einsum("ni,n,n->i", y, fv[:, CART[c]], w))
    return vec_from_components(comps, L_out)


# --------------------------------------------------------------------------
# the three plan kinds (exact Gaunt-tensor mirrors of VectorGauntPlan)
# --------------------------------------------------------------------------


def eps_irrep() -> np.ndarray:
    """Levi-Civita tensor re-indexed to irrep component order."""
    eps = np.zeros((3, 3, 3))
    for c in range(3):
        for a in range(3):
            for b in range(3):
                i, j, k = CART[c], CART[a], CART[b]
                if (i, j, k) in ((0, 1, 2), (1, 2, 0), (2, 0, 1)):
                    eps[c, a, b] = 1.0
                elif (i, j, k) in ((0, 2, 1), (2, 1, 0), (1, 0, 2)):
                    eps[c, a, b] = -1.0
    return eps


EPS = eps_irrep()


def apply_sv(l1: int, l2: int, l3: int, s: np.ndarray, v: np.ndarray) -> np.ndarray:
    g = so3.gaunt_tensor_real(l1, l2, l3)
    comps = [np.einsum("kij,i,j->k", g, s, vec_component(v, l2, c)) for c in range(3)]
    return vec_from_components(comps, l3)


def apply_dot(l1: int, l2: int, l3: int, v1: np.ndarray, v2: np.ndarray) -> np.ndarray:
    g = so3.gaunt_tensor_real(l1, l2, l3)
    out = np.zeros(so3.num_coeffs(l3))
    for c in range(3):
        out += np.einsum(
            "kij,i,j->k", g, vec_component(v1, l1, c), vec_component(v2, l2, c)
        )
    return out


def apply_cross(l1: int, l2: int, l3: int, v1: np.ndarray, v2: np.ndarray) -> np.ndarray:
    g = so3.gaunt_tensor_real(l1, l2, l3)
    c1 = [vec_component(v1, l1, c) for c in range(3)]
    c2 = [vec_component(v2, l2, c) for c in range(3)]
    comps = [np.zeros(so3.num_coeffs(l3)) for _ in range(3)]
    for c in range(3):
        for a in range(3):
            for b in range(3):
                e = EPS[c, a, b]
                if e != 0.0:
                    comps[c] += e * np.einsum("kij,i,j->k", g, c1[a], c2[b])
    return vec_from_components(comps, l3)


def plan_apply(kind: str, l1: int, l2: int, l3: int, x1, x2) -> np.ndarray:
    if kind == "sv":
        return apply_sv(l1, l2, l3, x1, x2)
    if kind == "dot":
        return apply_dot(l1, l2, l3, x1, x2)
    if kind == "cross":
        return apply_cross(l1, l2, l3, x1, x2)
    raise ValueError(kind)


def plan_vjp_x1(kind: str, l1: int, l2: int, l3: int, x2, g) -> np.ndarray:
    """d<g, plan(x1, x2)>/dx1 via the degree-rotated sibling plans."""
    if kind == "sv":
        return apply_dot(l3, l2, l1, g, x2)
    if kind == "dot":
        return apply_sv(l3, l2, l1, g, x2)
    if kind == "cross":
        return apply_cross(l2, l3, l1, x2, g)
    raise ValueError(kind)


def plan_dims(kind: str, l1: int, l2: int, l3: int):
    """(dim_x1, dim_x2, dim_out) for a plan kind."""
    n = so3.num_coeffs
    if kind == "sv":
        return n(l1), vec_dim(l2), vec_dim(l3)
    if kind == "dot":
        return vec_dim(l1), vec_dim(l2), n(l3)
    if kind == "cross":
        return vec_dim(l1), vec_dim(l2), vec_dim(l3)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# transforms: proper and improper rotations with the right parity
# --------------------------------------------------------------------------


def transform_scalar(x: np.ndarray, L: int, o: np.ndarray) -> np.ndarray:
    """Scalar signal under a (possibly improper) orthogonal map o."""
    det = float(np.sign(np.linalg.det(o)))
    r = o * det
    out = np.zeros_like(x)
    for l in range(L + 1):
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        out[sl] = (det**l) * (so3.wigner_d_real(l, r) @ x[sl])
    return out


def transform_vector(
    x: np.ndarray, L: int, o: np.ndarray, pseudo: bool = False
) -> np.ndarray:
    """Vector signal under o: components mix with D^1, each degree with D^l.

    A polar vector picks up det(o)^{l+1} per degree under an improper map,
    a pseudovector det(o)^l.
    """
    det = float(np.sign(np.linalg.det(o)))
    r = o * det
    d1 = so3.wigner_d_real(1, r)
    out = np.zeros_like(x)
    for l in range(L + 1):
        dl = so3.wigner_d_real(l, r)
        f = det**l if pseudo else det ** (l + 1)
        vec_panel(out, l)[:] = f * (d1 @ vec_panel(x, l) @ dl.T)
    return out


def plan_transform_io(kind: str, l1: int, l2: int, l3: int, x1, x2, o):
    """(T x1, T x2, out-transformer) under the plan's parity typing."""
    if kind == "sv":
        return (
            transform_scalar(x1, l1, o),
            transform_vector(x2, l2, o),
            lambda y: transform_vector(y, l3, o),
        )
    if kind == "dot":
        return (
            transform_vector(x1, l1, o),
            transform_vector(x2, l2, o),
            lambda y: transform_scalar(y, l3, o),
        )
    if kind == "cross":
        return (
            transform_vector(x1, l1, o),
            transform_vector(x2, l2, o),
            lambda y: transform_vector(y, l3, o, pseudo=True),
        )
    raise ValueError(kind)


# --------------------------------------------------------------------------
# dipole readout head (mirror of model::DipoleHead)
# --------------------------------------------------------------------------


def dipole_forward(h: np.ndarray, channels: int, L: int, w: np.ndarray, c_dip: float):
    """Per-atom dipole from node features h (Irreps::spherical(C, L) flat).

    s^c = w[(l, c)]-scaled channel c of h (per-degree path weights),
    t^c = sv(L, 1, L)(s^c, rhat),  d^c_k = <s^c, t^c_k>,
    mu = c_dip * sum_c d^c mapped from irrep to xyz order.

    Returns (mu_xyz[3], saved) with intermediates for the backward.
    """
    nf = so3.num_coeffs(L)
    rhat = rhat_signal()
    mu_irr = np.zeros(3)
    saved = []
    for c in range(channels):
        s = np.zeros(nf)
        for l in range(L + 1):
            sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
            s[sl] = w[l * channels + c] * h[_spherical_slot(h, channels, L, l, c)]
        t = apply_sv(L, 1, L, s, rhat)
        d = np.array([s @ vec_component(t, L, k) for k in range(3)])
        mu_irr += c_dip * d
        saved.append((s, t, d))
    mu = np.zeros(3)
    for k in range(3):
        mu[CART[k]] = mu_irr[k]
    return mu, saved


def _spherical_slot(h: np.ndarray, channels: int, L: int, l: int, c: int):
    base = channels * l * l + c * (2 * l + 1)
    return slice(base, base + 2 * l + 1)


def dipole_grads(
    h: np.ndarray, channels: int, L: int, w: np.ndarray, c_dip: float, g_mu: np.ndarray
):
    """Gradients of <g_mu, mu> w.r.t. (w, c_dip).  Mirrors the Rust backward:
    the quadratic form in s gives dL/ds = c_dip * (sum_k g_k t_k + vjp of the
    sv lift), then dL/dw via per-path dots against the unscaled channel."""
    nf = so3.num_coeffs(L)
    rhat = rhat_signal()
    g_irr = np.array([g_mu[CART[k]] for k in range(3)])
    _, saved = dipole_forward(h, channels, L, w, c_dip)
    gw = np.zeros_like(w)
    gc = 0.0
    for c in range(channels):
        s, t, d = saved[c]
        gc += float(g_irr @ d)
        # dL/ds from d_k = <s, t_k> (s appears twice: directly and inside t)
        gs = np.zeros(nf)
        for k in range(3):
            gs += c_dip * g_irr[k] * vec_component(t, L, k)
        gt = vec_from_components(
            [c_dip * g_irr[k] * s for k in range(3)], L
        )
        gs += plan_vjp_x1("sv", L, 1, L, rhat, gt)
        # dL/dw[(l, c)] = <gs_l, h^c_l>
        for l in range(L + 1):
            sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
            gw[l * channels + c] = gs[sl] @ h[_spherical_slot(h, channels, L, l, c)]
    return gw, gc


# --------------------------------------------------------------------------
# checks
# --------------------------------------------------------------------------


def check_vsh_orthonormality(L: int = 3):
    vset = vsh_set(L, L, L)
    u, w = quad_points(2 * L + 6)
    vals = np.stack([vsh_eval(k, l, m, u) for (k, l, m) in vset])
    gram = np.einsum("anx,bnx,n->ab", vals, vals, w)
    err = np.abs(gram - np.eye(len(vset))).max()
    assert err < 1e-10, f"VSH not orthonormal: {err}"
    return err


def check_vsh_completeness(L: int = 2, seed: int = 0):
    """A Cartesian vector signal of degree <= L expands exactly in
    {Y, Psi <= L+1, Phi <= L} — the truncation the Rust layout relies on."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(vec_dim(L))
    vset = vsh_set(L + 1, L + 1, L)
    coeffs = vsh_project(lambda u: field_eval(x, L, u), vset, 2 * L + 8)
    pts = rng.standard_normal((40, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    recon = np.zeros((40, 3))
    for a, (k, l, m) in enumerate(vset):
        recon += coeffs[a] * vsh_eval(k, l, m, pts)
    err = np.abs(recon - field_eval(x, L, pts)).max()
    assert err < 1e-9, f"VSH truncation incomplete: {err}"
    return err


def check_rhat_signal():
    pts = np.random.default_rng(1).standard_normal((20, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    err = np.abs(field_eval(rhat_signal(), 1, pts) - pts).max()
    assert err < 1e-12, f"rhat signal wrong: {err}"
    return err


def check_pointwise_semantics(seed: int = 2):
    """For l3 = l1 + l2 the plan output *is* the pointwise product field."""
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((30, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    l1, l2 = 2, 1
    l3 = l1 + l2
    s = rng.standard_normal(so3.num_coeffs(l1))
    v1 = rng.standard_normal(vec_dim(l1))
    v2 = rng.standard_normal(vec_dim(l2))
    sf = so3.real_sh_xyz(l1, pts) @ s
    f1 = field_eval(v1, l1, pts)
    f2 = field_eval(v2, l2, pts)

    out = apply_sv(l1, l2, l3, s, v2)
    err = np.abs(field_eval(out, l3, pts) - sf[:, None] * f2).max()
    assert err < 1e-9, f"sv pointwise: {err}"

    out = apply_dot(l1, l2, l3, v1, v2)
    got = so3.real_sh_xyz(l3, pts) @ out
    err = np.abs(got - np.einsum("nx,nx->n", f1, f2)).max()
    assert err < 1e-9, f"dot pointwise: {err}"

    out = apply_cross(l1, l2, l3, v1, v2)
    err = np.abs(field_eval(out, l3, pts) - np.cross(f1, f2)).max()
    assert err < 1e-9, f"cross pointwise: {err}"


def check_equivariance(seed: int = 3, cases: int = 4):
    """Proper AND improper equivariance for every kind (truncating l3)."""
    rng = np.random.default_rng(seed)
    triples = [("sv", 2, 2, 2), ("dot", 2, 1, 2), ("cross", 1, 2, 2),
               ("cross", 2, 2, 1)]
    worst = 0.0
    for _ in range(cases):
        r = so3.random_rotation(rng)
        for o in (r, -r):
            for kind, l1, l2, l3 in triples:
                n1, n2, _ = plan_dims(kind, l1, l2, l3)
                x1 = rng.standard_normal(n1)
                x2 = rng.standard_normal(n2)
                tx1, tx2, tout = plan_transform_io(kind, l1, l2, l3, x1, x2, o)
                a = plan_apply(kind, l1, l2, l3, tx1, tx2)
                b = tout(plan_apply(kind, l1, l2, l3, x1, x2))
                err = np.abs(a - b).max()
                worst = max(worst, err)
                assert err < 1e-8, (
                    f"{kind}({l1},{l2},{l3}) det={np.linalg.det(o):+.0f}: {err}"
                )
    return worst


def check_vjps(seed: int = 4):
    """Sibling-plan VJPs against finite differences of <g, apply(x1, x2)>."""
    rng = np.random.default_rng(seed)
    h = 1e-6
    for kind, l1, l2, l3 in [("sv", 2, 1, 2), ("dot", 2, 1, 2),
                             ("cross", 1, 1, 1), ("cross", 2, 1, 2)]:
        n1, n2, n3 = plan_dims(kind, l1, l2, l3)
        x1 = rng.standard_normal(n1)
        x2 = rng.standard_normal(n2)
        g = rng.standard_normal(n3)
        grad = plan_vjp_x1(kind, l1, l2, l3, x2, g)
        assert grad.shape == (n1,)
        for i in range(n1):
            xp = x1.copy(); xp[i] += h
            xm = x1.copy(); xm[i] -= h
            fd = (
                g @ plan_apply(kind, l1, l2, l3, xp, x2)
                - g @ plan_apply(kind, l1, l2, l3, xm, x2)
            ) / (2 * h)
            assert abs(grad[i] - fd) < 1e-5 * (1.0 + abs(fd)), (
                f"{kind}({l1},{l2},{l3}) comp {i}: vjp {grad[i]} vs fd {fd}"
            )


def check_vsh_coupling_vs_plan(seed: int = 5):
    """The VSH-basis dot coupling tensor agrees with the Cartesian route:
    contract T[k3, J1, J2] with VSH coefficients == convert both operands to
    the Cartesian layout and run the dot plan."""
    rng = np.random.default_rng(seed)
    lv, l3 = 1, 2
    vset = vsh_set(lv, lv, lv)
    t = vsh_dot_gaunt(l3, vset, vset)
    a = rng.standard_normal(len(vset))
    b = rng.standard_normal(len(vset))
    want = np.einsum("kab,a,b->k", t, a, b)
    lc = lv + 1  # Cartesian-layout degree that holds VSH of degree <= lv
    xa = cart_feature_from_vsh(a, vset, lc)
    xb = cart_feature_from_vsh(b, vset, lc)
    got = apply_dot(lc, lc, l3, xa, xb)
    err = np.abs(got - want).max()
    assert err < 1e-8, f"VSH coupling vs Cartesian plan route: {err}"
    return err


def check_dipole(seed: int = 6):
    """FD gradient check and O(3) equivariance of the dipole head."""
    rng = np.random.default_rng(seed)
    channels, L = 2, 2
    nd = channels * so3.num_coeffs(L)
    h = rng.standard_normal(nd)
    w = rng.standard_normal(channels * (L + 1))
    c_dip = 0.7
    g_mu = rng.standard_normal(3)
    gw, gc = dipole_grads(h, channels, L, w, c_dip, g_mu)
    step = 1e-6
    for i in range(len(w)):
        wp = w.copy(); wp[i] += step
        wm = w.copy(); wm[i] -= step
        fd = (
            g_mu @ dipole_forward(h, channels, L, wp, c_dip)[0]
            - g_mu @ dipole_forward(h, channels, L, wm, c_dip)[0]
        ) / (2 * step)
        assert abs(gw[i] - fd) < 1e-5 * (1.0 + abs(fd)), f"dw[{i}]: {gw[i]} vs {fd}"
    fd = (
        g_mu @ dipole_forward(h, channels, L, w, c_dip + step)[0]
        - g_mu @ dipole_forward(h, channels, L, w, c_dip - step)[0]
    ) / (2 * step)
    assert abs(gc - fd) < 1e-5 * (1.0 + abs(fd)), f"dc_dip: {gc} vs {fd}"

    # mu is a polar vector: mu(T h) = O mu(h) for proper AND improper O
    mu, _ = dipole_forward(h, channels, L, w, c_dip)
    r = so3.random_rotation(rng)
    for o in (r, -r):
        th = np.zeros_like(h)
        for c in range(channels):
            hc = np.concatenate(
                [h[_spherical_slot(h, channels, L, l, c)] for l in range(L + 1)]
            )
            rc = transform_scalar(hc, L, o)
            for l in range(L + 1):
                sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
                th[_spherical_slot(th, channels, L, l, c)] = rc[sl]
        tmu, _ = dipole_forward(th, channels, L, w, c_dip)
        err = np.abs(tmu - o @ mu).max()
        assert err < 1e-8, f"dipole equivariance det={np.linalg.det(o):+.0f}: {err}"


def run_checks(verbose: bool = True):
    steps = [
        ("VSH orthonormality", check_vsh_orthonormality),
        ("VSH truncation completeness", check_vsh_completeness),
        ("rhat constant signal", check_rhat_signal),
        ("pointwise product semantics", check_pointwise_semantics),
        ("O(3) equivariance (proper + improper)", check_equivariance),
        ("sibling-plan VJPs vs FD", check_vjps),
        ("VSH coupling tensor vs Cartesian route", check_vsh_coupling_vs_plan),
        ("dipole head grads + equivariance", check_dipole),
    ]
    for name, fn in steps:
        fn()
        if verbose:
            print(f"  ok: {name}")


# --------------------------------------------------------------------------
# golden emission
# --------------------------------------------------------------------------


def golden_doc() -> dict:
    rng = np.random.default_rng(20260807)
    doc: dict = {"meta": {"tol": 1e-9, "seed": 20260807}}

    # VSH values at fixed points (Rust evaluates the same basis natively)
    pts = rng.standard_normal((6, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    entries = []
    for kind, l, m in vsh_set(3, 3, 3):
        entries.append(
            {
                "kind": kind,
                "l": l,
                "m": m,
                "values": vsh_eval(kind, l, m, pts).reshape(-1).tolist(),
            }
        )
    doc["vsh"] = {"points": pts.reshape(-1).tolist(), "entries": entries}

    # plan io pairs (apply + cotangent + vjp grad) per kind
    plans = []
    for kind, l1, l2, l3 in [
        ("sv", 2, 2, 2),
        ("sv", 1, 2, 3),
        ("dot", 2, 2, 2),
        ("dot", 2, 1, 3),
        ("cross", 1, 1, 1),
        ("cross", 2, 1, 2),
    ]:
        n1, n2, n3 = plan_dims(kind, l1, l2, l3)
        x1 = rng.standard_normal(n1)
        x2 = rng.standard_normal(n2)
        g = rng.standard_normal(n3)
        plans.append(
            {
                "kind": kind,
                "l1": l1,
                "l2": l2,
                "l3": l3,
                "x1": x1.tolist(),
                "x2": x2.tolist(),
                "out": plan_apply(kind, l1, l2, l3, x1, x2).tolist(),
                "cotangent": g.tolist(),
                "grad_x1": plan_vjp_x1(kind, l1, l2, l3, x2, g).tolist(),
            }
        )
    doc["plans"] = plans

    # VSH-basis dot coupling tensor (small: degrees <= 1, output <= 2)
    vset = vsh_set(1, 1, 1)
    t = vsh_dot_gaunt(2, vset, vset)
    doc["vsh_dot_gaunt"] = {
        "l3": 2,
        "vset": [[k, l, m] for (k, l, m) in vset],
        "tensor": t.reshape(-1).tolist(),
    }

    # dipole head forward + grads on fixed features
    channels, L = 2, 2
    h = rng.standard_normal(channels * so3.num_coeffs(L))
    w = rng.standard_normal(channels * (L + 1))
    c_dip = 0.7
    g_mu = rng.standard_normal(3)
    mu, _ = dipole_forward(h, channels, L, w, c_dip)
    gw, gc = dipole_grads(h, channels, L, w, c_dip, g_mu)
    doc["dipole"] = {
        "channels": channels,
        "l": L,
        "h": h.tolist(),
        "w": w.tolist(),
        "c_dip": c_dip,
        "mu": mu.tolist(),
        "g_mu": g_mu.tolist(),
        "grad_w": gw.tolist(),
        "grad_c_dip": gc,
    }
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true", help="run every assertion")
    ap.add_argument("--out", help="artifacts dir (writes golden/vector_golden.json)")
    args = ap.parse_args()
    if not args.check and not args.out:
        ap.error("pass --check and/or --out DIR")
    if args.check:
        print("vector_golden: running mirror checks")
        run_checks()
        print("vector_golden: ALL CHECKS PASSED")
    if args.out:
        doc = golden_doc()
        path = os.path.join(args.out, "golden", "vector_golden.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        print(f"vector_golden: wrote {path}")


if __name__ == "__main__":
    main()
