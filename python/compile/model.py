"""Layer-2 JAX models built on the Gaunt Tensor Product kernels.

Two architectures, sharing an equivariant message-passing core:

* **GauntNet** — a MACE-lite E(3)-equivariant force field:
  Bessel radial basis + SH edge filters, equivariant convolution messages
  (paper Sec. 3.3 "Equivariant Convolutions"), a *Selfmix* equivariant
  feature interaction per layer (the operation the paper adds to
  EquiformerV2 for Table 1), invariant readout -> per-atom energies,
  forces via -dE/dr (which differentiates *through* the Pallas kernels via
  their custom VJP).

* **SEGNN-lite** for the N-body sanity check (Fig. 1 last panel):
  same core, vector (l=1) readout forecasting particle displacement.

Every tensor product is switchable between `tp="gaunt"` (the paper's
method, Pallas pipeline) and `tp="cg"` (Clebsch-Gordan baseline) so the
sanity-check/Table-1 comparisons change exactly one thing.

Everything here runs only at compile time (aot.py lowers jitted functions
to HLO text); Python is never on the request path.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import so3
from .kernels import cg_tp as ck
from .kernels import gaunt_tp as gk
from .kernels import ref as kref


# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Config:
    """Static model/problem configuration (fixed shapes for AOT)."""

    L: int = 2              # max irrep degree of node features
    channels: int = 8       # equivariant channels
    n_species: int = 4
    n_layers: int = 2
    n_bessel: int = 8
    r_cut: float = 4.0
    n_atoms: int = 32       # padded atoms per graph
    n_edges: int = 128      # padded directed edges per graph
    tp: str = "gaunt"       # "gaunt" | "cg"
    readout: str = "energy"  # "energy" | "vector"
    hidden: int = 32        # radial MLP width
    vec_in: bool = False    # consume an extra per-node l=1 input (velocity)

    @property
    def n_irreps(self) -> int:
        return so3.num_coeffs(self.L)


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------


def init_params(seed: int, cfg: Config) -> Dict[str, Any]:
    """Deterministic parameter pytree (dict of float32 arrays)."""
    rng = np.random.default_rng(seed)

    def dense(fan_in, shape):
        return (rng.standard_normal(shape) / math.sqrt(fan_in)).astype(np.float32)

    p: Dict[str, Any] = {
        "embed": dense(1, (cfg.n_species, cfg.channels)),
    }
    if cfg.vec_in:
        p["vec_embed"] = dense(1, (1, cfg.channels))
    for i in range(cfg.n_layers):
        lp = {
            # radial MLP: n_bessel -> hidden -> C*(L+1) degree weights
            "rad_w1": dense(cfg.n_bessel, (cfg.n_bessel, cfg.hidden)),
            "rad_b1": np.zeros(cfg.hidden, np.float32),
            "rad_w2": dense(cfg.hidden, (cfg.hidden, cfg.channels * (cfg.L + 1))),
            # per-degree channel mixing after aggregation
            "mix": dense(cfg.channels, (cfg.L + 1, cfg.channels, cfg.channels)),
            # Selfmix (equivariant feature interaction) degree weights
            "self_w1": (np.ones((cfg.channels, cfg.L + 1))
                        + 0.1 * rng.standard_normal((cfg.channels, cfg.L + 1))
                        ).astype(np.float32),
            "self_w2": (np.ones((cfg.channels, cfg.L + 1))
                        + 0.1 * rng.standard_normal((cfg.channels, cfg.L + 1))
                        ).astype(np.float32),
            "self_w3": (0.1 * rng.standard_normal((cfg.channels, cfg.L + 1))
                        ).astype(np.float32),
            "self_mix": dense(cfg.channels, (cfg.L + 1, cfg.channels, cfg.channels)),
            # gate: scalars -> per (channel, degree) sigmoid gates
            "gate_w": dense(cfg.channels, (cfg.channels, cfg.channels * (cfg.L + 1))),
            "gate_b": np.zeros(cfg.channels * (cfg.L + 1), np.float32),
        }
        p[f"layer{i}"] = lp
    if cfg.readout == "energy":
        p["out_w1"] = dense(cfg.channels, (cfg.channels, cfg.hidden))
        p["out_b1"] = np.zeros(cfg.hidden, np.float32)
        p["out_w2"] = dense(cfg.hidden, (cfg.hidden, 1))
        p["species_e0"] = np.zeros(cfg.n_species, np.float32)
    else:
        p["out_vec"] = dense(cfg.channels, (cfg.channels, 1))
    return {k: jnp.asarray(v) if not isinstance(v, dict)
            else {kk: jnp.asarray(vv) for kk, vv in v.items()}
            for k, v in p.items()}


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _sh_tables(L: int):
    exps, coefs = so3.sh_monomial_table(L)
    return (
        [np.asarray(e, np.int32) for e in exps],
        [np.asarray(c, np.float32) for c in coefs],
    )


def sh_cartesian(L: int, r: jnp.ndarray) -> jnp.ndarray:
    """Differentiable real SH of (possibly unnormalized) vectors r[..., 3].

    Evaluated as homogeneous polynomials of the safely-normalized direction
    — pole-free, so force gradients are finite everywhere (padded zero
    edges get an arbitrary finite direction and are masked downstream).
    """
    exps, coefs = _sh_tables(L)
    n = jnp.sqrt(jnp.sum(r * r, axis=-1, keepdims=True) + 1e-12)
    u = r / n

    # integer powers by iterated multiplication: u**k via jnp.power has a
    # NaN gradient at u=0 for k=0 (0 * 0^{-1}); products never do.
    def powers(t):
        out = [jnp.ones_like(t)]
        for _ in range(L):
            out.append(out[-1] * t)
        return jnp.concatenate(out, axis=-1)  # [..., L+1]

    px, py, pz = powers(u[..., 0:1]), powers(u[..., 1:2]), powers(u[..., 2:3])
    outs = []
    for l in range(L + 1):
        e = exps[l]  # numpy [n_mono, 3]
        mono = px[..., e[:, 0]] * py[..., e[:, 1]] * pz[..., e[:, 2]]
        outs.append(mono @ jnp.asarray(coefs[l], r.dtype).T)
    return jnp.concatenate(outs, axis=-1)


def bessel_basis(d: jnp.ndarray, n: int, r_cut: float) -> jnp.ndarray:
    """Radial Bessel basis with a smooth polynomial cutoff envelope."""
    ns = jnp.arange(1, n + 1, dtype=d.dtype)
    x = d[..., None] / r_cut
    safe_d = d[..., None] + 1e-9
    rb = jnp.sqrt(2.0 / r_cut) * jnp.sin(math.pi * ns * x) / safe_d
    # p=5 polynomial cutoff (Gasteiger et al.)
    u = jnp.clip(x, 0.0, 1.0)
    env = 1.0 - 10.0 * u**3 + 15.0 * u**4 - 6.0 * u**5
    return rb * env


def _tp_channelwise(x1: jnp.ndarray, x2: jnp.ndarray, L1: int, L2: int,
                    L3: int, tp: str) -> jnp.ndarray:
    """Channel-wise tensor product of [B, C, n1] x [B, C, n2] -> [B, C, n3]."""
    b, c = x1.shape[0], x1.shape[1]
    f1 = x1.reshape(b * c, -1)
    f2 = x2.reshape(b * c, -1)
    if tp == "gaunt":
        out = gk.make_gaunt_tp(L1, L2, L3)(f1, f2)
    elif tp == "cg":
        out = ck.make_cg_tp(L1, L2, L3)(f1, f2)
    else:  # pure-jnp oracle path (tests)
        out = kref.gaunt_tp_ref(f1, f2, L1, L2, L3)
    return out.reshape(b, c, -1)


def _mix_channels(x: jnp.ndarray, w: jnp.ndarray, L: int) -> jnp.ndarray:
    """Per-degree channel mixing: x[..., C, (L+1)^2], w[L+1, C, C]."""
    outs = []
    for l in range(L + 1):
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        outs.append(jnp.einsum("...cm,cd->...dm", x[..., sl], w[l]))
    return jnp.concatenate(outs, axis=-1)


def _scale_degrees(x: jnp.ndarray, w: jnp.ndarray, L: int) -> jnp.ndarray:
    """x[..., C, (L+1)^2] scaled per (channel, degree) by w[..., C, L+1]."""
    reps = np.concatenate([np.full(2 * l + 1, l) for l in range(L + 1)])
    return x * jnp.take(w, jnp.asarray(reps), axis=-1)


# --------------------------------------------------------------------------
# the equivariant core
# --------------------------------------------------------------------------


def _features(params, pos, species, edges, edge_mask, atom_mask, cfg: Config,
              vel=None):
    """Equivariant message-passing trunk -> node features [N, C, (L+1)^2]."""
    n_ir = cfg.n_irreps
    onehot = jax.nn.one_hot(species, cfg.n_species, dtype=pos.dtype)
    h0 = onehot @ params["embed"]  # [N, C]
    x = jnp.zeros((cfg.n_atoms, cfg.channels, n_ir), pos.dtype)
    x = x.at[:, :, 0].set(h0)
    if cfg.vec_in and vel is not None:
        # velocity is a type-1 irrep: components (y, z, x) at l=1 slots
        v_irrep = jnp.stack([vel[:, 1], vel[:, 2], vel[:, 0]], axis=-1)  # [N,3]
        vfeat = jnp.einsum("ni,cj->ncij", v_irrep, params["vec_embed"])[..., 0]
        # vfeat: [N, 3] x [1, C] -> [N, C, 3]
        vfeat = jnp.einsum("ni,oc->nci", v_irrep, params["vec_embed"])
        x = x.at[:, :, 1:4].add(vfeat)

    src, dst = edges[:, 0], edges[:, 1]
    rij = pos[dst] - pos[src]  # [E, 3]
    dij = jnp.sqrt(jnp.sum(rij * rij, axis=-1) + 1e-12)
    ysh = sh_cartesian(cfg.L, rij)  # [E, (L+1)^2]
    rb = bessel_basis(dij, cfg.n_bessel, cfg.r_cut)  # [E, nb]

    for i in range(cfg.n_layers):
        lp = params[f"layer{i}"]
        hidden = jnp.tanh(rb @ lp["rad_w1"] + lp["rad_b1"])
        rad = (hidden @ lp["rad_w2"]).reshape(-1, cfg.channels, cfg.L + 1)
        # message: equivariant convolution  (x_src * radial) (x) Y(r_ij)
        xs = x[src]  # [E, C, n_ir]
        xs = _scale_degrees(xs, rad, cfg.L)
        filt = jnp.broadcast_to(ysh[:, None, :], xs.shape)
        msg = _tp_channelwise(xs, filt, cfg.L, cfg.L, cfg.L, cfg.tp)
        msg = msg * edge_mask[:, None, None]
        agg = jnp.zeros_like(x).at[dst].add(msg)
        agg = _mix_channels(agg, lp["mix"], cfg.L)
        x = x + agg

        # Selfmix: equivariant feature interaction of x with itself
        a = _scale_degrees(x, lp["self_w1"][None], cfg.L)
        b = _scale_degrees(x, lp["self_w2"][None], cfg.L)
        mix = _tp_channelwise(a, b, cfg.L, cfg.L, cfg.L, cfg.tp)
        mix = _scale_degrees(mix, lp["self_w3"][None], cfg.L)
        x = x + _mix_channels(mix, lp["self_mix"], cfg.L)

        # gated nonlinearity driven by the invariant (l=0) channels
        gate = jax.nn.sigmoid(
            x[:, :, 0] @ lp["gate_w"] + lp["gate_b"]
        ).reshape(-1, cfg.channels, cfg.L + 1)
        x = _scale_degrees(x, gate, cfg.L)
        x = x * atom_mask[:, None, None]
    return x


def energy_fn(params, pos, species, edges, edge_mask, atom_mask,
              cfg: Config) -> jnp.ndarray:
    """Total energy of one (padded) graph."""
    x = _features(params, pos, species, edges, edge_mask, atom_mask, cfg)
    s = x[:, :, 0]  # invariant channels [N, C]
    h = jnp.tanh(s @ params["out_w1"] + params["out_b1"])
    e_atom = (h @ params["out_w2"])[:, 0]
    onehot = jax.nn.one_hot(species, cfg.n_species, dtype=pos.dtype)
    e0 = onehot @ params["species_e0"]
    return jnp.sum((e_atom + e0) * atom_mask)


def energy_forces(params, pos, species, edges, edge_mask, atom_mask,
                  cfg: Config):
    """(E, F) with F = -dE/dpos — flows through the Pallas kernels' VJP."""
    e, g = jax.value_and_grad(energy_fn, argnums=1)(
        params, pos, species, edges, edge_mask, atom_mask, cfg
    )
    return e, -g * atom_mask[:, None]


def batched_energy_forces(params, pos, species, edges, edge_mask, atom_mask,
                          cfg: Config):
    """vmapped over a leading batch axis."""
    return jax.vmap(
        lambda p, s, e, em, am: energy_forces(params, p, s, e, em, am, cfg)
    )(pos, species, edges, edge_mask, atom_mask)


def nbody_forecast(params, pos, vel, charge, edges, edge_mask, atom_mask,
                   cfg: Config) -> jnp.ndarray:
    """SEGNN-lite: predict future positions of charged particles."""
    x = _features(params, pos, charge, edges, edge_mask, atom_mask, cfg,
                  vel=vel)
    v1 = x[:, :, 1:4]  # [N, C, 3] type-1 irreps, m = (-1,0,1) ~ (y,z,x)
    dv = jnp.einsum("nci,co->ni", v1, params["out_vec"])
    delta = jnp.stack([dv[:, 2], dv[:, 0], dv[:, 1]], axis=-1)  # back to xyz
    return pos + vel + delta * atom_mask[:, None]


# --------------------------------------------------------------------------
# losses + Adam (hand-rolled; no optax in this environment)
# --------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree.map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def ff_loss(params, batch, cfg: Config, w_e=1.0, w_f=10.0):
    """Force-field loss: per-atom-normalized energy MSE + force MSE."""
    e, f = batched_energy_forces(
        params, batch["pos"], batch["species"], batch["edges"],
        batch["edge_mask"], batch["atom_mask"], cfg
    )
    n_atoms = jnp.sum(batch["atom_mask"], axis=1) + 1e-9
    le = jnp.mean(((e - batch["energy"]) / n_atoms) ** 2)
    fm = batch["atom_mask"][..., None]
    lf = jnp.sum(((f - batch["forces"]) * fm) ** 2) / (jnp.sum(fm) * 3.0)
    return w_e * le + w_f * lf


def ff_train_step(params, opt, batch, cfg: Config, lr=1e-3):
    loss, grads = jax.value_and_grad(ff_loss)(params, batch, cfg)
    params, opt = adam_update(params, grads, opt, lr=lr)
    return params, opt, loss


def nbody_loss(params, batch, cfg: Config):
    pred = jax.vmap(
        lambda p, v, c, e, em, am: nbody_forecast(params, p, v, c, e, em, am, cfg)
    )(batch["pos"], batch["vel"], batch["charge"], batch["edges"],
      batch["edge_mask"], batch["atom_mask"])
    am = batch["atom_mask"][..., None]
    return jnp.sum(((pred - batch["target"]) * am) ** 2) / (jnp.sum(am) * 3.0)


def nbody_train_step(params, opt, batch, cfg: Config, lr=5e-3):
    loss, grads = jax.value_and_grad(nbody_loss)(params, batch, cfg)
    params, opt = adam_update(params, grads, opt, lr=lr)
    return params, opt, loss
