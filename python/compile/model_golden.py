"""Numpy mirror of the native Rust force-field model + golden generator.

The Rust crate's `model` subsystem (MACE-style message passing, every
contraction a Gaunt product) is implemented here a second time, directly
against the slow-but-exact real Gaunt tensors of `compile.so3`.  Two jobs:

1. **Golden generator** (`python -m compile.model_golden --out
   ../rust/artifacts`): emits `golden/model_golden.json` — one frozen
   configuration (explicit weights, positions, species) with the reference
   energy and analytic forces.  `rust/tests/golden_cross_validation.rs`
   replays it through the native pipeline.
2. **Math validator** (`--check`): finite-difference checks of the SH
   Cartesian gradient, of the model forces (-dE/dx), of the parameter
   gradient, an equivariance check, and a descent check of the trainer
   update — the same identities the Rust tests pin.

Model math (mirrored exactly by `rust/src/model/`):

* every feature is one channel of real SH coefficients, degree <= L;
* edge filter: f_e[lm] = h2_e[l2] Y_lm(u_e), h2_e = W_rad @ rb(r_e);
* message: m_e = P_L(h_j * f_e) — a Gaunt product (the Rust side runs it
  through GauntConvPlan's aligned-filter fast path);
* node update: a_i = sum_e m_e, b_i = P_L(a_i^nu) (ManyBodyPlan
  self-product), h' = res (.) h + mix_a (.) a + mix_b (.) b per degree;
* readout: e_i = bias[s_i] + c_lin h[0] + c_quad (h (x) h)[0].

Backward passes use the full permutation symmetry of the real Gaunt
tensor G[k,i,j] = int Y_k Y_i Y_j dOmega: every VJP of a Gaunt product is
itself a Gaunt product with the degrees rotated, so the Rust backward
runs on the same planned engine as the forward.
"""
from __future__ import annotations

import argparse
import json
import math
import os

import numpy as np

try:  # runnable as `python -m compile.model_golden` or as a plain script
    from . import so3
except ImportError:  # pragma: no cover
    import so3  # type: ignore

SQRT_4PI = math.sqrt(4.0 * math.pi)


# --------------------------------------------------------------------------
# real SH values + Cartesian gradients (pole-free polynomial recurrence)
# --------------------------------------------------------------------------


def _double_fact_odd(m: int) -> float:
    """(2m-1)!! with the empty product = 1."""
    out = 1.0
    for k in range(1, m + 1):
        out *= 2 * k - 1
    return out


def real_sh_grad_xyz(l_max: int, d: np.ndarray):
    """Y(d/|d|) for all (l, m) <= l_max plus the gradient w.r.t. d.

    Uses the factorization (no Condon-Shortley, orthonormal real SH)
        Y_{l,+m} = N sqrt(2) T_l^m(z) C_m(x, y),   m > 0
        Y_{l,0}  = N T_l^0(z)
        Y_{l,-m} = N sqrt(2) T_l^m(z) S_m(x, y),   m > 0
    on the unit sphere, where C_m + i S_m = (x + i y)^m and
    T_l^m(z) = P_l^m(z) / (1-z^2)^{m/2} is a polynomial obeying the same
    upward recurrence as P_l^m.  All three factors are polynomials in the
    Cartesian coordinates, so the ambient gradient is exact and finite
    everywhere (including the poles); the gradient w.r.t. the
    *unnormalized* d follows from the projection (I - u u^T)/r.

    Returns (y [(L+1)^2], g [(L+1)^2, 3]).
    """
    d = np.asarray(d, dtype=np.float64)
    r = float(np.linalg.norm(d))
    u = d / r
    x, yy, z = u
    n = so3.num_coeffs(l_max)
    val = np.zeros(n)
    amb = np.zeros((n, 3))  # ambient dF at u
    # C_m, S_m and their m-1 predecessors
    cm, sm = 1.0, 0.0
    cm1, sm1 = 0.0, 0.0
    for m in range(l_max + 1):
        if m > 0:
            cm1, sm1 = cm, sm
            cm, sm = cm * x - sm * yy, cm * yy + sm * x
        # T recurrence over l for this m, with dT/dz
        t_prev, td_prev = 0.0, 0.0  # T_{l-2}, T'_{l-2}
        t, td = _double_fact_odd(m), 0.0  # T_m^m, constant in z
        for l in range(m, l_max + 1):
            if l > m:
                if l == m + 1:
                    t_next = z * (2 * m + 1) * t
                    td_next = (2 * m + 1) * t
                else:
                    t_next = (z * (2 * l - 1) * t - (l + m - 1) * t_prev) / (l - m)
                    td_next = (
                        (2 * l - 1) * (t + z * td) - (l + m - 1) * td_prev
                    ) / (l - m)
                t_prev, td_prev = t, td
                t, td = t_next, td_next
            norm = so3.sh_norm(l, m)
            pre = norm * (math.sqrt(2.0) if m > 0 else 1.0)
            ip = so3.lm_index(l, m)
            val[ip] = pre * t * cm
            amb[ip] = pre * np.array([t * m * cm1, -t * m * sm1, td * cm])
            if m > 0:
                im = so3.lm_index(l, -m)
                val[im] = pre * t * sm
                amb[im] = pre * np.array([t * m * sm1, t * m * cm1, td * sm])
    # chain rule through u = d/r:  g = (dF - (dF.u) u) / r
    g = (amb - np.outer(amb @ u, u)) / r
    return val, g


# --------------------------------------------------------------------------
# radial basis
# --------------------------------------------------------------------------


def radial_basis(n_radial: int, r_cut: float, r: float):
    """Gaussian RBF with a smooth polynomial cutoff envelope.

    rb_k(r) = exp(-beta (r - mu_k)^2) * (1 - (r/rc)^2)^2, mu_k linspace
    over [0, rc], beta = (n/rc)^2.  Value AND d/dr (both vanish at rc, so
    the learned energy stays C^1 as edges cross the cutoff).
    """
    if r >= r_cut:
        return np.zeros(n_radial), np.zeros(n_radial)
    mu = np.linspace(0.0, r_cut, n_radial)
    beta = (n_radial / r_cut) ** 2
    t = r / r_cut
    env = (1.0 - t * t) ** 2
    denv = -4.0 * t * (1.0 - t * t) / r_cut
    gauss = np.exp(-beta * (r - mu) ** 2)
    dgauss = -2.0 * beta * (r - mu) * gauss
    return gauss * env, dgauss * env + gauss * denv


# --------------------------------------------------------------------------
# model: parameters, forward, backward
# --------------------------------------------------------------------------


class Config:
    def __init__(self, l=2, l_filter=2, nu=2, n_layers=2, n_species=3,
                 n_radial=6, r_cut=3.5):
        assert nu >= 2
        self.l, self.l_filter, self.nu = l, l_filter, nu
        self.n_layers, self.n_species, self.n_radial = n_layers, n_species, n_radial
        self.r_cut = r_cut
        # degree of the saved a^(nu-1) power (Gaunt selection rules cut
        # anything above 2L out of the many-body VJP)
        self.l_pow = min((nu - 1) * l, 2 * l)

    @property
    def nf(self):
        return so3.num_coeffs(self.l)

    @property
    def nff(self):
        return so3.num_coeffs(self.l_filter)

    def layer_sizes(self):
        return [("w_rad", (self.l_filter + 1) * self.n_radial),
                ("mix_res", self.l + 1), ("mix_a", self.l + 1),
                ("mix_b", self.l + 1)]

    def n_params(self):
        per_layer = sum(n for _, n in self.layer_sizes())
        return 2 * self.n_species + self.n_layers * per_layer + 2


def param_views(cfg: Config, p: np.ndarray):
    """Split the flat parameter vector into named views (shared layout
    with rust/src/model/mod.rs)."""
    views = {}
    off = 0
    views["species_embed"] = p[off:off + cfg.n_species]; off += cfg.n_species
    views["species_bias"] = p[off:off + cfg.n_species]; off += cfg.n_species
    views["layers"] = []
    for _ in range(cfg.n_layers):
        lay = {}
        for name, n in cfg.layer_sizes():
            lay[name] = p[off:off + n]; off += n
        lay["w_rad"] = lay["w_rad"]  # flat [l2 * n_radial + k]
        views["layers"].append(lay)
    views["readout"] = p[off:off + 2]; off += 2
    assert off == p.size
    return views


def init_params(cfg: Config, rng: np.random.Generator) -> np.ndarray:
    p = np.zeros(cfg.n_params())
    v = param_views(cfg, p)
    v["species_embed"][:] = 1.0 + 0.3 * rng.standard_normal(cfg.n_species)
    v["species_bias"][:] = 0.1 * rng.standard_normal(cfg.n_species)
    for lay in v["layers"]:
        lay["w_rad"][:] = rng.standard_normal(lay["w_rad"].size) * (
            0.8 / math.sqrt(cfg.n_radial))
        lay["mix_res"][:] = 1.0
        lay["mix_a"][:] = 0.5 + 0.1 * rng.standard_normal(cfg.l + 1)
        lay["mix_b"][:] = 0.3 + 0.1 * rng.standard_normal(cfg.l + 1)
    v["readout"][:] = [0.5, 0.5]
    return p


def degree_scale(cfg: Config, w: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Per-degree scaling: out[(l,m)] = w[l] x[(l,m)]."""
    out = np.zeros_like(x)
    for l in range(cfg.l + 1):
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        out[sl] = w[l] * x[sl]
    return out


def degree_dot(cfg: Config, g: np.ndarray, x: np.ndarray) -> np.ndarray:
    """d/dw of <g, w (.) x>: per-degree inner products."""
    out = np.zeros(cfg.l + 1)
    for l in range(cfg.l + 1):
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        out[l] = float(g[sl] @ x[sl])
    return out


def gaunt_prod(l1, l2, l3, x, w):
    """P_{l3}(f_x f_w): the real Gaunt product (the planned engine's job
    on the Rust side)."""
    G = so3.gaunt_tensor_real(l1, l2, l3)
    return np.einsum("kij,i,j->k", G, x, w)


def self_power(cfg: Config, a: np.ndarray, nu: int, l_out: int) -> np.ndarray:
    """P_{l_out}(f_a^nu) via the exact pairwise fold (ManyBodyPlan oracle)."""
    acc, l_acc = a, cfg.l
    for _ in range(nu - 1):
        l_next = l_acc + cfg.l
        acc = gaunt_prod(l_acc, cfg.l, l_next, acc, a)
        l_acc = l_next
    return acc[: so3.num_coeffs(l_out)]


def build_edges(pos: np.ndarray, r_cut: float):
    """All directed pairs within the cutoff (mirrors md::neighbor)."""
    n = len(pos)
    edges = []
    for i in range(n):
        for j in range(n):
            if i != j and np.linalg.norm(pos[i] - pos[j]) < r_cut:
                edges.append((i, j))
    return edges


def forward(cfg: Config, p: np.ndarray, pos, species, edges):
    """Forward pass; returns (E, cache-for-backward)."""
    v = param_views(cfg, p)
    n_atoms, nf = len(pos), cfg.nf
    # per-edge geometry (position-dependent, shared by all layers)
    geo = []
    for (i, j) in edges:
        d = pos[i] - pos[j]
        r = float(np.linalg.norm(d))
        y, gy = real_sh_grad_xyz(cfg.l_filter, d)
        rb, drb = radial_basis(cfg.n_radial, cfg.r_cut, r)
        geo.append(dict(i=i, j=j, d=d, r=r, u=d / r, y=y, gy=gy, rb=rb, drb=drb))
    h = [np.zeros((n_atoms, nf))]
    h[0][:, 0] = v["species_embed"][species]
    layers_cache = []
    for lay in v["layers"]:
        w_rad = lay["w_rad"].reshape(cfg.l_filter + 1, cfg.n_radial)
        a = np.zeros((n_atoms, nf))
        h2s = []
        for e in geo:
            h2 = w_rad @ e["rb"]  # per-filter-degree weights
            f = np.zeros(cfg.nff)
            for l2 in range(cfg.l_filter + 1):
                sl = slice(so3.lm_index(l2, -l2), so3.lm_index(l2, l2) + 1)
                f[sl] = h2[l2] * e["y"][sl]
            m = gaunt_prod(cfg.l, cfg.l_filter, cfg.l, h[-1][e["j"]], f)
            a[e["i"]] += m
            h2s.append(h2)
        b = np.zeros((n_atoms, nf))
        pw = np.zeros((n_atoms, so3.num_coeffs(cfg.l_pow)))
        for i in range(n_atoms):
            b[i] = self_power(cfg, a[i], cfg.nu, cfg.l)
            pw[i] = self_power(cfg, a[i], cfg.nu - 1, cfg.l_pow)
        hn = np.zeros((n_atoms, nf))
        for i in range(n_atoms):
            hn[i] = (degree_scale(cfg, lay["mix_res"], h[-1][i])
                     + degree_scale(cfg, lay["mix_a"], a[i])
                     + degree_scale(cfg, lay["mix_b"], b[i]))
        h.append(hn)
        layers_cache.append(dict(a=a, b=b, pw=pw, h2s=h2s))
    c_lin, c_quad = v["readout"]
    inv = np.einsum("if,if->i", h[-1], h[-1]) / SQRT_4PI
    e_atom = v["species_bias"][species] + c_lin * h[-1][:, 0] + c_quad * inv
    E = float(e_atom.sum())
    return E, dict(geo=geo, h=h, layers=layers_cache, inv=inv)


def backward(cfg: Config, p: np.ndarray, pos, species, edges, cache):
    """Reverse pass: returns (forces [N,3], dE/dparams)."""
    v = param_views(cfg, p)
    gp = np.zeros_like(p)
    gv = param_views(cfg, gp)
    n_atoms = len(pos)
    geo, h, layers_cache = cache["geo"], cache["h"], cache["layers"]
    c_lin, c_quad = v["readout"]
    # readout
    gv["readout"][0] = h[-1][:, 0].sum()
    gv["readout"][1] = cache["inv"].sum()
    np.add.at(gv["species_bias"], species, 1.0)
    g_h = (2.0 * c_quad / SQRT_4PI) * h[-1].copy()
    g_h[:, 0] += c_lin
    forces = np.zeros((n_atoms, 3))
    for t in range(cfg.n_layers - 1, -1, -1):
        lay, lc = v["layers"][t], layers_cache[t]
        w_rad = lay["w_rad"].reshape(cfg.l_filter + 1, cfg.n_radial)
        g_hprev = np.zeros((n_atoms, cfg.nf))
        g_a = np.zeros((n_atoms, cfg.nf))
        for i in range(n_atoms):
            gv["layers"][t]["mix_res"] += degree_dot(cfg, g_h[i], h[t][i])
            gv["layers"][t]["mix_a"] += degree_dot(cfg, g_h[i], lc["a"][i])
            gv["layers"][t]["mix_b"] += degree_dot(cfg, g_h[i], lc["b"][i])
            g_hprev[i] = degree_scale(cfg, lay["mix_res"], g_h[i])
            g_a[i] = degree_scale(cfg, lay["mix_a"], g_h[i])
            g_b = degree_scale(cfg, lay["mix_b"], g_h[i])
            # many-body VJP: d P_L(f^nu)/da pulled back through the
            # symmetric Gaunt tensor = nu * P_L(f_g * f_pow)
            g_a[i] += cfg.nu * gaunt_prod(cfg.l, cfg.l_pow, cfg.l,
                                          g_b, lc["pw"][i])
        gw = np.zeros_like(w_rad)
        for e_idx, e in enumerate(geo):
            i, j = e["i"], e["j"]
            g_m = g_a[i]
            h2 = lc["h2s"][e_idx]
            f = np.zeros(cfg.nff)
            for l2 in range(cfg.l_filter + 1):
                sl = slice(so3.lm_index(l2, -l2), so3.lm_index(l2, l2) + 1)
                f[sl] = h2[l2] * e["y"][sl]
            # message VJPs (degree-rotated Gaunt products)
            g_hprev[j] += gaunt_prod(cfg.l, cfg.l_filter, cfg.l, g_m, f)
            g_f = gaunt_prod(cfg.l, cfg.l, cfg.l_filter, g_m, h[t][j])
            # filter chain: f[lm] = h2[l2] y[lm]
            g_d = np.zeros(3)
            g_r = 0.0
            for l2 in range(cfg.l_filter + 1):
                sl = slice(so3.lm_index(l2, -l2), so3.lm_index(l2, l2) + 1)
                g_h2 = float(g_f[sl] @ e["y"][sl])
                gw[l2] += g_h2 * e["rb"]
                g_r += g_h2 * float(w_rad[l2] @ e["drb"])
                g_d += h2[l2] * (g_f[sl] @ e["gy"][sl])
            g_d += g_r * e["u"]
            # d = pos_i - pos_j; F = -dE/dpos
            forces[i] -= g_d
            forces[j] += g_d
        gv["layers"][t]["w_rad"] += gw.ravel()
        g_h = g_hprev
    np.add.at(gv["species_embed"], species, g_h[:, 0])
    return forces, gp


def energy_forces_grad(cfg, p, pos, species, edges):
    E, cache = forward(cfg, p, pos, species, edges)
    forces, gp = backward(cfg, p, pos, species, edges, cache)
    return E, forces, gp


# --------------------------------------------------------------------------
# trainer mirror (energy + force loss; force term via central-difference
# Hessian-vector products on the parameter gradient)
# --------------------------------------------------------------------------


def loss_and_grad(cfg, p, graphs, w_energy=1.0, w_force=1.0, fd_eps=1e-4):
    loss, grad = 0.0, np.zeros_like(p)
    for (pos, species, edges, e_ref, f_ref) in graphs:
        n = len(pos)
        E, F, gp = energy_forces_grad(cfg, p, pos, species, edges)
        de = (E - e_ref) / n
        loss += w_energy * de * de
        grad += (2.0 * w_energy * de / n) * gp
        v = F - f_ref
        loss += w_force * float((v * v).sum()) / (3 * n)
        vn = float(np.linalg.norm(v))
        if vn > 0.0:
            vhat = v / vn
            scale = 2.0 * w_force * vn / (3 * n)
            # d/dtheta [ (F - F*) . F ] = -v . d(grad_x E)/dtheta
            #   = -(d/deps) dE/dtheta at x + eps vhat   (Pearlmutter HVP,
            # realized as a central difference on the exact theta-gradient)
            _, _, gp_p = energy_forces_grad(cfg, p, pos + fd_eps * vhat,
                                            species, edges)
            _, _, gp_m = energy_forces_grad(cfg, p, pos - fd_eps * vhat,
                                            species, edges)
            grad += scale * (-(gp_p - gp_m) / (2.0 * fd_eps))
    k = len(graphs)
    return loss / k, grad / k


# --------------------------------------------------------------------------
# checks
# --------------------------------------------------------------------------


def _toy_structure(rng, n_atoms=6, spread=1.6):
    pos = spread * rng.standard_normal((n_atoms, 3))
    species = rng.integers(0, 3, n_atoms)
    return pos, species


def check_sh_grad(rng):
    lmax, h = 4, 1e-6
    worst = 0.0
    for _ in range(20):
        d = rng.standard_normal(3) * rng.uniform(0.5, 3.0)
        y, g = real_sh_grad_xyz(lmax, d)
        y_ref = so3.real_sh_xyz(lmax, d)
        assert np.abs(y - y_ref).max() < 1e-11, "sh values disagree"
        for k in range(3):
            dp = d.copy(); dp[k] += h
            dm = d.copy(); dm[k] -= h
            fd = (so3.real_sh_xyz(lmax, dp) - so3.real_sh_xyz(lmax, dm)) / (2 * h)
            worst = max(worst, float(np.abs(g[:, k] - fd).max()))
    # pole directions (the angular form is singular there; ours must not be)
    for d in ([0.0, 0.0, 1.7], [0.0, 0.0, -2.1], [1e-9, 0.0, 1.0]):
        y, g = real_sh_grad_xyz(lmax, np.array(d))
        assert np.all(np.isfinite(y)) and np.all(np.isfinite(g))
    print(f"[check] SH cartesian gradient vs FD: max err {worst:.2e}")
    assert worst < 1e-7


def check_forces(rng):
    cfg = Config()
    p = init_params(cfg, rng)
    pos, species = _toy_structure(rng)
    edges = build_edges(pos, cfg.r_cut)
    E, F, _ = energy_forces_grad(cfg, p, pos, species, edges)
    h, worst = 1e-5, 0.0
    for i in range(len(pos)):
        for k in range(3):
            pp = pos.copy(); pp[i, k] += h
            pm = pos.copy(); pm[i, k] -= h
            ep, _ = forward(cfg, p, pp, species, build_edges(pp, cfg.r_cut))
            em, _ = forward(cfg, p, pm, species, build_edges(pm, cfg.r_cut))
            fd = -(ep - em) / (2 * h)
            worst = max(worst, abs(F[i, k] - fd) / (1.0 + abs(fd)))
    print(f"[check] forces vs -dE/dx (E={E:.4f}): max rel err {worst:.2e}")
    assert worst < 1e-6
    # translation invariance + zero net force
    e2, _ = forward(cfg, p, pos + np.array([0.3, -1.0, 0.7]), species, edges)
    assert abs(e2 - E) < 1e-10 * (1 + abs(E))
    assert np.abs(F.sum(axis=0)).max() < 1e-9


def check_param_grad(rng):
    cfg = Config(n_layers=2)
    p = init_params(cfg, rng)
    pos, species = _toy_structure(rng)
    edges = build_edges(pos, cfg.r_cut)
    _, _, gp = energy_forces_grad(cfg, p, pos, species, edges)
    h, worst = 1e-6, 0.0
    for idx in rng.choice(p.size, size=min(30, p.size), replace=False):
        pp = p.copy(); pp[idx] += h
        pm = p.copy(); pm[idx] -= h
        ep, _ = forward(cfg, pp, pos, species, edges)
        em, _ = forward(cfg, pm, pos, species, edges)
        fd = (ep - em) / (2 * h)
        worst = max(worst, abs(gp[idx] - fd) / (1.0 + abs(fd)))
    print(f"[check] dE/dtheta vs FD: max rel err {worst:.2e}")
    assert worst < 1e-6


def check_equivariance(rng):
    cfg = Config()
    p = init_params(cfg, rng)
    pos, species = _toy_structure(rng)
    edges = build_edges(pos, cfg.r_cut)
    E, F, _ = energy_forces_grad(cfg, p, pos, species, edges)
    R = so3.random_rotation(rng)
    E2, F2, _ = energy_forces_grad(cfg, p, pos @ R.T, species, edges)
    de = abs(E2 - E) / (1 + abs(E))
    df = np.abs(F2 - F @ R.T).max() / (1 + np.abs(F).max())
    print(f"[check] rotation: dE {de:.2e}, dF {df:.2e}")
    assert de < 1e-9 and df < 1e-9
    perm = rng.permutation(len(pos))
    E3, F3, _ = energy_forces_grad(cfg, p, pos[perm], species[perm],
                                   build_edges(pos[perm], cfg.r_cut))
    assert abs(E3 - E) < 1e-9 * (1 + abs(E))
    assert np.abs(F3 - F[perm]).max() < 1e-9 * (1 + np.abs(F).max())


def check_total_loss_grad(rng):
    """The trainer's energy+force gradient (with the FD-HVP force term)
    must match a finite difference of the total loss itself."""
    cfg = Config(n_layers=1)
    p = init_params(cfg, rng)
    graphs = []
    for _ in range(2):
        pos, species = _toy_structure(rng, n_atoms=4)
        edges = build_edges(pos, cfg.r_cut)
        e_ref = float(rng.standard_normal())
        f_ref = 0.1 * rng.standard_normal((4, 3))
        graphs.append((pos, species, edges, e_ref, f_ref))
    loss, grad = loss_and_grad(cfg, p, graphs)
    h, worst = 1e-5, 0.0
    for idx in rng.choice(p.size, size=12, replace=False):
        pp = p.copy(); pp[idx] += h
        pm = p.copy(); pm[idx] -= h
        lp, _ = loss_and_grad(cfg, pp, graphs)
        lm, _ = loss_and_grad(cfg, pm, graphs)
        fd = (lp - lm) / (2 * h)
        worst = max(worst, abs(grad[idx] - fd) / (1.0 + abs(fd)))
    print(f"[check] d(loss)/dtheta (energy+force, FD-HVP): max rel err {worst:.2e}")
    assert worst < 1e-4


def check_descent(rng):
    cfg = Config(n_layers=1)
    p = init_params(cfg, rng)
    graphs = []
    for _ in range(3):
        pos, species = _toy_structure(rng, n_atoms=5)
        edges = build_edges(pos, cfg.r_cut)
        # synthetic labels from a perturbed copy of the model (realizable)
        p_star = p + 0.2 * rng.standard_normal(p.size)
        e_ref, f_ref, _ = energy_forces_grad(cfg, p_star, pos, species, edges)
        graphs.append((pos, species, edges, e_ref, f_ref))
    # Adam, mirroring coordinator::trainer defaults
    m, v2 = np.zeros_like(p), np.zeros_like(p)
    lr, b1, b2, eps = 5e-3, 0.9, 0.999, 1e-8
    l0, _ = loss_and_grad(cfg, p, graphs)
    losses = [l0]
    for step in range(1, 11):
        _, g = loss_and_grad(cfg, p, graphs)
        m = b1 * m + (1 - b1) * g
        v2 = b2 * v2 + (1 - b2) * g * g
        mh, vh = m / (1 - b1 ** step), v2 / (1 - b2 ** step)
        p = p - lr * mh / (np.sqrt(vh) + eps)
        l, _ = loss_and_grad(cfg, p, graphs)
        losses.append(l)
    print(f"[check] Adam descent: loss {losses[0]:.5f} -> {losses[-1]:.5f}")
    assert losses[1] < losses[0] and losses[-1] < losses[0]


def run_checks():
    rng = np.random.default_rng(7)
    check_sh_grad(rng)
    check_forces(rng)
    check_param_grad(rng)
    check_equivariance(rng)
    check_total_loss_grad(rng)
    check_descent(rng)
    print("[check] all model-math checks passed")


# --------------------------------------------------------------------------
# golden emission
# --------------------------------------------------------------------------


def emit_model_golden(out_dir: str):
    cfg = Config(l=2, l_filter=2, nu=2, n_layers=2, n_species=3,
                 n_radial=6, r_cut=3.5)
    rng = np.random.default_rng(20240123)
    p = init_params(cfg, rng)
    # 8-atom frozen cluster, everything inside the cutoff ball
    pos = 1.3 * rng.standard_normal((8, 3))
    species = rng.integers(0, cfg.n_species, 8)
    edges = build_edges(pos, cfg.r_cut)
    E, F, _ = energy_forces_grad(cfg, p, pos, species, edges)
    doc = {
        "config": {"l": cfg.l, "l_filter": cfg.l_filter, "nu": cfg.nu,
                   "n_layers": cfg.n_layers, "n_species": cfg.n_species,
                   "n_radial": cfg.n_radial, "r_cut": cfg.r_cut},
        "params": p.tolist(),
        "pos": pos.ravel().tolist(),
        "species": species.tolist(),
        "n_edges": len(edges),
        "energy": E,
        "forces": F.ravel().tolist(),
    }
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)
    path = os.path.join(out_dir, "golden", "model_golden.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"[model-golden] wrote {path} (E = {E:.6f}, {len(edges)} edges)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../rust/artifacts",
                    help="artifact dir receiving golden/model_golden.json")
    ap.add_argument("--check", action="store_true",
                    help="run the FD/equivariance/descent validators only")
    args = ap.parse_args()
    if args.check:
        run_checks()
    else:
        emit_model_golden(args.out)


if __name__ == "__main__":
    main()
