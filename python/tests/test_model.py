"""Layer-2 model tests: GauntNet force field + SEGNN-lite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import so3

RNG = np.random.default_rng(17)

CFG = M.Config(L=2, channels=4, n_atoms=8, n_edges=24, n_layers=2, tp="gaunt")


def _system(cfg=CFG, seed=0, n_real_atoms=None, n_real_edges=None):
    rng = np.random.default_rng(seed)
    n = n_real_atoms or cfg.n_atoms
    e = n_real_edges or cfg.n_edges
    pos = np.zeros((cfg.n_atoms, 3), np.float32)
    pos[:n] = rng.uniform(-2, 2, (n, 3))
    species = np.zeros(cfg.n_atoms, np.int32)
    species[:n] = rng.integers(0, cfg.n_species, n)
    edges = np.zeros((cfg.n_edges, 2), np.int32)
    k = 0
    while k < e:
        i, j = rng.integers(0, n, 2)
        if i != j:
            edges[k] = (i, j)
            k += 1
    am = np.zeros(cfg.n_atoms, np.float32)
    am[:n] = 1.0
    em = np.zeros(cfg.n_edges, np.float32)
    em[:e] = 1.0
    return (jnp.asarray(pos), jnp.asarray(species), jnp.asarray(edges),
            jnp.asarray(em), jnp.asarray(am))


class TestShCartesian:
    @pytest.mark.parametrize("L", [1, 2, 3])
    def test_matches_numpy_tables(self, L):
        pts = RNG.standard_normal((10, 3)).astype(np.float32)
        got = M.sh_cartesian(L, jnp.asarray(pts))
        want = so3.real_sh_xyz_poly(L, pts.astype(np.float64))
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_gradient_finite_at_zero(self):
        g = jax.grad(lambda r: jnp.sum(M.sh_cartesian(2, r)))(jnp.zeros(3))
        assert bool(jnp.isfinite(g).all())

    def test_scale_invariant(self):
        r = jnp.asarray(RNG.standard_normal((5, 3)), jnp.float32)
        a = M.sh_cartesian(2, r)
        b = M.sh_cartesian(2, 3.7 * r)
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestBessel:
    def test_zero_at_cutoff(self):
        rb = M.bessel_basis(jnp.asarray([3.9999]), 4, 4.0)
        assert float(jnp.abs(rb).max()) < 1e-3

    def test_finite_at_zero_distance(self):
        rb = M.bessel_basis(jnp.asarray([0.0]), 4, 4.0)
        assert bool(jnp.isfinite(rb).all())

    def test_shapes(self):
        rb = M.bessel_basis(jnp.asarray([1.0, 2.0, 3.0]), 6, 4.0)
        assert rb.shape == (3, 6)


class TestEnergyForces:
    def test_energy_invariant_forces_equivariant(self):
        p = M.init_params(0, CFG)
        sys_ = _system()
        e, f = M.energy_forces(p, *sys_, CFG)
        rot = so3.random_rotation(np.random.default_rng(1))
        rj = jnp.asarray(rot, jnp.float32)
        pos2 = sys_[0] @ rj.T
        e2, f2 = M.energy_forces(p, pos2, *sys_[1:], CFG)
        assert abs(float(e - e2)) < 1e-4
        np.testing.assert_allclose(f2, f @ rj.T, atol=1e-4)

    def test_translation_invariance(self):
        p = M.init_params(0, CFG)
        sys_ = _system()
        e, f = M.energy_forces(p, *sys_, CFG)
        shift = jnp.asarray([1.0, -2.0, 0.5], jnp.float32)
        e2, f2 = M.energy_forces(p, sys_[0] + shift, *sys_[1:], CFG)
        assert abs(float(e - e2)) < 1e-4
        np.testing.assert_allclose(f, f2, atol=1e-4)

    def test_parity_invariance(self):
        """E(3) (not just SE(3)): energy invariant under point reflection."""
        p = M.init_params(0, CFG)
        sys_ = _system()
        e, _ = M.energy_forces(p, *sys_, CFG)
        e2, _ = M.energy_forces(p, -sys_[0], *sys_[1:], CFG)
        assert abs(float(e - e2)) < 1e-4

    def test_forces_are_gradient(self):
        p = M.init_params(0, CFG)
        pos, species, edges, em, am = _system()
        _, f = M.energy_forces(p, pos, species, edges, em, am, CFG)
        h = 1e-3
        for (atom, axis) in [(0, 0), (3, 2)]:
            pp = pos.at[atom, axis].add(h)
            ep = M.energy_fn(p, pp, species, edges, em, am, CFG)
            pm = pos.at[atom, axis].add(-h)
            em_ = M.energy_fn(p, pm, species, edges, em, am, CFG)
            fd = -(float(ep) - float(em_)) / (2 * h)
            assert abs(float(f[atom, axis]) - fd) < 5e-2 * (1 + abs(fd))

    def test_padding_invariance(self):
        """Extra padded atoms/edges must not change real outputs."""
        p = M.init_params(0, CFG)
        sys_full = _system(n_real_atoms=5, n_real_edges=12)
        e1, f1 = M.energy_forces(p, *sys_full, CFG)
        # perturb the PADDED atom positions; outputs must not move
        pos2 = np.asarray(sys_full[0]).copy()
        pos2[5:] += 17.0
        e2, f2 = M.energy_forces(p, jnp.asarray(pos2), *sys_full[1:], CFG)
        assert abs(float(e1 - e2)) < 1e-4
        np.testing.assert_allclose(f1[:5], f2[:5], atol=1e-4)

    def test_masked_forces_zero(self):
        p = M.init_params(0, CFG)
        sys_ = _system(n_real_atoms=5)
        _, f = M.energy_forces(p, *sys_, CFG)
        np.testing.assert_allclose(f[5:], 0.0, atol=1e-6)

    def test_cg_variant_runs(self):
        cfg = M.Config(**{**CFG.__dict__, "tp": "cg"})
        p = M.init_params(0, cfg)
        e, f = M.energy_forces(p, *_system(cfg), cfg)
        assert np.isfinite(float(e)) and bool(jnp.isfinite(f).all())

    def test_gaunt_and_cg_differ(self):
        cfg_cg = M.Config(**{**CFG.__dict__, "tp": "cg"})
        p = M.init_params(0, CFG)
        sys_ = _system()
        e1, _ = M.energy_forces(p, *sys_, CFG)
        e2, _ = M.energy_forces(p, *sys_, cfg_cg)
        assert abs(float(e1 - e2)) > 1e-6  # different parameterizations


class TestTraining:
    def test_loss_decreases(self):
        p = M.init_params(0, CFG)
        pos, species, edges, em, am = _system()
        batch = dict(
            pos=pos[None], species=species[None], edges=edges[None],
            edge_mask=em[None], atom_mask=am[None],
            energy=jnp.asarray([2.0], jnp.float32),
            forces=jnp.asarray(RNG.standard_normal((1, 8, 3)) * 0.1,
                               jnp.float32),
        )
        opt = M.adam_init(p)
        step = jax.jit(lambda p_, o_, b_: M.ff_train_step(p_, o_, b_, CFG))
        _, _, l0 = step(p, opt, batch)
        p2, o2 = p, opt
        for _ in range(10):
            p2, o2, loss = step(p2, o2, batch)
        assert float(loss) < float(l0)

    def test_adam_moments_shapes(self):
        p = M.init_params(0, CFG)
        opt = M.adam_init(p)
        flat_p = jax.tree.leaves(p)
        flat_m = jax.tree.leaves(opt["m"])
        assert len(flat_p) == len(flat_m)
        for a, b in zip(flat_p, flat_m):
            assert a.shape == b.shape


class TestNbody:
    CFGN = M.Config(L=1, channels=4, n_atoms=5, n_edges=20, n_layers=2,
                    tp="gaunt", readout="vector", vec_in=True, n_species=2,
                    r_cut=20.0)

    def _nbody_inputs(self, seed=0):
        rng = np.random.default_rng(seed)
        pos = jnp.asarray(rng.uniform(-1, 1, (5, 3)), jnp.float32)
        vel = jnp.asarray(rng.uniform(-1, 1, (5, 3)) * 0.1, jnp.float32)
        ch = jnp.asarray(rng.integers(0, 2, 5), jnp.int32)
        e5 = jnp.asarray([(i, j) for i in range(5) for j in range(5) if i != j],
                         jnp.int32)
        return pos, vel, ch, e5, jnp.ones(20), jnp.ones(5)

    def test_equivariance(self):
        p = M.init_params(1, self.CFGN)
        pos, vel, ch, e5, em, am = self._nbody_inputs()
        out = M.nbody_forecast(p, pos, vel, ch, e5, em, am, self.CFGN)
        rot = so3.random_rotation(np.random.default_rng(2))
        rj = jnp.asarray(rot, jnp.float32)
        out2 = M.nbody_forecast(p, pos @ rj.T, vel @ rj.T, ch, e5, em, am,
                                self.CFGN)
        np.testing.assert_allclose(out2, out @ rj.T, atol=1e-4)

    def test_zero_model_returns_inertial_forecast(self):
        """With zeroed readout weights, prediction = pos + vel."""
        p = M.init_params(1, self.CFGN)
        p = dict(p)
        p["out_vec"] = jnp.zeros_like(p["out_vec"])
        pos, vel, ch, e5, em, am = self._nbody_inputs()
        out = M.nbody_forecast(p, pos, vel, ch, e5, em, am, self.CFGN)
        np.testing.assert_allclose(out, pos + vel, atol=1e-6)

    def test_train_step(self):
        p = M.init_params(1, self.CFGN)
        pos, vel, ch, e5, em, am = self._nbody_inputs()
        batch = dict(pos=pos[None], vel=vel[None], charge=ch[None],
                     edges=e5[None], edge_mask=em[None], atom_mask=am[None],
                     target=(pos + vel)[None])
        opt = M.adam_init(p)
        step = jax.jit(lambda p_, o_, b_:
                       M.nbody_train_step(p_, o_, b_, self.CFGN))
        _, _, l0 = step(p, opt, batch)
        p2, o2 = p, opt
        for _ in range(8):
            p2, o2, loss = step(p2, o2, batch)
        assert float(loss) < float(l0)


class TestMixChannels:
    def test_identity_weights(self):
        x = jnp.asarray(RNG.standard_normal((3, 4, 9)), jnp.float32)
        w = jnp.stack([jnp.eye(4)] * 3)
        np.testing.assert_allclose(M._mix_channels(x, w, 2), x, atol=1e-6)

    def test_per_degree_blocks(self):
        x = jnp.asarray(RNG.standard_normal((1, 2, 9)), jnp.float32)
        w = jnp.stack([2.0 * jnp.eye(2), 3.0 * jnp.eye(2), 5.0 * jnp.eye(2)])
        out = M._mix_channels(x, w, 2)
        np.testing.assert_allclose(out[..., 0], 2 * x[..., 0], atol=1e-5)
        np.testing.assert_allclose(out[..., 1:4], 3 * x[..., 1:4], atol=1e-5)
        np.testing.assert_allclose(out[..., 4:], 5 * x[..., 4:], atol=1e-5)
