"""Tests for the SO(3) representation-theory substrate (so3.py)."""
import math

import numpy as np
import pytest

from compile import so3


RNG = np.random.default_rng(42)


def _sphere_grid(deg):
    th, ph, w, dphi = so3.sphere_quadrature(deg)
    TH, PH = np.meshgrid(th, ph, indexing="ij")
    return TH, PH, w[:, None] * dphi


# --------------------------------------------------------------------------
# associated Legendre
# --------------------------------------------------------------------------


class TestAssocLegendre:
    @pytest.mark.parametrize("l,m", [(l, m) for l in range(9) for m in range(l + 1)])
    def test_matches_scipy(self, l, m):
        from scipy.special import lpmv

        x = np.linspace(-0.999, 0.999, 31)
        ours = so3.assoc_legendre(l, m, x)
        # scipy includes the Condon-Shortley phase (-1)^m; we do not.
        theirs = lpmv(m, l, x) * ((-1.0) ** m)
        np.testing.assert_allclose(ours, theirs, rtol=1e-7, atol=1e-9)

    def test_p00_is_one(self):
        np.testing.assert_allclose(so3.assoc_legendre(0, 0, np.array([0.3])), [1.0])

    def test_p10_is_x(self):
        x = np.linspace(-1, 1, 5)
        np.testing.assert_allclose(so3.assoc_legendre(1, 0, x), x)

    def test_p11_is_sin(self):
        x = np.linspace(-0.9, 0.9, 5)
        np.testing.assert_allclose(
            so3.assoc_legendre(1, 1, x), np.sqrt(1 - x * x), rtol=1e-12
        )

    @pytest.mark.parametrize("l", range(1, 8))
    def test_orthogonality_in_l(self, l):
        # int_-1^1 P_l^0 P_{l'}^0 dx = 2/(2l+1) delta
        x, w = np.polynomial.legendre.leggauss(l + 4)
        a = so3.assoc_legendre(l, 0, x)
        b = so3.assoc_legendre(l - 1, 0, x)
        assert abs(np.sum(w * a * b)) < 1e-12
        np.testing.assert_allclose(np.sum(w * a * a), 2.0 / (2 * l + 1), rtol=1e-12)


# --------------------------------------------------------------------------
# real spherical harmonics
# --------------------------------------------------------------------------


class TestRealSH:
    @pytest.mark.parametrize("L", [0, 1, 2, 3, 5, 8])
    def test_orthonormality(self, L):
        TH, PH, W = _sphere_grid(2 * L)
        y = so3.real_sh_all(L, TH, PH)
        g = np.einsum("kja,kjb,kj->ab", y, y, W)
        np.testing.assert_allclose(g, np.eye(g.shape[0]), atol=1e-12)

    def test_y00_constant(self):
        v = so3.real_sh_angular(0, 0, np.array([0.3]), np.array([1.0]))
        np.testing.assert_allclose(v, [1.0 / math.sqrt(4 * math.pi)])

    def test_y1_components_are_axes(self):
        # l=1 real SH are proportional to (y, z, x) in m = (-1, 0, 1) order
        pts = RNG.standard_normal((20, 3))
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        y = so3.real_sh_xyz(1, pts)
        c = math.sqrt(3.0 / (4 * math.pi))
        np.testing.assert_allclose(y[:, 1], c * pts[:, 1], atol=1e-12)
        np.testing.assert_allclose(y[:, 2], c * pts[:, 2], atol=1e-12)
        np.testing.assert_allclose(y[:, 3], c * pts[:, 0], atol=1e-12)

    @pytest.mark.parametrize("l", range(6))
    def test_parity(self, l):
        # Y^l(-r) = (-1)^l Y^l(r)   (paper Section 2)
        pts = RNG.standard_normal((10, 3))
        a = so3.real_sh_xyz(l, pts)
        b = so3.real_sh_xyz(l, -pts)
        sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
        np.testing.assert_allclose(b[:, sl], ((-1.0) ** l) * a[:, sl], atol=1e-12)

    @pytest.mark.parametrize("L", [1, 2, 4, 6])
    def test_polynomial_form_matches_angular(self, L):
        pts = RNG.standard_normal((50, 3))
        np.testing.assert_allclose(
            so3.real_sh_xyz_poly(L, pts),
            so3.real_sh_xyz(L, pts),
            atol=1e-10,
        )

    def test_complex_sh_matches_scipy(self):
        from scipy.special import sph_harm_y

        th = np.linspace(0.1, 3.0, 7)
        ph = np.linspace(0.0, 6.0, 7)
        for l in range(5):
            for m in range(-l, l + 1):
                np.testing.assert_allclose(
                    so3.complex_sh(l, m, th, ph),
                    sph_harm_y(l, m, th, ph),
                    atol=1e-12,
                    err_msg=f"l={l} m={m}",
                )

    def test_real_to_complex_u_unitary(self):
        for l in range(5):
            u = so3.real_to_complex_u(l)
            np.testing.assert_allclose(
                u @ u.conj().T, np.eye(2 * l + 1), atol=1e-12
            )

    def test_real_to_complex_u_consistent(self):
        th = np.linspace(0.2, 2.9, 6)
        ph = np.linspace(0.1, 6.0, 6)
        for l in range(4):
            u = so3.real_to_complex_u(l)
            yc = np.array(
                [so3.complex_sh(l, mu, th, ph) for mu in range(-l, l + 1)]
            )
            yr = np.array(
                [so3.real_sh_angular(l, m, th, ph) for m in range(-l, l + 1)]
            )
            np.testing.assert_allclose(u @ yc, yr.astype(complex), atol=1e-12)


# --------------------------------------------------------------------------
# Wigner 3j / CG
# --------------------------------------------------------------------------


class TestWigner3j:
    def test_known_values(self):
        # standard tabulated values
        np.testing.assert_allclose(so3.wigner_3j(1, 1, 0, 0, 0, 0), -1 / math.sqrt(3))
        np.testing.assert_allclose(so3.wigner_3j(1, 1, 2, 0, 0, 0), math.sqrt(2 / 15))
        np.testing.assert_allclose(so3.wigner_3j(2, 2, 2, 0, 0, 0), -math.sqrt(2 / 35))
        np.testing.assert_allclose(
            so3.wigner_3j(1, 1, 1, 1, -1, 0), 1 / math.sqrt(6)
        )

    def test_selection_rules(self):
        assert so3.wigner_3j(1, 1, 3, 0, 0, 0) == 0.0  # triangle violated
        assert so3.wigner_3j(1, 1, 1, 1, 1, 1) == 0.0  # m-sum nonzero
        assert so3.wigner_3j(1, 2, 2, 2, 0, -2) == 0.0  # |m1| > l1

    def test_odd_sum_zero_at_m0(self):
        assert so3.wigner_3j(1, 1, 1, 0, 0, 0) == 0.0
        assert so3.wigner_3j(2, 2, 1, 0, 0, 0) == 0.0

    @pytest.mark.parametrize("l1,l2", [(1, 1), (2, 1), (2, 2), (3, 2)])
    def test_orthogonality(self, l1, l2):
        # sum_{m1 m2} (2l+1) 3j(m1 m2 m) 3j(m1 m2 m') = delta_ll' delta_mm'
        for l in range(abs(l1 - l2), l1 + l2 + 1):
            for lp in range(abs(l1 - l2), l1 + l2 + 1):
                for m in range(-l, l + 1):
                    for mp in range(-lp, lp + 1):
                        s = sum(
                            so3.wigner_3j(l1, l2, l, m1, m2, m)
                            * so3.wigner_3j(l1, l2, lp, m1, m2, mp)
                            for m1 in range(-l1, l1 + 1)
                            for m2 in range(-l2, l2 + 1)
                        )
                        expect = (1.0 / (2 * l + 1)) if (l, m) == (lp, mp) else 0.0
                        assert abs(s - expect) < 1e-11

    def test_column_permutation_symmetry(self):
        # even permutation invariance
        v1 = so3.wigner_3j(3, 2, 1, 1, -2, 1)
        v2 = so3.wigner_3j(2, 1, 3, -2, 1, 1)
        v3 = so3.wigner_3j(1, 3, 2, 1, 1, -2)
        np.testing.assert_allclose([v2, v3], [v1, v1], atol=1e-13)
        # odd permutation: factor (-1)^(l1+l2+l3)
        v4 = so3.wigner_3j(2, 3, 1, -2, 1, 1)
        np.testing.assert_allclose(v4, ((-1.0) ** 6) * v1, atol=1e-13)

    def test_m_negation_symmetry(self):
        l1, l2, l3 = 3, 2, 2
        for m1 in range(-l1, l1 + 1):
            for m2 in range(-l2, l2 + 1):
                m3 = -(m1 + m2)
                if abs(m3) > l3:
                    continue
                a = so3.wigner_3j(l1, l2, l3, m1, m2, m3)
                b = so3.wigner_3j(l1, l2, l3, -m1, -m2, -m3)
                np.testing.assert_allclose(b, ((-1.0) ** (l1 + l2 + l3)) * a,
                                           atol=1e-13)


class TestClebschGordan:
    def test_known_values(self):
        # <1 0 1 0 | 2 0> = sqrt(2/3)
        np.testing.assert_allclose(
            so3.clebsch_gordan(1, 0, 1, 0, 2, 0), math.sqrt(2 / 3)
        )
        # <1 1 1 -1 | 0 0> = 1/sqrt(3)
        np.testing.assert_allclose(
            so3.clebsch_gordan(1, 1, 1, -1, 0, 0), 1 / math.sqrt(3)
        )
        # <1/2-analog not applicable (integer l only)
        np.testing.assert_allclose(
            so3.clebsch_gordan(1, 1, 1, 0, 2, 1), 1 / math.sqrt(2)
        )

    @pytest.mark.parametrize("l1,l2", [(1, 1), (2, 1), (2, 2)])
    def test_orthogonality_rows(self, l1, l2):
        # paper Eqn. (20), first identity
        for l in range(abs(l1 - l2), l1 + l2 + 1):
            for lp in range(abs(l1 - l2), l1 + l2 + 1):
                for m in range(-l, l + 1):
                    for mp in range(-lp, lp + 1):
                        s = sum(
                            so3.clebsch_gordan(l1, m1, l2, m2, l, m)
                            * so3.clebsch_gordan(l1, m1, l2, m2, lp, mp)
                            for m1 in range(-l1, l1 + 1)
                            for m2 in range(-l2, l2 + 1)
                        )
                        expect = 1.0 if (l, m) == (lp, mp) else 0.0
                        assert abs(s - expect) < 1e-11

    def test_completeness(self):
        # paper Eqn. (20), second identity
        l1, l2 = 2, 1
        for m1 in range(-l1, l1 + 1):
            for m2 in range(-l2, l2 + 1):
                for m1p in range(-l1, l1 + 1):
                    for m2p in range(-l2, l2 + 1):
                        s = sum(
                            so3.clebsch_gordan(l1, m1, l2, m2, l, m1 + m2)
                            * so3.clebsch_gordan(l1, m1p, l2, m2p, l, m1p + m2p)
                            for l in range(abs(l1 - l2), l1 + l2 + 1)
                            if abs(m1 + m2) <= l and m1 + m2 == m1p + m2p
                        )
                        expect = 1.0 if (m1, m2) == (m1p, m2p) else 0.0
                        assert abs(s - expect) < 1e-11


# --------------------------------------------------------------------------
# Gaunt coefficients
# --------------------------------------------------------------------------


class TestGaunt:
    def test_complex_gaunt_matches_quadrature(self):
        from scipy.special import sph_harm_y

        TH, PH, W = _sphere_grid(9)
        cases = [
            (1, 0, 1, 0, 2, 0),
            (1, 1, 1, -1, 2, 0),
            (2, 1, 2, -2, 2, 1),
            (3, 1, 2, -2, 3, 1),
            (2, 2, 2, 2, 4, -4),
        ]
        for l1, m1, l2, m2, l3, m3 in cases:
            f = (
                sph_harm_y(l1, m1, TH, PH)
                * sph_harm_y(l2, m2, TH, PH)
                * sph_harm_y(l3, m3, TH, PH)
            )
            quad = np.einsum("kj,kj->", f, W.astype(complex) * np.ones_like(PH))
            formula = so3.gaunt_complex(l1, m1, l2, m2, l3, m3)
            np.testing.assert_allclose(quad.real, formula, atol=1e-12)
            assert abs(quad.imag) < 1e-12

    def test_wigner_eckart_ratio_constant(self):
        """Paper Eqn. (3): G / CG is constant over (m1, m2, m) per (l1,l2,l)."""
        for l1, l2, l in [(1, 1, 2), (2, 1, 3), (2, 2, 2), (3, 2, 3)]:
            ratios = []
            for m1 in range(-l1, l1 + 1):
                for m2 in range(-l2, l2 + 1):
                    m = m1 + m2
                    if abs(m) > l:
                        continue
                    cg = so3.clebsch_gordan(l1, m1, l2, m2, l, m)
                    # complex Gaunt with m3 = -m carries the bra <l m|
                    ga = so3.gaunt_complex(l1, m1, l2, m2, l, -m) * ((-1.0) ** m)
                    if abs(cg) > 1e-12:
                        ratios.append(ga / cg)
            assert len(ratios) > 0
            np.testing.assert_allclose(ratios, ratios[0], atol=1e-12)

    @pytest.mark.parametrize("L", [1, 2, 3])
    def test_real_gaunt_two_routes_agree(self, L):
        a = so3.gaunt_tensor_real(L, L, L)
        b = so3.gaunt_tensor_real_from_3j(L, L, L)
        np.testing.assert_allclose(a, b, atol=1e-12)

    def test_real_gaunt_symmetric_in_inputs(self):
        g = so3.gaunt_tensor_real(2, 2, 3)
        np.testing.assert_allclose(g, np.transpose(g, (0, 2, 1)), atol=1e-14)

    def test_real_gaunt_l0_is_identity_scaled(self):
        # Y_0^0 = 1/sqrt(4pi): G[(l,m), (0,0), (l,m)] = 1/sqrt(4pi)
        g = so3.gaunt_tensor_real(0, 3, 3)
        c = 1.0 / math.sqrt(4 * math.pi)
        np.testing.assert_allclose(g[:, 0, :], c * np.eye(16), atol=1e-12)

    def test_real_gaunt_odd_parity_vanishes(self):
        # l1 + l2 + l3 odd => zero (Gaunt TP excludes pseudo-irreps)
        g = so3.gaunt_tensor_real(1, 1, 1)
        blk = g[
            so3.lm_index(1, -1) : so3.lm_index(1, 1) + 1,
            so3.lm_index(1, -1) : so3.lm_index(1, 1) + 1,
            so3.lm_index(1, -1) : so3.lm_index(1, 1) + 1,
        ]
        assert np.abs(blk).max() == 0.0


# --------------------------------------------------------------------------
# real w3j / CG tensor
# --------------------------------------------------------------------------


class TestRealW3j:
    @pytest.mark.parametrize(
        "l1,l2,l3", [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1), (2, 2, 3), (3, 2, 2)]
    )
    def test_equivariance(self, l1, l2, l3):
        """D^{l3} contraction == contraction of (D^{l1} x, D^{l2} y)."""
        w = so3.w3j_real(l1, l2, l3)
        rng = np.random.default_rng(7)
        rot = so3.random_rotation(rng)
        d1 = so3.wigner_d_real(l1, rot)
        d2 = so3.wigner_d_real(l2, rot)
        d3 = so3.wigner_d_real(l3, rot)
        # condition: sum_{xy} D1[x,a] D2[y,b] w[x,y,c] = sum_d w[a,b,d] D3[c,d]
        lhs = np.einsum("xa,yb,xyc->abc", d1, d2, w)
        rhs = np.einsum("abd,cd->abc", w, d3)
        np.testing.assert_allclose(lhs, rhs, atol=1e-10)

    def test_norm(self):
        for l1, l2, l3 in [(1, 1, 2), (2, 2, 2), (1, 1, 1)]:
            w = so3.w3j_real(l1, l2, l3)
            np.testing.assert_allclose(np.sum(w * w), 1.0, atol=1e-10)

    def test_cross_product_is_111(self):
        # the (1,1)->1 coupling must be proportional to the cross product
        w = so3.w3j_real(1, 1, 1)
        rng = np.random.default_rng(3)
        a3, b3 = rng.standard_normal(3), rng.standard_normal(3)
        # irrep order (m=-1,0,1) = (y, z, x)
        a = np.array([a3[1], a3[2], a3[0]])
        b = np.array([b3[1], b3[2], b3[0]])
        out = np.einsum("xyc,x,y->c", w, a, b)
        cr = np.cross(a3, b3)
        cr_i = np.array([cr[1], cr[2], cr[0]])
        # proportional
        k = out @ cr_i / (cr_i @ cr_i)
        np.testing.assert_allclose(out, k * cr_i, atol=1e-10)
        assert abs(k) > 1e-3

    def test_cg_tensor_gaunt_proportionality(self):
        """Per (l1,l2,l3) block with even parity, Gaunt tensor is a scalar
        multiple of the real CG tensor (Wigner-Eckart in the real basis)."""
        g = so3.gaunt_tensor_real(2, 2, 2)
        for l1, l2, l3 in [(1, 1, 2), (2, 2, 2), (2, 1, 1), (0, 2, 2)]:
            w = np.transpose(so3.w3j_real(l1, l2, l3), (2, 0, 1))
            sl3 = slice(so3.lm_index(l3, -l3), so3.lm_index(l3, l3) + 1)
            sl1 = slice(so3.lm_index(l1, -l1), so3.lm_index(l1, l1) + 1)
            sl2 = slice(so3.lm_index(l2, -l2), so3.lm_index(l2, l2) + 1)
            blk = g[sl3, sl1, sl2]
            k = np.sum(blk * w) / np.sum(w * w)
            np.testing.assert_allclose(blk, k * w, atol=1e-10)


# --------------------------------------------------------------------------
# rotations / Wigner-D
# --------------------------------------------------------------------------


class TestRotations:
    def test_rotation_matrices_orthogonal(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            r = so3.random_rotation(rng)
            np.testing.assert_allclose(r @ r.T, np.eye(3), atol=1e-12)
            np.testing.assert_allclose(np.linalg.det(r), 1.0, atol=1e-12)

    def test_euler_zyz(self):
        r = so3.euler_zyz(0.3, 0.0, -0.3)
        np.testing.assert_allclose(r, np.eye(3), atol=1e-12)

    @pytest.mark.parametrize("l", range(5))
    def test_wigner_d_is_representation(self, l):
        rng = np.random.default_rng(l)
        r1, r2 = so3.random_rotation(rng), so3.random_rotation(rng)
        d12 = so3.wigner_d_real(l, r1 @ r2)
        np.testing.assert_allclose(
            d12, so3.wigner_d_real(l, r1) @ so3.wigner_d_real(l, r2), atol=1e-10
        )

    @pytest.mark.parametrize("l", range(5))
    def test_wigner_d_orthogonal(self, l):
        rng = np.random.default_rng(100 + l)
        d = so3.wigner_d_real(l, so3.random_rotation(rng))
        np.testing.assert_allclose(d @ d.T, np.eye(2 * l + 1), atol=1e-10)

    def test_wigner_d_equivariance_on_sh(self):
        rng = np.random.default_rng(5)
        rot = so3.random_rotation(rng)
        pts = rng.standard_normal((8, 3))
        for l in range(4):
            sl = slice(so3.lm_index(l, -l), so3.lm_index(l, l) + 1)
            ya = so3.real_sh_xyz(l, pts @ rot.T)[:, sl]
            yb = so3.real_sh_xyz(l, pts)[:, sl] @ so3.wigner_d_real(l, rot).T
            np.testing.assert_allclose(ya, yb, atol=1e-10)

    def test_align_to_y(self):
        rng = np.random.default_rng(9)
        for _ in range(20):
            v = rng.standard_normal(3)
            r = so3.align_to_y(v)
            np.testing.assert_allclose(r @ r.T, np.eye(3), atol=1e-10)
            np.testing.assert_allclose(
                r @ (v / np.linalg.norm(v)), [0.0, 1.0, 0.0], atol=1e-10
            )

    def test_align_to_y_antiparallel(self):
        r = so3.align_to_y(np.array([0.0, -1.0, 0.0]))
        np.testing.assert_allclose(r @ np.array([0.0, -1.0, 0.0]),
                                   [0.0, 1.0, 0.0], atol=1e-12)

    def test_escn_filter_sparsity(self):
        """Passaro & Zitnick: SH of the aligned edge vector is delta_{m0}
        in the m-order convention where the filter axis is y... our SH uses
        the z-axis convention, so align to z gives delta_{m0}; the library's
        align_to_y matches eSCN's convention via the D-matrix. Verify the
        z-form here: Y_m^l(0,0,1) = 0 for m != 0."""
        y = so3.real_sh_xyz(4, np.array([[0.0, 0.0, 1.0]]))[0]
        for l, m in so3.lm_iter(4):
            if m != 0:
                assert abs(y[so3.lm_index(l, m)]) < 1e-12
            else:
                assert abs(y[so3.lm_index(l, 0)]) > 1e-6
