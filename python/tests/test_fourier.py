"""Tests for the SH <-> 2D Fourier change of basis (fourier.py)."""
import math

import numpy as np
import pytest

from compile import fourier as fr
from compile import so3

RNG = np.random.default_rng(2024)


def _eval_grid(grid, theta, phi):
    """Evaluate sum U[u,v] e^{i(u th + v ph)} at sample points."""
    n = (grid.shape[-1] - 1) // 2
    us = np.arange(-n, n + 1)
    e_th = np.exp(1j * np.multiply.outer(theta, us))  # [K, 2n+1]
    e_ph = np.exp(1j * np.multiply.outer(phi, us))
    return np.real(np.einsum("uv,ku,kv->k", grid, e_th, e_ph))


class TestThetaFourier:
    @pytest.mark.parametrize("l,m", [(l, m) for l in range(7) for m in range(l + 1)])
    def test_reconstructs_theta_part(self, l, m):
        c = fr.theta_fourier(l, m)
        theta = np.linspace(0.05, math.pi - 0.05, 37)
        us = np.arange(-l, l + 1)
        rec = np.real(np.exp(1j * np.multiply.outer(theta, us)) @ c)
        exact = so3.assoc_legendre(l, m, np.cos(theta)) * so3.sh_norm(l, m)
        np.testing.assert_allclose(rec, exact, atol=1e-12)

    @pytest.mark.parametrize("l,m", [(2, 0), (3, 1), (4, 3), (5, 2)])
    def test_parity_structure(self, l, m):
        """even m: coefficients real & even in u; odd m: imaginary & odd."""
        c = fr.theta_fourier(l, m)
        rev = c[::-1]
        if m % 2 == 0:
            assert np.abs(c.imag).max() < 1e-12
            np.testing.assert_allclose(c, rev, atol=1e-12)
        else:
            assert np.abs(c.real).max() < 1e-12
            np.testing.assert_allclose(c, -rev, atol=1e-12)

    @pytest.mark.parametrize("l,m", [(0, 0), (1, 0), (2, 1), (4, 2), (5, 5)])
    def test_theta_projection_vs_quadrature(self, l, m):
        """t_u = int_0^pi e^{iu th} N P sin(th) dth, checked by quadrature."""
        n_grid = l + 2
        t = fr.theta_projection(l, m, n_grid)
        # Gauss-Legendre on [0, pi]
        x, w = np.polynomial.legendre.leggauss(64)
        th = (x + 1) * (math.pi / 2)
        ww = w * (math.pi / 2)
        f = so3.assoc_legendre(l, m, np.cos(th)) * so3.sh_norm(l, m) * np.sin(th)
        for u in range(-n_grid, n_grid + 1):
            quad = np.sum(ww * f * np.exp(1j * u * th))
            np.testing.assert_allclose(t[n_grid + u], quad, atol=1e-10)


class TestSh2f:
    @pytest.mark.parametrize("L", [0, 1, 2, 3, 5])
    def test_function_values_match(self, L):
        x = RNG.standard_normal(so3.num_coeffs(L))
        grid = fr.sh2f(x, L)
        th = RNG.uniform(0.05, math.pi - 0.05, 25)
        ph = RNG.uniform(0, 2 * math.pi, 25)
        f_sh = (so3.real_sh_all(L, th, ph) * x).sum(-1)
        np.testing.assert_allclose(_eval_grid(grid, th, ph), f_sh, atol=1e-12)

    @pytest.mark.parametrize("L", [1, 3, 5])
    def test_v_sparsity(self, L):
        """column v of sh2f(e_{lm}) non-zero only for v = +-m (paper Sec 3.2)."""
        for l, m in so3.lm_iter(L):
            x = np.zeros(so3.num_coeffs(L))
            x[so3.lm_index(l, m)] = 1.0
            grid = fr.sh2f(x, L)
            for v in range(-L, L + 1):
                col = grid[:, L + v]
                if abs(v) != abs(m):
                    assert np.abs(col).max() < 1e-14, (l, m, v)

    @pytest.mark.parametrize("L", [1, 2, 4])
    def test_hermitian_symmetry(self, L):
        """real spatial function => U[-u,-v] = conj(U[u,v])."""
        x = RNG.standard_normal(so3.num_coeffs(L))
        g = fr.sh2f(x, L)
        np.testing.assert_allclose(g[::-1, ::-1], np.conj(g), atol=1e-13)

    @pytest.mark.parametrize("L", [0, 1, 2, 4, 6])
    def test_panels_match_dense(self, L):
        x = RNG.standard_normal((3, so3.num_coeffs(L)))
        np.testing.assert_allclose(
            fr.apply_sh2f_panels(x, L), fr.sh2f(x, L), atol=1e-12
        )

    def test_linear(self):
        L = 3
        x, y = RNG.standard_normal((2, so3.num_coeffs(L)))
        np.testing.assert_allclose(
            fr.sh2f(2.0 * x - y, L), 2.0 * fr.sh2f(x, L) - fr.sh2f(y, L), atol=1e-12
        )


class TestF2sh:
    @pytest.mark.parametrize("L", [0, 1, 2, 3, 5, 7])
    def test_round_trip_identity(self, L):
        x = RNG.standard_normal(so3.num_coeffs(L))
        np.testing.assert_allclose(fr.f2sh(fr.sh2f(x, L), L), x, atol=1e-12)

    @pytest.mark.parametrize("L", [1, 2, 4])
    def test_panels_match_dense(self, L):
        x = RNG.standard_normal((2, so3.num_coeffs(L)))
        g = fr.sh2f(x, L)
        np.testing.assert_allclose(
            fr.apply_f2sh_panels(g, L), fr.f2sh(g, L), atol=1e-12
        )

    def test_truncation_projects(self):
        """f2sh to a lower degree = orthogonal projection (drop high l)."""
        L = 4
        x = RNG.standard_normal(so3.num_coeffs(L))
        g = fr.sh2f(x, L)
        lo = fr.f2sh(g, 2)
        np.testing.assert_allclose(lo, x[: so3.num_coeffs(2)], atol=1e-12)


class TestConv2d:
    def test_full_matches_numpy_1d_outer(self):
        a = RNG.standard_normal((3, 3)) + 1j * RNG.standard_normal((3, 3))
        b = RNG.standard_normal((5, 5)) + 1j * RNG.standard_normal((5, 5))
        out = fr.conv2d_full(a, b)
        # brute force
        ref = np.zeros((7, 7), dtype=complex)
        for i in range(3):
            for j in range(3):
                for k in range(5):
                    for l in range(5):
                        ref[i + k, j + l] += a[i, j] * b[k, l]
        np.testing.assert_allclose(out, ref, atol=1e-13)

    def test_fft_matches_direct(self):
        a = RNG.standard_normal((7, 7)) + 1j * RNG.standard_normal((7, 7))
        b = RNG.standard_normal((9, 9)) + 1j * RNG.standard_normal((9, 9))
        np.testing.assert_allclose(
            fr.conv2d_fft(a, b), fr.conv2d_full(a, b), atol=1e-12
        )

    def test_delta_identity(self):
        d = np.zeros((3, 3), dtype=complex)
        d[1, 1] = 1.0
        b = RNG.standard_normal((5, 5)).astype(complex)
        out = fr.conv2d_full(d, b)
        np.testing.assert_allclose(out[1:6, 1:6], b, atol=1e-14)

    def test_commutative(self):
        a = RNG.standard_normal((5, 5)).astype(complex)
        b = RNG.standard_normal((7, 7)).astype(complex)
        np.testing.assert_allclose(
            fr.conv2d_full(a, b), fr.conv2d_full(b, a), atol=1e-12
        )


class TestGauntTensorProduct:
    @pytest.mark.parametrize(
        "L1,L2,L3",
        [(0, 0, 0), (1, 1, 2), (2, 2, 4), (3, 2, 4), (2, 3, 1), (4, 4, 4)],
    )
    def test_pipeline_equals_direct_contraction(self, L1, L2, L3):
        """THE core correctness claim: Fourier pipeline == Gaunt contraction."""
        x1 = RNG.standard_normal((4, so3.num_coeffs(L1)))
        x2 = RNG.standard_normal((4, so3.num_coeffs(L2)))
        a = fr.gaunt_tp(x1, L1, x2, L2, L3)
        b = fr.gaunt_tp_direct(x1, L1, x2, L2, L3)
        np.testing.assert_allclose(a, b, atol=1e-11)

    @pytest.mark.parametrize("L1,L2,L3", [(2, 2, 2), (3, 3, 3)])
    def test_fft_path_matches(self, L1, L2, L3):
        x1 = RNG.standard_normal(so3.num_coeffs(L1))
        x2 = RNG.standard_normal(so3.num_coeffs(L2))
        np.testing.assert_allclose(
            fr.gaunt_tp(x1, L1, x2, L2, L3, use_fft=True),
            fr.gaunt_tp(x1, L1, x2, L2, L3, use_fft=False),
            atol=1e-11,
        )

    def test_multiplying_by_constant_function(self):
        """F2 = c * Y_0^0 with c = sqrt(4pi) is the constant 1: TP = x."""
        L = 3
        x = RNG.standard_normal(so3.num_coeffs(L))
        one = np.zeros(1)
        one[0] = math.sqrt(4 * math.pi)
        out = fr.gaunt_tp(x, L, one, 0, L)
        np.testing.assert_allclose(out, x, atol=1e-12)

    @pytest.mark.parametrize("L", [1, 2, 3])
    def test_equivariance(self, L):
        """Gaunt TP commutes with rotations (paper Appendix D)."""
        rot = so3.random_rotation(np.random.default_rng(11))
        d = so3.wigner_d_real_block(L, rot)
        d_out = so3.wigner_d_real_block(2 * L, rot)
        x1 = RNG.standard_normal(so3.num_coeffs(L))
        x2 = RNG.standard_normal(so3.num_coeffs(L))
        a = fr.gaunt_tp(d @ x1, L, d @ x2, L, 2 * L)
        b = d_out @ fr.gaunt_tp(x1, L, x2, L, 2 * L)
        np.testing.assert_allclose(a, b, atol=1e-10)

    @pytest.mark.parametrize("L", [1, 2])
    def test_parity_invariance(self, L):
        """Gaunt TP commutes with the point reflection (O(3), not just SO(3)):
        parity acts as (-1)^l per irrep."""
        def par(L_, x):
            out = x.copy()
            for l, m in so3.lm_iter(L_):
                out[so3.lm_index(l, m)] *= (-1.0) ** l
            return out

        x1 = RNG.standard_normal(so3.num_coeffs(L))
        x2 = RNG.standard_normal(so3.num_coeffs(L))
        a = fr.gaunt_tp(par(L, x1), L, par(L, x2), L, 2 * L)
        b = par(2 * L, fr.gaunt_tp(x1, L, x2, L, 2 * L))
        np.testing.assert_allclose(a, b, atol=1e-11)

    def test_pointwise_product_semantics(self):
        """coefficients of F1*F2: evaluate both sides on the sphere."""
        L = 2
        x1 = RNG.standard_normal(so3.num_coeffs(L))
        x2 = RNG.standard_normal(so3.num_coeffs(L))
        x3 = fr.gaunt_tp(x1, L, x2, L, 2 * L)
        th = RNG.uniform(0.1, math.pi - 0.1, 30)
        ph = RNG.uniform(0, 2 * math.pi, 30)
        f1 = (so3.real_sh_all(L, th, ph) * x1).sum(-1)
        f2 = (so3.real_sh_all(L, th, ph) * x2).sum(-1)
        f3 = (so3.real_sh_all(2 * L, th, ph) * x3).sum(-1)
        np.testing.assert_allclose(f3, f1 * f2, atol=1e-11)

    def test_associativity_through_grids(self):
        """(x1*x2)*x3 == x1*(x2*x3) as functions — basis for the many-body
        divide-and-conquer (paper Appendix C)."""
        L = 2
        xs = RNG.standard_normal((3, so3.num_coeffs(L)))
        g = [fr.sh2f(x, L) for x in xs]
        a = fr.conv2d_full(fr.conv2d_full(g[0], g[1]), g[2])
        b = fr.conv2d_full(g[0], fr.conv2d_full(g[1], g[2]))
        np.testing.assert_allclose(a, b, atol=1e-12)
        np.testing.assert_allclose(fr.f2sh(a, 2), fr.f2sh(b, 2), atol=1e-12)


class TestEscnSparsity:
    def test_aligned_filter_grid_single_column(self):
        """SH of the z-aligned vector have m=0 only => Fourier grid of the
        filter is non-zero only at v=0 (paper Sec 3.3, Equivariant Conv)."""
        L = 4
        y = so3.real_sh_xyz(L, np.array([0.0, 0.0, 1.0]))
        g = fr.sh2f(y, L)
        for v in range(-L, L + 1):
            if v != 0:
                assert np.abs(g[:, L + v]).max() < 1e-12
        assert np.abs(g[:, L]).max() > 1e-3


class TestPackedTables:
    def test_shapes_and_dtype(self):
        t = fr.packed_tables_f32(3, 2, 4)
        assert t["p1"].shape == (4, 7, 4, 2) and t["p1"].dtype == np.float32
        assert t["p2"].shape == (3, 5, 3, 2)
        assert t["t3"].shape == (5, 5, 11, 2)

    def test_p_zero_below_s(self):
        t = fr.packed_tables_f32(3, 3, 3)
        p = t["p1"]
        for s in range(4):
            for l in range(s):
                assert np.abs(p[s, :, l]).max() == 0.0
