"""Pallas kernels vs pure-jnp oracles (the core L1 correctness signal)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import so3
from compile.kernels import cg_tp as ck
from compile.kernels import gaunt_tp as gk
from compile.kernels import ref

RNG = np.random.default_rng(7)


def _rand(b, L, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal((b, so3.num_coeffs(L))), dtype)


# --------------------------------------------------------------------------
# sh2f / f2sh pallas stages
# --------------------------------------------------------------------------


class TestSh2fPallas:
    @pytest.mark.parametrize("L", [0, 1, 2, 4, 6])
    def test_matches_dense_ref(self, L):
        x = _rand(3, L)
        g_re, g_im = gk.sh2f_pallas(x, L)
        want = ref.sh2f_ref(x, L)
        np.testing.assert_allclose(g_re, jnp.real(want), atol=2e-5)
        np.testing.assert_allclose(g_im, jnp.imag(want), atol=2e-5)

    def test_float64(self):
        x = _rand(2, 3, jnp.float64)
        g_re, g_im = gk.sh2f_pallas(x, 3)
        want = ref.sh2f_ref(x, 3)
        np.testing.assert_allclose(g_re, jnp.real(want), atol=1e-12)
        np.testing.assert_allclose(g_im, jnp.imag(want), atol=1e-12)

    def test_batch_not_multiple_of_block(self):
        x = _rand(37, 2)
        g_re, _ = gk.sh2f_pallas(x, 2, block_b=16)
        want = ref.sh2f_ref(x, 2)
        np.testing.assert_allclose(g_re, jnp.real(want), atol=2e-5)

    def test_under_jit(self):
        x = _rand(4, 3)
        f = jax.jit(lambda a: gk.sh2f_pallas(a, 3))
        g_re, g_im = f(x)
        want = ref.sh2f_ref(x, 3)
        np.testing.assert_allclose(g_re, jnp.real(want), atol=2e-5)


class TestF2shPallas:
    @pytest.mark.parametrize("L", [0, 1, 2, 4, 6])
    def test_round_trip(self, L):
        x = _rand(3, L)
        g_re, g_im = gk.sh2f_pallas(x, L)
        back = gk.f2sh_pallas(g_re, g_im, L)
        np.testing.assert_allclose(back, x, atol=3e-5)

    @pytest.mark.parametrize("L_out", [0, 1, 3])
    def test_truncation(self, L_out):
        x = _rand(2, 4)
        g_re, g_im = gk.sh2f_pallas(x, 4)
        out = gk.f2sh_pallas(g_re, g_im, L_out)
        np.testing.assert_allclose(out, x[:, : so3.num_coeffs(L_out)], atol=3e-5)


class TestConv2dPallas:
    @pytest.mark.parametrize("n1,n2", [(3, 3), (5, 7), (9, 5)])
    def test_matches_ref(self, n1, n2):
        a = jnp.asarray(RNG.standard_normal((2, n1, n1, 2)), jnp.float32)
        b = jnp.asarray(RNG.standard_normal((2, n2, n2, 2)), jnp.float32)
        o_re, o_im = gk.conv2d_pallas(a[..., 0], a[..., 1], b[..., 0], b[..., 1])
        want = ref.conv2d_ref(
            (a[..., 0] + 1j * a[..., 1]).astype(jnp.complex64),
            (b[..., 0] + 1j * b[..., 1]).astype(jnp.complex64),
        )
        np.testing.assert_allclose(o_re, jnp.real(want), atol=2e-5)
        np.testing.assert_allclose(o_im, jnp.imag(want), atol=2e-5)

    def test_fft_path_matches_direct(self):
        a = jnp.asarray(RNG.standard_normal((3, 7, 7, 2)), jnp.float32)
        b = jnp.asarray(RNG.standard_normal((3, 7, 7, 2)), jnp.float32)
        d_re, d_im = gk.conv2d_pallas(a[..., 0], a[..., 1], b[..., 0], b[..., 1])
        f_re, f_im = gk.conv2d_fft_xla(a[..., 0], a[..., 1], b[..., 0], b[..., 1])
        np.testing.assert_allclose(d_re, f_re, atol=3e-5)
        np.testing.assert_allclose(d_im, f_im, atol=3e-5)


# --------------------------------------------------------------------------
# full Gaunt TP kernel
# --------------------------------------------------------------------------


class TestGauntTpPallas:
    @pytest.mark.parametrize(
        "L1,L2,L3", [(0, 0, 0), (1, 1, 2), (2, 2, 2), (3, 2, 4), (2, 3, 1),
                     (4, 4, 4)]
    )
    @pytest.mark.parametrize("method", ["fft", "direct"])
    def test_matches_gaunt_contraction(self, L1, L2, L3, method):
        x1, x2 = _rand(4, L1), _rand(4, L2)
        f = gk.make_gaunt_tp(L1, L2, L3, method)
        out = f(x1, x2)
        want = ref.gaunt_tp_ref(x1, x2, L1, L2, L3)
        np.testing.assert_allclose(out, want, atol=5e-5)

    def test_matches_fourier_ref(self):
        x1, x2 = _rand(2, 3), _rand(2, 3)
        f = gk.make_gaunt_tp(3, 3, 3)
        np.testing.assert_allclose(
            f(x1, x2), ref.gaunt_tp_fourier_ref(x1, x2, 3, 3, 3), atol=5e-5
        )

    def test_bilinear(self):
        f = gk.make_gaunt_tp(2, 2, 2)
        x1, x1b, x2 = _rand(3, 2), _rand(3, 2), _rand(3, 2)
        np.testing.assert_allclose(
            f(2.0 * x1 + x1b, x2),
            2.0 * f(x1, x2) + f(x1b, x2),
            atol=1e-4,
        )

    def test_symmetric_when_same_degrees(self):
        f = gk.make_gaunt_tp(2, 2, 3)
        x1, x2 = _rand(3, 2), _rand(3, 2)
        np.testing.assert_allclose(f(x1, x2), f(x2, x1), atol=2e-5)

    def test_equivariance(self):
        L = 2
        rot = so3.random_rotation(np.random.default_rng(3))
        d = jnp.asarray(so3.wigner_d_real_block(L, rot), jnp.float32)
        d_out = jnp.asarray(so3.wigner_d_real_block(2 * L, rot), jnp.float32)
        x1, x2 = _rand(3, L), _rand(3, L)
        f = gk.make_gaunt_tp(L, L, 2 * L)
        a = f(x1 @ d.T, x2 @ d.T)
        b = f(x1, x2) @ d_out.T
        np.testing.assert_allclose(a, b, atol=1e-4)

    def test_grad_matches_oracle(self):
        L = 2
        x1, x2 = _rand(3, L), _rand(3, L)
        f = gk.make_gaunt_tp(L, L, 2 * L)

        def loss(a, b):
            return jnp.sum(jnp.sin(f(a, b)))

        def loss_ref(a, b):
            return jnp.sum(jnp.sin(ref.gaunt_tp_ref(a, b, L, L, 2 * L)))

        g1, g2 = jax.grad(loss, (0, 1))(x1, x2)
        r1, r2 = jax.grad(loss_ref, (0, 1))(x1, x2)
        np.testing.assert_allclose(g1, r1, atol=1e-4)
        np.testing.assert_allclose(g2, r2, atol=1e-4)

    def test_jittable(self):
        f = jax.jit(gk.make_gaunt_tp(2, 2, 2))
        x1, x2 = _rand(5, 2), _rand(5, 2)
        np.testing.assert_allclose(
            f(x1, x2), ref.gaunt_tp_ref(x1, x2, 2, 2, 2), atol=5e-5
        )

    def test_channelwise(self):
        B, C, L = 2, 3, 2
        x1 = jnp.asarray(RNG.standard_normal((B, C, so3.num_coeffs(L))), jnp.float32)
        x2 = jnp.asarray(RNG.standard_normal((B, C, so3.num_coeffs(L))), jnp.float32)
        out = gk.gaunt_tp_channelwise(x1, x2, L, L, L)
        want = ref.gaunt_tp_ref(x1, x2, L, L, L)
        np.testing.assert_allclose(out, want, atol=5e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        L1=st.integers(0, 3),
        L2=st.integers(0, 3),
        b=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, L1, L2, b, seed):
        """Property sweep: kernel == oracle over random shapes/degrees."""
        r = np.random.default_rng(seed)
        L3 = min(L1 + L2, 3)
        x1 = jnp.asarray(r.standard_normal((b, so3.num_coeffs(L1))), jnp.float32)
        x2 = jnp.asarray(r.standard_normal((b, so3.num_coeffs(L2))), jnp.float32)
        f = gk.make_gaunt_tp(L1, L2, L3)
        np.testing.assert_allclose(
            f(x1, x2), ref.gaunt_tp_ref(x1, x2, L1, L2, L3), atol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_scaling_invariant(self, seed):
        """G(a x1, b x2) = ab G(x1, x2)."""
        r = np.random.default_rng(seed)
        x1, x2 = (jnp.asarray(r.standard_normal((2, 9)), jnp.float32) for _ in "ab")
        a, b = float(r.uniform(0.5, 2)), float(r.uniform(0.5, 2))
        f = gk.make_gaunt_tp(2, 2, 2)
        np.testing.assert_allclose(
            f(a * x1, b * x2), a * b * f(x1, x2), rtol=2e-4, atol=1e-4
        )


# --------------------------------------------------------------------------
# CG TP baseline kernel
# --------------------------------------------------------------------------


class TestCgTpPallas:
    @pytest.mark.parametrize("L1,L2,L3", [(1, 1, 2), (2, 2, 2), (3, 2, 4)])
    def test_matches_ref(self, L1, L2, L3):
        x1, x2 = _rand(4, L1), _rand(4, L2)
        f = ck.make_cg_tp(L1, L2, L3)
        np.testing.assert_allclose(
            f(x1, x2), ref.cg_tp_ref(x1, x2, L1, L2, L3), atol=5e-5
        )

    def test_equivariance(self):
        L = 2
        rot = so3.random_rotation(np.random.default_rng(5))
        d = jnp.asarray(so3.wigner_d_real_block(L, rot), jnp.float32)
        d_out = jnp.asarray(so3.wigner_d_real_block(2 * L, rot), jnp.float32)
        x1, x2 = _rand(3, L), _rand(3, L)
        f = ck.make_cg_tp(L, L, 2 * L)
        np.testing.assert_allclose(
            f(x1 @ d.T, x2 @ d.T), f(x1, x2) @ d_out.T, atol=1e-4
        )

    def test_grad(self):
        f = ck.make_cg_tp(2, 2, 2)
        x1, x2 = _rand(2, 2), _rand(2, 2)

        def loss(a, b):
            return jnp.sum(f(a, b) ** 2)

        def loss_ref(a, b):
            return jnp.sum(ref.cg_tp_ref(a, b, 2, 2, 2) ** 2)

        g = jax.grad(loss, (0, 1))(x1, x2)
        r = jax.grad(loss_ref, (0, 1))(x1, x2)
        np.testing.assert_allclose(g[0], r[0], atol=1e-4)
        np.testing.assert_allclose(g[1], r[1], atol=1e-4)

    def test_differs_from_gaunt(self):
        """CG includes odd-parity paths the Gaunt TP excludes: the two
        products must NOT coincide (1,1)->1 (the cross-product path)."""
        x1, x2 = _rand(1, 1), _rand(1, 1)
        # zero the l=0 parts so only the pure (1,1)->1 path remains
        x1 = x1.at[:, 0].set(0.0)
        x2 = x2.at[:, 0].set(0.0)
        cg = ck.make_cg_tp(1, 1, 1)(x1, x2)
        ga = gk.make_gaunt_tp(1, 1, 1)(x1, x2)
        l1_cg = cg[0, 1:4]
        l1_ga = ga[0, 1:4]
        assert float(jnp.abs(l1_cg).max()) > 1e-3  # CG has the l=1 output
        assert float(jnp.abs(l1_ga).max()) < 1e-5  # Gaunt kills it (parity)


# --------------------------------------------------------------------------
# many-body helpers
# --------------------------------------------------------------------------


class TestManyBody:
    def test_ref_three_body_symmetric(self):
        x = _rand(2, 1)
        a = ref.many_body_ref([x, x, x], 1, 2)
        # fully symmetric product of the same function: order irrelevant
        b = ref.many_body_ref([x, x, x], 1, 2)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_divide_and_conquer_matches_fold(self):
        """(x1*x2)*(x3*x4) == ((x1*x2)*x3)*x4 — associativity backs the
        paper's parallelization (Appendix C)."""
        L = 1
        xs = [_rand(2, L) for _ in range(4)]
        fold = ref.many_body_ref(xs, L, 2)
        f12 = gk.make_gaunt_tp(L, L, 2 * L)
        f34 = gk.make_gaunt_tp(L, L, 2 * L)
        top = gk.make_gaunt_tp(2 * L, 2 * L, 2)
        dc = top(f12(xs[0], xs[1]), f34(xs[2], xs[3]))
        np.testing.assert_allclose(dc, fold, atol=1e-4)


class TestScaleByDegree:
    def test_segments(self):
        x = jnp.ones((1, 9))
        w = jnp.asarray([[2.0, 3.0, 4.0]])
        out = ref.scale_by_degree(x, w, 2)
        np.testing.assert_allclose(
            out[0], [2, 3, 3, 3, 4, 4, 4, 4, 4], atol=1e-6
        )

    def test_weighted_tp_reparameterization(self):
        """w_l1 w_l2 w_l weighting == scaling inputs/outputs (paper Eqn. 57)."""
        L = 2
        x1, x2 = _rand(2, L), _rand(2, L)
        w1 = jnp.asarray(RNG.standard_normal((1, L + 1)), jnp.float32)
        w2 = jnp.asarray(RNG.standard_normal((1, L + 1)), jnp.float32)
        w3 = jnp.asarray(RNG.standard_normal((1, 2 * L + 1)), jnp.float32)
        f = gk.make_gaunt_tp(L, L, 2 * L)
        out = ref.scale_by_degree(
            f(ref.scale_by_degree(x1, w1, L), ref.scale_by_degree(x2, w2, L)),
            w3, 2 * L,
        )
        # against direct weighted contraction
        g = np.asarray(so3.gaunt_tensor_real(L, L, 2 * L))
        want = np.zeros((2, so3.num_coeffs(2 * L)))
        for l1 in range(L + 1):
            for l2 in range(L + 1):
                for l3 in range(2 * L + 1):
                    wgt = float(w1[0, l1] * w2[0, l2] * w3[0, l3])
                    s3 = slice(so3.lm_index(l3, -l3), so3.lm_index(l3, l3) + 1)
                    s1 = slice(so3.lm_index(l1, -l1), so3.lm_index(l1, l1) + 1)
                    s2 = slice(so3.lm_index(l2, -l2), so3.lm_index(l2, l2) + 1)
                    want[:, s3] += wgt * np.einsum(
                        "kij,bi,bj->bk", g[s3, s1, s2],
                        np.asarray(x1)[:, s1], np.asarray(x2)[:, s2],
                    )
        np.testing.assert_allclose(out, want, atol=1e-4)
