//! Multi-process serving, end to end in one program: stand up two
//! replicas and a front door over Unix-domain sockets (all in this
//! process, but over REAL sockets — the same wire path `gaunt-tp
//! replica` / `gaunt-tp frontdoor` serve across processes), then drive
//! them with the socket client:
//!
//! * typed submissions through the front door, sharded by shape bucket;
//! * a streaming `MdRollout` whose frames cross the wire one by one;
//! * a deadline that expires server-side and comes back typed;
//! * a wire cancel that releases the replica-side ticket;
//! * a replica shutdown mid-load — the prober marks it down and the
//!   front door reroutes, so every request still resolves.
//!
//!     cargo run --release --example socket_serving
//!
//! For separate processes, see `make serve-cluster` and
//! `make loadtest-net`.

use std::time::Duration;

use gaunt_tp::coordinator::server::{NativeGauntBackend, ServerConfig};
use gaunt_tp::coordinator::{
    EnergyForces, MdRollout, Request, Service, ServiceError, Structure,
};
use gaunt_tp::net::loadtest::cluster;
use gaunt_tp::net::{
    temp_socket_path, Addr, FrontDoor, FrontDoorConfig, NetClient, Replica,
};
use gaunt_tp::util::error::{Error, Result};

fn service() -> Result<Service> {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { n_workers: 2, ..Default::default() })
        .build()
}

fn main() -> Result<()> {
    // ---- the cluster: two replicas + a front door, Unix sockets ----
    let r0 = Replica::serve(
        service()?,
        &[Addr::Unix(temp_socket_path("example-r0"))],
        "r0",
    )?;
    let r1 = Replica::serve(
        service()?,
        &[Addr::Unix(temp_socket_path("example-r1"))],
        "r1",
    )?;
    let fd = FrontDoor::serve(
        &[r0.bound()[0].clone(), r1.bound()[0].clone()],
        &[Addr::Unix(temp_socket_path("example-fd"))],
        FrontDoorConfig::default(),
    )?;
    println!(
        "front door {} -> [{}, {}]",
        fd.bound()[0],
        r0.bound()[0],
        r1.bound()[0]
    );

    let nc = NetClient::connect(&fd.bound()[0])?;
    println!(
        "handshake: server takes <= {} atoms, buckets {:?}",
        nc.max_atoms(),
        nc.buckets()
    );

    // ---- typed submissions through the front door ----
    let st: Structure = cluster(12, 7);
    let f = nc
        .submit(Request::new(EnergyForces(st.clone())))
        .map_err(Error::msg)?
        .wait()
        .map_err(Error::msg)?;
    println!(
        "energy+forces: E = {:.6}, {} force rows",
        f.energy,
        f.forces.len()
    );

    // ---- streaming rollout: frames cross the wire as they compute ----
    let mut md = nc
        .submit(Request::new(MdRollout {
            structure: st.clone(),
            steps: 5,
            dt: 1e-3,
        }))
        .map_err(Error::msg)?;
    let mut streamed = 0usize;
    while let Some(frame) = md.next_frame() {
        streamed += 1;
        println!("  frame {}: E = {:.6}", frame.step, frame.energy);
    }
    let traj = md.wait().map_err(Error::msg)?;
    println!(
        "rollout: {streamed} frames streamed, {} integrator steps",
        traj.summary.steps
    );

    // ---- a deadline the work cannot meet comes back typed ----
    let doomed = nc
        .submit(
            Request::new(MdRollout {
                structure: cluster(20, 8),
                steps: 3000,
                dt: 1e-4,
            })
            .deadline(Duration::from_millis(1)),
        )
        .map_err(Error::msg)?;
    match doomed.wait() {
        Err(ServiceError::DeadlineExceeded) => {
            println!("deadline: typed DeadlineExceeded across the wire")
        }
        other => println!("deadline: unexpected {other:?}"),
    }

    // ---- a wire cancel releases the replica-side ticket ----
    let canceled = nc
        .submit(Request::new(MdRollout {
            structure: cluster(20, 9),
            steps: 100_000,
            dt: 1e-4,
        }))
        .map_err(Error::msg)?;
    std::thread::sleep(Duration::from_millis(20));
    canceled.cancel();
    match canceled.wait() {
        Err(ServiceError::Canceled) => {
            println!("cancel: typed Canceled, replica worker released")
        }
        other => println!("cancel: unexpected {other:?}"),
    }

    // ---- kill a replica mid-load: the front door reroutes ----
    r0.shutdown();
    let mut ok = 0usize;
    for k in 0..8u64 {
        if nc
            .submit(Request::new(EnergyForces(cluster(10, 100 + k))))
            .and_then(|t| t.wait())
            .is_ok()
        {
            ok += 1;
        }
    }
    println!("after replica shutdown: {ok}/8 served by the survivor");

    let stats = nc.stats(Duration::from_secs(5))?;
    println!(
        "fleet ledger: requests={} responses={} failed={} canceled={} \
         expired={} (reconciles: {})",
        stats.requests,
        stats.responses,
        stats.failed,
        stats.canceled,
        stats.expired,
        stats.reconciles()
    );

    nc.close();
    fd.shutdown();
    r1.shutdown();
    Ok(())
}
