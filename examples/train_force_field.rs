//! End-to-end force-field training, fully native (the repo's full-stack
//! proof, no compiled artifacts needed): sample a labeled 3BPA-lite
//! dataset with the MD substrate, train the Gaunt-engine model with the
//! native trainer (energy + force loss, Adam, analytic backward passes
//! through every planned tensor product), checkpoint to JSON, evaluate
//! on held-out structures, then HOT-SWAP the trained checkpoint into a
//! live typed `Service` (started on an untrained model) and watch the
//! served test error drop — the checkpoint-to-production path of
//! DESIGN.md §10, exercised end to end.
//!
//!     cargo run --release --example train_force_field \
//!         [-- --steps 120 --channels 2]
//!
//! (The XLA-artifact training path lives in `experiments::train_forcefield`
//! behind `make artifacts`; this example is its offline twin.)

use std::sync::Arc;

use gaunt_tp::coordinator::trainer::{NativeTrainConfig, NativeTrainer};
use gaunt_tp::coordinator::{
    Batch, Client, EnergyForces, Request, ServerConfig, Service, Structure,
};
use gaunt_tp::data::{energy_stats, gen_bpa_dataset, normalize_graphs, Graph};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::util::error::Result;
use gaunt_tp::util::rng::Rng;

fn flag(args: &[String], name: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn eval(model: &Model, set: &[Graph]) -> (f64, f64) {
    let mut e_mae = 0.0;
    let mut f_mae = 0.0;
    let mut f_n = 0usize;
    for g in set {
        let (e, f) = model.energy_forces(&g.pos, &g.species);
        e_mae += (e - g.energy).abs() / g.n_atoms() as f64;
        for (fi, fr) in f.iter().zip(&g.forces) {
            for ax in 0..3 {
                f_mae += (fi[ax] - fr[ax]).abs();
                f_n += 1;
            }
        }
    }
    (e_mae / set.len() as f64, f_mae / f_n as f64)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps = flag(&args, "--steps", 120);
    let batch_size = flag(&args, "--batch", 4).max(1);
    // feature multiplicity: `--channels 8` trains the multi-channel
    // Irreps model (8x0 + 8x1 + 8x2 node features)
    let channels = flag(&args, "--channels", 1).max(1);

    println!(
        "== native GauntNet training ({steps} steps, batch {batch_size}, \
         {channels} channel(s)) =="
    );
    // labeled data from the MD substrate (classical potential = "DFT")
    let mut graphs = gen_bpa_dataset(&[0.05], 40, 11).remove(0);
    let stats = energy_stats(&graphs[..32]);
    normalize_graphs(&mut graphs, stats);
    let (train, test) = graphs.split_at(32);
    let train = train.to_vec();
    let test = test.to_vec();

    let cfg = ModelConfig { r_cut: 3.0, channels, ..Default::default() };
    let model = Model::new(cfg, 7);
    println!("node irreps: {}", model.node_irreps());
    model.warm();
    let mut trainer = NativeTrainer::new(model, NativeTrainConfig {
        lr: 4e-3,
        ..Default::default()
    });

    let (e0, f0) = eval(&trainer.model, &test);
    println!("before: test energy MAE/atom {e0:.4}, force MAE {f0:.4}");

    let mut rng = Rng::new(0);
    let mut order: Vec<usize> = (0..train.len()).collect();
    let t0 = std::time::Instant::now();
    let mut first_loss = f64::NAN;
    for step in 0..steps {
        if step % (train.len() / batch_size).max(1) == 0 {
            rng.shuffle(&mut order);
        }
        let at = (step * batch_size) % train.len();
        let batch: Vec<Graph> = (0..batch_size)
            .map(|k| train[order[(at + k) % train.len()]].clone())
            .collect();
        let loss = trainer.step(&batch);
        if step == 0 {
            first_loss = loss;
        }
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: loss {loss:.5} (recent {:.5})",
                     trainer.recent_loss(10));
        }
    }
    let per_step = t0.elapsed().as_secs_f64() / steps as f64;
    let last = trainer.recent_loss(10);
    println!("loss {first_loss:.5} -> {last:.5}  ({per_step:.3} s/step)");
    assert!(
        last < first_loss,
        "training did not decrease the loss ({first_loss} -> {last})"
    );

    let (e1, f1) = eval(&trainer.model, &test);
    println!("after:  test energy MAE/atom {e1:.4}, force MAE {f1:.4}");

    // checkpoint through util::json
    let ckpt = "target/model_native.json";
    let _ = std::fs::create_dir_all("target");
    trainer.checkpoint(ckpt)?;
    println!("checkpoint -> {ckpt}");

    // serve through the typed service: start a live endpoint on a FRESH
    // (untrained) model, then hot-swap the trained checkpoint in — the
    // checkpoint-to-production path, no restart, no dropped requests
    let service = Service::builder()
        .model(Arc::new(Model::new(cfg, 99)))
        .config(ServerConfig::default())
        .build()?;
    let client = service.client();
    let served_mae = |client: &Client, label: &str| -> Result<f64> {
        // one multi-structure Batch task for the whole held-out set
        let rows = client
            .call(Request::new(Batch(
                test.iter()
                    .map(|g| Structure::new(g.pos.clone(), g.species.clone()))
                    .collect(),
            )))
            .map_err(|e| gaunt_tp::err!("{e}"))?;
        let mae = rows
            .iter()
            .zip(&test)
            .map(|(r, g)| (r.energy - g.energy).abs() / g.n_atoms() as f64)
            .sum::<f64>()
            / test.len() as f64;
        println!("served test energy MAE/atom ({label}): {mae:.4}");
        Ok(mae)
    };
    let mae_untrained = served_mae(&client, "untrained endpoint")?;
    let version = trainer.promote_to(&service, "default");
    println!("hot-swapped the trained checkpoint into the live service \
              (endpoint version {version})");
    let mae_trained = served_mae(&client, "after hot swap")?;
    assert!(
        mae_trained < mae_untrained,
        "promotion must improve the served model \
         ({mae_untrained:.4} -> {mae_trained:.4})"
    );
    // the served model is exactly the trainer's snapshot
    let model = Arc::new(trainer.into_model());
    let mut served_err = 0.0f64;
    for g in &test {
        let resp = client
            .call(Request::new(EnergyForces(Structure::new(
                g.pos.clone(),
                g.species.clone(),
            ))))
            .map_err(|e| gaunt_tp::err!("{e}"))?;
        let (e_local, _) = model.energy_forces(&g.pos, &g.species);
        served_err = served_err.max((resp.energy - e_local).abs());
    }
    println!(
        "served {} held-out structures through the hot-swapped endpoint \
         (max |served - local| = {served_err:.2e})",
        test.len()
    );
    println!("service metrics: {}", service.metrics().report());
    service.shutdown();
    Ok(())
}
