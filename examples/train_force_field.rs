//! End-to-end training driver (the repo's full-stack proof): generate a
//! synthetic adsorbate dataset with the MD substrate, train GauntNet for a
//! few hundred steps through the fused AOT train-step artifact (Pallas
//! Gaunt kernels + JAX autodiff + Adam, all inside one XLA computation
//! executed from Rust), log the loss curve, and report test metrics.
//!
//!     make artifacts && cargo run --release --example train_force_field
//!     [-- --steps 300 --variant gaunt]

use gaunt_tp::util::error::Result;
use gaunt_tp::experiments::{eval_forcefield, train_forcefield};
use gaunt_tp::data::{gen_adsorbate_dataset, normalize_graphs};
use gaunt_tp::runtime::Engine;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(200usize);
    let variant = args
        .iter()
        .position(|a| a == "--variant")
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| "gaunt".to_string());

    let engine = Engine::new("artifacts")?;
    println!("== end-to-end GauntNet training ({variant}, {steps} steps) ==");
    let (state, stats, per_step) =
        train_forcefield(&engine, &variant, steps, true)?;

    // held-out evaluation
    let mut test = gen_adsorbate_dataset(24, 777);
    normalize_graphs(&mut test, stats);
    let fwd = if variant == "gaunt" { "ff_fwd_B8" } else { "ff_fwd_cg_B8" };
    let (e_mae, f_mae, f_cos, efwt) = eval_forcefield(&engine, fwd, &state, &test)?;
    println!("\n== held-out test (24 structures) ==");
    println!("energy MAE / atom : {e_mae:.4} (normalized units)");
    println!("force MAE         : {f_mae:.4}");
    println!("force cos         : {f_cos:.3}");
    println!("EFwT              : {:.1}%", 100.0 * efwt);
    println!("throughput        : {:.2} s/step (batch 8)", per_step);
    println!("\nloss curve logged above; see EXPERIMENTS.md §e2e for the record.");
    Ok(())
}
