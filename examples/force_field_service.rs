//! Serving benchmark example for the typed multi-task protocol: drive a
//! shape-bucketed native `Service` with concurrent clients submitting a
//! mixed workload — single-structure `EnergyForces`, multi-structure
//! `Batch`, an `EnergyOnly` stream with deadlines, and a streaming
//! `MdRollout` — and report latency/throughput plus the padding
//! accounting.  Runs fully offline (no artifacts needed).
//!
//!     cargo run --release --example force_field_service

use std::time::{Duration, Instant};

use gaunt_tp::coordinator::batcher::BatchPolicy;
use gaunt_tp::coordinator::server::{NativeGauntBackend, ServerConfig};
use gaunt_tp::coordinator::{
    Batch, EnergyForces, EnergyOnly, MdRollout, Request, Service,
    ServiceError, Structure,
};
use gaunt_tp::data::gen_bpa_dataset;
use gaunt_tp::util::error::Result;
use gaunt_tp::util::rng::Rng;

fn small_cluster(seed: u64) -> Structure {
    let mut rng = Rng::new(seed);
    Structure::new(
        (0..4)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect(),
        (0..4).map(|i| i % 3).collect(),
    )
}

fn main() -> Result<()> {
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                max_queue: 8192,
            },
            n_workers: 2,
            ..Default::default()
        })
        .build()?;
    println!("buckets:");
    for b in service.buckets() {
        println!(
            "  <= {:>2} atoms ({} edge slots, max_batch {})",
            b.max_atoms, b.max_edges, b.policy.max_batch
        );
    }

    let n_clients = 4usize;
    let per_client = 32usize;
    let big = gen_bpa_dataset(&[0.05], per_client, 13).remove(0);

    println!(
        "load test: {n_clients} concurrent clients x {per_client} \
         mixed-size requests"
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = service.client();
        let structs = big.clone();
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::new();
            for (k, g) in structs.iter().enumerate() {
                // bimodal: alternate the 14-atom MD sample with a
                // 4-atom cluster so the bucket ladder earns its keep
                let st = if k % 2 == 0 {
                    Structure::new(g.pos.clone(), g.species.clone())
                } else {
                    small_cluster((c * per_client + k) as u64)
                };
                match client
                    .submit(Request::new(EnergyForces(st)))
                    .map(|t| t.wait())
                {
                    Ok(Ok(resp)) => {
                        assert!(resp.energy.is_finite());
                        lat.push(resp.latency_s);
                    }
                    Ok(Err(e)) => eprintln!("request failed: {e}"),
                    Err(e) => eprintln!("submit rejected: {e}"),
                }
            }
            lat
        }));
    }
    let mut all_lat = Vec::new();
    for h in handles {
        all_lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = all_lat.len();
    if total == 0 {
        return Err(gaunt_tp::err!(
            "no request completed — see the per-request errors above"
        ));
    }

    // the other task shapes, through the same live service
    let client = service.client();
    let batch = client
        .call(Request::new(Batch(
            (0..6).map(|k| small_cluster(1000 + k)).collect(),
        )))
        .map_err(|e| gaunt_tp::err!("{e}"))?;
    println!("batch task: {} structures in one submission", batch.len());

    // an aggressive deadline may or may not expire under load — both
    // outcomes are typed
    match client.call(
        Request::new(EnergyOnly(small_cluster(7)))
            .deadline(Duration::from_micros(50)),
    ) {
        Ok(r) => println!("deadline'd energy request made it: {:.4}", r.energy),
        Err(ServiceError::DeadlineExceeded) => {
            println!("deadline'd energy request expired (typed error)")
        }
        Err(e) => return Err(gaunt_tp::err!("{e}")),
    }

    let mut ticket = client
        .submit(Request::new(MdRollout {
            structure: small_cluster(3),
            steps: 25,
            dt: 1e-3,
        }))
        .map_err(|e| gaunt_tp::err!("{e}"))?;
    let mut frames = 0;
    while ticket.next_frame().is_some() {
        frames += 1;
    }
    let traj = ticket.wait().map_err(|e| gaunt_tp::err!("{e}"))?;
    println!(
        "rollout task: {frames} streamed frames, final E {:.4}",
        traj.summary.final_energy
    );

    println!("\n== results ==");
    println!("throughput : {:.1} structures/s", total as f64 / wall);
    println!("p50 latency: {:.2} ms", 1e3 * all_lat[total / 2]);
    println!(
        "p99 latency: {:.2} ms",
        1e3 * all_lat[(total * 99 / 100).min(total - 1)]
    );
    println!("atom fill  : {:.3} (1.0 = zero padding waste)",
             service.metrics().atom_fill());
    println!("server     : {}", service.metrics().report());
    service.shutdown();
    Ok(())
}
