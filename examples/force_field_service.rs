//! Serving benchmark example: drive the batched force-field service with
//! concurrent clients and report latency/throughput — the paper's
//! deployment setting (batch inference for relaxations/MD).
//!
//!     make artifacts && cargo run --release --example force_field_service

use std::sync::Arc;
use std::time::{Duration, Instant};

use gaunt_tp::util::error::Result;
use gaunt_tp::coordinator::batcher::BatchPolicy;
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
use gaunt_tp::data::gen_bpa_dataset;
use gaunt_tp::runtime::Engine;

fn main() -> Result<()> {
    let engine = Arc::new(Engine::new("artifacts")?);
    let server = Arc::new(ForceFieldServer::start(
        engine,
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(4),
                max_queue: 8192,
            },
            n_workers: 2,
            ..Default::default()
        },
    )?);

    let n_clients = 4usize;
    let per_client = 32usize;
    let structures = gen_bpa_dataset(&[0.05], per_client, 13).remove(0);

    println!(
        "load test: {n_clients} concurrent clients x {per_client} requests"
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let srv = server.clone();
        let structs = structures.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut lat = Vec::new();
            for g in &structs {
                let resp =
                    srv.infer_blocking(g.pos.clone(), g.species.clone())?;
                lat.push(resp.latency_s);
                assert_eq!(resp.forces.len(), g.pos.len());
            }
            let _ = c;
            Ok(lat)
        }));
    }
    let mut all_lat = Vec::new();
    for h in handles {
        all_lat.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = n_clients * per_client;
    println!("\n== results ==");
    println!("throughput : {:.1} structures/s", total as f64 / wall);
    println!("p50 latency: {:.2} ms", 1e3 * all_lat[total / 2]);
    println!("p99 latency: {:.2} ms", 1e3 * all_lat[total * 99 / 100]);
    println!("server     : {}", server.metrics().report());
    Arc::try_unwrap(server).ok().map(|s| s.shutdown());
    Ok(())
}
