//! Model-in-the-loop molecular dynamics, fully native: quick-train the
//! Gaunt-engine model on 3BPA-lite labels, then
//!
//! 1. drive BAOAB MD *locally* with [`LearnedPotential`] through
//!    `Integrator::step_with` (plus a FIRE relaxation on the learned
//!    surface), and
//! 2. drive MD through the *served* model as ONE streaming `MdRollout`
//!    task (the coordinator integrates server-side over the registered
//!    model and streams a frame per step), plus a served `Relax` task —
//!    comparing against ground-truth classical MD.
//!
//!     cargo run --release --example md_simulation
//!     GTP_STEPS=200 GTP_TRAIN_STEPS=80 ... for longer runs

use std::sync::Arc;

use gaunt_tp::coordinator::trainer::{NativeTrainConfig, NativeTrainer};
use gaunt_tp::coordinator::{MdRollout, Relax, Request, Service, Structure};
use gaunt_tp::data::{energy_stats, gen_bpa_dataset, normalize_graphs};
use gaunt_tp::md::{fire_relax, FireConfig, Integrator, LearnedPotential,
                   Molecule, Thermostat};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::util::error::Result;
use gaunt_tp::util::rng::Rng;

fn env_flag(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let steps = env_flag("GTP_STEPS", 40);
    let train_steps = env_flag("GTP_TRAIN_STEPS", 30);

    // --- quick-train the learned potential ---
    println!("== quick-training the learned potential ({train_steps} steps) ==");
    let mut graphs = gen_bpa_dataset(&[0.05], 16, 21).remove(0);
    let stats = energy_stats(&graphs);
    normalize_graphs(&mut graphs, stats);
    let cfg = ModelConfig { r_cut: 3.0, ..Default::default() };
    let model = Model::new(cfg, 13);
    model.warm();
    let mut trainer =
        NativeTrainer::new(model, NativeTrainConfig::default());
    for step in 0..train_steps {
        let at = (step * 4) % graphs.len();
        let batch: Vec<_> = (0..4)
            .map(|k| graphs[(at + k) % graphs.len()].clone())
            .collect();
        let loss = trainer.step(&batch);
        if step % 10 == 0 {
            println!("  train step {step:>3}: loss {loss:.5}");
        }
    }
    let model = Arc::new(trainer.into_model());

    let mol = Molecule::bpa_lite();
    let mut rng = Rng::new(3);
    let dt = 0.002f64;

    // --- FIRE relaxation on the learned surface (md::relax) ---
    let mut learned =
        LearnedPotential::new(model.clone(), mol.species.clone());
    let relax = fire_relax(
        &mut learned,
        &mol.pos,
        FireConfig { max_steps: 60, ..Default::default() },
    );
    println!(
        "FIRE on the learned surface: E {:.4} -> {:.4} in {} steps \
         (fmax {:.3})",
        relax.energy_trace[0], relax.energy, relax.steps, relax.max_force
    );
    assert!(relax.energy.is_finite());

    // --- local MD with the learned potential (Integrator::step_with) ---
    let mut md_learned = Integrator::new_with(
        mol.pos.clone(), mol.species.clone(), &mut learned, dt,
        Thermostat::None,
    );
    md_learned.thermalize(0.05, &mut rng);
    let e_start = md_learned.total_energy();
    for _ in 0..steps {
        md_learned.step_with(&mut learned, &mut rng);
    }
    println!(
        "local learned-potential MD: {steps} BAOAB steps, total energy \
         {:.4} -> {:.4}",
        e_start,
        md_learned.total_energy()
    );
    assert!(md_learned.pos.iter()
        .all(|p| p.iter().all(|x| x.is_finite())));

    // --- served MD: ONE streaming MdRollout task through the typed
    //     service — the coordinator integrates on the worker and
    //     streams a frame per step, instead of the client hand-rolling
    //     velocity Verlet around blocking force calls ---
    let service = Service::builder().model(model.clone()).build()?;
    let client = service.client();
    // classical reference trajectory from the same starting state
    // (both start at rest: the served rollout initializes v = 0)
    let mut md_ref = Integrator::new(
        mol.pos.clone(), mol.species.clone(), &mol.potential, dt,
        Thermostat::None,
    );
    let mut ticket = client
        .submit(Request::new(MdRollout {
            structure: Structure::new(mol.pos.clone(), mol.species.clone()),
            steps,
            dt,
        }))
        .map_err(|e| gaunt_tp::err!("{e}"))?;
    println!("step |  served-E | drift from classical reference");
    let mut n_frames = 0usize;
    while let Some(frame) = ticket.next_frame() {
        md_ref.step(&mol.potential, &mut rng);
        if frame.step % 10 == 0 || frame.step + 1 == steps {
            let mut d2 = 0.0;
            for (p, q) in frame.pos.iter().zip(&md_ref.pos) {
                for k in 0..3 {
                    d2 += (p[k] - q[k]) * (p[k] - q[k]);
                }
            }
            println!(
                "{:>4} | {:>9.4} | RMSD {:.4}",
                frame.step,
                frame.energy,
                (d2 / frame.pos.len() as f64).sqrt()
            );
        }
        assert!(
            frame.pos.iter().all(|p| p.iter().all(|x| x.is_finite())),
            "served-model MD diverged to non-finite positions"
        );
        n_frames += 1;
    }
    let traj = ticket.wait().map_err(|e| gaunt_tp::err!("{e}"))?;
    assert_eq!(n_frames, steps, "one streamed frame per step");
    assert_eq!(traj.summary.steps, steps);
    println!(
        "rollout complete: {} frames, final total energy {:.4}",
        n_frames, traj.summary.final_energy
    );

    // --- served relaxation: FIRE as a service task ---
    let relax_served = client
        .call(Request::new(Relax {
            structure: Structure::new(mol.pos.clone(), mol.species.clone()),
            max_steps: 60,
        }))
        .map_err(|e| gaunt_tp::err!("{e}"))?;
    println!(
        "served FIRE: E {:.4} -> {:.4} in {} steps (fmax {:.3})",
        relax_served.energy_trace[0], relax_served.energy,
        relax_served.steps, relax_served.max_force
    );
    assert!(relax_served.energy.is_finite());

    println!("\nservice metrics: {}", service.metrics().report());
    service.shutdown();
    Ok(())
}
