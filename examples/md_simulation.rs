//! Model-in-the-loop molecular dynamics, fully native: quick-train the
//! Gaunt-engine model on 3BPA-lite labels, then
//!
//! 1. drive BAOAB MD *locally* with [`LearnedPotential`] through
//!    `Integrator::step_with` (plus a FIRE relaxation on the learned
//!    surface), and
//! 2. drive velocity-Verlet MD through the *served* model — every force
//!    evaluation a round trip through the full coordinator (batcher ->
//!    router -> worker pool -> `NativeGauntBackend` with the trained
//!    model) — comparing both against ground-truth classical MD.
//!
//!     cargo run --release --example md_simulation
//!     GTP_STEPS=200 GTP_TRAIN_STEPS=80 ... for longer runs

use std::sync::Arc;

use gaunt_tp::coordinator::server::NativeGauntBackend;
use gaunt_tp::coordinator::trainer::{NativeTrainConfig, NativeTrainer};
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
use gaunt_tp::data::{energy_stats, gen_bpa_dataset, normalize_graphs};
use gaunt_tp::md::{fire_relax, FireConfig, Integrator, LearnedPotential,
                   Molecule, Thermostat};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::util::error::Result;
use gaunt_tp::util::rng::Rng;

fn env_flag(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let steps = env_flag("GTP_STEPS", 40);
    let train_steps = env_flag("GTP_TRAIN_STEPS", 30);

    // --- quick-train the learned potential ---
    println!("== quick-training the learned potential ({train_steps} steps) ==");
    let mut graphs = gen_bpa_dataset(&[0.05], 16, 21).remove(0);
    let stats = energy_stats(&graphs);
    normalize_graphs(&mut graphs, stats);
    let cfg = ModelConfig { r_cut: 3.0, ..Default::default() };
    let model = Model::new(cfg, 13);
    model.warm();
    let mut trainer =
        NativeTrainer::new(model, NativeTrainConfig::default());
    for step in 0..train_steps {
        let at = (step * 4) % graphs.len();
        let batch: Vec<_> = (0..4)
            .map(|k| graphs[(at + k) % graphs.len()].clone())
            .collect();
        let loss = trainer.step(&batch);
        if step % 10 == 0 {
            println!("  train step {step:>3}: loss {loss:.5}");
        }
    }
    let model = Arc::new(trainer.into_model());

    let mol = Molecule::bpa_lite();
    let mut rng = Rng::new(3);
    let dt = 0.002f64;

    // --- FIRE relaxation on the learned surface (md::relax) ---
    let mut learned =
        LearnedPotential::new(model.clone(), mol.species.clone());
    let relax = fire_relax(
        &mut learned,
        &mol.pos,
        FireConfig { max_steps: 60, ..Default::default() },
    );
    println!(
        "FIRE on the learned surface: E {:.4} -> {:.4} in {} steps \
         (fmax {:.3})",
        relax.energy_trace[0], relax.energy, relax.steps, relax.max_force
    );
    assert!(relax.energy.is_finite());

    // --- local MD with the learned potential (Integrator::step_with) ---
    let mut md_learned = Integrator::new_with(
        mol.pos.clone(), mol.species.clone(), &mut learned, dt,
        Thermostat::None,
    );
    md_learned.thermalize(0.05, &mut rng);
    let vel0 = md_learned.vel.clone();
    let e_start = md_learned.total_energy();
    for _ in 0..steps {
        md_learned.step_with(&mut learned, &mut rng);
    }
    println!(
        "local learned-potential MD: {steps} BAOAB steps, total energy \
         {:.4} -> {:.4}",
        e_start,
        md_learned.total_energy()
    );
    assert!(md_learned.pos.iter()
        .all(|p| p.iter().all(|x| x.is_finite())));

    // --- served MD: every force a round trip through the coordinator ---
    let server = ForceFieldServer::start_native(
        NativeGauntBackend::with_model(model.clone()),
        ServerConfig { r_cut: model.cfg.r_cut, ..Default::default() },
    )?;
    let mut md_ref = Integrator::new(
        mol.pos.clone(), mol.species.clone(), &mol.potential, dt,
        Thermostat::None,
    );
    md_ref.vel = vel0.clone();
    let mut pos = mol.pos.clone();
    let mut vel = vel0;
    let mass = 1.0f64;
    let mut f_model = server
        .infer_blocking(pos.clone(), mol.species.clone())?
        .forces;
    println!("step |  served-E | drift from classical reference");
    for step in 0..steps {
        // velocity Verlet with served model forces
        for i in 0..pos.len() {
            for k in 0..3 {
                vel[i][k] += 0.5 * dt * f_model[i][k] / mass;
                pos[i][k] += dt * vel[i][k];
            }
        }
        let resp = server.infer_blocking(pos.clone(), mol.species.clone())?;
        f_model = resp.forces;
        for i in 0..pos.len() {
            for k in 0..3 {
                vel[i][k] += 0.5 * dt * f_model[i][k] / mass;
            }
        }
        md_ref.step(&mol.potential, &mut rng);
        if step % 10 == 0 || step + 1 == steps {
            let mut d2 = 0.0;
            for (p, q) in pos.iter().zip(&md_ref.pos) {
                for k in 0..3 {
                    d2 += (p[k] - q[k]) * (p[k] - q[k]);
                }
            }
            println!(
                "{step:>4} | {:>9.4} | RMSD {:.4}",
                resp.energy,
                (d2 / pos.len() as f64).sqrt()
            );
        }
        assert!(
            pos.iter().all(|p| p.iter().all(|x| x.is_finite())),
            "served-model MD diverged to non-finite positions"
        );
    }
    println!("\nservice metrics: {}", server.metrics().report());
    server.shutdown();
    Ok(())
}
