//! Model-in-the-loop molecular dynamics: run MD on the 3BPA-lite molecule
//! where the forces come from the *served* GauntNet model (through the
//! full coordinator: batcher -> router -> PJRT), and compare the
//! trajectory against ground-truth classical-potential MD.
//!
//!     make artifacts && cargo run --release --example md_simulation

use std::sync::Arc;

use gaunt_tp::util::error::Result;
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
use gaunt_tp::md::{Integrator, Molecule, Thermostat};
use gaunt_tp::runtime::Engine;
use gaunt_tp::util::rng::Rng;

fn main() -> Result<()> {
    let engine = Arc::new(Engine::new("artifacts")?);
    let server = ForceFieldServer::start(engine, ServerConfig::default())?;

    let mol = Molecule::bpa_lite();
    let mut rng = Rng::new(3);
    let dt = 0.002f64;
    // each step is one served inference (~seconds on the CPU interpret
    // path); override with GTP_STEPS for longer runs
    let steps = std::env::var("GTP_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40usize);

    // ground-truth MD
    let mut md_ref = Integrator::new(
        mol.pos.clone(), mol.species.clone(), &mol.potential, dt,
        Thermostat::None,
    );
    md_ref.thermalize(0.05, &mut rng);
    let vel0 = md_ref.vel.clone();

    // model-driven MD: identical start, forces from the service
    let mut pos = mol.pos.clone();
    let mut vel = vel0.clone();
    let mass = 1.0f64;
    let mut f_model = server
        .infer_blocking(pos.clone(), mol.species.clone())?
        .forces;
    println!("step |  model-E  | drift from reference trajectory");
    for step in 0..steps {
        // velocity Verlet with model forces
        for i in 0..pos.len() {
            for k in 0..3 {
                vel[i][k] += 0.5 * dt * f_model[i][k] / mass;
                pos[i][k] += dt * vel[i][k];
            }
        }
        let resp = server.infer_blocking(pos.clone(), mol.species.clone())?;
        f_model = resp.forces;
        for i in 0..pos.len() {
            for k in 0..3 {
                vel[i][k] += 0.5 * dt * f_model[i][k] / mass;
            }
        }
        // advance the reference
        md_ref.step(&mol.potential, &mut rng);
        if step % 10 == 0 || step + 1 == steps {
            let mut d2 = 0.0;
            for (p, q) in pos.iter().zip(&md_ref.pos) {
                for k in 0..3 {
                    d2 += (p[k] - q[k]) * (p[k] - q[k]);
                }
            }
            println!(
                "{step:>4} | {:>9.4} | RMSD {:.4}",
                resp.energy,
                (d2 / pos.len() as f64).sqrt()
            );
        }
        assert!(
            pos.iter().all(|p| p.iter().all(|x| x.is_finite())),
            "model-driven MD diverged to non-finite positions"
        );
    }
    println!("\nservice metrics: {}", server.metrics().report());
    println!(
        "note: the shipped state is untrained — run \
         `cargo run --release --example train_force_field` and wire the \
         trained state via ForceFieldServer::set_state for physical forces."
    );
    server.shutdown();
    Ok(())
}
