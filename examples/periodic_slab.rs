//! OCP-style periodic workload: an adsorbate on a 2-layer slab in a
//! periodic box (vacuum gap along z), end to end —
//!
//! 1. FIRE-relax the adsorbate-slab complex under periodic boundary
//!    conditions through [`PeriodicPotential`] (minimum-image forces via
//!    a skin-buffered Verlet list),
//! 2. run Langevin MD on the relaxed structure, watching the Verlet
//!    rebuild/reuse ratio, and
//! 3. evaluate the learned Gaunt-engine model on the same periodic
//!    structure via image-shifted edges, checking that a lattice
//!    translation of any atom leaves energy and forces unchanged.
//!
//!     cargo run --release --example periodic_slab
//!     GTP_STEPS=500 ... for longer MD

use gaunt_tp::md::{
    fire_relax, FireConfig, Integrator, Molecule, PeriodicPotential,
    Thermostat,
};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::util::rng::Rng;

fn env_flag(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let steps = env_flag("GTP_STEPS", 120);

    // --- build the periodic slab ---
    let (mol, cell) = Molecule::periodic_slab(6, 6);
    let n = mol.pos.len();
    let [lx, ly, lz] = [cell.lattice()[0][0], cell.lattice()[1][1],
                        cell.lattice()[2][2]];
    println!(
        "periodic slab: {n} atoms in a {lx:.2} x {ly:.2} x {lz:.2} box \
         (minimum-image bound {:.2})",
        cell.max_cutoff()
    );

    // --- 1. relax under PBC ---
    let mut pp = PeriodicPotential::new(
        mol.potential.clone(), mol.species.clone(), cell.clone(), 0.4,
    );
    let relax = fire_relax(
        &mut pp,
        &mol.pos,
        FireConfig { max_steps: 300, fmax: 5e-3, ..Default::default() },
    );
    println!(
        "FIRE under PBC: E {:.4} -> {:.4} in {} steps (fmax {:.4}, \
         converged: {})",
        relax.energy_trace[0], relax.energy, relax.steps, relax.max_force,
        relax.converged
    );
    assert!(relax.energy.is_finite() && relax.energy <= relax.energy_trace[0]);

    // --- 2. Langevin MD from the relaxed structure ---
    let mut rng = Rng::new(7);
    let mut md = Integrator::new_with(
        relax.pos.clone(),
        mol.species.clone(),
        &mut pp,
        0.002,
        Thermostat::Langevin { gamma: 1.0, temperature: 0.05 },
    );
    md.thermalize(0.05, &mut rng);
    for step in 0..steps {
        md.step_with(&mut pp, &mut rng);
        if (step + 1) % (steps / 4).max(1) == 0 {
            println!(
                "  MD step {:>4}: T {:.4}, Verlet {} rebuilds / {} reuses",
                step + 1,
                md.temperature(),
                pp.list().rebuilds,
                pp.list().reuses
            );
        }
    }
    assert!(
        md.pos.iter().all(|p| p.iter().all(|v| v.is_finite())),
        "periodic MD diverged"
    );
    assert!(
        pp.list().reuses > pp.list().rebuilds,
        "skin buffer never paid off: {} rebuilds vs {} reuses",
        pp.list().rebuilds, pp.list().reuses
    );

    // --- 3. learned model on the periodic structure ---
    // periodic_slab boxes are at least 7.8 wide in x/y, so the default
    // model cutoff (3.5) respects the minimum-image bound
    let model = Model::new(ModelConfig::default(), 5);
    let (edges, _) = model.build_edges_periodic(&md.pos, &cell);
    println!(
        "model periodic graph: {} directed edges over {n} atoms",
        edges.len()
    );
    let (e0, f0) = model.energy_forces_periodic(&md.pos, &mol.species, &cell);
    // translate one slab atom by a lattice vector: every observable must
    // be bit-for-bit-level invariant
    let mut moved = md.pos.clone();
    let sv = cell.shift_vector([1, -2, 0]);
    for k in 0..3 {
        moved[n / 2][k] += sv[k];
    }
    let (e1, f1) = model.energy_forces_periodic(&moved, &mol.species, &cell);
    let df = f0
        .iter()
        .flatten()
        .zip(f1.iter().flatten())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!(
        "lattice-translation invariance: |dE| = {:.2e}, max |dF| = {df:.2e}",
        (e0 - e1).abs()
    );
    assert!((e0 - e1).abs() < 1e-9 && df < 1e-9);
    println!("periodic slab example OK");
}
