//! Quickstart: load an AOT-compiled Gaunt Tensor Product kernel and verify
//! it against the native Rust implementation.
//!
//!     make artifacts && cargo run --release --example quickstart

use gaunt_tp::util::error::Result;
use gaunt_tp::runtime::{Engine, Tensor};
use gaunt_tp::tp::{ConvMethod, GauntPlan};
use gaunt_tp::util::rng::Rng;
use gaunt_tp::num_coeffs;

fn main() -> Result<()> {
    let engine = Engine::new("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    // 1. the compiled Pallas pipeline (Python built it; Rust runs it)
    let name = "gaunt_tp_L2_B64";
    let exe = engine.load(name)?;
    println!(
        "loaded {name}: inputs {:?} -> outputs {:?}",
        exe.inputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
        exe.outputs.iter().map(|s| s.shape.clone()).collect::<Vec<_>>(),
    );

    let l = 2usize;
    let n = num_coeffs(l);
    let b = 64usize;
    let mut rng = Rng::new(0);
    let x1: Vec<f32> = rng.normals_f32(b * n);
    let x2: Vec<f32> = rng.normals_f32(b * n);
    let out = exe.run(&[Tensor::F32(x1.clone()), Tensor::F32(x2.clone())])?;
    let y = out[0].as_f32()?;

    // 2. the native Rust implementation of the same O(L^3) algorithm
    let plan = GauntPlan::new(l, l, l, ConvMethod::Auto);
    let mut max_err = 0.0f64;
    for r in 0..b {
        let a: Vec<f64> = x1[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let c: Vec<f64> = x2[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let want = plan.apply(&a, &c);
        for k in 0..n {
            max_err = max_err.max((y[r * n + k] as f64 - want[k]).abs());
        }
    }
    println!("XLA kernel vs native Rust Gaunt TP: max |diff| = {max_err:.2e}");
    assert!(max_err < 1e-4, "implementations disagree");
    println!("quickstart OK — the three layers agree.");
    Ok(())
}
