//! Conformance suite for the `util::simd` lane layer and every kernel
//! that dispatches onto it.  The contract under test is the one the
//! committed benchmark snapshot depends on: on f64 the vectorized hot
//! paths are BIT-IDENTICAL to their retained scalar oracles (no FMA, no
//! reassociation), so a SIMD speedup row can never hide a numeric
//! drift.  Covers:
//!
//! * every lane op of the active `F64x4`/`F32x8` against the portable
//!   scalar fallback, over a value set with NaNs, signed zeros,
//!   infinities, and denormals;
//! * `FftPlan::process` vs `process_scalar` across sizes and directions;
//! * planned convolution (the SIMD pointwise product) vs the direct
//!   O(n^2) convolution reference;
//! * `f2sh_contract` vs `f2sh_contract_scalar` on real panel data.

use gaunt_tp::fourier::{
    conv2d_direct, f2sh_contract, f2sh_contract_scalar, C64, ConvPlan,
    F2shPanelsT, FftPlan,
};
use gaunt_tp::num_coeffs;
use gaunt_tp::util::simd::{
    scalar::{ScalarF32x8, ScalarF64x4},
    SimdLanes, ACTIVE_IMPL, F32x8, F64x4,
};
use gaunt_tp::util::rng::Rng;

/// Adversarial lane values: ordinary magnitudes plus every IEEE special
/// the kernels could ever meet.
const TRICKY: [f64; 12] = [
    0.0,
    -0.0,
    1.0,
    -2.5,
    1.0e300,
    -1.0e-300,
    f64::INFINITY,
    f64::NEG_INFINITY,
    f64::NAN,
    f64::MIN_POSITIVE,
    4.9e-324, // smallest positive denormal
    -4.9e-324,
];

fn bits_eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn bits_eq_f32(a: f32, b: f32) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

#[test]
fn active_impl_is_reported() {
    assert!(["sse2", "neon", "scalar"].contains(&ACTIVE_IMPL));
}

#[test]
fn f64_lanes_bitwise_match_scalar_fallback_on_special_values() {
    let mut rng = Rng::new(3);
    // sweep window pairs over TRICKY plus random fill
    for trial in 0..64 {
        let mut a = [0.0f64; 4];
        let mut b = [0.0f64; 4];
        for i in 0..4 {
            a[i] = TRICKY[(trial + i) % TRICKY.len()];
            b[i] = if trial % 2 == 0 {
                TRICKY[(trial + 2 * i + 5) % TRICKY.len()]
            } else {
                rng.normal()
            };
        }
        let (va, vb) = (F64x4::load(&a), F64x4::load(&b));
        let (sa, sb) = (ScalarF64x4::load(&a), ScalarF64x4::load(&b));
        let check = |got: F64x4, want: ScalarF64x4, what: &str| {
            let (g, w) = (got.to_vec(), want.to_vec());
            for i in 0..4 {
                assert!(
                    bits_eq_f64(g[i], w[i]),
                    "{what} lane {i}: {ACTIVE_IMPL} {:e} vs scalar {:e} \
                     (a={a:?} b={b:?})",
                    g[i], w[i]
                );
            }
        };
        check(va + vb, sa + sb, "add");
        check(va - vb, sa - sb, "sub");
        check(va * vb, sa * sb, "mul");
        check(va.dup_even(), sa.dup_even(), "dup_even");
        check(va.dup_odd(), sa.dup_odd(), "dup_odd");
        check(va.swap_pairs(), sa.swap_pairs(), "swap_pairs");
        check(va.neg_even(), sa.neg_even(), "neg_even");
        check(va.complex_mul(vb), sa.complex_mul(sb), "complex_mul");
        let (re_v, im_v) = F64x4::unzip(va, vb);
        let (re_s, im_s) = ScalarF64x4::unzip(sa, sb);
        check(re_v, re_s, "unzip.re");
        check(im_v, im_s, "unzip.im");
    }
}

#[test]
fn f32_lanes_bitwise_match_scalar_fallback_on_special_values() {
    let mut rng = Rng::new(4);
    for trial in 0..64 {
        let mut a = [0.0f32; 8];
        let mut b = [0.0f32; 8];
        for i in 0..8 {
            a[i] = TRICKY[(trial + i) % TRICKY.len()] as f32;
            b[i] = if trial % 2 == 0 {
                TRICKY[(trial + 3 * i + 7) % TRICKY.len()] as f32
            } else {
                rng.normal() as f32
            };
        }
        let (va, vb) = (F32x8::load(&a), F32x8::load(&b));
        let (sa, sb) = (ScalarF32x8::load(&a), ScalarF32x8::load(&b));
        let check = |got: F32x8, want: ScalarF32x8, what: &str| {
            let (g, w) = (got.to_vec(), want.to_vec());
            for i in 0..8 {
                assert!(
                    bits_eq_f32(g[i], w[i]),
                    "{what} lane {i}: {ACTIVE_IMPL} {:e} vs scalar {:e}",
                    g[i], w[i]
                );
            }
        };
        check(va + vb, sa + sb, "add");
        check(va - vb, sa - sb, "sub");
        check(va * vb, sa * sb, "mul");
        check(va.dup_even(), sa.dup_even(), "dup_even");
        check(va.dup_odd(), sa.dup_odd(), "dup_odd");
        check(va.swap_pairs(), sa.swap_pairs(), "swap_pairs");
        check(va.neg_even(), sa.neg_even(), "neg_even");
        check(va.complex_mul(vb), sa.complex_mul(sb), "complex_mul");
        let (re_v, im_v) = F32x8::unzip(va, vb);
        let (re_s, im_s) = ScalarF32x8::unzip(sa, sb);
        check(re_v, re_s, "unzip.re");
        check(im_v, im_s, "unzip.im");
    }
}

#[test]
fn fft_simd_path_bit_matches_scalar_oracle_at_every_size() {
    let mut rng = Rng::new(11);
    for n in [1usize, 2, 4, 8, 16, 64, 256, 2048] {
        let plan = FftPlan::shared(n);
        let data: Vec<C64> = (0..n)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        for inverse in [false, true] {
            let mut simd = data.clone();
            let mut scalar = data.clone();
            plan.process(&mut simd, inverse);
            plan.process_scalar(&mut scalar, inverse);
            for (i, (s, sc)) in simd.iter().zip(&scalar).enumerate() {
                assert!(
                    s.re.to_bits() == sc.re.to_bits()
                        && s.im.to_bits() == sc.im.to_bits(),
                    "n={n} inverse={inverse} bin {i}: {s:?} vs {sc:?}"
                );
            }
        }
    }
}

#[test]
fn planned_conv_with_simd_pointwise_matches_direct_reference() {
    let mut rng = Rng::new(12);
    for &(n1, n2) in &[(1usize, 1usize), (2, 3), (4, 4), (5, 9), (8, 8)] {
        let a: Vec<C64> = (0..n1 * n1)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let b: Vec<C64> = (0..n2 * n2)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let want = conv2d_direct(&a, n1, &b, n2);
        let plan = ConvPlan::new(n1, n2);
        let mut scratch = plan.scratch();
        let mut got = vec![C64::default(); plan.n_out * plan.n_out];
        plan.conv_into(&a, &b, &mut got, &mut scratch);
        let n_out = n1 + n2 - 1;
        let scale = (n_out * n_out) as f64;
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.re - w.re).abs() < 1e-9 * scale
                    && (g.im - w.im).abs() < 1e-9 * scale,
                "conv {n1}x{n2}: {g:?} vs {w:?}"
            );
        }
    }
}

#[test]
fn f2sh_simd_contract_bit_matches_scalar_on_random_grids() {
    let mut rng = Rng::new(13);
    for &(l_out, n_grid) in
        &[(0usize, 0usize), (2, 2), (3, 4), (5, 6), (8, 8), (10, 12)]
    {
        let nu = 2 * n_grid + 1;
        let grid: Vec<C64> = (0..nu * nu)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let t3t = F2shPanelsT::build(l_out, n_grid);
        let mut out_simd = vec![0.0; num_coeffs(l_out)];
        let mut out_scalar = vec![0.0; num_coeffs(l_out)];
        f2sh_contract(&t3t, &grid, &mut out_simd);
        f2sh_contract_scalar(&t3t, &grid, &mut out_scalar);
        for (i, (s, sc)) in out_simd.iter().zip(&out_scalar).enumerate() {
            assert!(
                s.to_bits() == sc.to_bits(),
                "l_out={l_out} n_grid={n_grid} coeff {i}: {s:e} vs {sc:e}"
            );
        }
    }
}
