//! Symmetry harness for the end-to-end learned force field: the exact
//! invariances/equivariances a correct E(3)-equivariant architecture
//! must satisfy, checked on the FULL model (edge embedding -> Gaunt conv
//! messages -> many-body update -> invariant readout) for BOTH
//! convolution backends.
//!
//! * energy invariant under rotation, translation, atom permutation;
//! * forces equivariant: F(R x) = R F(x), F(x + t) = F(x),
//!   F(P x) = P F(x);
//! * net force and net torque vanish (consequences of translation and
//!   rotation invariance respectively — caught here because kernel-level
//!   unit tests cannot see force-assembly sign errors).
//!
//! These are exactly the failures unit tests on isolated plans cannot
//! catch: a wrong degree offset or a transposed Wigner block leaves
//! every kernel test green and silently breaks the physics.

use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::so3::rotation::Rot3;
use gaunt_tp::tp::ConvMethod;
use gaunt_tp::util::rng::Rng;

const REL_TOL: f64 = 1e-6; // the acceptance bar; observed errors ~1e-9

fn toy_structure(seed: u64, n: usize) -> (Vec<[f64; 3]>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let pos = (0..n)
        .map(|_| [1.5 * rng.normal(), 1.5 * rng.normal(),
                  1.5 * rng.normal()])
        .collect();
    let species = (0..n).map(|_| rng.below(3)).collect();
    (pos, species)
}

fn model_for(method: ConvMethod, nu: usize, n_layers: usize) -> Model {
    Model::new(
        ModelConfig { method, nu, n_layers, ..Default::default() },
        42,
    )
}

fn assert_energy_close(a: f64, b: f64, what: &str) {
    assert!(
        (a - b).abs() <= REL_TOL * (1.0 + a.abs()),
        "{what}: energy {a} vs {b} (diff {})",
        (a - b).abs()
    );
}

fn assert_forces_close(a: &[[f64; 3]], b: &[[f64; 3]], what: &str) {
    let scale = a
        .iter()
        .flat_map(|v| v.iter())
        .fold(0.0f64, |m, x| m.max(x.abs()));
    for (i, (fa, fb)) in a.iter().zip(b).enumerate() {
        for ax in 0..3 {
            assert!(
                (fa[ax] - fb[ax]).abs() <= REL_TOL * (1.0 + scale),
                "{what}: force[{i}][{ax}] {} vs {}",
                fa[ax],
                fb[ax]
            );
        }
    }
}

#[test]
fn energy_invariant_and_forces_equivariant_under_rotation() {
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = model_for(method, 2, 2);
        let (pos, species) = toy_structure(1, 7);
        let (e0, f0) = model.energy_forces(&pos, &species);
        let mut rng = Rng::new(99);
        for _ in 0..3 {
            let rot = Rot3::random(&mut rng);
            let pos_r: Vec<[f64; 3]> =
                pos.iter().map(|&p| rot.apply(p)).collect();
            let (e_r, f_r) = model.energy_forces(&pos_r, &species);
            assert_energy_close(e0, e_r, &format!("{method:?} rotation"));
            let f0_rot: Vec<[f64; 3]> =
                f0.iter().map(|&f| rot.apply(f)).collect();
            assert_forces_close(&f_r, &f0_rot,
                                &format!("{method:?} rotation"));
        }
    }
}

#[test]
fn energy_and_forces_invariant_under_translation() {
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = model_for(method, 2, 2);
        let (pos, species) = toy_structure(2, 6);
        let (e0, f0) = model.energy_forces(&pos, &species);
        for t in [[0.7, -2.0, 1.3], [100.0, 40.0, -7.0]] {
            let pos_t: Vec<[f64; 3]> = pos
                .iter()
                .map(|p| [p[0] + t[0], p[1] + t[1], p[2] + t[2]])
                .collect();
            let (e_t, f_t) = model.energy_forces(&pos_t, &species);
            assert_energy_close(e0, e_t, &format!("{method:?} translation"));
            assert_forces_close(&f_t, &f0,
                                &format!("{method:?} translation"));
        }
    }
}

#[test]
fn energy_invariant_and_forces_permute_under_atom_permutation() {
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = model_for(method, 2, 2);
        let (pos, species) = toy_structure(3, 8);
        let (e0, f0) = model.energy_forces(&pos, &species);
        let mut rng = Rng::new(5);
        let mut perm: Vec<usize> = (0..pos.len()).collect();
        rng.shuffle(&mut perm);
        let pos_p: Vec<[f64; 3]> = perm.iter().map(|&i| pos[i]).collect();
        let species_p: Vec<usize> =
            perm.iter().map(|&i| species[i]).collect();
        let (e_p, f_p) = model.energy_forces(&pos_p, &species_p);
        assert_energy_close(e0, e_p, &format!("{method:?} permutation"));
        let f0_p: Vec<[f64; 3]> = perm.iter().map(|&i| f0[i]).collect();
        assert_forces_close(&f_p, &f0_p, &format!("{method:?} permutation"));
    }
}

#[test]
fn net_force_and_net_torque_vanish() {
    // translation invariance => sum_i F_i = 0; rotation invariance =>
    // sum_i x_i cross F_i = 0 (no external field in the model)
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = model_for(method, 2, 2);
        let (pos, species) = toy_structure(4, 7);
        let (_, f) = model.energy_forces(&pos, &species);
        let scale = f
            .iter()
            .flat_map(|v| v.iter())
            .fold(0.0f64, |m, x| m.max(x.abs()))
            .max(1.0);
        let mut net = [0.0f64; 3];
        let mut torque = [0.0f64; 3];
        for (p, fi) in pos.iter().zip(&f) {
            for ax in 0..3 {
                net[ax] += fi[ax];
            }
            torque[0] += p[1] * fi[2] - p[2] * fi[1];
            torque[1] += p[2] * fi[0] - p[0] * fi[2];
            torque[2] += p[0] * fi[1] - p[1] * fi[0];
        }
        for ax in 0..3 {
            assert!(net[ax].abs() < 1e-8 * scale,
                    "{method:?}: net force {net:?}");
            assert!(torque[ax].abs() < 1e-7 * scale,
                    "{method:?}: net torque {torque:?}");
        }
    }
}

#[test]
fn multi_channel_models_keep_every_invariance() {
    // the acceptance gate for the Irreps layout: a model with mul > 1
    // channels must pass the full rotation/translation/permutation
    // suite on BOTH convolution backends
    let mut rng = Rng::new(77);
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = Model::new(
            ModelConfig { method, channels: 3, nu: 3,
                          ..Default::default() },
            42,
        );
        let (pos, species) = toy_structure(9, 6);
        let (e0, f0) = model.energy_forces(&pos, &species);
        // rotation
        let rot = Rot3::random(&mut rng);
        let pos_r: Vec<[f64; 3]> = pos.iter().map(|&p| rot.apply(p)).collect();
        let (e_r, f_r) = model.energy_forces(&pos_r, &species);
        assert_energy_close(e0, e_r, &format!("{method:?} C=3 rotation"));
        let f0_rot: Vec<[f64; 3]> = f0.iter().map(|&f| rot.apply(f)).collect();
        assert_forces_close(&f_r, &f0_rot,
                            &format!("{method:?} C=3 rotation"));
        // translation
        let t = [0.9, -1.4, 2.2];
        let pos_t: Vec<[f64; 3]> = pos
            .iter()
            .map(|p| [p[0] + t[0], p[1] + t[1], p[2] + t[2]])
            .collect();
        let (e_t, f_t) = model.energy_forces(&pos_t, &species);
        assert_energy_close(e0, e_t, &format!("{method:?} C=3 translation"));
        assert_forces_close(&f_t, &f0,
                            &format!("{method:?} C=3 translation"));
        // permutation
        let mut perm: Vec<usize> = (0..pos.len()).collect();
        rng.shuffle(&mut perm);
        let pos_p: Vec<[f64; 3]> = perm.iter().map(|&i| pos[i]).collect();
        let species_p: Vec<usize> =
            perm.iter().map(|&i| species[i]).collect();
        let (e_p, f_p) = model.energy_forces(&pos_p, &species_p);
        assert_energy_close(e0, e_p, &format!("{method:?} C=3 permutation"));
        let f0_p: Vec<[f64; 3]> = perm.iter().map(|&i| f0[i]).collect();
        assert_forces_close(&f_p, &f0_p,
                            &format!("{method:?} C=3 permutation"));
        // net force / net torque
        let scale = f0
            .iter()
            .flat_map(|v| v.iter())
            .fold(0.0f64, |m, x| m.max(x.abs()))
            .max(1.0);
        let mut net = [0.0f64; 3];
        let mut torque = [0.0f64; 3];
        for (p, fi) in pos.iter().zip(&f0) {
            for ax in 0..3 {
                net[ax] += fi[ax];
            }
            torque[0] += p[1] * fi[2] - p[2] * fi[1];
            torque[1] += p[2] * fi[0] - p[0] * fi[2];
            torque[2] += p[0] * fi[1] - p[1] * fi[0];
        }
        for ax in 0..3 {
            assert!(net[ax].abs() < 1e-8 * scale,
                    "{method:?} C=3: net force {net:?}");
            assert!(torque[ax].abs() < 1e-7 * scale,
                    "{method:?} C=3: net torque {torque:?}");
        }
    }
}

#[test]
fn higher_order_many_body_and_deep_stacks_stay_equivariant() {
    // nu = 3 exercises the true ManyBodyPlan power path (nu = 2's
    // (nu-1)-power shortcut is a plain copy); 3 layers exercise the
    // deep backward chain
    let model = model_for(ConvMethod::Auto, 3, 3);
    let (pos, species) = toy_structure(6, 5);
    let (e0, f0) = model.energy_forces(&pos, &species);
    let mut rng = Rng::new(7);
    let rot = Rot3::random(&mut rng);
    let pos_r: Vec<[f64; 3]> = pos.iter().map(|&p| rot.apply(p)).collect();
    let (e_r, f_r) = model.energy_forces(&pos_r, &species);
    assert_energy_close(e0, e_r, "nu=3 rotation");
    let f0_rot: Vec<[f64; 3]> = f0.iter().map(|&f| rot.apply(f)).collect();
    assert_forces_close(&f_r, &f0_rot, "nu=3 rotation");
}

#[test]
fn dipole_readout_is_a_polar_vector_under_o3() {
    // the vector readout head on top of the full model: under any
    // orthogonal O (proper rotation or rotation-with-inversion) the
    // per-atom dipole must follow the polar-vector law
    // mu(O x) = O mu(x) — improper ops catch parity-sign errors the
    // rotation-only checks cannot see
    use gaunt_tp::model::dipole::DipoleHead;
    let model = model_for(ConvMethod::Auto, 2, 2);
    let head = DipoleHead::new(
        model.cfg.channels, model.cfg.l, ConvMethod::Auto, 19);
    let (pos, species) = toy_structure(12, 6);
    let mut s = model.scratch();
    let mut hs = head.scratch();
    let edges = model.build_edges(&pos);
    model.energy_into(&pos, &species, &edges, &mut s);
    let mut mu0 = vec![0.0; 3 * pos.len()];
    model.dipoles_into(&head, pos.len(), &s, &mut hs, &mut mu0);
    let scale = mu0.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1e-3);
    let mut rng = Rng::new(31);
    let r = Rot3::random(&mut rng);
    let m = &r.0;
    let inv_r = Rot3([
        [-m[0][0], -m[0][1], -m[0][2]],
        [-m[1][0], -m[1][1], -m[1][2]],
        [-m[2][0], -m[2][1], -m[2][2]],
    ]);
    for (o, label) in [(r, "proper"), (inv_r, "improper")] {
        let pos_o: Vec<[f64; 3]> =
            pos.iter().map(|&p| o.apply(p)).collect();
        let edges_o = model.build_edges(&pos_o);
        model.energy_into(&pos_o, &species, &edges_o, &mut s);
        let mut mu_o = vec![0.0; 3 * pos.len()];
        model.dipoles_into(&head, pos.len(), &s, &mut hs, &mut mu_o);
        for i in 0..pos.len() {
            let want =
                o.apply([mu0[3 * i], mu0[3 * i + 1], mu0[3 * i + 2]]);
            for ax in 0..3 {
                assert!(
                    (mu_o[3 * i + ax] - want[ax]).abs() <= REL_TOL * scale,
                    "{label} dipole[{i}][{ax}]: {} vs {}",
                    mu_o[3 * i + ax], want[ax]
                );
            }
        }
    }
}

#[test]
fn served_energies_inherit_the_invariances() {
    // the same invariance must survive the full serving stack (padding,
    // f32 casts, batched multi-threaded inference)
    use gaunt_tp::coordinator::server::NativeGauntBackend;
    use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
    use std::sync::Arc;
    let model = Arc::new(model_for(ConvMethod::Auto, 2, 2));
    let server = ForceFieldServer::start_native(
        NativeGauntBackend::with_model(model.clone()),
        ServerConfig { r_cut: model.cfg.r_cut, ..Default::default() },
    )
    .unwrap();
    let (pos, species) = toy_structure(8, 6);
    let e0 = server.infer_blocking(pos.clone(), species.clone())
        .unwrap().energy;
    let mut rng = Rng::new(11);
    let rot = Rot3::random(&mut rng);
    let pos_r: Vec<[f64; 3]> = pos.iter().map(|&p| rot.apply(p)).collect();
    let e_r = server.infer_blocking(pos_r, species.clone()).unwrap().energy;
    // f32 transport bounds the achievable tolerance here
    assert!((e0 - e_r).abs() < 1e-4 * (1.0 + e0.abs()),
            "served rotation: {e0} vs {e_r}");
    server.shutdown();
}
