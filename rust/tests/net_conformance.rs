//! Socket-serving conformance: the wire path must honor every contract
//! the in-process `coordinator::Service` pins — typed errors, exactly
//! one resolution per ticket, deadline/cancel propagation, ledger
//! reconciliation — plus the new multi-process ones: a dead replica is
//! routed around, a dead client releases its replica-side work, and a
//! version-mismatched peer is refused with a typed handshake.
//!
//! `NET_SMOKE=1` shrinks the workloads for the fast verify gate.  The
//! two `multi_process_*` tests spawn real replica/front-door/worker
//! processes from the compiled `gaunt-tp` binary.

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use gaunt_tp::coordinator::{
    EnergyForces, EnergyOnly, HealthState, MdRollout, NativeGauntBackend,
    Relax, Request, ServerConfig, Service, ServiceError,
};
use gaunt_tp::net::loadtest::{cluster, run_cluster_loadtest, LoadOpts};
use gaunt_tp::net::proto::{decode_server, encode_client, ClientMsg, ServerMsg};
use gaunt_tp::net::{
    read_frame, temp_socket_path, write_frame, Addr, FrontDoor,
    FrontDoorConfig, NetClient, Replica, RespawnPolicy,
};

// sockets, services, and the process-global failpoint registry all
// want isolation: serialize the suite on one static mutex
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

fn smoke() -> bool {
    std::env::var("NET_SMOKE").is_ok()
}

fn scaled(full: usize, smoke_n: usize) -> usize {
    if smoke() { smoke_n } else { full }
}

fn service(workers: usize) -> Service {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig { n_workers: workers, ..Default::default() })
        .build()
        .expect("native service must start")
}

fn unix_replica(tag: &str, workers: usize) -> Replica {
    let addr = Addr::Unix(temp_socket_path(tag));
    Replica::serve(service(workers), &[addr], tag).expect("bind unix replica")
}

/// Poll `cond` every 5ms until it holds or `deadline` passes.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

// ---------------------------------------------------------------------
// single replica over a socket: every task kind, both transports
// ---------------------------------------------------------------------

#[test]
fn every_task_kind_roundtrips_over_a_unix_socket() {
    let _g = serial();
    let replica = unix_replica("net-kinds", 2);
    let nc = NetClient::connect(&replica.bound()[0]).expect("connect");

    let st = cluster(10, 7);
    let e = nc
        .submit(Request::new(EnergyOnly(st.clone())))
        .expect("submit energy")
        .wait()
        .expect("energy reply");
    assert!(e.energy.is_finite());

    let f = nc
        .submit(Request::new(EnergyForces(st.clone())))
        .expect("submit forces")
        .wait()
        .expect("forces reply");
    assert_eq!(f.forces.len(), st.n_atoms());
    assert!((f.energy - e.energy).abs() < 1e-9, "same structure, same E");

    let r = nc
        .submit(Request::new(Relax { structure: st.clone(), max_steps: 4 }))
        .expect("submit relax")
        .wait()
        .expect("relax reply");
    assert_eq!(r.pos.len(), st.n_atoms());
    assert!(r.energy.is_finite());

    let md = nc
        .submit(Request::new(MdRollout {
            structure: st.clone(),
            steps: 3,
            dt: 1e-3,
        }))
        .expect("submit rollout");
    let traj = md.wait().expect("rollout reply");
    assert_eq!(traj.summary.steps, 3);
    assert!(!traj.frames.is_empty(), "frames must stream over the wire");
    assert_eq!(traj.frames[0].pos.len(), st.n_atoms());

    let batch = nc
        .submit(Request::new(gaunt_tp::coordinator::Batch(vec![
            cluster(6, 1),
            cluster(9, 2),
        ])))
        .expect("submit batch")
        .wait()
        .expect("batch reply");
    assert_eq!(batch.len(), 2);
    assert_eq!(batch[1].forces.len(), 9);

    nc.close();
    replica.shutdown();
}

#[test]
fn tcp_loopback_serves_the_same_contract() {
    let _g = serial();
    let addr = Addr::Tcp("127.0.0.1:0".to_string());
    let replica =
        Replica::serve(service(1), &[addr], "net-tcp").expect("bind tcp");
    let nc = NetClient::connect(&replica.bound()[0]).expect("connect tcp");
    let st = cluster(8, 3);
    let f = nc
        .submit(Request::new(EnergyForces(st.clone())))
        .expect("submit")
        .wait()
        .expect("reply");
    assert_eq!(f.forces.len(), st.n_atoms());
    let (health, _depth) =
        nc.ping(Duration::from_secs(5)).expect("ping over tcp");
    assert_eq!(health, HealthState::Healthy);
    nc.close();
    replica.shutdown();
}

// ---------------------------------------------------------------------
// deadline + cancel propagation across the wire
// ---------------------------------------------------------------------

#[test]
fn deadline_expires_across_the_wire_as_a_typed_error() {
    let _g = serial();
    let replica = unix_replica("net-deadline", 1);
    let nc = NetClient::connect(&replica.bound()[0]).expect("connect");
    // a rollout long enough that a 1ms budget cannot cover it
    let req = Request::new(MdRollout {
        structure: cluster(20, 11),
        steps: scaled(3000, 600),
        dt: 1e-4,
    })
    .deadline(Duration::from_millis(1));
    match nc.submit(req).expect("submit").wait() {
        Err(ServiceError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    // the expiry is booked server-side, not just client-side
    let m = replica.client().metrics().snapshot();
    assert!(m.expired >= 1, "server must count the expiry: {m:?}");
    nc.close();
    replica.shutdown();
}

#[test]
fn wire_cancel_releases_the_replica_side_ticket() {
    let _g = serial();
    let replica = unix_replica("net-cancel", 1);
    let nc = NetClient::connect(&replica.bound()[0]).expect("connect");
    let inproc = replica.client();
    let before = inproc.metrics().snapshot().canceled;
    let md = nc
        .submit(Request::new(MdRollout {
            structure: cluster(20, 13),
            steps: scaled(200_000, 40_000),
            dt: 1e-4,
        }))
        .expect("submit long rollout");
    // let it start running, then cancel over the wire
    std::thread::sleep(Duration::from_millis(30));
    md.cancel();
    match md.wait() {
        Err(ServiceError::Canceled) => {}
        Err(ServiceError::DeadlineExceeded) => {
            panic!("cancel must not surface as a deadline")
        }
        other => panic!("expected Canceled, got {other:?}"),
    }
    // the cooperative flag reached the service: the worker stopped and
    // booked the cancel — no orphaned rollout keeps a worker busy
    assert!(
        wait_until(Duration::from_secs(10), || {
            inproc.metrics().snapshot().canceled > before
        }),
        "service never booked the wire cancel"
    );
    assert!(
        wait_until(Duration::from_secs(10), || inproc.queue_depth() == 0),
        "canceled work must leave the queue"
    );
    nc.close();
    replica.shutdown();
}

#[test]
fn client_disconnect_cancels_inflight_work() {
    let _g = serial();
    let replica = unix_replica("net-hangup", 1);
    let inproc = replica.client();
    let before = inproc.metrics().snapshot().canceled;
    {
        let nc = NetClient::connect(&replica.bound()[0]).expect("connect");
        let _raw = nc
            .submit_task(
                gaunt_tp::coordinator::Task::MdRollout {
                    structure: cluster(20, 17),
                    steps: scaled(200_000, 40_000),
                    dt: 1e-4,
                },
                None,
                None,
            )
            .expect("submit");
        std::thread::sleep(Duration::from_millis(30));
        // drop the whole client: the connection dies with work in flight
    }
    assert!(
        wait_until(Duration::from_secs(10), || {
            inproc.metrics().snapshot().canceled > before
        }),
        "replica must cancel in-flight work when the client vanishes"
    );
    assert!(
        wait_until(Duration::from_secs(10), || inproc.queue_depth() == 0),
        "orphaned work must not linger in the queue"
    );
    replica.shutdown();
}

// ---------------------------------------------------------------------
// handshake
// ---------------------------------------------------------------------

#[test]
fn version_mismatch_is_refused_with_a_typed_handshake() {
    let _g = serial();
    let replica = unix_replica("net-version", 1);
    let path = match &replica.bound()[0] {
        Addr::Unix(p) => p.clone(),
        other => panic!("expected unix addr, got {other}"),
    };
    let mut conn = UnixStream::connect(&path).expect("raw connect");
    let hello = encode_client(&ClientMsg::Hello {
        version: 99,
        name: "from-the-future".to_string(),
    });
    write_frame(&mut conn, &hello).expect("send hello");
    conn.flush().unwrap();
    let ack = read_frame(&mut conn).expect("read ack");
    match decode_server(&ack).expect("decode ack") {
        ServerMsg::HelloAck { version, max_atoms, .. } => {
            assert_eq!(version, 1, "server must answer with ITS version");
            assert_eq!(max_atoms, 0, "refusal advertises zero capacity");
        }
        other => panic!("expected HelloAck, got {other:?}"),
    }
    // the server hangs up after the refusal
    match read_frame(&mut conn) {
        Err(_) => {}
        Ok(f) => panic!("refused connection must close, got frame {f:?}"),
    }
    replica.shutdown();
}

// ---------------------------------------------------------------------
// front door
// ---------------------------------------------------------------------

#[test]
fn frontdoor_routes_probes_and_drains() {
    let _g = serial();
    let r0 = unix_replica("net-fd-r0", 1);
    let r1 = unix_replica("net-fd-r1", 1);
    let fd = FrontDoor::serve(
        &[r0.bound()[0].clone(), r1.bound()[0].clone()],
        &[Addr::Unix(temp_socket_path("net-fd"))],
        FrontDoorConfig::default(),
    )
    .expect("front door up");
    let nc = NetClient::connect(&fd.bound()[0]).expect("connect fd");

    let n = scaled(24, 8);
    let mut tickets = Vec::new();
    for i in 0..n {
        tickets.push(
            nc.submit(Request::new(EnergyForces(cluster(6 + i % 9, i as u64))))
                .expect("submit through fd"),
        );
    }
    for t in tickets {
        t.wait().expect("routed reply");
    }
    let (health, _) = nc.ping(Duration::from_secs(5)).expect("fd ping");
    assert_eq!(health, HealthState::Healthy);

    // the fleet ledger aggregates and reconciles
    let stats = nc.stats(Duration::from_secs(5)).expect("fd stats");
    assert!(stats.requests >= n as u64, "fleet stats must aggregate");
    assert!(stats.reconciles(), "fleet ledger must reconcile: {stats:?}");
    // both replicas' own ledgers reconcile too
    for r in [&r0, &r1] {
        assert!(r.client().metrics().snapshot().reconciles());
    }

    // drain: new work is refused with a typed error, service stays up
    nc.drain().expect("send drain");
    let refused = wait_until(Duration::from_secs(5), || {
        matches!(
            nc.submit(Request::new(EnergyForces(cluster(6, 99))))
                .and_then(|t| t.wait()),
            Err(ServiceError::Rejected(_))
        )
    });
    assert!(refused, "draining front door must reject new work");

    nc.close();
    fd.shutdown();
    r0.shutdown();
    r1.shutdown();
}

#[test]
fn frontdoor_with_no_live_replica_sheds_with_retry_after() {
    let _g = serial();
    // a front door pointed at an address nobody serves
    let ghost = Addr::Unix(temp_socket_path("net-ghost"));
    let fd = FrontDoor::serve(
        &[ghost],
        &[Addr::Unix(temp_socket_path("net-fd-empty"))],
        FrontDoorConfig::default(),
    )
    .expect("front door up");
    let nc = NetClient::connect(&fd.bound()[0]).expect("connect fd");
    match nc
        .submit(Request::new(EnergyForces(cluster(6, 5))))
        .and_then(|t| t.wait())
    {
        Err(ServiceError::Overloaded { retry_after }) => {
            assert!(retry_after > Duration::ZERO, "retry hint must be set");
        }
        other => panic!("expected Overloaded backpressure, got {other:?}"),
    }
    nc.close();
    fd.shutdown();
}

#[test]
fn frontdoor_reroutes_when_a_replica_is_shut_down() {
    let _g = serial();
    let r0 = unix_replica("net-rr-r0", 1);
    let r1 = unix_replica("net-rr-r1", 1);
    let cfg = FrontDoorConfig {
        probe_interval: Duration::from_millis(20),
        ..Default::default()
    };
    let fd = FrontDoor::serve(
        &[r0.bound()[0].clone(), r1.bound()[0].clone()],
        &[Addr::Unix(temp_socket_path("net-rr-fd"))],
        cfg,
    )
    .expect("front door up");
    let nc = NetClient::connect(&fd.bound()[0]).expect("connect fd");
    // warm up: both replicas take traffic
    for i in 0..scaled(8, 4) {
        nc.submit(Request::new(EnergyForces(cluster(8, i as u64))))
            .expect("warmup submit")
            .wait()
            .expect("warmup reply");
    }
    // kill one replica; the prober marks it down and routing moves
    r0.shutdown();
    let mut ok = 0usize;
    let n = scaled(16, 6);
    for i in 0..n {
        let out = nc
            .submit(Request::new(EnergyForces(cluster(8, 100 + i as u64))))
            .and_then(|t| t.wait());
        if out.is_ok() {
            ok += 1;
        }
        // idempotent retries mean the common case is zero failures, but
        // the contract is "typed error, never a hang" — wait() returned
    }
    assert!(
        ok >= n - 1,
        "with failover only ~one submission may race the death: {ok}/{n}"
    );
    assert!(
        wait_until(Duration::from_secs(5), || fd.live_replicas() == 1),
        "prober must mark the dead replica down"
    );
    nc.close();
    fd.shutdown();
    r1.shutdown();
}

#[test]
fn frontdoor_respawns_its_own_dead_spawned_replica() {
    let _g = serial();
    let exe = Path::new(env!("CARGO_BIN_EXE_gaunt-tp"));
    // spawn one real replica process, exactly as `--spawn-replicas` does
    let raddr = Addr::Unix(temp_socket_path("net-respawn-r0"));
    let cmd: Vec<String> = vec![
        exe.to_string_lossy().into_owned(),
        "replica".to_string(),
        "--listen".to_string(),
        raddr.to_string(),
        "--workers".to_string(),
        "1".to_string(),
        "--name".to_string(),
        "respawn-r0".to_string(),
    ];
    let child = std::process::Command::new(&cmd[0])
        .args(&cmd[1..])
        .spawn()
        .expect("spawn replica child");
    let pid = child.id();
    let cfg = FrontDoorConfig {
        probe_interval: Duration::from_millis(20),
        ..Default::default()
    };
    let fd = FrontDoor::serve(
        &[raddr],
        &[Addr::Unix(temp_socket_path("net-respawn-fd"))],
        cfg,
    )
    .expect("front door up");
    fd.supervise(0, child, cmd, RespawnPolicy {
        max_restarts: 3,
        backoff_initial: Duration::from_millis(50),
        backoff_max: Duration::from_millis(400),
    });
    assert!(
        wait_until(Duration::from_secs(15), || {
            fd.live_replicas().len() == 1
        }),
        "spawned replica must come up and join routing"
    );
    let nc = NetClient::connect(&fd.bound()[0]).expect("connect fd");
    nc.submit(Request::new(EnergyOnly(cluster(8, 1))))
        .expect("submit before kill")
        .wait()
        .expect("reply before kill");
    // SIGKILL the child out from under its supervisor: the prober must
    // notice the death, reap + respawn the child, and the fresh replica
    // must rejoin routing with no operator action
    assert!(
        std::process::Command::new("kill")
            .args(["-9", &pid.to_string()])
            .status()
            .expect("run kill")
            .success(),
        "kill -9 must reach the replica child"
    );
    assert!(
        wait_until(Duration::from_secs(10), || fd.live_replicas().is_empty()),
        "prober must mark the killed replica down"
    );
    assert!(
        wait_until(Duration::from_secs(15), || {
            fd.live_replicas().len() == 1
        }),
        "supervisor must respawn the child and the prober reconnect"
    );
    assert!(
        fd.respawn_counts()[0] >= 1,
        "the rejoin must come from a supervised respawn: {:?}",
        fd.respawn_counts()
    );
    nc.submit(Request::new(EnergyOnly(cluster(8, 2))))
        .expect("submit after respawn")
        .wait()
        .expect("reply after respawn");
    nc.close();
    // shutdown also kills + reaps the supervised child
    fd.shutdown();
}

// ---------------------------------------------------------------------
// the acceptance gate: real processes, real sockets
// ---------------------------------------------------------------------

fn acceptance_opts() -> LoadOpts {
    LoadOpts {
        replicas: 2,
        clients: 2,
        requests_per_client: scaled(40, 10),
        workers: 1,
        concurrency: 2,
        ..Default::default()
    }
}

#[test]
fn multi_process_loadtest_reconciles() {
    let _g = serial();
    let exe = Path::new(env!("CARGO_BIN_EXE_gaunt-tp"));
    let report = run_cluster_loadtest(exe, &acceptance_opts())
        .expect("cluster loadtest must complete");
    let t = &report.total;
    assert_eq!(
        t.n as usize,
        2 * acceptance_opts().requests_per_client,
        "every issued request must be accounted"
    );
    assert!(t.reconciles(), "client ledger must reconcile: {t:?}");
    assert!(
        report.success_rate() > 0.95,
        "healthy cluster must serve nearly everything: {t:?}"
    );
    if let Some(s) = &report.frontdoor_stats {
        assert!(s.reconciles(), "front-door fleet ledger: {s:?}");
    }
}

#[test]
fn multi_process_loadtest_survives_a_replica_kill() {
    let _g = serial();
    let exe = Path::new(env!("CARGO_BIN_EXE_gaunt-tp"));
    let opts = LoadOpts { kill_one: true, ..acceptance_opts() };
    // the loadtest returning AT ALL proves no client hung; the ledger
    // proves nothing was silently lost
    let report = run_cluster_loadtest(exe, &opts)
        .expect("kill-one loadtest must complete");
    let t = &report.total;
    assert!(report.killed_replica, "the kill must actually have happened");
    assert_eq!(t.n as usize, 2 * opts.requests_per_client);
    assert!(t.reconciles(), "ledger must reconcile through a kill: {t:?}");
    assert!(
        report.success_rate() > 0.5,
        "front door must recover the success rate after the kill: {t:?}"
    );
}
