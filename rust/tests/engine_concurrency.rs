//! Concurrency contract of the tensor-product engine: the plan cache
//! builds each key exactly once under contention, every thread sees the
//! same shared plan (through the typed accessors AND the uniform
//! `op(&OpKey)` entry point), and the generic multi-threaded batch
//! driver is bitwise identical to the serial path for every op family.

use std::sync::Arc;

use gaunt_tp::num_coeffs;
use gaunt_tp::tp::engine::{OpKey, PlanCache};
use gaunt_tp::tp::escn::EscnPlan;
use gaunt_tp::tp::op::{apply_batch_par, BatchInputs};
use gaunt_tp::tp::{CgPlan, ConvMethod, GauntPlan, ManyBodyPlan};
use gaunt_tp::util::prop::max_abs_diff;
use gaunt_tp::util::rng::Rng;

/// 8 threads hammer a fresh cache over a small key set THROUGH THE
/// UNIFORM `op()` ENTRY POINT: exactly one build per key must happen,
/// and every thread's outputs must equal the serial reference computed
/// from plans built outside the cache.
#[test]
fn plan_cache_one_build_per_key_under_contention() {
    let keys: Vec<(usize, usize, usize, ConvMethod)> = vec![
        (1, 1, 2, ConvMethod::Direct),
        (2, 2, 2, ConvMethod::Direct),
        (2, 2, 2, ConvMethod::Fft),
        (2, 1, 3, ConvMethod::Auto),
        (3, 3, 4, ConvMethod::Fft),
    ];
    // serial reference outputs on fixed inputs
    let mut refs = Vec::new();
    for &(l1, l2, l3, method) in &keys {
        let mut rng = Rng::new((l1 * 100 + l2 * 10 + l3) as u64);
        let x1 = rng.normals(num_coeffs(l1));
        let x2 = rng.normals(num_coeffs(l2));
        let want = GauntPlan::new(l1, l2, l3, method).apply(&x1, &x2);
        refs.push((x1, x2, want));
    }
    let cache = Arc::new(PlanCache::new());
    let keys = Arc::new(keys);
    let refs = Arc::new(refs);
    let mut handles = Vec::new();
    for t in 0..8 {
        let cache = cache.clone();
        let keys = keys.clone();
        let refs = refs.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..20 {
                // permute the key order per thread to vary contention
                for k in 0..keys.len() {
                    let idx = (k + t + round) % keys.len();
                    let (l1, l2, l3, method) = keys[idx];
                    let op = cache.op(&OpKey::Gaunt { l1, l2, l3, method });
                    let (x1, x2, want) = &refs[idx];
                    let got = apply_batch_par(
                        op.as_ref(), &BatchInputs::pair(x1, x2), 1, 1,
                    );
                    assert!(
                        max_abs_diff(&got, want) < 1e-12,
                        "thread {t}: cached plan diverged on key {idx}"
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(
        cache.builds(),
        keys.len(),
        "cache must build each of the {} keys exactly once",
        keys.len()
    );
    assert_eq!(cache.len(), keys.len());
    assert!(cache.hits() > 0);
    // per-key stats saw the traffic: every key was hit many times
    let stats = cache.stats();
    assert_eq!(stats.len, keys.len());
    assert_eq!(stats.per_key.len(), keys.len());
    for ks in &stats.per_key {
        assert!(ks.hits > 0, "{:?} never hit", ks.key);
    }
}

/// Typed accessors and the uniform entry point share one instance per
/// key: two lookups return literally the same Arc.
#[test]
fn plan_cache_shares_plan_instances() {
    let cache = PlanCache::new();
    let a = cache.gaunt(2, 2, 2, ConvMethod::Auto);
    let b = cache.gaunt(2, 2, 2, ConvMethod::Auto);
    assert!(Arc::ptr_eq(&a, &b));
    let c = cache.cg(2, 2, 2);
    let d = cache.cg(2, 2, 2);
    assert!(Arc::ptr_eq(&c, &d));
    let e = cache.escn(2, 2, 2);
    let f = cache.escn(2, 2, 2);
    assert!(Arc::ptr_eq(&e, &f));
    assert_eq!(cache.builds(), 3);
    // op() resolves to the SAME plan the typed accessor built
    let g = cache.op(&OpKey::Gaunt {
        l1: 2, l2: 2, l3: 2, method: ConvMethod::Auto,
    });
    assert!(std::ptr::eq(
        Arc::as_ptr(&a) as *const u8,
        Arc::as_ptr(&g) as *const u8,
    ));
    assert_eq!(cache.builds(), 3, "op() must not rebuild an existing key");
}

/// The global cache is one process-wide instance.
#[test]
fn global_cache_is_shared() {
    let a = PlanCache::global().gaunt(1, 1, 1, ConvMethod::Direct);
    let b = PlanCache::global().gaunt(1, 1, 1, ConvMethod::Direct);
    assert!(Arc::ptr_eq(&a, &b));
}

/// The ONE generic batch driver equals each family's serial path
/// bit-for-bit for every thread count (this is the replacement for the
/// per-family `*_apply_batch_par` free functions).
#[test]
fn generic_parallel_batches_match_serial_for_all_families() {
    let mut rng = Rng::new(9);
    let rows = 11usize;

    let gplan = GauntPlan::new(3, 2, 4, ConvMethod::Auto);
    let gx1 = rng.normals(rows * num_coeffs(3));
    let gx2 = rng.normals(rows * num_coeffs(2));
    let g_serial = gplan.apply_batch(&gx1, &gx2, rows);

    let cplan = CgPlan::new(2, 2, 3);
    let cx1 = rng.normals(rows * num_coeffs(2));
    let cx2 = rng.normals(rows * num_coeffs(2));
    let c_serial = cplan.apply_batch(&cx1, &cx2, rows);

    let eplan = EscnPlan::new(2, 2, 2);
    let ex = rng.normals(rows * num_coeffs(2));
    let dirs: Vec<[f64; 3]> = (0..rows).map(|_| rng.unit3()).collect();
    let h: Vec<f64> = (0..eplan.n_paths()).map(|_| rng.normal()).collect();
    let e_serial = eplan.apply_batch(&ex, &dirs, &h);

    let mplan = ManyBodyPlan::new(3, 2, 3);
    let mut m_serial = vec![0.0; rows * num_coeffs(3)];
    {
        let n = num_coeffs(2);
        let n3 = num_coeffs(3);
        for r in 0..rows {
            let y = mplan.apply_self(&ex[r * n..(r + 1) * n]);
            m_serial[r * n3..(r + 1) * n3].copy_from_slice(&y);
        }
    }

    for threads in [1usize, 2, 3, 8, 0] {
        let g = apply_batch_par(&gplan, &BatchInputs::pair(&gx1, &gx2),
                                rows, threads);
        assert_eq!(g, g_serial, "gaunt threads={threads}");
        let c = apply_batch_par(&cplan, &BatchInputs::pair(&cx1, &cx2),
                                rows, threads);
        assert_eq!(c, c_serial, "cg threads={threads}");
        let e = apply_batch_par(&eplan, &BatchInputs::edges(&ex, &dirs, &h),
                                rows, threads);
        assert_eq!(e, e_serial, "escn threads={threads}");
        let m = apply_batch_par(&mplan, &BatchInputs::singles(&ex),
                                rows, threads);
        assert_eq!(m, m_serial, "many-body threads={threads}");
    }
}
