//! Cross-language validation: the native Rust implementation must agree
//! with the Python build-time implementation on the golden vectors
//! exported by `python -m compile.aot` (artifacts/golden/so3_golden.json)
//! and `python -m compile.model_golden`
//! (artifacts/golden/model_golden.json — one frozen-weights model
//! energy/forces snapshot).
//!
//! Skip policy: when a golden file is absent (pre-`make artifacts`
//! checkouts) each cross-language test prints exactly which file it is
//! missing and returns — no silent empty passes, no `#[ignore]`.
//! Setting `GOLDENS_REQUIRED=1` (as `scripts/verify.sh` does whenever
//! goldens are expected) turns every such skip into a HARD FAILURE, so a
//! missing or misplaced export can never masquerade as a pass.  When a
//! file is present but a key is missing, the test always FAILS loudly.
//! The `native_golden_*` tests at the bottom need no Python artifacts
//! and always assert.

use gaunt_tp::fourier::tables::{f2sh_panels, sh2f_panels};
use gaunt_tp::num_coeffs;
use gaunt_tp::so3::gaunt::{cg_tensor_real, gaunt_tensor_real};
use gaunt_tp::so3::rotation::{wigner_d_real_block, Rot3};
use gaunt_tp::so3::sh::real_sh_all_xyz;
use gaunt_tp::so3::wigner::wigner_3j;
use gaunt_tp::tp::{ConvMethod, GauntPlan};
use gaunt_tp::util::json::{parse, Json};
use gaunt_tp::lm_index;

const GOLDEN_PATH: &str = "artifacts/golden/so3_golden.json";
const MODEL_GOLDEN_PATH: &str = "artifacts/golden/model_golden.json";
const VECTOR_GOLDEN_PATH: &str = "artifacts/golden/vector_golden.json";

/// Whether missing goldens are hard failures (scripts/verify.sh sets
/// this whenever the artifacts have been generated).
fn goldens_required() -> bool {
    std::env::var("GOLDENS_REQUIRED").map(|v| v == "1").unwrap_or(false)
}

fn load_golden_file(path: &str, test: &str) -> Option<Json> {
    match std::fs::read_to_string(path) {
        Ok(text) => match parse(&text) {
            Ok(v) => Some(v),
            Err(e) => panic!("{path} exists but does not parse: {e}"),
        },
        Err(_) => {
            if goldens_required() {
                panic!(
                    "{test}: golden file {path} missing but \
                     GOLDENS_REQUIRED=1 — regenerate with `make artifacts`"
                );
            }
            eprintln!(
                "SKIP {test}: golden file {path} missing \
                 (build it with `make artifacts`)"
            );
            None
        }
    }
}

fn load_golden(test: &str) -> Option<Json> {
    load_golden_file(GOLDEN_PATH, test)
}

/// Fetch a golden key; a present file with a missing key is a hard error.
fn key<'a>(g: &'a Json, k: &str) -> &'a Json {
    g.get(k).unwrap_or_else(|| {
        panic!(
            "{GOLDEN_PATH} present but golden key '{k}' missing — \
             regenerate with `make artifacts`"
        )
    })
}

macro_rules! golden {
    ($name:literal) => {
        match load_golden($name) {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn wigner_3j_matches_python() {
    let g = golden!("wigner_3j_matches_python");
    let rows = key(&g, "wigner3j").as_arr().unwrap();
    assert!(rows.len() > 50);
    for row in rows {
        let v: Vec<f64> = row.as_f64_vec().unwrap();
        let got = wigner_3j(
            v[0] as i64, v[1] as i64, v[2] as i64,
            v[3] as i64, v[4] as i64, v[5] as i64,
        );
        assert!(
            (got - v[6]).abs() < 1e-11,
            "3j({},{},{};{},{},{}) = {} vs python {}",
            v[0], v[1], v[2], v[3], v[4], v[5], got, v[6]
        );
    }
}

#[test]
fn gaunt_tensor_matches_python() {
    let g = golden!("gaunt_tensor_matches_python");
    let want = key(&g, "gaunt_222").as_f64_vec().unwrap();
    let got = gaunt_tensor_real(2, 2, 2);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn cg_tensor_matches_python() {
    let g = golden!("cg_tensor_matches_python");
    let want = key(&g, "cg_222").as_f64_vec().unwrap();
    let got = cg_tensor_real(2, 2, 2);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-10, "idx {i}: {a} vs {b}");
    }
}

#[test]
fn spherical_harmonics_match_python() {
    let g = golden!("spherical_harmonics_match_python");
    let pts = key(&g, "sh_points").as_f64_vec().unwrap();
    let want = key(&g, "sh_L3").as_f64_vec().unwrap();
    let n = num_coeffs(3);
    for (p_idx, chunk) in pts.chunks(3).enumerate() {
        let y = real_sh_all_xyz(3, [chunk[0], chunk[1], chunk[2]]);
        for k in 0..n {
            assert!(
                (y[k] - want[p_idx * n + k]).abs() < 1e-10,
                "point {p_idx} coeff {k}"
            );
        }
    }
}

#[test]
fn sh2f_panels_match_python() {
    let g = golden!("sh2f_panels_match_python");
    let re = key(&g, "sh2f_panels_L3_re").as_f64_vec().unwrap();
    let im = key(&g, "sh2f_panels_L3_im").as_f64_vec().unwrap();
    let p = sh2f_panels(3);
    // python layout: [s, u, l] over (4, 7, 4)
    let (nu, nl) = (7usize, 4usize);
    for s in 0..4 {
        for u in 0..nu {
            for l in 0..nl {
                let idx = (s * nu + u) * nl + l;
                let c = p.panels[s][u * nl + l];
                assert!((c.re - re[idx]).abs() < 1e-10, "re s={s} u={u} l={l}");
                assert!((c.im - im[idx]).abs() < 1e-10, "im s={s} u={u} l={l}");
            }
        }
    }
}

#[test]
fn f2sh_panels_match_python() {
    let g = golden!("f2sh_panels_match_python");
    let re = key(&g, "f2sh_panels_L3_N6_re").as_f64_vec().unwrap();
    let im = key(&g, "f2sh_panels_L3_N6_im").as_f64_vec().unwrap();
    let t = f2sh_panels(3, 6);
    // python layout: [s, l, u] over (4, 4, 13)
    let (nl, nu) = (4usize, 13usize);
    for s in 0..4 {
        for l in 0..nl {
            for u in 0..nu {
                let idx = (s * nl + l) * nu + u;
                let c = t.panels[s][l * nu + u];
                assert!((c.re - re[idx]).abs() < 1e-10, "re s={s} l={l} u={u}");
                assert!((c.im - im[idx]).abs() < 1e-10, "im s={s} l={l} u={u}");
            }
        }
    }
}

/// The legacy (pre-plan) FFT pipeline, composed from public pieces:
/// sh2f -> allocating `conv2d_fft` -> f2sh.  Kept pinned to the same
/// goldens as the planned path so both conv backends stay interchangeable.
fn legacy_fft_pipeline(plan: &GauntPlan, a: &[f64], b: &[f64]) -> Vec<f64> {
    use gaunt_tp::fourier::conv::conv2d_fft;
    let p1 = gaunt_tp::fourier::tables::sh2f_panels(plan.l1);
    let p2 = gaunt_tp::fourier::tables::sh2f_panels(plan.l2);
    let u1 = GauntPlan::sh2f(&p1, a);
    let u2 = GauntPlan::sh2f(&p2, b);
    let u3 = conv2d_fft(&u1, 2 * plan.l1 + 1, &u2, 2 * plan.l2 + 1);
    plan.f2sh(&u3)
}

#[test]
fn gaunt_tp_io_pairs_match_python() {
    let g = golden!("gaunt_tp_io_pairs_match_python");
    let x1 = key(&g, "tp_x1").as_f64_vec().unwrap();
    let x2 = key(&g, "tp_x2").as_f64_vec().unwrap();
    let y3 = key(&g, "tp_y_L3").as_f64_vec().unwrap();
    let y6 = key(&g, "tp_y_L6").as_f64_vec().unwrap();
    let n = num_coeffs(3);
    let plan3 = GauntPlan::new(3, 3, 3, ConvMethod::Fft);
    let plan6 = GauntPlan::new(3, 3, 6, ConvMethod::Direct);
    for r in 0..3 {
        let a = &x1[r * n..(r + 1) * n];
        let b = &x2[r * n..(r + 1) * n];
        // planned Hermitian FFT path
        let got3 = plan3.apply(a, b);
        // legacy allocating FFT path, pinned to the SAME golden
        let leg3 = legacy_fft_pipeline(&plan3, a, b);
        for k in 0..n {
            assert!((got3[k] - y3[r * n + k]).abs() < 1e-9, "planned k={k}");
            assert!((leg3[k] - y3[r * n + k]).abs() < 1e-9, "legacy k={k}");
        }
        let got6 = plan6.apply(a, b);
        let leg6 = legacy_fft_pipeline(&plan6, a, b);
        let n6 = num_coeffs(6);
        for k in 0..n6 {
            assert!((got6[k] - y6[r * n6 + k]).abs() < 1e-9);
            assert!((leg6[k] - y6[r * n6 + k]).abs() < 1e-9);
        }
    }
}

#[test]
fn wigner_d_matches_python() {
    let g = golden!("wigner_d_matches_python");
    let rot_flat = key(&g, "rot").as_f64_vec().unwrap();
    let want = key(&g, "wigner_d_block_L2").as_f64_vec().unwrap();
    let rot = Rot3([
        [rot_flat[0], rot_flat[1], rot_flat[2]],
        [rot_flat[3], rot_flat[4], rot_flat[5]],
        [rot_flat[6], rot_flat[7], rot_flat[8]],
    ]);
    let got = wigner_d_real_block(2, &rot);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-8, "idx {i}: {a} vs {b}");
    }
}

/// The full learned-model pipeline against the numpy mirror: frozen
/// weights, a frozen 8-atom cluster, reference energy AND analytic
/// forces from `python -m compile.model_golden` (whose math is validated
/// against the exact real Gaunt tensors + finite differences on the
/// Python side).  One number disagreeing anywhere in the stack — SH
/// conventions, radial basis, conv, many-body, readout, any backward
/// pass — fails this.
#[test]
fn model_energy_and_forces_match_python() {
    use gaunt_tp::model::{Model, ModelConfig};
    let g = match load_golden_file(MODEL_GOLDEN_PATH,
                                   "model_energy_and_forces_match_python") {
        Some(v) => v,
        None => return,
    };
    let key = |k: &str| -> &Json {
        g.get(k).unwrap_or_else(|| {
            panic!(
                "{MODEL_GOLDEN_PATH} present but key '{k}' missing — \
                 regenerate with `make model-golden`"
            )
        })
    };
    let cj = key("config");
    let geti = |k: &str| cj.get(k).and_then(Json::as_usize).unwrap();
    let cfg = ModelConfig {
        l: geti("l"),
        l_filter: geti("l_filter"),
        nu: geti("nu"),
        // pre-multi-channel goldens carry no `channels` key: they pin
        // the single-channel layout, which is unchanged at channels = 1
        channels: cj.get("channels").and_then(Json::as_usize).unwrap_or(1),
        n_layers: geti("n_layers"),
        n_species: geti("n_species"),
        n_radial: geti("n_radial"),
        r_cut: cj.get("r_cut").and_then(Json::as_f64).unwrap(),
        ..Default::default()
    };
    let params = key("params").as_f64_vec().unwrap();
    let model = Model::from_params(cfg, params);
    let pos_flat = key("pos").as_f64_vec().unwrap();
    let pos: Vec<[f64; 3]> = pos_flat
        .chunks_exact(3)
        .map(|c| [c[0], c[1], c[2]])
        .collect();
    let species: Vec<usize> = key("species")
        .as_f64_vec()
        .unwrap()
        .iter()
        .map(|&s| s as usize)
        .collect();
    // neighbor lists must agree on the edge COUNT (order may differ)
    let n_edges = key("n_edges").as_usize().unwrap();
    assert_eq!(model.build_edges(&pos).len(), n_edges,
               "neighbor count disagrees with the python mirror");
    let (e, f) = model.energy_forces(&pos, &species);
    let e_ref = key("energy").as_f64().unwrap();
    assert!(
        (e - e_ref).abs() < 1e-7 * (1.0 + e_ref.abs()),
        "energy {e} vs python {e_ref}"
    );
    let f_ref = key("forces").as_f64_vec().unwrap();
    for (i, fi) in f.iter().enumerate() {
        for ax in 0..3 {
            let want = f_ref[3 * i + ax];
            assert!(
                (fi[ax] - want).abs() < 1e-7 * (1.0 + want.abs()),
                "force[{i}][{ax}] {} vs python {want}",
                fi[ax]
            );
        }
    }
    // both conv backends stay pinned to the same golden
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let m2 = Model::from_params(
            ModelConfig { method, ..cfg },
            model.params.clone(),
        );
        let (e2, _) = m2.energy_forces(&pos, &species);
        assert!((e2 - e_ref).abs() < 1e-7 * (1.0 + e_ref.abs()),
                "{method:?}: {e2} vs {e_ref}");
    }
}

/// The vector-signal subsystem against the numpy mirror
/// (`python -m compile.vector_golden`): real VSH values at six frozen
/// directions, all three `tp::vector` plan kinds (forward AND
/// sibling-plan VJP, on both conv backends), the VSH dot-coupling
/// tensor, and the dipole readout head's forward + parameter
/// gradients.  The Python side validates the same numbers against
/// quadrature, finite differences, and O(3) transforms before
/// exporting.
#[test]
fn vector_ops_match_python() {
    use gaunt_tp::model::dipole::DipoleHead;
    use gaunt_tp::so3::{vsh_dot_gaunt, vsh_set, VshEvaluator, VshKind};
    use gaunt_tp::tp::{VectorGauntPlan, VectorKind};
    let g = match load_golden_file(VECTOR_GOLDEN_PATH, "vector_ops_match_python")
    {
        Some(v) => v,
        None => return,
    };
    let key = |k: &str| -> &Json {
        g.get(k).unwrap_or_else(|| {
            panic!(
                "{VECTOR_GOLDEN_PATH} present but key '{k}' missing — \
                 regenerate with `make vector-golden`"
            )
        })
    };

    // real vector spherical harmonics at the frozen directions
    let vsh = key("vsh");
    let pts = vsh.get("points").and_then(Json::as_f64_vec).unwrap();
    let entries = vsh.get("entries").and_then(Json::as_arr).unwrap();
    assert_eq!(entries.len(), vsh_set(3, 3, 3).len());
    let mut ev = VshEvaluator::new(3);
    for (p_idx, p) in pts.chunks_exact(3).enumerate() {
        ev.move_to([p[0], p[1], p[2]]);
        for e in entries {
            let kind = VshKind::from_name(
                e.get("kind").and_then(Json::as_str).unwrap(),
            )
            .unwrap();
            let l = e.get("l").and_then(Json::as_usize).unwrap();
            let m = e.get("m").and_then(Json::as_f64).unwrap() as i64;
            let want = e.get("values").and_then(Json::as_f64_vec).unwrap();
            let got = ev.eval(kind, l, m);
            for ax in 0..3 {
                assert!(
                    (got[ax] - want[3 * p_idx + ax]).abs() < 1e-9,
                    "vsh {}({l},{m}) point {p_idx} axis {ax}: {} vs {}",
                    kind.name(), got[ax], want[3 * p_idx + ax]
                );
            }
        }
    }

    // the three plan kinds: forward on both conv backends, then the
    // degree-rotated sibling-plan VJP against the mirror's grad
    for case in key("plans").as_arr().unwrap() {
        let kind = VectorKind::from_name(
            case.get("kind").and_then(Json::as_str).unwrap(),
        )
        .unwrap();
        let l1 = case.get("l1").and_then(Json::as_usize).unwrap();
        let l2 = case.get("l2").and_then(Json::as_usize).unwrap();
        let l3 = case.get("l3").and_then(Json::as_usize).unwrap();
        let x1 = case.get("x1").and_then(Json::as_f64_vec).unwrap();
        let x2 = case.get("x2").and_then(Json::as_f64_vec).unwrap();
        let want_out = case.get("out").and_then(Json::as_f64_vec).unwrap();
        let cot = case.get("cotangent").and_then(Json::as_f64_vec).unwrap();
        let want_grad =
            case.get("grad_x1").and_then(Json::as_f64_vec).unwrap();
        for method in [ConvMethod::Direct, ConvMethod::Fft] {
            let plan = VectorGauntPlan::new(kind, l1, l2, l3, method);
            let got = plan.apply(&x1, &x2);
            assert_eq!(got.len(), want_out.len());
            for (k, (a, b)) in got.iter().zip(&want_out).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "{}({l1},{l2},{l3}) {method:?} out[{k}]: {a} vs {b}",
                    kind.name()
                );
            }
            let (sk, s1, s2, s3) = plan.vjp_sibling_key();
            let sib = VectorGauntPlan::new(sk, s1, s2, s3, method);
            let grad = if plan.vjp_operands_swapped() {
                sib.apply(&x2, &cot)
            } else {
                sib.apply(&cot, &x2)
            };
            assert_eq!(grad.len(), want_grad.len());
            for (k, (a, b)) in grad.iter().zip(&want_grad).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9,
                    "{}({l1},{l2},{l3}) {method:?} grad_x1[{k}]: {a} vs {b}",
                    kind.name()
                );
            }
        }
    }

    // the VSH-basis dot-coupling tensor, index list pinned first
    let vd = key("vsh_dot_gaunt");
    let l3 = vd.get("l3").and_then(Json::as_usize).unwrap();
    let vset = vsh_set(1, 1, 1);
    let vset_g = vd.get("vset").and_then(Json::as_arr).unwrap();
    assert_eq!(vset_g.len(), vset.len());
    for (row, &(k, l, m)) in vset_g.iter().zip(&vset) {
        let row = row.as_arr().unwrap();
        assert_eq!(row[0].as_str().unwrap(), k.name());
        assert_eq!(row[1].as_usize().unwrap(), l);
        assert_eq!(row[2].as_f64().unwrap() as i64, m);
    }
    let want_t = vd.get("tensor").and_then(Json::as_f64_vec).unwrap();
    let got_t = vsh_dot_gaunt(l3, &vset, &vset);
    assert_eq!(got_t.len(), want_t.len());
    for (i, (a, b)) in got_t.iter().zip(&want_t).enumerate() {
        assert!((a - b).abs() < 1e-9, "vsh_dot_gaunt[{i}]: {a} vs {b}");
    }

    // dipole readout head: forward + parameter gradients, both backends
    let d = key("dipole");
    let channels = d.get("channels").and_then(Json::as_usize).unwrap();
    let l = d.get("l").and_then(Json::as_usize).unwrap();
    let h = d.get("h").and_then(Json::as_f64_vec).unwrap();
    let w = d.get("w").and_then(Json::as_f64_vec).unwrap();
    let c_dip = d.get("c_dip").and_then(Json::as_f64).unwrap();
    let gmv = d.get("g_mu").and_then(Json::as_f64_vec).unwrap();
    let g_mu = [gmv[0], gmv[1], gmv[2]];
    let want_mu = d.get("mu").and_then(Json::as_f64_vec).unwrap();
    let want_gw = d.get("grad_w").and_then(Json::as_f64_vec).unwrap();
    let want_gc = d.get("grad_c_dip").and_then(Json::as_f64).unwrap();
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let head =
            DipoleHead::with_params(channels, l, method, w.clone(), c_dip);
        let mut s = head.scratch();
        let mu = head.dipole_into(&h, &mut s);
        for ax in 0..3 {
            assert!(
                (mu[ax] - want_mu[ax]).abs() < 1e-9,
                "{method:?} mu[{ax}]: {} vs {}",
                mu[ax], want_mu[ax]
            );
        }
        let mut gw = vec![0.0; w.len()];
        let mut gc = 0.0;
        head.grads_into(&h, g_mu, &mut gw, &mut gc, &mut s);
        for (i, (a, b)) in gw.iter().zip(&want_gw).enumerate() {
            assert!(
                (a - b).abs() < 1e-9,
                "{method:?} grad_w[{i}]: {a} vs {b}"
            );
        }
        assert!(
            (gc - want_gc).abs() < 1e-9,
            "{method:?} grad_c_dip: {gc} vs {want_gc}"
        );
    }
}

// ---------------------------------------------------------------------
// Native-only goldens — no Python artifacts required; these always run.
// ---------------------------------------------------------------------

/// Frobenius norm of the (l1, l2, l3) block of a coupling tensor over the
/// flat (L+1)^2 layout, plus the <G, C> inner product against another
/// tensor's matching block.
fn block_stats(
    g: &[f64], c: &[f64], n: usize, l1: usize, l2: usize, l3: usize,
) -> (f64, f64, f64) {
    let (d1, d2, d3) = (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1);
    let b1 = lm_index(l1, -(l1 as i64));
    let b2 = lm_index(l2, -(l2 as i64));
    let b3 = lm_index(l3, -(l3 as i64));
    let (mut gg, mut cc, mut gc) = (0.0, 0.0, 0.0);
    for a in 0..d1 {
        for b in 0..d2 {
            for k in 0..d3 {
                let idx = ((b3 + k) * n + (b1 + a)) * n + (b2 + b);
                gg += g[idx] * g[idx];
                cc += c[idx] * c[idx];
                gc += g[idx] * c[idx];
            }
        }
    }
    (gg.sqrt(), cc.sqrt(), gc)
}

/// CG vs Gaunt selection-rule cross-check at L = 4: a golden test with no
/// external inputs.  For every (l1, l2, l3) block up to degree 4:
///   * outside the triangle inequality both tensors vanish;
///   * odd-parity blocks survive in CG but vanish identically in Gaunt;
///   * even-parity triangle blocks are nonzero in both and, by
///     Wigner-Eckart, the Gaunt block is a scalar multiple of the CG one.
#[test]
fn native_golden_cg_vs_gaunt_selection_rules_l4() {
    let l = 4usize;
    let n = num_coeffs(l);
    let g = gaunt_tensor_real(l, l, l);
    let c = cg_tensor_real(l, l, l);
    let mut even_blocks = 0usize;
    let mut odd_blocks = 0usize;
    for l1 in 0..=l {
        for l2 in 0..=l {
            for l3 in 0..=l {
                let (gn, cn, gc) = block_stats(&g, &c, n, l1, l2, l3);
                let triangle = l3 >= l1.abs_diff(l2) && l3 <= l1 + l2;
                let even = (l1 + l2 + l3) % 2 == 0;
                if !triangle {
                    assert!(gn < 1e-10, "({l1},{l2},{l3}): gaunt outside triangle");
                    assert!(cn < 1e-10, "({l1},{l2},{l3}): cg outside triangle");
                } else if !even {
                    // parity: Gaunt (integral of three SH) kills odd sums,
                    // the CG coupling keeps them
                    assert!(gn < 1e-10, "({l1},{l2},{l3}): odd gaunt = {gn}");
                    assert!(cn > 1e-8, "({l1},{l2},{l3}): odd cg missing");
                    odd_blocks += 1;
                } else {
                    assert!(gn > 1e-8, "({l1},{l2},{l3}): even gaunt missing");
                    assert!(cn > 1e-8, "({l1},{l2},{l3}): even cg missing");
                    // Wigner-Eckart: G = k C on the block
                    let k = gc / (cn * cn);
                    let resid = (gn * gn - 2.0 * k * gc + k * k * cn * cn)
                        .max(0.0)
                        .sqrt();
                    assert!(
                        resid < 1e-8 * (1.0 + gn),
                        "({l1},{l2},{l3}): gaunt not proportional to cg \
                         (residual {resid})"
                    );
                    even_blocks += 1;
                }
            }
        }
    }
    // explicit assertion count: the sweep must have exercised real blocks
    assert!(even_blocks >= 30, "only {even_blocks} even blocks checked");
    assert!(odd_blocks >= 20, "only {odd_blocks} odd blocks checked");
}

/// The Gaunt pipeline (direct and FFT) must agree with its own coupling
/// tensor at L = 4 — a native end-to-end golden for the fast path.
#[test]
fn native_golden_gaunt_pipeline_matches_tensor_l4() {
    use gaunt_tp::util::rng::Rng;
    let l = 4usize;
    let n = num_coeffs(l);
    let g = gaunt_tensor_real(l, l, l);
    let mut rng = Rng::new(42);
    let x1 = rng.normals(n);
    let x2 = rng.normals(n);
    let mut want = vec![0.0; n];
    for k in 0..n {
        for i in 0..n {
            for j in 0..n {
                want[k] += g[(k * n + i) * n + j] * x1[i] * x2[j];
            }
        }
    }
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let got = GauntPlan::new(l, l, l, method).apply(&x1, &x2);
        for k in 0..n {
            assert!(
                (got[k] - want[k]).abs() < 1e-9,
                "{method:?} coeff {k}: {} vs {}",
                got[k], want[k]
            );
        }
    }
    // the legacy allocating FFT pipeline stays pinned to the same native
    // golden as the planned paths
    let plan = GauntPlan::new(l, l, l, ConvMethod::Fft);
    let legacy = legacy_fft_pipeline(&plan, &x1, &x2);
    for k in 0..n {
        assert!(
            (legacy[k] - want[k]).abs() < 1e-9,
            "legacy pipeline coeff {k}: {} vs {}",
            legacy[k], want[k]
        );
    }
}
