//! Cross-language validation: the native Rust implementation must agree
//! with the Python build-time implementation on the golden vectors
//! exported by `python -m compile.aot` (artifacts/golden/so3_golden.json).
//!
//! These tests skip gracefully when artifacts are absent (pre-`make
//! artifacts` checkouts) so `cargo test` stays green everywhere.

use gaunt_tp::fourier::tables::{f2sh_panels, sh2f_panels};
use gaunt_tp::num_coeffs;
use gaunt_tp::so3::gaunt::{cg_tensor_real, gaunt_tensor_real};
use gaunt_tp::so3::rotation::{wigner_d_real_block, Rot3};
use gaunt_tp::so3::sh::real_sh_all_xyz;
use gaunt_tp::so3::wigner::wigner_3j;
use gaunt_tp::tp::{ConvMethod, GauntPlan};
use gaunt_tp::util::json::{parse, Json};

fn load_golden() -> Option<Json> {
    let text = std::fs::read_to_string("artifacts/golden/so3_golden.json").ok()?;
    parse(&text).ok()
}

macro_rules! golden {
    ($g:ident) => {
        match load_golden() {
            Some(v) => v,
            None => {
                eprintln!("skipping: golden vectors not present");
                return;
            }
        }
    };
}

#[test]
fn wigner_3j_matches_python() {
    let g = golden!(g);
    let rows = g.get("wigner3j").and_then(Json::as_arr).unwrap();
    assert!(rows.len() > 50);
    for row in rows {
        let v: Vec<f64> = row.as_f64_vec().unwrap();
        let got = wigner_3j(
            v[0] as i64, v[1] as i64, v[2] as i64,
            v[3] as i64, v[4] as i64, v[5] as i64,
        );
        assert!(
            (got - v[6]).abs() < 1e-11,
            "3j({},{},{};{},{},{}) = {} vs python {}",
            v[0], v[1], v[2], v[3], v[4], v[5], got, v[6]
        );
    }
}

#[test]
fn gaunt_tensor_matches_python() {
    let g = golden!(g);
    let want = g.get("gaunt_222").and_then(Json::as_f64_vec).unwrap();
    let got = gaunt_tensor_real(2, 2, 2);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn cg_tensor_matches_python() {
    let g = golden!(g);
    let want = g.get("cg_222").and_then(Json::as_f64_vec).unwrap();
    let got = cg_tensor_real(2, 2, 2);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-10, "idx {i}: {a} vs {b}");
    }
}

#[test]
fn spherical_harmonics_match_python() {
    let g = golden!(g);
    let pts = g.get("sh_points").and_then(Json::as_f64_vec).unwrap();
    let want = g.get("sh_L3").and_then(Json::as_f64_vec).unwrap();
    let n = num_coeffs(3);
    for (p_idx, chunk) in pts.chunks(3).enumerate() {
        let y = real_sh_all_xyz(3, [chunk[0], chunk[1], chunk[2]]);
        for k in 0..n {
            assert!(
                (y[k] - want[p_idx * n + k]).abs() < 1e-10,
                "point {p_idx} coeff {k}"
            );
        }
    }
}

#[test]
fn sh2f_panels_match_python() {
    let g = golden!(g);
    let re = g.get("sh2f_panels_L3_re").and_then(Json::as_f64_vec).unwrap();
    let im = g.get("sh2f_panels_L3_im").and_then(Json::as_f64_vec).unwrap();
    let p = sh2f_panels(3);
    // python layout: [s, u, l] over (4, 7, 4)
    let (nu, nl) = (7usize, 4usize);
    for s in 0..4 {
        for u in 0..nu {
            for l in 0..nl {
                let idx = (s * nu + u) * nl + l;
                let c = p.panels[s][u * nl + l];
                assert!((c.re - re[idx]).abs() < 1e-10, "re s={s} u={u} l={l}");
                assert!((c.im - im[idx]).abs() < 1e-10, "im s={s} u={u} l={l}");
            }
        }
    }
}

#[test]
fn f2sh_panels_match_python() {
    let g = golden!(g);
    let re = g.get("f2sh_panels_L3_N6_re").and_then(Json::as_f64_vec).unwrap();
    let im = g.get("f2sh_panels_L3_N6_im").and_then(Json::as_f64_vec).unwrap();
    let t = f2sh_panels(3, 6);
    // python layout: [s, l, u] over (4, 4, 13)
    let (nl, nu) = (4usize, 13usize);
    for s in 0..4 {
        for l in 0..nl {
            for u in 0..nu {
                let idx = (s * nl + l) * nu + u;
                let c = t.panels[s][l * nu + u];
                assert!((c.re - re[idx]).abs() < 1e-10, "re s={s} l={l} u={u}");
                assert!((c.im - im[idx]).abs() < 1e-10, "im s={s} l={l} u={u}");
            }
        }
    }
}

#[test]
fn gaunt_tp_io_pairs_match_python() {
    let g = golden!(g);
    let x1 = g.get("tp_x1").and_then(Json::as_f64_vec).unwrap();
    let x2 = g.get("tp_x2").and_then(Json::as_f64_vec).unwrap();
    let y3 = g.get("tp_y_L3").and_then(Json::as_f64_vec).unwrap();
    let y6 = g.get("tp_y_L6").and_then(Json::as_f64_vec).unwrap();
    let n = num_coeffs(3);
    let plan3 = GauntPlan::new(3, 3, 3, ConvMethod::Fft);
    let plan6 = GauntPlan::new(3, 3, 6, ConvMethod::Direct);
    for r in 0..3 {
        let a = &x1[r * n..(r + 1) * n];
        let b = &x2[r * n..(r + 1) * n];
        let got3 = plan3.apply(a, b);
        for k in 0..n {
            assert!((got3[k] - y3[r * n + k]).abs() < 1e-9);
        }
        let got6 = plan6.apply(a, b);
        let n6 = num_coeffs(6);
        for k in 0..n6 {
            assert!((got6[k] - y6[r * n6 + k]).abs() < 1e-9);
        }
    }
}

#[test]
fn wigner_d_matches_python() {
    let g = golden!(g);
    let rot_flat = g.get("rot").and_then(Json::as_f64_vec).unwrap();
    let want = g.get("wigner_d_block_L2").and_then(Json::as_f64_vec).unwrap();
    let rot = Rot3([
        [rot_flat[0], rot_flat[1], rot_flat[2]],
        [rot_flat[3], rot_flat[4], rot_flat[5]],
        [rot_flat[6], rot_flat[7], rot_flat[8]],
    ]);
    let got = wigner_d_real_block(2, &rot);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-8, "idx {i}: {a} vs {b}");
    }
}
