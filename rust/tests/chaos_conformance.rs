//! Chaos conformance: every failpoint site fired against a live
//! service under traffic.  The invariants under ANY injected fault:
//!
//! * no caller ever hangs (reply-on-drop turns worker death into a
//!   typed `Dropped`);
//! * no reply is lost or duplicated — every ticket resolves exactly
//!   once;
//! * faults surface as TYPED errors (`Exec(Backend)`, `Exec(NonFinite)`,
//!   `Overloaded`, `Rejected`), never as strings to parse or panics to
//!   catch;
//! * the metrics ledger reconciles (`requests = responses + failed +
//!   canceled + expired`, with `Dropped` as the counted-panic remainder);
//! * the service keeps serving after the fault clears — supervised
//!   respawn for dead/hung workers, poison recovery for the queue.
//!
//! The failpoint registry is process-global, so every test serializes
//! on one static mutex and starts from `failpoint::clear()`.
//!
//! `CHAOS_SMOKE=1` shrinks workloads for the fast verify gate.  The
//! `fixed_env_schedule_mixed_traffic` test self-skips unless a
//! `FAILPOINTS` schedule is set in the environment (see `make chaos`).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use gaunt_tp::coordinator::request::{
    Batch, EnergyForces, EnergyOnly, ExecFault, MdRollout, Request,
    ServiceError, Structure,
};
use gaunt_tp::coordinator::server::{NativeGauntBackend, ServerConfig};
use gaunt_tp::coordinator::{
    AdmissionConfig, BatchPolicy, BucketConfig, HealthState, RetryPolicy,
    Service, SupervisorConfig,
};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::net::{
    temp_socket_path, Addr, FrontDoor, FrontDoorConfig, NetClient, Replica,
};
use gaunt_tp::util::failpoint;
use gaunt_tp::util::rng::Rng;

// the failpoint registry is process-global: serialize every test so one
// test's armed sites never fire inside another's service
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    // a failed assertion poisons the lock; later tests must still run
    TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

fn smoke() -> bool {
    std::env::var("CHAOS_SMOKE").is_ok()
}

fn scaled(full: usize, smoke_n: usize) -> usize {
    if smoke() { smoke_n } else { full }
}

/// Jittered-grid cluster with valid species (0..3); spacing 3.5 keeps
/// the neighbor degree small enough for every bucket's edge budget.
fn cluster(n: usize, seed: u64) -> Structure {
    let mut rng = Rng::new(seed);
    Structure::new(
        (0..n)
            .map(|i| {
                [
                    3.5 * (i % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * ((i / 3) % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * (i / 9) as f64 + 0.1 * rng.normal(),
                ]
            })
            .collect(),
        (0..n).map(|i| i % 3).collect(),
    )
}

/// A supervisor tuned for test time scales: fast scans, fast respawn,
/// and a hang timeout short enough to trip on an injected delay.
fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        enabled: true,
        heartbeat_interval: Duration::from_millis(5),
        hang_timeout: Duration::from_millis(50),
        max_restarts: 8,
        backoff_base: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(100),
    }
}

fn chaos_service(n_workers: usize) -> Service {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                max_queue: 256,
            },
            n_workers,
            supervisor: fast_supervisor(),
            ..Default::default()
        })
        .build()
        .expect("chaos service must start")
}

/// Poll `cond` every 5ms until it holds or `deadline` passes.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

/// `requests = responses + failed + canceled + expired` — the ledger
/// every non-dropping test must close.
fn assert_reconciled(service: &Service) {
    let m = service.metrics();
    let requests = m.requests.load(Ordering::Relaxed);
    let accounted = m.responses.load(Ordering::Relaxed)
        + m.failed.load(Ordering::Relaxed)
        + m.canceled.load(Ordering::Relaxed)
        + m.expired.load(Ordering::Relaxed);
    assert_eq!(
        requests, accounted,
        "metrics ledger must reconcile: {}",
        m.report()
    );
}

// ---------------------------------------------------------------------
// backend faults: typed errors, quarantine, recovery
// ---------------------------------------------------------------------

#[test]
fn backend_error_fault_is_typed_and_clears_with_the_guard() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    {
        let _g = failpoint::scoped("backend.run", "error(injected backend chaos)");
        match client.call(Request::new(EnergyForces(cluster(4, 1)))) {
            Err(ServiceError::Exec(ExecFault::Backend(m))) => {
                assert!(m.contains("injected backend chaos"), "{m}")
            }
            other => panic!("expected Exec(Backend), got {other:?}"),
        }
        assert!(failpoint::hits("backend.run") >= 1);
    }
    // guard dropped: the very next request executes normally
    let ok = client
        .call(Request::new(EnergyForces(cluster(4, 2))))
        .expect("service must recover once the fault clears");
    assert!(ok.energy.is_finite());
    assert_eq!(service.metrics().failed.load(Ordering::Relaxed), 1);
    assert_reconciled(&service);
    service.shutdown();
}

#[test]
fn one_shot_nan_quarantines_one_row_and_batchmates_survive() {
    let _s = serial();
    failpoint::clear();
    // one worker + a 4-wide flush window so the submissions can share a
    // padded batch; the invariant below holds for ANY batch split
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
                max_queue: 64,
            },
            n_workers: 1,
            supervisor: fast_supervisor(),
            ..Default::default()
        })
        .build()
        .unwrap();
    let client = service.client();
    let _g = failpoint::scoped("backend.run", "one_shot:nan");
    let tickets: Vec<_> = (0..4)
        .map(|k| {
            client
                .submit(Request::new(EnergyForces(cluster(4, 10 + k))))
                .expect("admitted")
        })
        .collect();
    let mut quarantined = 0usize;
    let mut ok = 0usize;
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                assert!(r.energy.is_finite(), "surviving rows stay finite");
                ok += 1;
            }
            Err(ServiceError::Exec(ExecFault::NonFinite(m))) => {
                assert!(m.contains("quarantined"), "{m}");
                quarantined += 1;
            }
            other => panic!("expected Ok or NonFinite, got {other:?}"),
        }
    }
    assert_eq!(
        quarantined, 1,
        "the one_shot NaN poisons exactly one batch row"
    );
    assert_eq!(ok, 3, "batchmates of the poisoned row must keep their results");
    assert_reconciled(&service);
    service.shutdown();
}

// ---------------------------------------------------------------------
// long-task faults: rollout force provider
// ---------------------------------------------------------------------

#[test]
fn rollout_force_error_fault_is_typed_and_service_recovers() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    {
        let _g = failpoint::scoped(
            "svc.rollout.force",
            "one_shot:error(injected rollout fault)",
        );
        match client.call(Request::new(MdRollout {
            structure: cluster(4, 3),
            steps: 5,
            dt: 1e-3,
        })) {
            Err(ServiceError::Exec(ExecFault::Backend(m))) => {
                assert!(m.contains("injected rollout fault"), "{m}")
            }
            other => panic!("expected Exec(Backend), got {other:?}"),
        }
    }
    let traj = client
        .call(Request::new(MdRollout {
            structure: cluster(4, 4),
            steps: 3,
            dt: 1e-3,
        }))
        .expect("rollout must succeed after the fault clears");
    assert_eq!(traj.steps, 3);
    assert_reconciled(&service);
    service.shutdown();
}

#[test]
fn rollout_force_nan_is_contained_before_any_frame_streams() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    let _g = failpoint::scoped("svc.rollout.force", "one_shot:nan");
    match client.call(Request::new(MdRollout {
        structure: cluster(4, 5),
        steps: 8,
        dt: 1e-3,
    })) {
        Err(ServiceError::Exec(ExecFault::NonFinite(m))) => {
            assert!(m.contains("non-finite"), "{m}")
        }
        other => panic!("expected Exec(NonFinite), got {other:?}"),
    }
    // the poison hit the FIRST force evaluation: no frame was ever
    // streamed carrying a non-finite value
    assert_eq!(service.metrics().frames.load(Ordering::Relaxed), 0);
    assert_reconciled(&service);
    service.shutdown();
}

// ---------------------------------------------------------------------
// supervisor: dead-worker respawn, hang detection, poisoned queue
// ---------------------------------------------------------------------

#[test]
fn worker_death_by_panic_is_respawned_and_serving_resumes() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    let _g = failpoint::scoped("svc.worker.tick", "one_shot:panic");
    // the tick panic fires OUTSIDE the batch catch: the worker thread
    // dies, its batch unwinds through reply-on-drop
    match client.call(Request::new(EnergyForces(cluster(4, 6)))) {
        Err(ServiceError::Dropped(_)) => {}
        other => panic!("expected Dropped from the dying worker, got {other:?}"),
    }
    assert!(
        wait_until(Duration::from_secs(5), || {
            service.metrics().restarts.load(Ordering::Relaxed) >= 1
        }),
        "supervisor must respawn the dead worker: {}",
        service.metrics().report()
    );
    let ok = client
        .call(Request::new(EnergyForces(cluster(4, 7))))
        .expect("the respawned worker must serve");
    assert!(ok.energy.is_finite());
    service.shutdown();
}

#[test]
fn batcher_flush_panic_poisons_the_queue_and_service_recovers() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    let _g = failpoint::scoped("svc.batcher.flush", "one_shot:panic");
    // the panic fires INSIDE the bucket mutex scope: the worker dies,
    // the mutex is poisoned, and the drained batch drops its replies
    match client.call(Request::new(EnergyForces(cluster(4, 8)))) {
        Err(ServiceError::Dropped(_)) => {}
        other => panic!("expected Dropped from the flush panic, got {other:?}"),
    }
    assert!(
        wait_until(Duration::from_secs(5), || {
            service.metrics().restarts.load(Ordering::Relaxed) >= 1
        }),
        "supervisor must replace the dead worker: {}",
        service.metrics().report()
    );
    // poison recovery: pushes and flushes on the poisoned mutex keep
    // working, so the respawned worker serves normally
    let ok = client
        .call(Request::new(EnergyForces(cluster(4, 9))))
        .expect("the queue must survive its own poisoned mutex");
    assert!(ok.energy.is_finite());
    service.shutdown();
}

#[test]
fn hung_worker_is_detached_replaced_and_its_request_still_completes() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    // 400ms stall against a 50ms hang timeout: the supervisor declares
    // the worker hung and backfills the slot while the stalled worker
    // keeps exclusive ownership of its batch (replies stay exactly-once)
    let _g = failpoint::scoped("svc.worker.batch", "one_shot:delay(400)");
    let ticket = client
        .submit(Request::new(EnergyForces(cluster(4, 10))))
        .expect("admitted");
    assert!(
        wait_until(Duration::from_secs(5), || {
            service.metrics().hung_detected.load(Ordering::Relaxed) >= 1
        }),
        "supervisor must detect the stalled heartbeat: {}",
        service.metrics().report()
    );
    // the detached worker finishes its delayed batch: the reply arrives
    let ok = ticket.wait().expect("the stalled batch must still complete");
    assert!(ok.energy.is_finite());
    assert!(
        wait_until(Duration::from_secs(5), || {
            service.metrics().restarts.load(Ordering::Relaxed) >= 1
        }),
        "a replacement worker must be spawned: {}",
        service.metrics().report()
    );
    // and the replacement serves new traffic
    let ok2 = client
        .call(Request::new(EnergyForces(cluster(4, 11))))
        .expect("replacement worker must serve");
    assert!(ok2.energy.is_finite());
    assert_reconciled(&service);
    service.shutdown();
}

#[test]
fn cancel_landing_inside_an_injected_stall_is_typed_canceled() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    // the stall holds the batch between dequeue and the cancel check:
    // a cancel landing mid-stall must resolve as Canceled, not execute
    let _g = failpoint::scoped("svc.worker.batch", "one_shot:delay(100)");
    let ticket = client
        .submit(Request::new(EnergyForces(cluster(4, 12))))
        .expect("admitted");
    std::thread::sleep(Duration::from_millis(20));
    ticket.cancel();
    match ticket.wait() {
        Err(ServiceError::Canceled) => {}
        other => panic!("expected Canceled inside the stall, got {other:?}"),
    }
    assert_eq!(service.metrics().canceled.load(Ordering::Relaxed), 1);
    assert_reconciled(&service);
    service.shutdown();
}

// ---------------------------------------------------------------------
// registry faults
// ---------------------------------------------------------------------

#[test]
fn registry_resolve_fault_fails_named_requests_typed_then_recovers() {
    let _s = serial();
    failpoint::clear();
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let service = Service::builder()
        .model(Arc::new(Model::new(cfg, 3)))
        .config(ServerConfig {
            n_workers: 1,
            supervisor: fast_supervisor(),
            ..Default::default()
        })
        .build()
        .unwrap();
    let client = service.client();
    let st = cluster(4, 13);
    {
        let _g = failpoint::scoped("registry.resolve", "error");
        // submit-time validation uses `contains` (not resolve), so the
        // request is admitted; the WORKER's resolution fails and the
        // reply is a typed rejection naming the endpoint
        match client
            .call(Request::new(EnergyForces(st.clone())).model("default"))
        {
            Err(ServiceError::Rejected(m)) => {
                assert!(m.contains("unknown model endpoint"), "{m}")
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }
    let ok = client
        .call(Request::new(EnergyForces(st)).model("default"))
        .expect("resolution must recover with the guard");
    assert!(ok.energy.is_finite());
    assert_reconciled(&service);
    service.shutdown();
}

// ---------------------------------------------------------------------
// overload: typed shedding, retry, drain
// ---------------------------------------------------------------------

#[test]
fn overload_sheds_typed_overloaded_and_accepted_work_completes() {
    let _s = serial();
    failpoint::clear();
    let policy = BatchPolicy {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        max_queue: 8,
    };
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy,
            n_workers: 1,
            supervisor: fast_supervisor(),
            admission: AdmissionConfig {
                low_watermark: 0.25,
                high_watermark: 0.5,
                retry_after: Duration::from_millis(5),
            },
            buckets: Some(vec![BucketConfig {
                max_atoms: 32,
                max_edges: 256,
                policy,
            }]),
            ..Default::default()
        })
        .build()
        .unwrap();
    let client = service.client();
    // slow the pipe so the flood outruns the drain (~2x overload)
    let delay_guard = failpoint::scoped("svc.worker.batch", "delay(20)");
    let n = scaled(40, 12);
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for k in 0..n {
        match client.submit(Request::new(EnergyForces(cluster(4, 50 + k as u64))))
        {
            Ok(t) => tickets.push(t),
            Err(ServiceError::Overloaded { retry_after }) => {
                assert_eq!(retry_after, Duration::from_millis(5));
                if shed == 0 {
                    // at the moment of a shed the queue is at/over the
                    // watermark: the health probe must say so
                    assert_eq!(client.health(), HealthState::Shedding);
                }
                shed += 1;
            }
            Err(other) => panic!("expected Ok or Overloaded, got {other:?}"),
        }
    }
    assert!(shed >= 1, "a 2x overload against an 8-deep queue must shed");
    assert!(!tickets.is_empty(), "some work must be admitted");
    // every accepted ticket resolves Ok — shedding never corrupts
    // admitted work
    for t in tickets {
        let r = t.wait().expect("admitted work completes under overload");
        assert!(r.energy.is_finite());
    }
    let m = service.metrics();
    assert_eq!(
        m.shed.load(Ordering::Relaxed),
        shed as u64,
        "every Overloaded reply is counted as shed"
    );
    assert_eq!(
        m.rejected.load(Ordering::Relaxed),
        shed as u64,
        "sheds are the only rejections in this flood"
    );
    assert_reconciled(&service);
    // fault cleared: a retrying submit rides out any residual pressure
    drop(delay_guard);
    let ticket = client
        .submit_with_retry(
            Request::new(EnergyForces(cluster(4, 999))),
            RetryPolicy {
                max_attempts: 8,
                base: Duration::from_millis(5),
                cap: Duration::from_millis(100),
            },
        )
        .expect("retry must get through once the overload clears");
    assert!(ticket.wait().unwrap().energy.is_finite());
    assert_eq!(client.health(), HealthState::Healthy);
    service.shutdown();
}

#[test]
fn drain_refuses_new_work_while_queued_work_completes() {
    let _s = serial();
    failpoint::clear();
    let service = chaos_service(1);
    let client = service.client();
    let ticket = client
        .submit(Request::new(EnergyForces(cluster(4, 14))))
        .expect("admitted before drain");
    service.drain();
    assert_eq!(service.health(), HealthState::Draining);
    match client.submit(Request::new(EnergyForces(cluster(4, 15)))) {
        Err(ServiceError::Rejected(m)) => {
            assert!(m.contains("draining"), "{m}")
        }
        other => panic!("expected Rejected while draining, got {other:?}"),
    }
    // already-queued work still runs to completion
    let ok = ticket.wait().expect("queued work completes during drain");
    assert!(ok.energy.is_finite());
    assert_reconciled(&service);
    service.shutdown();
}

// ---------------------------------------------------------------------
// env-driven schedule (the `make chaos` second pass)
// ---------------------------------------------------------------------

#[test]
fn fixed_env_schedule_mixed_traffic() {
    let _s = serial();
    // this test exists to be run alone with a FAILPOINTS schedule, e.g.
    //   FAILPOINTS="svc.worker.batch=every_nth(3):delay(2);..." \
    //     cargo test --test chaos_conformance fixed_env_schedule
    // (see `make chaos`); without a schedule there is nothing to test
    if std::env::var("FAILPOINTS").is_err() {
        eprintln!("fixed_env_schedule_mixed_traffic: FAILPOINTS unset, skipping");
        return;
    }
    let service = chaos_service(2);
    let client = service.client();
    let n = scaled(60, 16);
    let mut ok = 0usize;
    let mut typed_failures = 0usize;
    for k in 0..n as u64 {
        // mixed traffic: every priority class under the env schedule
        let outcome = match k % 4 {
            0 => client
                .call(Request::new(EnergyOnly(cluster(4, 100 + k))))
                .map(|_| ()),
            1 => client
                .call(Request::new(EnergyForces(cluster(6, 200 + k))))
                .map(|_| ()),
            2 => client
                .call(Request::new(Batch(vec![
                    cluster(4, 300 + k),
                    cluster(5, 400 + k),
                ])))
                .map(|_| ()),
            _ => client
                .call(Request::new(MdRollout {
                    structure: cluster(4, 500 + k),
                    steps: 2,
                    dt: 1e-3,
                }))
                .map(|_| ()),
        };
        match outcome {
            Ok(()) => ok += 1,
            // every failure must be a typed error — a hang would stall
            // this loop and a panic would abort the test binary
            Err(
                ServiceError::Exec(_)
                | ServiceError::Overloaded { .. }
                | ServiceError::Rejected(_)
                | ServiceError::Dropped(_),
            ) => typed_failures += 1,
            Err(other) => panic!("unexpected error class: {other:?}"),
        }
    }
    assert_eq!(ok + typed_failures, n, "every request resolved exactly once");
    assert!(
        ok > 0,
        "a paced schedule must let most traffic through \
         (ok={ok} typed_failures={typed_failures})"
    );
    service.shutdown();
}

// ---------------------------------------------------------------------
// net failpoints: the wire path under chaos (DESIGN.md section 14)
// ---------------------------------------------------------------------

#[test]
fn torn_frame_is_a_typed_teardown_not_a_deadlock() {
    let _s = serial();
    failpoint::clear();
    let replica = Replica::serve(
        chaos_service(1),
        &[Addr::Unix(temp_socket_path("chaos-torn"))],
        "chaos-torn",
    )
    .expect("bind replica");
    let nc = NetClient::connect(&replica.bound()[0]).expect("connect");
    // the handshake is done, so both reader loops are parked inside a
    // frame read; tear the NEXT frame on whichever side reads first
    let _g = failpoint::scoped("net.read_frame", "one_shot:error(torn)");
    let outcome = nc
        .submit(Request::new(EnergyForces(cluster(6, 611))))
        .and_then(|t| t.wait());
    match outcome {
        // the reply may race ahead of the tear — a success is legal
        Ok(r) => assert!(r.energy.is_finite()),
        // replica-side tear: the severed connection surfaces as Dropped
        // (or Canceled if the cancel-all beat the worker); client-side
        // tear: protocol damage is its own typed class
        Err(
            ServiceError::Dropped(_)
            | ServiceError::Protocol(_)
            | ServiceError::Canceled,
        ) => {}
        Err(other) => panic!("torn frame must be typed, got {other:?}"),
    }
    assert!(failpoint::hits("net.read_frame") >= 1, "the tear must fire");
    // nothing orphaned: the replica's queue drains and its ledger closes
    let inproc = replica.client();
    assert!(
        wait_until(Duration::from_secs(10), || inproc.queue_depth() == 0),
        "torn connection must not strand queued work"
    );
    assert!(
        wait_until(Duration::from_secs(10), || {
            inproc.metrics().snapshot().reconciles()
        }),
        "ledger must reconcile after the tear: {:?}",
        inproc.metrics().snapshot()
    );
    // the replica keeps serving: a fresh connection works (one_shot
    // policies stay registered but spent)
    let nc2 = NetClient::connect(&replica.bound()[0]).expect("reconnect");
    nc2.submit(Request::new(EnergyForces(cluster(5, 612))))
        .expect("submit after tear")
        .wait()
        .expect("replica must keep serving after a torn connection");
    nc2.close();
    replica.shutdown();
}

#[test]
fn replica_crash_failpoint_is_routed_around_by_the_front_door() {
    let _s = serial();
    failpoint::clear();
    let r0 = Replica::serve(
        chaos_service(1),
        &[Addr::Unix(temp_socket_path("chaos-crash-r0"))],
        "chaos-r0",
    )
    .expect("bind r0");
    let r1 = Replica::serve(
        chaos_service(1),
        &[Addr::Unix(temp_socket_path("chaos-crash-r1"))],
        "chaos-r1",
    )
    .expect("bind r1");
    let fd = FrontDoor::serve(
        &[r0.bound()[0].clone(), r1.bound()[0].clone()],
        &[Addr::Unix(temp_socket_path("chaos-crash-fd"))],
        FrontDoorConfig {
            probe_interval: Duration::from_millis(20),
            ..Default::default()
        },
    )
    .expect("front door up");
    let nc = NetClient::connect(&fd.bound()[0]).expect("connect fd");
    nc.submit(Request::new(EnergyForces(cluster(6, 613))))
        .expect("warmup submit")
        .wait()
        .expect("warmup reply");

    // arm AFTER the cluster is live: the site sits in the replica's
    // Submit arm, so health probes never trip it — only routed work
    {
        let _g = failpoint::scoped(
            "net.replica.crash",
            "one_shot:error(injected replica crash)",
        );
        let r = nc
            .submit(Request::new(EnergyForces(cluster(7, 614))))
            .expect("submit through fd")
            .wait()
            .expect("front door must reroute around the crashed replica");
        assert!(r.energy.is_finite());
        assert!(failpoint::hits("net.replica.crash") >= 1);
    }
    // same invariant through the panic path: the handler thread dies
    // unwinding, catch_unwind tears the connection down, routing moves
    {
        let _g = failpoint::scoped("net.replica.crash", "one_shot:panic");
        nc.submit(Request::new(EnergyForces(cluster(6, 615))))
            .expect("submit through fd")
            .wait()
            .expect("reroute must also survive a panicking handler");
    }
    // the crashed connections healed (the replicas never died, only
    // their conns) and the fleet keeps taking traffic
    for k in 0..scaled(6, 3) as u64 {
        nc.submit(Request::new(EnergyForces(cluster(5, 700 + k))))
            .expect("steady-state submit")
            .wait()
            .expect("steady-state reply");
    }
    let stats = nc.stats(Duration::from_secs(5)).expect("fleet stats");
    assert!(stats.reconciles(), "fleet ledger must reconcile: {stats:?}");
    nc.close();
    fd.shutdown();
    r0.shutdown();
    r1.shutdown();
}
