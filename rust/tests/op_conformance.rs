//! Generic conformance harness for EVERY [`EquivariantOp`] impl: one
//! test drives the full contract over a representative key of each of
//! the five plan families, resolved uniformly through
//! [`PlanCache::op`]:
//!
//! 1. **Legacy agreement** — `apply_into` through the trait equals the
//!    family's historical typed apply on random inputs.
//! 2. **Equivariance** — rotating every input (features by the real
//!    Wigner blocks of their `Irreps`, directions by the rotation
//!    itself) rotates the output by its block.
//! 3. **Zero steady-state allocations** — a counting global allocator
//!    (installed for THIS binary only) proves `apply_into` AND
//!    `vjp_into` allocate nothing once the scratch is warm.
//! 4. **Exact VJPs** — `vjp_into` against central finite differences of
//!    `<g, op(x)>`.
//!
//! `CONFORMANCE_SMOKE=1` (set by `scripts/verify.sh`) shrinks the key
//! set and probe counts to a fast liveness pass.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self, ptr: *mut u8, layout: Layout, new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use std::sync::Mutex;

/// The test runner executes `#[test]`s concurrently; the allocation
/// window below must not see another test's traffic.
static SERIAL: Mutex<()> = Mutex::new(());

use gaunt_tp::so3::linalg::matvec;
use gaunt_tp::so3::rotation::{wigner_d_real_block, Rot3};
use gaunt_tp::tp::engine::{OpKey, PlanCache};
use gaunt_tp::tp::op::{EquivariantOp, Inputs};
use gaunt_tp::tp::ConvMethod;
use gaunt_tp::util::prop::max_abs_diff;
use gaunt_tp::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("CONFORMANCE_SMOKE").map_or(false, |v| v == "1")
}

fn keys() -> Vec<OpKey> {
    if smoke() {
        vec![
            OpKey::Gaunt { l1: 2, l2: 2, l3: 2, method: ConvMethod::Direct },
            OpKey::GauntConv { l_in: 2, l_filter: 2, l_out: 2 },
            OpKey::GauntF32 { l1: 2, l2: 2, l3: 2 },
        ]
    } else {
        vec![
            OpKey::Cg { l1: 2, l2: 2, l3: 2 },
            OpKey::Cg { l1: 1, l2: 2, l3: 3 },
            OpKey::Gaunt { l1: 2, l2: 2, l3: 3, method: ConvMethod::Direct },
            OpKey::Gaunt { l1: 3, l2: 2, l3: 4, method: ConvMethod::Fft },
            OpKey::Gaunt { l1: 2, l2: 2, l3: 2, method: ConvMethod::Auto },
            OpKey::GauntF32 { l1: 2, l2: 2, l3: 3 },
            OpKey::GauntF32 { l1: 3, l2: 2, l3: 4 },
            OpKey::Escn { l_in: 2, l_filter: 2, l_out: 2 },
            OpKey::Escn { l_in: 1, l_filter: 2, l_out: 3 },
            OpKey::GauntConv { l_in: 2, l_filter: 2, l_out: 3 },
            OpKey::GauntConv { l_in: 3, l_filter: 1, l_out: 2 },
            OpKey::ManyBody { nu: 2, l: 2, l_out: 2 },
            OpKey::ManyBody { nu: 3, l: 2, l_out: 3 },
        ]
    }
}

/// Per-key numeric tiers: (legacy-agreement, equivariance) tolerances.
/// f64 families are held to near-machine agreement; the f32 serving
/// tier gets single-precision bounds (documented in DESIGN.md §11).
fn tolerances(key: &OpKey) -> (f64, f64) {
    match key {
        OpKey::GauntF32 { .. } => (1e-10, 5e-4),
        _ => (1e-10, 1e-8),
    }
}

/// Random inputs shaped by the op's own layout metadata.
struct Operands {
    x1: Vec<f64>,
    x2: Option<Vec<f64>>,
    dir: Option<[f64; 3]>,
    weights: Option<Vec<f64>>,
}

impl Operands {
    fn random(op: &dyn EquivariantOp, rng: &mut Rng) -> Operands {
        Operands {
            x1: rng.normals(op.irreps_in().dim()),
            x2: op.irreps_in2().map(|ir| rng.normals(ir.dim())),
            dir: op.needs_dir().then(|| rng.unit3()),
            weights: (op.n_weights() > 0)
                .then(|| rng.normals(op.n_weights())),
        }
    }

    fn inputs(&self) -> Inputs<'_> {
        Inputs {
            x1: &self.x1,
            x2: self.x2.as_deref(),
            dir: self.dir,
            weights: self.weights.as_deref(),
        }
    }
}

/// The family's historical typed apply — the oracle the trait path must
/// reproduce exactly.
fn legacy_apply(key: &OpKey, ops: &Operands) -> Vec<f64> {
    let cache = PlanCache::global();
    match *key {
        OpKey::Cg { l1, l2, l3 } => cache
            .cg(l1, l2, l3)
            .apply_sparse(&ops.x1, ops.x2.as_ref().unwrap()),
        OpKey::Gaunt { l1, l2, l3, method } => cache
            .gaunt(l1, l2, l3, method)
            .apply(&ops.x1, ops.x2.as_ref().unwrap()),
        OpKey::GauntF32 { l1, l2, l3 } => cache
            .gaunt_f32(l1, l2, l3)
            .apply(&ops.x1, ops.x2.as_ref().unwrap()),
        OpKey::Escn { l_in, l_filter, l_out } => {
            cache.escn(l_in, l_filter, l_out).apply(
                &ops.x1,
                ops.dir.unwrap(),
                ops.weights.as_ref().unwrap(),
            )
        }
        OpKey::GauntConv { l_in, l_filter, l_out } => {
            cache.gaunt_conv(l_in, l_filter, l_out).apply(
                &ops.x1,
                ops.dir.unwrap(),
                ops.weights.as_ref().unwrap(),
            )
        }
        OpKey::ManyBody { nu, l, l_out } => {
            cache.many_body(nu, l, l_out).apply_self(&ops.x1)
        }
    }
}

/// Rotate a single-channel spherical feature by the block Wigner-D.
fn rotate_feature(x: &[f64], l_max: usize, rot: &Rot3) -> Vec<f64> {
    let d = wigner_d_real_block(l_max, rot);
    matvec(&d, x, x.len(), x.len())
}

#[test]
fn every_equivariant_op_satisfies_the_contract() {
    let _guard = SERIAL.lock().unwrap();
    let cache = PlanCache::global();
    let mut rng = Rng::new(42);
    let fd_probes = if smoke() { 4 } else { 12 };
    let equi_cases = if smoke() { 1 } else { 3 };
    for key in keys() {
        let op = cache.op(&key);
        let op = op.as_ref();
        assert_eq!(op.key(), key);
        let n_out = op.irreps_out().dim();
        let l_in = op.irreps_in().l_max();
        let l_out = op.irreps_out().l_max();
        let ops = Operands::random(op, &mut rng);
        let mut scratch = op.scratch();
        let mut out = vec![0.0; n_out];
        let (legacy_tol, equi_tol) = tolerances(&key);

        // 1. agreement with the legacy typed apply
        op.apply_into(ops.inputs(), &mut scratch, &mut out);
        let want = legacy_apply(&key, &ops);
        assert!(
            max_abs_diff(&out, &want) < legacy_tol,
            "{key:?}: trait apply diverges from legacy ({})",
            max_abs_diff(&out, &want)
        );

        // 2. equivariance under random rotations
        for _ in 0..equi_cases {
            let rot = Rot3::random(&mut rng);
            let rotated = Operands {
                x1: rotate_feature(&ops.x1, l_in, &rot),
                x2: ops.x2.as_ref().map(|x2| {
                    rotate_feature(
                        x2, op.irreps_in2().unwrap().l_max(), &rot,
                    )
                }),
                dir: ops.dir.map(|d| rot.apply(d)),
                weights: ops.weights.clone(),
            };
            let mut out_rot = vec![0.0; n_out];
            op.apply_into(rotated.inputs(), &mut scratch, &mut out_rot);
            let want_rot = rotate_feature(&out, l_out, &rot);
            assert!(
                max_abs_diff(&out_rot, &want_rot) < equi_tol,
                "{key:?}: equivariance violated ({})",
                max_abs_diff(&out_rot, &want_rot)
            );
        }

        // 3. zero steady-state allocations for apply AND vjp (the first
        // calls above warmed the scratch, shared FFT tables, Wigner fit
        // caches, and the cached VJP sibling plans)
        let g = rng.normals(n_out);
        let mut grad = vec![0.0; op.irreps_in().dim()];
        op.vjp_into(ops.inputs(), &g, &mut scratch, &mut grad);
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..8 {
            op.apply_into(ops.inputs(), &mut scratch, &mut out);
            op.vjp_into(ops.inputs(), &g, &mut scratch, &mut grad);
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{key:?}: {delta} allocations in 8 steady-state \
             apply_into+vjp_into rounds (expected 0)"
        );

        // 4. VJP correctness.  The f32 tier's finite differences would
        // drown in single-precision forward noise (output rounding
        // ~1e-7 against h=1e-6), so its gradient is checked against the
        // exact f64 sibling plan's VJP instead of FD.
        if let OpKey::GauntF32 { l1, l2, l3 } = key {
            let p64 = cache.gaunt(l1, l2, l3, ConvMethod::Auto);
            let mut s64 = EquivariantOp::scratch(p64.as_ref());
            let mut grad64 = vec![0.0; op.irreps_in().dim()];
            p64.vjp_into(ops.inputs(), &g, &mut s64, &mut grad64);
            let scale = grad64
                .iter()
                .fold(1.0f64, |a, v| a.max(v.abs()));
            assert!(
                max_abs_diff(&grad, &grad64) < 1e-3 * scale,
                "{key:?}: f32 vjp strays {} from the f64 gradient",
                max_abs_diff(&grad, &grad64)
            );
            continue;
        }
        let h = 1e-6;
        let n1 = ops.x1.len();
        let mut x = ops.x1.clone();
        for probe in 0..fd_probes.min(n1) {
            // spread probes across the components deterministically
            let i = (probe * n1) / fd_probes.min(n1);
            let x0 = x[i];
            x[i] = x0 + h;
            op.apply_into(
                Inputs { x1: &x, ..ops.inputs() }, &mut scratch, &mut out,
            );
            let fp: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0 - h;
            op.apply_into(
                Inputs { x1: &x, ..ops.inputs() }, &mut scratch, &mut out,
            );
            let fm: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0;
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "{key:?}: vjp[{i}] = {} but fd = {fd}", grad[i]
            );
        }
    }
}

/// The batch driver refuses nothing the per-row path accepts: spot-check
/// that uniform dispatch through `op()` + the generic driver reproduces
/// the per-row trait applies for a mixed key set (the coordinator's
/// dispatch pattern).
#[test]
fn uniform_dispatch_matches_per_row_applies() {
    let _guard = SERIAL.lock().unwrap();
    use gaunt_tp::tp::op::{apply_batch_par, BatchInputs};
    let cache = PlanCache::global();
    let mut rng = Rng::new(7);
    let rows = 6usize;
    for key in [
        OpKey::Gaunt { l1: 2, l2: 2, l3: 2, method: ConvMethod::Auto },
        OpKey::Escn { l_in: 2, l_filter: 2, l_out: 2 },
    ] {
        let op = cache.op(&key);
        let n1 = op.irreps_in().dim();
        let n_out = op.irreps_out().dim();
        let x1 = rng.normals(rows * n1);
        let x2 = op.irreps_in2().map(|ir| rng.normals(rows * ir.dim()));
        let dirs: Vec<[f64; 3]> = (0..rows).map(|_| rng.unit3()).collect();
        let weights = (op.n_weights() > 0)
            .then(|| rng.normals(op.n_weights()));
        let batch = BatchInputs {
            x1: &x1,
            x2: x2.as_deref(),
            dirs: op.needs_dir().then_some(&dirs[..]),
            weights: weights.as_deref(),
        };
        let got = apply_batch_par(op.as_ref(), &batch, rows, 0);
        let mut scratch = op.scratch();
        let n2 = op.irreps_in2().map(|ir| ir.dim()).unwrap_or(0);
        for r in 0..rows {
            let mut row = vec![0.0; n_out];
            op.apply_into(
                Inputs {
                    x1: &x1[r * n1..(r + 1) * n1],
                    x2: x2.as_ref().map(|v| &v[r * n2..(r + 1) * n2]),
                    dir: op.needs_dir().then(|| dirs[r]),
                    weights: weights.as_deref(),
                },
                &mut scratch,
                &mut row,
            );
            assert!(
                max_abs_diff(&row, &got[r * n_out..(r + 1) * n_out]) == 0.0,
                "{key:?}: row {r} diverged"
            );
        }
    }
}

/// Improper rotations (inversion composed with a proper rotation) catch
/// the parity signs the rotation-only equivariance block cannot see:
/// every scalar-signal op must transform degree-l blocks with an extra
/// `det^l` (functions on the sphere: `Y_lm(-u) = (-1)^l Y_lm(u)`), with
/// directions mapped by the full orthogonal matrix.
#[test]
fn every_op_transforms_correctly_under_improper_rotations() {
    let _guard = SERIAL.lock().unwrap();
    use gaunt_tp::tp::vector::transform_scalar;
    let cache = PlanCache::global();
    let mut rng = Rng::new(2026);
    for key in keys() {
        // The CG full tensor product keeps BOTH parities of coupling
        // path: an (l1, l2) -> l pair with l1 + l2 + l odd (e.g. the
        // antisymmetric 2 (x) 2 -> 1) transforms with det^(l1+l2), not
        // det^l, so the scalar-signal parity law does not apply — CG is
        // an SO(3) op.  Every pointwise-product family (Gaunt, eSCN,
        // many-body) is a function on the sphere and IS O(3)-covariant.
        if matches!(key, OpKey::Cg { .. }) {
            continue;
        }
        let op = cache.op(&key);
        let op = op.as_ref();
        let n_out = op.irreps_out().dim();
        let l_in = op.irreps_in().l_max();
        let l_out = op.irreps_out().l_max();
        let ops = Operands::random(op, &mut rng);
        let mut scratch = op.scratch();
        let mut out = vec![0.0; n_out];
        op.apply_into(ops.inputs(), &mut scratch, &mut out);
        let (_, equi_tol) = tolerances(&key);
        let r = Rot3::random(&mut rng);
        // compose with inversion: det(o) = -1
        let o = Rot3([
            [-r.0[0][0], -r.0[0][1], -r.0[0][2]],
            [-r.0[1][0], -r.0[1][1], -r.0[1][2]],
            [-r.0[2][0], -r.0[2][1], -r.0[2][2]],
        ]);
        let transformed = Operands {
            x1: transform_scalar(&ops.x1, l_in, &o),
            x2: ops.x2.as_ref().map(|x2| {
                transform_scalar(
                    x2, op.irreps_in2().unwrap().l_max(), &o,
                )
            }),
            dir: ops.dir.map(|d| o.apply(d)),
            weights: ops.weights.clone(),
        };
        let mut out_t = vec![0.0; n_out];
        op.apply_into(transformed.inputs(), &mut scratch, &mut out_t);
        let want = transform_scalar(&out, l_out, &o);
        assert!(
            max_abs_diff(&out_t, &want) < equi_tol,
            "{key:?}: improper-rotation parity violated ({})",
            max_abs_diff(&out_t, &want)
        );
    }
}

/// The vector plan family under the same four-part contract, with its
/// OWN transformation laws: the generic `rotate_feature` block-D is
/// wrong for the `spherical(3, L)` component-major vector layout, so
/// equivariance here uses the typed `transform_scalar`/`transform_vector`
/// helpers (polar inputs, polar or pseudo outputs per kind), under both
/// proper and improper orthogonal maps.
#[test]
fn vector_ops_satisfy_the_contract() {
    let _guard = SERIAL.lock().unwrap();
    use gaunt_tp::tp::vector::{
        transform_scalar, transform_vector, VectorKind,
    };
    let cache = PlanCache::global();
    let mut rng = Rng::new(314);
    let triples: Vec<(VectorKind, usize, usize, usize, ConvMethod)> =
        if smoke() {
            vec![(VectorKind::ScalarVector, 2, 1, 2, ConvMethod::Direct)]
        } else {
            vec![
                (VectorKind::ScalarVector, 2, 1, 2, ConvMethod::Direct),
                (VectorKind::ScalarVector, 2, 2, 3, ConvMethod::Fft),
                (VectorKind::VectorDot, 2, 2, 2, ConvMethod::Direct),
                (VectorKind::VectorDot, 2, 1, 3, ConvMethod::Fft),
                (VectorKind::VectorCross, 1, 1, 1, ConvMethod::Direct),
                (VectorKind::VectorCross, 2, 1, 2, ConvMethod::Fft),
            ]
        };
    let fd_probes = if smoke() { 4 } else { 10 };
    for (kind, l1, l2, l3, method) in triples {
        let key = OpKey::Vector { kind, l1, l2, l3, method };
        let op = cache.op(&key);
        let op = op.as_ref();
        assert_eq!(op.key(), key);
        let n_out = op.irreps_out().dim();
        let ops = Operands::random(op, &mut rng);
        let mut scratch = op.scratch();
        let mut out = vec![0.0; n_out];

        // 1. trait apply equals the typed plan apply
        op.apply_into(ops.inputs(), &mut scratch, &mut out);
        let want = cache
            .vector(kind, l1, l2, l3, method)
            .apply(&ops.x1, ops.x2.as_ref().unwrap());
        assert!(
            max_abs_diff(&out, &want) < 1e-12,
            "{key:?}: trait apply diverges from typed apply"
        );

        // 2. equivariance under proper AND improper orthogonal maps,
        // with the kind's parity typing
        let x2 = ops.x2.as_ref().unwrap();
        for improper in [false, true] {
            let r = Rot3::random(&mut rng);
            let o = if improper {
                Rot3([
                    [-r.0[0][0], -r.0[0][1], -r.0[0][2]],
                    [-r.0[1][0], -r.0[1][1], -r.0[1][2]],
                    [-r.0[2][0], -r.0[2][1], -r.0[2][2]],
                ])
            } else {
                r
            };
            let (tx1, tx2, tout) = match kind {
                VectorKind::ScalarVector => (
                    transform_scalar(&ops.x1, l1, &o),
                    transform_vector(x2, l2, &o, false),
                    transform_vector(&out, l3, &o, false),
                ),
                VectorKind::VectorDot => (
                    transform_vector(&ops.x1, l1, &o, false),
                    transform_vector(x2, l2, &o, false),
                    transform_scalar(&out, l3, &o),
                ),
                VectorKind::VectorCross => (
                    transform_vector(&ops.x1, l1, &o, false),
                    transform_vector(x2, l2, &o, false),
                    transform_vector(&out, l3, &o, true),
                ),
            };
            let mut out_t = vec![0.0; n_out];
            op.apply_into(
                Inputs { x1: &tx1, x2: Some(&tx2), ..ops.inputs() },
                &mut scratch,
                &mut out_t,
            );
            assert!(
                max_abs_diff(&out_t, &tout) < 1e-8,
                "{key:?} improper={improper}: equivariance violated ({})",
                max_abs_diff(&out_t, &tout)
            );
        }

        // 3. zero steady-state allocations (warm the lazy VJP sibling
        // first)
        let g = rng.normals(n_out);
        let mut grad = vec![0.0; op.irreps_in().dim()];
        op.vjp_into(ops.inputs(), &g, &mut scratch, &mut grad);
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..8 {
            op.apply_into(ops.inputs(), &mut scratch, &mut out);
            op.vjp_into(ops.inputs(), &g, &mut scratch, &mut grad);
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta, 0,
            "{key:?}: {delta} allocations in 8 steady-state rounds"
        );

        // 4. VJP vs central finite differences on x1
        let h = 1e-6;
        let n1 = ops.x1.len();
        let mut x = ops.x1.clone();
        for probe in 0..fd_probes.min(n1) {
            let i = (probe * n1) / fd_probes.min(n1);
            let x0 = x[i];
            x[i] = x0 + h;
            op.apply_into(
                Inputs { x1: &x, ..ops.inputs() }, &mut scratch, &mut out,
            );
            let fp: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0 - h;
            op.apply_into(
                Inputs { x1: &x, ..ops.inputs() }, &mut scratch, &mut out,
            );
            let fm: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0;
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "{key:?}: vjp[{i}] = {} but fd = {fd}", grad[i]
            );
        }
    }
}
