//! Allocation regression tests for the fused Gaunt hot path.
//!
//! A counting global allocator (installed for THIS test binary only)
//! proves the plan-layer claim directly: once a [`GauntScratch`] exists,
//! `GauntPlan::apply_into` performs ZERO allocations — for the direct
//! and the planned-FFT convolution backends alike — and
//! `GauntPlan::apply_batch` allocates O(1) (output + scratch), not
//! O(rows).
//!
//! Each assertion brackets its measurement window with two counter
//! reads; the tests serialize on a shared lock so one test's allocations
//! never land in another's window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self, ptr: *mut u8, layout: Layout, new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use std::sync::Mutex;

use gaunt_tp::md::{Cell, PeriodicPotential, Potential, PotentialKind,
                   VerletList};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::num_coeffs;
use gaunt_tp::tp::{ConvMethod, GauntConvPlan, GauntPlan, ManyBodyPlan};
use gaunt_tp::util::rng::Rng;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// The test runner executes `#[test]`s concurrently; both tests below
/// read the global counter, so they serialize on this lock to keep each
/// other's allocations out of their measurement windows.
static SERIAL: Mutex<()> = Mutex::new(());

/// All steady-state assertions in ONE test: the suite runs tests on
/// multiple threads, and any concurrent test's allocations would show up
/// in our counter window.
#[test]
fn gaunt_hot_path_steady_state_is_allocation_free() {
    let _guard = SERIAL.lock().unwrap();
    let mut rng = Rng::new(0);

    for (l, method) in [
        (2usize, ConvMethod::Direct),
        (4, ConvMethod::Fft),
        (6, ConvMethod::Auto), // resolves to FFT above the crossover
    ] {
        let n = num_coeffs(l);
        let plan = GauntPlan::new(l, l, l, method);
        let x1 = rng.normals(n);
        let x2 = rng.normals(n);
        let mut out = vec![0.0; n];
        let mut scratch = plan.scratch();
        // warm once: shared FFT tables for this size are built on first
        // use; after this the path must be quiet
        plan.apply_into(&x1, &x2, &mut out, &mut scratch);
        let before = allocs();
        for _ in 0..16 {
            plan.apply_into(&x1, &x2, &mut out, &mut scratch);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "L={l} {method:?}: {delta} allocations in 16 steady-state \
             apply_into calls (expected 0)"
        );
    }

    // aligned-frame Gaunt convolution: direct sweep and cached-spectrum
    // FFT paths over one scratch
    {
        let (li, lf, lo) = (3usize, 2usize, 3usize);
        let plan = GauntConvPlan::new(li, lf, lo);
        let x = rng.normals(num_coeffs(li));
        let h2: Vec<f64> = (0..=lf).map(|_| 1.0).collect();
        let mut out = vec![0.0; num_coeffs(lo)];
        let mut scratch = plan.scratch();
        plan.apply_aligned_direct_into(&x, &h2, &mut out, &mut scratch);
        plan.apply_aligned_fft_into(&x, &h2, &mut out, &mut scratch);
        let before = allocs();
        for _ in 0..8 {
            plan.apply_aligned_direct_into(&x, &h2, &mut out, &mut scratch);
            plan.apply_aligned_fft_into(&x, &h2, &mut out, &mut scratch);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "gaunt-conv aligned paths: {delta} steady-state allocations"
        );
    }

    // vector-signal plans: every kind on both backends, forward AND the
    // degree-rotated sibling VJP, over one caller-owned scratch each
    {
        use gaunt_tp::tp::{VectorGauntPlan, VectorKind};
        for (kind, l1, l2, l3) in [
            (VectorKind::ScalarVector, 2usize, 1usize, 2usize),
            (VectorKind::VectorDot, 2, 2, 2),
            (VectorKind::VectorCross, 2, 1, 2),
        ] {
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = VectorGauntPlan::new(kind, l1, l2, l3, method);
                let (d1, d2, d3) = plan.dims();
                let x1 = rng.normals(d1);
                let x2 = rng.normals(d2);
                let g = rng.normals(d3);
                let mut out = vec![0.0; d3];
                let mut grad = vec![0.0; d1];
                let mut scratch = plan.scratch();
                // the VJP runs through the sibling plan directly: the
                // operand order is the sibling's forward order
                let (sk, s1, s2, s3) = plan.vjp_sibling_key();
                let sib = VectorGauntPlan::new(sk, s1, s2, s3, method);
                let mut sib_scratch = sib.scratch();
                let (a, b): (&[f64], &[f64]) =
                    if plan.vjp_operands_swapped() {
                        (&x2, &g)
                    } else {
                        (&g, &x2)
                    };
                // warm once (shared FFT tables)
                plan.apply_into(&x1, &x2, &mut out, &mut scratch);
                sib.apply_into(a, b, &mut grad, &mut sib_scratch);
                let before = allocs();
                for _ in 0..8 {
                    plan.apply_into(&x1, &x2, &mut out, &mut scratch);
                    sib.apply_into(a, b, &mut grad, &mut sib_scratch);
                }
                let delta = allocs() - before;
                assert_eq!(
                    delta, 0,
                    "vector {kind:?} ({l1},{l2},{l3}) {method:?}: {delta} \
                     allocations in 8 steady-state apply+vjp rounds \
                     (expected 0)"
                );
            }
        }
    }

    // many-body planned pipeline (chain + self-product)
    {
        let (nu, l, lo) = (3usize, 2usize, 3usize);
        let plan = ManyBodyPlan::new(nu, l, lo);
        let xs: Vec<Vec<f64>> =
            (0..nu).map(|_| rng.normals(num_coeffs(l))).collect();
        let mut out = vec![0.0; num_coeffs(lo)];
        let mut scratch = plan.scratch();
        plan.apply_into(&xs, &mut out, &mut scratch);
        plan.apply_self_into(&xs[0], &mut out, &mut scratch);
        let before = allocs();
        for _ in 0..8 {
            plan.apply_into(&xs, &mut out, &mut scratch);
            plan.apply_self_into(&xs[0], &mut out, &mut scratch);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "many-body planned pipeline: {delta} steady-state allocations"
        );
    }
}

/// The FULL model inference path — edge embedding, aligned-filter Gaunt
/// conv (with its Wigner rotation round trip), many-body update,
/// readout, AND the complete force backward pass — must be
/// allocation-free per call once warm, for both conv backends.  This is
/// the serving-path claim: `pool::shard_rows_with` gives each worker one
/// [`ModelScratch`], so steady-state batched inference allocates
/// nothing per graph.
#[test]
fn model_forward_and_forces_steady_state_are_allocation_free() {
    let _guard = SERIAL.lock().unwrap();
    let mut rng = Rng::new(7);
    let n_atoms = 6;
    let pos: Vec<[f64; 3]> = (0..n_atoms)
        .map(|_| [1.5 * rng.normal(), 1.5 * rng.normal(),
                  1.5 * rng.normal()])
        .collect();
    let species: Vec<usize> = (0..n_atoms).map(|_| rng.below(3)).collect();
    // channels > 1 exercises the per-channel gather/scatter staging of
    // the Irreps layout — it must stay as quiet as the mul = 1 path
    for (method, channels) in [
        (ConvMethod::Direct, 1usize),
        (ConvMethod::Fft, 1),
        (ConvMethod::Direct, 2),
        (ConvMethod::Fft, 2),
    ] {
        let model = Model::new(
            ModelConfig { method, channels, nu: 3, ..Default::default() },
            1);
        let edges = model.build_edges(&pos);
        assert!(!edges.is_empty(), "toy structure has no edges");
        let mut scratch = model.scratch();
        let mut forces = vec![0.0; 3 * n_atoms];
        // warm once: shared FFT tables and per-degree Wigner fit caches
        // are built lazily on first use
        let e = model.energy_forces_into(&pos, &species, &edges,
                                         &mut forces, &mut scratch);
        assert!(e.is_finite());
        let before = allocs();
        for _ in 0..8 {
            let _ = model.energy_into(&pos, &species, &edges, &mut scratch);
            let _ = model.energy_forces_into(&pos, &species, &edges,
                                             &mut forces, &mut scratch);
        }
        let delta = allocs() - before;
        assert_eq!(
            delta, 0,
            "{method:?} C={channels}: {delta} allocations in 8 \
             steady-state model energy+forces calls (expected 0)"
        );
    }
}

/// The periodic MD hot path: once the Verlet list and force buffer have
/// reached their high-water capacity, a reuse step (`update` returning
/// false — every atom within skin/2 of the reference build) performs
/// ZERO allocations through the full classical energy+forces
/// evaluation, and even a REBUILD step stays quiet because the
/// linked-cell scratch, edge vector, and reference positions are all
/// retained at capacity.
#[test]
fn verlet_reuse_steps_are_allocation_free() {
    let _guard = SERIAL.lock().unwrap();
    let mut rng = Rng::new(3);
    let cell = Cell::cubic(9.0);
    let pot = Potential::lj(1.0, 1.0, 2.5);
    let n = 60;
    let mut pos: Vec<[f64; 3]> = (0..n)
        .map(|_| [rng.uniform(0.0, 9.0), rng.uniform(0.0, 9.0),
                  rng.uniform(0.0, 9.0)])
        .collect();
    let species = vec![0usize; n];
    let mut list = VerletList::periodic(cell, 2.5, 0.6);
    let mut forces = Vec::new();
    // warm: first call builds the list and sizes every buffer
    let e = pot.energy_forces_with_list(&pos, &species, &mut list,
                                        &mut forces);
    assert!(e.is_finite());
    assert_eq!(list.rebuilds, 1);

    // pure reuse steps: positions drift by well under skin/2
    let before = allocs();
    for step in 0..8 {
        for p in pos.iter_mut() {
            p[0] += 0.01;
        }
        let e = pot.energy_forces_with_list(&pos, &species, &mut list,
                                            &mut forces);
        assert!(e.is_finite(), "step {step}");
    }
    let delta = allocs() - before;
    assert_eq!(list.reuses, 8, "drift exceeded the skin — bad test setup");
    assert_eq!(
        delta, 0,
        "{delta} allocations in 8 Verlet-reuse energy+forces steps \
         (expected 0)"
    );

    // rebuild steps reuse retained capacity: move past skin/2 so every
    // update rebuilds; after one capacity-settling rebuild the counter
    // must stay flat (edge count only shrinks or holds under uniform
    // translation, so no buffer can outgrow its high-water mark)
    for p in pos.iter_mut() {
        p[1] += 0.4;
    }
    let _ = pot.energy_forces_with_list(&pos, &species, &mut list,
                                        &mut forces);
    let rebuilds_before = list.rebuilds;
    let before = allocs();
    for _ in 0..4 {
        for p in pos.iter_mut() {
            p[1] += 0.4;
        }
        let _ = pot.energy_forces_with_list(&pos, &species, &mut list,
                                            &mut forces);
    }
    let delta = allocs() - before;
    assert_eq!(list.rebuilds, rebuilds_before + 4);
    assert_eq!(
        delta, 0,
        "{delta} allocations in 4 Verlet-rebuild steps over retained \
         buffers (expected 0)"
    );
}

/// Same gate for a BONDED system through [`PeriodicPotential`]: the
/// bonded-exclusion set is captured at construction, so reuse steps
/// stay allocation-free even with `exclude_bonded_nonbonded` on (the
/// per-call sort/dedup rebuild would otherwise allocate every step).
#[test]
fn periodic_potential_bonded_reuse_steps_are_allocation_free() {
    let _guard = SERIAL.lock().unwrap();
    let mut rng = Rng::new(9);
    let cell = Cell::cubic(9.0);
    let mut pot = Potential::lj(1.0, 1.0, 2.5);
    let n = 40;
    pot.exclude_bonded_nonbonded = true;
    for i in 0..n / 2 {
        pot.bonds.push((2 * i, 2 * i + 1,
                        PotentialKind::Harmonic { k: 4.0, r0: 1.1 }));
    }
    let mut pos: Vec<[f64; 3]> = Vec::with_capacity(n);
    for _ in 0..n / 2 {
        let a = [rng.uniform(0.0, 9.0), rng.uniform(0.0, 9.0),
                 rng.uniform(0.0, 9.0)];
        pos.push(a);
        pos.push([a[0] + 1.1, a[1], a[2]]);
    }
    let species = vec![0usize; n];
    let mut pp = PeriodicPotential::new(pot, species, cell, 0.6);
    // warm: first call builds the list and sizes every buffer
    let (e, _) = pp.energy_forces_ref(&pos);
    assert!(e.is_finite());
    assert_eq!(pp.list().rebuilds, 1);

    let before = allocs();
    for step in 0..8 {
        for p in pos.iter_mut() {
            p[0] += 0.01;
        }
        let (e, _) = pp.energy_forces_ref(&pos);
        assert!(e.is_finite(), "step {step}");
    }
    let delta = allocs() - before;
    assert_eq!(pp.list().reuses, 8,
               "drift exceeded the skin — bad test setup");
    assert_eq!(
        delta, 0,
        "{delta} allocations in 8 bonded Verlet-reuse energy+forces \
         steps (expected 0)"
    );
}

#[test]
fn apply_batch_allocations_do_not_scale_with_rows() {
    let _guard = SERIAL.lock().unwrap();
    let mut rng = Rng::new(1);
    let l = 4usize;
    let n = num_coeffs(l);
    let plan = GauntPlan::new(l, l, l, ConvMethod::Fft);
    let count_batch = |rows: usize, rng: &mut Rng| -> usize {
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        // warm shared tables
        let _ = plan.apply_batch(&x1, &x2, rows);
        let before = allocs();
        let out = plan.apply_batch(&x1, &x2, rows);
        let delta = allocs() - before;
        assert_eq!(out.len(), rows * n);
        delta
    };
    let one = count_batch(1, &mut rng);
    let many = count_batch(64, &mut rng);
    // output + scratch only: identical allocation count regardless of
    // batch size (the 64-row batch reuses one scratch for every row)
    assert_eq!(
        one, many,
        "apply_batch allocations scale with rows: {one} for 1 row vs \
         {many} for 64 rows"
    );
}
