//! Conformance suite for the typed multi-task serving protocol:
//!
//! * every `Task` variant round-trips end-to-end through the native
//!   Gaunt backend on ONE `Service` instance;
//! * deadline expiry and cancellation come back as typed errors;
//! * reply-on-drop holds under injected worker failure (a panicking
//!   backend can never hang a caller, and the worker survives);
//! * hot model swap mid-traffic never yields a torn batch;
//! * shape-bucketed batching provably pads less than the single
//!   worst-case-width queue on a bimodal size mix.
//!
//! `SERVE_SMOKE=1` shrinks workloads for the fast verify gate.

use std::sync::Arc;
use std::time::Duration;

use gaunt_tp::coordinator::batcher::{BatchPolicy, BucketConfig};
use gaunt_tp::coordinator::request::{
    Batch, EnergyForces, EnergyOnly, ExecFault, MdRollout, Relax, Request,
    ServiceError, Structure,
};
use gaunt_tp::coordinator::router::Variant;
use gaunt_tp::coordinator::server::{
    Backend, BackendSpec, NativeGauntBackend, ServerConfig,
};
use gaunt_tp::coordinator::Service;
use gaunt_tp::data::PaddedBatch;
use gaunt_tp::md::{Integrator, LearnedPotential, Thermostat};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::runtime::Tensor;
use gaunt_tp::tp::Precision;
use gaunt_tp::util::rng::Rng;

fn smoke() -> bool {
    std::env::var("SERVE_SMOKE").is_ok()
}

fn scaled(full: usize, smoke_n: usize) -> usize {
    if smoke() { smoke_n } else { full }
}

/// A jittered-grid cluster with valid species (0..3).  Grid spacing 3.5
/// with small jitter keeps the neighbor degree <= 6 at the serving
/// cutoffs, so even 28-atom structures fit every bucket's edge budget.
fn cluster(n: usize, seed: u64) -> Structure {
    let mut rng = Rng::new(seed);
    Structure::new(
        (0..n)
            .map(|i| {
                [
                    3.5 * (i % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * ((i / 3) % 3) as f64 + 0.1 * rng.normal(),
                    3.5 * (i / 9) as f64 + 0.1 * rng.normal(),
                ]
            })
            .collect(),
        (0..n).map(|i| i % 3).collect(),
    )
}

fn native_service(n_workers: usize) -> Service {
    Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                max_queue: 4096,
            },
            n_workers,
            ..Default::default()
        })
        .build()
        .expect("native service must start without artifacts")
}

// ---------------------------------------------------------------------
// every Task variant end-to-end through one service
// ---------------------------------------------------------------------

#[test]
fn all_task_variants_round_trip_through_one_service() {
    let service = native_service(2);
    let client = service.client();
    let st = cluster(6, 3);

    // EnergyForces: the baseline
    let ef = client
        .call(Request::new(EnergyForces(st.clone())))
        .expect("energy_forces");
    assert!(ef.energy.is_finite());
    assert_eq!(ef.forces.len(), 6);
    assert!(ef.latency_s >= 0.0);

    // EnergyOnly agrees with EnergyForces on the same structure
    let eo = client
        .call(Request::new(EnergyOnly(st.clone())))
        .expect("energy_only");
    assert!(
        (eo.energy - ef.energy).abs() < 1e-9,
        "EnergyOnly {} vs EnergyForces {}",
        eo.energy,
        ef.energy
    );

    // Batch: every row matches its individual submission
    let sts = vec![st.clone(), cluster(4, 5), cluster(9, 7)];
    let batch = client
        .call(Request::new(Batch(sts.clone())))
        .expect("batch");
    assert_eq!(batch.len(), 3);
    for (row, s) in batch.iter().zip(&sts) {
        let single = client
            .call(Request::new(EnergyForces(s.clone())))
            .unwrap();
        assert!(
            (row.energy - single.energy).abs() < 1e-6,
            "batch row diverged: {} vs {}",
            row.energy,
            single.energy
        );
        assert_eq!(row.forces.len(), s.n_atoms());
    }

    // Relax: bounded steps, finite trace
    let relax = client
        .call(Request::new(Relax {
            structure: st.clone(),
            max_steps: scaled(20, 5),
        }))
        .expect("relax");
    assert!(relax.energy.is_finite());
    assert_eq!(relax.pos.len(), 6);
    assert_eq!(relax.energy_trace.len(), relax.steps + 1);
    assert!(relax.steps <= scaled(20, 5));

    // MdRollout: streamed frames + summary
    let steps = scaled(8, 4);
    let mut ticket = client
        .submit(Request::new(MdRollout {
            structure: st.clone(),
            steps,
            dt: 1e-3,
        }))
        .unwrap();
    let mut seen = 0usize;
    while let Some(frame) = ticket.next_frame() {
        assert_eq!(frame.step, seen);
        assert!(frame.energy.is_finite());
        assert_eq!(frame.pos.len(), 6);
        assert!((frame.time - (seen + 1) as f64 * 1e-3).abs() < 1e-12);
        seen += 1;
    }
    let traj = ticket.wait().expect("rollout");
    assert_eq!(seen, steps, "one frame per step");
    assert_eq!(traj.summary.steps, steps);
    assert!(traj.frames.is_empty(), "frames were drained by next_frame");
    assert!(traj
        .summary
        .final_pos
        .iter()
        .all(|p| p.iter().all(|x| x.is_finite())));

    // try_poll resolves without blocking once the reply landed
    let mut t2 = client
        .submit(Request::new(EnergyOnly(st.clone())))
        .unwrap();
    let mut polled = None;
    for _ in 0..2000 {
        if let Some(r) = t2.try_poll() {
            polled = Some(r);
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let polled = polled.expect("try_poll must resolve").expect("ok");
    assert!((polled.energy - ef.energy).abs() < 1e-9);

    assert!(
        service.metrics().responses.load(std::sync::atomic::Ordering::Relaxed)
            > 0
    );
    service.shutdown();
}

// ---------------------------------------------------------------------
// typed deadline + cancellation
// ---------------------------------------------------------------------

#[test]
fn deadline_expiry_returns_a_typed_error() {
    // one worker, a queue that flushes only after 100ms: a 1ms deadline
    // is deterministically expired by dequeue time
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(100),
                max_queue: 256,
            },
            n_workers: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let ticket = service
        .client()
        .submit(
            Request::new(EnergyForces(cluster(4, 1)))
                .deadline(Duration::from_millis(1)),
        )
        .unwrap();
    match ticket.wait() {
        Err(ServiceError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(
        service.metrics().expired.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    service.shutdown();
}

#[test]
fn cancellation_returns_a_typed_error() {
    // cancel while the request is still queued (slow flush)
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_millis(100),
                max_queue: 256,
            },
            n_workers: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let ticket = service
        .client()
        .submit(Request::new(EnergyForces(cluster(4, 2))))
        .unwrap();
    ticket.cancel();
    match ticket.wait() {
        Err(ServiceError::Canceled) => {}
        other => panic!("expected Canceled, got {other:?}"),
    }
    service.shutdown();
}

#[test]
fn cancel_racing_the_batch_flush_yields_exactly_one_terminal_reply() {
    // a fast-flushing single worker so the cancel genuinely races the
    // dequeue: depending on timing the request is either canceled while
    // queued, canceled at execution admission, or completes normally.
    // The contract is that EVERY outcome is a single terminal reply —
    // Ok or Canceled, never a hang, never Dropped.
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(50),
                max_queue: 256,
            },
            n_workers: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let client = service.client();
    let n = scaled(60, 12);
    let mut completed = 0usize;
    let mut canceled = 0usize;
    for k in 0..n {
        let ticket = client
            .submit(Request::new(EnergyForces(cluster(4, 1000 + k as u64))))
            .unwrap();
        if k % 3 != 0 {
            // vary the race window: sometimes cancel immediately,
            // sometimes after the flush has likely started
            std::thread::sleep(Duration::from_micros(20 * (k % 5) as u64));
        }
        ticket.cancel();
        match ticket.wait() {
            Ok(r) => {
                assert!(r.energy.is_finite());
                completed += 1;
            }
            Err(ServiceError::Canceled) => canceled += 1,
            other => panic!(
                "cancel/flush race produced a non-terminal outcome: \
                 {other:?}"
            ),
        }
    }
    let m = service.metrics();
    let responses =
        m.responses.load(std::sync::atomic::Ordering::Relaxed) as usize;
    let canceled_m =
        m.canceled.load(std::sync::atomic::Ordering::Relaxed) as usize;
    assert_eq!(
        completed + canceled,
        n,
        "every racing request must resolve exactly once"
    );
    assert_eq!(responses, completed, "metrics must match observed replies");
    assert_eq!(canceled_m, canceled, "metrics must match observed cancels");
    service.shutdown();
}

#[test]
fn poisoned_promote_is_refused_and_the_endpoint_keeps_serving() {
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let model = Arc::new(Model::new(cfg, 5));
    let service = Service::builder()
        .model(model.clone())
        .config(ServerConfig { n_workers: 1, ..Default::default() })
        .build()
        .unwrap();
    let client = service.client();
    let st = cluster(5, 31);
    let before = client
        .call(Request::new(EnergyForces(st.clone())))
        .expect("healthy endpoint serves");
    let v0 = service.registry().endpoints()[0].1;

    // a diverged snapshot: one NaN parameter
    let mut bad = Model::new(cfg, 6);
    let mid = bad.params.len() / 2;
    bad.params[mid] = f64::NAN;
    let err = service
        .promote("default", Arc::new(bad))
        .expect_err("NaN snapshot must be refused at the service boundary");
    assert!(err.to_string().contains("non-finite"), "{err}");

    // the refused promote changed nothing: same version, same numbers
    assert_eq!(service.registry().endpoints()[0].1, v0);
    let after = client
        .call(Request::new(EnergyForces(st)))
        .expect("endpoint keeps serving after the refused promote");
    assert!(
        (after.energy - before.energy).abs() < 1e-12,
        "the live model must be untouched: {} vs {}",
        after.energy,
        before.energy
    );
    service.shutdown();
}

#[test]
fn cancellation_interrupts_a_streaming_rollout() {
    let service = native_service(1);
    let client = service.client();
    // far more steps than could ever finish before the cancel lands;
    // the provider checks the flag every force evaluation
    let mut ticket = client
        .submit(Request::new(MdRollout {
            structure: cluster(4, 9),
            steps: 1_000_000,
            dt: 1e-4,
        }))
        .unwrap();
    let first = ticket.next_frame().expect("at least one frame streams");
    assert_eq!(first.step, 0);
    ticket.cancel();
    // drain whatever was in flight; the stream must END (not hang)
    while ticket.next_frame().is_some() {}
    match ticket.wait() {
        Err(ServiceError::Canceled) => {}
        other => panic!("expected Canceled mid-rollout, got {other:?}"),
    }
    service.shutdown();
}

// ---------------------------------------------------------------------
// submit-side typed rejections
// ---------------------------------------------------------------------

#[test]
fn malformed_and_oversize_submissions_are_rejected_synchronously() {
    let service = native_service(1);
    let client = service.client();
    // species/pos mismatch
    let bad = Structure::new(vec![[0.0; 3]; 3], vec![0; 2]);
    match client.submit(Request::new(EnergyForces(bad))) {
        Err(ServiceError::Rejected(m)) => assert!(m.contains("species"), "{m}"),
        other => panic!("expected Rejected, got {:?}", other.err()),
    }
    // larger than the largest bucket
    let big = cluster(service.max_atoms() + 1, 4);
    match client.submit(Request::new(EnergyForces(big))) {
        Err(ServiceError::Rejected(m)) => assert!(m.contains("bucket"), "{m}"),
        other => panic!("expected Rejected, got {:?}", other.err()),
    }
    // unknown model endpoint
    match client
        .submit(Request::new(EnergyForces(cluster(4, 4))).model("nope"))
    {
        Err(ServiceError::Rejected(m)) => {
            assert!(m.contains("unknown model"), "{m}")
        }
        other => panic!("expected Rejected, got {:?}", other.err()),
    }
    // zero-step rollout
    match client.submit(Request::new(MdRollout {
        structure: cluster(4, 4),
        steps: 0,
        dt: 1e-3,
    })) {
        Err(ServiceError::Rejected(_)) => {}
        other => panic!("expected Rejected, got {:?}", other.err()),
    }
    service.shutdown();
}

// ---------------------------------------------------------------------
// reply-on-drop under injected worker failure
// ---------------------------------------------------------------------

struct PanickingBackend;

impl Backend for PanickingBackend {
    fn run(
        &self, _v: &Variant, _pb: &PaddedBatch, _s: &[Tensor],
        _m: Option<&Arc<Model>>,
    ) -> gaunt_tp::util::error::Result<(Vec<f32>, Vec<f32>)> {
        panic!("injected backend failure");
    }
}

struct ErroringBackend;

impl Backend for ErroringBackend {
    fn run(
        &self, _v: &Variant, _pb: &PaddedBatch, _s: &[Tensor],
        _m: Option<&Arc<Model>>,
    ) -> gaunt_tp::util::error::Result<(Vec<f32>, Vec<f32>)> {
        Err(gaunt_tp::err!("injected backend error"))
    }
}

fn spec_with(backend: Arc<dyn Backend>) -> BackendSpec {
    BackendSpec {
        backend,
        variants: vec![
            Variant { name: "inj_B1".to_string(), batch: 1 },
            Variant { name: "inj_B4".to_string(), batch: 4 },
        ],
        state: Vec::new(),
        n_atoms: 32,
        n_edges: 256,
        fixed_shape: false,
        precision: Precision::F64,
    }
}

#[test]
fn worker_panic_can_never_hang_a_caller() {
    let service = Service::builder()
        .backend(spec_with(Arc::new(PanickingBackend)))
        .config(ServerConfig { n_workers: 1, ..Default::default() })
        .build()
        .unwrap();
    let client = service.client();
    // the panic unwinds through the reply slots: wait() returns an
    // error instead of blocking forever
    match client.call(Request::new(EnergyForces(cluster(4, 1)))) {
        Err(ServiceError::Dropped(_)) => {}
        other => panic!("expected Dropped after worker panic, got {other:?}"),
    }
    assert!(
        service
            .metrics()
            .worker_panics
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 1
    );
    // the worker survived the panic and keeps serving (and failing)
    match client.call(Request::new(EnergyForces(cluster(4, 2)))) {
        Err(ServiceError::Dropped(_)) => {}
        other => panic!("worker died after panic: got {other:?}"),
    }
    service.shutdown();
}

#[test]
fn backend_errors_are_typed_exec_errors() {
    let service = Service::builder()
        .backend(spec_with(Arc::new(ErroringBackend)))
        .config(ServerConfig { n_workers: 1, ..Default::default() })
        .build()
        .unwrap();
    match service
        .client()
        .call(Request::new(EnergyForces(cluster(4, 1))))
    {
        Err(ServiceError::Exec(ExecFault::Backend(m))) => {
            assert!(m.contains("injected"), "{m}")
        }
        other => panic!("expected Exec(Backend), got {other:?}"),
    }
    assert_eq!(
        service.metrics().failed.load(std::sync::atomic::Ordering::Relaxed),
        1,
        "execution failures must land in the failed counter"
    );
    service.shutdown();
}

#[test]
fn shutdown_fails_queued_requests_instead_of_leaking_them() {
    // a service whose only worker never flushes before shutdown
    let service = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy: BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(60),
                max_queue: 256,
            },
            n_workers: 1,
            ..Default::default()
        })
        .build()
        .unwrap();
    let t1 = service
        .client()
        .submit(Request::new(EnergyForces(cluster(4, 1))))
        .unwrap();
    let t2 = service
        .client()
        .submit(Request::new(EnergyForces(cluster(20, 2))))
        .unwrap();
    service.shutdown();
    for t in [t1, t2] {
        match t.wait() {
            Err(ServiceError::Shutdown) => {}
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// hot swap: never a torn batch
// ---------------------------------------------------------------------

#[test]
fn hot_swap_mid_traffic_never_tears_a_batch() {
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let model_a = Arc::new(Model::new(cfg, 1));
    let model_b = Arc::new(Model::new(cfg, 2));
    let st = cluster(5, 11);
    let (e_a, _) = model_a.energy_forces(&st.pos, &st.species);
    let (e_b, _) = model_b.energy_forces(&st.pos, &st.species);
    assert!(
        (e_a - e_b).abs() > 1e-9,
        "seeds must give distinguishable models"
    );

    let service = Service::builder()
        .model(model_a.clone())
        .config(ServerConfig { n_workers: 2, ..Default::default() })
        .build()
        .unwrap();
    let client = service.client();
    let v0 = service.registry().endpoints()[0].1;

    // swapper thread: a<->b as fast as it can while traffic flows.
    // The stop flag is raised by a drop guard so that a FAILING
    // assertion below (unwinding out of the scope closure) still stops
    // the swapper — thread::scope joins it before propagating the
    // panic, and without the guard the test would hang instead of fail.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    struct StopOnDrop(Arc<std::sync::atomic::AtomicBool>);
    impl Drop for StopOnDrop {
        fn drop(&mut self) {
            self.0.store(true, std::sync::atomic::Ordering::Relaxed);
        }
    }
    let n_waves = scaled(24, 6);
    std::thread::scope(|scope| {
        let _stop_guard = StopOnDrop(stop.clone());
        let svc = &service;
        let stop3 = stop.clone();
        let (ma, mb) = (model_a.clone(), model_b.clone());
        scope.spawn(move || {
            let mut flip = false;
            while !stop3.load(std::sync::atomic::Ordering::Relaxed) {
                let m = if flip { ma.clone() } else { mb.clone() };
                svc.promote("default", m).expect("finite model promotes");
                flip = !flip;
                std::thread::sleep(Duration::from_micros(200));
            }
        });
        for _ in 0..n_waves {
            // 4 identical structures in ONE Batch task: they execute in
            // one padded batch against ONE resolved model version, so
            // all four energies must be identical — a torn batch would
            // mix e_a and e_b rows
            let rows = client
                .call(Request::new(Batch(vec![
                    st.clone(),
                    st.clone(),
                    st.clone(),
                    st.clone(),
                ])))
                .expect("batch under hot swap");
            for w in rows.windows(2) {
                assert!(
                    (w[0].energy - w[1].energy).abs() < 1e-9,
                    "TORN BATCH: rows saw different model versions: {} vs {}",
                    w[0].energy,
                    w[1].energy
                );
            }
            // and each wave matches one of the two registered models
            let e = rows[0].energy;
            assert!(
                (e - e_a).abs() < 1e-4 * (1.0 + e_a.abs())
                    || (e - e_b).abs() < 1e-4 * (1.0 + e_b.abs()),
                "batch energy {e} matches neither model ({e_a} / {e_b})"
            );
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    let v1 = service.registry().endpoints()[0].1;
    assert!(v1 > v0, "swaps must bump the endpoint version");
    service.shutdown();
}

// ---------------------------------------------------------------------
// bucketed batching pads strictly less than the global queue
// ---------------------------------------------------------------------

fn drive_bimodal(service: &Service, n_pairs: usize) {
    // sequential closed loop: each request is flushed alone, so the
    // padded-slot accounting is deterministic (1 row x bucket width per
    // request) and the comparison below cannot be blurred by row
    // padding from racy batch coalescing
    let client = service.client();
    for k in 0..n_pairs {
        client
            .call(Request::new(EnergyForces(cluster(4, 100 + k as u64))))
            .unwrap();
        client
            .call(Request::new(EnergyForces(cluster(28, 200 + k as u64))))
            .unwrap();
    }
}

#[test]
fn bucketed_batching_pads_strictly_less_than_the_global_queue() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        max_queue: 4096,
    };
    let global = Service::builder()
        .native(NativeGauntBackend::default())
        .policy(policy)
        .workers(2)
        // the pre-redesign shape: ONE bucket at the worst-case width
        .buckets(vec![BucketConfig {
            max_atoms: 32,
            max_edges: 256,
            policy,
        }])
        .build()
        .unwrap();
    let bucketed = Service::builder()
        .native(NativeGauntBackend::default())
        .policy(policy)
        .workers(2)
        .buckets(vec![
            BucketConfig { max_atoms: 8, max_edges: 56, policy },
            BucketConfig { max_atoms: 32, max_edges: 256, policy },
        ])
        .build()
        .unwrap();

    let n_pairs = scaled(24, 8);
    drive_bimodal(&global, n_pairs);
    drive_bimodal(&bucketed, n_pairs);

    let load = |s: &Service| {
        let m = s.metrics();
        (
            m.padded_atom_slots.load(std::sync::atomic::Ordering::Relaxed),
            m.true_atom_slots.load(std::sync::atomic::Ordering::Relaxed),
        )
    };
    let (pad_g, true_g) = load(&global);
    let (pad_b, true_b) = load(&bucketed);
    assert_eq!(
        true_g, true_b,
        "both services carried the same real atoms"
    );
    assert!(
        pad_b < pad_g,
        "bucketed batching must pad strictly less: bucketed {pad_b} vs \
         global {pad_g} padded slots for {true_g} real atoms"
    );
    let fill_g = global.metrics().atom_fill();
    let fill_b = bucketed.metrics().atom_fill();
    assert!(
        fill_b > fill_g,
        "bucketed fill {fill_b:.3} must beat global fill {fill_g:.3}"
    );
    global.shutdown();
    bucketed.shutdown();
}

// ---------------------------------------------------------------------
// relax/rollout are exactly the MD substrate over LearnedPotential
// ---------------------------------------------------------------------

#[test]
fn served_rollout_reproduces_local_learned_potential_md() {
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let model = Arc::new(Model::new(cfg, 7));
    let service = Service::builder()
        .model(model.clone())
        .config(ServerConfig { n_workers: 1, ..Default::default() })
        .build()
        .unwrap();
    let st = cluster(5, 21);
    let steps = scaled(10, 4);
    let dt = 1e-3;
    let traj = service
        .client()
        .call(Request::new(MdRollout {
            structure: st.clone(),
            steps,
            dt,
        }))
        .expect("served rollout");
    assert_eq!(traj.frames.len(), steps);

    // the served task IS Integrator+LearnedPotential: reproduce locally
    let mut lp = LearnedPotential::new(model.clone(), st.species.clone());
    let mut rng = Rng::new(0); // unused by Thermostat::None
    let mut md = Integrator::new_with(
        st.pos.clone(),
        st.species.clone(),
        &mut lp,
        dt,
        Thermostat::None,
    );
    for frame in &traj.frames {
        md.step_with(&mut lp, &mut rng);
        assert!(
            (frame.energy - md.potential_energy).abs() < 1e-9,
            "served frame {} energy {} vs local {}",
            frame.step,
            frame.energy,
            md.potential_energy
        );
        for (a, b) in frame.pos.iter().zip(&md.pos) {
            for k in 0..3 {
                assert!(
                    (a[k] - b[k]).abs() < 1e-9,
                    "served rollout diverged from local LearnedPotential MD"
                );
            }
        }
    }
    // relax through the same endpoint stays finite and traces steps
    let relax = service
        .client()
        .call(Request::new(Relax {
            structure: st,
            max_steps: scaled(15, 5),
        }))
        .expect("served relax");
    assert!(relax.energy.is_finite());
    assert_eq!(relax.energy_trace.len(), relax.steps + 1);
    service.shutdown();
}
