//! Property tests for `fourier::fft`: roundtrip, linearity, Parseval, and
//! the Bluestein path for non-power-of-two (incl. prime) lengths — the
//! transform underneath the paper's O(L^2 log L) convolution.

use gaunt_tp::fourier::complex::C64;
use gaunt_tp::fourier::fft::{fft, fft2, ifft};
use gaunt_tp::util::prop::{check, PropConfig};
use gaunt_tp::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
}

fn naive_dft(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = C64::default();
            for (j, v) in x.iter().enumerate() {
                let ang =
                    -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
                acc += *v * C64::cis(ang);
            }
            acc
        })
        .collect()
}

#[test]
fn roundtrip_all_sizes_1_to_40() {
    check("fft-roundtrip", PropConfig { cases: 40, seed: 1 }, |rng, case| {
        let n = case + 1; // covers pow2, even, odd, prime sizes
        let x = rand_vec(rng, n);
        let y = ifft(&fft(&x));
        for (i, (a, b)) in x.iter().zip(&y).enumerate() {
            if (*a - *b).abs() > 1e-9 {
                return Err(format!("n={n} idx={i}: roundtrip off"));
            }
        }
        Ok(())
    });
}

#[test]
fn linearity_property() {
    check("fft-linearity", PropConfig { cases: 24, seed: 2 }, |rng, case| {
        let n = 3 + case; // mixed pow2 / non-pow2
        let a = rand_vec(rng, n);
        let b = rand_vec(rng, n);
        let alpha = rng.uniform(-2.0, 2.0);
        let combo: Vec<C64> = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.scale(alpha) + *y)
            .collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fc = fft(&combo);
        for i in 0..n {
            let want = fa[i].scale(alpha) + fb[i];
            if (fc[i] - want).abs() > 1e-8 {
                return Err(format!("n={n} idx={i}: not linear"));
            }
        }
        Ok(())
    });
}

#[test]
fn parseval_property() {
    check("fft-parseval", PropConfig { cases: 24, seed: 3 }, |rng, case| {
        let n = 2 + case;
        let x = rand_vec(rng, n);
        let f = fft(&x);
        let e_time: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let e_freq: f64 =
            f.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        if (e_time - e_freq).abs() > 1e-8 * (1.0 + e_time) {
            return Err(format!("n={n}: {e_time} vs {e_freq}"));
        }
        Ok(())
    });
}

#[test]
fn bluestein_matches_naive_on_primes() {
    let mut rng = Rng::new(4);
    for n in [2usize, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 53] {
        let x = rand_vec(&mut rng, n);
        let got = fft(&x);
        let want = naive_dft(&x);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((*g - *w).abs() < 1e-8, "prime n={n} idx={i}");
        }
    }
}

#[test]
fn fft2_roundtrip_non_power_of_two_grids() {
    let mut rng = Rng::new(5);
    for (rows, cols) in [(3usize, 5usize), (7, 7), (6, 10), (9, 4), (1, 13)] {
        let g = rand_vec(&mut rng, rows * cols);
        let f = fft2(&g, rows, cols, false);
        let back = fft2(&f, rows, cols, true);
        for (i, (a, b)) in g.iter().zip(&back).enumerate() {
            assert!(
                (*a - *b).abs() < 1e-9,
                "{rows}x{cols} idx={i}: 2D roundtrip off"
            );
        }
    }
}

#[test]
fn shift_theorem_on_bluestein_sizes() {
    // x delayed by one sample multiplies spectrum by e^{-2 pi i k / n}
    let mut rng = Rng::new(6);
    for n in [5usize, 9, 12, 21] {
        let x = rand_vec(&mut rng, n);
        let mut shifted = vec![C64::default(); n];
        for i in 0..n {
            shifted[(i + 1) % n] = x[i];
        }
        let fx = fft(&x);
        let fs = fft(&shifted);
        for k in 0..n {
            let phase =
                C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64);
            assert!((fs[k] - fx[k] * phase).abs() < 1e-8, "n={n} k={k}");
        }
    }
}
