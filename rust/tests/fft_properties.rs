//! Property tests for `fourier::fft`: roundtrip, linearity, Parseval, and
//! the Bluestein path for non-power-of-two (incl. prime) lengths — the
//! transform underneath the paper's O(L^2 log L) convolution — plus the
//! planned workspace layer: `FftPlan` in-place transforms, the real-input
//! two-for-one forward, and the Hermitian convolution fast path against
//! both the direct and the generic complex planned paths.

use gaunt_tp::fourier::complex::C64;
use gaunt_tp::fourier::conv::{conv2d_direct, conv2d_fft, conv2d_fft_planned};
use gaunt_tp::fourier::fft::{fft, fft2, ifft, FftPlan};
use gaunt_tp::fourier::plan::ConvPlan;
use gaunt_tp::util::prop::{check, PropConfig};
use gaunt_tp::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<C64> {
    (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
}

/// Random centered odd-size grid with exact conjugate symmetry
/// g(-u,-v) = conj(g(u,v)) — the shape of every grid the Gaunt pipeline
/// produces from real SH coefficients.
fn rand_hermitian_grid(rng: &mut Rng, n: usize) -> Vec<C64> {
    let mut g = rand_vec(rng, n * n);
    let last = n - 1;
    for i in 0..n {
        for j in 0..n {
            let (mi, mj) = (last - i, last - j);
            if (i, j) < (mi, mj) {
                g[mi * n + mj] = g[i * n + j].conj();
            } else if (i, j) == (mi, mj) {
                g[i * n + j] = C64::real(g[i * n + j].re);
            }
        }
    }
    g
}

fn max_cdiff(a: &[C64], b: &[C64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
}

fn naive_dft(x: &[C64]) -> Vec<C64> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = C64::default();
            for (j, v) in x.iter().enumerate() {
                let ang =
                    -2.0 * std::f64::consts::PI * (k * j) as f64 / n as f64;
                acc += *v * C64::cis(ang);
            }
            acc
        })
        .collect()
}

#[test]
fn roundtrip_all_sizes_1_to_40() {
    check("fft-roundtrip", PropConfig { cases: 40, seed: 1 }, |rng, case| {
        let n = case + 1; // covers pow2, even, odd, prime sizes
        let x = rand_vec(rng, n);
        let y = ifft(&fft(&x));
        for (i, (a, b)) in x.iter().zip(&y).enumerate() {
            if (*a - *b).abs() > 1e-9 {
                return Err(format!("n={n} idx={i}: roundtrip off"));
            }
        }
        Ok(())
    });
}

#[test]
fn linearity_property() {
    check("fft-linearity", PropConfig { cases: 24, seed: 2 }, |rng, case| {
        let n = 3 + case; // mixed pow2 / non-pow2
        let a = rand_vec(rng, n);
        let b = rand_vec(rng, n);
        let alpha = rng.uniform(-2.0, 2.0);
        let combo: Vec<C64> = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.scale(alpha) + *y)
            .collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fc = fft(&combo);
        for i in 0..n {
            let want = fa[i].scale(alpha) + fb[i];
            if (fc[i] - want).abs() > 1e-8 {
                return Err(format!("n={n} idx={i}: not linear"));
            }
        }
        Ok(())
    });
}

#[test]
fn parseval_property() {
    check("fft-parseval", PropConfig { cases: 24, seed: 3 }, |rng, case| {
        let n = 2 + case;
        let x = rand_vec(rng, n);
        let f = fft(&x);
        let e_time: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let e_freq: f64 =
            f.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        if (e_time - e_freq).abs() > 1e-8 * (1.0 + e_time) {
            return Err(format!("n={n}: {e_time} vs {e_freq}"));
        }
        Ok(())
    });
}

#[test]
fn bluestein_matches_naive_on_primes() {
    let mut rng = Rng::new(4);
    for n in [2usize, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 53] {
        let x = rand_vec(&mut rng, n);
        let got = fft(&x);
        let want = naive_dft(&x);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((*g - *w).abs() < 1e-8, "prime n={n} idx={i}");
        }
    }
}

#[test]
fn fft2_roundtrip_non_power_of_two_grids() {
    let mut rng = Rng::new(5);
    for (rows, cols) in [(3usize, 5usize), (7, 7), (6, 10), (9, 4), (1, 13)] {
        let g = rand_vec(&mut rng, rows * cols);
        let f = fft2(&g, rows, cols, false);
        let back = fft2(&f, rows, cols, true);
        for (i, (a, b)) in g.iter().zip(&back).enumerate() {
            assert!(
                (*a - *b).abs() < 1e-9,
                "{rows}x{cols} idx={i}: 2D roundtrip off"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Planned workspace layer
// ---------------------------------------------------------------------

#[test]
fn planned_fft2_inplace_round_trips() {
    let mut rng = Rng::new(20);
    for n in [1usize, 2, 4, 8, 16, 32] {
        let plan = FftPlan::shared(n);
        let g = rand_vec(&mut rng, n * n);
        let mut buf = g.clone();
        let mut col = vec![C64::default(); n];
        plan.fft2_inplace(&mut buf, false, &mut col);
        plan.fft2_inplace(&mut buf, true, &mut col);
        let s = 1.0 / (n * n) as f64;
        for (a, b) in g.iter().zip(&buf) {
            assert!((*a - b.scale(s)).abs() < 1e-10, "n={n}");
        }
    }
}

#[test]
fn real_forward_matches_complex_forward() {
    check("fwd2-real-vs-complex", PropConfig { cases: 10, seed: 21 },
          |rng, case| {
        let n = 1usize << (case % 5); // 1, 2, 4, 8, 16
        let plan = FftPlan::shared(n);
        let q: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let qc: Vec<C64> = q.iter().map(|v| C64::real(*v)).collect();
        let want = fft2(&qc, n, n, false);
        let mut got = vec![C64::default(); n * n];
        let mut col = vec![C64::default(); n];
        plan.fwd2_real_into(&q, &mut got, &mut col);
        if max_cdiff(&got, &want) > 1e-9 {
            return Err(format!("n={n}: real-input forward diverges"));
        }
        Ok(())
    });
}

#[test]
fn hermitian_conv_matches_direct_and_generic() {
    // the tentpole identity: on conjugate-symmetric grids the packed
    // two-for-one Hermitian path, the generic planned complex path, the
    // legacy conv2d_fft, and the direct convolution all agree
    let mut rng = Rng::new(22);
    for (n1, n2) in [(1usize, 1usize), (3, 3), (3, 5), (5, 5), (5, 9), (7, 7)] {
        let a = rand_hermitian_grid(&mut rng, n1);
        let b = rand_hermitian_grid(&mut rng, n2);
        let plan = ConvPlan::new(n1, n2);
        let mut scratch = plan.scratch();
        let n = plan.n_out;
        let mut herm = vec![C64::default(); n * n];
        plan.conv_hermitian_into(&a, &b, &mut herm, &mut scratch);
        let mut generic = vec![C64::default(); n * n];
        plan.conv_into(&a, &b, &mut generic, &mut scratch);
        let direct = conv2d_direct(&a, n1, &b, n2);
        let legacy = conv2d_fft(&a, n1, &b, n2);
        assert!(max_cdiff(&herm, &direct) < 1e-9,
                "hermitian vs direct n1={n1} n2={n2}: {}",
                max_cdiff(&herm, &direct));
        assert!(max_cdiff(&generic, &direct) < 1e-9,
                "generic vs direct n1={n1} n2={n2}");
        assert!(max_cdiff(&herm, &legacy) < 1e-9,
                "hermitian vs legacy n1={n1} n2={n2}");
    }
}

#[test]
fn hermitian_conv_bilinear_property() {
    check("hermitian-conv-bilinear", PropConfig { cases: 12, seed: 23 },
          |rng, _| {
        let (n1, n2) = (5usize, 3usize);
        let a1 = rand_hermitian_grid(rng, n1);
        let a2 = rand_hermitian_grid(rng, n1);
        let b = rand_hermitian_grid(rng, n2);
        let alpha = rng.uniform(-2.0, 2.0);
        let combo: Vec<C64> = a1
            .iter()
            .zip(&a2)
            .map(|(x, y)| x.scale(alpha) + *y)
            .collect();
        let plan = ConvPlan::new(n1, n2);
        let mut scratch = plan.scratch();
        let n = plan.n_out;
        let mut lhs = vec![C64::default(); n * n];
        let mut r1 = vec![C64::default(); n * n];
        let mut r2 = vec![C64::default(); n * n];
        plan.conv_hermitian_into(&combo, &b, &mut lhs, &mut scratch);
        plan.conv_hermitian_into(&a1, &b, &mut r1, &mut scratch);
        plan.conv_hermitian_into(&a2, &b, &mut r2, &mut scratch);
        let rhs: Vec<C64> =
            r1.iter().zip(&r2).map(|(x, y)| x.scale(alpha) + *y).collect();
        if max_cdiff(&lhs, &rhs) > 1e-8 {
            return Err("hermitian conv not bilinear".into());
        }
        Ok(())
    });
}

#[test]
fn planned_one_shot_matches_legacy_on_random_grids() {
    let mut rng = Rng::new(24);
    for (n1, n2) in [(2usize, 4usize), (3, 3), (4, 6), (5, 7)] {
        let a = rand_vec(&mut rng, n1 * n1);
        let b = rand_vec(&mut rng, n2 * n2);
        let legacy = conv2d_fft(&a, n1, &b, n2);
        let planned = conv2d_fft_planned(&a, n1, &b, n2);
        assert!(max_cdiff(&legacy, &planned) < 1e-9, "n1={n1} n2={n2}");
    }
}

#[test]
fn shift_theorem_on_bluestein_sizes() {
    // x delayed by one sample multiplies spectrum by e^{-2 pi i k / n}
    let mut rng = Rng::new(6);
    for n in [5usize, 9, 12, 21] {
        let x = rand_vec(&mut rng, n);
        let mut shifted = vec![C64::default(); n];
        for i in 0..n {
            shifted[(i + 1) % n] = x[i];
        }
        let fx = fft(&x);
        let fs = fft(&shifted);
        for k in 0..n {
            let phase =
                C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64);
            assert!((fs[k] - fx[k] * phase).abs() < 1e-8, "n={n} k={k}");
        }
    }
}
