//! Periodic neighbor-stack property suite (DESIGN.md §13).
//!
//! The contract under test: the O(N) periodic cell list (serial and
//! cell-block-parallel) is EXACTLY the brute-force minimum-image oracle
//! for any cell and any cutoff up to half the minimum width; lattice
//! translations of individual atoms are unobservable; Verlet lists with
//! a skin stay exact across the whole rebuild/reuse lifecycle; and
//! classical forces under PBC sum to zero (Newton's third law survives
//! image shifts).

use gaunt_tp::md::neighbor::{
    neighbors_periodic_brute, neighbors_periodic_cell,
    neighbors_periodic_par, Cell, Edge, VerletList,
};
use gaunt_tp::md::Potential;
use gaunt_tp::util::prop::{check, PropConfig};
use gaunt_tp::util::rng::Rng;

/// A random cell: orthorhombic or moderately sheared triclinic, with
/// min width comfortably positive.
fn random_cell(rng: &mut Rng, case: usize) -> Cell {
    let l = rng.uniform(5.0, 9.0);
    if case % 2 == 0 {
        Cell::orthorhombic(l, rng.uniform(0.8, 1.4) * l,
                           rng.uniform(0.8, 1.4) * l)
    } else {
        Cell::triclinic([
            [l, 0.0, 0.0],
            [rng.uniform(-0.3, 0.3) * l, 1.1 * l, 0.0],
            [rng.uniform(-0.2, 0.2) * l, rng.uniform(-0.2, 0.2) * l, 0.9 * l],
        ])
    }
}

fn random_pos(rng: &mut Rng, cell: &Cell, n: usize) -> Vec<[f64; 3]> {
    // sample in fractional space well OUTSIDE [0, 1): the builders must
    // handle unwrapped coordinates
    (0..n)
        .map(|_| {
            cell.cart([
                rng.uniform(-1.5, 2.5),
                rng.uniform(-1.5, 2.5),
                rng.uniform(-1.5, 2.5),
            ])
        })
        .collect()
}

fn sorted(mut e: Vec<Edge>) -> Vec<Edge> {
    e.sort_unstable();
    e
}

#[test]
fn cell_list_equals_minimum_image_oracle() {
    check(
        "periodic cell list == MIC oracle (cutoffs up to L/2)",
        PropConfig { cases: 40, seed: 101 },
        |rng, case| {
            let cell = random_cell(rng, case);
            let pos = random_pos(rng, &cell, 5 + case % 40);
            // bias toward the hard regime: cutoffs near the MIC bound
            let frac = if case % 2 == 0 {
                rng.uniform(0.85, 1.0)
            } else {
                rng.uniform(0.2, 0.85)
            };
            let rc = frac * cell.max_cutoff();
            let want = sorted(neighbors_periodic_brute(&pos, &cell, rc));
            let got = sorted(neighbors_periodic_cell(&pos, &cell, rc));
            if want != got {
                return Err(format!(
                    "serial: oracle {} edges vs cell list {}",
                    want.len(), got.len()
                ));
            }
            for threads in [1usize, 2, 5] {
                let got =
                    sorted(neighbors_periodic_par(&pos, &cell, rc, threads));
                if want != got {
                    return Err(format!(
                        "par({threads}): oracle {} edges vs {}",
                        want.len(), got.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn lattice_translations_are_unobservable() {
    check(
        "edges invariant under per-atom lattice translations",
        PropConfig { cases: 24, seed: 202 },
        |rng, case| {
            let cell = random_cell(rng, case);
            let pos = random_pos(rng, &cell, 4 + case % 20);
            let rc = rng.uniform(0.3, 0.95) * cell.max_cutoff();
            let base = sorted(neighbors_periodic_cell(&pos, &cell, rc));
            // translate EACH atom by its own random lattice vector
            let moved: Vec<[f64; 3]> = pos
                .iter()
                .map(|p| {
                    let s = [
                        rng.uniform(-3.0, 3.0).round() as i32,
                        rng.uniform(-3.0, 3.0).round() as i32,
                        rng.uniform(-3.0, 3.0).round() as i32,
                    ];
                    let sv = cell.shift_vector(s);
                    [p[0] + sv[0], p[1] + sv[1], p[2] + sv[2]]
                })
                .collect();
            let shifted = neighbors_periodic_cell(&moved, &cell, rc);
            // shifts differ (they absorb the translations), but the
            // pair set and every minimum-image DISTANCE must agree
            let mut got: Vec<(usize, usize)> =
                shifted.iter().map(|e| (e.i, e.j)).collect();
            let mut want: Vec<(usize, usize)> =
                base.iter().map(|e| (e.i, e.j)).collect();
            got.sort_unstable();
            want.sort_unstable();
            if got != want {
                return Err(format!(
                    "pair sets differ: {} vs {}", want.len(), got.len()
                ));
            }
            for e in &shifted {
                let sv = cell.shift_vector(e.shift);
                let d = [
                    moved[e.i][0] - moved[e.j][0] + sv[0],
                    moved[e.i][1] - moved[e.j][1] + sv[1],
                    moved[e.i][2] - moved[e.j][2] + sv[2],
                ];
                let r2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
                if r2 >= rc * rc {
                    return Err(format!(
                        "edge ({}, {}) shift {:?} reconstructs out-of-range \
                         distance {}", e.i, e.j, e.shift, r2.sqrt()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn verlet_list_stays_exact_across_rebuild_boundaries() {
    check(
        "Verlet pair iteration == oracle at every step of a drift",
        PropConfig { cases: 10, seed: 303 },
        |rng, case| {
            let cell = random_cell(rng, case);
            let n = 12 + case % 24;
            let mut pos = random_pos(rng, &cell, n);
            let rc = 0.55 * cell.max_cutoff();
            let skin = 0.25 * cell.max_cutoff();
            let mut vl = VerletList::periodic(cell.clone(), rc, skin);
            for step in 0..12 {
                vl.update(&pos);
                let mut got: Vec<(usize, usize)> = Vec::new();
                vl.for_each_pair(&pos, |i, j, d, r2| {
                    let n2 = d[0] * d[0] + d[1] * d[1] + d[2] * d[2];
                    if (n2 - r2).abs() > 1e-12 {
                        panic!("for_each_pair: r2 disagrees with d");
                    }
                    got.push((i, j));
                });
                got.sort_unstable();
                let mut want: Vec<(usize, usize)> =
                    neighbors_periodic_brute(&pos, &cell, rc)
                        .into_iter()
                        .filter(|e| e.i < e.j)
                        .map(|e| (e.i, e.j))
                        .collect();
                want.sort_unstable();
                if got != want {
                    return Err(format!(
                        "step {step} (rebuilds {}, reuses {}): {} pairs vs \
                         oracle {}",
                        vl.rebuilds, vl.reuses, got.len(), want.len()
                    ));
                }
                // random drift, sized so some steps reuse and some
                // rebuild — both sides of the boundary get exercised
                for p in pos.iter_mut() {
                    for v in p.iter_mut() {
                        *v += rng.uniform(-0.3, 0.3) * skin;
                    }
                }
            }
            if vl.rebuilds < 2 || vl.reuses < 2 {
                return Err(format!(
                    "drift never crossed the boundary both ways: rebuilds \
                     {}, reuses {}", vl.rebuilds, vl.reuses
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn periodic_forces_sum_to_zero() {
    check(
        "classical LJ forces under PBC sum to zero",
        PropConfig { cases: 16, seed: 404 },
        |rng, case| {
            let cell = random_cell(rng, case);
            let pos = random_pos(rng, &cell, 8 + case % 30);
            let rc = 0.8 * cell.max_cutoff();
            let pot = Potential::lj(1.0, 1.0, rc);
            let species = vec![0usize; pos.len()];
            let (e, f) = pot.energy_forces_periodic(&pos, &species, &cell);
            if !e.is_finite() {
                return Err("non-finite periodic energy".into());
            }
            for k in 0..3 {
                let s: f64 = f.iter().map(|v| v[k]).sum();
                let scale: f64 = f
                    .iter()
                    .map(|v| v[k].abs())
                    .fold(0.0, f64::max)
                    .max(1.0);
                if s.abs() > 1e-9 * scale {
                    return Err(format!(
                        "net force along axis {k}: {s} (scale {scale})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn periodic_forces_match_finite_differences() {
    // one deterministic case with central differences of the periodic
    // energy — ties the force sign convention to the energy under PBC.
    // Jittered lattice, not uniform-random positions: a near-overlapping
    // pair would dominate the total energy and wash out the finite
    // differences of every other atom.
    let mut rng = Rng::new(55);
    let cell = Cell::orthorhombic(6.0, 7.0, 8.0);
    let pot = Potential::lj(1.0, 1.0, 2.5);
    let mut pos: Vec<[f64; 3]> = Vec::new();
    for ix in 0..2 {
        for iy in 0..2 {
            for iz in 0..3 {
                pos.push([
                    (ix as f64 + 0.5) * 3.0 + rng.uniform(-0.3, 0.3),
                    (iy as f64 + 0.5) * 3.5 + rng.uniform(-0.3, 0.3),
                    (iz as f64 + 0.5) * 8.0 / 3.0 + rng.uniform(-0.3, 0.3),
                ]);
            }
        }
    }
    let n = pos.len();
    let species = vec![0usize; n];
    let (_, f) = pot.energy_forces_periodic(&pos, &species, &cell);
    let h = 1e-6;
    for i in 0..n {
        for k in 0..3 {
            let mut pp = pos.clone();
            pp[i][k] += h;
            let (ep, _) = pot.energy_forces_periodic(&pp, &species, &cell);
            pp[i][k] -= 2.0 * h;
            let (em, _) = pot.energy_forces_periodic(&pp, &species, &cell);
            let fd = -(ep - em) / (2.0 * h);
            assert!(
                (f[i][k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "atom {i} axis {k}: {} vs {fd}", f[i][k]
            );
        }
    }
}
