//! Gradient harness: the analytic backward passes of the learned force
//! field against central finite differences, per layer and end to end,
//! for both convolution backends — plus a descent check of the native
//! trainer on a fixed synthetic batch.
//!
//! Everything here is the Rust twin of
//! `python/compile/model_golden.py --check` (which validated the same
//! identities against the exact real Gaunt tensors before this
//! implementation existed).

use gaunt_tp::data::Graph;
use gaunt_tp::coordinator::trainer::{NativeTrainConfig, NativeTrainer};
use gaunt_tp::model::{Model, ModelConfig};
use gaunt_tp::tp::ConvMethod;
use gaunt_tp::util::rng::Rng;

/// Acceptance bar for forces vs -dE/dx; observed errors are ~1e-9.
const FORCE_REL_TOL: f64 = 1e-4;
const FD_H: f64 = 1e-5;

fn toy_structure(seed: u64, n: usize) -> (Vec<[f64; 3]>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let pos = (0..n)
        .map(|_| [1.5 * rng.normal(), 1.5 * rng.normal(),
                  1.5 * rng.normal()])
        .collect();
    let species = (0..n).map(|_| rng.below(3)).collect();
    (pos, species)
}

/// F = -dE/dx by central differences, neighbor list rebuilt at every
/// displacement (the smooth radial envelope makes E continuous across
/// edge-set changes, so this probes the REAL energy surface).
fn check_forces_fd(model: &Model, pos: &[[f64; 3]], species: &[usize],
                   what: &str) {
    let (_, forces) = model.energy_forces(pos, species);
    for i in 0..pos.len() {
        for ax in 0..3 {
            let mut pp = pos.to_vec();
            pp[i][ax] += FD_H;
            let ep = model.energy(&pp, species);
            pp[i][ax] -= 2.0 * FD_H;
            let em = model.energy(&pp, species);
            let fd = -(ep - em) / (2.0 * FD_H);
            assert!(
                (forces[i][ax] - fd).abs()
                    <= FORCE_REL_TOL * (1.0 + fd.abs()),
                "{what}: atom {i} axis {ax}: analytic {} vs fd {}",
                forces[i][ax],
                fd
            );
        }
    }
}

#[test]
fn forces_match_finite_differences_single_layer() {
    // one interaction layer: isolates the edge-embedding -> conv ->
    // many-body -> readout chain without cross-layer backprop
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = Model::new(
            ModelConfig { n_layers: 1, method, ..Default::default() }, 3);
        let (pos, species) = toy_structure(1, 5);
        check_forces_fd(&model, &pos, &species,
                        &format!("1-layer {method:?}"));
    }
}

#[test]
fn forces_match_finite_differences_end_to_end() {
    // two layers: the full backward chain including the h-cotangent
    // flowing through the messages of the upper layer
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = Model::new(
            ModelConfig { n_layers: 2, method, ..Default::default() }, 4);
        let (pos, species) = toy_structure(2, 6);
        check_forces_fd(&model, &pos, &species,
                        &format!("2-layer {method:?}"));
    }
}

#[test]
fn forces_match_finite_differences_nu3() {
    // nu = 3 takes the real ManyBodyPlan (nu-1)-power path in the VJP
    let model = Model::new(
        ModelConfig { nu: 3, n_layers: 2, ..Default::default() }, 5);
    let (pos, species) = toy_structure(3, 5);
    check_forces_fd(&model, &pos, &species, "nu=3");
}

#[test]
fn forces_match_finite_differences_multi_channel() {
    // mul > 1 node features: the per-channel message/many-body VJPs and
    // the per-(channel, l) path-weight chain must stay exact on both
    // convolution backends
    for method in [ConvMethod::Direct, ConvMethod::Fft] {
        let model = Model::new(
            ModelConfig { channels: 2, n_layers: 2, method,
                          ..Default::default() },
            8,
        );
        let (pos, species) = toy_structure(6, 5);
        check_forces_fd(&model, &pos, &species,
                        &format!("C=2 {method:?}"));
    }
}

#[test]
fn parameter_gradient_matches_finite_differences_multi_channel() {
    let model = Model::new(
        ModelConfig { channels: 2, nu: 3, n_layers: 2,
                      ..Default::default() },
        16,
    );
    let (pos, species) = toy_structure(14, 5);
    let edges = model.build_edges(&pos);
    let mut scratch = model.scratch();
    let mut forces = vec![0.0; 3 * pos.len()];
    let mut gp = vec![0.0; model.n_params()];
    let _ = model.grad_into(&pos, &species, &edges, &mut forces, &mut gp,
                            &mut scratch);
    let h = 1e-6;
    let mut rng = Rng::new(19);
    for _ in 0..model.n_params() / 3 {
        let idx = rng.below(model.n_params());
        let mut m2 = Model::from_params(model.cfg, model.params.clone());
        m2.params[idx] += h;
        let ep = m2.energy_into(&pos, &species, &edges, &mut scratch);
        m2.params[idx] -= 2.0 * h;
        let em = m2.energy_into(&pos, &species, &edges, &mut scratch);
        let fd = (ep - em) / (2.0 * h);
        assert!(
            (gp[idx] - fd).abs() <= 1e-5 * (1.0 + fd.abs()),
            "C=2 param {idx}: analytic {} vs fd {}",
            gp[idx],
            fd
        );
    }
}

#[test]
fn parameter_gradient_matches_finite_differences() {
    let model = Model::new(ModelConfig { n_layers: 2, ..Default::default() },
                           6);
    let (pos, species) = toy_structure(4, 5);
    let edges = model.build_edges(&pos);
    let mut scratch = model.scratch();
    let mut forces = vec![0.0; 3 * pos.len()];
    let mut gp = vec![0.0; model.n_params()];
    let _ = model.grad_into(&pos, &species, &edges, &mut forces, &mut gp,
                            &mut scratch);
    let h = 1e-6;
    let mut rng = Rng::new(9);
    // spot-check a random third of the parameters (every layout family
    // is hit with overwhelming probability)
    for _ in 0..model.n_params() / 3 {
        let idx = rng.below(model.n_params());
        let mut m2 = Model::from_params(model.cfg, model.params.clone());
        m2.params[idx] += h;
        let ep = m2.energy_into(&pos, &species, &edges, &mut scratch);
        m2.params[idx] -= 2.0 * h;
        let em = m2.energy_into(&pos, &species, &edges, &mut scratch);
        let fd = (ep - em) / (2.0 * h);
        assert!(
            (gp[idx] - fd).abs() <= 1e-5 * (1.0 + fd.abs()),
            "param {idx}: analytic {} vs fd {}",
            gp[idx],
            fd
        );
    }
}

/// Labels realizable by a perturbed copy of the model, so the loss has
/// headroom to decrease from the very first step.
fn synthetic_batch(model_cfg: ModelConfig, seed: u64, k: usize)
    -> Vec<Graph> {
    let teacher = {
        let mut t = Model::new(model_cfg, 777);
        let mut rng = Rng::new(seed);
        for p in t.params.iter_mut() {
            *p += 0.2 * rng.normal();
        }
        t
    };
    (0..k)
        .map(|i| {
            let (pos, species) = toy_structure(seed + 10 + i as u64, 5);
            let (energy, forces) = teacher.energy_forces(&pos, &species);
            Graph { pos, species, energy, forces }
        })
        .collect()
}

#[test]
fn trainer_step_decreases_the_loss_on_a_fixed_batch() {
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let batch = synthetic_batch(cfg, 31, 3);
    let mut trainer = NativeTrainer::new(
        Model::new(cfg, 777),
        NativeTrainConfig { lr: 5e-3, ..Default::default() },
    );
    let before = trainer.loss(&batch);
    assert!(before.is_finite() && before > 0.0);
    trainer.step(&batch);
    let after_one = trainer.loss(&batch);
    assert!(
        after_one < before,
        "one Adam step did not decrease the loss: {before} -> {after_one}"
    );
    for _ in 0..7 {
        trainer.step(&batch);
    }
    let after = trainer.loss(&batch);
    assert!(
        after < 0.9 * before,
        "8 steps barely moved the loss: {before} -> {after}"
    );
}

#[test]
fn trainer_total_gradient_matches_loss_finite_differences() {
    // the full energy+force gradient — including the Pearlmutter-style
    // HVP force term — against a central difference of the loss itself
    let cfg = ModelConfig { n_layers: 1, ..Default::default() };
    let batch = synthetic_batch(cfg, 41, 2);
    let tcfg = NativeTrainConfig::default();
    let h = 1e-5;
    let mut rng = Rng::new(12);
    let base = Model::new(cfg, 55);
    let mut trainer = NativeTrainer::new(
        Model::from_params(cfg, base.params.clone()), tcfg);
    let (_, grad) = trainer.eval_grad(&batch);
    for _ in 0..10 {
        let idx = rng.below(base.n_params());
        let mut lp = NativeTrainer::new(
            Model::from_params(cfg, {
                let mut p = base.params.clone();
                p[idx] += h;
                p
            }),
            tcfg,
        );
        let mut lm = NativeTrainer::new(
            Model::from_params(cfg, {
                let mut p = base.params.clone();
                p[idx] -= h;
                p
            }),
            tcfg,
        );
        let fd = (lp.loss(&batch) - lm.loss(&batch)) / (2.0 * h);
        assert!(
            (grad[idx] - fd).abs() <= 1e-4 * (1.0 + fd.abs()),
            "loss gradient param {idx}: analytic {} vs fd {}",
            grad[idx],
            fd
        );
    }
}

#[test]
fn dipole_head_gradients_match_finite_differences_on_model_features() {
    // the vector readout's analytic parameter gradients (w and c_dip)
    // against central differences, evaluated on REAL node features from
    // a model forward pass (not synthetic draws): this exercises the
    // sv-lift VJP sibling on the actual feature distribution
    use gaunt_tp::model::dipole::{DipoleHead, DipoleScratch};
    let model = Model::new(
        ModelConfig { n_layers: 1, ..Default::default() }, 5);
    let (pos, species) = toy_structure(3, 5);
    let edges = model.build_edges(&pos);
    let mut s = model.scratch();
    model.energy_into(&pos, &species, &edges, &mut s);
    let mut head = DipoleHead::new(
        model.cfg.channels, model.cfg.l, ConvMethod::Auto, 21);
    let mut hs = head.scratch();
    let g_mu = [0.4, -0.9, 1.3];
    let n = pos.len();
    let loss = |head: &DipoleHead, hs: &mut DipoleScratch| -> f64 {
        (0..n)
            .map(|i| {
                let mu = head.dipole_into(model.node_features(&s, i), hs);
                g_mu[0] * mu[0] + g_mu[1] * mu[1] + g_mu[2] * mu[2]
            })
            .sum()
    };
    let mut gw = vec![0.0; head.w.len()];
    let mut gc = 0.0;
    for i in 0..n {
        head.grads_into(
            model.node_features(&s, i), g_mu, &mut gw, &mut gc, &mut hs);
    }
    let h = 1e-6;
    for idx in 0..gw.len() {
        let w0 = head.w[idx];
        head.w[idx] = w0 + h;
        let up = loss(&head, &mut hs);
        head.w[idx] = w0 - h;
        let dn = loss(&head, &mut hs);
        head.w[idx] = w0;
        let fd = (up - dn) / (2.0 * h);
        assert!(
            (gw[idx] - fd).abs() <= 1e-5 * (1.0 + fd.abs()),
            "dipole dw[{idx}]: analytic {} vs fd {}", gw[idx], fd
        );
    }
    let c0 = head.c_dip;
    head.c_dip = c0 + h;
    let up = loss(&head, &mut hs);
    head.c_dip = c0 - h;
    let dn = loss(&head, &mut hs);
    head.c_dip = c0;
    let fd = (up - dn) / (2.0 * h);
    assert!(
        (gc - fd).abs() <= 1e-5 * (1.0 + fd.abs()),
        "dipole dc_dip: analytic {gc} vs fd {fd}"
    );
}
