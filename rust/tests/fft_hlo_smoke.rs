//! Early toolchain check: the PJRT runtime must execute the HLO `fft`
//! op — the Gaunt Tensor Product fast path multiplies 2D-Fourier
//! coefficient grids via FFT-based convolution.
//!
//! Skips (loudly) when the HLO file is absent or when the offline xla
//! stub is active (see DESIGN.md section 5); with a real PJRT backend the
//! numeric assertions run.
use gaunt_tp::util::error::Result;
use gaunt_tp::xla;

#[test]
fn fft_hlo_executes_on_cpu() -> Result<()> {
    let path = "/tmp/fft_hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("SKIP fft_hlo_executes_on_cpu: {path} not present \
                   (run python /tmp/fft_check.py)");
        return Ok(());
    }
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(path)?;
    let exe = match client.compile(&xla::XlaComputation::from_proto(&proto)) {
        Ok(exe) => exe,
        // only the offline stub's unavailability is a skip; a real PJRT
        // backend failing to compile the FFT HLO must FAIL the test
        Err(e) if e.to_string().contains("offline") => {
            eprintln!("SKIP fft_hlo_executes_on_cpu: {e}");
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    // delta at (0,0) convolved with anything = identity
    let mut x = vec![0f32; 64];
    x[0] = 1.0;
    let y: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let lx = xla::Literal::vec1(&x).reshape(&[8, 8])?;
    let ly = xla::Literal::vec1(&y).reshape(&[8, 8])?;
    let out = exe.execute::<xla::Literal>(&[lx, ly])?[0][0]
        .to_literal_sync()?
        .to_tuple1()?;
    let v = out.to_vec::<f32>()?;
    for (i, (a, b)) in v.iter().zip(y.iter()).enumerate() {
        assert!((a - b).abs() < 1e-3, "idx {i}: {a} vs {b}");
    }
    Ok(())
}
