//! Deterministic fuzz of the hardened JSON parser (`util::json`) and
//! the wire codec built on it.
//!
//! The wire path feeds `parse_limited` bytes from the network, so the
//! contract under test is: **no panic on any input, typed errors, and
//! exact value roundtrip on valid documents**.  Mutations come from
//! `util::rng` with fixed seeds — every failure reproduces.
//!
//! `JSON_FUZZ_FULL=1` scales the iteration counts up ~20x for soak
//! runs; the default sizing keeps tier-1 fast.

use gaunt_tp::coordinator::{Structure, Task};
use gaunt_tp::net::proto::{encode_client, task_to_json, ClientMsg};
use gaunt_tp::util::json::{self, Json, JsonError, Limits};
use gaunt_tp::util::rng::Rng;

fn scaled(base: usize) -> usize {
    if std::env::var("JSON_FUZZ_FULL").is_ok() {
        base * 20
    } else {
        base
    }
}

/// A pool of valid documents shaped like real wire traffic plus
/// rng-grown nasties (deep-ish nesting, unicode strings, big numbers).
fn corpus(rng: &mut Rng) -> Vec<String> {
    let st = Structure {
        pos: vec![[1.25, -3.5, 0.0], [2.0, 2.0, 2.0]],
        species: vec![0, 2],
    };
    let mut docs = vec![
        "null".to_string(),
        "true".to_string(),
        "-12.5e-3".to_string(),
        "\"hello \\\"world\\\" \\u00e9\"".to_string(),
        "[]".to_string(),
        "{}".to_string(),
        "[1,[2,[3,[4,[5]]]]]".to_string(),
        encode_client(&ClientMsg::Submit {
            seq: 42,
            deadline_ms: Some(250),
            model: Some("prod".to_string()),
            task: Task::MdRollout {
                structure: st.clone(),
                steps: 5,
                dt: 0.002,
            },
        }),
        encode_client(&ClientMsg::Hello {
            version: 1,
            name: "fuzz \n\t\"client\"".to_string(),
        }),
        task_to_json(&Task::Batch { structures: vec![st.clone(), st] })
            .to_string(),
    ];
    // rng-grown random documents
    for _ in 0..scaled(30) {
        docs.push(grow(rng, 0).to_string());
    }
    docs
}

/// Grow a random JSON value, bounded depth.
fn grow(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth >= 6 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => {
            // mix integers, fractions, exponents, negatives
            let base = rng.uniform(-1e9, 1e9);
            Json::Num(if rng.below(3) == 0 { base.trunc() } else { base })
        }
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    // printable ascii + a few escapes and non-ascii
                    match rng.below(20) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'é',
                        _ => (b'a' + rng.below(26) as u8) as char,
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.below(5);
            Json::Arr((0..len).map(|_| grow(rng, depth + 1)).collect())
        }
        _ => {
            let len = rng.below(5);
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), grow(rng, depth + 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn valid_documents_roundtrip_exactly() {
    let mut rng = Rng::new(0xF00D);
    for doc in corpus(&mut rng) {
        let v = json::parse(&doc)
            .unwrap_or_else(|e| panic!("corpus doc must parse: {e}\n{doc}"));
        let re = v.to_string();
        let v2 = json::parse(&re)
            .unwrap_or_else(|e| panic!("reserialized must parse: {e}\n{re}"));
        assert_eq!(v, v2, "roundtrip drift on {doc}");
    }
}

#[test]
fn truncations_never_panic_and_prefix_cuts_are_typed() {
    let mut rng = Rng::new(0xBEEF);
    for doc in corpus(&mut rng) {
        let cuts: Vec<usize> = if doc.len() <= 64 {
            (0..doc.len()).collect()
        } else {
            (0..scaled(40)).map(|_| rng.below(doc.len())).collect()
        };
        for cut in cuts {
            if !doc.is_char_boundary(cut) {
                continue;
            }
            // must return *something* — a shorter valid document is
            // fine, a typed error is fine, a panic is the bug
            let _ = json::parse_limited(&doc[..cut], &Limits::default());
        }
    }
    // cutting a structurally open document is Truncated, not Syntax
    let doc = "{\"a\": [1, 2, {\"b\": \"xy";
    match json::parse_limited(doc, &Limits::default()) {
        Err(JsonError::Truncated(_)) => {}
        other => panic!("open-structure cut must be Truncated: {other:?}"),
    }
}

#[test]
fn random_byte_flips_never_panic() {
    let mut rng = Rng::new(0xCAFE);
    let docs = corpus(&mut rng);
    for doc in &docs {
        if doc.is_empty() {
            continue;
        }
        for _ in 0..scaled(60) {
            let mut bytes = doc.as_bytes().to_vec();
            let flips = 1 + rng.below(3);
            for _ in 0..flips {
                let i = rng.below(bytes.len());
                bytes[i] = (rng.next_u64() & 0xFF) as u8;
            }
            // invalid UTF-8 can't even reach the parser (it takes
            // &str); lossy-decode like a defensive caller would
            let s = String::from_utf8_lossy(&bytes);
            let _ = json::parse_limited(&s, &Limits::default());
        }
    }
}

#[test]
fn random_splices_never_panic() {
    let mut rng = Rng::new(0xD1CE);
    let docs = corpus(&mut rng);
    let shards = [
        "{", "}", "[", "]", ",", ":", "\"", "\\", "null", "1e999", "-",
        "\\u12", "{\"a\":", "[[", "\u{7f}",
    ];
    for doc in &docs {
        for _ in 0..scaled(40) {
            let mut s = doc.clone();
            let shard = shards[rng.below(shards.len())];
            let mut at = rng.below(s.len() + 1);
            while !s.is_char_boundary(at) {
                at -= 1;
            }
            s.insert_str(at, shard);
            let _ = json::parse_limited(&s, &Limits::default());
        }
    }
}

#[test]
fn depth_and_size_bombs_are_typed_not_crashes() {
    // a recursion bomb far past the default depth limit: the parser
    // must refuse it with TooDeep instead of overflowing the stack
    let bomb = "[".repeat(500_000);
    match json::parse_limited(&bomb, &Limits::default()) {
        Err(JsonError::TooDeep { .. }) => {}
        other => panic!("depth bomb must be TooDeep: {other:?}"),
    }
    let mixed = "{\"a\":".repeat(300_000);
    match json::parse_limited(&mixed, &Limits::default()) {
        Err(JsonError::TooDeep { .. }) => {}
        other => panic!("object bomb must be TooDeep: {other:?}"),
    }
    // size cap
    let limits = Limits { max_depth: 128, max_bytes: 64 };
    let big = format!("\"{}\"", "x".repeat(256));
    match json::parse_limited(&big, &limits) {
        Err(JsonError::TooLarge { .. }) => {}
        other => panic!("oversize doc must be TooLarge: {other:?}"),
    }
}
