//! Property tests for the tentpole: plans served by the cache must agree
//! with the coupling-tensor baseline and stay equivariant under random
//! rotations — for both convolution backends.

use gaunt_tp::num_coeffs;
use gaunt_tp::so3::gaunt::gaunt_tensor_real;
use gaunt_tp::so3::linalg::matvec;
use gaunt_tp::so3::rotation::{wigner_d_real_block, Rot3};
use gaunt_tp::tp::engine::PlanCache;
use gaunt_tp::tp::ConvMethod;
use gaunt_tp::util::prop::{check, max_abs_diff, PropConfig};

/// The CG-projected baseline: contract the exact Gaunt coupling tensor
/// (the even-parity, Wigner-Eckart-scaled projection of the CG tensor)
/// directly — O(L^6), used only as an oracle.
fn baseline(x1: &[f64], l1: usize, x2: &[f64], l2: usize, l3: usize) -> Vec<f64> {
    let g = gaunt_tensor_real(l1, l2, l3);
    let (n1, n2, n3) = (num_coeffs(l1), num_coeffs(l2), num_coeffs(l3));
    let mut out = vec![0.0; n3];
    for k in 0..n3 {
        for i in 0..n1 {
            for j in 0..n2 {
                out[k] += g[(k * n1 + i) * n2 + j] * x1[i] * x2[j];
            }
        }
    }
    out
}

#[test]
fn cached_plans_match_cg_projected_baseline() {
    check(
        "cache-gaunt-vs-baseline",
        PropConfig { cases: 12, seed: 0xBEEF },
        |rng, case| {
            let l1 = 1 + case % 3;
            let l2 = 1 + (case / 2) % 3;
            let l3 = 1 + (case / 4) % 4;
            let x1 = rng.normals(num_coeffs(l1));
            let x2 = rng.normals(num_coeffs(l2));
            let want = baseline(&x1, l1, &x2, l2, l3);
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = PlanCache::global().gaunt(l1, l2, l3, method);
                let got = plan.apply(&x1, &x2);
                let d = max_abs_diff(&got, &want);
                if d > 1e-9 {
                    return Err(format!(
                        "({l1},{l2},{l3}) {method:?}: |diff| = {d}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cached_plans_equivariant_under_random_rotations() {
    check(
        "cache-gaunt-equivariance",
        PropConfig { cases: 10, seed: 0xD1CE },
        |rng, case| {
            let l = 1 + case % 3;
            let rot = Rot3::random(rng);
            let d_in = wigner_d_real_block(l, &rot);
            let d_out = wigner_d_real_block(2 * l, &rot);
            let n = num_coeffs(l);
            let nn = num_coeffs(2 * l);
            let x1 = rng.normals(n);
            let x2 = rng.normals(n);
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = PlanCache::global().gaunt(l, l, 2 * l, method);
                let rotated_inputs = plan.apply(
                    &matvec(&d_in, &x1, n, n),
                    &matvec(&d_in, &x2, n, n),
                );
                let rotated_output =
                    matvec(&d_out, &plan.apply(&x1, &x2), nn, nn);
                let d = max_abs_diff(&rotated_inputs, &rotated_output);
                if d > 1e-8 {
                    return Err(format!("L={l} {method:?}: |diff| = {d}"));
                }
            }
            Ok(())
        },
    );
}

/// Truncated outputs from the cache agree with prefixes of wider plans —
/// two different cache keys, one algebraic identity.
#[test]
fn cached_truncation_matches_projection() {
    let cache = PlanCache::global();
    check(
        "cache-truncation",
        PropConfig { cases: 8, seed: 0xFADE },
        |rng, _| {
            let x1 = rng.normals(num_coeffs(3));
            let x2 = rng.normals(num_coeffs(2));
            let full = cache.gaunt(3, 2, 5, ConvMethod::Fft).apply(&x1, &x2);
            let trunc = cache.gaunt(3, 2, 2, ConvMethod::Fft).apply(&x1, &x2);
            let d = max_abs_diff(&trunc, &full[..num_coeffs(2)]);
            if d < 1e-10 {
                Ok(())
            } else {
                Err(format!("truncation mismatch {d}"))
            }
        },
    );
}
