//! End-to-end serving through the full coordinator stack (batcher ->
//! router -> worker pool) on the native Gaunt-TP backend — no compiled
//! artifacts required, so unlike `runtime_integration` these tests always
//! run.  Every flushed batch exercises the engine's plan cache and the
//! multi-threaded batched tensor product.

use std::time::Duration;

use gaunt_tp::coordinator::batcher::BatchPolicy;
use gaunt_tp::coordinator::server::NativeGauntBackend;
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig};
use gaunt_tp::data::gen_bpa_dataset;
use gaunt_tp::so3::rotation::Rot3;
use gaunt_tp::tp::engine::{OpKey, PlanCache};
use gaunt_tp::tp::Precision;
use gaunt_tp::util::rng::Rng;

fn start_server(n_workers: usize) -> ForceFieldServer {
    ForceFieldServer::start_native(
        NativeGauntBackend::default(),
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                max_queue: 256,
            },
            n_workers,
            ..Default::default()
        },
    )
    .expect("native server must start without artifacts")
}

#[test]
fn native_server_end_to_end() {
    let server = start_server(2);
    let graphs = gen_bpa_dataset(&[0.05], 20, 3).remove(0);
    // batched path must agree with the single-shot path
    let single = server
        .infer_blocking(graphs[0].pos.clone(), graphs[0].species.clone())
        .unwrap();
    assert!(single.energy.is_finite());
    assert_eq!(single.forces.len(), graphs[0].pos.len());
    let tickets: Vec<_> = graphs
        .iter()
        .map(|g| server.submit(g.pos.clone(), g.species.clone()).unwrap())
        .collect();
    let responses: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap())
        .collect();
    assert_eq!(responses.len(), 20);
    for resp in &responses {
        assert!(resp.energy.is_finite());
        assert!(resp
            .forces
            .iter()
            .all(|f| f.iter().all(|v| v.is_finite())));
        // antisymmetric pair forces conserve momentum
        for k in 0..3 {
            let s: f64 = resp.forces.iter().map(|f| f[k]).sum();
            assert!(s.abs() < 1e-3, "momentum component {k} = {s}");
        }
    }
    // request 0 is the same structure as the single-shot call: padding
    // and batching must not change results
    let batched = &responses[0];
    assert!((batched.energy - single.energy).abs() < 1e-6);
    for (a, b) in batched.forces.iter().zip(&single.forces) {
        for k in 0..3 {
            assert!((a[k] - b[k]).abs() < 1e-6);
        }
    }
    assert!(server.metrics().mean_batch_size() >= 1.0);
    // the hot path went through the global plan cache
    assert!(PlanCache::global().hits() + PlanCache::global().builds() > 0);
    // ... and serving observes it: plan churn is folded into the
    // metrics report after every batch, and the per-OpKey breakdown is
    // one call away
    let report = server.metrics().report();
    assert!(report.contains("plans="),
            "plan stats missing from report: {report}");
    assert!(
        server.metrics().plan_entries.load(std::sync::atomic::Ordering::Relaxed)
            >= 1,
        "metrics never observed the plan cache"
    );
    let stats = server.plan_stats();
    assert!(stats.len >= 1);
    assert!(!stats.per_key.is_empty());
    server.shutdown();
}

#[test]
fn native_server_is_equivariant() {
    // rotating the structure must rotate energies not at all and forces
    // exactly (up to f32 rounding in the response path)
    let server = start_server(1);
    let graphs = gen_bpa_dataset(&[0.05], 1, 11).remove(0);
    let g = &graphs[0];
    let mut rng = Rng::new(99);
    let rot = Rot3::random(&mut rng);
    let pos_rot: Vec<[f64; 3]> = g.pos.iter().map(|&p| rot.apply(p)).collect();

    let base = server
        .infer_blocking(g.pos.clone(), g.species.clone())
        .unwrap();
    let rotated = server
        .infer_blocking(pos_rot, g.species.clone())
        .unwrap();
    assert!(
        (base.energy - rotated.energy).abs() < 1e-4 * (1.0 + base.energy.abs()),
        "energy not invariant: {} vs {}",
        base.energy,
        rotated.energy
    );
    for (f, fr) in base.forces.iter().zip(&rotated.forces) {
        let want = rot.apply(*f);
        for k in 0..3 {
            assert!(
                (want[k] - fr[k]).abs() < 1e-3 * (1.0 + want[k].abs()),
                "force not equivariant: {want:?} vs {fr:?}"
            );
        }
    }
    server.shutdown();
}

#[test]
fn f32_serving_mode_tracks_f64_results() {
    // the same surrogate served at Precision::F32 must agree with the
    // f64 server to single-precision tolerance, and its hot path must
    // actually run through the GauntF32 plan family
    let f64_srv = start_server(1);
    let f32_srv = ForceFieldServer::start_native(
        NativeGauntBackend { precision: Precision::F32, ..Default::default() },
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                max_queue: 256,
            },
            n_workers: 1,
            precision: Precision::F32,
            ..Default::default()
        },
    )
    .expect("f32 native server must start");
    let graphs = gen_bpa_dataset(&[0.05], 4, 7).remove(0);
    for g in &graphs {
        let a = f64_srv
            .infer_blocking(g.pos.clone(), g.species.clone())
            .unwrap();
        let b = f32_srv
            .infer_blocking(g.pos.clone(), g.species.clone())
            .unwrap();
        assert!(
            (a.energy - b.energy).abs() < 1e-3 * (1.0 + a.energy.abs()),
            "f32 energy off: {} vs {}", b.energy, a.energy
        );
        for (fa, fb) in a.forces.iter().zip(&b.forces) {
            for k in 0..3 {
                assert!(
                    (fa[k] - fb[k]).abs() < 1e-3 * (1.0 + fa[k].abs()),
                    "f32 force off: {fb:?} vs {fa:?}"
                );
            }
        }
    }
    // the f32 server's plan cache traffic includes a GauntF32 key
    let stats = f32_srv.plan_stats();
    assert!(
        stats.per_key.iter().any(|ks| matches!(
            ks.key, OpKey::GauntF32 { .. }
        )),
        "no GauntF32 plan in cache stats: {:?}", stats.per_key
    );
    f64_srv.shutdown();
    f32_srv.shutdown();
}

#[test]
fn native_server_applies_backpressure() {
    let server = ForceFieldServer::start_native(
        NativeGauntBackend::default(),
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_queue: 2,
            },
            n_workers: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let graphs = gen_bpa_dataset(&[0.05], 1, 5).remove(0);
    let g = &graphs[0];
    // flood faster than one worker can drain a queue of depth 2; at least
    // one submit must be rejected OR all succeed if the worker keeps up —
    // either way the server must stay consistent and drain cleanly.
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..64 {
        match server.submit(g.pos.clone(), g.species.clone()) {
            Ok(t) => tickets.push(t),
            Err(_) => rejected += 1,
        }
    }
    for t in tickets {
        let resp = t.wait().unwrap();
        assert!(resp.energy.is_finite());
    }
    let m = server.metrics();
    assert_eq!(
        m.rejected.load(std::sync::atomic::Ordering::Relaxed),
        rejected as u64
    );
    server.shutdown();
}
