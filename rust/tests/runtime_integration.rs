//! Integration tests over the PJRT runtime + coordinator with the real
//! compiled artifacts.  Each test skips gracefully when `artifacts/` has
//! not been built yet.

use std::sync::Arc;
use std::time::Duration;

use gaunt_tp::coordinator::batcher::BatchPolicy;
use gaunt_tp::coordinator::{ForceFieldServer, ServerConfig, Trainer};
use gaunt_tp::data::{gen_bpa_dataset, PaddedBatch};
use gaunt_tp::experiments::ff_batch_tensors;
use gaunt_tp::num_coeffs;
use gaunt_tp::runtime::{Engine, Tensor};
use gaunt_tp::tp::{CgPlan, ConvMethod, GauntPlan};
use gaunt_tp::util::rng::Rng;

fn engine() -> Option<Arc<Engine>> {
    match Engine::new("artifacts") {
        Ok(e) => Some(Arc::new(e)),
        Err(err) => {
            eprintln!("skipping (no artifacts): {err}");
            None
        }
    }
}

#[test]
fn gaunt_kernel_artifact_matches_native() {
    let Some(engine) = engine() else { return };
    let exe = match engine.load("gaunt_tp_L3_B64") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let n = num_coeffs(3);
    let mut rng = Rng::new(42);
    let x1: Vec<f32> = rng.normals_f32(64 * n);
    let x2: Vec<f32> = rng.normals_f32(64 * n);
    let out = exe
        .run(&[Tensor::F32(x1.clone()), Tensor::F32(x2.clone())])
        .unwrap();
    let y = out[0].as_f32().unwrap();
    let plan = GauntPlan::new(3, 3, 3, ConvMethod::Fft);
    for r in [0usize, 17, 63] {
        let a: Vec<f64> = x1[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let b: Vec<f64> = x2[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let want = plan.apply(&a, &b);
        for k in 0..n {
            assert!(
                (y[r * n + k] as f64 - want[k]).abs() < 2e-4,
                "row {r} coeff {k}: {} vs {}",
                y[r * n + k],
                want[k]
            );
        }
    }
}

#[test]
fn cg_kernel_artifact_matches_native() {
    let Some(engine) = engine() else { return };
    let exe = match engine.load("cg_tp_L2_B64") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let n = num_coeffs(2);
    let mut rng = Rng::new(7);
    let x1: Vec<f32> = rng.normals_f32(64 * n);
    let x2: Vec<f32> = rng.normals_f32(64 * n);
    let out = exe
        .run(&[Tensor::F32(x1.clone()), Tensor::F32(x2.clone())])
        .unwrap();
    let y = out[0].as_f32().unwrap();
    let plan = CgPlan::new(2, 2, 2);
    for r in [0usize, 31] {
        let a: Vec<f64> = x1[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let b: Vec<f64> = x2[r * n..(r + 1) * n].iter().map(|&v| v as f64).collect();
        let want = plan.apply_sparse(&a, &b);
        for k in 0..n {
            assert!((y[r * n + k] as f64 - want[k]).abs() < 2e-4);
        }
    }
}

#[test]
fn wrong_input_count_rejected() {
    let Some(engine) = engine() else { return };
    let Ok(exe) = engine.load("gaunt_tp_L2_B64") else { return };
    let err = exe.run(&[Tensor::F32(vec![0.0; 64 * 9])]);
    assert!(err.is_err());
}

#[test]
fn wrong_shape_rejected() {
    let Some(engine) = engine() else { return };
    let Ok(exe) = engine.load("gaunt_tp_L2_B64") else { return };
    let err = exe.run(&[
        Tensor::F32(vec![0.0; 10]),
        Tensor::F32(vec![0.0; 64 * 9]),
    ]);
    assert!(err.is_err(), "shape mismatch must be rejected before PJRT");
}

#[test]
fn train_step_decreases_loss() {
    let Some(engine) = engine() else { return };
    let mut trainer =
        match Trainer::new(&engine, "ff_train_step_gaunt", "ff_state_init_gaunt") {
            Ok(t) => t,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
    let graphs = gen_bpa_dataset(&[0.05], 8, 1).remove(0);
    let pb = PaddedBatch::from_graphs(&graphs, 8, 32, 128, 4.0);
    let batch = ff_batch_tensors(&pb, true);
    let first = trainer.step(batch.clone()).unwrap();
    for _ in 0..15 {
        trainer.step(batch.clone()).unwrap();
    }
    let last = trainer.step(batch).unwrap();
    assert!(
        last < first,
        "loss should decrease on a fixed batch: {first} -> {last}"
    );
}

#[test]
fn forces_are_negative_energy_gradient_through_stack() {
    // finite-difference check END TO END: perturb one coordinate, compare
    // dE/dx from the fwd artifact against the returned force.
    let Some(engine) = engine() else { return };
    let Ok(exe) = engine.load("ff_fwd_B1") else { return };
    let state: Vec<Tensor> = engine
        .load_state_blob("ff_state_init")
        .unwrap()
        .into_iter()
        .map(|(_, t)| t)
        .collect();
    let graphs = gen_bpa_dataset(&[0.05], 1, 2).remove(0);
    let run = |pos_override: Option<(usize, usize, f64)>| -> (f64, Vec<f32>) {
        let mut g = graphs[0].clone();
        if let Some((atom, axis, delta)) = pos_override {
            g.pos[atom][axis] += delta;
        }
        let pb = PaddedBatch::from_graphs(
            std::slice::from_ref(&g), 1, 32, 128, 4.0,
        );
        let mut inputs = state.clone();
        inputs.extend(ff_batch_tensors(&pb, false));
        let out = exe.run(&inputs).unwrap();
        (
            out[0].as_f32().unwrap()[0] as f64,
            out[1].as_f32().unwrap().to_vec(),
        )
    };
    let (_, forces) = run(None);
    let h = 1e-3;
    for (atom, axis) in [(0usize, 0usize), (5, 1), (13, 2)] {
        let (ep, _) = run(Some((atom, axis, h)));
        let (em, _) = run(Some((atom, axis, -h)));
        let fd = -(ep - em) / (2.0 * h);
        let f = forces[(atom * 3 + axis)] as f64;
        assert!(
            (f - fd).abs() < 2e-2 * (1.0 + fd.abs()),
            "atom {atom} axis {axis}: force {f} vs -dE/dx {fd}"
        );
    }
}

#[test]
fn server_end_to_end() {
    let Some(engine) = engine() else { return };
    let server = match ForceFieldServer::start(
        engine,
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
                max_queue: 256,
            },
            n_workers: 2,
            ..Default::default()
        },
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let graphs = gen_bpa_dataset(&[0.05], 20, 3).remove(0);
    // batched path must agree with single-shot path
    let single = server
        .infer_blocking(graphs[0].pos.clone(), graphs[0].species.clone())
        .unwrap();
    let tickets: Vec<_> = graphs
        .iter()
        .map(|g| server.submit(g.pos.clone(), g.species.clone()).unwrap())
        .collect();
    let responses: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap())
        .collect();
    assert_eq!(responses.len(), 20);
    // request 0 is the same structure as the single-shot call
    let batched = &responses[0];
    assert!((batched.energy - single.energy).abs() < 1e-3,
            "batched vs single energy: {} vs {}", batched.energy, single.energy);
    for (a, b) in batched.forces.iter().zip(&single.forces) {
        for k in 0..3 {
            assert!((a[k] - b[k]).abs() < 1e-3,
                    "padding/batching must not change results");
        }
    }
    assert!(server.metrics().mean_batch_size() >= 1.0);
    server.shutdown();
}

#[test]
fn nbody_artifacts_run() {
    let Some(engine) = engine() else { return };
    for tp in ["gaunt", "cg"] {
        let name = format!("nbody_fwd_{tp}");
        let Ok(exe) = engine.load(&name) else {
            eprintln!("skipping {name}");
            return;
        };
        let inputs: Vec<Tensor> = exe
            .inputs
            .iter()
            .map(|s| match s.dtype {
                gaunt_tp::runtime::DType::F32 => Tensor::F32(vec![0.1; s.numel()]),
                gaunt_tp::runtime::DType::I32 => Tensor::I32(vec![0; s.numel()]),
            })
            .collect();
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }
}
