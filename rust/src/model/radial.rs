//! Radial basis for the edge embedding: Gaussian RBFs under a smooth
//! polynomial cutoff envelope.
//!
//! `rb_k(r) = exp(-beta (r - mu_k)^2) * (1 - (r/rc)^2)^2` with centers
//! `mu_k` spread evenly over `[0, rc]` and `beta = (K/rc)^2`.  Both the
//! value and the derivative vanish at the cutoff, so the learned energy
//! stays C^1 as atoms cross the neighbor-list boundary — without that,
//! the finite-difference force checks (and MD energy conservation) would
//! see kinks every time an edge appears or disappears.
//!
//! Mirrored bit-for-bit by `python/compile/model_golden.py::radial_basis`.

/// Gaussian RBF bank with a smooth cutoff.
#[derive(Clone, Debug)]
pub struct RadialBasis {
    pub n: usize,
    pub r_cut: f64,
    centers: Vec<f64>,
    beta: f64,
}

impl RadialBasis {
    pub fn new(n: usize, r_cut: f64) -> RadialBasis {
        assert!(n >= 2, "radial basis needs >= 2 centers");
        assert!(r_cut > 0.0);
        let centers = (0..n)
            .map(|k| k as f64 * r_cut / (n - 1) as f64)
            .collect();
        RadialBasis { n, r_cut, centers, beta: (n as f64 / r_cut).powi(2) }
    }

    /// Values and d/dr of every basis function at `r`, into caller
    /// buffers of `n` entries each (allocation-free).
    pub fn eval_into(&self, r: f64, val: &mut [f64], dval: &mut [f64]) {
        debug_assert!(val.len() >= self.n && dval.len() >= self.n);
        if r >= self.r_cut {
            val[..self.n].fill(0.0);
            dval[..self.n].fill(0.0);
            return;
        }
        let t = r / self.r_cut;
        let env = (1.0 - t * t) * (1.0 - t * t);
        let denv = -4.0 * t * (1.0 - t * t) / self.r_cut;
        for k in 0..self.n {
            let dr = r - self.centers[k];
            let g = (-self.beta * dr * dr).exp();
            let dg = -2.0 * self.beta * dr * g;
            val[k] = g * env;
            dval[k] = dg * env + g * denv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivative_matches_finite_differences() {
        let rb = RadialBasis::new(6, 3.5);
        let h = 1e-6;
        let mut v = vec![0.0; 6];
        let mut d = vec![0.0; 6];
        let mut vp = vec![0.0; 6];
        let mut vm = vec![0.0; 6];
        let mut scratch = vec![0.0; 6];
        for r in [0.1, 0.9, 1.7, 2.6, 3.3] {
            rb.eval_into(r, &mut v, &mut d);
            rb.eval_into(r + h, &mut vp, &mut scratch);
            rb.eval_into(r - h, &mut vm, &mut scratch);
            for k in 0..6 {
                let fd = (vp[k] - vm[k]) / (2.0 * h);
                assert!(
                    (d[k] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                    "r={r} k={k}: {} vs {fd}",
                    d[k]
                );
            }
        }
    }

    #[test]
    fn smooth_at_cutoff() {
        let rb = RadialBasis::new(5, 2.0);
        let mut v = vec![0.0; 5];
        let mut d = vec![0.0; 5];
        rb.eval_into(1.999999, &mut v, &mut d);
        // value and slope both -> 0 at rc (C^1 across the cutoff)
        assert!(v.iter().all(|x| x.abs() < 1e-9));
        assert!(d.iter().all(|x| x.abs() < 1e-4));
        rb.eval_into(2.5, &mut v, &mut d);
        assert!(v.iter().all(|&x| x == 0.0));
        assert!(d.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn covers_the_range() {
        let rb = RadialBasis::new(8, 4.0);
        let mut v = vec![0.0; 8];
        let mut d = vec![0.0; 8];
        for i in 1..20 {
            let r = 3.6 * i as f64 / 20.0;
            rb.eval_into(r, &mut v, &mut d);
            assert!(v.iter().cloned().fold(0.0, f64::max) > 1e-3, "r={r}");
        }
    }
}
