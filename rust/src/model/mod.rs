//! The learned force field: a MACE-style equivariant message-passing
//! model whose every tensor contraction routes through the planned Gaunt
//! engine (DESIGN.md §"The model stack").
//!
//! Node features are typed by an [`Irreps`]: `channels` channels of real
//! SH coefficients per atom (degree <= L, layout
//! [`Irreps::spherical`]`(channels, L)` — degree-major panels
//! `[l][channel][m]`, so `channels = 1` is byte-compatible with the
//! historical single-channel layout and its frozen goldens).  Channels
//! evolve through per-`(channel, l)` path weights and shared plans; the
//! readout sums each channel's invariants.  Per interaction layer:
//!
//! 1. **Edge embedding** — radial basis [`radial::RadialBasis`] x
//!    spherical harmonics of the edge direction
//!    ([`crate::so3::sh::real_sh_grad_xyz_into`]: values AND Cartesian
//!    gradients, so the force backward pass is analytic end to end).
//! 2. **eSCN-style equivariant convolution** — the per-edge, per-channel
//!    message `m_e^c = P_L(h_j^c * f_e^c)` with the degree-weighted
//!    filter `f_e^c[lm] = h2_e[c, l2] Y_lm(u_e)`, evaluated by
//!    [`GauntConvPlan::apply_full_into`] (aligned-filter fast path,
//!    allocation-free rotation round trip; one shared plan, per-channel
//!    radial weights).
//! 3. **Many-body update** — `b_i^c = P_L((a_i^c)^nu)` through
//!    [`ManyBodyPlan::apply_self_into`] (one transform, pointwise
//!    nu-th power), then a per-path residual mix
//!    `h' = res (.) h + mix_a (.) a + mix_b (.) b` over the full
//!    multi-channel layout ([`Irreps::scale_paths_add`]).
//! 4. **Invariant readout** — `e_i = bias[s_i] + c_lin sum_c h^c[0] +
//!    c_quad sum_c (h^c (x) h^c)[0]`, the quadratic invariant evaluated
//!    by a `(L, L, 0)` [`GauntPlan`] per channel.
//!
//! **Backward convention.** The real Gaunt tensor `G[k,i,j] = int Y_k
//! Y_i Y_j dOmega` is symmetric under any permutation of its three
//! slots, so every VJP of a Gaunt product is itself a Gaunt product with
//! the degrees rotated:
//!
//! ```text
//!   y = P_{L3}(f_x f_w)          (plan (L1, L2, L3))
//!   dL/dx = P_{L1}(f_g f_w)      (plan (L3, L2, L1))
//!   dL/dw = P_{L2}(f_g f_x)      (plan (L3, L1, L2))
//!   b = P_L(f_a^nu)              (ManyBodyPlan)
//!   dL/da = nu P_L(f_g f_a^{nu-1})   (a^{nu-1} from a (nu-1)-fold
//!            self-product, truncated to 2L by the selection rules)
//! ```
//!
//! so the backward pass runs on the same cached plans as the forward —
//! channels share the plans and differ only in the per-path weights
//! (whose gradients are [`Irreps::dot_paths_add`], the exact adjoint of
//! the mix).  Position gradients (= -forces) flow through the radial
//! basis derivative and the pole-free SH Cartesian gradient.  Every
//! identity is validated against central differences by
//! `python/compile/model_golden.py --check` and `tests/grad_check.rs`
//! (the `channels > 1` configurations by the latter).
//!
//! All `_into` entry points are **allocation-free in steady state**
//! (asserted by `tests/alloc_regression.rs`): plans come from the global
//! [`PlanCache`], intermediates live in a caller-owned [`ModelScratch`]
//! (including the per-channel gather/scatter staging), and batched
//! inference shards graphs across workers with one scratch each via
//! [`crate::util::pool::shard_rows_with`].

pub mod dipole;
pub mod radial;

use std::sync::Arc;

use crate::err;
use crate::md::neighbor::{neighbors_cell, neighbors_periodic_cell,
                          neighbors_periodic_par, Cell};
use crate::so3::sh::real_sh_grad_xyz_into;
use crate::tp::engine::PlanCache;
use crate::tp::escn::{GauntConvPlan, GauntConvScratch};
use crate::tp::gaunt::{ConvMethod, GauntPlan, GauntScratch};
use crate::tp::irreps::Irreps;
use crate::tp::many_body::{ManyBodyPlan, ManyBodyScratch};
use crate::util::error::Result;
use crate::util::failpoint;
use crate::util::json::{self, Json};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::{lm_index, num_coeffs};
use dipole::{DipoleHead, DipoleScratch};
use radial::RadialBasis;

/// 1 / sqrt(4 pi): the value of Y_00, used by the closed-form VJP of the
/// quadratic readout invariant `(h (x) h)[0] = sum_j h_j^2 / sqrt(4 pi)`.
const INV_SQRT_4PI: f64 = 0.28209479177387814;

/// Hyperparameters of the learned force field.  `max_atoms`/`max_edges`
/// size the scratch buffers (a single inference may not exceed them —
/// the serving path checks and refuses loudly).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelConfig {
    /// feature degree L
    pub l: usize,
    /// filter degree of the edge convolution
    pub l_filter: usize,
    /// many-body correlation order (>= 2)
    pub nu: usize,
    /// feature multiplicity: node features are
    /// [`Irreps::spherical`]`(channels, l)` (1 = the historical
    /// single-channel model, checkpoint-compatible)
    pub channels: usize,
    /// interaction layers
    pub n_layers: usize,
    pub n_species: usize,
    /// radial basis size
    pub n_radial: usize,
    /// neighbor cutoff (the radial envelope vanishes smoothly here)
    pub r_cut: f64,
    /// convolution backend for every Gaunt plan (forward conv dispatch
    /// and all backward-pass plans)
    pub method: ConvMethod,
    pub max_atoms: usize,
    pub max_edges: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            l: 2,
            l_filter: 2,
            nu: 2,
            channels: 1,
            n_layers: 2,
            n_species: 3,
            n_radial: 6,
            r_cut: 3.5,
            method: ConvMethod::Auto,
            max_atoms: 32,
            max_edges: 1024,
        }
    }
}

impl ModelConfig {
    /// Per-channel feature width `(L+1)^2` (what every plan consumes).
    pub fn nf(&self) -> usize {
        num_coeffs(self.l)
    }

    /// Filter feature width.
    pub fn nff(&self) -> usize {
        num_coeffs(self.l_filter)
    }

    /// Full node-feature layout: `channels` channels of degrees
    /// `0..=l`, degree-major panels.
    pub fn node_irreps(&self) -> Irreps {
        Irreps::spherical(self.channels, self.l)
    }

    /// Flat node-feature width `channels * (L+1)^2`.
    pub fn node_dim(&self) -> usize {
        self.channels * self.nf()
    }

    /// Degree of the saved `a^(nu-1)` power: Gaunt selection rules cut
    /// everything above 2L out of the many-body VJP.
    pub fn l_pow(&self) -> usize {
        ((self.nu - 1) * self.l).min(2 * self.l)
    }

    fn per_layer_params(&self) -> usize {
        self.channels * ((self.l_filter + 1) * self.n_radial
                         + 3 * (self.l + 1))
    }

    /// Total parameter count (layout documented at [`Model::params`]).
    pub fn n_params(&self) -> usize {
        2 * self.n_species + self.n_layers * self.per_layer_params() + 2
    }
}

/// Parameter layout offsets (shared with
/// `python/compile/model_golden.py::param_views`, whose single-channel
/// layout is the `channels = 1` case):
/// `[species_embed S][species_bias S]` then per layer
/// `[w_rad (Lf+1)*C*K  — row (l2, c) at (l2*C + c)*K]`
/// `[mix_res C*(L+1)][mix_a C*(L+1)][mix_b C*(L+1)  — path (l, c) at
/// l*C + c, the `Irreps` path order]`, then `[c_lin, c_quad]`.
struct Offsets {
    embed: usize,
    bias: usize,
    layer0: usize,
    per_layer: usize,
    w_rad: usize,
    mix_res: usize,
    mix_a: usize,
    mix_b: usize,
    readout: usize,
}

impl Offsets {
    fn new(cfg: &ModelConfig) -> Offsets {
        let w_rad_len = (cfg.l_filter + 1) * cfg.channels * cfg.n_radial;
        let mix_len = cfg.channels * (cfg.l + 1);
        let per_layer = cfg.per_layer_params();
        Offsets {
            embed: 0,
            bias: cfg.n_species,
            layer0: 2 * cfg.n_species,
            per_layer,
            w_rad: 0,
            mix_res: w_rad_len,
            mix_a: w_rad_len + mix_len,
            mix_b: w_rad_len + 2 * mix_len,
            readout: 2 * cfg.n_species + cfg.n_layers * per_layer,
        }
    }

    fn layer(&self, t: usize) -> usize {
        self.layer0 + t * self.per_layer
    }
}

/// The learned force field (parameters + resolved plans).  Cheap to
/// share behind an `Arc`; per-thread mutable state lives in
/// [`ModelScratch`].
pub struct Model {
    pub cfg: ModelConfig,
    /// flat parameter vector (layout above)
    pub params: Vec<f64>,
    rb: RadialBasis,
    off: Offsets,
    /// node-feature layout (degree-major channel panels)
    nir: Irreps,
    /// filter layout (single channel of degrees 0..=l_filter)
    fir: Irreps,
    /// forward conv plan (aligned-filter fast path), (L, Lf, L)
    conv: Arc<GauntConvPlan>,
    /// message VJP w.r.t. the source feature, plan (L, Lf, L)
    vjp_x: Arc<GauntPlan>,
    /// message VJP w.r.t. the filter, plan (L, L, Lf)
    vjp_f: Arc<GauntPlan>,
    /// many-body self-product, (nu, L, L)
    mb: Arc<ManyBodyPlan>,
    /// the (nu-1)-fold power for the many-body VJP (None when nu == 2:
    /// the power is `a` itself)
    mb_pow: Option<Arc<ManyBodyPlan>>,
    /// many-body VJP, plan (L, l_pow, L)
    vjp_mb: Arc<GauntPlan>,
    /// quadratic readout invariant, plan (L, L, 0)
    quad: Arc<GauntPlan>,
}

/// Caller-owned workspace: every intermediate of one forward+backward
/// pass, sized once from the config — one per worker thread.
pub struct ModelScratch {
    // plan scratches
    conv_s: GauntConvScratch,
    vjp_x_s: GauntScratch,
    vjp_f_s: GauntScratch,
    vjp_mb_s: GauntScratch,
    quad_s: GauntScratch,
    mb_s: ManyBodyScratch,
    mb_pow_s: Option<ManyBodyScratch>,
    // per-edge geometry (shared by all layers)
    er: Vec<f64>,          // [max_e] edge length
    eu: Vec<[f64; 3]>,     // [max_e] unit direction (pos_i - pos_j)/r
    ey: Vec<f64>,          // [max_e * nff] SH values of the direction
    egy: Vec<[f64; 3]>,    // [max_e * nff] SH Cartesian gradients
    erb: Vec<f64>,         // [max_e * K] radial basis values
    edrb: Vec<f64>,        // [max_e * K] radial basis derivatives
    eh2: Vec<f64>,         // [n_layers * max_e * C * (Lf+1)] filter weights
    // per-atom state (saved for the backward pass); nd = C * (L+1)^2
    h: Vec<f64>,           // [(n_layers+1) * max_a * nd]
    a: Vec<f64>,           // [n_layers * max_a * nd] aggregated messages
    b: Vec<f64>,           // [n_layers * max_a * nd] many-body features
    pw: Vec<f64>,          // [n_layers * max_a * C * npow] a^(nu-1) powers
    inv: Vec<f64>,         // [max_a] quadratic readout invariants
    // backward work buffers
    g_h: Vec<f64>,         // [max_a * nd]
    g_hprev: Vec<f64>,     // [max_a * nd]
    g_a: Vec<f64>,         // [max_a * nd]
    g_b: Vec<f64>,         // [nd]
    g_f: Vec<f64>,         // [nff]
    msg: Vec<f64>,         // [nf] single-channel message / VJP staging
    filt: Vec<f64>,        // [nff] filter coefficients
    ch_a: Vec<f64>,        // [nf] channel gather staging (primary)
    ch_b: Vec<f64>,        // [nf] channel gather staging (secondary)
    one: Vec<f64>,         // [1] quad-plan output
    /// internal parameter-gradient buffer for force-only calls
    gparams: Vec<f64>,
}

impl Model {
    /// Random initialization (scales mirrored from the Python reference:
    /// O(1) scalars, residual mixes at 1, modest message/many-body
    /// mixes).  For `channels = 1` and a fixed seed this reproduces the
    /// historical single-channel initialization draw for draw.
    pub fn new(cfg: ModelConfig, seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let mut params = vec![0.0; cfg.n_params()];
        let off = Offsets::new(&cfg);
        for s in 0..cfg.n_species {
            params[off.embed + s] = 1.0 + 0.3 * rng.normal();
            params[off.bias + s] = 0.1 * rng.normal();
        }
        let w_scale = 0.8 / (cfg.n_radial as f64).sqrt();
        let w_rad_len = (cfg.l_filter + 1) * cfg.channels * cfg.n_radial;
        let n_paths = cfg.channels * (cfg.l + 1);
        for t in 0..cfg.n_layers {
            let lt = off.layer(t);
            for k in 0..w_rad_len {
                params[lt + off.w_rad + k] = w_scale * rng.normal();
            }
            for pth in 0..n_paths {
                params[lt + off.mix_res + pth] = 1.0;
                params[lt + off.mix_a + pth] = 0.5 + 0.1 * rng.normal();
                params[lt + off.mix_b + pth] = 0.3 + 0.1 * rng.normal();
            }
        }
        params[off.readout] = 0.5;
        params[off.readout + 1] = 0.5;
        Model::from_params(cfg, params)
    }

    /// Build from an explicit parameter vector (checkpoints, goldens).
    pub fn from_params(cfg: ModelConfig, params: Vec<f64>) -> Model {
        assert!(cfg.nu >= 2, "many-body order must be >= 2");
        assert!(cfg.n_layers >= 1);
        assert!(cfg.channels >= 1, "need at least one feature channel");
        // the filter VJP projects a degree-2L product grid onto degree
        // l_filter, which the f2sh panels require to fit inside the grid
        assert!(cfg.l_filter <= 2 * cfg.l,
                "l_filter must be <= 2*l (got l_filter={}, l={})",
                cfg.l_filter, cfg.l);
        assert_eq!(params.len(), cfg.n_params(), "parameter layout mismatch");
        let cache = PlanCache::global();
        let (l, lf, lp) = (cfg.l, cfg.l_filter, cfg.l_pow());
        Model {
            rb: RadialBasis::new(cfg.n_radial, cfg.r_cut),
            off: Offsets::new(&cfg),
            nir: cfg.node_irreps(),
            fir: Irreps::single(lf),
            conv: cache.gaunt_conv(l, lf, l),
            vjp_x: cache.gaunt(l, lf, l, cfg.method),
            vjp_f: cache.gaunt(l, l, lf, cfg.method),
            mb: cache.many_body(cfg.nu, l, l),
            mb_pow: if cfg.nu > 2 {
                Some(cache.many_body(cfg.nu - 1, l, lp))
            } else {
                None
            },
            vjp_mb: cache.gaunt(l, lp, l, cfg.method),
            quad: cache.gaunt(l, l, 0, cfg.method),
            cfg,
            params,
        }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// An independent copy of this model (config + parameters; plans
    /// are shared through the global cache).  This is the promotion
    /// unit for the serving registry: a trainer snapshots, the service
    /// hot-swaps, and the trainer keeps mutating its own parameters.
    pub fn snapshot(&self) -> Model {
        Model::from_params(self.cfg, self.params.clone())
    }

    /// The node-feature layout contract.
    pub fn node_irreps(&self) -> &Irreps {
        &self.nir
    }

    /// Fresh scratch sized for this model (one per worker thread).
    pub fn scratch(&self) -> ModelScratch {
        let c = &self.cfg;
        let (nf, nff, npow) = (c.nf(), c.nff(), num_coeffs(c.l_pow()));
        let nd = c.node_dim();
        let (ma, me, nl, cc) =
            (c.max_atoms, c.max_edges, c.n_layers, c.channels);
        ModelScratch {
            conv_s: self.conv.scratch(),
            vjp_x_s: self.vjp_x.scratch(),
            vjp_f_s: self.vjp_f.scratch(),
            vjp_mb_s: self.vjp_mb.scratch(),
            quad_s: self.quad.scratch(),
            mb_s: self.mb.scratch(),
            mb_pow_s: self.mb_pow.as_ref().map(|p| p.scratch()),
            er: vec![0.0; me],
            eu: vec![[0.0; 3]; me],
            ey: vec![0.0; me * nff],
            egy: vec![[0.0; 3]; me * nff],
            erb: vec![0.0; me * c.n_radial],
            edrb: vec![0.0; me * c.n_radial],
            eh2: vec![0.0; nl * me * cc * (c.l_filter + 1)],
            h: vec![0.0; (nl + 1) * ma * nd],
            a: vec![0.0; nl * ma * nd],
            b: vec![0.0; nl * ma * nd],
            pw: vec![0.0; nl * ma * cc * npow],
            inv: vec![0.0; ma],
            g_h: vec![0.0; ma * nd],
            g_hprev: vec![0.0; ma * nd],
            g_a: vec![0.0; ma * nd],
            g_b: vec![0.0; nd],
            g_f: vec![0.0; nff],
            msg: vec![0.0; nf],
            filt: vec![0.0; nff],
            ch_a: vec![0.0; nf],
            ch_b: vec![0.0; nf],
            one: vec![0.0; 1],
            gparams: vec![0.0; self.params.len()],
        }
    }

    /// Pre-build every lazily constructed shared table (FFT twiddles,
    /// Wigner fit caches) by running one tiny inference — the serving
    /// analog of the XLA path's eager compile.
    pub fn warm(&self) {
        let d = 0.4 * self.cfg.r_cut;
        let pos = [[0.0, 0.0, 0.0], [d, 0.25 * d, 0.1 * d]];
        let species = [0usize, 0];
        let edges = [(0usize, 1usize), (1usize, 0usize)];
        let mut scratch = self.scratch();
        let mut forces = [0.0; 6];
        let _ = self.energy_forces_into(&pos, &species, &edges, &mut forces,
                                        &mut scratch);
    }

    /// Directed neighbor list for one structure at the model's cutoff.
    pub fn build_edges(&self, pos: &[[f64; 3]]) -> Vec<(usize, usize)> {
        neighbors_cell(pos, self.cfg.r_cut)
    }

    /// Periodic directed neighbor list at the model's cutoff: pairs plus
    /// per-edge Cartesian image-shift vectors, the `shifts` input of
    /// [`Model::energy_forces_into_shifted`].  Edge displacement
    /// convention (DESIGN.md §13): `d = pos[i] - pos[j] + shift`.
    pub fn build_edges_periodic(
        &self, pos: &[[f64; 3]], cell: &Cell,
    ) -> (Vec<(usize, usize)>, Vec<[f64; 3]>) {
        let raw = neighbors_periodic_cell(pos, cell, self.cfg.r_cut);
        Self::split_periodic_edges(raw, cell)
    }

    /// [`Model::build_edges_periodic`] with the cell-list walk sharded
    /// across `threads` workers (`0` = all cores) by cell block.
    pub fn build_edges_periodic_par(
        &self, pos: &[[f64; 3]], cell: &Cell, threads: usize,
    ) -> (Vec<(usize, usize)>, Vec<[f64; 3]>) {
        let raw = neighbors_periodic_par(pos, cell, self.cfg.r_cut, threads);
        Self::split_periodic_edges(raw, cell)
    }

    fn split_periodic_edges(
        raw: Vec<crate::md::neighbor::Edge>, cell: &Cell,
    ) -> (Vec<(usize, usize)>, Vec<[f64; 3]>) {
        let mut pairs = Vec::with_capacity(raw.len());
        let mut shifts = Vec::with_capacity(raw.len());
        for e in raw {
            pairs.push((e.i, e.j));
            shifts.push(cell.shift_vector(e.shift));
        }
        (pairs, shifts)
    }

    fn check_sizes(&self, pos: &[[f64; 3]], species: &[usize],
                   edges: &[(usize, usize)]) {
        assert_eq!(pos.len(), species.len());
        assert!(pos.len() <= self.cfg.max_atoms,
                "{} atoms exceed max_atoms {}", pos.len(), self.cfg.max_atoms);
        assert!(edges.len() <= self.cfg.max_edges,
                "{} edges exceed max_edges {}", edges.len(),
                self.cfg.max_edges);
        debug_assert!(species.iter().all(|&s| s < self.cfg.n_species));
        debug_assert!(edges.iter().all(|&(i, j)| {
            i != j && i < pos.len() && j < pos.len()
        }));
    }

    /// Forward pass over caller scratch: total energy, zero allocations
    /// in steady state.  `edges` is a directed neighbor list (both
    /// directions present, as produced by [`Model::build_edges`]).
    pub fn energy_into(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], s: &mut ModelScratch,
    ) -> f64 {
        self.energy_into_impl(pos, species, edges, None, s)
    }

    /// Periodic forward pass: like [`Model::energy_into`], but edge `e`
    /// uses displacement `pos[i] - pos[j] + shifts[e]` (the Cartesian
    /// image shift from [`Model::build_edges_periodic`]).  Everything
    /// downstream of the edge geometry — layers, backward pass, forces
    /// — is untouched: image shifts are position-independent constants,
    /// so dE/d(pos) flows through the identical cached geometry.
    pub fn energy_into_shifted(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], shifts: &[[f64; 3]],
        s: &mut ModelScratch,
    ) -> f64 {
        assert_eq!(shifts.len(), edges.len());
        self.energy_into_impl(pos, species, edges, Some(shifts), s)
    }

    /// Final node features of atom `i` (layout
    /// [`ModelConfig::node_irreps`]) after the forward pass that filled
    /// `s`.  Read-only view into the scratch, valid until the next
    /// forward — the input of equivariant readout heads like
    /// [`DipoleHead`].
    pub fn node_features<'a>(
        &self, s: &'a ModelScratch, i: usize,
    ) -> &'a [f64] {
        let nd = self.cfg.node_dim();
        let h_t = self.cfg.n_layers * self.cfg.max_atoms * nd;
        &s.h[h_t + i * nd..h_t + (i + 1) * nd]
    }

    /// Per-atom dipoles through a [`DipoleHead`], written to `out`
    /// (flat `3 n_atoms`, xyz order).  Must run over the scratch a
    /// matching forward pass just filled.  Zero allocations in steady
    /// state.
    pub fn dipoles_into(
        &self, head: &DipoleHead, n_atoms: usize, s: &ModelScratch,
        hs: &mut DipoleScratch, out: &mut [f64],
    ) {
        assert!(out.len() >= 3 * n_atoms);
        for i in 0..n_atoms {
            let mu = head.dipole_into(self.node_features(s, i), hs);
            out[3 * i..3 * i + 3].copy_from_slice(&mu);
        }
    }

    fn energy_into_impl(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], shifts: Option<&[[f64; 3]]>,
        s: &mut ModelScratch,
    ) -> f64 {
        self.check_sizes(pos, species, edges);
        let c = &self.cfg;
        let (nff, nh2, cc) = (c.nff(), c.l_filter + 1, c.channels);
        let nd = c.node_dim();
        let (ma, me, k) = (c.max_atoms, c.max_edges, c.n_radial);
        let n_mix = self.nir.n_paths();
        let n_atoms = pos.len();
        let p = &self.params;
        // --- edge geometry (shared by every layer) ---
        for (e, &(i, j)) in edges.iter().enumerate() {
            let sh = shifts.map_or([0.0; 3], |sv| sv[e]);
            let d = [
                pos[i][0] - pos[j][0] + sh[0],
                pos[i][1] - pos[j][1] + sh[1],
                pos[i][2] - pos[j][2] + sh[2],
            ];
            let r = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt()
                .max(1e-12);
            s.er[e] = r;
            s.eu[e] = [d[0] / r, d[1] / r, d[2] / r];
            real_sh_grad_xyz_into(
                c.l_filter, d,
                &mut s.ey[e * nff..(e + 1) * nff],
                &mut s.egy[e * nff..(e + 1) * nff],
            );
            self.rb.eval_into(
                r,
                &mut s.erb[e * k..(e + 1) * k],
                &mut s.edrb[e * k..(e + 1) * k],
            );
        }
        // --- node init: species embedding in every channel's scalar ---
        for i in 0..n_atoms {
            let row = &mut s.h[i * nd..(i + 1) * nd];
            row.fill(0.0);
            row[..cc].fill(p[self.off.embed + species[i]]);
        }
        // --- interaction layers ---
        for t in 0..c.n_layers {
            let lt = self.off.layer(t);
            let w_rad = &p[lt + self.off.w_rad
                ..lt + self.off.w_rad + nh2 * cc * k];
            let h_t = t * ma * nd;
            s.a[t * ma * nd..t * ma * nd + n_atoms * nd].fill(0.0);
            for (e, &(i, j)) in edges.iter().enumerate() {
                // per-(channel, filter-degree) weights from the radial
                // basis: h2[c][l2] = <w_rad[(l2, c)], rb(r_e)>
                {
                    let h2_all = &mut s.eh2[(t * me + e) * cc * nh2
                        ..(t * me + e + 1) * cc * nh2];
                    let rb = &s.erb[e * k..(e + 1) * k];
                    for ch in 0..cc {
                        for l2 in 0..nh2 {
                            h2_all[ch * nh2 + l2] = w_rad
                                [(l2 * cc + ch) * k..(l2 * cc + ch + 1) * k]
                                .iter()
                                .zip(rb)
                                .map(|(w, r)| w * r)
                                .sum();
                        }
                    }
                }
                // eSCN-style message through the aligned-filter fast
                // path, one shared plan applied per channel
                for ch in 0..cc {
                    {
                        let h_j = &s.h[h_t + j * nd..h_t + (j + 1) * nd];
                        self.nir.gather_channel(h_j, ch, &mut s.ch_a);
                    }
                    let h2 = &s.eh2[((t * me + e) * cc + ch) * nh2
                        ..((t * me + e) * cc + ch + 1) * nh2];
                    self.conv.apply_full_into(
                        &s.ch_a,
                        s.eu[e],
                        h2,
                        c.method,
                        &mut s.msg,
                        &mut s.conv_s,
                    );
                    let a_i =
                        &mut s.a[(t * ma + i) * nd..(t * ma + i + 1) * nd];
                    self.nir.scatter_channel_add(&s.msg, ch, a_i);
                }
            }
            // many-body update per channel + per-path residual mix
            let npow = num_coeffs(c.l_pow());
            for i in 0..n_atoms {
                for ch in 0..cc {
                    {
                        let a_i =
                            &s.a[(t * ma + i) * nd..(t * ma + i + 1) * nd];
                        self.nir.gather_channel(a_i, ch, &mut s.ch_a);
                    }
                    self.mb.apply_self_into(&s.ch_a, &mut s.msg, &mut s.mb_s);
                    let b_i =
                        &mut s.b[(t * ma + i) * nd..(t * ma + i + 1) * nd];
                    self.nir.scatter_channel(&s.msg, ch, b_i);
                    let pw_i = &mut s.pw[((t * ma + i) * cc + ch) * npow
                        ..((t * ma + i) * cc + ch + 1) * npow];
                    match (&self.mb_pow, &mut s.mb_pow_s) {
                        (Some(plan), Some(ps)) => {
                            plan.apply_self_into(&s.ch_a, pw_i, ps)
                        }
                        // nu == 2: the (nu-1)-fold power is `a` itself
                        _ => pw_i.copy_from_slice(&s.ch_a),
                    }
                }
            }
            for i in 0..n_atoms {
                let (head, tail) = s.h.split_at_mut((t + 1) * ma * nd);
                let h_prev = &head[h_t + i * nd..h_t + (i + 1) * nd];
                let h_next = &mut tail[i * nd..(i + 1) * nd];
                h_next.fill(0.0);
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_res..lt + self.off.mix_res + n_mix],
                    h_prev, h_next,
                );
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_a..lt + self.off.mix_a + n_mix],
                    &s.a[(t * ma + i) * nd..(t * ma + i + 1) * nd], h_next,
                );
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_b..lt + self.off.mix_b + n_mix],
                    &s.b[(t * ma + i) * nd..(t * ma + i + 1) * nd], h_next,
                );
            }
        }
        // --- invariant readout (summed over channels) ---
        let (c_lin, c_quad) =
            (p[self.off.readout], p[self.off.readout + 1]);
        let h_t = c.n_layers * ma * nd;
        let mut energy = 0.0;
        for i in 0..n_atoms {
            let mut inv_i = 0.0;
            let mut lin_i = 0.0;
            for ch in 0..cc {
                {
                    let h_i = &s.h[h_t + i * nd..h_t + (i + 1) * nd];
                    self.nir.gather_channel(h_i, ch, &mut s.ch_a);
                }
                self.quad.apply_into(&s.ch_a, &s.ch_a, &mut s.one,
                                     &mut s.quad_s);
                inv_i += s.one[0];
                lin_i += s.ch_a[0];
            }
            s.inv[i] = inv_i;
            energy += p[self.off.bias + species[i]] + c_lin * lin_i
                + c_quad * inv_i;
        }
        energy
    }

    /// Reverse pass.  ACCUMULATES into `forces` (flat `3 * n_atoms`,
    /// `F = -dE/dx`) and `gparams` (`n_params`); the caller zeroes them.
    /// Must run over the scratch a matching [`Model::energy_into`] just
    /// filled.  Zero allocations in steady state.
    fn backward(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], s: &mut ModelScratch,
        forces: &mut [f64], gparams: &mut [f64],
    ) {
        let c = &self.cfg;
        let (nff, nh2, cc) = (c.nff(), c.l_filter + 1, c.channels);
        let nd = c.node_dim();
        let (ma, me, k) = (c.max_atoms, c.max_edges, c.n_radial);
        let n_mix = self.nir.n_paths();
        let n_atoms = pos.len();
        debug_assert!(forces.len() >= 3 * n_atoms);
        debug_assert_eq!(gparams.len(), self.params.len());
        let p = &self.params;
        let (c_lin, c_quad) =
            (p[self.off.readout], p[self.off.readout + 1]);
        // --- readout cotangents ---
        let h_t = c.n_layers * ma * nd;
        for i in 0..n_atoms {
            let h_i = &s.h[h_t + i * nd..h_t + (i + 1) * nd];
            // channel scalars are the first `cc` entries (degree-0 panel)
            gparams[self.off.readout] += h_i[..cc].iter().sum::<f64>();
            gparams[self.off.readout + 1] += s.inv[i];
            gparams[self.off.bias + species[i]] += 1.0;
            // d inv/dh = 2 h / sqrt(4 pi) componentwise: the closed form
            // of the (0, L, L) Gaunt VJP (Y_00 is constant), channel by
            // channel
            let g_i = &mut s.g_h[i * nd..(i + 1) * nd];
            for (gv, hv) in g_i.iter_mut().zip(h_i) {
                *gv = 2.0 * c_quad * INV_SQRT_4PI * hv;
            }
            for gv in g_i[..cc].iter_mut() {
                *gv += c_lin;
            }
        }
        // --- layers, top down ---
        let npow = num_coeffs(c.l_pow());
        let nu_f = c.nu as f64;
        for t in (0..c.n_layers).rev() {
            let lt = self.off.layer(t);
            let h_base = t * ma * nd;
            s.g_hprev[..n_atoms * nd].fill(0.0);
            s.g_a[..n_atoms * nd].fill(0.0);
            for i in 0..n_atoms {
                let g_h_i = &s.g_h[i * nd..(i + 1) * nd];
                let h_i = &s.h[h_base + i * nd..h_base + (i + 1) * nd];
                let a_i = &s.a[(t * ma + i) * nd..(t * ma + i + 1) * nd];
                let b_i = &s.b[(t * ma + i) * nd..(t * ma + i + 1) * nd];
                self.nir.dot_paths_add(
                    g_h_i, h_i,
                    &mut gparams[lt + self.off.mix_res
                                 ..lt + self.off.mix_res + n_mix],
                );
                self.nir.dot_paths_add(
                    g_h_i, a_i,
                    &mut gparams[lt + self.off.mix_a
                                 ..lt + self.off.mix_a + n_mix],
                );
                self.nir.dot_paths_add(
                    g_h_i, b_i,
                    &mut gparams[lt + self.off.mix_b
                                 ..lt + self.off.mix_b + n_mix],
                );
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_res..lt + self.off.mix_res + n_mix],
                    g_h_i, &mut s.g_hprev[i * nd..(i + 1) * nd],
                );
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_a..lt + self.off.mix_a + n_mix],
                    g_h_i, &mut s.g_a[i * nd..(i + 1) * nd],
                );
                s.g_b.fill(0.0);
                self.nir.scale_paths_add(
                    &p[lt + self.off.mix_b..lt + self.off.mix_b + n_mix],
                    g_h_i, &mut s.g_b,
                );
                // many-body VJP per channel: nu * P_L(f_g f_a^{nu-1})
                for ch in 0..cc {
                    self.nir.gather_channel(&s.g_b, ch, &mut s.ch_b);
                    self.vjp_mb.apply_into(
                        &s.ch_b,
                        &s.pw[((t * ma + i) * cc + ch) * npow
                              ..((t * ma + i) * cc + ch + 1) * npow],
                        &mut s.msg,
                        &mut s.vjp_mb_s,
                    );
                    for mv in s.msg.iter_mut() {
                        *mv *= nu_f;
                    }
                    self.nir.scatter_channel_add(
                        &s.msg, ch, &mut s.g_a[i * nd..(i + 1) * nd],
                    );
                }
            }
            // --- edges: message VJPs + geometry chain to the forces ---
            for (e, &(i, j)) in edges.iter().enumerate() {
                let y_e = &s.ey[e * nff..(e + 1) * nff];
                let gy_e = &s.egy[e * nff..(e + 1) * nff];
                let rb = &s.erb[e * k..(e + 1) * k];
                let drb = &s.edrb[e * k..(e + 1) * k];
                let mut g_r = 0.0;
                let mut g_d = [0.0f64; 3];
                for ch in 0..cc {
                    let h2 = &s.eh2[((t * me + e) * cc + ch) * nh2
                        ..((t * me + e) * cc + ch + 1) * nh2];
                    // rebuild the filter f_e[lm] = h2[ch][l2] y[lm]
                    s.filt.copy_from_slice(y_e);
                    self.fir.scale_paths_inplace(&mut s.filt, h2);
                    {
                        let g_a_i = &s.g_a[i * nd..(i + 1) * nd];
                        self.nir.gather_channel(g_a_i, ch, &mut s.ch_a);
                    }
                    // VJP w.r.t. the source feature h_j: P_L(f_g f_filt)
                    self.vjp_x.apply_into(&s.ch_a, &s.filt, &mut s.msg,
                                          &mut s.vjp_x_s);
                    self.nir.scatter_channel_add(
                        &s.msg, ch, &mut s.g_hprev[j * nd..(j + 1) * nd],
                    );
                    // VJP w.r.t. the filter: P_Lf(f_g f_hj)
                    {
                        let h_j = &s.h[h_base + j * nd..h_base + (j + 1) * nd];
                        self.nir.gather_channel(h_j, ch, &mut s.ch_b);
                    }
                    self.vjp_f.apply_into(&s.ch_a, &s.ch_b, &mut s.g_f,
                                          &mut s.vjp_f_s);
                    // chain through h2 (radial) and y (angular)
                    for l2 in 0..nh2 {
                        let base = lm_index(l2, -(l2 as i64));
                        let mut g_h2 = 0.0;
                        for m in 0..(2 * l2 + 1) {
                            g_h2 += s.g_f[base + m] * y_e[base + m];
                            for ax in 0..3 {
                                g_d[ax] += h2[l2] * s.g_f[base + m]
                                    * gy_e[base + m][ax];
                            }
                        }
                        let row = lt + self.off.w_rad + (l2 * cc + ch) * k;
                        let gw = &mut gparams[row..row + k];
                        for (gwv, rbv) in gw.iter_mut().zip(rb) {
                            *gwv += g_h2 * rbv;
                        }
                        let w_row = &p[row..row + k];
                        g_r += g_h2
                            * w_row.iter().zip(drb).map(|(w, d)| w * d)
                                .sum::<f64>();
                    }
                }
                for ax in 0..3 {
                    g_d[ax] += g_r * s.eu[e][ax];
                    // d = pos_i - pos_j and F = -dE/dpos
                    forces[3 * i + ax] -= g_d[ax];
                    forces[3 * j + ax] += g_d[ax];
                }
            }
            std::mem::swap(&mut s.g_h, &mut s.g_hprev);
        }
        // --- species embedding (every channel's scalar of h_0) ---
        for i in 0..n_atoms {
            gparams[self.off.embed + species[i]] +=
                s.g_h[i * nd..i * nd + cc].iter().sum::<f64>();
        }
    }

    /// Energy + forces over caller scratch: zero steady-state
    /// allocations.  `forces` is flat `3 * n_atoms` and is overwritten.
    pub fn energy_forces_into(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], forces: &mut [f64],
        s: &mut ModelScratch,
    ) -> f64 {
        let e = self.energy_into(pos, species, edges, s);
        forces[..3 * pos.len()].fill(0.0);
        let mut gp = std::mem::take(&mut s.gparams);
        gp.fill(0.0);
        self.backward(pos, species, edges, s, forces, &mut gp);
        s.gparams = gp;
        e
    }

    /// Periodic energy + forces over caller scratch (see
    /// [`Model::energy_into_shifted`] for the displacement convention).
    /// The backward pass reads only the cached edge geometry, so no
    /// shift plumbing is needed there; forces on atoms are exact
    /// gradients of the periodic energy.
    pub fn energy_forces_into_shifted(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], shifts: &[[f64; 3]],
        forces: &mut [f64], s: &mut ModelScratch,
    ) -> f64 {
        let e = self.energy_into_shifted(pos, species, edges, shifts, s);
        forces[..3 * pos.len()].fill(0.0);
        let mut gp = std::mem::take(&mut s.gparams);
        gp.fill(0.0);
        self.backward(pos, species, edges, s, forces, &mut gp);
        s.gparams = gp;
        e
    }

    /// Convenience periodic energy + forces (builds the periodic
    /// neighbor list and a scratch; use
    /// [`Model::energy_forces_into_shifted`] on hot paths).
    pub fn energy_forces_periodic(
        &self, pos: &[[f64; 3]], species: &[usize], cell: &Cell,
    ) -> (f64, Vec<[f64; 3]>) {
        let (edges, shifts) = self.build_edges_periodic(pos, cell);
        let mut s = self.scratch();
        let mut flat = vec![0.0; 3 * pos.len()];
        let e = self.energy_forces_into_shifted(
            pos, species, &edges, &shifts, &mut flat, &mut s);
        let forces = flat
            .chunks_exact(3)
            .map(|c3| [c3[0], c3[1], c3[2]])
            .collect();
        (e, forces)
    }

    /// Energy + forces + parameter gradient (the trainer's primitive).
    /// ACCUMULATES into `forces` and `gparams`; the caller zeroes them.
    pub fn grad_into(
        &self, pos: &[[f64; 3]], species: &[usize],
        edges: &[(usize, usize)], forces: &mut [f64],
        gparams: &mut [f64], s: &mut ModelScratch,
    ) -> f64 {
        let e = self.energy_into(pos, species, edges, s);
        self.backward(pos, species, edges, s, forces, gparams);
        e
    }

    /// Convenience forward (builds the neighbor list and a scratch).
    pub fn energy(&self, pos: &[[f64; 3]], species: &[usize]) -> f64 {
        let edges = self.build_edges(pos);
        let mut s = self.scratch();
        self.energy_into(pos, species, &edges, &mut s)
    }

    /// Convenience energy + forces (builds the neighbor list and a
    /// scratch; use the `_into` variants on hot paths).
    pub fn energy_forces(
        &self, pos: &[[f64; 3]], species: &[usize],
    ) -> (f64, Vec<[f64; 3]>) {
        let edges = self.build_edges(pos);
        let mut s = self.scratch();
        let mut flat = vec![0.0; 3 * pos.len()];
        let e = self.energy_forces_into(pos, species, &edges, &mut flat,
                                        &mut s);
        let forces = flat
            .chunks_exact(3)
            .map(|c3| [c3[0], c3[1], c3[2]])
            .collect();
        (e, forces)
    }

    // --- serialization (util::json; no serde offline) ---

    /// Checkpoint as a JSON document (config + flat parameters + an
    /// FNV-1a checksum over the parameter bits).  The node layout is
    /// also embedded as an `irreps` string for human readers and
    /// layout-checking tools.
    pub fn to_json(&self) -> Json {
        let c = &self.cfg;
        let method = match c.method {
            ConvMethod::Direct => "direct",
            ConvMethod::Fft => "fft",
            ConvMethod::Auto => "auto",
        };
        Json::obj(vec![
            ("config", Json::obj(vec![
                ("l", Json::Num(c.l as f64)),
                ("l_filter", Json::Num(c.l_filter as f64)),
                ("nu", Json::Num(c.nu as f64)),
                ("channels", Json::Num(c.channels as f64)),
                ("n_layers", Json::Num(c.n_layers as f64)),
                ("n_species", Json::Num(c.n_species as f64)),
                ("n_radial", Json::Num(c.n_radial as f64)),
                ("r_cut", Json::Num(c.r_cut)),
                ("method", Json::Str(method.to_string())),
                ("max_atoms", Json::Num(c.max_atoms as f64)),
                ("max_edges", Json::Num(c.max_edges as f64)),
                ("irreps", Json::Str(format!("{}", self.nir))),
            ])),
            ("params", Json::arr_f64(&self.params)),
            ("checksum", Json::Str(params_checksum(&self.params))),
        ])
    }

    /// Rebuild a model from [`Model::to_json`] output.  Checkpoints
    /// written before the multi-channel layout (no `channels` key) load
    /// as `channels = 1`, whose parameter layout is unchanged.
    pub fn from_json(doc: &Json) -> Result<Model> {
        let cj = doc.get("config").ok_or_else(|| err!("missing config"))?;
        let get = |k: &str| -> Result<usize> {
            cj.get(k).and_then(Json::as_usize)
                .ok_or_else(|| err!("config.{k} missing"))
        };
        let method = match cj.get("method").and_then(Json::as_str) {
            Some("direct") => ConvMethod::Direct,
            Some("fft") => ConvMethod::Fft,
            _ => ConvMethod::Auto,
        };
        let cfg = ModelConfig {
            l: get("l")?,
            l_filter: get("l_filter")?,
            nu: get("nu")?,
            channels: cj.get("channels").and_then(Json::as_usize)
                .unwrap_or(1),
            n_layers: get("n_layers")?,
            n_species: get("n_species")?,
            n_radial: get("n_radial")?,
            r_cut: cj.get("r_cut").and_then(Json::as_f64)
                .ok_or_else(|| err!("config.r_cut missing"))?,
            method,
            max_atoms: get("max_atoms")?,
            max_edges: get("max_edges")?,
        };
        if let Some(text) = cj.get("irreps").and_then(Json::as_str) {
            let declared = Irreps::parse(text)?;
            if declared != cfg.node_irreps() {
                return Err(err!(
                    "checkpoint irreps '{text}' disagree with config \
                     (expected {})", cfg.node_irreps()
                ));
            }
        }
        let params = doc
            .get("params")
            .and_then(Json::as_f64_vec)
            .ok_or_else(|| err!("missing params"))?;
        if params.len() != cfg.n_params() {
            return Err(err!(
                "checkpoint has {} params, config wants {}",
                params.len(), cfg.n_params()
            ));
        }
        // verify the parameter checksum when present (checkpoints written
        // before the checksum era have no field and are accepted as-is);
        // a mismatch means the file was truncated or bit-rotted after the
        // atomic rename — refuse it rather than serve garbage
        if let Some(stored) = doc.get("checksum").and_then(Json::as_str) {
            let actual = params_checksum(&params);
            if stored != actual {
                return Err(err!(
                    "parameter checksum mismatch (stored {stored}, \
                     recomputed {actual})"
                ));
            }
        }
        Ok(Model::from_params(cfg, params))
    }

    /// Write a JSON checkpoint to disk **atomically**: the document goes
    /// to a temp file in the same directory, is fsynced, and only then
    /// renamed over `path`.  A crash (or an injected `ckpt.write` fault)
    /// at any point leaves either the old checkpoint or the new one —
    /// never a torn file.
    pub fn save(&self, path: &str) -> Result<()> {
        use std::io::Write as _;
        let tmp = format!("{path}.tmp");
        let text = self.to_json().to_string();
        let res = (|| -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            f.sync_all()?;
            Ok(())
        })();
        if let Err(e) = res {
            let _ = std::fs::remove_file(&tmp);
            return Err(err!("checkpoint write {path}: {e}"));
        }
        // chaos site: simulate a crash between the durable temp write
        // and the rename — the original checkpoint must stay intact
        if let Some(failpoint::Fault::Error(m)) =
            failpoint::check("ckpt.write")
        {
            let _ = std::fs::remove_file(&tmp);
            return Err(err!("checkpoint write {path}: {m}"));
        }
        std::fs::rename(&tmp, path).map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            err!("checkpoint write {path}: rename failed: {e}")
        })
    }

    /// Load a JSON checkpoint from disk.  Parse failures, layout
    /// mismatches, and checksum mismatches all surface as a typed
    /// "Corrupt checkpoint" error naming the path.
    pub fn load(path: &str) -> Result<Model> {
        if let Some(f) = failpoint::check("ckpt.load") {
            let m = match f {
                failpoint::Fault::Error(m) => m,
                failpoint::Fault::Nan => "injected load fault".to_string(),
            };
            return Err(err!("Corrupt checkpoint {path}: {m}"));
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| err!("checkpoint read {path}: {e}"))?;
        let doc = json::parse(&text)
            .map_err(|e| err!("Corrupt checkpoint {path}: {e}"))?;
        Model::from_json(&doc)
            .map_err(|e| err!("Corrupt checkpoint {path}: {e}"))
    }
}

/// FNV-1a 64 over the parameter bit patterns (sign-of-zero normalized,
/// since the JSON integer fast path prints `-0.0` as `0`).  Fast,
/// dependency-free, and stable across platforms — this is an integrity
/// check against truncation/bit rot, not a cryptographic digest.
pub fn params_checksum(params: &[f64]) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in params {
        for b in (*p + 0.0).to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    format!("{h:016x}")
}

/// One structure by reference, for batched inference.  `shifts` is
/// `None` for open boundaries, or one Cartesian image-shift vector per
/// edge for periodic structures ([`Model::build_edges_periodic`]).
#[derive(Clone, Copy)]
pub struct GraphRef<'a> {
    pub pos: &'a [[f64; 3]],
    pub species: &'a [usize],
    pub edges: &'a [(usize, usize)],
    pub shifts: Option<&'a [[f64; 3]]>,
}

/// Row width of [`energy_forces_batch_par`] output:
/// `[energy, f_x0, f_y0, f_z0, ...]` padded to the model's atom capacity.
pub fn batch_row_len(model: &Model) -> usize {
    1 + 3 * model.cfg.max_atoms
}

/// Batched energy + forces, graphs sharded across `threads` workers
/// (`0` = all cores) with ONE scratch per worker
/// ([`pool::shard_rows_with`]) — the serving path's inference primitive:
/// steady-state per-graph work is allocation-free and bitwise identical
/// to the serial loop.  Row `g` of the result is
/// `[E_g, forces (3 * max_atoms, zero-padded)]`.
pub fn energy_forces_batch_par(
    model: &Model, graphs: &[GraphRef<'_>], threads: usize,
) -> Vec<f64> {
    let row_len = batch_row_len(model);
    let mut out = vec![0.0; graphs.len() * row_len];
    if graphs.is_empty() {
        return out;
    }
    let threads = pool::resolve_threads(threads);
    pool::shard_rows_with(
        &mut out,
        row_len,
        threads,
        || model.scratch(),
        |g, row, scratch| {
            let gr = &graphs[g];
            if gr.pos.is_empty() {
                return;
            }
            let (e_slot, f_slot) = row.split_at_mut(1);
            e_slot[0] = match gr.shifts {
                Some(shifts) => model.energy_forces_into_shifted(
                    gr.pos, gr.species, gr.edges, shifts,
                    &mut f_slot[..3 * gr.pos.len()], scratch,
                ),
                None => model.energy_forces_into(
                    gr.pos, gr.species, gr.edges,
                    &mut f_slot[..3 * gr.pos.len()], scratch,
                ),
            };
        },
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::max_abs_diff;

    fn toy(seed: u64, n: usize) -> (Vec<[f64; 3]>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| [1.5 * rng.normal(), 1.5 * rng.normal(),
                      1.5 * rng.normal()])
            .collect();
        let species = (0..n).map(|_| rng.below(3)).collect();
        (pos, species)
    }

    #[test]
    fn param_count_matches_layout() {
        let cfg = ModelConfig::default();
        let m = Model::new(cfg, 0);
        assert_eq!(m.params.len(), cfg.n_params());
        // S=3 embed + 3 bias + 2 layers * (3*6 w_rad + 3*3 mixes) + 2
        assert_eq!(cfg.n_params(), 6 + 2 * (18 + 9) + 2);
        // channels scale every per-layer family
        let cfg2 = ModelConfig { channels: 2, ..Default::default() };
        assert_eq!(cfg2.n_params(), 6 + 2 * 2 * (18 + 9) + 2);
        assert_eq!(cfg2.node_dim(), 2 * cfg2.nf());
        assert_eq!(cfg2.node_irreps().n_paths(), 2 * 3);
    }

    #[test]
    fn json_round_trip() {
        let m = Model::new(ModelConfig { nu: 3, ..Default::default() }, 5);
        let m2 = Model::from_json(&m.to_json()).unwrap();
        assert_eq!(m.cfg, m2.cfg);
        assert_eq!(m.params, m2.params);
        let (pos, species) = toy(1, 5);
        let (e1, f1) = m.energy_forces(&pos, &species);
        let (e2, f2) = m2.energy_forces(&pos, &species);
        assert_eq!(e1, e2);
        assert_eq!(f1, f2);
        // multi-channel configs round-trip too (channels + irreps keys)
        let m3 = Model::new(ModelConfig { channels: 3, ..Default::default() },
                            6);
        let m4 = Model::from_json(&m3.to_json()).unwrap();
        assert_eq!(m3.cfg, m4.cfg);
        assert_eq!(m3.params, m4.params);
    }

    #[test]
    fn checkpoints_without_channels_load_as_single_channel() {
        // a pre-multi-channel checkpoint: no `channels`, no `irreps`
        let m = Model::new(ModelConfig::default(), 9);
        let doc = m.to_json();
        let text = doc.to_string()
            .replace("\"channels\":1,", "")
            .replace("\"irreps\":\"1x0 + 1x1 + 1x2\",", "");
        let doc2 = json::parse(&text).unwrap();
        // both keys must REALLY be gone, or this test silently stops
        // exercising the legacy no-channels/no-irreps load path
        assert_eq!(doc2.get("config").and_then(|c| c.get("channels")), None);
        assert_eq!(doc2.get("config").and_then(|c| c.get("irreps")), None);
        let m2 = Model::from_json(&doc2).unwrap();
        assert_eq!(m2.cfg.channels, 1);
        assert_eq!(m.params, m2.params);
    }

    #[test]
    fn energy_into_matches_energy_forces_into() {
        let m = Model::new(ModelConfig::default(), 2);
        let (pos, species) = toy(3, 6);
        let edges = m.build_edges(&pos);
        let mut s = m.scratch();
        let e1 = m.energy_into(&pos, &species, &edges, &mut s);
        let mut f = vec![0.0; 3 * pos.len()];
        let e2 = m.energy_forces_into(&pos, &species, &edges, &mut f,
                                      &mut s);
        assert_eq!(e1, e2);
        assert!(f.iter().any(|v| v.abs() > 1e-9), "forces all zero");
        // Newton's third law: internal forces sum to zero
        for ax in 0..3 {
            let tot: f64 = f.chunks_exact(3).map(|c| c[ax]).sum();
            assert!(tot.abs() < 1e-9, "net force {tot} on axis {ax}");
        }
    }

    #[test]
    fn multi_channel_forward_backward_stay_consistent() {
        // the multi-channel assembly obeys the same global checks as the
        // single-channel model: energy reproducible, forces non-trivial,
        // Newton's third law exact
        for channels in [2usize, 3] {
            let m = Model::new(
                ModelConfig { channels, nu: 3, ..Default::default() }, 21);
            let (pos, species) = toy(11, 6);
            let edges = m.build_edges(&pos);
            let mut s = m.scratch();
            let e1 = m.energy_into(&pos, &species, &edges, &mut s);
            let mut f = vec![0.0; 3 * pos.len()];
            let e2 = m.energy_forces_into(&pos, &species, &edges, &mut f,
                                          &mut s);
            assert_eq!(e1, e2, "channels={channels}");
            assert!(f.iter().any(|v| v.abs() > 1e-9),
                    "channels={channels}: forces all zero");
            for ax in 0..3 {
                let tot: f64 = f.chunks_exact(3).map(|c| c[ax]).sum();
                assert!(tot.abs() < 1e-9,
                        "channels={channels}: net force {tot} axis {ax}");
            }
        }
    }

    #[test]
    fn multi_channel_model_decomposes_into_per_channel_models() {
        // channels interact only through the (linear) readout sum, so a
        // C-channel model must equal the sum of the C single-channel
        // models carved out of its parameter vector, minus the (C-1)
        // extra bias copies — this pins the per-(channel, l) parameter
        // layout exactly (a single mis-indexed weight breaks it)
        let cc = 3usize;
        let cfg = ModelConfig { channels: cc, nu: 3, ..Default::default() };
        let multi = Model::new(cfg, 51);
        let off_m = Offsets::new(&cfg);
        let cfg1 = ModelConfig { channels: 1, ..cfg };
        let off_s = Offsets::new(&cfg1);
        let (k, nh2) = (cfg.n_radial, cfg.l_filter + 1);
        let (pos, species) = toy(17, 6);
        let (e_multi, f_multi) = multi.energy_forces(&pos, &species);
        let bias_sum: f64 = species
            .iter()
            .map(|&s| multi.params[off_m.bias + s])
            .sum();
        let mut e_sum = 0.0;
        let mut f_sum = vec![[0.0f64; 3]; pos.len()];
        for c in 0..cc {
            // carve channel c's parameters into the single-channel layout
            let mut p1 = vec![0.0; cfg1.n_params()];
            p1[..2 * cfg.n_species]
                .copy_from_slice(&multi.params[..2 * cfg.n_species]);
            for t in 0..cfg.n_layers {
                let (lm, ls) = (off_m.layer(t), off_s.layer(t));
                for l2 in 0..nh2 {
                    for kk in 0..k {
                        p1[ls + off_s.w_rad + l2 * k + kk] = multi.params
                            [lm + off_m.w_rad + (l2 * cc + c) * k + kk];
                    }
                }
                for l in 0..=cfg.l {
                    p1[ls + off_s.mix_res + l] =
                        multi.params[lm + off_m.mix_res + l * cc + c];
                    p1[ls + off_s.mix_a + l] =
                        multi.params[lm + off_m.mix_a + l * cc + c];
                    p1[ls + off_s.mix_b + l] =
                        multi.params[lm + off_m.mix_b + l * cc + c];
                }
            }
            p1[off_s.readout] = multi.params[off_m.readout];
            p1[off_s.readout + 1] = multi.params[off_m.readout + 1];
            let single = Model::from_params(cfg1, p1);
            let (e_c, f_c) = single.energy_forces(&pos, &species);
            e_sum += e_c;
            for (fs, fc) in f_sum.iter_mut().zip(&f_c) {
                for ax in 0..3 {
                    fs[ax] += fc[ax];
                }
            }
        }
        let want_e = e_sum - (cc as f64 - 1.0) * bias_sum;
        assert!(
            (e_multi - want_e).abs() < 1e-9 * (1.0 + want_e.abs()),
            "multi-channel energy {e_multi} != decomposition {want_e}"
        );
        for (fm, fs) in f_multi.iter().zip(&f_sum) {
            for ax in 0..3 {
                assert!(
                    (fm[ax] - fs[ax]).abs() < 1e-9,
                    "force decomposition broke: {} vs {}", fm[ax], fs[ax]
                );
            }
        }
    }

    #[test]
    fn extra_channels_change_the_model() {
        // channels see independent weights, so a 2-channel model is not
        // the 1-channel model doubled
        let (pos, species) = toy(13, 5);
        let m1 = Model::new(ModelConfig::default(), 30);
        let m2 = Model::new(ModelConfig { channels: 2, ..Default::default() },
                            30);
        let (e1, _) = m1.energy_forces(&pos, &species);
        let (e2, _) = m2.energy_forces(&pos, &species);
        assert!((e1 - e2).abs() > 1e-9, "{e1} vs {e2}");
    }

    #[test]
    fn batch_par_matches_serial() {
        let m = Model::new(ModelConfig::default(), 7);
        let structures: Vec<_> = (0..5).map(|k| toy(40 + k, 6)).collect();
        let edge_lists: Vec<_> = structures
            .iter()
            .map(|(pos, _)| m.build_edges(pos))
            .collect();
        let graphs: Vec<GraphRef<'_>> = structures
            .iter()
            .zip(&edge_lists)
            .map(|((pos, species), edges)| GraphRef {
                pos, species, edges, shifts: None,
            })
            .collect();
        let serial = energy_forces_batch_par(&m, &graphs, 1);
        for threads in [2usize, 4, 0] {
            let par = energy_forces_batch_par(&m, &graphs, threads);
            assert!(max_abs_diff(&serial, &par) == 0.0,
                    "threads={threads}");
        }
        // rows decode to the per-graph convenience results
        let row_len = batch_row_len(&m);
        for (g, (pos, species)) in structures.iter().enumerate() {
            let (e, f) = m.energy_forces(pos, species);
            assert!((serial[g * row_len] - e).abs() < 1e-12);
            for (i, fi) in f.iter().enumerate() {
                for ax in 0..3 {
                    assert!(
                        (serial[g * row_len + 1 + 3 * i + ax] - fi[ax])
                            .abs() < 1e-12
                    );
                }
            }
        }
    }

    #[test]
    fn periodic_edges_match_open_for_isolated_cluster() {
        // a tight cluster in a huge box: periodic edges have all-zero
        // shifts and the shifted forward pass reproduces the open one
        let m = Model::new(ModelConfig { n_layers: 1, ..Default::default() },
                           3);
        let (pos, species) = toy(4, 5);
        let cell = Cell::cubic(60.0);
        let (edges_p, shifts) = m.build_edges_periodic(&pos, &cell);
        assert!(shifts.iter().all(|s| s == &[0.0, 0.0, 0.0]));
        let mut edges_open = m.build_edges(&pos);
        let mut edges_sorted = edges_p.clone();
        edges_open.sort_unstable();
        edges_sorted.sort_unstable();
        assert_eq!(edges_open, edges_sorted);
        let (e_open, f_open) = m.energy_forces(&pos, &species);
        let (e_per, f_per) = m.energy_forces_periodic(&pos, &species, &cell);
        assert!((e_open - e_per).abs() < 1e-10 * (1.0 + e_open.abs()));
        for (a, b) in f_open.iter().zip(&f_per) {
            for ax in 0..3 {
                assert!((a[ax] - b[ax]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn periodic_model_invariant_under_lattice_translation() {
        let m = Model::new(ModelConfig { n_layers: 1, ..Default::default() },
                           8);
        let cell = Cell::cubic(8.0); // default r_cut 3.5 < L/2
        let (pos, species) = toy(21, 6);
        let (e, f) = m.energy_forces_periodic(&pos, &species, &cell);
        // translating one atom by lattice vectors is a no-op
        let mut pos2 = pos.clone();
        pos2[2][0] += 8.0;
        pos2[2][2] -= 16.0;
        let (e2, f2) = m.energy_forces_periodic(&pos2, &species, &cell);
        assert!((e - e2).abs() < 1e-9 * (1.0 + e.abs()), "{e} vs {e2}");
        for (a, b) in f.iter().zip(&f2) {
            for ax in 0..3 {
                assert!((a[ax] - b[ax]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn periodic_forces_are_negative_gradient_of_periodic_energy() {
        let m = Model::new(ModelConfig { n_layers: 1, ..Default::default() },
                           5);
        let cell = Cell::orthorhombic(8.0, 9.0, 10.0);
        let (pos, species) = toy(17, 5);
        let (_, f) = m.energy_forces_periodic(&pos, &species, &cell);
        // central differences of the PERIODIC energy (fresh edge build
        // per displacement, so edges crossing images are exercised)
        let h = 1e-6;
        for i in 0..pos.len() {
            for ax in 0..3 {
                let mut pp = pos.clone();
                pp[i][ax] += h;
                let (ep, _) = m.energy_forces_periodic(&pp, &species, &cell);
                pp[i][ax] -= 2.0 * h;
                let (em, _) = m.energy_forces_periodic(&pp, &species, &cell);
                let fd = -(ep - em) / (2.0 * h);
                assert!(
                    (f[i][ax] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                    "atom {i} axis {ax}: {} vs {fd}", f[i][ax]
                );
            }
        }
    }

    #[test]
    fn periodic_batch_par_matches_shifted_serial() {
        let m = Model::new(ModelConfig { n_layers: 1, ..Default::default() },
                           12);
        let cell = Cell::cubic(8.0);
        let structures: Vec<_> = (0..3).map(|k| toy(50 + k, 5)).collect();
        let built: Vec<_> = structures
            .iter()
            .map(|(pos, _)| m.build_edges_periodic(pos, &cell))
            .collect();
        let graphs: Vec<GraphRef<'_>> = structures
            .iter()
            .zip(&built)
            .map(|((pos, species), (edges, shifts))| GraphRef {
                pos, species, edges, shifts: Some(shifts),
            })
            .collect();
        let serial = energy_forces_batch_par(&m, &graphs, 1);
        let par = energy_forces_batch_par(&m, &graphs, 0);
        assert_eq!(max_abs_diff(&serial, &par), 0.0);
        let row_len = batch_row_len(&m);
        for (g, (pos, species)) in structures.iter().enumerate() {
            let (e, _) = m.energy_forces_periodic(pos, species, &cell);
            assert!((serial[g * row_len] - e).abs() < 1e-12);
        }
    }

    #[test]
    fn periodic_edge_builder_parallel_matches_serial() {
        let m = Model::new(ModelConfig::default(), 2);
        let mut rng = Rng::new(31);
        let cell = Cell::orthorhombic(9.0, 10.0, 11.0);
        let pos: Vec<[f64; 3]> = (0..40)
            .map(|_| [rng.uniform(0.0, 9.0), rng.uniform(0.0, 10.0),
                      rng.uniform(0.0, 11.0)])
            .collect();
        let (mut ep, _) = m.build_edges_periodic(&pos, &cell);
        for threads in [1usize, 2, 0] {
            let (mut e2, _) = m.build_edges_periodic_par(&pos, &cell, threads);
            ep.sort_unstable();
            e2.sort_unstable();
            assert_eq!(ep, e2, "threads={threads}");
        }
    }

    #[test]
    fn direct_and_fft_methods_agree() {
        let (pos, species) = toy(9, 6);
        let mut results = Vec::new();
        for method in [ConvMethod::Direct, ConvMethod::Fft] {
            let m = Model::new(
                ModelConfig { method, ..Default::default() }, 11);
            results.push(m.energy_forces(&pos, &species));
        }
        let (e_d, f_d) = &results[0];
        let (e_f, f_f) = &results[1];
        assert!((e_d - e_f).abs() < 1e-8 * (1.0 + e_d.abs()));
        for (a, b) in f_d.iter().zip(f_f) {
            for ax in 0..3 {
                assert!((a[ax] - b[ax]).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn isolated_atoms_have_bias_only_energy() {
        let m = Model::new(ModelConfig::default(), 13);
        // two atoms far outside the cutoff: no edges, a = b = 0, and the
        // energy reduces to biases + readout of the bare embedding
        let pos = vec![[0.0; 3], [100.0, 0.0, 0.0]];
        let species = vec![0usize, 1];
        let (e, f) = m.energy_forces(&pos, &species);
        assert!(f.iter().all(|v| v.iter().all(|x| x.abs() < 1e-12)));
        let p = &m.params;
        let off = Offsets::new(&m.cfg);
        let mut want = 0.0;
        for &sp in &species {
            let mut h0 = p[off.embed + sp];
            for t in 0..m.cfg.n_layers {
                h0 *= p[off.layer(t) + off.mix_res];
            }
            want += p[off.bias + sp] + p[off.readout] * h0
                + p[off.readout + 1] * h0 * h0 * INV_SQRT_4PI;
        }
        assert!((e - want).abs() < 1e-10, "{e} vs {want}");
    }
}
