//! Vector (dipole) readout head on top of the model's node features.
//!
//! The head turns each atom's final equivariant features `h` (layout
//! [`Irreps::spherical`]`(channels, L)`) into a per-atom polar vector:
//!
//! ```text
//!   s^c   = w[(l, c)] (.) h^c            per-degree path weights
//!   t^c   = sv(L, 1, L)(s^c, rhat)       lift against the identity
//!                                        vector field F(u) = u
//!   d^c_k = <s^c, t^c_k>                 k = irrep component (y, z, x)
//!   mu    = c_dip sum_c d^c              mapped irrep -> xyz order
//! ```
//!
//! `d^c` is quadratic in `s^c`, so it is the simplest rotation-covariant
//! polar vector built from the features: under `h -> D(R) h` the lift is
//! equivariant and the component-wise inner products rotate as a degree-1
//! irrep, giving `mu(R h) = R mu(h)`; under inversion every `d_k` flips
//! sign (the integrand `F(u) (Y-expansion of s^2)` is parity-odd), which
//! is exactly the polar-vector law `mu -> det(O) O mu`.
//!
//! **Backward.** With cotangent `g` on `mu` (and `g_irr` its irrep-order
//! shuffle), `d_k = <s, t_k>` sees `s` twice — directly and inside the
//! lift — so
//!
//! ```text
//!   dL/ds    = c_dip sum_k g_irr[k] t_k
//!            + vjp_x1(sv)(gt, rhat),   gt_k = c_dip g_irr[k] s
//!   dL/dw_lc = <(dL/ds)_l, h^c_l>
//!   dL/dc    = sum_c <g_irr, d^c>
//! ```
//!
//! where `vjp_x1(sv(L, 1, L)) = dot(L, 1, L)` by the degree-rotation
//! identity ([`VectorGauntPlan::vjp_sibling_key`]).  Both plans come
//! from the global [`PlanCache`]; all intermediates live in a
//! caller-owned [`DipoleScratch`], so steady state allocates nothing.
//!
//! The head owns its own parameters (`w`, `c_dip`) — it never touches
//! [`Model::params`](super::Model::params), so energy checkpoints and
//! the frozen model goldens are unaffected.  Cross-validated against
//! `python/compile/vector_golden.py` (`dipole` block) through
//! `tests/golden_cross_validation.rs`.

use std::sync::Arc;

use crate::num_coeffs;
use crate::tp::engine::PlanCache;
use crate::tp::gaunt::ConvMethod;
use crate::tp::irreps::Irreps;
use crate::tp::vector::{
    VectorGauntPlan, VectorIrreps, VectorKind, VectorScratch, CART,
};
use crate::util::rng::Rng;

/// Learned dipole readout: per-(degree, channel) path weights plus a
/// global scale, with the sv lift and its VJP sibling resolved once from
/// the plan cache.  Cheap to share behind an `Arc`; per-thread state
/// lives in [`DipoleScratch`].
pub struct DipoleHead {
    channels: usize,
    l: usize,
    /// path weights, index `l * channels + c` (length `channels (L+1)`)
    pub w: Vec<f64>,
    /// global output scale
    pub c_dip: f64,
    /// the lift `sv(L, 1, L)`
    sv: Arc<VectorGauntPlan>,
    /// its x1-VJP sibling `dot(L, 1, L)`
    vjp: Arc<VectorGauntPlan>,
    vir: VectorIrreps,
    /// the constant field `F(u) = u` as a degree-1 vector signal
    rhat: Vec<f64>,
}

/// Caller-owned workspace for one [`DipoleHead`] forward/backward: one
/// per worker thread, sized at construction, never resized.
pub struct DipoleScratch {
    sv_s: VectorScratch,
    vjp_s: VectorScratch,
    /// scaled channel features (`(L+1)^2`)
    s: Vec<f64>,
    /// lifted vector signal (`3 (L+1)^2`)
    t: Vec<f64>,
    /// component gather / VJP-output staging (`(L+1)^2`)
    tk: Vec<f64>,
    /// lift cotangent (`3 (L+1)^2`)
    gt: Vec<f64>,
    /// feature cotangent (`(L+1)^2`)
    gs: Vec<f64>,
}

impl DipoleHead {
    /// Random initialization (O(1) path weights, like the model mixes).
    pub fn new(
        channels: usize, l: usize, method: ConvMethod, seed: u64,
    ) -> DipoleHead {
        let mut rng = Rng::new(seed);
        let mut w = vec![0.0; channels * (l + 1)];
        for wv in w.iter_mut() {
            *wv = 1.0 + 0.3 * rng.normal();
        }
        let c_dip = 0.5 + 0.1 * rng.normal();
        DipoleHead::with_params(channels, l, method, w, c_dip)
    }

    /// Head with explicit parameters (checkpoint restore, golden tests).
    pub fn with_params(
        channels: usize, l: usize, method: ConvMethod, w: Vec<f64>,
        c_dip: f64,
    ) -> DipoleHead {
        assert_eq!(w.len(), channels * (l + 1), "w is per (degree, channel)");
        let cache = PlanCache::global();
        DipoleHead {
            channels,
            l,
            w,
            c_dip,
            sv: cache.vector(VectorKind::ScalarVector, l, 1, l, method),
            vjp: cache.vector(VectorKind::VectorDot, l, 1, l, method),
            vir: VectorIrreps::new(l),
            rhat: VectorIrreps::rhat_signal(),
        }
    }

    /// Number of learned parameters (`w` plus `c_dip`).
    pub fn n_params(&self) -> usize {
        self.w.len() + 1
    }

    /// Expected node-feature layout.
    pub fn irreps_in(&self) -> Irreps {
        Irreps::spherical(self.channels, self.l)
    }

    /// Fresh scratch sized for this head (one per worker thread).
    pub fn scratch(&self) -> DipoleScratch {
        let nf = num_coeffs(self.l);
        DipoleScratch {
            sv_s: self.sv.scratch(),
            vjp_s: self.vjp.scratch(),
            s: vec![0.0; nf],
            t: vec![0.0; 3 * nf],
            tk: vec![0.0; nf],
            gt: vec![0.0; 3 * nf],
            gs: vec![0.0; nf],
        }
    }

    /// `s^c = w[(l, c)] (.) h^c`: gather channel `c` with the per-degree
    /// path weights applied.
    fn gather_scaled(&self, h: &[f64], c: usize, out: &mut [f64]) {
        for l in 0..=self.l {
            let wv = self.w[l * self.channels + c];
            let hb = self.channels * l * l + c * (2 * l + 1);
            for m in 0..2 * l + 1 {
                out[l * l + m] = wv * h[hb + m];
            }
        }
    }

    /// Per-atom dipole (Cartesian xyz) from one node-feature row.
    /// Zero allocations in steady state.
    pub fn dipole_into(&self, h: &[f64], s: &mut DipoleScratch) -> [f64; 3] {
        debug_assert_eq!(h.len(), self.channels * num_coeffs(self.l));
        let mut mu_irr = [0.0; 3];
        for c in 0..self.channels {
            self.gather_scaled(h, c, &mut s.s);
            self.sv.apply_into(&s.s, &self.rhat, &mut s.t, &mut s.sv_s);
            for (k, mv) in mu_irr.iter_mut().enumerate() {
                self.vir.gather(&s.t, k, &mut s.tk);
                let d: f64 =
                    s.s.iter().zip(&s.tk).map(|(a, b)| a * b).sum();
                *mv += self.c_dip * d;
            }
        }
        let mut mu = [0.0; 3];
        for k in 0..3 {
            mu[CART[k]] = mu_irr[k];
        }
        mu
    }

    /// Gradients of `<g_mu, mu>` w.r.t. the head parameters, ACCUMULATED
    /// into `gw` (length `channels (L+1)`) and `gc`; the caller zeroes
    /// them.  Recomputes the per-channel forward intermediates in place
    /// (they are two plan applies per channel — cheaper than persisting
    /// `channels` copies).  Zero allocations in steady state.
    pub fn grads_into(
        &self, h: &[f64], g_mu: [f64; 3], gw: &mut [f64], gc: &mut f64,
        s: &mut DipoleScratch,
    ) {
        debug_assert_eq!(gw.len(), self.w.len());
        let g_irr = [g_mu[CART[0]], g_mu[CART[1]], g_mu[CART[2]]];
        for c in 0..self.channels {
            self.gather_scaled(h, c, &mut s.s);
            self.sv.apply_into(&s.s, &self.rhat, &mut s.t, &mut s.sv_s);
            // dL/ds from the direct slot of d_k = <s, t_k> (and dL/dc)
            s.gs.fill(0.0);
            for (k, &gk) in g_irr.iter().enumerate() {
                self.vir.gather(&s.t, k, &mut s.tk);
                let d: f64 =
                    s.s.iter().zip(&s.tk).map(|(a, b)| a * b).sum();
                *gc += gk * d;
                for (gv, tv) in s.gs.iter_mut().zip(&s.tk) {
                    *gv += self.c_dip * gk * tv;
                }
            }
            // dL/ds through the lift: gt_k = c_dip g_irr[k] s, pulled
            // back by the sibling dot(L, 1, L) plan
            for (k, &gk) in g_irr.iter().enumerate() {
                for (tv, sv) in s.tk.iter_mut().zip(&s.s) {
                    *tv = self.c_dip * gk * sv;
                }
                self.vir.scatter(&s.tk, k, &mut s.gt);
            }
            self.vjp.apply_into(&s.gt, &self.rhat, &mut s.tk, &mut s.vjp_s);
            for (gv, tv) in s.gs.iter_mut().zip(&s.tk) {
                *gv += tv;
            }
            // dL/dw[(l, c)] = <gs_l, h^c_l>
            for l in 0..=self.l {
                let hb = self.channels * l * l + c * (2 * l + 1);
                let mut acc = 0.0;
                for m in 0..2 * l + 1 {
                    acc += s.gs[l * l + m] * h[hb + m];
                }
                gw[l * self.channels + c] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::rotation::Rot3;
    use crate::tp::vector::transform_scalar;

    const CHANNELS: usize = 2;
    const L: usize = 2;

    fn head() -> DipoleHead {
        DipoleHead::new(CHANNELS, L, ConvMethod::Auto, 41)
    }

    fn features(seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..CHANNELS * num_coeffs(L)).map(|_| rng.normal()).collect()
    }

    /// Transform the spherical(C, L) feature row per channel by the
    /// scalar law (Wigner-D with det^l parity).
    fn transform_features(h: &[f64], o: &Rot3) -> Vec<f64> {
        let nf = num_coeffs(L);
        let mut out = vec![0.0; h.len()];
        let mut ch = vec![0.0; nf];
        let ir = Irreps::spherical(CHANNELS, L);
        for c in 0..CHANNELS {
            ir.gather_channel(h, c, &mut ch);
            let t = transform_scalar(&ch, L, o);
            ir.scatter_channel(&t, c, &mut out);
        }
        out
    }

    #[test]
    fn parameter_gradients_match_finite_differences() {
        let mut hd = head();
        let h = features(7);
        let mut s = hd.scratch();
        let g_mu = [0.3, -1.1, 0.7];
        let loss = |hd: &DipoleHead, s: &mut DipoleScratch| {
            let mu = hd.dipole_into(&h, s);
            g_mu[0] * mu[0] + g_mu[1] * mu[1] + g_mu[2] * mu[2]
        };
        let mut gw = vec![0.0; hd.w.len()];
        let mut gc = 0.0;
        hd.grads_into(&h, g_mu, &mut gw, &mut gc, &mut s);
        let step = 1e-6;
        for i in 0..gw.len() {
            let w0 = hd.w[i];
            hd.w[i] = w0 + step;
            let up = loss(&hd, &mut s);
            hd.w[i] = w0 - step;
            let dn = loss(&hd, &mut s);
            hd.w[i] = w0;
            let fd = (up - dn) / (2.0 * step);
            assert!(
                (gw[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "dw[{i}]: analytic {} vs fd {}", gw[i], fd
            );
        }
        let c0 = hd.c_dip;
        hd.c_dip = c0 + step;
        let up = loss(&hd, &mut s);
        hd.c_dip = c0 - step;
        let dn = loss(&hd, &mut s);
        hd.c_dip = c0;
        let fd = (up - dn) / (2.0 * step);
        assert!(
            (gc - fd).abs() < 1e-5 * (1.0 + fd.abs()),
            "dc_dip: analytic {gc} vs fd {fd}"
        );
    }

    #[test]
    fn dipole_is_a_polar_vector_under_o3() {
        let hd = head();
        let h = features(11);
        let mut s = hd.scratch();
        let mu = hd.dipole_into(&h, &mut s);
        let mut rng = Rng::new(23);
        let r = Rot3::random(&mut rng);
        // proper rotation and the same rotation composed with inversion
        for (o, label) in [
            (r, "proper"),
            (Rot3([
                [-r.0[0][0], -r.0[0][1], -r.0[0][2]],
                [-r.0[1][0], -r.0[1][1], -r.0[1][2]],
                [-r.0[2][0], -r.0[2][1], -r.0[2][2]],
            ]), "improper"),
        ] {
            let th = transform_features(&h, &o);
            let tmu = hd.dipole_into(&th, &mut s);
            let want = o.apply(mu);
            for k in 0..3 {
                assert!(
                    (tmu[k] - want[k]).abs() < 1e-9,
                    "{label} dipole[{k}]: {} vs {}", tmu[k], want[k]
                );
            }
        }
    }

    #[test]
    fn zero_weights_give_zero_dipole_and_gradients_flow() {
        let mut hd = head();
        hd.w.iter_mut().for_each(|w| *w = 0.0);
        let h = features(3);
        let mut s = hd.scratch();
        let mu = hd.dipole_into(&h, &mut s);
        assert_eq!(mu, [0.0; 3]);
        // d is quadratic in s, so at w = 0 every dw is zero too — but
        // the accumulation contract must still hold (no NaNs, adds only)
        let mut gw = vec![1.5; hd.w.len()];
        let mut gc = 2.5;
        hd.grads_into(&h, [1.0, 1.0, 1.0], &mut gw, &mut gc, &mut s);
        assert!(gw.iter().all(|g| (*g - 1.5).abs() < 1e-12));
        assert!((gc - 2.5).abs() < 1e-12);
    }
}
