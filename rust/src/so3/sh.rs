//! Associated Legendre functions and orthonormal real spherical harmonics.
//!
//! Conventions identical to `so3.py`: no Condon-Shortley phase,
//! `Y_m^l = N_l^{|m|} P_l^{|m|}(cos th) * {sqrt2 cos(m ph) | 1 | sqrt2 sin(|m| ph)}`.

use crate::{lm_index, num_coeffs};

/// n! as f64 (exact for n <= 22, adequate to ~1e-15 relative beyond).
pub fn factorial(n: i64) -> f64 {
    if n < 0 {
        return 0.0;
    }
    let mut f = 1.0f64;
    for k in 2..=n {
        f *= k as f64;
    }
    f
}

/// P_l^m(x) for 0 <= m <= l, no Condon-Shortley phase.
pub fn assoc_legendre(l: usize, m: usize, x: f64) -> f64 {
    debug_assert!(m <= l);
    let somx2 = (1.0 - x * x).max(0.0).sqrt();
    let mut pmm = 1.0f64;
    let mut fact = 1.0f64;
    for _ in 0..m {
        pmm *= fact * somx2;
        fact += 2.0;
    }
    if l == m {
        return pmm;
    }
    let mut pmmp1 = x * (2 * m + 1) as f64 * pmm;
    if l == m + 1 {
        return pmmp1;
    }
    let mut pll = pmmp1;
    for ll in (m + 2)..=l {
        pll = (x * (2 * ll - 1) as f64 * pmmp1 - (ll + m - 1) as f64 * pmm)
            / (ll - m) as f64;
        pmm = pmmp1;
        pmmp1 = pll;
    }
    pll
}

/// Orthonormalization constant N_l^{|m|}.
pub fn sh_norm(l: usize, m: i64) -> f64 {
    let am = m.unsigned_abs() as i64;
    ((2 * l as i64 + 1) as f64 / (4.0 * std::f64::consts::PI)
        * factorial(l as i64 - am)
        / factorial(l as i64 + am))
    .sqrt()
}

/// Real orthonormal Y_m^l(theta, phi).
pub fn real_sh_angular(l: usize, m: i64, theta: f64, phi: f64) -> f64 {
    let am = m.unsigned_abs() as usize;
    let p = assoc_legendre(l, am, theta.cos()) * sh_norm(l, m);
    if m > 0 {
        p * std::f64::consts::SQRT_2 * (m as f64 * phi).cos()
    } else if m < 0 {
        p * std::f64::consts::SQRT_2 * (am as f64 * phi).sin()
    } else {
        p
    }
}

/// All real SH up to degree L at a Cartesian direction (normalized inside).
pub fn real_sh_all_xyz(l_max: usize, r: [f64; 3]) -> Vec<f64> {
    let mut out = vec![0.0; num_coeffs(l_max)];
    real_sh_all_xyz_into(l_max, r, &mut out);
    out
}

/// [`real_sh_all_xyz`] into a caller buffer of `num_coeffs(l_max)`:
/// allocation-free (the hot-path variant the model forward and the
/// allocation-free Wigner-D evaluation use).
pub fn real_sh_all_xyz_into(l_max: usize, r: [f64; 3], out: &mut [f64]) {
    debug_assert!(out.len() >= num_coeffs(l_max));
    let n = (r[0] * r[0] + r[1] * r[1] + r[2] * r[2]).sqrt().max(1e-30);
    let u = [r[0] / n, r[1] / n, r[2] / n];
    let theta = u[2].clamp(-1.0, 1.0).acos();
    let phi = u[1].atan2(u[0]);
    for l in 0..=l_max {
        for m in -(l as i64)..=(l as i64) {
            out[lm_index(l, m)] = real_sh_angular(l, m, theta, phi);
        }
    }
}

/// Values AND Cartesian gradients of every real SH composed with the
/// direction normalization: `val[(l,m)] = Y_lm(d/|d|)` and
/// `grad[(l,m)] = d/dd Y_lm(d/|d|)` — the derivative the force backward
/// pass needs through the edge embedding.
///
/// Pole-free evaluation: with our conventions (orthonormal real SH, no
/// Condon-Shortley phase)
///
/// ```text
///   Y_{l,+m} = N sqrt(2) T_l^m(z) C_m(x, y)   (m > 0)
///   Y_{l,0}  = N T_l^0(z)
///   Y_{l,-m} = N sqrt(2) T_l^m(z) S_m(x, y)   (m > 0)
/// ```
///
/// on the unit sphere, where `C_m + i S_m = (x + i y)^m` and
/// `T_l^m(z) = P_l^m(z) / (1 - z^2)^{m/2}` is a *polynomial* obeying the
/// same upward recurrence as `P_l^m` (seeded by `T_m^m = (2m-1)!!`).
/// Every factor is polynomial in the Cartesian components, so the
/// ambient gradient is exact and finite everywhere — including the +-z
/// poles where the angular (theta, phi) form is singular.  The gradient
/// w.r.t. the unnormalized displacement follows from the projection
/// `(I - u u^T)/r`.  Validated against central differences by
/// `python/compile/model_golden.py --check` and `tests/grad_check.rs`.
pub fn real_sh_grad_xyz_into(
    l_max: usize, d: [f64; 3], val: &mut [f64], grad: &mut [[f64; 3]],
) {
    let nc = num_coeffs(l_max);
    debug_assert!(val.len() >= nc && grad.len() >= nc);
    let r = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt().max(1e-30);
    let u = [d[0] / r, d[1] / r, d[2] / r];
    let (x, y, z) = (u[0], u[1], u[2]);
    // C_m, S_m (and their m-1 predecessors for the x/y derivatives)
    let (mut cm, mut sm) = (1.0f64, 0.0f64);
    let (mut cm1, mut sm1) = (0.0f64, 0.0f64);
    let mut dfact = 1.0f64; // (2m-1)!!
    for m in 0..=l_max {
        if m > 0 {
            cm1 = cm;
            sm1 = sm;
            let c_next = cm * x - sm * y;
            sm = cm * y + sm * x;
            cm = c_next;
            dfact *= (2 * m - 1) as f64;
        }
        // T_l^m recurrence in l (same as assoc_legendre's, divided by
        // sin^m theta), carried with its z-derivative
        let (mut t_prev, mut td_prev) = (0.0f64, 0.0f64);
        let (mut t, mut td) = (dfact, 0.0f64);
        for l in m..=l_max {
            if l > m {
                let (t_next, td_next) = if l == m + 1 {
                    (z * (2 * m + 1) as f64 * t, (2 * m + 1) as f64 * t)
                } else {
                    let a = (2 * l - 1) as f64;
                    let b = (l + m - 1) as f64;
                    let c = (l - m) as f64;
                    (
                        (z * a * t - b * t_prev) / c,
                        (a * (t + z * td) - b * td_prev) / c,
                    )
                };
                t_prev = t;
                td_prev = td;
                t = t_next;
                td = td_next;
            }
            let pre = sh_norm(l, m as i64)
                * if m > 0 { std::f64::consts::SQRT_2 } else { 1.0 };
            let mf = m as f64;
            // (value, ambient dF at u) -> project through (I - u u^T)/r
            let mut emit = |idx: usize, plane: f64, df: [f64; 3]| {
                val[idx] = pre * t * plane;
                let dot = df[0] * u[0] + df[1] * u[1] + df[2] * u[2];
                for k in 0..3 {
                    grad[idx][k] = pre * (df[k] - dot * u[k]) / r;
                }
            };
            emit(
                lm_index(l, m as i64),
                cm,
                [t * mf * cm1, -t * mf * sm1, td * cm],
            );
            if m > 0 {
                emit(
                    lm_index(l, -(m as i64)),
                    sm,
                    [t * mf * sm1, t * mf * cm1, td * sm],
                );
            }
        }
    }
}

/// All real SH up to degree L at spherical coordinates.
pub fn real_sh_all_angular(l_max: usize, theta: f64, phi: f64) -> Vec<f64> {
    let mut out = vec![0.0; num_coeffs(l_max)];
    for l in 0..=l_max {
        for m in -(l as i64)..=(l as i64) {
            out[lm_index(l, m)] = real_sh_angular(l, m, theta, phi);
        }
    }
    out
}

/// Evaluate a feature x (flat irrep layout) as a function on the sphere.
pub fn eval_sh_series(x: &[f64], l_max: usize, theta: f64, phi: f64) -> f64 {
    let y = real_sh_all_angular(l_max, theta, phi);
    x.iter().zip(&y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::quadrature::sphere_quadrature;

    #[test]
    fn legendre_base_cases() {
        assert!((assoc_legendre(0, 0, 0.3) - 1.0).abs() < 1e-15);
        assert!((assoc_legendre(1, 0, 0.3) - 0.3).abs() < 1e-15);
        let x = 0.6f64;
        assert!((assoc_legendre(1, 1, x) - (1.0 - x * x).sqrt()).abs() < 1e-14);
        // P_2^0 = (3x^2 - 1)/2
        assert!((assoc_legendre(2, 0, x) - (3.0 * x * x - 1.0) / 2.0).abs() < 1e-14);
    }

    #[test]
    fn y00_constant() {
        let v = real_sh_angular(0, 0, 0.7, 1.3);
        assert!((v - 1.0 / (4.0 * std::f64::consts::PI).sqrt()).abs() < 1e-14);
    }

    #[test]
    fn y1_is_axes() {
        let c = (3.0 / (4.0 * std::f64::consts::PI)).sqrt();
        let pts: [[f64; 3]; 3] =
            [[0.3, -0.5, 0.81], [1.0, 0.0, 0.0], [0.0, 0.0, 1.0]];
        for p in pts {
            let n = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            let u = [p[0] / n, p[1] / n, p[2] / n];
            let y = real_sh_all_xyz(1, p);
            assert!((y[1] - c * u[1]).abs() < 1e-12, "m=-1 ~ y");
            assert!((y[2] - c * u[2]).abs() < 1e-12, "m=0 ~ z");
            assert!((y[3] - c * u[0]).abs() < 1e-12, "m=1 ~ x");
        }
    }

    #[test]
    fn orthonormality_via_quadrature() {
        let l_max = 4;
        let (nodes, dphi) = sphere_quadrature(2 * l_max);
        let n = num_coeffs(l_max);
        let mut gram = vec![0.0; n * n];
        for (theta, phi, w) in &nodes {
            let y = real_sh_all_angular(l_max, *theta, *phi);
            for i in 0..n {
                for j in 0..n {
                    gram[i * n + j] += w * dphi * y[i] * y[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram[i * n + j] - want).abs() < 1e-10,
                    "gram[{i}][{j}] = {}",
                    gram[i * n + j]
                );
            }
        }
    }

    #[test]
    fn parity() {
        let p = [0.4, -0.7, 0.59];
        let q = [-p[0], -p[1], -p[2]];
        for l in 0..5usize {
            let a = real_sh_all_xyz(l, p);
            let b = real_sh_all_xyz(l, q);
            let sign = if l % 2 == 0 { 1.0 } else { -1.0 };
            for m in -(l as i64)..=(l as i64) {
                let i = lm_index(l, m);
                assert!((b[i] - sign * a[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn grad_xyz_matches_values_and_finite_differences() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(17);
        let l_max = 4;
        let n = num_coeffs(l_max);
        let h = 1e-6;
        for _ in 0..12 {
            let scale = rng.uniform(0.4, 2.5);
            let d = [
                scale * rng.normal(),
                scale * rng.normal(),
                scale * rng.normal(),
            ];
            let mut val = vec![0.0; n];
            let mut grad = vec![[0.0; 3]; n];
            real_sh_grad_xyz_into(l_max, d, &mut val, &mut grad);
            // values must agree with the angular evaluation exactly
            let want = real_sh_all_xyz(l_max, d);
            for k in 0..n {
                assert!((val[k] - want[k]).abs() < 1e-11, "value {k}");
            }
            // gradient vs central differences of the angular form
            for ax in 0..3 {
                let mut dp = d;
                dp[ax] += h;
                let mut dm = d;
                dm[ax] -= h;
                let yp = real_sh_all_xyz(l_max, dp);
                let ym = real_sh_all_xyz(l_max, dm);
                for k in 0..n {
                    let fd = (yp[k] - ym[k]) / (2.0 * h);
                    assert!(
                        (grad[k][ax] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                        "coeff {k} axis {ax}: {} vs fd {}",
                        grad[k][ax],
                        fd
                    );
                }
            }
        }
    }

    #[test]
    fn grad_xyz_finite_at_poles() {
        // the angular form is singular at the poles; the polynomial
        // factorization must not be
        let n = num_coeffs(4);
        for d in [[0.0, 0.0, 1.7], [0.0, 0.0, -2.1], [1e-12, 0.0, 1.0]] {
            let mut val = vec![0.0; n];
            let mut grad = vec![[0.0; 3]; n];
            real_sh_grad_xyz_into(4, d, &mut val, &mut grad);
            assert!(val.iter().all(|v| v.is_finite()));
            assert!(grad.iter().all(|g| g.iter().all(|v| v.is_finite())));
        }
        // directional check at a near-pole direction: gradients along z
        // of Y_{1,0} = c * z/r: d/dz (z/r) at (0,0,r) is 0
        let mut val = vec![0.0; num_coeffs(1)];
        let mut grad = vec![[0.0; 3]; num_coeffs(1)];
        real_sh_grad_xyz_into(1, [0.0, 0.0, 2.0], &mut val, &mut grad);
        assert!(grad[2][2].abs() < 1e-14);
        // while d/dx (x/r) = 1/r there for Y_{1,1}
        let c = (3.0 / (4.0 * std::f64::consts::PI)).sqrt();
        assert!((grad[3][0] - c / 2.0).abs() < 1e-12);
    }

    #[test]
    fn into_variant_matches_allocating() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let d = [rng.normal(), rng.normal(), rng.normal()];
        let want = real_sh_all_xyz(3, d);
        let mut got = vec![0.0; num_coeffs(3)];
        real_sh_all_xyz_into(3, d, &mut got);
        assert_eq!(want, got);
    }

    #[test]
    fn z_axis_kills_nonzero_m() {
        let y = real_sh_all_xyz(4, [0.0, 0.0, 1.0]);
        for l in 0..=4usize {
            for m in -(l as i64)..=(l as i64) {
                if m != 0 {
                    assert!(y[lm_index(l, m)].abs() < 1e-12);
                } else {
                    assert!(y[lm_index(l, 0)].abs() > 1e-6);
                }
            }
        }
    }
}
