//! Associated Legendre functions and orthonormal real spherical harmonics.
//!
//! Conventions identical to `so3.py`: no Condon-Shortley phase,
//! `Y_m^l = N_l^{|m|} P_l^{|m|}(cos th) * {sqrt2 cos(m ph) | 1 | sqrt2 sin(|m| ph)}`.

use crate::{lm_index, num_coeffs};

/// n! as f64 (exact for n <= 22, adequate to ~1e-15 relative beyond).
pub fn factorial(n: i64) -> f64 {
    if n < 0 {
        return 0.0;
    }
    let mut f = 1.0f64;
    for k in 2..=n {
        f *= k as f64;
    }
    f
}

/// P_l^m(x) for 0 <= m <= l, no Condon-Shortley phase.
pub fn assoc_legendre(l: usize, m: usize, x: f64) -> f64 {
    debug_assert!(m <= l);
    let somx2 = (1.0 - x * x).max(0.0).sqrt();
    let mut pmm = 1.0f64;
    let mut fact = 1.0f64;
    for _ in 0..m {
        pmm *= fact * somx2;
        fact += 2.0;
    }
    if l == m {
        return pmm;
    }
    let mut pmmp1 = x * (2 * m + 1) as f64 * pmm;
    if l == m + 1 {
        return pmmp1;
    }
    let mut pll = pmmp1;
    for ll in (m + 2)..=l {
        pll = (x * (2 * ll - 1) as f64 * pmmp1 - (ll + m - 1) as f64 * pmm)
            / (ll - m) as f64;
        pmm = pmmp1;
        pmmp1 = pll;
    }
    pll
}

/// Orthonormalization constant N_l^{|m|}.
pub fn sh_norm(l: usize, m: i64) -> f64 {
    let am = m.unsigned_abs() as i64;
    ((2 * l as i64 + 1) as f64 / (4.0 * std::f64::consts::PI)
        * factorial(l as i64 - am)
        / factorial(l as i64 + am))
    .sqrt()
}

/// Real orthonormal Y_m^l(theta, phi).
pub fn real_sh_angular(l: usize, m: i64, theta: f64, phi: f64) -> f64 {
    let am = m.unsigned_abs() as usize;
    let p = assoc_legendre(l, am, theta.cos()) * sh_norm(l, m);
    if m > 0 {
        p * std::f64::consts::SQRT_2 * (m as f64 * phi).cos()
    } else if m < 0 {
        p * std::f64::consts::SQRT_2 * (am as f64 * phi).sin()
    } else {
        p
    }
}

/// All real SH up to degree L at a Cartesian direction (normalized inside).
pub fn real_sh_all_xyz(l_max: usize, r: [f64; 3]) -> Vec<f64> {
    let n = (r[0] * r[0] + r[1] * r[1] + r[2] * r[2]).sqrt().max(1e-30);
    let u = [r[0] / n, r[1] / n, r[2] / n];
    let theta = u[2].clamp(-1.0, 1.0).acos();
    let phi = u[1].atan2(u[0]);
    let mut out = vec![0.0; num_coeffs(l_max)];
    for l in 0..=l_max {
        for m in -(l as i64)..=(l as i64) {
            out[lm_index(l, m)] = real_sh_angular(l, m, theta, phi);
        }
    }
    out
}

/// All real SH up to degree L at spherical coordinates.
pub fn real_sh_all_angular(l_max: usize, theta: f64, phi: f64) -> Vec<f64> {
    let mut out = vec![0.0; num_coeffs(l_max)];
    for l in 0..=l_max {
        for m in -(l as i64)..=(l as i64) {
            out[lm_index(l, m)] = real_sh_angular(l, m, theta, phi);
        }
    }
    out
}

/// Evaluate a feature x (flat irrep layout) as a function on the sphere.
pub fn eval_sh_series(x: &[f64], l_max: usize, theta: f64, phi: f64) -> f64 {
    let y = real_sh_all_angular(l_max, theta, phi);
    x.iter().zip(&y).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::quadrature::sphere_quadrature;

    #[test]
    fn legendre_base_cases() {
        assert!((assoc_legendre(0, 0, 0.3) - 1.0).abs() < 1e-15);
        assert!((assoc_legendre(1, 0, 0.3) - 0.3).abs() < 1e-15);
        let x = 0.6f64;
        assert!((assoc_legendre(1, 1, x) - (1.0 - x * x).sqrt()).abs() < 1e-14);
        // P_2^0 = (3x^2 - 1)/2
        assert!((assoc_legendre(2, 0, x) - (3.0 * x * x - 1.0) / 2.0).abs() < 1e-14);
    }

    #[test]
    fn y00_constant() {
        let v = real_sh_angular(0, 0, 0.7, 1.3);
        assert!((v - 1.0 / (4.0 * std::f64::consts::PI).sqrt()).abs() < 1e-14);
    }

    #[test]
    fn y1_is_axes() {
        let c = (3.0 / (4.0 * std::f64::consts::PI)).sqrt();
        let pts: [[f64; 3]; 3] =
            [[0.3, -0.5, 0.81], [1.0, 0.0, 0.0], [0.0, 0.0, 1.0]];
        for p in pts {
            let n = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            let u = [p[0] / n, p[1] / n, p[2] / n];
            let y = real_sh_all_xyz(1, p);
            assert!((y[1] - c * u[1]).abs() < 1e-12, "m=-1 ~ y");
            assert!((y[2] - c * u[2]).abs() < 1e-12, "m=0 ~ z");
            assert!((y[3] - c * u[0]).abs() < 1e-12, "m=1 ~ x");
        }
    }

    #[test]
    fn orthonormality_via_quadrature() {
        let l_max = 4;
        let (nodes, dphi) = sphere_quadrature(2 * l_max);
        let n = num_coeffs(l_max);
        let mut gram = vec![0.0; n * n];
        for (theta, phi, w) in &nodes {
            let y = real_sh_all_angular(l_max, *theta, *phi);
            for i in 0..n {
                for j in 0..n {
                    gram[i * n + j] += w * dphi * y[i] * y[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram[i * n + j] - want).abs() < 1e-10,
                    "gram[{i}][{j}] = {}",
                    gram[i * n + j]
                );
            }
        }
    }

    #[test]
    fn parity() {
        let p = [0.4, -0.7, 0.59];
        let q = [-p[0], -p[1], -p[2]];
        for l in 0..5usize {
            let a = real_sh_all_xyz(l, p);
            let b = real_sh_all_xyz(l, q);
            let sign = if l % 2 == 0 { 1.0 } else { -1.0 };
            for m in -(l as i64)..=(l as i64) {
                let i = lm_index(l, m);
                assert!((b[i] - sign * a[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn z_axis_kills_nonzero_m() {
        let y = real_sh_all_xyz(4, [0.0, 0.0, 1.0]);
        for l in 0..=4usize {
            for m in -(l as i64)..=(l as i64) {
                if m != 0 {
                    assert!(y[lm_index(l, m)].abs() < 1e-12);
                } else {
                    assert!(y[lm_index(l, 0)].abs() > 1e-6);
                }
            }
        }
    }
}
