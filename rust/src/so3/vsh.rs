//! Real vector spherical harmonics (VSH) and their Gaunt-style couplings.
//!
//! The basis (conventions mirrored by `python/compile/vector_golden.py`,
//! frozen in `artifacts/golden/vector_golden.json`):
//!
//! ```text
//!   Y_{lm}(u)   = Y_lm(u) u                      radial,   parity (-1)^{l+1}
//!   Psi_{lm}(u) = grad_S Y_lm / sqrt(l(l+1))     gradient, parity (-1)^{l+1}
//!   Phi_{lm}(u) = u x Psi_{lm}                   curl,     parity (-1)^l
//! ```
//!
//! where `grad_S` is the surface gradient on S^2 — exactly what
//! [`real_sh_grad_xyz_into`] emits at unit radius (its projected ambient
//! gradient `(I - u u^T) grad F / r`).  The family is orthonormal under
//! the vector-field inner product `int V . W dOmega`, and truncation is
//! exact: a Cartesian-component vector signal of degree <= L expands in
//! `{Y, Psi: l <= L+1, Phi: l <= L}` (validated by the numpy mirror's
//! completeness check).
//!
//! [`vsh_dot_gaunt`] builds the coupling tensor
//! `T[k3, J1, J2] = int Y_{k3} (V_{J1} . V_{J2}) dOmega` by exact
//! quadrature — the VSH-basis analogue of the scalar real Gaunt tensor,
//! connecting VSH triple products to the scalar Gaunt machinery the
//! `tp::vector` plans route through (DESIGN.md §15).

use super::quadrature::sphere_quadrature;
use super::sh::{real_sh_all_xyz_into, real_sh_grad_xyz_into};
use crate::{lm_index, num_coeffs};

/// The three VSH families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VshKind {
    /// `Y_lm(u) u` — the radial family (all l >= 0).
    Radial,
    /// `Psi_lm = grad_S Y_lm / sqrt(l(l+1))` — gradient family (l >= 1).
    Gradient,
    /// `Phi_lm = u x Psi_lm` — curl family (l >= 1).
    Curl,
}

impl VshKind {
    /// Parity factor of the degree-l member under inversion `u -> -u`:
    /// radial/gradient pick up `(-1)^{l+1}`, curl `(-1)^l` (pseudo).
    pub fn parity(self, l: usize) -> f64 {
        let s = match self {
            VshKind::Radial | VshKind::Gradient => l + 1,
            VshKind::Curl => l,
        };
        if s % 2 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Golden-file name ("Y" / "Psi" / "Phi").
    pub fn name(self) -> &'static str {
        match self {
            VshKind::Radial => "Y",
            VshKind::Gradient => "Psi",
            VshKind::Curl => "Phi",
        }
    }

    /// Inverse of [`VshKind::name`].
    pub fn from_name(s: &str) -> Option<VshKind> {
        match s {
            "Y" => Some(VshKind::Radial),
            "Psi" => Some(VshKind::Gradient),
            "Phi" => Some(VshKind::Curl),
            _ => None,
        }
    }
}

/// The canonical (kind, l, m) index list: radial to `l_y`, gradient and
/// curl from 1 to `l_psi` / `l_phi` (Psi/Phi vanish identically at l=0).
pub fn vsh_set(
    l_y: usize, l_psi: usize, l_phi: usize,
) -> Vec<(VshKind, usize, i64)> {
    let mut out = Vec::new();
    for l in 0..=l_y {
        for m in -(l as i64)..=(l as i64) {
            out.push((VshKind::Radial, l, m));
        }
    }
    for l in 1..=l_psi {
        for m in -(l as i64)..=(l as i64) {
            out.push((VshKind::Gradient, l, m));
        }
    }
    for l in 1..=l_phi {
        for m in -(l as i64)..=(l as i64) {
            out.push((VshKind::Curl, l, m));
        }
    }
    out
}

/// Shared-workspace VSH evaluator: one scalar-SH value+gradient sweep per
/// point serves every (kind, l, m) read-out.  Allocation-free after
/// construction.
pub struct VshEvaluator {
    l_max: usize,
    u: [f64; 3],
    val: Vec<f64>,
    grad: Vec<[f64; 3]>,
}

impl VshEvaluator {
    pub fn new(l_max: usize) -> VshEvaluator {
        VshEvaluator {
            l_max,
            u: [0.0, 0.0, 1.0],
            val: vec![0.0; num_coeffs(l_max)],
            grad: vec![[0.0; 3]; num_coeffs(l_max)],
        }
    }

    /// Position the evaluator at direction `d` (normalized inside).
    pub fn move_to(&mut self, d: [f64; 3]) {
        let n = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt().max(1e-30);
        self.u = [d[0] / n, d[1] / n, d[2] / n];
        real_sh_grad_xyz_into(self.l_max, self.u, &mut self.val, &mut self.grad);
    }

    /// The VSH value (xyz components) at the current point.
    pub fn eval(&self, kind: VshKind, l: usize, m: i64) -> [f64; 3] {
        debug_assert!(l <= self.l_max);
        let i = lm_index(l, m);
        let u = self.u;
        if let VshKind::Radial = kind {
            let y = self.val[i];
            return [y * u[0], y * u[1], y * u[2]];
        }
        assert!(l >= 1, "Psi/Phi require l >= 1");
        let s = 1.0 / ((l * (l + 1)) as f64).sqrt();
        let g = self.grad[i];
        let psi = [s * g[0], s * g[1], s * g[2]];
        match kind {
            VshKind::Gradient => psi,
            VshKind::Curl => [
                u[1] * psi[2] - u[2] * psi[1],
                u[2] * psi[0] - u[0] * psi[2],
                u[0] * psi[1] - u[1] * psi[0],
            ],
            VshKind::Radial => unreachable!(),
        }
    }
}

/// One real VSH at one direction (convenience wrapper over
/// [`VshEvaluator`]).
pub fn vsh_eval(kind: VshKind, l: usize, m: i64, d: [f64; 3]) -> [f64; 3] {
    let mut ev = VshEvaluator::new(l);
    ev.move_to(d);
    ev.eval(kind, l, m)
}

/// The VSH dot-coupling tensor
/// `T[k3, J1, J2] = int Y_{k3} (V_{J1} . V_{J2}) dOmega`, flat
/// `[(l3+1)^2, set1.len(), set2.len()]` row-major, by quadrature exact
/// for the band limit of the integrand.  Its `l3 = 0` row is
/// `delta_{J1 J2} / sqrt(4 pi)` (VSH orthonormality) — the identity the
/// unit tests pin.
pub fn vsh_dot_gaunt(
    l3: usize,
    set1: &[(VshKind, usize, i64)],
    set2: &[(VshKind, usize, i64)],
) -> Vec<f64> {
    let lmax = set1
        .iter()
        .chain(set2)
        .map(|&(_, l, _)| l)
        .max()
        .unwrap_or(0);
    // surface gradients of degree-l SH are degree <= l+1 polynomials in u
    // on the sphere; 2(lmax+1) + l3 bounds the integrand's band limit
    let (nodes, dphi) = sphere_quadrature(l3 + 2 * lmax + 4);
    let (j1, j2) = (set1.len(), set2.len());
    let n3 = num_coeffs(l3);
    let mut out = vec![0.0; n3 * j1 * j2];
    let mut ev = VshEvaluator::new(lmax);
    let mut y3 = vec![0.0; n3];
    let mut v1 = vec![[0.0f64; 3]; j1];
    let mut v2 = vec![[0.0f64; 3]; j2];
    for (theta, phi, w) in &nodes {
        let (st, ct) = theta.sin_cos();
        let (sp, cp) = phi.sin_cos();
        let u = [st * cp, st * sp, ct];
        ev.move_to(u);
        real_sh_all_xyz_into(l3, u, &mut y3);
        for (a, &(k, l, m)) in set1.iter().enumerate() {
            v1[a] = ev.eval(k, l, m);
        }
        for (b, &(k, l, m)) in set2.iter().enumerate() {
            v2[b] = ev.eval(k, l, m);
        }
        let ww = w * dphi;
        for (k3, yk) in y3.iter().enumerate() {
            let wk = ww * yk;
            if wk.abs() < 1e-300 {
                continue;
            }
            let block = &mut out[k3 * j1 * j2..(k3 + 1) * j1 * j2];
            for (a, va) in v1.iter().enumerate() {
                let row = &mut block[a * j2..(a + 1) * j2];
                for (b, vb) in v2.iter().enumerate() {
                    row[b] +=
                        wk * (va[0] * vb[0] + va[1] * vb[1] + va[2] * vb[2]);
                }
            }
        }
    }
    for v in out.iter_mut() {
        if v.abs() < 1e-12 {
            *v = 0.0;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SQRT_4PI: f64 = 3.5449077018110318;

    fn quad_dirs(deg: usize) -> Vec<([f64; 3], f64)> {
        let (nodes, dphi) = sphere_quadrature(deg);
        nodes
            .iter()
            .map(|&(theta, phi, w)| {
                let (st, ct) = theta.sin_cos();
                let (sp, cp) = phi.sin_cos();
                ([st * cp, st * sp, ct], w * dphi)
            })
            .collect()
    }

    #[test]
    fn orthonormal_under_quadrature() {
        let l = 2;
        let set = vsh_set(l, l, l);
        let mut ev = VshEvaluator::new(l);
        let n = set.len();
        let mut gram = vec![0.0; n * n];
        for (u, w) in quad_dirs(2 * l + 6) {
            ev.move_to(u);
            let vals: Vec<[f64; 3]> =
                set.iter().map(|&(k, l, m)| ev.eval(k, l, m)).collect();
            for a in 0..n {
                for b in 0..n {
                    gram[a * n + b] += w
                        * (vals[a][0] * vals[b][0]
                            + vals[a][1] * vals[b][1]
                            + vals[a][2] * vals[b][2]);
                }
            }
        }
        for a in 0..n {
            for b in 0..n {
                let want = if a == b { 1.0 } else { 0.0 };
                assert!(
                    (gram[a * n + b] - want).abs() < 1e-10,
                    "gram[{a},{b}] = {}",
                    gram[a * n + b]
                );
            }
        }
    }

    #[test]
    fn dot_gaunt_l0_row_is_orthonormality() {
        let set = vsh_set(1, 1, 1);
        let t = vsh_dot_gaunt(0, &set, &set);
        let n = set.len();
        for a in 0..n {
            for b in 0..n {
                let want = if a == b { 1.0 / SQRT_4PI } else { 0.0 };
                assert!(
                    (t[a * n + b] - want).abs() < 1e-10,
                    "T[0,{a},{b}] = {}",
                    t[a * n + b]
                );
            }
        }
    }

    #[test]
    fn parity_signs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let set = vsh_set(3, 3, 3);
        let mut ev = VshEvaluator::new(3);
        for _ in 0..5 {
            let d = [rng.normal(), rng.normal(), rng.normal()];
            for &(k, l, m) in &set {
                ev.move_to(d);
                let v = ev.eval(k, l, m);
                ev.move_to([-d[0], -d[1], -d[2]]);
                let vm = ev.eval(k, l, m);
                let p = k.parity(l);
                for x in 0..3 {
                    assert!(
                        (vm[x] - p * v[x]).abs() < 1e-10,
                        "{k:?} l={l} m={m}"
                    );
                }
            }
        }
    }

    #[test]
    fn radial_l0_is_unit_direction() {
        let d = [0.3, -0.8, 0.52];
        let n = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt();
        let v = vsh_eval(VshKind::Radial, 0, 0, d);
        for x in 0..3 {
            assert!((v[x] - d[x] / n / SQRT_4PI).abs() < 1e-12);
        }
    }
}
