//! Small dense linear algebra: row-major matrices, Gaussian elimination,
//! least squares via normal equations.  Only used for modest sizes
//! ((2l+1) <= ~17), where this is plenty accurate and fast.

/// Solve A x = b in place (Gaussian elimination, partial pivoting).
/// `a` is n x n row-major; `b` has n entries.  Returns x.
pub fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for r in (col + 1)..n {
            let v = a[r * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-300 {
            return Err(format!("singular matrix at column {col}"));
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut s = b[row];
        for c in (row + 1)..n {
            s -= a[row * n + c] * x[c];
        }
        x[row] = s / a[row * n + row];
    }
    Ok(x)
}

/// Least squares min ||A x - b||: A is m x n row-major (m >= n).
pub fn lstsq(a: &[f64], b: &[f64], m: usize, n: usize) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), m);
    // normal equations: (A^T A) x = A^T b
    let mut ata = vec![0.0; n * n];
    let mut atb = vec![0.0; n];
    for r in 0..m {
        for i in 0..n {
            let ari = a[r * n + i];
            atb[i] += ari * b[r];
            for j in i..n {
                ata[i * n + j] += ari * a[r * n + j];
            }
        }
    }
    for i in 0..n {
        for j in 0..i {
            ata[i * n + j] = ata[j * n + i];
        }
    }
    solve(&mut ata, &mut atb, n)
}

/// C = A (m x k) * B (k x n), row-major.
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    matmul_into(a, b, m, k, n, &mut c);
    c
}

/// [`matmul`] into a caller buffer of `m * n` (allocation-free).
pub fn matmul_into(
    a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64],
) {
    debug_assert!(a.len() >= m * k && b.len() >= k * n && c.len() >= m * n);
    c[..m * n].fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
    }
}

/// y = A (m x n) * x.
pub fn matvec(a: &[f64], x: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut y = vec![0.0; m];
    matvec_into(a, x, m, n, &mut y);
    y
}

/// [`matvec`] into a caller buffer of `m` entries (allocation-free).
pub fn matvec_into(a: &[f64], x: &[f64], m: usize, n: usize, y: &mut [f64]) {
    debug_assert!(a.len() >= m * n && x.len() >= n && y.len() >= m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        y[i] = row.iter().zip(x).map(|(p, q)| p * q).sum();
    }
}

/// Transpose of an m x n row-major matrix.
pub fn transpose(a: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut t = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            t[j * m + i] = a[i * n + j];
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_identity() {
        let mut a = vec![1.0, 0.0, 0.0, 1.0];
        let mut b = vec![3.0, -4.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert_eq!(x, vec![3.0, -4.0]);
    }

    #[test]
    fn solve_general() {
        // [2 1; 1 3] x = [5; 10] -> x = [1, 3]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_fails() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_err());
    }

    #[test]
    fn lstsq_overdetermined() {
        // fit y = 2x + 1 through noisy-free points
        let xs = [0.0, 1.0, 2.0, 3.0];
        let mut a = Vec::new();
        let mut b = Vec::new();
        for x in xs {
            a.extend_from_slice(&[x, 1.0]);
            b.push(2.0 * x + 1.0);
        }
        let sol = lstsq(&a, &b, 4, 2).unwrap();
        assert!((sol[0] - 2.0).abs() < 1e-12 && (sol[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn matmul_matvec_agree() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2x3
        let x = vec![1.0, 0.5, -1.0];
        let y1 = matvec(&a, &x, 2, 3);
        let y2 = matmul(&a, &x, 2, 3, 1);
        assert_eq!(y1, y2);
    }

    #[test]
    fn transpose_round_trip() {
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let t = transpose(&a, 2, 3);
        let tt = transpose(&t, 3, 2);
        assert_eq!(a, tt);
    }
}
