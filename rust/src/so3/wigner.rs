//! Wigner 3j symbols, Clebsch-Gordan coefficients, complex Gaunt formula
//! (paper Eqns. 22-24).

use super::sh::factorial;

/// Wigner 3j symbol via the Racah explicit sum (paper Eqn. (23)).
pub fn wigner_3j(l1: i64, l2: i64, l3: i64, m1: i64, m2: i64, m3: i64) -> f64 {
    if m1 + m2 + m3 != 0 {
        return 0.0;
    }
    if l3 < (l1 - l2).abs() || l3 > l1 + l2 {
        return 0.0;
    }
    if m1.abs() > l1 || m2.abs() > l2 || m3.abs() > l3 {
        return 0.0;
    }
    let pref = (factorial(l1 + l2 - l3) * factorial(l1 - l2 + l3)
        * factorial(-l1 + l2 + l3)
        / factorial(l1 + l2 + l3 + 1))
    .sqrt()
        * (factorial(l1 - m1)
            * factorial(l1 + m1)
            * factorial(l2 - m2)
            * factorial(l2 + m2)
            * factorial(l3 - m3)
            * factorial(l3 + m3))
        .sqrt();
    let k_min = 0.max(l2 - l3 - m1).max(l1 - l3 + m2);
    let k_max = (l1 + l2 - l3).min(l1 - m1).min(l2 + m2);
    let mut s = 0.0;
    let mut k = k_min;
    while k <= k_max {
        let den = factorial(k)
            * factorial(l1 + l2 - l3 - k)
            * factorial(l1 - m1 - k)
            * factorial(l2 + m2 - k)
            * factorial(l3 - l2 + m1 + k)
            * factorial(l3 - l1 - m2 + k);
        let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
        s += sign / den;
        k += 1;
    }
    let phase = if (l1 - l2 - m3).rem_euclid(2) == 0 { 1.0 } else { -1.0 };
    phase * pref * s
}

/// Clebsch-Gordan coefficient C^{(l,m)}_{(l1,m1)(l2,m2)} (paper Eqn. (22)).
pub fn clebsch_gordan(l1: i64, m1: i64, l2: i64, m2: i64, l: i64, m: i64) -> f64 {
    if m1 + m2 != m {
        return 0.0;
    }
    let phase = if (-l1 + l2 - m).rem_euclid(2) == 0 { 1.0 } else { -1.0 };
    phase * ((2 * l + 1) as f64).sqrt() * wigner_3j(l1, l2, l, m1, m2, -m)
}

/// Complex Gaunt coefficient (integral of three complex SH, Eqn. (24)).
pub fn gaunt_complex(l1: i64, m1: i64, l2: i64, m2: i64, l3: i64, m3: i64) -> f64 {
    (((2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)) as f64
        / (4.0 * std::f64::consts::PI))
        .sqrt()
        * wigner_3j(l1, l2, l3, 0, 0, 0)
        * wigner_3j(l1, l2, l3, m1, m2, m3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_3j_values() {
        assert!((wigner_3j(1, 1, 0, 0, 0, 0) + 1.0 / 3f64.sqrt()).abs() < 1e-13);
        assert!((wigner_3j(1, 1, 2, 0, 0, 0) - (2.0 / 15.0f64).sqrt()).abs() < 1e-13);
        assert!((wigner_3j(2, 2, 2, 0, 0, 0) + (2.0 / 35.0f64).sqrt()).abs() < 1e-13);
        assert!((wigner_3j(1, 1, 1, 1, -1, 0) - 1.0 / 6f64.sqrt()).abs() < 1e-13);
    }

    #[test]
    fn selection_rules() {
        assert_eq!(wigner_3j(1, 1, 3, 0, 0, 0), 0.0);
        assert_eq!(wigner_3j(1, 1, 1, 1, 1, 1), 0.0);
        assert_eq!(wigner_3j(1, 2, 2, 2, 0, -2), 0.0);
        assert_eq!(wigner_3j(1, 1, 1, 0, 0, 0), 0.0); // odd sum at m=0
    }

    #[test]
    fn orthogonality() {
        let (l1, l2) = (2i64, 1i64);
        for l in (l1 - l2).abs()..=(l1 + l2) {
            for lp in (l1 - l2).abs()..=(l1 + l2) {
                for m in -l..=l {
                    for mp in -lp..=lp {
                        let mut s = 0.0;
                        for m1 in -l1..=l1 {
                            for m2 in -l2..=l2 {
                                s += wigner_3j(l1, l2, l, m1, m2, m)
                                    * wigner_3j(l1, l2, lp, m1, m2, mp);
                            }
                        }
                        let want = if l == lp && m == mp {
                            1.0 / (2 * l + 1) as f64
                        } else {
                            0.0
                        };
                        assert!((s - want).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn cg_known_values() {
        assert!((clebsch_gordan(1, 0, 1, 0, 2, 0) - (2.0 / 3.0f64).sqrt()).abs()
            < 1e-13);
        assert!((clebsch_gordan(1, 1, 1, -1, 0, 0) - 1.0 / 3f64.sqrt()).abs()
            < 1e-13);
        assert!((clebsch_gordan(1, 1, 1, 0, 2, 1) - 1.0 / 2f64.sqrt()).abs()
            < 1e-13);
    }

    #[test]
    fn cg_orthogonality_rows() {
        let (l1, l2) = (2i64, 2i64);
        for l in 0..=4i64 {
            for m in -l..=l {
                let mut s = 0.0;
                for m1 in -l1..=l1 {
                    for m2 in -l2..=l2 {
                        let c = clebsch_gordan(l1, m1, l2, m2, l, m);
                        s += c * c;
                    }
                }
                assert!((s - 1.0).abs() < 1e-12, "l={l} m={m}: {s}");
            }
        }
    }

    #[test]
    fn wigner_eckart_ratio_constant() {
        // paper Eqn. (3): complex Gaunt / CG constant over m per (l1,l2,l)
        for (l1, l2, l) in [(1i64, 1i64, 2i64), (2, 1, 3), (2, 2, 2)] {
            let mut ratio: Option<f64> = None;
            for m1 in -l1..=l1 {
                for m2 in -l2..=l2 {
                    let m = m1 + m2;
                    if m.abs() > l {
                        continue;
                    }
                    let cg = clebsch_gordan(l1, m1, l2, m2, l, m);
                    if cg.abs() < 1e-12 {
                        continue;
                    }
                    let sign = if m.rem_euclid(2) == 0 { 1.0 } else { -1.0 };
                    let ga = gaunt_complex(l1, m1, l2, m2, l, -m) * sign;
                    let r = ga / cg;
                    match ratio {
                        None => ratio = Some(r),
                        Some(r0) => assert!((r - r0).abs() < 1e-11),
                    }
                }
            }
            assert!(ratio.is_some());
        }
    }
}
