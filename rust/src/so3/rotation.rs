//! 3D rotations, Euler angles, and real Wigner-D matrices.

use super::linalg;
use super::sh::{real_sh_all_xyz, real_sh_all_xyz_into};
use crate::util::rng::Rng;
use crate::{lm_index, num_coeffs};

/// 3x3 rotation matrix, row-major.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rot3(pub [[f64; 3]; 3]);

impl Rot3 {
    pub fn identity() -> Self {
        Rot3([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]])
    }

    pub fn rot_z(a: f64) -> Self {
        let (s, c) = a.sin_cos();
        Rot3([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
    }

    pub fn rot_y(a: f64) -> Self {
        let (s, c) = a.sin_cos();
        Rot3([[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]])
    }

    /// ZYZ Euler composition Rz(alpha) Ry(beta) Rz(gamma).
    pub fn euler_zyz(alpha: f64, beta: f64, gamma: f64) -> Self {
        Rot3::rot_z(alpha) * Rot3::rot_y(beta) * Rot3::rot_z(gamma)
    }

    /// Haar-ish random rotation (QR of a Gaussian matrix, det fixed to +1).
    pub fn random(rng: &mut Rng) -> Self {
        // Gram-Schmidt on 3 Gaussian vectors
        let mut a = [[0.0f64; 3]; 3];
        loop {
            for row in a.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.normal();
                }
            }
            // orthonormalize rows
            let ok = gram_schmidt(&mut a);
            if ok {
                break;
            }
        }
        // det +1
        let d = det3(&a);
        if d < 0.0 {
            for v in a[0].iter_mut() {
                *v = -*v;
            }
        }
        Rot3(a)
    }

    pub fn apply(&self, v: [f64; 3]) -> [f64; 3] {
        let m = &self.0;
        [
            m[0][0] * v[0] + m[0][1] * v[1] + m[0][2] * v[2],
            m[1][0] * v[0] + m[1][1] * v[1] + m[1][2] * v[2],
            m[2][0] * v[0] + m[2][1] * v[1] + m[2][2] * v[2],
        ]
    }

    pub fn transpose(&self) -> Self {
        let m = &self.0;
        Rot3([
            [m[0][0], m[1][0], m[2][0]],
            [m[0][1], m[1][1], m[2][1]],
            [m[0][2], m[1][2], m[2][2]],
        ])
    }

    pub fn det(&self) -> f64 {
        det3(&self.0)
    }
}

impl std::ops::Mul for Rot3 {
    type Output = Rot3;
    fn mul(self, o: Rot3) -> Rot3 {
        let mut r = [[0.0; 3]; 3];
        for i in 0..3 {
            for j in 0..3 {
                for (k, ok) in o.0.iter().enumerate() {
                    r[i][j] += self.0[i][k] * ok[j];
                }
            }
        }
        Rot3(r)
    }
}

fn det3(m: &[[f64; 3]; 3]) -> f64 {
    m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
        - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
        + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
}

fn gram_schmidt(a: &mut [[f64; 3]; 3]) -> bool {
    for i in 0..3 {
        let mut v = a[i];
        for j in 0..i {
            let d = dot(&a[j], &a[i]);
            for k in 0..3 {
                v[k] -= d * a[j][k];
            }
        }
        let n = dot(&v, &v).sqrt();
        if n < 1e-6 {
            return false;
        }
        for (k, vk) in v.iter().enumerate() {
            a[i][k] = vk / n;
        }
    }
    true
}

fn dot(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

/// Rotation R with R r/||r|| = (0, 1, 0) — the eSCN alignment trick.
pub fn align_to_y(r: [f64; 3]) -> Rot3 {
    let n = dot(&r, &r).sqrt();
    let u = [r[0] / n, r[1] / n, r[2] / n];
    let y = [0.0, 1.0, 0.0];
    let c = dot(&u, &y);
    if c < -1.0 + 1e-12 {
        return Rot3([[1.0, 0.0, 0.0], [0.0, -1.0, 0.0], [0.0, 0.0, -1.0]]);
    }
    let v = [u[1] * y[2] - u[2] * y[1], u[2] * y[0] - u[0] * y[2],
             u[0] * y[1] - u[1] * y[0]];
    let vx = [
        [0.0, -v[2], v[1]],
        [v[2], 0.0, -v[0]],
        [-v[1], v[0], 0.0],
    ];
    let mut out = [[0.0f64; 3]; 3];
    // I + vx + vx^2/(1+c)
    for i in 0..3 {
        for j in 0..3 {
            let mut vx2 = 0.0;
            for (k, vxk) in vx.iter().enumerate() {
                vx2 += vx[i][k] * vxk[j];
            }
            out[i][j] = (i == j) as u8 as f64 + vx[i][j] + vx2 / (1.0 + c);
        }
    }
    Rot3(out)
}

/// Cached fit data for [`wigner_d_real`]: fixed sample directions and the
/// precomputed pseudo-inverse of the unrotated SH sample matrix.  Turns
/// each D^l(R) evaluation into one SH sweep over the rotated points plus a
/// small matmul (perf pass #1, see EXPERIMENTS.md §Perf).
struct DFit {
    pts: Vec<[f64; 3]>,
    /// dim x npts pseudo-inverse (Y^T Y)^{-1} Y^T, row-major
    pinv: Vec<f64>,
}

fn d_fit(l: usize) -> std::sync::Arc<DFit> {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<DFit>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(f) = cache.lock().unwrap().get(&l) {
        return f.clone();
    }
    let dim = 2 * l + 1;
    let npts = dim + 6; // mildly overdetermined for conditioning
    let mut rng = Rng::new(12345 + l as u64);
    let base = lm_index(l, -(l as i64));
    let mut pts = Vec::with_capacity(npts);
    let mut y = vec![0.0; npts * dim];
    for p in 0..npts {
        let u = rng.unit3();
        let a = real_sh_all_xyz(l, u);
        y[p * dim..(p + 1) * dim].copy_from_slice(&a[base..base + dim]);
        pts.push(u);
    }
    // pinv = (Y^T Y)^{-1} Y^T: solve dim systems with RHS = columns of Y^T
    let mut ata = vec![0.0; dim * dim];
    for p in 0..npts {
        for i in 0..dim {
            for j in i..dim {
                ata[i * dim + j] += y[p * dim + i] * y[p * dim + j];
            }
        }
    }
    for i in 0..dim {
        for j in 0..i {
            ata[i * dim + j] = ata[j * dim + i];
        }
    }
    let mut pinv = vec![0.0; dim * npts];
    for col in 0..npts {
        let mut a = ata.clone();
        let mut b: Vec<f64> = (0..dim).map(|i| y[col * dim + i]).collect();
        let x = linalg::solve(&mut a, &mut b, dim).expect("wigner_d fit");
        for (row, v) in x.iter().enumerate() {
            pinv[row * npts + col] = *v;
        }
    }
    let fit = Arc::new(DFit { pts, pinv });
    cache.lock().unwrap().insert(l, fit.clone());
    fit
}

/// Caller-owned scratch for the allocation-free Wigner-D evaluations:
/// sized once for a maximum degree, reused for every rotation.  One per
/// worker thread (the model's conv layer holds one per
/// [`crate::tp::escn::GauntConvScratch`]).
pub struct WignerScratch {
    l_max: usize,
    /// full SH sweep at one sample direction
    sh: Vec<f64>,
    /// rotated sample matrix (npts x dim)
    yr: Vec<f64>,
    /// pinv * yr product (dim x dim, pre-transpose)
    m: Vec<f64>,
    /// per-degree block staging for the block-diagonal assembly
    blk: Vec<f64>,
}

impl WignerScratch {
    /// Scratch serving every `wigner_d_real_into` call with `l <= l_max`.
    pub fn new(l_max: usize) -> WignerScratch {
        let dim = 2 * l_max + 1;
        // size from the authoritative fit (which this also pre-warms)
        // rather than duplicating its overdetermination margin; sample
        // counts grow with l, so the l_max fit bounds every smaller l
        let npts = d_fit(l_max).pts.len();
        WignerScratch {
            l_max,
            sh: vec![0.0; num_coeffs(l_max)],
            yr: vec![0.0; npts * dim],
            m: vec![0.0; dim * dim],
            blk: vec![0.0; dim * dim],
        }
    }
}

/// Real Wigner-D matrix D^l(R) with Y^l(R r) = D^l(R) Y^l(r), solved to
/// machine precision against cached sample directions.
pub fn wigner_d_real(l: usize, rot: &Rot3) -> Vec<f64> {
    let dim = 2 * l + 1;
    let mut out = vec![0.0; dim * dim];
    let mut ws = WignerScratch::new(l);
    wigner_d_real_into(l, rot, &mut out, &mut ws);
    out
}

/// [`wigner_d_real`] into a caller buffer of `(2l+1)^2`: allocation-free
/// once the per-degree fit cache is warm (first call per `l` builds it).
pub fn wigner_d_real_into(
    l: usize, rot: &Rot3, out: &mut [f64], ws: &mut WignerScratch,
) {
    let dim = 2 * l + 1;
    debug_assert!(l <= ws.l_max, "WignerScratch sized for l_max {}", ws.l_max);
    debug_assert!(out.len() >= dim * dim);
    let fit = d_fit(l);
    let npts = fit.pts.len();
    let base = lm_index(l, -(l as i64));
    let sh = &mut ws.sh[..num_coeffs(l)];
    let yr = &mut ws.yr[..npts * dim];
    for (p, u) in fit.pts.iter().enumerate() {
        real_sh_all_xyz_into(l, rot.apply(*u), sh);
        yr[p * dim..(p + 1) * dim].copy_from_slice(&sh[base..base + dim]);
    }
    // M = pinv (dim x npts) * Yr (npts x dim); D = M^T
    let m = &mut ws.m[..dim * dim];
    linalg::matmul_into(&fit.pinv, yr, dim, npts, dim, m);
    for i in 0..dim {
        for j in 0..dim {
            out[j * dim + i] = m[i * dim + j];
        }
    }
}

/// Block-diagonal real Wigner-D on a full (L+1)^2 feature, row-major.
pub fn wigner_d_real_block(l_max: usize, rot: &Rot3) -> Vec<f64> {
    let n = num_coeffs(l_max);
    let mut out = vec![0.0; n * n];
    let mut ws = WignerScratch::new(l_max);
    wigner_d_real_block_into(l_max, rot, &mut out, &mut ws);
    out
}

/// [`wigner_d_real_block`] into a caller buffer of `(L+1)^2 x (L+1)^2`:
/// allocation-free once the fit caches are warm.
pub fn wigner_d_real_block_into(
    l_max: usize, rot: &Rot3, out: &mut [f64], ws: &mut WignerScratch,
) {
    let n = num_coeffs(l_max);
    debug_assert!(out.len() >= n * n);
    out[..n * n].fill(0.0);
    for l in 0..=l_max {
        let dim = 2 * l + 1;
        // stage the degree block in ws.blk, then scatter; the borrow is
        // re-taken per degree so ws.m/ws.yr stay usable inside
        let mut blk = std::mem::take(&mut ws.blk);
        wigner_d_real_into(l, rot, &mut blk, ws);
        let base = lm_index(l, -(l as i64));
        for i in 0..dim {
            for j in 0..dim {
                out[(base + i) * n + (base + j)] = blk[i * dim + j];
            }
        }
        ws.blk = blk;
    }
}

/// Apply a block Wigner-D (row-major n x n) to a feature vector.
pub fn apply_block(d: &[f64], x: &[f64]) -> Vec<f64> {
    let n = x.len();
    linalg::matvec(d, x, n, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rotations_orthogonal() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let r = Rot3::random(&mut rng);
            let rt = r.transpose();
            let p = r * rt;
            for i in 0..3 {
                for j in 0..3 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((p.0[i][j] - want).abs() < 1e-12);
                }
            }
            assert!((r.det() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn euler_identity() {
        let r = Rot3::euler_zyz(0.4, 0.0, -0.4);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((r.0[i][j] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn align_to_y_works() {
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            let v = [rng.normal(), rng.normal(), rng.normal()];
            let r = align_to_y(v);
            let n = dot(&v, &v).sqrt();
            let u = r.apply([v[0] / n, v[1] / n, v[2] / n]);
            assert!(u[0].abs() < 1e-10 && (u[1] - 1.0).abs() < 1e-10
                    && u[2].abs() < 1e-10);
            assert!((r.det() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn align_antiparallel() {
        let r = align_to_y([0.0, -1.0, 0.0]);
        let u = r.apply([0.0, -1.0, 0.0]);
        assert!((u[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wigner_d_is_representation() {
        let mut rng = Rng::new(3);
        let r1 = Rot3::random(&mut rng);
        let r2 = Rot3::random(&mut rng);
        for l in 0..4usize {
            let dim = 2 * l + 1;
            let d1 = wigner_d_real(l, &r1);
            let d2 = wigner_d_real(l, &r2);
            let d12 = wigner_d_real(l, &(r1 * r2));
            let prod = linalg::matmul(&d1, &d2, dim, dim, dim);
            for i in 0..dim * dim {
                assert!((d12[i] - prod[i]).abs() < 1e-9, "l={l} idx={i}");
            }
        }
    }

    #[test]
    fn wigner_d_equivariance() {
        let mut rng = Rng::new(7);
        let rot = Rot3::random(&mut rng);
        for l in 0..4usize {
            let dim = 2 * l + 1;
            let d = wigner_d_real(l, &rot);
            let base = lm_index(l, -(l as i64));
            for _ in 0..5 {
                let u = rng.unit3();
                let a = real_sh_all_xyz(l, rot.apply(u));
                let b = real_sh_all_xyz(l, u);
                let rotated = linalg::matvec(&d, &b[base..base + dim], dim, dim);
                for i in 0..dim {
                    assert!((a[base + i] - rotated[i]).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn wigner_d_orthogonal() {
        let mut rng = Rng::new(9);
        let rot = Rot3::random(&mut rng);
        for l in 0..4usize {
            let dim = 2 * l + 1;
            let d = wigner_d_real(l, &rot);
            let dt = linalg::transpose(&d, dim, dim);
            let p = linalg::matmul(&d, &dt, dim, dim, dim);
            for i in 0..dim {
                for j in 0..dim {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((p[i * dim + j] - want).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn into_variants_match_allocating() {
        let mut rng = Rng::new(21);
        let l_max = 3;
        let n = num_coeffs(l_max);
        let mut ws = WignerScratch::new(l_max);
        for _ in 0..4 {
            let rot = Rot3::random(&mut rng);
            // per-degree
            for l in 0..=l_max {
                let dim = 2 * l + 1;
                let want = wigner_d_real(l, &rot);
                let mut got = vec![0.0; dim * dim];
                wigner_d_real_into(l, &rot, &mut got, &mut ws);
                assert_eq!(want, got, "l={l}");
            }
            // block
            let want = wigner_d_real_block(l_max, &rot);
            let mut got = vec![1.0; n * n]; // dirty buffer: must be cleared
            wigner_d_real_block_into(l_max, &rot, &mut got, &mut ws);
            assert_eq!(want, got);
        }
    }

    #[test]
    fn escn_alignment_sparsifies_filter() {
        // after aligning the edge to y... our SH convention has the m=0
        // column along z; verify the *z*-aligned variant sparsifies, which
        // is what tp::escn uses.
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let v = [rng.normal(), rng.normal(), rng.normal()];
            // rotation sending v to +z: align_to_y composed with y->z swap
            let ry = align_to_y(v);
            let y2z = Rot3([[1.0, 0.0, 0.0], [0.0, 0.0, -1.0], [0.0, 1.0, 0.0]]);
            let r = y2z * ry;
            let u = r.apply(v);
            let n = dot(&u, &u).sqrt();
            assert!((u[2] / n - 1.0).abs() < 1e-9);
            let ysh = real_sh_all_xyz(3, u);
            for l in 0..=3usize {
                for m in -(l as i64)..=(l as i64) {
                    if m != 0 {
                        assert!(ysh[lm_index(l, m)].abs() < 1e-9);
                    }
                }
            }
        }
    }
}
