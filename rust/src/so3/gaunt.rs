//! Real Gaunt tensors and real CG coupling tensors.
//!
//! The real Gaunt tensor `G[k, i, j] = int Y^R_k Y^R_i Y^R_j dOmega` is the
//! coupling of the paper's Gaunt Tensor Product; computed here by exact
//! quadrature (Gauss-Legendre x trapezoid, exact for band-limited
//! integrands).  The real CG tensor (the e3nn-style baseline coupling) is
//! built from the complex Wigner 3j via the real<->complex SH unitary.

use super::quadrature::sphere_quadrature;
use super::sh::real_sh_all_angular;
use super::wigner::wigner_3j;
use crate::fourier::complex::C64;
use crate::{lm_index, num_coeffs};

/// Real Gaunt tensor, shape [(L3+1)^2, (L1+1)^2, (L2+1)^2] row-major
/// (k fastest-varying last: index = (k*n1 + i)*n2 + j).
pub fn gaunt_tensor_real(l1_max: usize, l2_max: usize, l3_max: usize) -> Vec<f64> {
    let deg = l1_max + l2_max + l3_max;
    let (nodes, dphi) = sphere_quadrature(deg);
    let n1 = num_coeffs(l1_max);
    let n2 = num_coeffs(l2_max);
    let n3 = num_coeffs(l3_max);
    let mut out = vec![0.0; n3 * n1 * n2];
    for (theta, phi, w) in &nodes {
        let y1 = real_sh_all_angular(l1_max, *theta, *phi);
        let y2 = real_sh_all_angular(l2_max, *theta, *phi);
        let y3 = real_sh_all_angular(l3_max, *theta, *phi);
        let ww = w * dphi;
        for (k, y3k) in y3.iter().enumerate() {
            let wk = ww * y3k;
            if wk.abs() < 1e-300 {
                continue;
            }
            let block = &mut out[k * n1 * n2..(k + 1) * n1 * n2];
            for (i, y1i) in y1.iter().enumerate() {
                let wi = wk * y1i;
                let row = &mut block[i * n2..(i + 1) * n2];
                for (j, y2j) in y2.iter().enumerate() {
                    row[j] += wi * y2j;
                }
            }
        }
    }
    for v in out.iter_mut() {
        if v.abs() < 1e-12 {
            *v = 0.0;
        }
    }
    out
}

/// Sparse entry list of a coupling tensor: (k, i, j, value).
pub fn sparsify(t: &[f64], n3: usize, n1: usize, n2: usize)
    -> Vec<(u32, u32, u32, f64)> {
    let mut out = Vec::new();
    for k in 0..n3 {
        for i in 0..n1 {
            for j in 0..n2 {
                let v = t[(k * n1 + i) * n2 + j];
                if v != 0.0 {
                    out.push((k as u32, i as u32, j as u32, v));
                }
            }
        }
    }
    out
}

/// U with Y^R_m = sum_mu U[m, mu] Y^C_mu  (rows/cols -l..l), row-major.
fn real_to_complex_u(l: usize) -> Vec<C64> {
    let dim = 2 * l + 1;
    let mut u = vec![C64::default(); dim * dim];
    let c = l; // center
    u[c * dim + c] = C64::real(1.0);
    let s = 0.5f64.sqrt();
    for m in 1..=l {
        let sgn = if m % 2 == 0 { 1.0 } else { -1.0 };
        u[(c + m) * dim + (c + m)] = C64::real(s * sgn);
        u[(c + m) * dim + (c - m)] = C64::real(s);
        u[(c - m) * dim + (c + m)] = C64::new(0.0, -s * sgn);
        u[(c - m) * dim + (c - m)] = C64::new(0.0, s);
    }
    u
}

/// Real-basis Wigner 3j tensor for (l1, l2, l3): [2l1+1, 2l2+1, 2l3+1]
/// row-major; normalized so the sum of squares is 1 inside the triangle.
pub fn w3j_real(l1: usize, l2: usize, l3: usize) -> Vec<f64> {
    let (d1, d2, d3) = (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1);
    let mut out = vec![0.0; d1 * d2 * d3];
    if l3 < l1.abs_diff(l2) || l3 > l1 + l2 {
        return out;
    }
    let u1 = real_to_complex_u(l1);
    let u2 = real_to_complex_u(l2);
    let u3 = real_to_complex_u(l3);
    // complex 3j tensor t[mu1, mu2, mu3]
    let mut t = vec![C64::default(); d1 * d2 * d3];
    for m1 in -(l1 as i64)..=(l1 as i64) {
        for m2 in -(l2 as i64)..=(l2 as i64) {
            let m3 = -(m1 + m2);
            if m3.abs() > l3 as i64 {
                continue;
            }
            let v = wigner_3j(l1 as i64, l2 as i64, l3 as i64, m1, m2, m3);
            let i1 = (l1 as i64 + m1) as usize;
            let i2 = (l2 as i64 + m2) as usize;
            let i3 = (l3 as i64 + m3) as usize;
            t[(i1 * d2 + i2) * d3 + i3] = C64::real(v);
        }
    }
    // out[a,b,c] = sum u1[a,x] u2[b,y] u3[c,z] t[x,y,z]
    let even = (l1 + l2 + l3) % 2 == 0;
    for a in 0..d1 {
        for b in 0..d2 {
            for c in 0..d3 {
                let mut acc = C64::default();
                for x in 0..d1 {
                    let ua = u1[a * d1 + x];
                    if ua.norm_sqr() == 0.0 {
                        continue;
                    }
                    for y in 0..d2 {
                        let ub = u2[b * d2 + y];
                        if ub.norm_sqr() == 0.0 {
                            continue;
                        }
                        let uab = ua * ub;
                        for z in 0..d3 {
                            let uc = u3[c * d3 + z];
                            if uc.norm_sqr() == 0.0 {
                                continue;
                            }
                            acc += uab * uc * t[(x * d2 + y) * d3 + z];
                        }
                    }
                }
                let v = if even { acc.re } else { acc.im };
                out[(a * d2 + b) * d3 + c] = if v.abs() < 1e-12 { 0.0 } else { v };
            }
        }
    }
    out
}

/// Full real CG coupling tensor C[k, i, j] (the O(L^6) baseline's
/// coefficients, paper Eqn. (1)) with sqrt(2l3+1) path normalization.
pub fn cg_tensor_real(l1_max: usize, l2_max: usize, l3_max: usize) -> Vec<f64> {
    let n1 = num_coeffs(l1_max);
    let n2 = num_coeffs(l2_max);
    let n3 = num_coeffs(l3_max);
    let mut out = vec![0.0; n3 * n1 * n2];
    for l1 in 0..=l1_max {
        for l2 in 0..=l2_max {
            let lo = l1.abs_diff(l2);
            let hi = (l1 + l2).min(l3_max);
            for l3 in lo..=hi {
                let w = w3j_real(l1, l2, l3);
                let (d1, d2, d3) = (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1);
                let norm = ((2 * l3 + 1) as f64).sqrt();
                let b1 = lm_index(l1, -(l1 as i64));
                let b2 = lm_index(l2, -(l2 as i64));
                let b3 = lm_index(l3, -(l3 as i64));
                for a in 0..d1 {
                    for b in 0..d2 {
                        for c in 0..d3 {
                            out[((b3 + c) * n1 + (b1 + a)) * n2 + (b2 + b)] +=
                                norm * w[(a * d2 + b) * d3 + c];
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::rotation::{wigner_d_real, Rot3};
    use crate::util::rng::Rng;

    #[test]
    fn gaunt_l0_is_scaled_identity() {
        let g = gaunt_tensor_real(0, 2, 2);
        let c = 1.0 / (4.0 * std::f64::consts::PI).sqrt();
        let n = num_coeffs(2);
        for k in 0..n {
            for j in 0..n {
                let v = g[(k * 1) * n + j]; // n1 = 1
                let want = if k == j { c } else { 0.0 };
                assert!((v - want).abs() < 1e-11);
            }
        }
    }

    #[test]
    fn gaunt_symmetric_in_inputs() {
        let g = gaunt_tensor_real(2, 2, 2);
        let n = num_coeffs(2);
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let a = g[(k * n + i) * n + j];
                    let b = g[(k * n + j) * n + i];
                    assert!((a - b).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn gaunt_fully_symmetric() {
        // integral of three SH: symmetric under any permutation of (k,i,j)
        let g = gaunt_tensor_real(2, 2, 2);
        let n = num_coeffs(2);
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let a = g[(k * n + i) * n + j];
                    let b = g[(i * n + k) * n + j];
                    assert!((a - b).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn gaunt_odd_parity_vanishes() {
        let g = gaunt_tensor_real(1, 1, 1);
        let n = num_coeffs(1);
        // pure l=1 x l=1 -> l=1 block must vanish
        for k in 1..4 {
            for i in 1..4 {
                for j in 1..4 {
                    assert_eq!(g[(k * n + i) * n + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn w3j_real_norm() {
        for (l1, l2, l3) in [(1, 1, 2), (2, 2, 2), (1, 1, 1), (2, 1, 1)] {
            let w = w3j_real(l1, l2, l3);
            let s: f64 = w.iter().map(|x| x * x).sum();
            assert!((s - 1.0).abs() < 1e-10, "{l1}{l2}{l3}: {s}");
        }
    }

    #[test]
    fn w3j_real_equivariant() {
        let mut rng = Rng::new(17);
        let rot = Rot3::random(&mut rng);
        for (l1, l2, l3) in [(1, 1, 1), (1, 1, 2), (2, 1, 2), (2, 2, 2)] {
            let w = w3j_real(l1, l2, l3);
            let (d1m, d2m, d3m) = (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1);
            let d1 = wigner_d_real(l1, &rot);
            let d2 = wigner_d_real(l2, &rot);
            let d3 = wigner_d_real(l3, &rot);
            // sum_{xy} D1[x,a] D2[y,b] w[x,y,c] == sum_d w[a,b,d] D3[c,d]
            for a in 0..d1m {
                for b in 0..d2m {
                    for c in 0..d3m {
                        let mut lhs = 0.0;
                        for x in 0..d1m {
                            for y in 0..d2m {
                                lhs += d1[x * d1m + a] * d2[y * d2m + b]
                                    * w[(x * d2m + y) * d3m + c];
                            }
                        }
                        let mut rhs = 0.0;
                        for d in 0..d3m {
                            rhs += w[(a * d2m + b) * d3m + d] * d3[c * d3m + d];
                        }
                        assert!((lhs - rhs).abs() < 1e-8,
                                "({l1},{l2},{l3}) [{a},{b},{c}]: {lhs} vs {rhs}");
                    }
                }
            }
        }
    }

    #[test]
    fn cg_111_is_cross_product() {
        let c = cg_tensor_real(1, 1, 1);
        let n = num_coeffs(1);
        // contract two pure-l1 vectors; result l=1 part ∝ cross product
        let mut rng = Rng::new(4);
        let a3 = [rng.normal(), rng.normal(), rng.normal()];
        let b3 = [rng.normal(), rng.normal(), rng.normal()];
        // irrep order (m=-1,0,1) = (y,z,x)
        let a = [0.0, a3[1], a3[2], a3[0]];
        let b = [0.0, b3[1], b3[2], b3[0]];
        let mut out = [0.0f64; 4];
        for k in 0..4 {
            for i in 0..4 {
                for j in 0..4 {
                    out[k] += c[(k * n + i) * n + j] * a[i] * b[j];
                }
            }
        }
        let cr = [
            a3[1] * b3[2] - a3[2] * b3[1],
            a3[2] * b3[0] - a3[0] * b3[2],
            a3[0] * b3[1] - a3[1] * b3[0],
        ];
        let cr_irrep = [cr[1], cr[2], cr[0]];
        // proportionality
        let dot_oc: f64 = out[1..].iter().zip(&cr_irrep).map(|(x, y)| x * y).sum();
        let dot_cc: f64 = cr_irrep.iter().map(|x| x * x).sum();
        let k = dot_oc / dot_cc;
        assert!(k.abs() > 1e-3);
        for i in 0..3 {
            assert!((out[1 + i] - k * cr_irrep[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn gaunt_blocks_proportional_to_cg_blocks() {
        // Wigner-Eckart in the real basis: even-parity blocks of the Gaunt
        // tensor are scalar multiples of the real w3j blocks.
        let g = gaunt_tensor_real(2, 2, 2);
        let n = num_coeffs(2);
        for (l1, l2, l3) in [(1usize, 1usize, 2usize), (2, 2, 2), (0, 2, 2)] {
            let w = w3j_real(l1, l2, l3);
            let (d1, d2, d3) = (2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1);
            let b1 = lm_index(l1, -(l1 as i64));
            let b2 = lm_index(l2, -(l2 as i64));
            let b3 = lm_index(l3, -(l3 as i64));
            let mut num = 0.0;
            let mut den = 0.0;
            for a in 0..d1 {
                for b in 0..d2 {
                    for c in 0..d3 {
                        let gv = g[((b3 + c) * n + (b1 + a)) * n + (b2 + b)];
                        let wv = w[(a * d2 + b) * d3 + c];
                        num += gv * wv;
                        den += wv * wv;
                    }
                }
            }
            let k = num / den;
            for a in 0..d1 {
                for b in 0..d2 {
                    for c in 0..d3 {
                        let gv = g[((b3 + c) * n + (b1 + a)) * n + (b2 + b)];
                        let wv = w[(a * d2 + b) * d3 + c];
                        assert!((gv - k * wv).abs() < 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn sparsify_round_trip() {
        let g = gaunt_tensor_real(1, 1, 2);
        let (n1, n2, n3) = (num_coeffs(1), num_coeffs(1), num_coeffs(2));
        let sp = sparsify(&g, n3, n1, n2);
        assert!(!sp.is_empty());
        let mut dense = vec![0.0; n3 * n1 * n2];
        for (k, i, j, v) in &sp {
            dense[((*k as usize) * n1 + *i as usize) * n2 + *j as usize] = *v;
        }
        assert_eq!(dense, g);
    }
}
