//! SO(3)/O(3) representation theory, natively in Rust.
//!
//! Mirrors `python/compile/so3.py` exactly (same conventions: orthonormal
//! real SH, no Condon-Shortley phase, flat `(L+1)^2` irrep layout) so the
//! two implementations cross-validate through the golden vectors in
//! `artifacts/golden/`.

pub mod gaunt;
pub mod linalg;
pub mod quadrature;
pub mod rotation;
pub mod sh;
pub mod vsh;
pub mod wigner;

pub use gaunt::{cg_tensor_real, gaunt_tensor_real};
pub use rotation::{
    align_to_y, wigner_d_real, wigner_d_real_block, wigner_d_real_block_into,
    wigner_d_real_into, Rot3, WignerScratch,
};
pub use sh::{
    assoc_legendre, real_sh_all_xyz, real_sh_all_xyz_into,
    real_sh_angular, real_sh_grad_xyz_into, sh_norm,
};
pub use vsh::{vsh_dot_gaunt, vsh_eval, vsh_set, VshEvaluator, VshKind};
pub use wigner::{clebsch_gordan, gaunt_complex, wigner_3j};
