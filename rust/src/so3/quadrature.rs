//! Gauss-Legendre quadrature nodes (Newton iteration on P_n) and the
//! product rule on the sphere used to build exact Gaunt tensors.

/// Legendre polynomial P_n(x) and derivative P_n'(x).
fn legendre_pd(n: usize, x: f64) -> (f64, f64) {
    let mut p0 = 1.0f64;
    let mut p1 = x;
    if n == 0 {
        return (1.0, 0.0);
    }
    for k in 2..=n {
        let kf = k as f64;
        let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
        p0 = p1;
        p1 = p2;
    }
    // derivative from the recurrence: (x^2-1) P_n' = n (x P_n - P_{n-1})
    let d = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, d)
}

/// Gauss-Legendre nodes and weights on [-1, 1].
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut xs = vec![0.0; n];
    let mut ws = vec![0.0; n];
    for i in 0..n {
        // Tricomi initial guess
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5))
            .cos();
        for _ in 0..100 {
            let (p, d) = legendre_pd(n, x);
            let dx = p / d;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        let (_, d) = legendre_pd(n, x);
        xs[i] = x;
        ws[i] = 2.0 / ((1.0 - x * x) * d * d);
    }
    (xs, ws)
}

/// Quadrature exact for spherical-harmonic products of total degree <= deg.
///
/// Returns ((theta, phi, w_theta) nodes, dphi) with the integral of f over
/// S^2 equal to sum over nodes of `w_theta * dphi * f(theta, phi)`.
pub fn sphere_quadrature(deg: usize) -> (Vec<(f64, f64, f64)>, f64) {
    let n_theta = deg / 2 + 2;
    let (xs, ws) = gauss_legendre(n_theta);
    let n_phi = deg + 2;
    let dphi = 2.0 * std::f64::consts::PI / n_phi as f64;
    let mut nodes = Vec::with_capacity(n_theta * n_phi);
    for (x, w) in xs.iter().zip(&ws) {
        let theta = x.clamp(-1.0, 1.0).acos();
        for j in 0..n_phi {
            nodes.push((theta, j as f64 * dphi, *w));
        }
    }
    (nodes, dphi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nodes_integrate_polynomials_exactly() {
        let (xs, ws) = gauss_legendre(6);
        // integral x^k over [-1,1]
        for k in 0..=11usize {
            let got: f64 = xs.iter().zip(&ws).map(|(x, w)| w * x.powi(k as i32)).sum();
            let want = if k % 2 == 1 { 0.0 } else { 2.0 / (k as f64 + 1.0) };
            assert!((got - want).abs() < 1e-12, "k={k}: {got} vs {want}");
        }
    }

    #[test]
    fn weights_sum_to_two() {
        for n in [2, 5, 9, 16] {
            let (_, ws) = gauss_legendre(n);
            let s: f64 = ws.iter().sum();
            assert!((s - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn sphere_area() {
        let (nodes, dphi) = sphere_quadrature(4);
        let area: f64 = nodes.iter().map(|(_, _, w)| w * dphi).sum();
        assert!((area - 4.0 * std::f64::consts::PI).abs() < 1e-10);
    }

    #[test]
    fn sphere_integrates_z_squared() {
        // int z^2 dOmega = 4 pi / 3
        let (nodes, dphi) = sphere_quadrature(4);
        let got: f64 = nodes
            .iter()
            .map(|(th, _, w)| w * dphi * th.cos() * th.cos())
            .sum();
        assert!((got - 4.0 * std::f64::consts::PI / 3.0).abs() < 1e-10);
    }
}
