//! Experiment drivers: every table and figure of the paper (see DESIGN.md
//! §6 for the index), plus serving/training demos used by the CLI and
//! examples.  Results are printed and written to `target/experiments/`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::server::NativeGauntBackend;
use crate::coordinator::{ForceFieldServer, ServerConfig, Trainer};
use crate::err;
use crate::util::error::Result;
use crate::data::metrics::{efwt, force_cos, force_mae, mae};
use crate::data::{
    energy_stats, gen_adsorbate_dataset, gen_bpa_dataset, gen_dihedral_slices,
    normalize_graphs, EnergyStats, Graph, PaddedBatch,
};
use crate::md::integrator::{Integrator, Thermostat};
use crate::md::molecule::Molecule;
use crate::nbody::{dataset as nbody_dataset, NbodyConfig, NbodySample};
use crate::runtime::{Engine, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

const R_CUT: f64 = 4.0;
const FF_ATOMS: usize = 32;
const FF_EDGES: usize = 128;

// ---------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------

/// Batch tensors for the ff train-step artifacts (input order: pos,
/// species, edges, edge_mask, atom_mask, energy, forces).
pub fn ff_batch_tensors(pb: &PaddedBatch, with_labels: bool) -> Vec<Tensor> {
    let mut v = vec![
        Tensor::F32(pb.pos.clone()),
        Tensor::I32(pb.species.clone()),
        Tensor::I32(pb.edges.clone()),
        Tensor::F32(pb.edge_mask.clone()),
        Tensor::F32(pb.atom_mask.clone()),
    ];
    if with_labels {
        v.push(Tensor::F32(pb.energy.clone()));
        v.push(Tensor::F32(pb.forces.clone()));
    }
    v
}

/// Evaluate a trained state on a dataset with a fwd artifact; returns
/// (energy MAE [per-atom], force MAE, force cos, EFwT) in normalized units.
pub fn eval_forcefield(
    engine: &Engine,
    fwd_name: &str,
    state: &[Tensor],
    graphs: &[Graph],
) -> Result<(f64, f64, f64, f64)> {
    let exe = engine.load(fwd_name)?;
    let b = exe
        .meta
        .get("batch")
        .and_then(Json::as_usize)
        .ok_or_else(|| err!("fwd artifact missing batch meta"))?;
    let mut e_pred = Vec::new();
    let mut e_true = Vec::new();
    let mut f_pred: Vec<Vec<[f64; 3]>> = Vec::new();
    let mut f_true: Vec<Vec<[f64; 3]>> = Vec::new();
    for chunk in graphs.chunks(b) {
        let pb = PaddedBatch::from_graphs(chunk, b, FF_ATOMS, FF_EDGES, R_CUT);
        let mut inputs: Vec<Tensor> = state.to_vec();
        inputs.extend(ff_batch_tensors(&pb, false));
        let out = exe.run(&inputs)?;
        let energy = out[0].as_f32()?;
        let forces = out[1].as_f32()?;
        for (gi, g) in chunk.iter().enumerate() {
            let na = g.n_atoms();
            e_pred.push(energy[gi] as f64 / na as f64);
            e_true.push(g.energy / na as f64);
            let mut fp = Vec::with_capacity(na);
            for a in 0..na {
                let base = (gi * FF_ATOMS + a) * 3;
                fp.push([
                    forces[base] as f64,
                    forces[base + 1] as f64,
                    forces[base + 2] as f64,
                ]);
            }
            f_pred.push(fp);
            f_true.push(g.forces.clone());
        }
    }
    let e_mae = mae(&e_pred, &e_true);
    let f_mae = force_mae(&f_pred, &f_true);
    let f_cos = force_cos(&f_pred, &f_true);
    // thresholds chosen so the metric discriminates in normalized units
    let ew: Vec<f64> = e_pred
        .iter()
        .zip(&e_true)
        .map(|(a, b)| (a - b) * 14.0)
        .collect(); // scale back to total energy-ish
    let et: Vec<f64> = vec![0.0; ew.len()];
    let efwt_v = efwt(&ew, &et, &f_pred, &f_true, 0.4, 0.6);
    Ok((e_mae, f_mae, f_cos, efwt_v))
}

fn write_result_json(name: &str, value: &Json) {
    let dir = std::path::Path::new("target/experiments");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(format!("{name}.json"));
    if std::fs::write(&path, value.to_string()).is_ok() {
        println!("[json] wrote {path:?}");
    }
}

// ---------------------------------------------------------------------
// artifact smoke check
// ---------------------------------------------------------------------

/// Load every artifact and run it once on zero inputs (shape check).
pub fn check_artifacts(engine: &Arc<Engine>) -> Result<()> {
    let mut names = engine.artifact_names();
    names.sort();
    for name in &names {
        let t0 = Instant::now();
        let exe = engine.load(name)?;
        let inputs: Vec<Tensor> = exe
            .inputs
            .iter()
            .map(|s| match s.dtype {
                crate::runtime::DType::F32 => Tensor::F32(vec![0.0; s.numel()]),
                crate::runtime::DType::I32 => Tensor::I32(vec![0; s.numel()]),
            })
            .collect();
        let out = exe.run(&inputs)?;
        println!(
            "ok  {name:<28} {} inputs -> {} outputs  (compile+run {:.2}s)",
            exe.inputs.len(),
            out.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("all {} artifacts pass", names.len());
    Ok(())
}

// ---------------------------------------------------------------------
// serving demo (the vLLM-style path)
// ---------------------------------------------------------------------

/// The demo's batch policy (shared by the XLA and native variants).
fn serve_demo_config() -> ServerConfig {
    ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(4),
            max_queue: 4096,
        },
        n_workers: 2,
        r_cut: R_CUT,
        ..Default::default()
    }
}

/// Drive a started server with MD-sampled client structures and report
/// throughput + metrics; consumes (and shuts down) the server.
fn run_serve_demo(
    server: ForceFieldServer, n_requests: usize, label: &str,
) -> Result<()> {
    let graphs = gen_bpa_dataset(&[0.05], n_requests, 7).remove(0);
    let t0 = Instant::now();
    let tickets: Vec<_> = graphs
        .iter()
        .map(|g| server.submit(g.pos.clone(), g.species.clone()).unwrap())
        .collect();
    let mut ok = 0usize;
    for ticket in tickets {
        let resp = ticket.wait().map_err(|e| err!("{e}"))?;
        assert_eq!(resp.forces.len(), graphs[0].n_atoms());
        ok += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {ok} requests{label} in {dt:.3}s  ({:.1} req/s)",
        ok as f64 / dt
    );
    println!("metrics: {}", server.metrics().report());
    server.shutdown();
    Ok(())
}

pub fn serve_demo(engine: Arc<Engine>, n_requests: usize) -> Result<()> {
    let server = ForceFieldServer::start(engine, serve_demo_config())?;
    run_serve_demo(server, n_requests, "")
}

/// Serving demo on the native Gaunt-TP backend: the full coordinator
/// stack (batcher -> router -> worker pool) with every batch executed by
/// the engine's cached plans + multi-threaded batched TP — runs offline,
/// no compiled artifacts required.
pub fn serve_demo_native(n_requests: usize) -> Result<()> {
    let server = ForceFieldServer::start_native(
        NativeGauntBackend::default(),
        serve_demo_config(),
    )?;
    run_serve_demo(server, n_requests, " natively")?;
    let stats = crate::tp::engine::PlanCache::global().stats();
    println!(
        "plan cache: {} plans, {} builds, {} hits",
        stats.len, stats.builds, stats.hits
    );
    for ks in stats.per_key.iter().take(5) {
        println!("  {:?}: {} hits", ks.key, ks.hits);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// load test: the typed Client API under concurrent mixed-size traffic
// ---------------------------------------------------------------------

/// Drive a native [`Service`] with concurrent clients submitting a
/// bimodal (small/large structure) `EnergyForces` stream through the
/// typed [`Client`] handle, and report p50/p99 latency, throughput, and
/// the padding accounting (`atom_fill`) of the shape-bucketed queue —
/// the `make loadtest` entry point.
pub fn loadtest(
    n_requests: usize, n_clients: usize, n_workers: usize, bucketed: bool,
) -> Result<()> {
    use crate::coordinator::batcher::BucketConfig;
    use crate::coordinator::request::{EnergyForces, Request, Structure};
    use crate::coordinator::Service;

    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: std::time::Duration::from_millis(2),
        max_queue: 65536,
    };
    let mut builder = Service::builder()
        .native(NativeGauntBackend::default())
        .config(ServerConfig {
            policy,
            n_workers,
            r_cut: R_CUT,
            ..Default::default()
        });
    if !bucketed {
        // the pre-redesign baseline: ONE worst-case-width queue
        builder = builder.buckets(vec![BucketConfig {
            max_atoms: 32,
            max_edges: 256,
            policy,
        }]);
    }
    let service = builder.build()?;
    println!(
        "loadtest: {n_requests} requests x {n_clients} clients, \
         {n_workers} workers, {} ({} buckets)",
        if bucketed { "shape-bucketed" } else { "single global queue" },
        service.buckets().len()
    );

    // bimodal workload: 14-atom MD samples + 4-atom clusters
    let big = gen_bpa_dataset(&[0.05], 8, 7).remove(0);
    let mut structures: Vec<Structure> = Vec::new();
    let mut rng = Rng::new(42);
    for (i, g) in big.iter().enumerate() {
        structures.push(Structure::new(g.pos.clone(), g.species.clone()));
        let small: Vec<[f64; 3]> = (0..4)
            .map(|_| [rng.normal(), rng.normal(), rng.normal()])
            .collect();
        structures.push(Structure::new(small, vec![i % 3; 4]));
    }

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients.max(1) {
        let client = service.client();
        let structs = structures.clone();
        let per_client = n_requests / n_clients.max(1);
        handles.push(std::thread::spawn(move || -> Vec<f64> {
            let mut lat = Vec::with_capacity(per_client);
            for k in 0..per_client {
                let st = structs[(c + k) % structs.len()].clone();
                match client
                    .submit(Request::new(EnergyForces(st)))
                    .map(|t| t.wait())
                {
                    Ok(Ok(resp)) => lat.push(resp.latency_s),
                    Ok(Err(e)) => eprintln!("request failed: {e}"),
                    Err(e) => eprintln!("submit rejected: {e}"),
                }
            }
            lat
        }));
    }
    let mut all_lat: Vec<f64> = Vec::new();
    for h in handles {
        all_lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    if all_lat.is_empty() {
        return Err(err!("no request completed"));
    }
    all_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = all_lat.len();
    println!("throughput : {:.1} structures/s", total as f64 / wall);
    println!("p50 latency: {:.3} ms", 1e3 * all_lat[total / 2]);
    println!(
        "p99 latency: {:.3} ms",
        1e3 * all_lat[(total * 99 / 100).min(total - 1)]
    );
    println!("atom_fill  : {:.3}", service.metrics().atom_fill());
    println!("metrics    : {}", service.metrics().report());
    service.shutdown();
    Ok(())
}

// ---------------------------------------------------------------------
// batched-TP throughput (table 2 native rows: 1 thread vs all cores)
// ---------------------------------------------------------------------

/// Batched Gaunt-TP throughput, single-thread vs multi-thread, using the
/// global plan cache — the native rows of the speed/memory table.
pub fn tp_throughput(rows: usize) -> Result<()> {
    use crate::tp::engine::{OpKey, PlanCache};
    use crate::tp::op::{apply_batch_par, BatchInputs};
    use crate::tp::ConvMethod;
    use crate::util::pool;

    let threads = pool::default_threads();
    println!("batched Gaunt TP throughput: {rows} rows, 1 vs {threads} threads");
    let mut out = Vec::new();
    for l in [2usize, 4, 6] {
        let n = crate::num_coeffs(l);
        let mut rng = Rng::new(100 + l as u64);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        // the serving configuration: resolve the op uniformly through
        // the cache and run the generic batched driver
        let op = PlanCache::global().op(&OpKey::Gaunt {
            l1: l, l2: l, l3: l, method: ConvMethod::Auto,
        });
        let batch = BatchInputs::pair(&x1, &x2);
        // best-of-3 wallclock per mode
        let mut t_serial = f64::INFINITY;
        let mut t_par = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            let a = apply_batch_par(op.as_ref(), &batch, rows, 1);
            t_serial = t_serial.min(t0.elapsed().as_secs_f64());
            let t0 = Instant::now();
            let b = apply_batch_par(op.as_ref(), &batch, rows, 0);
            t_par = t_par.min(t0.elapsed().as_secs_f64());
            assert_eq!(a, b, "parallel path diverged from serial");
        }
        let speedup = t_serial / t_par;
        println!(
            "L={l}: {:>10.1} rows/s x1   {:>10.1} rows/s x{threads}   \
             speedup {speedup:.2}x",
            rows as f64 / t_serial,
            rows as f64 / t_par,
        );
        out.push(Json::obj(vec![
            ("l", Json::Num(l as f64)),
            ("rows", Json::Num(rows as f64)),
            ("threads", Json::Num(threads as f64)),
            ("s_serial", Json::Num(t_serial)),
            ("s_par", Json::Num(t_par)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    write_result_json("tp_throughput", &Json::Arr(out));
    Ok(())
}

// ---------------------------------------------------------------------
// training driver (shared by CLI, examples, table1/table2)
// ---------------------------------------------------------------------

/// Train GauntNet (variant "gaunt" or "cg") on the synthetic adsorbate
/// dataset; returns (trainer state, stats, wallclock seconds per step).
pub fn train_forcefield(
    engine: &Engine,
    variant: &str,
    steps: usize,
    verbose: bool,
) -> Result<(Vec<Tensor>, EnergyStats, f64)> {
    let mut train = gen_adsorbate_dataset(64, 11);
    let stats = energy_stats(&train);
    normalize_graphs(&mut train, stats);
    let mut trainer = Trainer::new(
        engine,
        &format!("ff_train_step_{variant}"),
        &format!("ff_state_init_{variant}"),
    )?;
    let b = trainer.batch_size();
    let mut rng = Rng::new(5);
    let mut order: Vec<usize> = (0..train.len()).collect();
    let t0 = Instant::now();
    for step in 0..steps {
        if step % train.len().div_ceil(b) == 0 {
            rng.shuffle(&mut order);
        }
        let start = (step * b) % (train.len() - b + 1);
        let chunk: Vec<Graph> = order[start..start + b]
            .iter()
            .map(|&i| train[i].clone())
            .collect();
        let pb = PaddedBatch::from_graphs(&chunk, b, FF_ATOMS, FF_EDGES, R_CUT);
        let loss = trainer.step(ff_batch_tensors(&pb, true))?;
        if verbose && (step % 20 == 0 || step + 1 == steps) {
            println!(
                "step {step:>4}  loss {loss:.5}  (avg20 {:.5})",
                trainer.recent_loss(20)
            );
        }
    }
    let per_step = t0.elapsed().as_secs_f64() / steps.max(1) as f64;
    if verbose {
        println!(
            "trained {steps} steps ({variant}), {:.3}s/step, final loss {:.5}",
            per_step,
            trainer.recent_loss(10)
        );
    }
    Ok((trainer.take_state(), stats, per_step))
}

// ---------------------------------------------------------------------
// fig1d: SEGNN N-body sanity check (Gaunt vs CG parameterization)
// ---------------------------------------------------------------------

fn nbody_batch_tensors(samples: &[NbodySample], b: usize,
                       with_target: bool) -> Vec<Tensor> {
    let n = 5usize;
    let e = 20usize;
    let mut pos = vec![0f32; b * n * 3];
    let mut vel = vec![0f32; b * n * 3];
    let mut charge = vec![0i32; b * n];
    let mut edges = vec![0i32; b * e * 2];
    let mut em = vec![0f32; b * e];
    let mut am = vec![0f32; b * n];
    let mut target = vec![0f32; b * n * 3];
    for (s_idx, s) in samples.iter().enumerate() {
        for a in 0..n {
            for k in 0..3 {
                pos[(s_idx * n + a) * 3 + k] = s.pos[a][k] as f32;
                vel[(s_idx * n + a) * 3 + k] = s.vel[a][k] as f32;
                target[(s_idx * n + a) * 3 + k] = s.target[a][k] as f32;
            }
            charge[s_idx * n + a] = s.charge[a] as i32;
            am[s_idx * n + a] = 1.0;
        }
        let mut idx = 0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    edges[(s_idx * e + idx) * 2] = i as i32;
                    edges[(s_idx * e + idx) * 2 + 1] = j as i32;
                    em[s_idx * e + idx] = 1.0;
                    idx += 1;
                }
            }
        }
    }
    let mut v = vec![
        Tensor::F32(pos),
        Tensor::F32(vel),
        Tensor::I32(charge),
        Tensor::I32(edges),
        Tensor::F32(em),
        Tensor::F32(am),
    ];
    if with_target {
        v.push(Tensor::F32(target));
    }
    v
}

fn nbody_eval(engine: &Engine, tp: &str, state: &[Tensor],
              test: &[NbodySample]) -> Result<f64> {
    let exe = engine.load(&format!("nbody_fwd_{tp}"))?;
    let b = exe.meta.get("batch").and_then(Json::as_usize).unwrap_or(16);
    let mut se = 0.0f64;
    let mut count = 0usize;
    for chunk in test.chunks(b) {
        let mut padded: Vec<NbodySample> = chunk.to_vec();
        while padded.len() < b {
            padded.push(chunk[0].clone());
        }
        let mut inputs = state.to_vec();
        inputs.extend(nbody_batch_tensors(&padded, b, false));
        let out = exe.run(&inputs)?;
        let pred = out[0].as_f32()?;
        for (s_idx, s) in chunk.iter().enumerate() {
            for a in 0..5 {
                for k in 0..3 {
                    let p = pred[(s_idx * 5 + a) * 3 + k] as f64;
                    let d = p - s.target[a][k];
                    se += d * d;
                    count += 1;
                }
            }
        }
    }
    Ok(se / count as f64)
}

/// Fig. 1 (last panel): position-forecast MSE, Gaunt vs CG SEGNN.
pub fn fig1d_sanity_check(engine: &Arc<Engine>) -> Result<()> {
    let cfg = NbodyConfig { horizon_steps: 500, ..Default::default() };
    let train = nbody_dataset(&cfg, 256, 100);
    let test = nbody_dataset(&cfg, 64, 999);
    // CPU budget: interpret-mode pallas steps are slow (EXPERIMENTS.md §Perf)
    let steps = std::env::var("GTP_STEPS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(80usize);
    let mut results = Vec::new();
    for tp in ["gaunt", "cg"] {
        let mut trainer = Trainer::new(
            engine,
            &format!("nbody_train_{tp}"),
            &format!("nbody_state_init_{tp}"),
        )?;
        let b = trainer.batch_size();
        let mut rng = Rng::new(3);
        for step in 0..steps {
            let batch: Vec<NbodySample> = (0..b)
                .map(|_| train[rng.below(train.len())].clone())
                .collect();
            let loss = trainer.step(nbody_batch_tensors(&batch, b, true))?;
            if step % 50 == 0 {
                println!("[fig1d:{tp}] step {step} loss {loss:.6}");
            }
        }
        let mse = nbody_eval(engine, tp, trainer.state(), &test)?;
        println!("[fig1d:{tp}] test MSE {mse:.6}");
        results.push((tp.to_string(), mse));
    }
    let (g, c) = (results[0].1, results[1].1);
    println!(
        "fig1d sanity check: Gaunt MSE {g:.6} vs CG MSE {c:.6}  \
         (paper: parameterizations perform comparably)"
    );
    write_result_json(
        "fig1d",
        &Json::obj(vec![
            ("gaunt_mse", Json::Num(g)),
            ("cg_mse", Json::Num(c)),
        ]),
    );
    Ok(())
}

// ---------------------------------------------------------------------
// table1: OC20-analog S2EF
// ---------------------------------------------------------------------

/// Table 1 analog: GauntNet with CG Selfmix vs Gaunt Selfmix on the
/// synthetic adsorbate-on-slab S2EF task.
pub fn table1_oc_analog(engine: &Arc<Engine>) -> Result<()> {
    let steps = std::env::var("GTP_STEPS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(40usize);
    let mut test = gen_adsorbate_dataset(32, 77);
    let mut rows = Vec::new();
    for variant in ["cg", "gaunt"] {
        let (state, stats, per_step) =
            train_forcefield(engine, variant, steps, false)?;
        let mut test_n = test.clone();
        normalize_graphs(&mut test_n, stats);
        let fwd = if variant == "gaunt" { "ff_fwd_B8" } else { "ff_fwd_cg_B8" };
        let (e_mae, f_mae, f_cos, efwt_v) =
            eval_forcefield(engine, fwd, &state, &test_n)?;
        println!(
            "[table1:{variant:<5}] E-MAE/atom {e_mae:.4}  F-MAE {f_mae:.4}  \
             Fcos {f_cos:.3}  EFwT {:.1}%  ({per_step:.2}s/step)",
            100.0 * efwt_v
        );
        rows.push((variant.to_string(), e_mae, f_mae, f_cos, efwt_v, per_step));
    }
    test.clear();
    write_result_json(
        "table1",
        &Json::Arr(
            rows.iter()
                .map(|(v, e, f, c, w, s)| {
                    Json::obj(vec![
                        ("variant", Json::Str(v.clone())),
                        ("energy_mae", Json::Num(*e)),
                        ("force_mae", Json::Num(*f)),
                        ("force_cos", Json::Num(*c)),
                        ("efwt", Json::Num(*w)),
                        ("s_per_step", Json::Num(*s)),
                    ])
                })
                .collect(),
        ),
    );
    Ok(())
}

// ---------------------------------------------------------------------
// table2: 3BPA-analog
// ---------------------------------------------------------------------

/// Table 2 analog: train at 300 K-analog, test at 300/600/1200 K analogs +
/// dihedral slices; Gaunt vs CG parameterization.
pub fn table2_bpa_analog(engine: &Arc<Engine>) -> Result<()> {
    // temperatures in reduced units: 0.05 ~ 300 K, 0.10 ~ 600 K, 0.20 ~ 1200 K
    let temps = [0.05, 0.10, 0.20];
    let sets = gen_bpa_dataset(&temps, 48, 21);
    let mut train = sets[0][..32].to_vec();
    let stats = energy_stats(&train);
    normalize_graphs(&mut train, stats);
    let steps = std::env::var("GTP_STEPS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(40usize);
    let mut table: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for variant in ["gaunt", "cg"] {
        let mut trainer = Trainer::new(
            engine,
            &format!("ff_train_step_{variant}"),
            &format!("ff_state_init_{variant}"),
        )?;
        let b = trainer.batch_size();
        let mut rng = Rng::new(9);
        for step in 0..steps {
            let chunk: Vec<Graph> = (0..b)
                .map(|_| train[rng.below(train.len())].clone())
                .collect();
            let pb =
                PaddedBatch::from_graphs(&chunk, b, FF_ATOMS, FF_EDGES, R_CUT);
            let loss = trainer.step(ff_batch_tensors(&pb, true))?;
            if step % 100 == 0 {
                println!("[table2:{variant}] step {step} loss {loss:.5}");
            }
        }
        let state = trainer.take_state();
        let fwd = if variant == "gaunt" { "ff_fwd_B8" } else { "ff_fwd_cg_B8" };
        let mut rows = Vec::new();
        let labels = ["300K", "600K", "1200K", "dihedral"];
        let mut eval_sets: Vec<Vec<Graph>> = vec![
            sets[0][32..].to_vec(),
            sets[1].clone(),
            sets[2].clone(),
            gen_dihedral_slices(24),
        ];
        for (label, set) in labels.iter().zip(eval_sets.iter_mut()) {
            normalize_graphs(set, stats);
            let (e_mae, f_mae, _, _) =
                eval_forcefield(engine, fwd, &state, set)?;
            println!(
                "[table2:{variant:<5}] {label:<9} E-MAE {e_mae:.4}  F-MAE {f_mae:.4}"
            );
            rows.push((e_mae, f_mae));
        }
        table.push((variant.to_string(), rows));
    }
    write_result_json(
        "table2",
        &Json::Arr(
            table
                .iter()
                .map(|(v, rows)| {
                    Json::obj(vec![
                        ("variant", Json::Str(v.clone())),
                        (
                            "rows",
                            Json::Arr(
                                rows.iter()
                                    .map(|(e, f)| {
                                        Json::arr_f64(&[*e, *f])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        ),
    );
    Ok(())
}

// ---------------------------------------------------------------------
// md demo
// ---------------------------------------------------------------------

pub fn md_demo() -> Result<()> {
    let mol = Molecule::bpa_lite();
    let mut rng = Rng::new(0);
    let mut md = Integrator::new(
        mol.pos.clone(),
        mol.species.clone(),
        &mol.potential,
        0.002,
        Thermostat::Langevin { gamma: 1.0, temperature: 0.05 },
    );
    md.thermalize(0.05, &mut rng);
    println!("3BPA-lite: {} atoms, E0 = {:.4}", mol.n_atoms(),
             md.potential_energy);
    for block in 0..10 {
        for _ in 0..500 {
            md.step(&mol.potential, &mut rng);
        }
        println!(
            "t = {:>5.1}  E_pot {:>9.4}  E_tot {:>9.4}  T {:.4}",
            (block + 1) as f64 * 500.0 * 0.002,
            md.potential_energy,
            md.total_energy(),
            md.temperature()
        );
    }
    Ok(())
}

// used by the serve path metric assertions in tests
pub fn metrics_requests(server: &ForceFieldServer) -> u64 {
    server.metrics().requests.load(Ordering::Relaxed)
}
