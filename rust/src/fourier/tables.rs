//! SH <-> 2D-Fourier conversion tables (mirrors `python/compile/fourier.py`).
//!
//! * `theta_fourier(l, m)` — coefficients of the signed torus extension of
//!   `N P_l^m(cos th)` (trig polynomial of degree l; FFT-sampled, exact).
//! * `theta_projection(l, m, N)` — `int_0^pi e^{iu th} N P sin(th) dth`
//!   via trig-poly algebra and the analytic integral
//!   I(0)=pi, I(odd n)=2i/n, I(even n)=0.
//! * packed per-|v| panels consumed by the O(L^3) fast path in `tp::gaunt`.

use super::complex::{as_floats, C64};
use super::fft::fft;
use crate::so3::sh::{assoc_legendre, sh_norm};
use crate::util::simd::{F64x4, SimdLanes};

pub const SQRT2_OVER_2: f64 = std::f64::consts::FRAC_1_SQRT_2;

/// Coefficients c_u (u = -l..l) of the theta-part trig polynomial.
pub fn theta_fourier(l: usize, m: usize) -> Vec<C64> {
    let n = 4 * l + 8;
    let mut g = vec![C64::default(); n];
    for (k, gk) in g.iter_mut().enumerate() {
        let theta = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
        let mut v = assoc_legendre(l, m, theta.cos()) * sh_norm(l, m as i64);
        if m % 2 == 1 && theta.sin() < 0.0 {
            v = -v;
        }
        *gk = C64::real(v);
    }
    let c = fft(&g);
    let scale = 1.0 / n as f64;
    let mut out = vec![C64::default(); 2 * l + 1];
    for u in -(l as i64)..=(l as i64) {
        let idx = u.rem_euclid(n as i64) as usize;
        out[(l as i64 + u) as usize] = c[idx].scale(scale);
    }
    out
}

/// t_u = int_0^pi e^{iu th} N P_l^m(cos th) sin th dth for u=-N..N.
pub fn theta_projection(l: usize, m: usize, n_grid: usize) -> Vec<C64> {
    let n = 4 * (l + 1) + 8;
    let mut h = vec![C64::default(); n];
    for (k, hk) in h.iter_mut().enumerate() {
        let theta = 2.0 * std::f64::consts::PI * k as f64 / n as f64;
        let mut v = assoc_legendre(l, m, theta.cos()) * sh_norm(l, m as i64)
            * theta.sin();
        if m % 2 == 1 && theta.sin() < 0.0 {
            v = -v;
        }
        *hk = C64::real(v);
    }
    let c = fft(&h);
    let scale = 1.0 / n as f64;
    let deg = l as i64 + 1;
    let integral = |nn: i64| -> C64 {
        if nn == 0 {
            C64::real(std::f64::consts::PI)
        } else if nn % 2 == 0 {
            C64::default()
        } else {
            C64::new(0.0, 2.0 / nn as f64)
        }
    };
    let mut out = vec![C64::default(); 2 * n_grid + 1];
    for u in -(n_grid as i64)..=(n_grid as i64) {
        let mut acc = C64::default();
        for k in -deg..=deg {
            let dk = c[k.rem_euclid(n as i64) as usize].scale(scale);
            acc += dk * integral(u + k);
        }
        out[(n_grid as i64 + u) as usize] = acc;
    }
    out
}

/// sh2f panels: P[s][u * (L+1) + l] complex, s = 0..=L, u index 0..2L.
/// Zero where l < s.
pub struct Sh2fPanels {
    pub l_max: usize,
    /// panels[s] is a (2L+1) x (L+1) row-major complex matrix over (u, l)
    pub panels: Vec<Vec<C64>>,
}

pub fn sh2f_panels(l_max: usize) -> Sh2fPanels {
    let nu = 2 * l_max + 1;
    let nl = l_max + 1;
    let mut panels = Vec::with_capacity(nl);
    for s in 0..=l_max {
        let mut p = vec![C64::default(); nu * nl];
        for l in s..=l_max {
            let pf = theta_fourier(l, s); // u = -l..l
            for (k, v) in pf.iter().enumerate() {
                let u_idx = l_max - l + k;
                p[u_idx * nl + l] = *v;
            }
        }
        panels.push(p);
    }
    Sh2fPanels { l_max, panels }
}

/// f2sh panels: T[s][l * (2N+1) + u] complex over (l, u), s = 0..=L_out.
pub struct F2shPanels {
    pub l_out: usize,
    pub n_grid: usize,
    pub panels: Vec<Vec<C64>>,
}

pub fn f2sh_panels(l_out: usize, n_grid: usize) -> F2shPanels {
    let nu = 2 * n_grid + 1;
    let nl = l_out + 1;
    let mut panels = Vec::with_capacity(nl);
    for s in 0..=l_out {
        let mut t = vec![C64::default(); nl * nu];
        for l in s..=l_out {
            let tp = theta_projection(l, s, n_grid);
            t[l * nu..(l + 1) * nu].copy_from_slice(&tp);
        }
        panels.push(t);
    }
    F2shPanels { l_out, n_grid, panels }
}

/// Transposed f2sh panels: Tt[s][u * (L_out+1) + l] over (u, l).
///
/// The back-projection contracts the product grid row by row; with this
/// layout both the grid walk (u outer) and the table walk (l inner) are
/// unit-stride, replacing the stride-(2N+1) column scans of the original
/// [`F2shPanels`] orientation (kept for the Python golden comparisons).
pub struct F2shPanelsT {
    pub l_out: usize,
    pub n_grid: usize,
    /// panels[s] is a (2N+1) x (L_out+1) row-major matrix over (u, l)
    pub panels: Vec<Vec<C64>>,
}

impl F2shPanelsT {
    /// Transpose the (l, u)-major panels into (u, l)-major.
    pub fn from_panels(t: &F2shPanels) -> F2shPanelsT {
        let nu = 2 * t.n_grid + 1;
        let nl = t.l_out + 1;
        let panels = t
            .panels
            .iter()
            .map(|p| {
                let mut q = vec![C64::default(); nu * nl];
                for l in 0..nl {
                    for u in 0..nu {
                        q[u * nl + l] = p[l * nu + u];
                    }
                }
                q
            })
            .collect();
        F2shPanelsT { l_out: t.l_out, n_grid: t.n_grid, panels }
    }

    /// Build directly for `(l_out, n_grid)`.
    pub fn build(l_out: usize, n_grid: usize) -> F2shPanelsT {
        F2shPanelsT::from_panels(&f2sh_panels(l_out, n_grid))
    }
}

/// Largest `l_out + 1` the SIMD contraction keeps its accumulators on
/// the stack for; larger (never seen in practice — the paper tops out
/// far below) falls back to [`f2sh_contract_scalar`].
const F2SH_MAX_NL: usize = 64;

/// Row-major f2sh contraction shared by the Gaunt, eSCN, and many-body
/// pipelines: project a centered `(2N+1)^2` product grid onto real SH
/// coefficients of degree <= `l_out` (requires `l_out <= n_grid`).
///
/// SIMD layout: s-outer / u-middle / l-inner with per-(l,s) stack
/// accumulators, two panel entries per `F64x4` lane vector against a
/// pair-splatted `sp` / `sm`.  For every output the per-u addition
/// sequence performs the exact IEEE operations of
/// [`f2sh_contract_scalar`] in the same order (negation commutes with
/// rounding), so the two agree BIT-FOR-BIT — asserted by the tests.
/// `out` must hold `(l_out+1)^2` values; the call is allocation-free.
pub fn f2sh_contract(t3t: &F2shPanelsT, grid: &[C64], out: &mut [f64]) {
    let l_out = t3t.l_out;
    let nl = l_out + 1;
    if nl > F2SH_MAX_NL {
        f2sh_contract_scalar(t3t, grid, out);
        return;
    }
    let n = t3t.n_grid;
    let nu = 2 * n + 1;
    debug_assert_eq!(grid.len(), nu * nu);
    debug_assert_eq!(out.len(), nl * nl);
    debug_assert!(l_out <= n);
    out.fill(0.0);
    // interleaved [re, im] accumulator per l; re carries the +m channel
    // partial sums, im (of accm) the -m channel's
    let mut accp = [0.0f64; 2 * F2SH_MAX_NL];
    let mut accm = [0.0f64; 2 * F2SH_MAX_NL];
    for s in 0..=l_out {
        accp[..2 * nl].fill(0.0);
        accm[..2 * nl].fill(0.0);
        let panel = &t3t.panels[s];
        for u in 0..nu {
            let grow = &grid[u * nu..(u + 1) * nu];
            let ts = as_floats(&panel[u * nl..(u + 1) * nl]);
            let (sp, sm) = if s == 0 {
                // the v = 0 column; sm is unused (its lanes are still
                // computed but never extracted)
                (grow[n], C64::default())
            } else {
                let gp = grow[n + s];
                let gm = grow[n - s];
                (gp + gm, gp - gm)
            };
            let spv = F64x4::load(&[sp.re, sp.im, sp.re, sp.im]);
            let smv = F64x4::load(&[sm.re, sm.im, sm.re, sm.im]);
            let mut l = s;
            while l + 1 <= l_out {
                let tv = F64x4::load(&ts[2 * l..]);
                let pa = F64x4::load(&accp[2 * l..]);
                (pa + tv.complex_mul(spv)).store(&mut accp[2 * l..]);
                let ma = F64x4::load(&accm[2 * l..]);
                (ma + tv.complex_mul(smv)).store(&mut accm[2 * l..]);
                l += 2;
            }
            if l <= l_out {
                // odd tail: only the extracted lanes need computing
                let (tr, ti) = (ts[2 * l], ts[2 * l + 1]);
                accp[2 * l] += tr * sp.re - ti * sp.im;
                accm[2 * l + 1] += tr * sm.im + ti * sm.re;
            }
        }
        for l in s..=l_out {
            if s == 0 {
                out[crate::lm_index(l, 0)] = accp[2 * l];
            } else {
                out[crate::lm_index(l, s as i64)] = accp[2 * l];
                out[crate::lm_index(l, -(s as i64))] = -accm[2 * l + 1];
            }
        }
    }
    f2sh_normalize(l_out, out);
}

/// The pre-SIMD u-outer traversal, kept verbatim as the conformance
/// oracle and the "before" side of the SIMD benches.
pub fn f2sh_contract_scalar(t3t: &F2shPanelsT, grid: &[C64], out: &mut [f64]) {
    let n = t3t.n_grid;
    let l_out = t3t.l_out;
    let nu = 2 * n + 1;
    let nl = l_out + 1;
    debug_assert_eq!(grid.len(), nu * nu);
    debug_assert_eq!(out.len(), nl * nl);
    debug_assert!(l_out <= n);
    out.fill(0.0);
    for u in 0..nu {
        let grow = &grid[u * nu..(u + 1) * nu];
        // s = 0: the v = 0 column
        let g = grow[n];
        let t0 = &t3t.panels[0][u * nl..(u + 1) * nl];
        for (l, tv) in t0.iter().enumerate() {
            out[crate::lm_index(l, 0)] += tv.re * g.re - tv.im * g.im;
        }
        for s in 1..=l_out {
            let gp = grow[n + s];
            let gm = grow[n - s];
            let sp = gp + gm;
            let sm = gp - gm;
            let ts = &t3t.panels[s][u * nl..(u + 1) * nl];
            for l in s..=l_out {
                let tv = ts[l];
                out[crate::lm_index(l, s as i64)] +=
                    tv.re * sp.re - tv.im * sp.im;
                out[crate::lm_index(l, -(s as i64))] -=
                    tv.im * sm.re + tv.re * sm.im;
            }
        }
    }
    f2sh_normalize(l_out, out);
}

/// normalization: m = 0 channels get 2 pi, |m| > 0 get sqrt(2) pi
fn f2sh_normalize(l_out: usize, out: &mut [f64]) {
    let two_pi = 2.0 * std::f64::consts::PI;
    let s2pi = std::f64::consts::SQRT_2 * std::f64::consts::PI;
    for l in 0..=l_out {
        for m in -(l as i64)..=(l as i64) {
            out[crate::lm_index(l, m)] *= if m == 0 { two_pi } else { s2pi };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::quadrature::gauss_legendre;

    #[test]
    fn theta_fourier_reconstructs() {
        for (l, m) in [(0usize, 0usize), (2, 0), (3, 1), (4, 3), (5, 5)] {
            let c = theta_fourier(l, m);
            for k in 0..17 {
                let theta = 0.05 + (std::f64::consts::PI - 0.1) * k as f64 / 16.0;
                let mut rec = C64::default();
                for u in -(l as i64)..=(l as i64) {
                    rec += c[(l as i64 + u) as usize] * C64::cis(u as f64 * theta);
                }
                let want = assoc_legendre(l, m, theta.cos()) * sh_norm(l, m as i64);
                assert!((rec.re - want).abs() < 1e-11, "l={l} m={m}");
                assert!(rec.im.abs() < 1e-11);
            }
        }
    }

    #[test]
    fn theta_fourier_parity() {
        // even m: real, even in u; odd m: imaginary, odd in u
        let c = theta_fourier(4, 2);
        for (k, v) in c.iter().enumerate() {
            assert!(v.im.abs() < 1e-12);
            assert!((v.re - c[c.len() - 1 - k].re).abs() < 1e-12);
        }
        let c = theta_fourier(5, 3);
        for (k, v) in c.iter().enumerate() {
            assert!(v.re.abs() < 1e-12);
            assert!((v.im + c[c.len() - 1 - k].im).abs() < 1e-12);
        }
    }

    #[test]
    fn theta_projection_vs_quadrature() {
        let (xs, ws) = gauss_legendre(64);
        for (l, m) in [(0usize, 0usize), (2, 1), (3, 3), (5, 2)] {
            let n_grid = l + 2;
            let t = theta_projection(l, m, n_grid);
            for u in -(n_grid as i64)..=(n_grid as i64) {
                // quadrature over [0, pi]
                let mut acc = C64::default();
                for (x, w) in xs.iter().zip(&ws) {
                    let th = (x + 1.0) * std::f64::consts::FRAC_PI_2;
                    let f = assoc_legendre(l, m, th.cos())
                        * sh_norm(l, m as i64)
                        * th.sin();
                    acc += C64::cis(u as f64 * th)
                        .scale(f * w * std::f64::consts::FRAC_PI_2);
                }
                let got = t[(n_grid as i64 + u) as usize];
                assert!((got - acc).abs() < 1e-9, "l={l} m={m} u={u}");
            }
        }
    }

    #[test]
    fn f2sh_contract_matches_column_major_reference() {
        // reference: the original (l, u)-major traversal with per-term
        // normalization, as GauntPlan::f2sh shipped it
        use crate::util::rng::Rng;
        let (l_out, n) = (3usize, 4usize);
        let nu = 2 * n + 1;
        let mut rng = Rng::new(0);
        let grid: Vec<C64> =
            (0..nu * nu).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let t3 = f2sh_panels(l_out, n);
        let pi = std::f64::consts::PI;
        let s2pi = std::f64::consts::SQRT_2 * pi;
        let mut want = vec![0.0; (l_out + 1) * (l_out + 1)];
        for s in 0..=l_out {
            let t = &t3.panels[s];
            for l in s..=l_out {
                let trow = &t[l * nu..(l + 1) * nu];
                if s == 0 {
                    let mut acc = 0.0;
                    for u in 0..nu {
                        let g = grid[u * nu + n];
                        acc += trow[u].re * g.re - trow[u].im * g.im;
                    }
                    want[crate::lm_index(l, 0)] = 2.0 * pi * acc;
                } else {
                    let (mut accp, mut accm) = (0.0, 0.0);
                    for u in 0..nu {
                        let gp = grid[u * nu + n + s];
                        let gm = grid[u * nu + n - s];
                        let sp = gp + gm;
                        let sm = gp - gm;
                        accp += trow[u].re * sp.re - trow[u].im * sp.im;
                        accm += -(trow[u].im * sm.re + trow[u].re * sm.im);
                    }
                    want[crate::lm_index(l, s as i64)] = s2pi * accp;
                    want[crate::lm_index(l, -(s as i64))] = s2pi * accm;
                }
            }
        }
        let t3t = F2shPanelsT::from_panels(&t3);
        let mut got = vec![0.0; (l_out + 1) * (l_out + 1)];
        f2sh_contract(&t3t, &grid, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn f2sh_contract_simd_bit_matches_scalar_oracle() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        for (l_out, n) in [(0usize, 0usize), (1, 2), (2, 4), (3, 4), (5, 8)] {
            let nu = 2 * n + 1;
            let grid: Vec<C64> = (0..nu * nu)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect();
            let t3t = F2shPanelsT::build(l_out, n);
            let nc = (l_out + 1) * (l_out + 1);
            let mut got = vec![0.0; nc];
            let mut want = vec![0.0; nc];
            f2sh_contract(&t3t, &grid, &mut got);
            f2sh_contract_scalar(&t3t, &grid, &mut want);
            for (k, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "l_out={l_out} n={n} idx={k}: {a:e} vs {b:e}"
                );
            }
        }
    }

    #[test]
    fn panels_zero_below_s() {
        let p = sh2f_panels(3);
        let nl = 4;
        for s in 0..4usize {
            for l in 0..s {
                for u in 0..7 {
                    assert_eq!(p.panels[s][u * nl + l], C64::default());
                }
            }
        }
    }
}
