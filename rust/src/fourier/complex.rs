//! Minimal complex arithmetic (num-complex is unavailable offline).

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Complex number with f64 parts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
pub const I: C64 = C64 { re: 0.0, im: 1.0 };

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> Self {
        C64 { re, im: 0.0 }
    }

    /// e^{i theta}
    #[inline]
    pub fn cis(theta: f64) -> Self {
        C64 { re: theta.cos(), im: theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        C64 { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    #[inline]
    pub fn scale(self, k: f64) -> Self {
        C64 { re: self.re * k, im: self.im * k }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_axioms() {
        let a = C64::new(1.5, -2.0);
        let b = C64::new(-0.5, 3.0);
        assert_eq!(a + b, b + a);
        assert_eq!(a * b, b * a);
        let d = (a * b) - C64::new(1.5 * -0.5 - (-2.0) * 3.0, 1.5 * 3.0 + -2.0 * -0.5);
        assert!(d.abs() < 1e-15);
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..10 {
            let c = C64::cis(0.7 * k as f64);
            assert!((c.abs() - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn conj_mul_is_norm() {
        let a = C64::new(3.0, 4.0);
        let n = a * a.conj();
        assert!((n.re - 25.0).abs() < 1e-12 && n.im.abs() < 1e-12);
    }
}
