//! Minimal complex arithmetic (num-complex is unavailable offline).

use std::ops::{Add, AddAssign, Mul, Neg, Sub};

/// Complex number with f64 parts.
///
/// `repr(C)` is load-bearing: the SIMD kernels view `&[C64]` as the
/// interleaved float slice `[re0, im0, re1, im1, ...]` via
/// [`as_floats`] / [`as_floats_mut`], which needs the field order and
/// packing guaranteed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct C64 {
    pub re: f64,
    pub im: f64,
}

/// View a complex slice as its interleaved `[re, im, re, im, ...]`
/// floats (twice the length).
#[inline(always)]
pub fn as_floats(z: &[C64]) -> &[f64] {
    // SAFETY: C64 is repr(C) { re: f64, im: f64 } — size 16, align 8,
    // no padding — so N complex values are exactly 2N contiguous f64s.
    unsafe { std::slice::from_raw_parts(z.as_ptr() as *const f64, z.len() * 2) }
}

/// Mutable interleaved-float view of a complex slice.
#[inline(always)]
pub fn as_floats_mut(z: &mut [C64]) -> &mut [f64] {
    // SAFETY: as for `as_floats`; the borrow rules carry over unchanged.
    unsafe {
        std::slice::from_raw_parts_mut(z.as_mut_ptr() as *mut f64, z.len() * 2)
    }
}

pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };
pub const I: C64 = C64 { re: 0.0, im: 1.0 };

impl C64 {
    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> Self {
        C64 { re, im: 0.0 }
    }

    /// e^{i theta}
    #[inline]
    pub fn cis(theta: f64) -> Self {
        C64 { re: theta.cos(), im: theta.sin() }
    }

    #[inline]
    pub fn conj(self) -> Self {
        C64 { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    #[inline]
    pub fn scale(self, k: f64) -> Self {
        C64 { re: self.re * k, im: self.im * k }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline]
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for C64 {
    #[inline]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline]
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline]
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_axioms() {
        let a = C64::new(1.5, -2.0);
        let b = C64::new(-0.5, 3.0);
        assert_eq!(a + b, b + a);
        assert_eq!(a * b, b * a);
        let d = (a * b) - C64::new(1.5 * -0.5 - (-2.0) * 3.0, 1.5 * 3.0 + -2.0 * -0.5);
        assert!(d.abs() < 1e-15);
    }

    #[test]
    fn cis_is_unit() {
        for k in 0..10 {
            let c = C64::cis(0.7 * k as f64);
            assert!((c.abs() - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn conj_mul_is_norm() {
        let a = C64::new(3.0, 4.0);
        let n = a * a.conj();
        assert!((n.re - 25.0).abs() < 1e-12 && n.im.abs() < 1e-12);
    }

    #[test]
    fn float_view_is_interleaved_re_im() {
        let mut z = vec![C64::new(1.0, 2.0), C64::new(3.0, 4.0)];
        assert_eq!(as_floats(&z), &[1.0, 2.0, 3.0, 4.0]);
        as_floats_mut(&mut z)[3] = -4.0;
        assert_eq!(z[1], C64::new(3.0, -4.0));
        assert_eq!(std::mem::size_of::<C64>(), 16);
        assert_eq!(std::mem::align_of::<C64>(), 8);
    }
}
