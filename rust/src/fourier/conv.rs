//! Full 2D convolution of complex coefficient grids: direct (small L) and
//! FFT-based (the paper's O(L^2 log L) path).
//!
//! The allocating [`conv2d_fft`] here is the LEGACY FFT path (five fresh
//! vectors and three full complex 2D transforms per call) — kept as the
//! "before" row of the benches and as a cross-check oracle.  Hot paths
//! use the planned, allocation-free [`super::plan::ConvPlan`] instead.

use super::complex::C64;
use super::fft::fft2;
use super::plan::ConvPlan;

/// Direct full convolution of an n1 x n1 grid with an n2 x n2 grid
/// (row-major), producing (n1+n2-1)^2.
pub fn conv2d_direct(a: &[C64], n1: usize, b: &[C64], n2: usize) -> Vec<C64> {
    let n = n1 + n2 - 1;
    let mut out = vec![C64::default(); n * n];
    conv2d_direct_into(a, n1, b, n2, &mut out);
    out
}

/// [`conv2d_direct`] into a caller-provided output buffer (overwritten);
/// allocation-free.
pub fn conv2d_direct_into(
    a: &[C64], n1: usize, b: &[C64], n2: usize, out: &mut [C64],
) {
    debug_assert_eq!(a.len(), n1 * n1);
    debug_assert_eq!(b.len(), n2 * n2);
    let n = n1 + n2 - 1;
    debug_assert_eq!(out.len(), n * n);
    out.fill(C64::default());
    for i in 0..n1 {
        for j in 0..n1 {
            let av = a[i * n1 + j];
            if av.norm_sqr() == 0.0 {
                continue;
            }
            for k in 0..n2 {
                let orow = &mut out[(i + k) * n..];
                let brow = &b[k * n2..(k + 1) * n2];
                for (l, bv) in brow.iter().enumerate() {
                    orow[j + l] += av * *bv;
                }
            }
        }
    }
}

/// One-shot planned convolution (generic complex grids): identical output
/// to [`conv2d_fft`] through the [`ConvPlan`] tables.  Builds a plan and
/// scratch per call — for repeated shapes hold a `ConvPlan` and reuse its
/// scratch instead.
pub fn conv2d_fft_planned(
    a: &[C64], n1: usize, b: &[C64], n2: usize,
) -> Vec<C64> {
    let plan = ConvPlan::new(n1, n2);
    let mut scratch = plan.scratch();
    let mut out = vec![C64::default(); plan.n_out * plan.n_out];
    plan.conv_into(a, b, &mut out, &mut scratch);
    out
}

/// FFT-based full convolution; identical output to [`conv2d_direct`].
pub fn conv2d_fft(a: &[C64], n1: usize, b: &[C64], n2: usize) -> Vec<C64> {
    let n = n1 + n2 - 1;
    // zero-pad to n x n (fft2 handles arbitrary sizes via Bluestein; pad to
    // next power of two rows/cols for speed)
    let m = n.next_power_of_two();
    let mut pa = vec![C64::default(); m * m];
    let mut pb = vec![C64::default(); m * m];
    for i in 0..n1 {
        pa[i * m..i * m + n1].copy_from_slice(&a[i * n1..(i + 1) * n1]);
    }
    for i in 0..n2 {
        pb[i * m..i * m + n2].copy_from_slice(&b[i * n2..(i + 1) * n2]);
    }
    let fa = fft2(&pa, m, m, false);
    let fb = fft2(&pb, m, m, false);
    let prod: Vec<C64> = fa.iter().zip(&fb).map(|(x, y)| *x * *y).collect();
    let full = fft2(&prod, m, m, true);
    let mut out = vec![C64::default(); n * n];
    for i in 0..n {
        out[i * n..(i + 1) * n].copy_from_slice(&full[i * m..i * m + n]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_grid(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n * n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn direct_matches_brute_force() {
        let mut rng = Rng::new(0);
        let a = rand_grid(&mut rng, 3);
        let b = rand_grid(&mut rng, 5);
        let out = conv2d_direct(&a, 3, &b, 5);
        let n = 7;
        for p in 0..n {
            for q in 0..n {
                let mut acc = C64::default();
                for i in 0..3 {
                    for j in 0..3 {
                        let (k, l) = (p as i64 - i as i64, q as i64 - j as i64);
                        if (0..5).contains(&k) && (0..5).contains(&l) {
                            acc += a[i * 3 + j] * b[(k * 5 + l) as usize];
                        }
                    }
                }
                assert!((out[p * n + q] - acc).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn fft_matches_direct() {
        let mut rng = Rng::new(1);
        for (n1, n2) in [(3usize, 3usize), (5, 7), (9, 9), (1, 5)] {
            let a = rand_grid(&mut rng, n1);
            let b = rand_grid(&mut rng, n2);
            let d = conv2d_direct(&a, n1, &b, n2);
            let f = conv2d_fft(&a, n1, &b, n2);
            for (x, y) in d.iter().zip(&f) {
                assert!((*x - *y).abs() < 1e-9, "n1={n1} n2={n2}");
            }
        }
    }

    #[test]
    fn planned_matches_legacy_fft() {
        let mut rng = Rng::new(7);
        for (n1, n2) in [(3usize, 3usize), (5, 7), (9, 9), (1, 5), (2, 4)] {
            let a = rand_grid(&mut rng, n1);
            let b = rand_grid(&mut rng, n2);
            let legacy = conv2d_fft(&a, n1, &b, n2);
            let planned = conv2d_fft_planned(&a, n1, &b, n2);
            for (x, y) in legacy.iter().zip(&planned) {
                assert!((*x - *y).abs() < 1e-9, "n1={n1} n2={n2}");
            }
        }
    }

    #[test]
    fn delta_is_identity() {
        let mut rng = Rng::new(2);
        let mut d = vec![C64::default(); 9];
        d[4] = C64::real(1.0); // center of 3x3
        let b = rand_grid(&mut rng, 5);
        let out = conv2d_direct(&d, 3, &b, 5);
        for i in 0..5 {
            for j in 0..5 {
                assert!((out[(i + 1) * 7 + (j + 1)] - b[i * 5 + j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn commutative() {
        let mut rng = Rng::new(3);
        let a = rand_grid(&mut rng, 5);
        let b = rand_grid(&mut rng, 7);
        let ab = conv2d_direct(&a, 5, &b, 7);
        let ba = conv2d_direct(&b, 7, &a, 5);
        for (x, y) in ab.iter().zip(&ba) {
            assert!((*x - *y).abs() < 1e-10);
        }
    }

    #[test]
    fn associative() {
        let mut rng = Rng::new(4);
        let a = rand_grid(&mut rng, 3);
        let b = rand_grid(&mut rng, 3);
        let c = rand_grid(&mut rng, 3);
        let ab_c = conv2d_direct(&conv2d_direct(&a, 3, &b, 3), 5, &c, 3);
        let a_bc = conv2d_direct(&a, 3, &conv2d_direct(&b, 3, &c, 3), 5);
        for (x, y) in ab_c.iter().zip(&a_bc) {
            assert!((*x - *y).abs() < 1e-9);
        }
    }
}
