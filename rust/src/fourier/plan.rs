//! Planned, allocation-free 2D convolution workspaces.
//!
//! [`ConvPlan`] precomputes everything a repeated full 2D convolution of
//! fixed-size coefficient grids needs — the padded power-of-two transform
//! size, the shared [`FftPlan`] tables for it, and the centered→cyclic
//! wrap maps — so the per-pair hot path touches only caller-owned scratch
//! ([`ConvScratch`]) and performs zero allocations.
//!
//! Two apply paths:
//!
//! * [`ConvPlan::conv_into`] — generic complex grids: two planned forward
//!   2D FFTs, pointwise product, planned inverse (the legacy
//!   `conv2d_fft` pipeline minus its five per-call allocations and
//!   per-stage twiddle recomputation).
//! * [`ConvPlan::conv_hermitian_into`] — grids with the conjugate
//!   symmetry `g(-u,-v) = conj(g(u,v))` of 2D Fourier coefficients of
//!   REAL functions (every grid the Gaunt pipeline produces from real SH
//!   coefficients, and every convolution of such grids).  Embedding the
//!   centered grid into Z_m x Z_m by wrapping negative frequencies makes
//!   its unscaled inverse DFT a REAL sample array, so
//!     - ONE packed inverse FFT `INV2[G1 + i G2]` transforms BOTH
//!       operands (`f1 = Re z`, `f2 = Im z`),
//!     - the spectral product is a real x real pointwise multiply,
//!     - the forward transform back is a real-input FFT with two-for-one
//!       packed rows ([`FftPlan::fwd2_real_into`]).
//!   Per pair that is ~2.5 m row/column transforms instead of the legacy
//!   path's 6 m, with no phase factors (the wrap embedding absorbs the
//!   centering shift exactly).
//!
//! Derivation of the Hermitian path (1D, per axis; 2D is the tensor
//! product).  With `FWD[x](t) = sum_j x_j e^{-2 pi i j t / m}` and
//! `INV = conj-FWD` (both unscaled), for wrapped Hermitian `G`:
//! `f = INV[G]` is real, `f(j) = FWD[G](-j)`.  So
//! `q := f1 f2 = FWD[G1 (*) G2](-j) = INV[h](j)` for the cyclic
//! convolution `h = G1 (*) G2`, hence `FWD[q] = m h` (m^2 in 2D).  With
//! `m >= n1 + n2 - 1` the cyclic convolution equals the linear one.

use std::sync::Arc;

use super::complex::{as_floats, as_floats_mut, C64};
use super::fft::{FftPlan, COL_BLOCK};
use crate::util::simd::{F64x4, SimdLanes};

/// Caller-owned scratch buffers for [`ConvPlan`] applies.  One per worker
/// thread; every buffer is sized at construction and never reallocated.
pub struct ConvScratch {
    /// packed complex workspace (m x m)
    pub z: Vec<C64>,
    /// spectrum workspace (m x m)
    pub h: Vec<C64>,
    /// real sample product (m x m)
    pub q: Vec<f64>,
    /// column tile buffer (m * COL_BLOCK) for the transpose-blocked
    /// column passes; anything >= m works, bigger just means more
    /// columns per cache-friendly tile
    pub col: Vec<C64>,
}

impl ConvScratch {
    fn new(m: usize) -> ConvScratch {
        ConvScratch {
            z: vec![C64::default(); m * m],
            h: vec![C64::default(); m * m],
            q: vec![0.0; m * m],
            col: vec![C64::default(); m * COL_BLOCK],
        }
    }

    /// Zero-sized scratch for consumers that may never take an FFT path
    /// (grow it with [`ConvScratch::ensure`] before first use).
    pub fn empty() -> ConvScratch {
        ConvScratch {
            z: Vec::new(),
            h: Vec::new(),
            q: Vec::new(),
            col: Vec::new(),
        }
    }

    /// Grow the buffers to transform size `m` if they are not already
    /// there (no-op afterwards, so steady state stays allocation-free).
    pub fn ensure(&mut self, m: usize) {
        if self.z.len() != m * m {
            self.z.resize(m * m, C64::default());
            self.h.resize(m * m, C64::default());
            self.q.resize(m * m, 0.0);
            self.col.resize(m * COL_BLOCK, C64::default());
        }
    }
}

/// Precomputed workspace for full 2D convolutions of an `n1 x n1` grid
/// with an `n2 x n2` grid (both row-major), producing `n_out x n_out`
/// with `n_out = n1 + n2 - 1`.  Read-only after construction; share via
/// `Arc` and give each worker its own [`ConvScratch`].
pub struct ConvPlan {
    pub n1: usize,
    pub n2: usize,
    pub n_out: usize,
    /// padded transform size (power of two >= n_out)
    pub m: usize,
    pub(crate) fft: Arc<FftPlan>,
    /// centered->cyclic row/col index maps: operand entries at centered
    /// frequency u (index i, u = i - (n-1)/2) land at u mod m.  Only
    /// valid for odd sizes (centered grids); even sizes fall back to the
    /// offset embedding in the generic path.
    pub(crate) wrap1: Vec<usize>,
    pub(crate) wrap2: Vec<usize>,
    pub(crate) wrap_out: Vec<usize>,
}

/// Centered->cyclic index map: entry i (centered frequency i - (n-1)/2)
/// lands at index `(i - (n-1)/2) mod m`.  The single source of the wrap
/// convention every Hermitian-path consumer shares.
pub(crate) fn wrap_map(n: usize, m: usize) -> Vec<usize> {
    let c = (n - 1) / 2;
    (0..n).map(|i| (i + m - c) % m).collect()
}

impl ConvPlan {
    pub fn new(n1: usize, n2: usize) -> ConvPlan {
        assert!(n1 >= 1 && n2 >= 1);
        let n_out = n1 + n2 - 1;
        let m = n_out.next_power_of_two();
        ConvPlan {
            n1,
            n2,
            n_out,
            m,
            fft: FftPlan::shared(m),
            wrap1: wrap_map(n1, m),
            wrap2: wrap_map(n2, m),
            wrap_out: wrap_map(n_out, m),
        }
    }

    /// Plan for a chained pointwise-product pipeline (many-body): each
    /// operand is `n1 x n1`, the chain's final product grid is
    /// `n_out x n_out` (>= n1).  The equivalent pairwise shape would be
    /// n2 = n_out - n1 + 1; the wrap maps and transform size cover the
    /// whole chain.
    pub fn for_chain(n1: usize, n_out: usize) -> ConvPlan {
        assert!(n1 >= 1 && n_out >= n1);
        let n2 = n_out - n1 + 1;
        let m = n_out.next_power_of_two();
        ConvPlan {
            n1,
            n2,
            n_out,
            m,
            fft: FftPlan::shared(m),
            wrap1: wrap_map(n1, m),
            wrap2: wrap_map(n2, m),
            wrap_out: wrap_map(n_out, m),
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).
    pub fn scratch(&self) -> ConvScratch {
        ConvScratch::new(self.m)
    }

    /// Generic planned full convolution of complex grids; identical
    /// output to [`super::conv::conv2d_direct`] up to rounding.
    /// Allocation-free: all workspace lives in `scratch`.
    pub fn conv_into(
        &self, a: &[C64], b: &[C64], out: &mut [C64],
        scratch: &mut ConvScratch,
    ) {
        let (n1, n2, n, m) = (self.n1, self.n2, self.n_out, self.m);
        debug_assert_eq!(a.len(), n1 * n1);
        debug_assert_eq!(b.len(), n2 * n2);
        debug_assert_eq!(out.len(), n * n);
        if m == 1 {
            out[0] = a[0] * b[0];
            return;
        }
        // offset (top-left) embedding: no centering assumption needed
        let z = &mut scratch.z;
        let h = &mut scratch.h;
        z.fill(C64::default());
        h.fill(C64::default());
        for i in 0..n1 {
            z[i * m..i * m + n1].copy_from_slice(&a[i * n1..(i + 1) * n1]);
        }
        for i in 0..n2 {
            h[i * m..i * m + n2].copy_from_slice(&b[i * n2..(i + 1) * n2]);
        }
        self.fft.fft2_inplace(z, false, &mut scratch.col);
        self.fft.fft2_inplace(h, false, &mut scratch.col);
        // pointwise complex product, two complexes per lane vector; the
        // lane formula is the same op sequence as `C64::mul`, so this is
        // bit-identical to the scalar loop.  m >= 2 is a power of two,
        // so 2*m*m floats split into whole vectors with no tail.
        {
            let zf = as_floats_mut(z);
            let hf = as_floats(h);
            let mut p = 0;
            while p < zf.len() {
                let zv = F64x4::load(&zf[p..]);
                let hv = F64x4::load(&hf[p..]);
                zv.complex_mul(hv).store(&mut zf[p..]);
                p += 4;
            }
        }
        self.fft.fft2_inplace(z, true, &mut scratch.col);
        let s = 1.0 / (m * m) as f64;
        for i in 0..n {
            for j in 0..n {
                out[i * n + j] = z[i * m + j].scale(s);
            }
        }
    }

    /// Hermitian fast path: both operands must be centered odd-size grids
    /// with (approximate) conjugate symmetry `g(-u,-v) = conj(g(u,v))`
    /// — 2D Fourier coefficients of real functions.  Output is their
    /// full linear convolution, identical to the generic path up to
    /// rounding of the (physically zero) anti-Hermitian component.
    /// Allocation-free.
    pub fn conv_hermitian_into(
        &self, a: &[C64], b: &[C64], out: &mut [C64],
        scratch: &mut ConvScratch,
    ) {
        let (n1, n2, n, m) = (self.n1, self.n2, self.n_out, self.m);
        debug_assert_eq!(a.len(), n1 * n1);
        debug_assert_eq!(b.len(), n2 * n2);
        debug_assert_eq!(out.len(), n * n);
        debug_assert!(n1 % 2 == 1 && n2 % 2 == 1,
                      "hermitian path needs centered odd-size grids");
        if m == 1 {
            out[0] = a[0] * b[0];
            return;
        }
        // z = wrap(a) + i wrap(b); the wrap maps send centered frequency
        // (u, v) to (u mod m, v mod m), so INV2[wrap(g)] is the real
        // sample array of g's function — no phase factors.
        let z = &mut scratch.z;
        z.fill(C64::default());
        for i in 0..n1 {
            let r = self.wrap1[i] * m;
            for j in 0..n1 {
                z[r + self.wrap1[j]] = a[i * n1 + j];
            }
        }
        for i in 0..n2 {
            let r = self.wrap2[i] * m;
            for j in 0..n2 {
                let g = b[i * n2 + j];
                // += i * g  (operand cells can coincide with a's)
                let cell = &mut z[r + self.wrap2[j]];
                cell.re -= g.im;
                cell.im += g.re;
            }
        }
        self.fft.fft2_inplace(z, true, &mut scratch.col);
        // f1 = Re z, f2 = Im z (both real by Hermitian symmetry): the
        // real x real spectral product q = Re z * Im z, de-interleaving
        // four complexes per step (m >= 2 power of two, so no tail).
        {
            let zf = as_floats(z);
            let q = &mut scratch.q;
            let mut p = 0;
            while p < q.len() {
                let a = F64x4::load(&zf[2 * p..]);
                let b = F64x4::load(&zf[2 * p + 4..]);
                let (re, im) = F64x4::unzip(a, b);
                (re * im).store(&mut q[p..]);
                p += 4;
            }
        }
        self.fft.fwd2_real_into(&scratch.q, &mut scratch.h, &mut scratch.col);
        let s = 1.0 / (m * m) as f64;
        for i in 0..n {
            let r = self.wrap_out[i] * m;
            for j in 0..n {
                out[i * n + j] = scratch.h[r + self.wrap_out[j]].scale(s);
            }
        }
    }

    /// Unscaled real sample array `f = INV2[wrap(g)]` of one centered
    /// Hermitian grid (the reusable half of the pair trick): the caller
    /// can cache `f` for a fixed operand and combine it against many
    /// partners, or chain pointwise products of several sample arrays and
    /// transform back once (many-body).  Writes `f` into `q` (m x m);
    /// uses `z`/`col` as workspace.  Allocates only the wrap map for
    /// `ng`; use [`ConvPlan::samples_op1_into`] for the allocation-free
    /// plan-operand case.
    pub fn samples_into(
        &self, g: &[C64], ng: usize, q: &mut [f64], scratch: &mut ConvScratch,
    ) {
        debug_assert!(ng % 2 == 1 && ng <= self.m);
        let wrap = wrap_map(ng, self.m);
        self.samples_with_map(g, ng, &wrap, q, scratch);
    }

    /// [`ConvPlan::samples_into`] for a grid of exactly the plan's first
    /// operand size `n1`, using the precomputed wrap map: allocation-free.
    pub fn samples_op1_into(
        &self, g: &[C64], q: &mut [f64], scratch: &mut ConvScratch,
    ) {
        self.samples_with_map(g, self.n1, &self.wrap1, q, scratch);
    }

    fn samples_with_map(
        &self, g: &[C64], ng: usize, wrap: &[usize], q: &mut [f64],
        scratch: &mut ConvScratch,
    ) {
        let m = self.m;
        debug_assert_eq!(g.len(), ng * ng);
        debug_assert_eq!(q.len(), m * m);
        debug_assert_eq!(wrap.len(), ng);
        let z = &mut scratch.z;
        z.fill(C64::default());
        for i in 0..ng {
            let r = wrap[i] * m;
            for j in 0..ng {
                z[r + wrap[j]] = g[i * ng + j];
            }
        }
        self.fft.fft2_inplace(z, true, &mut scratch.col);
        for (qv, zv) in q.iter_mut().zip(z.iter()) {
            *qv = zv.re;
        }
    }

    /// Joint sample arrays of an operand pair through ONE packed inverse
    /// FFT: `z = wrap1(a) + i wrap2(b)`, so `qa = Re INV2[z]` and
    /// `qb = Im INV2[z]` are the real sample arrays of `a` and `b`
    /// (both Hermitian by assumption).  Halves the forward-transform
    /// count of pipelines that need both sample arrays separately —
    /// e.g. the vector plans, which accumulate several pointwise
    /// products before one shared [`ConvPlan::grid_from_samples_into`].
    /// `a` must be `n1 x n1` and `b` `n2 x n2`.  Allocation-free.
    pub fn samples_pair_into(
        &self, a: &[C64], b: &[C64], qa: &mut [f64], qb: &mut [f64],
        scratch: &mut ConvScratch,
    ) {
        let (n1, n2, m) = (self.n1, self.n2, self.m);
        debug_assert_eq!(a.len(), n1 * n1);
        debug_assert_eq!(b.len(), n2 * n2);
        debug_assert_eq!(qa.len(), m * m);
        debug_assert_eq!(qb.len(), m * m);
        debug_assert!(n1 % 2 == 1 && n2 % 2 == 1,
                      "hermitian path needs centered odd-size grids");
        if m == 1 {
            qa[0] = a[0].re;
            qb[0] = b[0].re;
            return;
        }
        let z = &mut scratch.z;
        z.fill(C64::default());
        for i in 0..n1 {
            let r = self.wrap1[i] * m;
            for j in 0..n1 {
                z[r + self.wrap1[j]] = a[i * n1 + j];
            }
        }
        for i in 0..n2 {
            let r = self.wrap2[i] * m;
            for j in 0..n2 {
                let g = b[i * n2 + j];
                let cell = &mut z[r + self.wrap2[j]];
                cell.re -= g.im;
                cell.im += g.re;
            }
        }
        self.fft.fft2_inplace(z, true, &mut scratch.col);
        for (p, zv) in z.iter().enumerate() {
            qa[p] = zv.re;
            qb[p] = zv.im;
        }
    }

    /// Transform a real sample-product array back to the centered output
    /// grid: `out = wrap^{-1}[FWD2[q] / m^2]`.  The counterpart of
    /// [`ConvPlan::samples_into`] for cached-spectrum / chained-product
    /// pipelines.  Allocation-free.
    pub fn grid_from_samples_into(
        &self, q: &[f64], out: &mut [C64], scratch: &mut ConvScratch,
    ) {
        let (n, m) = (self.n_out, self.m);
        debug_assert_eq!(q.len(), m * m);
        debug_assert_eq!(out.len(), n * n);
        if m == 1 {
            out[0] = C64::real(q[0]);
            return;
        }
        self.fft.fwd2_real_into(q, &mut scratch.h, &mut scratch.col);
        let s = 1.0 / (m * m) as f64;
        for i in 0..n {
            let r = self.wrap_out[i] * m;
            for j in 0..n {
                out[i * n + j] = scratch.h[r + self.wrap_out[j]].scale(s);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::conv::conv2d_direct;
    use crate::util::rng::Rng;

    fn rand_grid(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n * n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    /// Random centered grid with exact conjugate symmetry
    /// g(-u,-v) = conj(g(u,v)).
    fn rand_hermitian_grid(rng: &mut Rng, n: usize) -> Vec<C64> {
        let mut g = rand_grid(rng, n);
        let last = n - 1;
        for i in 0..n {
            for j in 0..n {
                let (mi, mj) = (last - i, last - j);
                if (i, j) < (mi, mj) {
                    g[mi * n + mj] = g[i * n + j].conj();
                } else if (i, j) == (mi, mj) {
                    g[i * n + j] = C64::real(g[i * n + j].re);
                }
            }
        }
        g
    }

    fn max_diff(a: &[C64], b: &[C64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x - *y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn generic_planned_matches_direct() {
        let mut rng = Rng::new(0);
        for (n1, n2) in [(1usize, 1usize), (1, 5), (3, 3), (4, 6), (5, 7), (9, 9)] {
            let a = rand_grid(&mut rng, n1);
            let b = rand_grid(&mut rng, n2);
            let plan = ConvPlan::new(n1, n2);
            let mut scratch = plan.scratch();
            let mut out = vec![C64::default(); plan.n_out * plan.n_out];
            plan.conv_into(&a, &b, &mut out, &mut scratch);
            let want = conv2d_direct(&a, n1, &b, n2);
            assert!(max_diff(&out, &want) < 1e-9, "n1={n1} n2={n2}");
        }
    }

    #[test]
    fn hermitian_matches_direct_on_symmetric_grids() {
        let mut rng = Rng::new(1);
        for (n1, n2) in [(1usize, 1usize), (1, 5), (3, 3), (3, 7), (5, 5), (7, 9)] {
            let a = rand_hermitian_grid(&mut rng, n1);
            let b = rand_hermitian_grid(&mut rng, n2);
            let plan = ConvPlan::new(n1, n2);
            let mut scratch = plan.scratch();
            let mut out = vec![C64::default(); plan.n_out * plan.n_out];
            plan.conv_hermitian_into(&a, &b, &mut out, &mut scratch);
            let want = conv2d_direct(&a, n1, &b, n2);
            assert!(
                max_diff(&out, &want) < 1e-9,
                "n1={n1} n2={n2}: {}",
                max_diff(&out, &want)
            );
        }
    }

    #[test]
    fn hermitian_output_is_hermitian() {
        let mut rng = Rng::new(2);
        let plan = ConvPlan::new(5, 5);
        let a = rand_hermitian_grid(&mut rng, 5);
        let b = rand_hermitian_grid(&mut rng, 5);
        let mut scratch = plan.scratch();
        let n = plan.n_out;
        let mut out = vec![C64::default(); n * n];
        plan.conv_hermitian_into(&a, &b, &mut out, &mut scratch);
        for i in 0..n {
            for j in 0..n {
                let m = out[(n - 1 - i) * n + (n - 1 - j)].conj();
                assert!((out[i * n + j] - m).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn samples_round_trip_through_pointwise_product() {
        // samples_into + pointwise product + grid_from_samples_into must
        // equal the one-shot hermitian convolution
        let mut rng = Rng::new(3);
        let (n1, n2) = (5usize, 3usize);
        let a = rand_hermitian_grid(&mut rng, n1);
        let b = rand_hermitian_grid(&mut rng, n2);
        let plan = ConvPlan::new(n1, n2);
        let mut scratch = plan.scratch();
        let m = plan.m;
        let mut fa = vec![0.0; m * m];
        let mut fb = vec![0.0; m * m];
        plan.samples_into(&a, n1, &mut fa, &mut scratch);
        plan.samples_into(&b, n2, &mut fb, &mut scratch);
        for (x, y) in fa.iter_mut().zip(&fb) {
            *x *= *y;
        }
        let n = plan.n_out;
        let mut got = vec![C64::default(); n * n];
        plan.grid_from_samples_into(&fa, &mut got, &mut scratch);
        let want = conv2d_direct(&a, n1, &b, n2);
        assert!(max_diff(&got, &want) < 1e-9, "{}", max_diff(&got, &want));
    }

    #[test]
    fn samples_pair_matches_single_sampling() {
        let mut rng = Rng::new(5);
        for (n1, n2) in [(1usize, 1usize), (3, 3), (5, 3), (5, 7)] {
            let a = rand_hermitian_grid(&mut rng, n1);
            let b = rand_hermitian_grid(&mut rng, n2);
            let plan = ConvPlan::new(n1, n2);
            let mut scratch = plan.scratch();
            let m = plan.m;
            let (mut fa, mut fb) = (vec![0.0; m * m], vec![0.0; m * m]);
            plan.samples_into(&a, n1, &mut fa, &mut scratch);
            plan.samples_into(&b, n2, &mut fb, &mut scratch);
            let (mut qa, mut qb) = (vec![0.0; m * m], vec![0.0; m * m]);
            plan.samples_pair_into(&a, &b, &mut qa, &mut qb, &mut scratch);
            let d = fa
                .iter()
                .zip(&qa)
                .chain(fb.iter().zip(&qb))
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(d < 1e-9, "n1={n1} n2={n2}: {d}");
        }
    }

    #[test]
    fn scratch_reuse_is_deterministic() {
        let mut rng = Rng::new(4);
        let plan = ConvPlan::new(5, 5);
        let a = rand_hermitian_grid(&mut rng, 5);
        let b = rand_hermitian_grid(&mut rng, 5);
        let mut scratch = plan.scratch();
        let n = plan.n_out;
        let mut out1 = vec![C64::default(); n * n];
        let mut out2 = vec![C64::default(); n * n];
        plan.conv_hermitian_into(&a, &b, &mut out1, &mut scratch);
        plan.conv_hermitian_into(&a, &b, &mut out2, &mut scratch);
        assert_eq!(max_diff(&out1, &out2), 0.0);
    }
}
