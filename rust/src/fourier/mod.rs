//! 2D Fourier substrate: complex arithmetic, FFTs, the SH <-> Fourier
//! conversion tables, and grid convolutions (paper Section 3.2).

pub mod complex;
pub mod conv;
pub mod fft;
pub mod tables;

pub use complex::C64;
pub use conv::{conv2d_direct, conv2d_fft};
pub use fft::{fft, fft2, ifft};
pub use tables::{f2sh_panels, sh2f_panels, theta_fourier, theta_projection};
