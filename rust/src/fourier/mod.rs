//! 2D Fourier substrate: complex arithmetic, FFTs, the SH <-> Fourier
//! conversion tables, grid convolutions (paper Section 3.2), and the
//! planned allocation-free workspace layer ([`plan`]) the hot paths run
//! on (DESIGN.md §4.1).

pub mod complex;
pub mod conv;
pub mod fft;
pub mod fp32;
pub mod plan;
pub mod tables;

pub use complex::{as_floats, as_floats_mut, C64};
pub use conv::{conv2d_direct, conv2d_fft, conv2d_fft_planned};
pub use fft::{fft, fft2, ifft, FftPlan, COL_BLOCK};
pub use fp32::{Conv32Plan, Conv32Scratch, Fft32Plan, C32};
pub use plan::{ConvPlan, ConvScratch};
pub use tables::{
    f2sh_contract, f2sh_contract_scalar, f2sh_panels, sh2f_panels,
    theta_fourier, theta_projection, F2shPanelsT,
};
