//! f32 mirror of the Fourier hot path for the opt-in serving-precision
//! mode (train f64, serve f32).
//!
//! This is a dedicated single-precision pipeline, NOT a genericization:
//! [`C32`], [`Fft32Plan`], [`Conv32Plan`] and the direct convolution
//! transliterate their f64 counterparts with f32 interiors.  All TABLES
//! (twiddles, and the Gaunt panels in `tp::gaunt32`) are built in f64
//! and rounded once, so the only f32 error is per-operation rounding in
//! the apply path — the op-conformance suite pins the resulting
//! tolerance tier (~1e-4 relative against the f64 plans at bench sizes).
//!
//! The butterflies and pointwise products ride the same
//! [`crate::util::simd`] lane types as the f64 path, at twice the lane
//! width ([`F32x8`]): serving in f32 halves both memory traffic and the
//! SIMD op count per value, which is the whole point of the mode.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use super::plan::wrap_map;
use crate::util::simd::{F32x8, SimdLanes};

/// Complex number with f32 parts (`repr(C)` for the interleaved float
/// view, exactly like [`super::complex::C64`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct C32 {
    pub re: f32,
    pub im: f32,
}

impl C32 {
    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        C32 { re, im }
    }

    #[inline]
    pub fn real(re: f32) -> Self {
        C32 { re, im: 0.0 }
    }

    /// Round an f64 complex value once.
    #[inline]
    pub fn from_c64(z: super::complex::C64) -> Self {
        C32 { re: z.re as f32, im: z.im as f32 }
    }

    #[inline]
    pub fn conj(self) -> Self {
        C32 { re: self.re, im: -self.im }
    }

    #[inline]
    pub fn norm_sqr(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn scale(self, k: f32) -> Self {
        C32 { re: self.re * k, im: self.im * k }
    }
}

impl std::ops::Add for C32 {
    type Output = C32;
    #[inline]
    fn add(self, o: C32) -> C32 {
        C32 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl std::ops::AddAssign for C32 {
    #[inline]
    fn add_assign(&mut self, o: C32) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl std::ops::Sub for C32 {
    type Output = C32;
    #[inline]
    fn sub(self, o: C32) -> C32 {
        C32 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl std::ops::Mul for C32 {
    type Output = C32;
    #[inline]
    fn mul(self, o: C32) -> C32 {
        C32 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

/// Interleaved `[re, im, ...]` view of a complex f32 slice.
#[inline(always)]
pub fn as_floats32(z: &[C32]) -> &[f32] {
    // SAFETY: C32 is repr(C) { re: f32, im: f32 } — size 8, align 4, no
    // padding.
    unsafe { std::slice::from_raw_parts(z.as_ptr() as *const f32, z.len() * 2) }
}

/// Mutable interleaved-float view of a complex f32 slice.
#[inline(always)]
pub fn as_floats32_mut(z: &mut [C32]) -> &mut [f32] {
    // SAFETY: as for `as_floats32`.
    unsafe {
        std::slice::from_raw_parts_mut(z.as_mut_ptr() as *mut f32, z.len() * 2)
    }
}

/// f32 radix-2 FFT plan: bit-reversal + twiddle tables for one
/// power-of-two size.  Twiddles are f64 `cis` evaluations rounded once.
pub struct Fft32Plan {
    n: usize,
    bitrev: Vec<u32>,
    tw: Vec<C32>,
}

impl Fft32Plan {
    pub fn new(n: usize) -> Fft32Plan {
        assert!(n.is_power_of_two(), "Fft32Plan: n={n} is not a power of two");
        let mut bitrev = vec![0u32; n];
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            bitrev[i] = j as u32;
        }
        let tw: Vec<C32> = (0..n / 2)
            .map(|k| {
                C32::from_c64(super::complex::C64::cis(
                    -2.0 * std::f64::consts::PI * k as f64 / n as f64,
                ))
            })
            .collect();
        Fft32Plan { n, bitrev, tw }
    }

    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Process-wide shared plan for size `n` (separate cache from the
    /// f64 plans).
    pub fn shared(n: usize) -> Arc<Fft32Plan> {
        assert!(
            n.is_power_of_two(),
            "Fft32Plan::shared: n={n} is not a power of two"
        );
        static CACHE: OnceLock<RwLock<HashMap<usize, Arc<Fft32Plan>>>> =
            OnceLock::new();
        let cache = CACHE.get_or_init(|| RwLock::new(HashMap::new()));
        if let Some(p) = cache.read().unwrap().get(&n) {
            return p.clone();
        }
        let p = Arc::new(Fft32Plan::new(n));
        let mut w = cache.write().unwrap();
        w.entry(n).or_insert(p).clone()
    }

    /// In-place unscaled DFT (forward) or conjugate DFT (inverse);
    /// allocation-free.  Stages with `half >= 4` run four butterflies
    /// per [`F32x8`] lane vector; shorter stages stay scalar.
    pub fn process(&self, buf: &mut [C32], inverse: bool) {
        let n = self.n;
        debug_assert_eq!(buf.len(), n, "Fft32Plan::process: wrong buffer size");
        if n <= 1 {
            return;
        }
        for i in 1..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let stride = n / len;
            let half = len / 2;
            if half < 4 {
                let mut i = 0;
                while i < n {
                    for k in 0..half {
                        let w = if inverse {
                            self.tw[k * stride].conj()
                        } else {
                            self.tw[k * stride]
                        };
                        let u = buf[i + k];
                        let v = buf[i + k + half] * w;
                        buf[i + k] = u + v;
                        buf[i + k + half] = u - v;
                    }
                    i += len;
                }
            } else {
                let bf = as_floats32_mut(buf);
                let mut i = 0;
                while i < n {
                    let mut k = 0;
                    while k < half {
                        let mut wlanes = [0.0f32; 8];
                        for (t, pair) in wlanes.chunks_exact_mut(2).enumerate()
                        {
                            let w = self.tw[(k + t) * stride];
                            pair[0] = w.re;
                            pair[1] = if inverse { -w.im } else { w.im };
                        }
                        let wv = F32x8::load(&wlanes);
                        let pa = 2 * (i + k);
                        let pb = 2 * (i + k + half);
                        let a = F32x8::load(&bf[pa..]);
                        let b = F32x8::load(&bf[pb..]);
                        let t = wv.complex_mul(b);
                        (a + t).store(&mut bf[pa..]);
                        (a - t).store(&mut bf[pb..]);
                        k += 4;
                    }
                    i += len;
                }
            }
            len <<= 1;
        }
    }

    /// Transpose-blocked column transforms (mirror of the f64
    /// `FftPlan::col_pass`); any `col_buf.len() >= n` works.
    fn col_pass(&self, grid: &mut [C32], inverse: bool, col_buf: &mut [C32]) {
        let n = self.n;
        debug_assert!(col_buf.len() >= n);
        let block = (col_buf.len() / n).clamp(1, n);
        let mut c0 = 0;
        while c0 < n {
            let b = block.min(n - c0);
            for r in 0..n {
                for t in 0..b {
                    col_buf[t * n + r] = grid[r * n + c0 + t];
                }
            }
            for t in 0..b {
                self.process(&mut col_buf[t * n..(t + 1) * n], inverse);
            }
            for r in 0..n {
                for t in 0..b {
                    grid[r * n + c0 + t] = col_buf[t * n + r];
                }
            }
            c0 += b;
        }
    }

    /// In-place unscaled 2D transform of a square `n x n` grid.
    pub fn fft2_inplace(
        &self, grid: &mut [C32], inverse: bool, col_buf: &mut [C32],
    ) {
        let n = self.n;
        debug_assert_eq!(grid.len(), n * n);
        for r in 0..n {
            self.process(&mut grid[r * n..(r + 1) * n], inverse);
        }
        self.col_pass(grid, inverse, col_buf);
    }

    /// Unscaled forward 2D DFT of a REAL square grid with two-for-one
    /// packed rows (mirror of `FftPlan::fwd2_real_into`).
    pub fn fwd2_real_into(
        &self, q: &[f32], out: &mut [C32], col_buf: &mut [C32],
    ) {
        let n = self.n;
        debug_assert_eq!(q.len(), n * n);
        debug_assert_eq!(out.len(), n * n);
        debug_assert!(col_buf.len() >= n);
        if n == 1 {
            out[0] = C32::real(q[0]);
            return;
        }
        for a in 0..n / 2 {
            let r0 = 2 * a;
            let r1 = 2 * a + 1;
            let row_buf = &mut col_buf[..n];
            for t in 0..n {
                row_buf[t] = C32::new(q[r0 * n + t], q[r1 * n + t]);
            }
            self.process(row_buf, false);
            for t in 0..n {
                let tm = if t == 0 { 0 } else { n - t };
                let y = row_buf[t];
                let ym = row_buf[tm].conj();
                let s = y + ym;
                let d = y - ym;
                out[r0 * n + t] = s.scale(0.5);
                // (-i/2) * d
                out[r1 * n + t] = C32::new(0.5 * d.im, -0.5 * d.re);
            }
        }
        self.col_pass(out, false, col_buf);
    }
}

/// Caller-owned scratch for [`Conv32Plan`] applies.
pub struct Conv32Scratch {
    pub z: Vec<C32>,
    pub h: Vec<C32>,
    pub q: Vec<f32>,
    pub col: Vec<C32>,
}

impl Conv32Scratch {
    fn new(m: usize) -> Conv32Scratch {
        Conv32Scratch {
            z: vec![C32::default(); m * m],
            h: vec![C32::default(); m * m],
            q: vec![0.0; m * m],
            col: vec![C32::default(); m * super::fft::COL_BLOCK],
        }
    }

    /// Zero-sized scratch for consumers that may never take an FFT path.
    pub fn empty() -> Conv32Scratch {
        Conv32Scratch {
            z: Vec::new(),
            h: Vec::new(),
            q: Vec::new(),
            col: Vec::new(),
        }
    }
}

/// f32 mirror of [`super::plan::ConvPlan`], restricted to the Hermitian
/// fast path (the only one the Gaunt serving pipeline uses).
pub struct Conv32Plan {
    pub n1: usize,
    pub n2: usize,
    pub n_out: usize,
    pub m: usize,
    fft: Arc<Fft32Plan>,
    wrap1: Vec<usize>,
    wrap2: Vec<usize>,
    wrap_out: Vec<usize>,
}

impl Conv32Plan {
    pub fn new(n1: usize, n2: usize) -> Conv32Plan {
        assert!(n1 >= 1 && n2 >= 1);
        let n_out = n1 + n2 - 1;
        let m = n_out.next_power_of_two();
        Conv32Plan {
            n1,
            n2,
            n_out,
            m,
            fft: Fft32Plan::shared(m),
            wrap1: wrap_map(n1, m),
            wrap2: wrap_map(n2, m),
            wrap_out: wrap_map(n_out, m),
        }
    }

    /// Fresh scratch sized for this plan.
    pub fn scratch(&self) -> Conv32Scratch {
        Conv32Scratch::new(self.m)
    }

    /// Hermitian fast path, mirroring `ConvPlan::conv_hermitian_into`:
    /// one packed inverse FFT for both operands, a real x real SIMD
    /// pointwise product, one real-input forward.  Allocation-free.
    pub fn conv_hermitian_into(
        &self, a: &[C32], b: &[C32], out: &mut [C32],
        scratch: &mut Conv32Scratch,
    ) {
        let (n1, n2, n, m) = (self.n1, self.n2, self.n_out, self.m);
        debug_assert_eq!(a.len(), n1 * n1);
        debug_assert_eq!(b.len(), n2 * n2);
        debug_assert_eq!(out.len(), n * n);
        debug_assert!(n1 % 2 == 1 && n2 % 2 == 1,
                      "hermitian path needs centered odd-size grids");
        if m == 1 {
            out[0] = a[0] * b[0];
            return;
        }
        let z = &mut scratch.z;
        z.fill(C32::default());
        for i in 0..n1 {
            let r = self.wrap1[i] * m;
            for j in 0..n1 {
                z[r + self.wrap1[j]] = a[i * n1 + j];
            }
        }
        for i in 0..n2 {
            let r = self.wrap2[i] * m;
            for j in 0..n2 {
                let g = b[i * n2 + j];
                let cell = &mut z[r + self.wrap2[j]];
                cell.re -= g.im;
                cell.im += g.re;
            }
        }
        self.fft.fft2_inplace(z, true, &mut scratch.col);
        // q = Re z * Im z, eight floats (four complexes) per step;
        // m >= 2 is a power of two so 2*m*m splits into whole vectors
        {
            let zf = as_floats32(z);
            let q = &mut scratch.q;
            let mut p = 0;
            while p < q.len() {
                let a = F32x8::load(&zf[2 * p..]);
                let b = F32x8::load(&zf[2 * p + 8..]);
                let (re, im) = F32x8::unzip(a, b);
                (re * im).store(&mut q[p..]);
                p += 8;
            }
        }
        self.fft.fwd2_real_into(&scratch.q, &mut scratch.h, &mut scratch.col);
        let s = 1.0 / (m * m) as f32;
        for i in 0..n {
            let r = self.wrap_out[i] * m;
            for j in 0..n {
                out[i * n + j] = scratch.h[r + self.wrap_out[j]].scale(s);
            }
        }
    }
}

/// f32 direct full convolution into a caller buffer (mirror of
/// [`super::conv::conv2d_direct_into`]).
pub fn conv2d_direct32_into(
    a: &[C32], n1: usize, b: &[C32], n2: usize, out: &mut [C32],
) {
    debug_assert_eq!(a.len(), n1 * n1);
    debug_assert_eq!(b.len(), n2 * n2);
    let n = n1 + n2 - 1;
    debug_assert_eq!(out.len(), n * n);
    out.fill(C32::default());
    for i in 0..n1 {
        for j in 0..n1 {
            let av = a[i * n1 + j];
            if av.norm_sqr() == 0.0 {
                continue;
            }
            for k in 0..n2 {
                let orow = &mut out[(i + k) * n..];
                let brow = &b[k * n2..(k + 1) * n2];
                for (l, bv) in brow.iter().enumerate() {
                    orow[j + l] += av * *bv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::complex::C64;
    use crate::fourier::conv::conv2d_direct;
    use crate::util::rng::Rng;

    fn rand_hermitian64(rng: &mut Rng, n: usize) -> Vec<C64> {
        let mut g: Vec<C64> =
            (0..n * n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let last = n - 1;
        for i in 0..n {
            for j in 0..n {
                let (mi, mj) = (last - i, last - j);
                if (i, j) < (mi, mj) {
                    g[mi * n + mj] = g[i * n + j].conj();
                } else if (i, j) == (mi, mj) {
                    g[i * n + j] = C64::real(g[i * n + j].re);
                }
            }
        }
        g
    }

    fn cast32(g: &[C64]) -> Vec<C32> {
        g.iter().map(|z| C32::from_c64(*z)).collect()
    }

    #[test]
    fn fft32_matches_f64_plan_within_f32_tolerance() {
        use crate::fourier::fft::FftPlan;
        let mut rng = Rng::new(30);
        for n in [1usize, 2, 4, 8, 16, 64] {
            let x64: Vec<C64> =
                (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect();
            let p64 = FftPlan::new(n);
            let p32 = Fft32Plan::new(n);
            for inverse in [false, true] {
                let mut want = x64.clone();
                p64.process(&mut want, inverse);
                let mut got = cast32(&x64);
                p32.process(&mut got, inverse);
                // unscaled DFT values grow like n; tolerance scales with
                // the transform length
                let tol = 1e-5 * n as f32;
                for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g.re - w.re as f32).abs() < tol
                            && (g.im - w.im as f32).abs() < tol,
                        "n={n} inverse={inverse} idx={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn fft32_round_trip() {
        let mut rng = Rng::new(31);
        let n = 32usize;
        let plan = Fft32Plan::new(n);
        let x: Vec<C32> = (0..n)
            .map(|_| C32::new(rng.normal() as f32, rng.normal() as f32))
            .collect();
        let mut y = x.clone();
        plan.process(&mut y, false);
        plan.process(&mut y, true);
        let s = 1.0 / n as f32;
        for (a, b) in x.iter().zip(&y) {
            let r = b.scale(s);
            assert!((a.re - r.re).abs() < 1e-4 && (a.im - r.im).abs() < 1e-4);
        }
    }

    #[test]
    fn conv32_hermitian_matches_f64_direct() {
        let mut rng = Rng::new(32);
        for (n1, n2) in [(1usize, 1usize), (3, 3), (3, 7), (5, 5), (7, 9)] {
            let a64 = rand_hermitian64(&mut rng, n1);
            let b64 = rand_hermitian64(&mut rng, n2);
            let want = conv2d_direct(&a64, n1, &b64, n2);
            let plan = Conv32Plan::new(n1, n2);
            let mut scratch = plan.scratch();
            let n = plan.n_out;
            let mut out = vec![C32::default(); n * n];
            plan.conv_hermitian_into(
                &cast32(&a64), &cast32(&b64), &mut out, &mut scratch,
            );
            let scale: f32 = want
                .iter()
                .map(|z| z.abs() as f32)
                .fold(1.0f32, f32::max);
            for (k, (g, w)) in out.iter().zip(&want).enumerate() {
                assert!(
                    (g.re - w.re as f32).abs() < 2e-4 * scale
                        && (g.im - w.im as f32).abs() < 2e-4 * scale,
                    "n1={n1} n2={n2} idx={k}: {g:?} vs {w:?}"
                );
            }
        }
    }

    #[test]
    fn direct32_matches_f64_direct() {
        let mut rng = Rng::new(33);
        let (n1, n2) = (3usize, 5usize);
        let a64: Vec<C64> = (0..n1 * n1)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let b64: Vec<C64> = (0..n2 * n2)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let want = conv2d_direct(&a64, n1, &b64, n2);
        let n = n1 + n2 - 1;
        let mut out = vec![C32::default(); n * n];
        conv2d_direct32_into(&cast32(&a64), n1, &cast32(&b64), n2, &mut out);
        for (g, w) in out.iter().zip(&want) {
            assert!(
                (g.re - w.re as f32).abs() < 1e-4
                    && (g.im - w.im as f32).abs() < 1e-4
            );
        }
    }

    #[test]
    fn shared32_is_memoized() {
        let a = Fft32Plan::shared(16);
        let b = Fft32Plan::shared(16);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 16);
    }
}
