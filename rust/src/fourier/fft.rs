//! Complex FFT from scratch: iterative radix-2 Cooley-Tukey for powers of
//! two, Bluestein's algorithm for arbitrary lengths, and 2D transforms.
//!
//! The hot paths go through [`FftPlan`]: per-size precomputed twiddle and
//! bit-reversal tables (every twiddle is a direct `cis` evaluation — no
//! incremental `w = w * wl` accumulation, whose rounding drift grows with
//! the butterfly length), in-place 1D/2D transforms over caller-provided
//! scratch, and a two-for-one real-input 2D forward transform.  Plans are
//! read-only after construction and shared process-wide via
//! [`FftPlan::shared`], so concurrent workers reuse one table set.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use super::complex::{as_floats_mut, C64};
use crate::util::simd::{F64x4, SimdLanes};

/// Preferred number of columns per transpose-blocked column pass.
/// Scratch sized `n * COL_BLOCK` lets [`FftPlan::fft2_inplace`] /
/// [`FftPlan::fwd2_real_into`] transform columns in cache-friendly
/// contiguous tiles instead of one strided gather per column; any
/// scratch length >= `n` still works (block count degrades gracefully).
pub const COL_BLOCK: usize = 8;

/// Precomputed radix-2 FFT tables for one power-of-two size.
///
/// Read-only after construction (safe to share across threads via `Arc`);
/// all transforms are in place over caller-owned buffers and perform no
/// allocation.  Forward is the unscaled DFT; `inverse` applies the
/// conjugate transform, also WITHOUT the 1/n scaling (callers fold the
/// scale into their own extraction step).
pub struct FftPlan {
    n: usize,
    /// bit-reversal permutation: `bitrev[i]` is `i` with log2(n) bits
    /// reversed.
    bitrev: Vec<u32>,
    /// Forward twiddles `tw[k] = e^{-2 pi i k / n}` for `k < n/2`, each
    /// computed directly by `cis` (exact table, no incremental drift).
    /// The stage with butterfly length `len` uses `tw[k * (n / len)]`.
    tw: Vec<C64>,
}

impl FftPlan {
    /// Build the tables for size `n` (must be a power of two, n >= 1).
    pub fn new(n: usize) -> FftPlan {
        assert!(n.is_power_of_two(), "FftPlan: n={n} is not a power of two");
        let mut bitrev = vec![0u32; n];
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            bitrev[i] = j as u32;
        }
        let tw: Vec<C64> = (0..n / 2)
            .map(|k| {
                C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64)
            })
            .collect();
        FftPlan { n, bitrev, tw }
    }

    /// Transform size (always >= 1; n = 1 is the valid trivial plan, so
    /// there is deliberately no `is_empty`).
    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        self.n
    }

    /// Process-wide shared plan for size `n` — built once per size, then
    /// served as an `Arc` clone from a read lock.
    pub fn shared(n: usize) -> Arc<FftPlan> {
        // validate BEFORE touching the lock, and construct OUTSIDE it: a
        // panic while holding the write lock would poison the cache and
        // take down every FFT in the process, not just the bad caller.
        assert!(
            n.is_power_of_two(),
            "FftPlan::shared: n={n} is not a power of two"
        );
        static CACHE: OnceLock<RwLock<HashMap<usize, Arc<FftPlan>>>> =
            OnceLock::new();
        let cache = CACHE.get_or_init(|| RwLock::new(HashMap::new()));
        if let Some(p) = cache.read().unwrap().get(&n) {
            return p.clone();
        }
        let p = Arc::new(FftPlan::new(n));
        let mut w = cache.write().unwrap();
        // two threads may race past the read miss and both build; the
        // tables are identical and cheap, so first insert wins
        w.entry(n).or_insert(p).clone()
    }

    /// In-place unscaled DFT (forward) or conjugate DFT (inverse) of
    /// `buf` (`buf.len()` must equal the plan size).  Allocation-free.
    ///
    /// The butterflies run two complex values per `F64x4` lane vector
    /// (the k-loop batched across lanes).  Because the lane formula is
    /// the same mul/sub/add sequence as `C64::mul` with no FMA, the
    /// result is BIT-IDENTICAL to [`process_scalar`] — the conformance
    /// tests assert exact equality, so goldens are unaffected.
    pub fn process(&self, buf: &mut [C64], inverse: bool) {
        let n = self.n;
        debug_assert_eq!(buf.len(), n, "FftPlan::process: wrong buffer size");
        if n <= 1 {
            return;
        }
        for i in 1..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        // len == 2 stage: w = 1, plain add/sub — nothing to vectorize.
        let mut i = 0;
        while i < n {
            let u = buf[i];
            let v = buf[i + 1];
            buf[i] = u + v;
            buf[i + 1] = u - v;
            i += 2;
        }
        if n < 4 {
            return;
        }
        // len >= 4 stages: half >= 2, so each lane vector holds the
        // twiddles (w_k, w_{k+1}) as interleaved [re, im, re, im] and
        // multiplies two adjacent butterflies at once.  half is a power
        // of two — the k-loop has no scalar tail.
        let bf = as_floats_mut(buf);
        let mut len = 4;
        while len <= n {
            let stride = n / len;
            let half = len / 2;
            let mut i = 0;
            while i < n {
                let mut k = 0;
                while k < half {
                    let w0 = self.tw[k * stride];
                    let w1 = self.tw[(k + 1) * stride];
                    let (im0, im1) = if inverse {
                        (-w0.im, -w1.im)
                    } else {
                        (w0.im, w1.im)
                    };
                    let wv = F64x4::load(&[w0.re, im0, w1.re, im1]);
                    let pa = 2 * (i + k);
                    let pb = 2 * (i + k + half);
                    let a = F64x4::load(&bf[pa..]);
                    let b = F64x4::load(&bf[pb..]);
                    let t = wv.complex_mul(b);
                    (a + t).store(&mut bf[pa..]);
                    (a - t).store(&mut bf[pb..]);
                    k += 2;
                }
                i += len;
            }
            len <<= 1;
        }
    }

    /// The pre-SIMD butterfly loop, kept verbatim as the conformance
    /// oracle and the "before" side of the SIMD benches.
    pub fn process_scalar(&self, buf: &mut [C64], inverse: bool) {
        let n = self.n;
        debug_assert_eq!(buf.len(), n, "FftPlan::process: wrong buffer size");
        if n <= 1 {
            return;
        }
        for i in 1..n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut len = 2;
        while len <= n {
            let stride = n / len;
            let half = len / 2;
            let mut i = 0;
            while i < n {
                for k in 0..half {
                    let w = if inverse {
                        self.tw[k * stride].conj()
                    } else {
                        self.tw[k * stride]
                    };
                    let u = buf[i + k];
                    let v = buf[i + k + half] * w;
                    buf[i + k] = u + v;
                    buf[i + k + half] = u - v;
                }
                i += len;
            }
            len <<= 1;
        }
    }

    /// Unscaled forward DFT in place.
    pub fn forward(&self, buf: &mut [C64]) {
        self.process(buf, false);
    }

    /// Unscaled conjugate (inverse without 1/n) DFT in place.
    pub fn inverse(&self, buf: &mut [C64]) {
        self.process(buf, true);
    }

    /// Transpose-blocked column transforms: gather a block of up to
    /// `col_buf.len() / n` columns into contiguous length-`n` tiles of
    /// `col_buf` (reading each grid row once, sequentially, instead of
    /// one strided walk per column), transform the tiles in place, and
    /// scatter back.  Any `col_buf.len() >= n` works; a length-`n`
    /// scratch degenerates to the old one-column-at-a-time behavior.
    fn col_pass(&self, grid: &mut [C64], inverse: bool, col_buf: &mut [C64]) {
        let n = self.n;
        debug_assert!(col_buf.len() >= n, "col scratch shorter than n");
        let block = (col_buf.len() / n).clamp(1, n);
        let mut c0 = 0;
        while c0 < n {
            let b = block.min(n - c0);
            for r in 0..n {
                for t in 0..b {
                    col_buf[t * n + r] = grid[r * n + c0 + t];
                }
            }
            for t in 0..b {
                self.process(&mut col_buf[t * n..(t + 1) * n], inverse);
            }
            for r in 0..n {
                for t in 0..b {
                    grid[r * n + c0 + t] = col_buf[t * n + r];
                }
            }
            c0 += b;
        }
    }

    /// In-place 2D transform of a square row-major `n x n` grid using this
    /// plan for both axes.  UNSCALED in both directions (unlike the
    /// allocating [`fft2`], which folds 1/(rows*cols) into the inverse) —
    /// callers fold the scale into extraction.  `col_buf` is caller
    /// scratch of length >= `n` (ideally `n * COL_BLOCK`, enabling the
    /// transpose-blocked column pass); the call is allocation-free.
    pub fn fft2_inplace(
        &self, grid: &mut [C64], inverse: bool, col_buf: &mut [C64],
    ) {
        let n = self.n;
        debug_assert_eq!(grid.len(), n * n);
        for r in 0..n {
            self.process(&mut grid[r * n..(r + 1) * n], inverse);
        }
        self.col_pass(grid, inverse, col_buf);
    }

    /// Unscaled forward 2D DFT of a REAL square `n x n` grid into the
    /// complex grid `out`, exploiting realness: row transforms are done
    /// two-for-one (rows 2a and 2a+1 packed as the real/imaginary parts of
    /// one complex row, separated afterwards by Hermitian symmetry), which
    /// halves the row-transform work.  `col_buf` is caller scratch of
    /// length >= `n` (ideally `n * COL_BLOCK` for the blocked column
    /// pass); the call is allocation-free.
    pub fn fwd2_real_into(
        &self, q: &[f64], out: &mut [C64], col_buf: &mut [C64],
    ) {
        let n = self.n;
        debug_assert_eq!(q.len(), n * n);
        debug_assert_eq!(out.len(), n * n);
        debug_assert!(col_buf.len() >= n);
        if n == 1 {
            out[0] = C64::real(q[0]);
            return;
        }
        // row pairs: y = row_{2a} + i row_{2a+1}; after Y = FWD[y],
        //   FWD[row_{2a}](t)   = (Y(t) + conj(Y(-t))) / 2
        //   FWD[row_{2a+1}](t) = (Y(t) - conj(Y(-t))) / (2i)
        for a in 0..n / 2 {
            let r0 = 2 * a;
            let r1 = 2 * a + 1;
            let row_buf = &mut col_buf[..n];
            for t in 0..n {
                row_buf[t] = C64::new(q[r0 * n + t], q[r1 * n + t]);
            }
            self.process(row_buf, false);
            for t in 0..n {
                let tm = if t == 0 { 0 } else { n - t };
                let y = row_buf[t];
                let ym = row_buf[tm].conj();
                let s = y + ym;
                let d = y - ym;
                out[r0 * n + t] = s.scale(0.5);
                // (-i/2) * d
                out[r1 * n + t] = C64::new(0.5 * d.im, -0.5 * d.re);
            }
        }
        // column transforms on the now-complex rows
        self.col_pass(out, false, col_buf);
    }
}

/// In-place radix-2 DIT FFT; `n` must be a power of two.
/// `inverse` applies the conjugate transform WITHOUT the 1/n scaling.
///
/// Delegates to the process-wide [`FftPlan::shared`] tables, so every
/// caller (Bluestein, table construction, legacy `fft2`) gets the
/// drift-free precomputed twiddles.
pub fn fft_pow2(buf: &mut [C64], inverse: bool) {
    let n = buf.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    FftPlan::shared(n).process(buf, inverse);
}

/// DFT of arbitrary length via Bluestein (chirp-z), O(n log n).
pub fn dft(input: &[C64], inverse: bool) -> Vec<C64> {
    let n = input.len();
    if n == 0 {
        return Vec::new();
    }
    if n.is_power_of_two() {
        let mut buf = input.to_vec();
        fft_pow2(&mut buf, inverse);
        return buf;
    }
    // Bluestein: x_k w^{k^2/2} convolved with chirp
    let sign = if inverse { 1.0 } else { -1.0 };
    let m = (2 * n - 1).next_power_of_two();
    let mut a = vec![C64::default(); m];
    let mut b = vec![C64::default(); m];
    let mut chirp = vec![C64::default(); n];
    for k in 0..n {
        // k^2 mod 2n to keep angles accurate
        let kk = (k * k) % (2 * n);
        let ang = sign * std::f64::consts::PI * kk as f64 / n as f64;
        chirp[k] = C64::cis(ang);
        a[k] = input[k] * chirp[k];
        b[k] = chirp[k].conj();
        if k > 0 {
            b[m - k] = chirp[k].conj();
        }
    }
    fft_pow2(&mut a, false);
    fft_pow2(&mut b, false);
    for i in 0..m {
        a[i] = a[i] * b[i];
    }
    fft_pow2(&mut a, true);
    let scale = 1.0 / m as f64;
    (0..n).map(|k| (a[k].scale(scale)) * chirp[k]).collect()
}

/// Forward DFT (no scaling).
pub fn fft(input: &[C64]) -> Vec<C64> {
    dft(input, false)
}

/// Inverse DFT with the 1/n scaling.
pub fn ifft(input: &[C64]) -> Vec<C64> {
    let n = input.len();
    let mut out = dft(input, true);
    let s = 1.0 / n as f64;
    for v in out.iter_mut() {
        *v = v.scale(s);
    }
    out
}

/// 2D FFT of a row-major rows x cols grid (in place semantics via return).
pub fn fft2(grid: &[C64], rows: usize, cols: usize, inverse: bool) -> Vec<C64> {
    debug_assert_eq!(grid.len(), rows * cols);
    let mut tmp: Vec<C64> = Vec::with_capacity(rows * cols);
    // rows
    for r in 0..rows {
        tmp.extend(dft(&grid[r * cols..(r + 1) * cols], inverse));
    }
    // cols
    let mut out = vec![C64::default(); rows * cols];
    let mut col_buf = vec![C64::default(); rows];
    for c in 0..cols {
        for r in 0..rows {
            col_buf[r] = tmp[r * cols + c];
        }
        let f = dft(&col_buf, inverse);
        for r in 0..rows {
            out[r * cols + c] = f[r];
        }
    }
    if inverse {
        let s = 1.0 / (rows * cols) as f64;
        for v in out.iter_mut() {
            *v = v.scale(s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dft(x: &[C64]) -> Vec<C64> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = C64::default();
                for (j, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * j) as f64
                        / n as f64;
                    acc += *v * C64::cis(ang);
                }
                acc
            })
            .collect()
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn pow2_matches_naive() {
        let mut rng = Rng::new(0);
        for n in [1usize, 2, 4, 8, 16, 64] {
            let x = rand_vec(&mut rng, n);
            let got = fft(&x);
            let want = naive_dft(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g - *w).abs() < 1e-9 * n as f64);
            }
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [3usize, 5, 7, 9, 11, 13, 17, 33] {
            let x = rand_vec(&mut rng, n);
            let got = fft(&x);
            let want = naive_dft(&x);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((*g - *w).abs() < 1e-8, "n={n} idx={i}");
            }
        }
    }

    #[test]
    fn round_trip() {
        let mut rng = Rng::new(2);
        for n in [4usize, 7, 16, 21] {
            let x = rand_vec(&mut rng, n);
            let y = ifft(&fft(&x));
            for (a, b) in x.iter().zip(&y) {
                assert!((*a - *b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Rng::new(3);
        let x = rand_vec(&mut rng, 32);
        let f = fft(&x);
        let e_time: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let e_freq: f64 = f.iter().map(|v| v.norm_sqr()).sum::<f64>() / 32.0;
        assert!((e_time - e_freq).abs() < 1e-9);
    }

    #[test]
    fn delta_transforms_to_ones() {
        let mut x = vec![C64::default(); 8];
        x[0] = C64::real(1.0);
        for v in fft(&x) {
            assert!((v - C64::real(1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(5);
        let a = rand_vec(&mut rng, 12);
        let b = rand_vec(&mut rng, 12);
        let sum: Vec<C64> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fs = fft(&sum);
        for i in 0..12 {
            assert!((fs[i] - (fa[i] + fb[i])).abs() < 1e-9);
        }
    }

    #[test]
    fn fft2_round_trip() {
        let mut rng = Rng::new(6);
        let (r, c) = (5usize, 9usize);
        let g = rand_vec(&mut rng, r * c);
        let f = fft2(&g, r, c, false);
        let back = fft2(&f, r, c, true);
        for (a, b) in g.iter().zip(&back) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn large_n_matches_naive_dft() {
        // the old incremental-twiddle butterflies (w = w * wl) accumulated
        // rounding drift over long stages; the planned tables must track
        // the naive DFT tightly even at large n.
        let mut rng = Rng::new(8);
        let n = 2048usize;
        let x = rand_vec(&mut rng, n);
        let got = fft(&x);
        let want = naive_dft(&x);
        let scale: f64 = x.iter().map(|v| v.abs()).sum::<f64>().max(1.0);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (*g - *w).abs() < 1e-10 * scale,
                "n={n} bin {k}: |err| = {}",
                (*g - *w).abs()
            );
        }
    }

    #[test]
    fn large_n_round_trip_tight() {
        let mut rng = Rng::new(9);
        let n = 1usize << 14;
        let x = rand_vec(&mut rng, n);
        let y = ifft(&fft(&x));
        for (a, b) in x.iter().zip(&y) {
            assert!((*a - *b).abs() < 1e-11);
        }
    }

    #[test]
    fn plan_twiddles_are_exact_cis() {
        let plan = FftPlan::new(256);
        for k in 0..128usize {
            let want =
                C64::cis(-2.0 * std::f64::consts::PI * k as f64 / 256.0);
            assert_eq!(plan.tw[k], want, "twiddle {k} not a direct cis");
        }
        assert_eq!(plan.bitrev[1], 128);
        assert_eq!(plan.bitrev[128], 1);
        assert_eq!(plan.bitrev[255], 255);
    }

    #[test]
    fn shared_plan_is_memoized() {
        let a = FftPlan::shared(64);
        let b = FftPlan::shared(64);
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 64);
    }

    #[test]
    fn fft2_inplace_matches_allocating_fft2() {
        let mut rng = Rng::new(10);
        let n = 8usize;
        let plan = FftPlan::new(n);
        let g = rand_vec(&mut rng, n * n);
        let mut col = vec![C64::default(); n];
        for inverse in [false, true] {
            let want_raw = fft2(&g, n, n, inverse);
            let mut got = g.clone();
            plan.fft2_inplace(&mut got, inverse, &mut col);
            // fft2 scales the inverse by 1/n^2; fft2_inplace is unscaled
            let s = if inverse { (n * n) as f64 } else { 1.0 };
            for (a, b) in got.iter().zip(&want_raw) {
                assert!((*a - b.scale(s)).abs() < 1e-9, "inverse={inverse}");
            }
        }
    }

    #[test]
    fn fwd2_real_matches_complex_path() {
        let mut rng = Rng::new(11);
        for n in [1usize, 2, 4, 8, 16] {
            let plan = FftPlan::new(n);
            let q: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let qc: Vec<C64> = q.iter().map(|v| C64::real(*v)).collect();
            let want = fft2(&qc, n, n, false);
            let mut got = vec![C64::default(); n * n];
            let mut col = vec![C64::default(); n];
            plan.fwd2_real_into(&q, &mut got, &mut col);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!((*a - *b).abs() < 1e-9, "n={n} idx={i}");
            }
        }
    }

    #[test]
    fn simd_butterflies_bit_match_scalar_oracle() {
        // not "close": IDENTICAL.  The lane formula performs the same
        // IEEE operations in the same order as the scalar butterflies,
        // so goldens produced before the SIMD path must be unchanged.
        let mut rng = Rng::new(12);
        for n in [1usize, 2, 4, 8, 32, 256, 1024] {
            let plan = FftPlan::new(n);
            for inverse in [false, true] {
                let x = rand_vec(&mut rng, n);
                let mut got = x.clone();
                let mut want = x.clone();
                plan.process(&mut got, inverse);
                plan.process_scalar(&mut want, inverse);
                for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        g.re.to_bits() == w.re.to_bits()
                            && g.im.to_bits() == w.im.to_bits(),
                        "n={n} inverse={inverse} idx={k}: {g:?} vs {w:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_col_pass_matches_single_column_scratch() {
        let mut rng = Rng::new(13);
        for n in [2usize, 4, 8, 16] {
            let plan = FftPlan::new(n);
            let g = rand_vec(&mut rng, n * n);
            for inverse in [false, true] {
                let mut want = g.clone();
                let mut col1 = vec![C64::default(); n];
                plan.fft2_inplace(&mut want, inverse, &mut col1);
                // oversized scratch in assorted multiples (and one
                // non-multiple) of n must give bit-identical grids
                for extra in [n, 3 * n, COL_BLOCK * n, n + 1] {
                    let mut got = g.clone();
                    let mut col = vec![C64::default(); extra];
                    plan.fft2_inplace(&mut got, inverse, &mut col);
                    assert_eq!(got, want, "n={n} scratch={extra}");
                }
            }
            // real-input forward path too
            let q: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let mut want = vec![C64::default(); n * n];
            let mut col1 = vec![C64::default(); n];
            plan.fwd2_real_into(&q, &mut want, &mut col1);
            let mut got = vec![C64::default(); n * n];
            let mut col = vec![C64::default(); COL_BLOCK * n];
            plan.fwd2_real_into(&q, &mut got, &mut col);
            assert_eq!(got, want, "fwd2_real n={n}");
        }
    }

    #[test]
    fn fft2_separable_vs_naive() {
        // direct 2D DFT on a tiny grid
        let mut rng = Rng::new(7);
        let (rows, cols) = (3usize, 4usize);
        let g = rand_vec(&mut rng, rows * cols);
        let f = fft2(&g, rows, cols, false);
        for p in 0..rows {
            for q in 0..cols {
                let mut acc = C64::default();
                for r in 0..rows {
                    for c in 0..cols {
                        let ang = -2.0 * std::f64::consts::PI
                            * ((p * r) as f64 / rows as f64
                                + (q * c) as f64 / cols as f64);
                        acc += g[r * cols + c] * C64::cis(ang);
                    }
                }
                assert!((f[p * cols + q] - acc).abs() < 1e-9);
            }
        }
    }
}
