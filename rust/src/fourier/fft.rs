//! Complex FFT from scratch: iterative radix-2 Cooley-Tukey for powers of
//! two, Bluestein's algorithm for arbitrary lengths, and 2D transforms.

use super::complex::C64;

/// In-place radix-2 DIT FFT; `n` must be a power of two.
/// `inverse` applies the conjugate transform WITHOUT the 1/n scaling.
pub fn fft_pow2(buf: &mut [C64], inverse: bool) {
    let n = buf.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wl = C64::cis(ang);
        let mut i = 0;
        while i < n {
            let mut w = C64::real(1.0);
            for k in 0..len / 2 {
                let u = buf[i + k];
                let v = buf[i + k + len / 2] * w;
                buf[i + k] = u + v;
                buf[i + k + len / 2] = u - v;
                w = w * wl;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// DFT of arbitrary length via Bluestein (chirp-z), O(n log n).
pub fn dft(input: &[C64], inverse: bool) -> Vec<C64> {
    let n = input.len();
    if n == 0 {
        return Vec::new();
    }
    if n.is_power_of_two() {
        let mut buf = input.to_vec();
        fft_pow2(&mut buf, inverse);
        return buf;
    }
    // Bluestein: x_k w^{k^2/2} convolved with chirp
    let sign = if inverse { 1.0 } else { -1.0 };
    let m = (2 * n - 1).next_power_of_two();
    let mut a = vec![C64::default(); m];
    let mut b = vec![C64::default(); m];
    let mut chirp = vec![C64::default(); n];
    for k in 0..n {
        // k^2 mod 2n to keep angles accurate
        let kk = (k * k) % (2 * n);
        let ang = sign * std::f64::consts::PI * kk as f64 / n as f64;
        chirp[k] = C64::cis(ang);
        a[k] = input[k] * chirp[k];
        b[k] = chirp[k].conj();
        if k > 0 {
            b[m - k] = chirp[k].conj();
        }
    }
    fft_pow2(&mut a, false);
    fft_pow2(&mut b, false);
    for i in 0..m {
        a[i] = a[i] * b[i];
    }
    fft_pow2(&mut a, true);
    let scale = 1.0 / m as f64;
    (0..n).map(|k| (a[k].scale(scale)) * chirp[k]).collect()
}

/// Forward DFT (no scaling).
pub fn fft(input: &[C64]) -> Vec<C64> {
    dft(input, false)
}

/// Inverse DFT with the 1/n scaling.
pub fn ifft(input: &[C64]) -> Vec<C64> {
    let n = input.len();
    let mut out = dft(input, true);
    let s = 1.0 / n as f64;
    for v in out.iter_mut() {
        *v = v.scale(s);
    }
    out
}

/// 2D FFT of a row-major rows x cols grid (in place semantics via return).
pub fn fft2(grid: &[C64], rows: usize, cols: usize, inverse: bool) -> Vec<C64> {
    debug_assert_eq!(grid.len(), rows * cols);
    let mut tmp: Vec<C64> = Vec::with_capacity(rows * cols);
    // rows
    for r in 0..rows {
        tmp.extend(dft(&grid[r * cols..(r + 1) * cols], inverse));
    }
    // cols
    let mut out = vec![C64::default(); rows * cols];
    let mut col_buf = vec![C64::default(); rows];
    for c in 0..cols {
        for r in 0..rows {
            col_buf[r] = tmp[r * cols + c];
        }
        let f = dft(&col_buf, inverse);
        for r in 0..rows {
            out[r * cols + c] = f[r];
        }
    }
    if inverse {
        let s = 1.0 / (rows * cols) as f64;
        for v in out.iter_mut() {
            *v = v.scale(s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_dft(x: &[C64]) -> Vec<C64> {
        let n = x.len();
        (0..n)
            .map(|k| {
                let mut acc = C64::default();
                for (j, v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (k * j) as f64
                        / n as f64;
                    acc += *v * C64::cis(ang);
                }
                acc
            })
            .collect()
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.normal(), rng.normal())).collect()
    }

    #[test]
    fn pow2_matches_naive() {
        let mut rng = Rng::new(0);
        for n in [1usize, 2, 4, 8, 16, 64] {
            let x = rand_vec(&mut rng, n);
            let got = fft(&x);
            let want = naive_dft(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g - *w).abs() < 1e-9 * n as f64);
            }
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [3usize, 5, 7, 9, 11, 13, 17, 33] {
            let x = rand_vec(&mut rng, n);
            let got = fft(&x);
            let want = naive_dft(&x);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((*g - *w).abs() < 1e-8, "n={n} idx={i}");
            }
        }
    }

    #[test]
    fn round_trip() {
        let mut rng = Rng::new(2);
        for n in [4usize, 7, 16, 21] {
            let x = rand_vec(&mut rng, n);
            let y = ifft(&fft(&x));
            for (a, b) in x.iter().zip(&y) {
                assert!((*a - *b).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Rng::new(3);
        let x = rand_vec(&mut rng, 32);
        let f = fft(&x);
        let e_time: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let e_freq: f64 = f.iter().map(|v| v.norm_sqr()).sum::<f64>() / 32.0;
        assert!((e_time - e_freq).abs() < 1e-9);
    }

    #[test]
    fn delta_transforms_to_ones() {
        let mut x = vec![C64::default(); 8];
        x[0] = C64::real(1.0);
        for v in fft(&x) {
            assert!((v - C64::real(1.0)).abs() < 1e-12);
        }
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(5);
        let a = rand_vec(&mut rng, 12);
        let b = rand_vec(&mut rng, 12);
        let sum: Vec<C64> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let fa = fft(&a);
        let fb = fft(&b);
        let fs = fft(&sum);
        for i in 0..12 {
            assert!((fs[i] - (fa[i] + fb[i])).abs() < 1e-9);
        }
    }

    #[test]
    fn fft2_round_trip() {
        let mut rng = Rng::new(6);
        let (r, c) = (5usize, 9usize);
        let g = rand_vec(&mut rng, r * c);
        let f = fft2(&g, r, c, false);
        let back = fft2(&f, r, c, true);
        for (a, b) in g.iter().zip(&back) {
            assert!((*a - *b).abs() < 1e-9);
        }
    }

    #[test]
    fn fft2_separable_vs_naive() {
        // direct 2D DFT on a tiny grid
        let mut rng = Rng::new(7);
        let (rows, cols) = (3usize, 4usize);
        let g = rand_vec(&mut rng, rows * cols);
        let f = fft2(&g, rows, cols, false);
        for p in 0..rows {
            for q in 0..cols {
                let mut acc = C64::default();
                for r in 0..rows {
                    for c in 0..cols {
                        let ang = -2.0 * std::f64::consts::PI
                            * ((p * r) as f64 / rows as f64
                                + (q * c) as f64 / cols as f64);
                        acc += g[r * cols + c] * C64::cis(ang);
                    }
                }
                assert!((f[p * cols + q] - acc).abs() < 1e-9);
            }
        }
    }
}
