//! Minimal JSON: parser + writer (serde is unavailable offline).
//!
//! Covers what the repo needs: the artifact `manifest.json`, the golden
//! cross-language test vectors, experiment result files — and, since the
//! `net` subsystem, the **wire codec** for the multi-process serving
//! protocol.  That last role means the parser runs against untrusted
//! bytes, so it is hardened: [`parse_limited`] enforces a nesting-depth
//! cap (the recursive-descent parser must never overflow the stack on
//! `[[[[...`) and a document-size cap, and every failure is a typed
//! [`JsonError`] — truncated input is distinguished from malformed
//! input, and nothing panics.  The legacy [`parse`] keeps its
//! `Result<Json, String>` signature but now delegates to the limited
//! parser with [`Limits::default`], so every existing caller gets the
//! stack-overflow protection for free.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an array of numbers into f64s.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Json::as_f64).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------
// hardened parsing
// ---------------------------------------------------------------------

/// Typed parse failure, so untrusted-input callers (the wire codec) can
/// tell a short read from garbage without string matching.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonError {
    /// nesting exceeded `Limits::max_depth` (recursion guard)
    TooDeep { max_depth: usize },
    /// the document is longer than `Limits::max_bytes`
    TooLarge { len: usize, max_bytes: usize },
    /// the input ended mid-value (torn frame / short read)
    Truncated(String),
    /// malformed JSON syntax
    Syntax(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::TooDeep { max_depth } => {
                write!(f, "nesting deeper than the {max_depth}-level limit")
            }
            JsonError::TooLarge { len, max_bytes } => write!(
                f,
                "document of {len} bytes exceeds the {max_bytes}-byte limit"
            ),
            JsonError::Truncated(m) => write!(f, "truncated document: {m}"),
            JsonError::Syntax(m) => write!(f, "syntax error: {m}"),
        }
    }
}

impl std::error::Error for JsonError {}

/// Resource limits for parsing untrusted documents.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// maximum array/object nesting depth
    pub max_depth: usize,
    /// maximum document length in bytes
    pub max_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        // 128 levels is far beyond any document this repo produces and
        // far below what would threaten the thread stack; 256 MiB
        // accommodates the largest Batch reply while refusing an
        // adversarial length claim
        Limits { max_depth: 128, max_bytes: 256 << 20 }
    }
}

/// Parse a JSON document (default [`Limits`]; string errors).
pub fn parse(src: &str) -> Result<Json, String> {
    parse_limited(src, &Limits::default()).map_err(|e| e.to_string())
}

/// Parse a JSON document from untrusted input: typed errors, no panics,
/// bounded depth and size.
pub fn parse_limited(src: &str, limits: &Limits) -> Result<Json, JsonError> {
    let bytes = src.as_bytes();
    if bytes.len() > limits.max_bytes {
        return Err(JsonError::TooLarge {
            len: bytes.len(),
            max_bytes: limits.max_bytes,
        });
    }
    let mut p = Parser { b: bytes, i: 0, depth: 0, max_depth: limits.max_depth };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(JsonError::Syntax(format!("trailing data at byte {}", p.i)));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        match self.peek() {
            Some(got) if got == c => {
                self.i += 1;
                Ok(())
            }
            Some(got) => Err(JsonError::Syntax(format!(
                "expected '{}' at byte {} (got '{}')",
                c as char, self.i, got as char
            ))),
            None => Err(JsonError::Truncated(format!(
                "expected '{}' at byte {} (end of input)",
                c as char, self.i
            ))),
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > self.max_depth {
            return Err(JsonError::TooDeep { max_depth: self.max_depth });
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => {
                Err(JsonError::Truncated("unexpected end of input".to_string()))
            }
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        let rest = &self.b[self.i..];
        if rest.starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else if rest.len() < word.len()
            && word.as_bytes().starts_with(rest)
        {
            Err(JsonError::Truncated(format!(
                "input ends inside the literal '{word}'"
            )))
        } else {
            Err(JsonError::Syntax(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| {
                JsonError::Syntax(format!("bad number at byte {start}"))
            })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| {
                                    JsonError::Truncated(
                                        "input ends inside a \\u escape"
                                            .to_string(),
                                    )
                                })?;
                            let code = std::str::from_utf8(hex)
                                .ok()
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| {
                                    JsonError::Syntax(format!(
                                        "bad \\u escape at byte {}",
                                        self.i
                                    ))
                                })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        Some(c) => {
                            return Err(JsonError::Syntax(format!(
                                "bad escape '\\{}' at byte {}",
                                c as char, self.i
                            )))
                        }
                        None => {
                            return Err(JsonError::Truncated(
                                "input ends inside an escape".to_string(),
                            ))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(
                            |e| JsonError::Syntax(format!("bad UTF-8: {e}")),
                        )?,
                    );
                }
                None => {
                    return Err(JsonError::Truncated(
                        "unterminated string".to_string(),
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                Some(_) => {
                    return Err(JsonError::Syntax(format!(
                        "bad array at byte {}",
                        self.i
                    )))
                }
                None => {
                    return Err(JsonError::Truncated(format!(
                        "input ends inside an array at byte {}",
                        self.i
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                Some(_) => {
                    return Err(JsonError::Syntax(format!(
                        "bad object at byte {}",
                        self.i
                    )))
                }
                None => {
                    return Err(JsonError::Truncated(format!(
                        "input ends inside an object at byte {}",
                        self.i
                    )))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-7}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn f64_vec() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }

    #[test]
    fn depth_limit_is_enforced_not_overflowed() {
        // a pathological `[[[[...` must come back as a typed TooDeep,
        // never as a stack overflow (this is the wire-codec guarantee)
        let deep = "[".repeat(100_000);
        match parse_limited(&deep, &Limits::default()) {
            Err(JsonError::TooDeep { max_depth }) => {
                assert_eq!(max_depth, Limits::default().max_depth)
            }
            other => panic!("expected TooDeep, got {other:?}"),
        }
        // documents AT the limit parse fine
        let n = 16usize;
        let ok = format!("{}{}", "[".repeat(n), "]".repeat(n));
        let lim = Limits { max_depth: n, max_bytes: 1 << 20 };
        assert!(parse_limited(&ok, &lim).is_ok());
        let over = format!("{}{}", "[".repeat(n + 1), "]".repeat(n + 1));
        assert!(matches!(
            parse_limited(&over, &lim),
            Err(JsonError::TooDeep { .. })
        ));
    }

    #[test]
    fn size_limit_is_enforced() {
        let lim = Limits { max_depth: 8, max_bytes: 16 };
        let doc = "\"0123456789abcdef0123\"";
        match parse_limited(doc, &lim) {
            Err(JsonError::TooLarge { len, max_bytes }) => {
                assert_eq!(len, doc.len());
                assert_eq!(max_bytes, 16);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncation_is_typed_distinct_from_syntax() {
        // torn-frame shapes: every prefix cut is Truncated, not Syntax
        for doc in [
            "{\"a\": [1, 2",
            "{\"a\"",
            "\"unterminated",
            "tru",
            "[1, 2,",
            "\"esc\\",
            "\"esc\\u00",
        ] {
            match parse_limited(doc, &Limits::default()) {
                Err(JsonError::Truncated(_)) => {}
                other => panic!("{doc:?}: expected Truncated, got {other:?}"),
            }
        }
        // garbage (not a prefix of a valid doc) stays Syntax
        for doc in ["[1,]", "{\"a\" 1}", "@", "truce"] {
            match parse_limited(doc, &Limits::default()) {
                Err(JsonError::Syntax(_)) => {}
                other => panic!("{doc:?}: expected Syntax, got {other:?}"),
            }
        }
    }
}
