//! Minimal JSON: parser + writer (serde is unavailable offline).
//!
//! Covers exactly what the repo needs: the artifact `manifest.json`, the
//! golden cross-language test vectors, and experiment result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an array of numbers into f64s.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(Json::as_f64).collect())
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, String> {
    let bytes = src.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (got {:?})",
                c as char, self.i,
                self.peek().map(|x| x as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":-7}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn f64_vec() {
        let v = parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.as_f64_vec().unwrap(), vec![1.0, 2.0, 3.5]);
    }
}
