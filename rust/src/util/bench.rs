//! Micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are plain `main()` bins (harness = false) that
//! call [`bench`] / [`BenchTable`]: warmup, adaptive iteration count,
//! median + MAD reporting, and machine-readable TSV output so the
//! experiment scripts can regenerate the paper's figures.

use std::hint::black_box;
use std::time::Instant;

/// One measured result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
}

impl Measurement {
    pub fn pretty(&self) -> String {
        format!(
            "{:<44} {:>12}  (±{:>9}, {} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f` with warmup; targets ~`budget_ms` of sampling.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> Measurement {
    // Warmup.  The FIRST call pays for lazy plan/table construction
    // (FftPlan::shared, PlanCache) and can be orders of magnitude slower
    // than steady state; it must never feed calibration.  Run twice,
    // then keep warming until ~2 ms of steady-state calls have elapsed.
    f();
    f();
    let warm = Instant::now();
    while warm.elapsed().as_nanos() < 2_000_000 {
        f();
    }
    // Calibrate from WARM timings: double the batch until one batch is
    // long enough to trust, then size iters_per_sample so each sample
    // lasts at least 100 µs — a floor that keeps timer granularity out
    // of the medians for fast post-warmup kernels.
    let budget_ns = (budget_ms as f64) * 1e6;
    let samples = 15usize;
    let target_ns = (budget_ns / samples as f64).max(100_000.0);
    let mut cal_iters = 1usize;
    let iters_per_sample = loop {
        let t0 = Instant::now();
        for _ in 0..cal_iters {
            f();
        }
        let t = t0.elapsed().as_nanos().max(1) as f64;
        if t >= 0.8 * target_ns || cal_iters >= (1 << 20) {
            let per_call = t / cal_iters as f64;
            break ((target_ns / per_call).ceil() as usize).clamp(1, 1_000_000);
        }
        cal_iters *= 2;
    };
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        per_iter.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mut devs: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    Measurement {
        name: name.to_string(),
        median_ns: median,
        mad_ns: mad,
        iters: iters_per_sample * samples,
    }
}

/// Keep the optimizer honest.
pub fn consume<T>(x: T) -> T {
    black_box(x)
}

/// Whether the bench binary was invoked with `--smoke` (e.g. via
/// `cargo bench --bench <name> -- --smoke`): run ONE tiny size per table
/// with a minimal budget, as a fast CI check that the bench still builds
/// and executes.  `scripts/verify.sh` runs every bench this way so a
/// broken bench fails tier-1 instead of only at figure-generation time.
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// `base_ms` normally, 1 ms in smoke mode.
pub fn budget_ms(base_ms: u64) -> u64 {
    if smoke() { 1 } else { base_ms }
}

/// Collects rows, prints a table, and writes TSV next to the bench.
pub struct BenchTable {
    pub title: String,
    pub rows: Vec<Measurement>,
}

impl BenchTable {
    pub fn new(title: &str) -> Self {
        println!("\n== {title} ==");
        BenchTable { title: title.to_string(), rows: Vec::new() }
    }

    pub fn add(&mut self, m: Measurement) {
        println!("{}", m.pretty());
        self.rows.push(m);
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, budget_ms: u64, f: F) {
        let m = bench(name, budget_ms, f);
        self.add(m);
    }

    /// Write `target/bench-results/<file>.tsv`.
    pub fn write_tsv(&self, file: &str) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let mut out = String::from("name\tmedian_ns\tmad_ns\titers\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{}\t{}\t{}\t{}\n",
                r.name, r.median_ns, r.mad_ns, r.iters
            ));
        }
        let path = dir.join(format!("{file}.tsv"));
        if let Err(e) = std::fs::write(&path, out) {
            eprintln!("warn: could not write {path:?}: {e}");
        } else {
            println!("[tsv] {path:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let m = bench("spin", 5, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(consume(i));
            }
            consume(s);
        });
        assert!(m.median_ns > 0.0);
        assert!(m.iters >= 15);
    }

    #[test]
    fn calibration_ignores_cold_first_call() {
        // First call simulates lazy plan construction (~5 ms); steady
        // state is microseconds.  The old calibrator divided the budget
        // by the COLD call and produced 1 iter/sample (15 total); the
        // warm calibrator with a 100 µs sample floor must batch far
        // more aggressively.
        let mut first = true;
        let m = bench("cold-then-fast", 5, || {
            if first {
                first = false;
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            let mut s = 0u64;
            for i in 0..64u64 {
                s = s.wrapping_add(consume(i));
            }
            consume(s);
        });
        assert!(
            m.iters >= 150,
            "cold first call still dominates calibration: {} iters",
            m.iters
        );
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5.0e4).contains("µs"));
        assert!(fmt_ns(5.0e7).contains("ms"));
        assert!(fmt_ns(5.0e9).contains("s"));
    }
}
