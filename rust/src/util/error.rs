//! Minimal error handling (external error crates are unavailable in the
//! offline build).
//!
//! One string-backed [`Error`] type, a [`Result`] alias with a defaulted
//! error parameter, a [`Context`] extension trait providing the familiar
//! `context`/`with_context`, and the `err!`/`bail!` macros (exported at
//! the crate root) for formatted construction and early return.

use std::fmt;

/// A boxed-free, string-backed error.  Context is prepended on the way up
/// (`"reading manifest: No such file"`), which is all this crate needs:
/// errors here are diagnostics for operators, not control flow.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer: `"<context>: <self>"`.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

/// Crate-wide result alias; the error parameter defaults to [`Error`] so
/// `Result<T>` is the common spelling, while `Result<T, Other>` works.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `context`/`with_context` to results and options.
pub trait Context<T> {
    /// Replace/annotate the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Replace/annotate the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] from format-string arguments.  Lives at the crate
/// root: `use gaunt_tp::err;`
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("inner"))
    }

    #[test]
    fn display_and_context() {
        let e = Error::msg("boom").context("outer");
        assert_eq!(e.to_string(), "outer: boom");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = fails().context("stage");
        assert_eq!(r.unwrap_err().to_string(), "stage: inner");
        let o: Option<u32> = None;
        let r = o.with_context(|| format!("missing {}", 7));
        assert_eq!(r.unwrap_err().to_string(), "missing 7");
        assert_eq!(Some(3u32).context("never").unwrap(), 3);
    }

    #[test]
    fn macros_format() {
        let e = err!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        fn bails(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {flag}");
            }
            Ok(1)
        }
        assert!(bails(false).is_ok());
        assert_eq!(bails(true).unwrap_err().to_string(), "flagged true");
    }

    #[test]
    fn io_error_converts() {
        fn read_missing() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(read_missing().is_err());
    }
}
