//! Deterministic, zero-dependency fault injection (the `fail`-crate
//! idea, hand-rolled for the offline workspace).
//!
//! A **site** is a named point in the code — `"backend.run"`,
//! `"svc.worker.tick"` — that calls [`check`] on its hot path.  When no
//! policy is configured for any site, `check` compiles down to one
//! relaxed atomic load and a branch: no allocation, no lock, no string
//! hashing.  That is the whole cost the serving hot paths pay in
//! production.
//!
//! A **policy** attaches a behavior to a site:
//!
//! ```text
//!   panic                abort the site by panicking (unwind)
//!   error                return Fault::Error with a default message
//!   error(msg)           return Fault::Error(msg)
//!   delay(ms)            sleep `ms` milliseconds, then pass
//!   nan                  return Fault::Nan (the site poisons its output)
//! ```
//!
//! with optional modifiers, e.g. `one_shot:panic` (fire once, then
//! disarm) or `every_nth(3):error(boom)` (fire on every 3rd call).
//!
//! Configuration comes from either the `FAILPOINTS` environment
//! variable (`site=policy;site2=policy2`, parsed lazily on the first
//! armed check) or the test-scoped [`scoped`] guard API, which removes
//! its site again on drop.  `Fault::Error`/`Fault::Nan` are *returned*
//! to the site so it can surface a typed error through its own error
//! channel; `panic` and `delay` take effect inside `check` itself.
//!
//! Site naming convention (DESIGN.md §12): `area.component.event`,
//! lower-case, dot-separated, e.g. `svc.worker.tick`,
//! `svc.batcher.flush`, `registry.resolve`, `ckpt.write`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::Duration;

/// Sentinel: the `FAILPOINTS` env var has not been parsed yet.  Any
/// non-zero value routes the first check through the slow path exactly
/// once; after parsing, `ARMED` holds the live site count (0 = free).
const UNINIT: usize = usize::MAX;

static ARMED: AtomicUsize = AtomicUsize::new(UNINIT);

/// site name -> live policy + counters
static REGISTRY: Mutex<Option<HashMap<String, Site>>> = Mutex::new(None);

/// What a triggered failpoint asks its site to do.  `panic`/`delay`
/// policies never reach the caller (they act inside [`check`]); the
/// returned variants are the ones a site must translate into its own
/// typed error channel.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Surface this message as the site's typed error.
    Error(String),
    /// Poison the site's numeric output with a NaN (exercises the
    /// non-finite containment layer downstream).
    Nan,
}

/// The behavior half of a policy.
#[derive(Clone, Debug, PartialEq)]
pub enum Behavior {
    Panic,
    Error(String),
    /// sleep this long, then let the site proceed normally
    Delay(u64),
    Nan,
}

/// A parsed per-site policy.
#[derive(Clone, Debug, PartialEq)]
pub struct Policy {
    pub behavior: Behavior,
    /// fire at most once, then disarm (the site stays registered so
    /// hit/call counters keep counting)
    pub one_shot: bool,
    /// fire only on every Nth call to the site (1 = every call)
    pub every_nth: u64,
}

struct Site {
    policy: Policy,
    /// calls to `check` for this site (armed or not)
    calls: u64,
    /// times the policy actually fired
    hits: u64,
    /// a one_shot policy that already fired
    spent: bool,
}

enum Deferred {
    Panic(String),
    Delay(u64),
}

/// Parse one policy string: `[one_shot:|every_nth(N):]behavior`.
pub fn parse_policy(s: &str) -> Result<Policy, String> {
    let mut rest = s.trim();
    let mut one_shot = false;
    let mut every_nth = 1u64;
    loop {
        if let Some(r) = rest.strip_prefix("one_shot:") {
            one_shot = true;
            rest = r.trim();
        } else if let Some(r) = rest.strip_prefix("every_nth(") {
            let (n, r2) = r
                .split_once("):")
                .ok_or_else(|| format!("bad every_nth modifier in '{s}'"))?;
            every_nth = n
                .trim()
                .parse::<u64>()
                .map_err(|_| format!("bad every_nth count in '{s}'"))?
                .max(1);
            rest = r2.trim();
        } else {
            break;
        }
    }
    let behavior = if rest == "panic" {
        Behavior::Panic
    } else if rest == "nan" {
        Behavior::Nan
    } else if rest == "error" {
        Behavior::Error("injected failpoint error".to_string())
    } else if let Some(arg) = rest
        .strip_prefix("error(")
        .and_then(|r| r.strip_suffix(')'))
    {
        Behavior::Error(arg.to_string())
    } else if let Some(arg) = rest
        .strip_prefix("delay(")
        .and_then(|r| r.strip_suffix(')'))
    {
        Behavior::Delay(
            arg.trim()
                .parse::<u64>()
                .map_err(|_| format!("bad delay ms in '{s}'"))?,
        )
    } else {
        return Err(format!(
            "unknown failpoint behavior '{rest}' (want panic | error | \
             error(msg) | delay(ms) | nan)"
        ));
    };
    Ok(Policy { behavior, one_shot, every_nth })
}

fn registry_lock(
) -> std::sync::MutexGuard<'static, Option<HashMap<String, Site>>> {
    // a panic policy firing inside the lock scope poisons this mutex by
    // design; recovery keeps the framework usable afterwards
    REGISTRY.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Parse `FAILPOINTS` exactly once (idempotent; races resolve to one
/// winner under the registry lock).  Malformed entries are skipped —
/// fault injection must never break a production start-up.
fn init_from_env(map: &mut HashMap<String, Site>) {
    if ARMED.load(Ordering::Relaxed) != UNINIT {
        return;
    }
    if let Ok(spec) = std::env::var("FAILPOINTS") {
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some((site, policy)) = part.split_once('=') {
                if let Ok(policy) = parse_policy(policy) {
                    map.insert(
                        site.trim().to_string(),
                        Site { policy, calls: 0, hits: 0, spent: false },
                    );
                }
            }
        }
    }
    ARMED.store(map.len(), Ordering::Relaxed);
}

/// The hot-path check every instrumented site calls.  Returns `None`
/// (by far the common case, one relaxed load) unless a policy is
/// armed for `site` and fires on this call.
#[inline]
pub fn check(site: &str) -> Option<Fault> {
    if ARMED.load(Ordering::Relaxed) == 0 {
        return None;
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> Option<Fault> {
    let deferred = {
        let mut g = registry_lock();
        let map = g.get_or_insert_with(HashMap::new);
        init_from_env(map);
        let s = map.get_mut(site)?;
        s.calls += 1;
        if s.spent {
            return None;
        }
        if s.policy.every_nth > 1 && s.calls % s.policy.every_nth != 0 {
            return None;
        }
        if s.policy.one_shot {
            s.spent = true;
        }
        s.hits += 1;
        match &s.policy.behavior {
            Behavior::Error(m) => return Some(Fault::Error(m.clone())),
            Behavior::Nan => return Some(Fault::Nan),
            Behavior::Panic => Deferred::Panic(site.to_string()),
            Behavior::Delay(ms) => Deferred::Delay(*ms),
        }
        // the lock is released HERE, before panicking or sleeping:
        // a panic policy must not poison the framework's own registry,
        // and a delay must not serialize unrelated sites
    };
    match deferred {
        Deferred::Panic(site) => {
            panic!("failpoint '{site}': injected panic")
        }
        Deferred::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
    }
}

/// RAII guard from [`scoped`]: removes its site again on drop.
pub struct Guard {
    site: String,
}

impl Drop for Guard {
    fn drop(&mut self) {
        remove(&self.site);
    }
}

/// Arm `site` with `policy` (parsed per the module grammar) for the
/// guard's lifetime — the test-scoped configuration API.
///
/// Panics on an unparsable policy string: this is test infrastructure,
/// a typo should fail loudly.
pub fn scoped(site: &str, policy: &str) -> Guard {
    let policy = parse_policy(policy)
        .unwrap_or_else(|e| panic!("failpoint::scoped({site}): {e}"));
    let mut g = registry_lock();
    let map = g.get_or_insert_with(HashMap::new);
    init_from_env(map);
    let fresh = map
        .insert(
            site.to_string(),
            Site { policy, calls: 0, hits: 0, spent: false },
        )
        .is_none();
    if fresh {
        ARMED.fetch_add(1, Ordering::Relaxed);
    }
    Guard { site: site.to_string() }
}

/// Disarm `site` (no-op when it was never armed).
pub fn remove(site: &str) {
    let mut g = registry_lock();
    if let Some(map) = g.as_mut() {
        if map.remove(site).is_some() {
            ARMED.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Times the policy at `site` actually fired (0 when never armed).
pub fn hits(site: &str) -> u64 {
    let g = registry_lock();
    g.as_ref()
        .and_then(|m| m.get(site))
        .map(|s| s.hits)
        .unwrap_or(0)
}

/// Calls [`check`] made against `site` while it was armed.
pub fn calls(site: &str) -> u64 {
    let g = registry_lock();
    g.as_ref()
        .and_then(|m| m.get(site))
        .map(|s| s.calls)
        .unwrap_or(0)
}

/// Disarm every site (env-configured ones included).  `ARMED` lands on
/// 0, not the parse-pending sentinel, so a later check stays on the
/// fast path instead of re-reading the environment.
pub fn clear() {
    let mut g = registry_lock();
    let map = g.get_or_insert_with(HashMap::new);
    init_from_env(map);
    map.clear();
    ARMED.store(0, Ordering::Relaxed);
}

/// True when any site is armed (after lazy env parsing, without
/// triggering it).
pub fn any_armed() -> bool {
    match ARMED.load(Ordering::Relaxed) {
        0 => false,
        UNINIT => {
            let mut g = registry_lock();
            let map = g.get_or_insert_with(HashMap::new);
            init_from_env(map);
            !map.is_empty()
        }
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::MutexGuard;

    // the registry is process-global; serialize the unit tests so one
    // test's guards never leak into another's assertions
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    fn serial() -> MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn unarmed_check_is_none() {
        let _s = serial();
        clear();
        assert_eq!(check("tests.nowhere"), None);
        assert_eq!(hits("tests.nowhere"), 0);
    }

    #[test]
    fn policy_grammar_round_trips() {
        let p = parse_policy("panic").unwrap();
        assert_eq!(p.behavior, Behavior::Panic);
        assert!(!p.one_shot);
        assert_eq!(p.every_nth, 1);
        let p = parse_policy("one_shot:error(boom)").unwrap();
        assert!(p.one_shot);
        assert_eq!(p.behavior, Behavior::Error("boom".to_string()));
        let p = parse_policy("every_nth(3):nan").unwrap();
        assert_eq!(p.every_nth, 3);
        assert_eq!(p.behavior, Behavior::Nan);
        let p = parse_policy("one_shot:every_nth(2):delay(7)").unwrap();
        assert!(p.one_shot);
        assert_eq!(p.every_nth, 2);
        assert_eq!(p.behavior, Behavior::Delay(7));
        assert!(parse_policy("explode").is_err());
        assert!(parse_policy("delay(forever)").is_err());
        assert!(parse_policy("every_nth(x):panic").is_err());
    }

    #[test]
    fn scoped_guard_arms_and_disarms() {
        let _s = serial();
        clear();
        {
            let _g = scoped("tests.err", "error(injected)");
            match check("tests.err") {
                Some(Fault::Error(m)) => assert_eq!(m, "injected"),
                other => panic!("expected Error fault, got {other:?}"),
            }
            assert_eq!(hits("tests.err"), 1);
            assert_eq!(calls("tests.err"), 1);
        }
        // guard dropped: site disarmed, fast path again
        assert_eq!(check("tests.err"), None);
        assert_eq!(hits("tests.err"), 0);
    }

    #[test]
    fn one_shot_fires_exactly_once() {
        let _s = serial();
        clear();
        let _g = scoped("tests.once", "one_shot:nan");
        assert_eq!(check("tests.once"), Some(Fault::Nan));
        assert_eq!(check("tests.once"), None);
        assert_eq!(check("tests.once"), None);
        assert_eq!(hits("tests.once"), 1);
        assert_eq!(calls("tests.once"), 3);
    }

    #[test]
    fn every_nth_fires_on_multiples() {
        let _s = serial();
        clear();
        let _g = scoped("tests.nth", "every_nth(3):error(tick)");
        let fired: Vec<bool> =
            (0..9).map(|_| check("tests.nth").is_some()).collect();
        assert_eq!(
            fired,
            vec![false, false, true, false, false, true, false, false, true]
        );
        assert_eq!(hits("tests.nth"), 3);
    }

    #[test]
    fn panic_policy_unwinds_and_registry_survives() {
        let _s = serial();
        clear();
        let _g = scoped("tests.boom", "one_shot:panic");
        let r = std::panic::catch_unwind(|| check("tests.boom"));
        assert!(r.is_err(), "panic policy must unwind");
        // the registry mutex was released before the panic: counters
        // still readable, later checks pass
        assert_eq!(hits("tests.boom"), 1);
        assert_eq!(check("tests.boom"), None, "one_shot spent");
    }

    #[test]
    fn delay_policy_sleeps_then_passes() {
        let _s = serial();
        clear();
        let _g = scoped("tests.slow", "delay(20)");
        let t0 = std::time::Instant::now();
        assert_eq!(check("tests.slow"), None);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn rearming_a_site_replaces_its_policy() {
        let _s = serial();
        clear();
        let _g1 = scoped("tests.swap", "error(first)");
        let _g2 = scoped("tests.swap", "error(second)");
        match check("tests.swap") {
            Some(Fault::Error(m)) => assert_eq!(m, "second"),
            other => panic!("expected replaced policy, got {other:?}"),
        }
    }
}
