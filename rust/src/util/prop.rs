//! Mini property-testing driver (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it retries
//! with "shrunk" scale factors to report the smallest failing magnitude,
//! then panics with the seed so the case is reproducible.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop(rng, case_index) -> Result<(), String>` over `cfg.cases`
/// independently seeded cases.  Panics with the failing seed + message.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two slices are element-wise close.
pub fn assert_close(a: &[f64], b: &[f64], atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol {
            return Err(format!(
                "idx {i}: {x} vs {y} (|diff| = {} > atol {atol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

/// Max absolute difference of two slices.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", PropConfig::default(), |rng, _| {
            let a = rng.normal();
            let b = rng.normal();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics() {
        check(
            "always-fails",
            PropConfig { cases: 3, seed: 1 },
            |_, _| Err("nope".into()),
        );
    }

    #[test]
    fn close_check() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1.0).is_err());
    }
}
