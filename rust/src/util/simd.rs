//! Zero-dependency explicit SIMD lanes for the Fourier hot path.
//!
//! Two portable lane types, [`F64x4`] and [`F32x8`], expose exactly the
//! handful of operations the FFT butterflies, the pointwise spectral
//! products, and the `f2sh` back-projection need: element-wise
//! add/sub/mul plus the pair-shuffles that make an interleaved
//! `[re0, im0, re1, im1, ...]` lane vector behave like packed complex
//! numbers ([`SimdLanes::complex_mul`]).
//!
//! Dispatch is at COMPILE time, per `target_arch`:
//!
//! * `x86_64` — SSE2 (part of the x86-64 baseline, so no runtime feature
//!   detection): `F64x4` is two `__m128d`, `F32x8` two `__m128`.
//! * `aarch64` — NEON (baseline on AArch64): two `float64x2_t` /
//!   `float32x4_t`.
//! * anything else — the [`scalar`] fallback structs.
//!
//! The [`scalar`] module is ALWAYS compiled and implements the identical
//! lane semantics with plain loops; it is both the fallback and the
//! conformance oracle (`tests/simd_conformance.rs` bit-compares every
//! op against it, including NaN/denormal/signed-zero inputs).  Every
//! implementation sticks to IEEE-exact single operations — mul, add,
//! sub, sign-flip — and deliberately avoids FMA, so the SIMD paths are
//! BIT-IDENTICAL to the scalar fallback (and to the pre-SIMD scalar
//! kernels) in f64, not merely close.

use std::ops::{Add, Mul, Sub};

/// Name of the lane implementation compiled into this build (for bench
/// output and docs): `"sse2"`, `"neon"`, or `"scalar"`.
#[cfg(target_arch = "x86_64")]
pub const ACTIVE_IMPL: &str = "sse2";
#[cfg(target_arch = "aarch64")]
pub const ACTIVE_IMPL: &str = "neon";
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub const ACTIVE_IMPL: &str = "scalar";

/// The lane-vector contract shared by the SIMD types and their scalar
/// oracles.  "Pairs" means adjacent lanes `(2k, 2k+1)` — the interleaved
/// re/im layout of a complex slice viewed as floats.
pub trait SimdLanes:
    Copy + Sized + Add<Output = Self> + Sub<Output = Self> + Mul<Output = Self>
{
    type Elem: Copy + Default + PartialEq + std::fmt::Debug;
    const LANES: usize;

    /// All lanes set to `v`.
    fn splat(v: Self::Elem) -> Self;

    /// Load `LANES` elements from the front of `src` (unaligned;
    /// panics if `src` is shorter).
    fn load(src: &[Self::Elem]) -> Self;

    /// Store the lanes to the front of `dst` (unaligned; panics if
    /// `dst` is shorter).
    fn store(self, dst: &mut [Self::Elem]);

    /// `[a0, a0, a2, a2, ...]` — broadcast each pair's even lane.
    fn dup_even(self) -> Self;

    /// `[a1, a1, a3, a3, ...]` — broadcast each pair's odd lane.
    fn dup_odd(self) -> Self;

    /// `[a1, a0, a3, a2, ...]` — swap the lanes of each pair.
    fn swap_pairs(self) -> Self;

    /// `[-a0, a1, -a2, a3, ...]` — sign-flip the even lanes (exact bit
    /// flip, never a multiply, so NaN payloads survive).
    fn neg_even(self) -> Self;

    /// De-interleave the concatenation of `a` and `b`:
    /// `(evens, odds)` with `evens = [a0, a2, .., b0, b2, ..]`.
    fn unzip(a: Self, b: Self) -> (Self, Self);

    /// Packed complex product of the pairs of `self` (as `[re, im]`)
    /// with the pairs of `rhs`.  Defined ONCE here so every
    /// implementation computes the same expression
    /// `re = a.re*b.re - a.im*b.im`, `im = a.re*b.im + a.im*b.re` —
    /// lane-for-lane the same mul/sub/add sequence as the scalar
    /// complex multiply.
    #[inline(always)]
    fn complex_mul(self, rhs: Self) -> Self {
        self.dup_even() * rhs + (self.dup_odd() * rhs.swap_pairs()).neg_even()
    }

    /// Lanes as a plain vector (test/debug convenience).
    fn to_vec(self) -> Vec<Self::Elem> {
        let mut out = vec![Self::Elem::default(); Self::LANES];
        self.store(&mut out);
        out
    }
}

/// Plain-loop lane structs: the portable fallback and the conformance
/// oracle the SIMD paths are bit-compared against.
pub mod scalar {
    use super::SimdLanes;
    use std::ops::{Add, Mul, Sub};

    macro_rules! scalar_lanes {
        ($name:ident, $elem:ty, $lanes:expr) => {
            #[derive(Clone, Copy, Debug)]
            pub struct $name(pub [$elem; $lanes]);

            impl Add for $name {
                type Output = $name;
                #[inline(always)]
                fn add(self, o: $name) -> $name {
                    let mut r = self.0;
                    for (x, y) in r.iter_mut().zip(&o.0) {
                        *x += *y;
                    }
                    $name(r)
                }
            }

            impl Sub for $name {
                type Output = $name;
                #[inline(always)]
                fn sub(self, o: $name) -> $name {
                    let mut r = self.0;
                    for (x, y) in r.iter_mut().zip(&o.0) {
                        *x -= *y;
                    }
                    $name(r)
                }
            }

            impl Mul for $name {
                type Output = $name;
                #[inline(always)]
                fn mul(self, o: $name) -> $name {
                    let mut r = self.0;
                    for (x, y) in r.iter_mut().zip(&o.0) {
                        *x *= *y;
                    }
                    $name(r)
                }
            }

            impl SimdLanes for $name {
                type Elem = $elem;
                const LANES: usize = $lanes;

                #[inline(always)]
                fn splat(v: $elem) -> $name {
                    $name([v; $lanes])
                }

                #[inline(always)]
                fn load(src: &[$elem]) -> $name {
                    let mut r = [<$elem>::default(); $lanes];
                    r.copy_from_slice(&src[..$lanes]);
                    $name(r)
                }

                #[inline(always)]
                fn store(self, dst: &mut [$elem]) {
                    dst[..$lanes].copy_from_slice(&self.0);
                }

                #[inline(always)]
                fn dup_even(self) -> $name {
                    let mut r = self.0;
                    for k in 0..$lanes / 2 {
                        r[2 * k + 1] = r[2 * k];
                    }
                    $name(r)
                }

                #[inline(always)]
                fn dup_odd(self) -> $name {
                    let mut r = self.0;
                    for k in 0..$lanes / 2 {
                        r[2 * k] = r[2 * k + 1];
                    }
                    $name(r)
                }

                #[inline(always)]
                fn swap_pairs(self) -> $name {
                    let mut r = self.0;
                    for k in 0..$lanes / 2 {
                        r.swap(2 * k, 2 * k + 1);
                    }
                    $name(r)
                }

                #[inline(always)]
                fn neg_even(self) -> $name {
                    let mut r = self.0;
                    for k in 0..$lanes / 2 {
                        r[2 * k] = -r[2 * k];
                    }
                    $name(r)
                }

                #[inline(always)]
                fn unzip(a: $name, b: $name) -> ($name, $name) {
                    let mut ev = [<$elem>::default(); $lanes];
                    let mut od = [<$elem>::default(); $lanes];
                    let h = $lanes / 2;
                    for k in 0..h {
                        ev[k] = a.0[2 * k];
                        ev[h + k] = b.0[2 * k];
                        od[k] = a.0[2 * k + 1];
                        od[h + k] = b.0[2 * k + 1];
                    }
                    ($name(ev), $name(od))
                }
            }
        };
    }

    scalar_lanes!(ScalarF64x4, f64, 4);
    scalar_lanes!(ScalarF32x8, f32, 8);
}

#[cfg(target_arch = "x86_64")]
mod sse2 {
    use super::SimdLanes;
    use core::arch::x86_64::*;
    use std::ops::{Add, Mul, Sub};

    /// Four f64 lanes as two SSE2 `__m128d` halves.
    #[derive(Clone, Copy)]
    pub struct F64x4(__m128d, __m128d);

    impl Add for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn add(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(_mm_add_pd(self.0, o.0), _mm_add_pd(self.1, o.1)) }
        }
    }

    impl Sub for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn sub(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(_mm_sub_pd(self.0, o.0), _mm_sub_pd(self.1, o.1)) }
        }
    }

    impl Mul for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn mul(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(_mm_mul_pd(self.0, o.0), _mm_mul_pd(self.1, o.1)) }
        }
    }

    impl SimdLanes for F64x4 {
        type Elem = f64;
        const LANES: usize = 4;

        #[inline(always)]
        fn splat(v: f64) -> F64x4 {
            unsafe { F64x4(_mm_set1_pd(v), _mm_set1_pd(v)) }
        }

        #[inline(always)]
        fn load(src: &[f64]) -> F64x4 {
            assert!(src.len() >= 4);
            unsafe {
                F64x4(
                    _mm_loadu_pd(src.as_ptr()),
                    _mm_loadu_pd(src.as_ptr().add(2)),
                )
            }
        }

        #[inline(always)]
        fn store(self, dst: &mut [f64]) {
            assert!(dst.len() >= 4);
            unsafe {
                _mm_storeu_pd(dst.as_mut_ptr(), self.0);
                _mm_storeu_pd(dst.as_mut_ptr().add(2), self.1);
            }
        }

        #[inline(always)]
        fn dup_even(self) -> F64x4 {
            unsafe {
                F64x4(
                    _mm_unpacklo_pd(self.0, self.0),
                    _mm_unpacklo_pd(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn dup_odd(self) -> F64x4 {
            unsafe {
                F64x4(
                    _mm_unpackhi_pd(self.0, self.0),
                    _mm_unpackhi_pd(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn swap_pairs(self) -> F64x4 {
            unsafe {
                F64x4(
                    _mm_shuffle_pd::<0b01>(self.0, self.0),
                    _mm_shuffle_pd::<0b01>(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn neg_even(self) -> F64x4 {
            unsafe {
                let m = _mm_set_pd(0.0, -0.0);
                F64x4(_mm_xor_pd(self.0, m), _mm_xor_pd(self.1, m))
            }
        }

        #[inline(always)]
        fn unzip(a: F64x4, b: F64x4) -> (F64x4, F64x4) {
            unsafe {
                (
                    F64x4(
                        _mm_unpacklo_pd(a.0, a.1),
                        _mm_unpacklo_pd(b.0, b.1),
                    ),
                    F64x4(
                        _mm_unpackhi_pd(a.0, a.1),
                        _mm_unpackhi_pd(b.0, b.1),
                    ),
                )
            }
        }
    }

    /// Eight f32 lanes as two SSE2 `__m128` halves.
    #[derive(Clone, Copy)]
    pub struct F32x8(__m128, __m128);

    impl Add for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn add(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(_mm_add_ps(self.0, o.0), _mm_add_ps(self.1, o.1)) }
        }
    }

    impl Sub for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn sub(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(_mm_sub_ps(self.0, o.0), _mm_sub_ps(self.1, o.1)) }
        }
    }

    impl Mul for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn mul(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(_mm_mul_ps(self.0, o.0), _mm_mul_ps(self.1, o.1)) }
        }
    }

    impl SimdLanes for F32x8 {
        type Elem = f32;
        const LANES: usize = 8;

        #[inline(always)]
        fn splat(v: f32) -> F32x8 {
            unsafe { F32x8(_mm_set1_ps(v), _mm_set1_ps(v)) }
        }

        #[inline(always)]
        fn load(src: &[f32]) -> F32x8 {
            assert!(src.len() >= 8);
            unsafe {
                F32x8(
                    _mm_loadu_ps(src.as_ptr()),
                    _mm_loadu_ps(src.as_ptr().add(4)),
                )
            }
        }

        #[inline(always)]
        fn store(self, dst: &mut [f32]) {
            assert!(dst.len() >= 8);
            unsafe {
                _mm_storeu_ps(dst.as_mut_ptr(), self.0);
                _mm_storeu_ps(dst.as_mut_ptr().add(4), self.1);
            }
        }

        #[inline(always)]
        fn dup_even(self) -> F32x8 {
            unsafe {
                F32x8(
                    _mm_shuffle_ps::<0xA0>(self.0, self.0),
                    _mm_shuffle_ps::<0xA0>(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn dup_odd(self) -> F32x8 {
            unsafe {
                F32x8(
                    _mm_shuffle_ps::<0xF5>(self.0, self.0),
                    _mm_shuffle_ps::<0xF5>(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn swap_pairs(self) -> F32x8 {
            unsafe {
                F32x8(
                    _mm_shuffle_ps::<0xB1>(self.0, self.0),
                    _mm_shuffle_ps::<0xB1>(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn neg_even(self) -> F32x8 {
            unsafe {
                let m = _mm_set_ps(0.0, -0.0, 0.0, -0.0);
                F32x8(_mm_xor_ps(self.0, m), _mm_xor_ps(self.1, m))
            }
        }

        #[inline(always)]
        fn unzip(a: F32x8, b: F32x8) -> (F32x8, F32x8) {
            unsafe {
                (
                    F32x8(
                        _mm_shuffle_ps::<0x88>(a.0, a.1),
                        _mm_shuffle_ps::<0x88>(b.0, b.1),
                    ),
                    F32x8(
                        _mm_shuffle_ps::<0xDD>(a.0, a.1),
                        _mm_shuffle_ps::<0xDD>(b.0, b.1),
                    ),
                )
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::SimdLanes;
    use core::arch::aarch64::*;
    use std::ops::{Add, Mul, Sub};

    /// Four f64 lanes as two NEON `float64x2_t` halves.
    #[derive(Clone, Copy)]
    pub struct F64x4(float64x2_t, float64x2_t);

    impl Add for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn add(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(vaddq_f64(self.0, o.0), vaddq_f64(self.1, o.1)) }
        }
    }

    impl Sub for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn sub(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(vsubq_f64(self.0, o.0), vsubq_f64(self.1, o.1)) }
        }
    }

    impl Mul for F64x4 {
        type Output = F64x4;
        #[inline(always)]
        fn mul(self, o: F64x4) -> F64x4 {
            unsafe { F64x4(vmulq_f64(self.0, o.0), vmulq_f64(self.1, o.1)) }
        }
    }

    impl SimdLanes for F64x4 {
        type Elem = f64;
        const LANES: usize = 4;

        #[inline(always)]
        fn splat(v: f64) -> F64x4 {
            unsafe { F64x4(vdupq_n_f64(v), vdupq_n_f64(v)) }
        }

        #[inline(always)]
        fn load(src: &[f64]) -> F64x4 {
            assert!(src.len() >= 4);
            unsafe {
                F64x4(vld1q_f64(src.as_ptr()), vld1q_f64(src.as_ptr().add(2)))
            }
        }

        #[inline(always)]
        fn store(self, dst: &mut [f64]) {
            assert!(dst.len() >= 4);
            unsafe {
                vst1q_f64(dst.as_mut_ptr(), self.0);
                vst1q_f64(dst.as_mut_ptr().add(2), self.1);
            }
        }

        #[inline(always)]
        fn dup_even(self) -> F64x4 {
            unsafe {
                F64x4(vtrn1q_f64(self.0, self.0), vtrn1q_f64(self.1, self.1))
            }
        }

        #[inline(always)]
        fn dup_odd(self) -> F64x4 {
            unsafe {
                F64x4(vtrn2q_f64(self.0, self.0), vtrn2q_f64(self.1, self.1))
            }
        }

        #[inline(always)]
        fn swap_pairs(self) -> F64x4 {
            unsafe {
                F64x4(
                    vextq_f64::<1>(self.0, self.0),
                    vextq_f64::<1>(self.1, self.1),
                )
            }
        }

        #[inline(always)]
        fn neg_even(self) -> F64x4 {
            unsafe {
                let mask = [0x8000_0000_0000_0000u64, 0u64];
                let m = vld1q_u64(mask.as_ptr());
                let flip = |v: float64x2_t| {
                    vreinterpretq_f64_u64(veorq_u64(
                        vreinterpretq_u64_f64(v),
                        m,
                    ))
                };
                F64x4(flip(self.0), flip(self.1))
            }
        }

        #[inline(always)]
        fn unzip(a: F64x4, b: F64x4) -> (F64x4, F64x4) {
            unsafe {
                (
                    F64x4(vuzp1q_f64(a.0, a.1), vuzp1q_f64(b.0, b.1)),
                    F64x4(vuzp2q_f64(a.0, a.1), vuzp2q_f64(b.0, b.1)),
                )
            }
        }
    }

    /// Eight f32 lanes as two NEON `float32x4_t` halves.
    #[derive(Clone, Copy)]
    pub struct F32x8(float32x4_t, float32x4_t);

    impl Add for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn add(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(vaddq_f32(self.0, o.0), vaddq_f32(self.1, o.1)) }
        }
    }

    impl Sub for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn sub(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(vsubq_f32(self.0, o.0), vsubq_f32(self.1, o.1)) }
        }
    }

    impl Mul for F32x8 {
        type Output = F32x8;
        #[inline(always)]
        fn mul(self, o: F32x8) -> F32x8 {
            unsafe { F32x8(vmulq_f32(self.0, o.0), vmulq_f32(self.1, o.1)) }
        }
    }

    impl SimdLanes for F32x8 {
        type Elem = f32;
        const LANES: usize = 8;

        #[inline(always)]
        fn splat(v: f32) -> F32x8 {
            unsafe { F32x8(vdupq_n_f32(v), vdupq_n_f32(v)) }
        }

        #[inline(always)]
        fn load(src: &[f32]) -> F32x8 {
            assert!(src.len() >= 8);
            unsafe {
                F32x8(vld1q_f32(src.as_ptr()), vld1q_f32(src.as_ptr().add(4)))
            }
        }

        #[inline(always)]
        fn store(self, dst: &mut [f32]) {
            assert!(dst.len() >= 8);
            unsafe {
                vst1q_f32(dst.as_mut_ptr(), self.0);
                vst1q_f32(dst.as_mut_ptr().add(4), self.1);
            }
        }

        #[inline(always)]
        fn dup_even(self) -> F32x8 {
            unsafe {
                F32x8(vtrn1q_f32(self.0, self.0), vtrn1q_f32(self.1, self.1))
            }
        }

        #[inline(always)]
        fn dup_odd(self) -> F32x8 {
            unsafe {
                F32x8(vtrn2q_f32(self.0, self.0), vtrn2q_f32(self.1, self.1))
            }
        }

        #[inline(always)]
        fn swap_pairs(self) -> F32x8 {
            unsafe { F32x8(vrev64q_f32(self.0), vrev64q_f32(self.1)) }
        }

        #[inline(always)]
        fn neg_even(self) -> F32x8 {
            unsafe {
                let mask = [0x8000_0000u32, 0, 0x8000_0000, 0];
                let m = vld1q_u32(mask.as_ptr());
                let flip = |v: float32x4_t| {
                    vreinterpretq_f32_u32(veorq_u32(
                        vreinterpretq_u32_f32(v),
                        m,
                    ))
                };
                F32x8(flip(self.0), flip(self.1))
            }
        }

        #[inline(always)]
        fn unzip(a: F32x8, b: F32x8) -> (F32x8, F32x8) {
            unsafe {
                (
                    F32x8(vuzp1q_f32(a.0, a.1), vuzp1q_f32(b.0, b.1)),
                    F32x8(vuzp2q_f32(a.0, a.1), vuzp2q_f32(b.0, b.1)),
                )
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
pub use sse2::{F32x8, F64x4};

#[cfg(target_arch = "aarch64")]
pub use neon::{F32x8, F64x4};

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub use scalar::{ScalarF32x8 as F32x8, ScalarF64x4 as F64x4};

#[cfg(test)]
mod tests {
    use super::scalar::{ScalarF32x8, ScalarF64x4};
    use super::{F32x8, F64x4, SimdLanes};

    /// Bit-exact comparison that treats any-NaN-vs-any-NaN as equal (the
    /// payload of a NaN produced by an arithmetic op is implementation
    /// flavored; everything else must match to the last bit).
    fn same_f64(a: f64, b: f64) -> bool {
        (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits()
    }

    fn same_f32(a: f32, b: f32) -> bool {
        (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits()
    }

    const TRICKY64: [f64; 8] = [
        1.5,
        -2.25,
        0.0,
        -0.0,
        f64::NAN,
        f64::INFINITY,
        f64::MIN_POSITIVE / 4.0, // denormal
        -1.0e-300,
    ];

    #[test]
    fn f64x4_ops_bit_match_scalar_oracle() {
        for off in 0..TRICKY64.len() {
            let a: Vec<f64> =
                (0..4).map(|k| TRICKY64[(off + k) % TRICKY64.len()]).collect();
            let b: Vec<f64> = (0..4)
                .map(|k| TRICKY64[(off + k + 3) % TRICKY64.len()])
                .collect();
            let (va, vb) = (F64x4::load(&a), F64x4::load(&b));
            let (sa, sb) = (ScalarF64x4::load(&a), ScalarF64x4::load(&b));
            let cases: [(Vec<f64>, Vec<f64>, &str); 8] = [
                ((va + vb).to_vec(), (sa + sb).to_vec(), "add"),
                ((va - vb).to_vec(), (sa - sb).to_vec(), "sub"),
                ((va * vb).to_vec(), (sa * sb).to_vec(), "mul"),
                (va.dup_even().to_vec(), sa.dup_even().to_vec(), "dup_even"),
                (va.dup_odd().to_vec(), sa.dup_odd().to_vec(), "dup_odd"),
                (va.swap_pairs().to_vec(), sa.swap_pairs().to_vec(), "swap"),
                (va.neg_even().to_vec(), sa.neg_even().to_vec(), "neg_even"),
                (
                    va.complex_mul(vb).to_vec(),
                    sa.complex_mul(sb).to_vec(),
                    "complex_mul",
                ),
            ];
            for (got, want, op) in &cases {
                for (g, w) in got.iter().zip(want) {
                    assert!(same_f64(*g, *w), "{op}: {g:e} vs {w:e}");
                }
            }
            let (ge, go) = F64x4::unzip(va, vb);
            let (we, wo) = ScalarF64x4::unzip(sa, sb);
            for (g, w) in ge.to_vec().iter().zip(&we.to_vec()) {
                assert!(same_f64(*g, *w), "unzip evens");
            }
            for (g, w) in go.to_vec().iter().zip(&wo.to_vec()) {
                assert!(same_f64(*g, *w), "unzip odds");
            }
        }
    }

    #[test]
    fn f32x8_ops_bit_match_scalar_oracle() {
        let tricky: [f32; 8] = [
            1.5,
            -2.25,
            0.0,
            -0.0,
            f32::NAN,
            f32::INFINITY,
            f32::MIN_POSITIVE / 4.0,
            -1.0e-38,
        ];
        for off in 0..tricky.len() {
            let a: Vec<f32> =
                (0..8).map(|k| tricky[(off + k) % tricky.len()]).collect();
            let b: Vec<f32> =
                (0..8).map(|k| tricky[(off + k + 5) % tricky.len()]).collect();
            let (va, vb) = (F32x8::load(&a), F32x8::load(&b));
            let (sa, sb) = (ScalarF32x8::load(&a), ScalarF32x8::load(&b));
            let cases: [(Vec<f32>, Vec<f32>, &str); 8] = [
                ((va + vb).to_vec(), (sa + sb).to_vec(), "add"),
                ((va - vb).to_vec(), (sa - sb).to_vec(), "sub"),
                ((va * vb).to_vec(), (sa * sb).to_vec(), "mul"),
                (va.dup_even().to_vec(), sa.dup_even().to_vec(), "dup_even"),
                (va.dup_odd().to_vec(), sa.dup_odd().to_vec(), "dup_odd"),
                (va.swap_pairs().to_vec(), sa.swap_pairs().to_vec(), "swap"),
                (va.neg_even().to_vec(), sa.neg_even().to_vec(), "neg_even"),
                (
                    va.complex_mul(vb).to_vec(),
                    sa.complex_mul(sb).to_vec(),
                    "complex_mul",
                ),
            ];
            for (got, want, op) in &cases {
                for (g, w) in got.iter().zip(want) {
                    assert!(same_f32(*g, *w), "{op}: {g:e} vs {w:e}");
                }
            }
            let (ge, go) = F32x8::unzip(va, vb);
            let (we, wo) = ScalarF32x8::unzip(sa, sb);
            for (g, w) in ge.to_vec().iter().zip(&we.to_vec()) {
                assert!(same_f32(*g, *w), "unzip evens");
            }
            for (g, w) in go.to_vec().iter().zip(&wo.to_vec()) {
                assert!(same_f32(*g, *w), "unzip odds");
            }
        }
    }

    #[test]
    fn complex_mul_matches_complex_arithmetic() {
        // [re0, im0, re1, im1] pairs against the scalar complex product
        let a = [1.5f64, -2.0, 0.25, 3.0];
        let b = [-0.5f64, 4.0, 2.0, -1.5];
        let got = F64x4::load(&a).complex_mul(F64x4::load(&b)).to_vec();
        for k in 0..2 {
            let (ar, ai) = (a[2 * k], a[2 * k + 1]);
            let (br, bi) = (b[2 * k], b[2 * k + 1]);
            assert_eq!(got[2 * k], ar * br - ai * bi);
            assert_eq!(got[2 * k + 1], ar * bi + ai * br);
        }
    }

    #[test]
    fn splat_fills_all_lanes() {
        assert_eq!(F64x4::splat(2.5).to_vec(), vec![2.5; 4]);
        assert_eq!(F32x8::splat(-1.25).to_vec(), vec![-1.25f32; 8]);
    }
}
