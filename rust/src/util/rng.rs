//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! The `rand` crate is unavailable offline; this is the standard public
//! domain construction (Blackman & Vigna), plus the helpers we need
//! (uniform ranges, normals via Box-Muller, shuffles).

/// xoshiro256++ with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached spare normal from Box-Muller
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// f32 normals (for feeding the f32 PJRT executables).
    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Random unit 3-vector.
    pub fn unit3(&mut self) -> [f64; 3] {
        loop {
            let v = [self.normal(), self.normal(), self.normal()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            if n > 1e-9 {
                return [v[0] / n, v[1] / n, v[2] / n];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs = r.normals(n);
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn unit3_normalized() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let u = r.unit3();
            let n = (u[0] * u[0] + u[1] * u[1] + u[2] * u[2]).sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }
}
