//! Scoped worker pool (std::thread based; rayon is unavailable offline).
//!
//! The one parallel shape this crate needs: shard the rows of a row-major
//! output buffer across cores, each worker filling a disjoint chunk of
//! rows.  Built on `std::thread::scope`, so workers may borrow the plans
//! and input slices of the caller without `'static` bounds, and every
//! worker is joined before the call returns (no detached threads, no
//! channels on the hot path).

/// Number of hardware threads available to this process (>= 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested worker count: `0` means "all available cores".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

/// Fill the rows of `out` (row-major, `row_len` wide) in parallel:
/// `work(row_index, out_row)` is invoked exactly once per row, sharded
/// contiguously across at most `threads` scoped workers.  Rows are
/// disjoint `&mut` chunks, so workers never contend on the output, and
/// determinism is exact: the result is identical to the serial loop.
pub fn shard_rows<F>(out: &mut [f64], row_len: usize, threads: usize, work: F)
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    shard_rows_with(out, row_len, threads, || (), |r, row, _state| {
        work(r, row)
    });
}

/// [`shard_rows`] with per-worker mutable state: `init()` runs once on
/// each worker (and once on the caller for the serial path) to build a
/// private state value — typically a plan scratch — which is then passed
/// to every `work(row_index, out_row, &mut state)` call that worker
/// executes.  This is how batched tensor products stay allocation-free
/// in steady state: the scratch is allocated once per worker, not once
/// per row, and workers never share it.
pub fn shard_rows_with<S, I, F>(
    out: &mut [f64], row_len: usize, threads: usize, init: I, work: F,
) where
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut [f64], &mut S) + Sync,
{
    assert!(row_len > 0, "shard_rows: row_len must be positive");
    debug_assert_eq!(out.len() % row_len, 0);
    let rows = out.len() / row_len;
    if rows == 0 {
        return;
    }
    let threads = threads.clamp(1, rows);
    if threads == 1 {
        let mut state = init();
        for (r, row) in out.chunks_mut(row_len).enumerate() {
            work(r, row, &mut state);
        }
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let work = &work;
    let init = &init;
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(chunk_rows * row_len).enumerate() {
            s.spawn(move || {
                let base = ci * chunk_rows;
                let mut state = init();
                for (i, row) in chunk.chunks_mut(row_len).enumerate() {
                    work(base + i, row, &mut state);
                }
            });
        }
    });
}

/// Shard the index range `0..n` into contiguous blocks across at most
/// `threads` scoped workers.  Each worker builds one private accumulator
/// with `init()` and folds every index of its block into it with
/// `work(index, &mut acc)`; the accumulators come back in block order,
/// so concatenating them is deterministic for a fixed thread count.
/// This is the cell-block sharding shape of the periodic neighbor
/// builder: the grid is read-only, the per-block edge vectors are
/// private, and no index is visited twice.
pub fn shard_range<S, I, F>(n: usize, threads: usize, init: I, work: F) -> Vec<S>
where
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        let mut acc = init();
        for i in 0..n {
            work(i, &mut acc);
        }
        return vec![acc];
    }
    let chunk = n.div_ceil(threads);
    let n_blocks = n.div_ceil(chunk);
    let work = &work;
    let init = &init;
    let mut out: Vec<Option<S>> = (0..n_blocks).map(|_| None).collect();
    std::thread::scope(|s| {
        for (bi, slot) in out.iter_mut().enumerate() {
            s.spawn(move || {
                let mut acc = init();
                let lo = bi * chunk;
                let hi = (lo + chunk).min(n);
                for i in lo..hi {
                    work(i, &mut acc);
                }
                *slot = Some(acc);
            });
        }
    });
    out.into_iter().map(|s| s.expect("worker filled its slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(rows: usize, row_len: usize, threads: usize) -> Vec<f64> {
        let mut out = vec![0.0; rows * row_len];
        shard_rows(&mut out, row_len, threads, |r, row| {
            for (k, v) in row.iter_mut().enumerate() {
                *v = (r * row_len + k) as f64;
            }
        });
        out
    }

    #[test]
    fn matches_serial_for_any_thread_count() {
        let want = run(13, 5, 1);
        for threads in [0usize, 2, 3, 4, 7, 13, 64] {
            assert_eq!(run(13, 5, threads), want, "threads={threads}");
        }
    }

    #[test]
    fn every_row_visited_exactly_once() {
        let rows = 29;
        let mut out = vec![0.0; rows * 2];
        shard_rows(&mut out, 2, 4, |r, row| {
            row[0] += 1.0;
            row[1] = r as f64;
        });
        for r in 0..rows {
            assert_eq!(out[2 * r], 1.0, "row {r} visited more than once");
            assert_eq!(out[2 * r + 1], r as f64);
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let mut out: Vec<f64> = Vec::new();
        shard_rows(&mut out, 3, 8, |_, _| panic!("no rows to visit"));
        assert_eq!(run(1, 4, 8), run(1, 4, 1));
    }

    #[test]
    fn per_worker_state_initialized_once_per_worker() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inits = AtomicUsize::new(0);
        let rows = 16usize;
        let mut out = vec![0.0; rows * 2];
        shard_rows_with(
            &mut out,
            2,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                vec![0.0f64; 8] // stand-in for a plan scratch
            },
            |r, row, state| {
                state[0] += 1.0; // rows on one worker share the state
                row[0] = r as f64;
                row[1] = state[0];
            },
        );
        // one init per worker, not per row
        assert!(inits.load(Ordering::Relaxed) <= 4);
        for r in 0..rows {
            assert_eq!(out[2 * r], r as f64);
            assert!(out[2 * r + 1] >= 1.0);
        }
    }

    #[test]
    fn shard_range_covers_every_index_once() {
        for threads in [0usize, 1, 2, 3, 7, 16] {
            let blocks = shard_range(23, resolve_threads(threads), Vec::new,
                                     |i, acc: &mut Vec<usize>| acc.push(i));
            let mut all: Vec<usize> =
                blocks.into_iter().flatten().collect();
            // block order concatenation is already sorted for
            // contiguous blocks
            assert_eq!(all, (0..23).collect::<Vec<_>>(),
                       "threads={threads}");
            all.sort_unstable();
            assert_eq!(all, (0..23).collect::<Vec<_>>());
        }
        assert!(shard_range(0, 4, || 0u32, |_, _| {}).is_empty());
    }

    #[test]
    fn thread_helpers() {
        assert!(default_threads() >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), default_threads());
    }
}
