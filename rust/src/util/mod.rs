//! Infrastructure substrates the offline environment lacks as crates:
//! PRNG, JSON, a mini property-testing driver, a micro-bench harness,
//! error handling ([`error`], no external error crate), and a scoped
//! worker pool ([`pool`], replacing rayon for the one shape we need).

pub mod bench;
pub mod error;
pub mod failpoint;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod sync;
