//! Infrastructure substrates the offline environment lacks as crates:
//! PRNG, JSON, a mini property-testing driver, and a micro-bench harness.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
