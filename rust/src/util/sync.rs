//! Poison-recovering lock helpers.
//!
//! A `Mutex`/`RwLock` is *poisoned* when a thread panics while holding
//! it; every later `lock().unwrap()` then panics too, cascading one
//! worker's panic into a bricked service.  For the serving runtime the
//! data under these locks stays structurally valid across an unwind —
//! queues of owned `Pending`s (whose reply-on-drop guards already fired
//! for anything mid-flight), `Arc` swaps, counter maps — so the right
//! recovery is to take the guard anyway and keep serving.  These
//! helpers centralize the `unwrap_or_else(PoisonError::into_inner)`
//! idiom so no lock in `coordinator` ever re-panics on poison.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard,
    RwLockWriteGuard, WaitTimeoutResult,
};
use std::time::Duration;

/// `Mutex::lock` that recovers from poisoning instead of panicking.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `RwLock::read` with poison recovery.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// `RwLock::write` with poison recovery.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` with poison recovery.
pub fn cv_wait<'a, T>(
    cv: &Condvar, g: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` with poison recovery.
pub fn cv_wait_timeout<'a, T>(
    cv: &Condvar, g: MutexGuard<'a, T>, d: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(g, d).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(41));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(7));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(*read(&l), 7);
        *write(&l) = 8;
        assert_eq!(*read(&l), 8);
    }
}
