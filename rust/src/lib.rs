//! # gaunt-tp
//!
//! Production-oriented reproduction of *"Enabling Efficient Equivariant
//! Operations in the Fourier Basis via Gaunt Tensor Products"* (ICLR 2024).
//!
//! Three layers (see DESIGN.md):
//!
//! * **Layer 1/2** (build-time Python): Pallas kernels + JAX models, AOT
//!   lowered to HLO text in `artifacts/` by `python/compile/aot.py`.
//! * **Layer 3** (this crate): the runtime — a PJRT engine that loads the
//!   artifacts ([`runtime`]), a serving coordinator with dynamic batching
//!   ([`coordinator`]), and a complete *native* implementation of the
//!   paper's math ([`so3`], [`fourier`], [`tp`]) used as an independent
//!   correctness oracle and as the benchmark substrate for every figure
//!   and table in the paper.
//!
//! The force-field workload is opened end-to-end by [`model`]: a
//! MACE-style equivariant message-passing model whose every contraction
//! (edge convolution, many-body products, readout, and all backward
//! passes) runs on the planned Gaunt engine — trained by
//! [`coordinator::trainer::NativeTrainer`], driven in MD through
//! [`md::potential::LearnedPotential`], and served batched+multi-threaded
//! by the native backend.
//!
//! Simulation substrates the evaluation needs ([`md`], [`nbody`]) are
//! implemented from scratch, as are the infrastructure pieces the offline
//! environment lacks ([`util`]: PRNG, JSON, property testing, benching,
//! error handling, worker pool) and the typed seam standing in for the
//! native XLA/PJRT bindings ([`xla`], see DESIGN.md section 5).
//!
//! The crate builds with **zero external dependencies** so `cargo build`
//! works from a clean checkout with no network; serving-grade execution
//! (plan memoization + multi-threaded batched tensor products) lives in
//! [`tp::engine`].

pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fourier;
pub mod md;
pub mod model;
pub mod nbody;
pub mod net;
pub mod runtime;
pub mod so3;
pub mod tp;
pub mod util;
pub mod xla;

/// Flat irrep index of (l, m) in the `(L+1)^2` layout (m = -l..l).
#[inline]
pub fn lm_index(l: usize, m: i64) -> usize {
    debug_assert!(m.unsigned_abs() as usize <= l);
    l * l + (l as i64 + m) as usize
}

/// Dimension of a feature holding irreps of degree 0..=L.
#[inline]
pub fn num_coeffs(l_max: usize) -> usize {
    (l_max + 1) * (l_max + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_index_layout() {
        assert_eq!(lm_index(0, 0), 0);
        assert_eq!(lm_index(1, -1), 1);
        assert_eq!(lm_index(1, 0), 2);
        assert_eq!(lm_index(1, 1), 3);
        assert_eq!(lm_index(2, -2), 4);
        assert_eq!(lm_index(2, 2), 8);
    }

    #[test]
    fn num_coeffs_matches_sum() {
        for l in 0..8usize {
            let total: usize = (0..=l).map(|k| 2 * k + 1).sum();
            assert_eq!(num_coeffs(l), total);
        }
    }
}
