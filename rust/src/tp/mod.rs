//! Tensor products of irreps — the paper's subject.
//!
//! * [`cg`] — the O(L^6) Clebsch-Gordan full tensor product (the e3nn-style
//!   baseline of Fig. 1), dense and sparse variants.
//! * [`gaunt`] — the paper's O(L^3) Gaunt Tensor Product: per-|v| panel
//!   conversions + 2D convolution (direct or FFT).
//! * [`escn`] — Equivariant Convolutions (feature (x) SH filter): the eSCN
//!   SO(2)-restriction baseline and the Gaunt-accelerated variant
//!   (paper Sec. 3.3).
//! * [`many_body`] — Equivariant Many-body Interactions: nu-fold products,
//!   sequential vs divide-and-conquer grid-domain evaluation, plus the
//!   MACE-style precomputed-tensor emulation (trades memory for speed).
//! * [`engine`] — the serving-grade execution engine: a process-wide
//!   [`engine::PlanCache`] (build plans once, share under contention) and
//!   multi-threaded batched applies for all three plan families.

pub mod cg;
pub mod engine;
pub mod escn;
pub mod gaunt;
pub mod many_body;

pub use cg::CgPlan;
pub use engine::PlanCache;
pub use escn::{GauntConvPlan, GauntConvScratch};
pub use gaunt::{ConvMethod, GauntPlan, GauntScratch};
pub use many_body::{ManyBodyPlan, ManyBodyScratch};
