//! Tensor products of irreps — the paper's subject.
//!
//! * [`irreps`] — the typed `mul x l` feature layout ([`Irreps`]) every
//!   equivariant module declares its contract in.
//! * [`op`] — the unified [`EquivariantOp`] interface (typed layouts,
//!   caller-owned scratch, exact VJPs) all five plan families implement,
//!   plus the generic batched drivers.
//! * [`cg`] — the O(L^6) Clebsch-Gordan full tensor product (the e3nn-style
//!   baseline of Fig. 1), dense and sparse variants.
//! * [`gaunt`] — the paper's O(L^3) Gaunt Tensor Product: per-|v| panel
//!   conversions + 2D convolution (direct or FFT).
//! * [`escn`] — Equivariant Convolutions (feature (x) SH filter): the eSCN
//!   SO(2)-restriction baseline and the Gaunt-accelerated variant
//!   (paper Sec. 3.3).
//! * [`many_body`] — Equivariant Many-body Interactions: nu-fold products,
//!   sequential vs divide-and-conquer grid-domain evaluation, plus the
//!   MACE-style precomputed-tensor emulation (trades memory for speed).
//! * [`vector`] — vector-signal Gaunt products over vector spherical
//!   harmonics: scalar (x) vector, dot, and cross plans routing each
//!   Cartesian component through the same O(L^3) scalar pipeline.
//! * [`engine`] — the serving-grade execution engine: a process-wide
//!   [`engine::PlanCache`] keyed by [`OpKey`], resolving any key to a
//!   shared `Arc<dyn EquivariantOp>` with per-key hit statistics.

pub mod cg;
pub mod engine;
pub mod escn;
pub mod gaunt;
pub mod gaunt32;
pub mod irreps;
pub mod many_body;
pub mod op;
pub mod vector;

pub use cg::CgPlan;
pub use engine::{CacheStats, OpKey, PlanCache, Precision};
pub use escn::{EscnPlan, EscnScratch, GauntConvPlan, GauntConvScratch};
pub use gaunt::{ConvMethod, GauntPlan, GauntScratch};
pub use gaunt32::{Gaunt32Plan, Gaunt32Scratch};
pub use irreps::{IrrepSeg, Irreps};
pub use many_body::{ManyBodyPlan, ManyBodyScratch};
pub use op::{
    apply_batch, apply_batch_par, BatchInputs, EquivariantOp, Inputs,
    OpScratch,
};
pub use vector::{
    NaiveVectorTp, VectorGauntPlan, VectorIrreps, VectorKind, VectorScratch,
};
