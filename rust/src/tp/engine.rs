//! Serving-grade batched tensor-product engine.
//!
//! Two pieces turn the one-shot plans of [`cg`](crate::tp::cg) /
//! [`gaunt`](crate::tp::gaunt) / [`escn`](crate::tp::escn) into something
//! a coordinator can run under heavy traffic:
//!
//! * [`PlanCache`] — a process-wide memo of built plans keyed by
//!   `(degrees, method)`.  Plan construction is the expensive part of a
//!   tensor product (tables, coupling tensors: milliseconds to seconds at
//!   high L); apply is microseconds.  e3nn-style systems win by compiling
//!   the coupling once — this is that, with build-once-under-contention
//!   semantics: concurrent requests for a missing key serialize on one
//!   build and share the resulting `Arc`.
//! * Parallel batch applies — [`gaunt_apply_batch_par`],
//!   [`cg_apply_batch_par`], [`escn_apply_batch_par`] shard independent
//!   batch rows across cores through [`crate::util::pool`], bitwise
//!   identical to the serial path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::num_coeffs;
use crate::tp::cg::CgPlan;
use crate::tp::escn::{EscnPlan, GauntConvPlan};
use crate::tp::gaunt::{ConvMethod, GauntPlan};
use crate::tp::many_body::ManyBodyPlan;
use crate::util::pool;

/// Cache key: plan family + the degrees (and conv method) that fully
/// determine a plan's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanKey {
    /// Clebsch-Gordan full TP plan.
    Cg { l1: usize, l2: usize, l3: usize },
    /// Gaunt TP plan (method changes the convolution backend).
    Gaunt { l1: usize, l2: usize, l3: usize, method: ConvMethod },
    /// eSCN SO(2)-restricted convolution plan.
    Escn { l_in: usize, l_filter: usize, l_out: usize },
    /// Gaunt-accelerated aligned-filter convolution plan (cached filter
    /// spectra live in the plan).
    GauntConv { l_in: usize, l_filter: usize, l_out: usize },
    /// Many-body Fourier-domain plan (single final-size transforms).
    ManyBody { nu: usize, l: usize, l_out: usize },
}

#[derive(Clone)]
enum CachedPlan {
    Cg(Arc<CgPlan>),
    Gaunt(Arc<GauntPlan>),
    Escn(Arc<EscnPlan>),
    GauntConv(Arc<GauntConvPlan>),
    ManyBody(Arc<ManyBodyPlan>),
}

/// Process-wide memo of tensor-product plans.
///
/// Reads take a shared lock (the hot path: one `HashMap` probe + `Arc`
/// clone).  A miss upgrades to the write lock, re-checks, and builds the
/// plan while holding it — exactly one thread builds each key under
/// contention.  Note the trade-off: a build stalls *all* cache reads for
/// its duration (high-L plans can take seconds), which is acceptable as
/// a cold-start cost today; if warm-path stalls ever matter, move to
/// per-key once-cells built outside the map lock.
pub struct PlanCache {
    plans: RwLock<HashMap<PlanKey, CachedPlan>>,
    builds: AtomicUsize,
    hits: AtomicUsize,
}

impl PlanCache {
    /// An empty cache (prefer [`PlanCache::global`] outside tests).
    pub fn new() -> PlanCache {
        PlanCache {
            plans: RwLock::new(HashMap::new()),
            builds: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// The process-wide cache used by the coordinator, experiments, and
    /// benches.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(PlanCache::new)
    }

    fn lookup(&self, key: &PlanKey) -> Option<CachedPlan> {
        let found = self.plans.read().unwrap().get(key).cloned();
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Memoized [`CgPlan`] for `(l1, l2, l3)`.
    pub fn cg(&self, l1: usize, l2: usize, l3: usize) -> Arc<CgPlan> {
        let key = PlanKey::Cg { l1, l2, l3 };
        if let Some(CachedPlan::Cg(p)) = self.lookup(&key) {
            return p;
        }
        let mut w = self.plans.write().unwrap();
        if let Some(CachedPlan::Cg(p)) = w.get(&key) {
            return p.clone();
        }
        let p = Arc::new(CgPlan::new(l1, l2, l3));
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, CachedPlan::Cg(p.clone()));
        p
    }

    /// Memoized [`GauntPlan`] for `(l1, l2, l3, method)`.
    pub fn gaunt(
        &self, l1: usize, l2: usize, l3: usize, method: ConvMethod,
    ) -> Arc<GauntPlan> {
        let key = PlanKey::Gaunt { l1, l2, l3, method };
        if let Some(CachedPlan::Gaunt(p)) = self.lookup(&key) {
            return p;
        }
        let mut w = self.plans.write().unwrap();
        if let Some(CachedPlan::Gaunt(p)) = w.get(&key) {
            return p.clone();
        }
        let p = Arc::new(GauntPlan::new(l1, l2, l3, method));
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, CachedPlan::Gaunt(p.clone()));
        p
    }

    /// Memoized [`EscnPlan`] for `(l_in, l_filter, l_out)`.
    pub fn escn(
        &self, l_in: usize, l_filter: usize, l_out: usize,
    ) -> Arc<EscnPlan> {
        let key = PlanKey::Escn { l_in, l_filter, l_out };
        if let Some(CachedPlan::Escn(p)) = self.lookup(&key) {
            return p;
        }
        let mut w = self.plans.write().unwrap();
        if let Some(CachedPlan::Escn(p)) = w.get(&key) {
            return p.clone();
        }
        let p = Arc::new(EscnPlan::new(l_in, l_filter, l_out));
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, CachedPlan::Escn(p.clone()));
        p
    }

    /// Memoized [`GauntConvPlan`] for `(l_in, l_filter, l_out)`.
    pub fn gaunt_conv(
        &self, l_in: usize, l_filter: usize, l_out: usize,
    ) -> Arc<GauntConvPlan> {
        let key = PlanKey::GauntConv { l_in, l_filter, l_out };
        if let Some(CachedPlan::GauntConv(p)) = self.lookup(&key) {
            return p;
        }
        let mut w = self.plans.write().unwrap();
        if let Some(CachedPlan::GauntConv(p)) = w.get(&key) {
            return p.clone();
        }
        let p = Arc::new(GauntConvPlan::new(l_in, l_filter, l_out));
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, CachedPlan::GauntConv(p.clone()));
        p
    }

    /// Memoized [`ManyBodyPlan`] for `(nu, l, l_out)`.
    pub fn many_body(
        &self, nu: usize, l: usize, l_out: usize,
    ) -> Arc<ManyBodyPlan> {
        // ManyBodyPlan::new asserts on these; fail here, BEFORE the
        // write lock, so a bad request cannot poison the shared cache
        assert!(
            nu >= 1 && l_out <= nu * l,
            "many_body plan: need nu >= 1 and l_out <= nu*l \
             (got nu={nu}, l={l}, l_out={l_out})"
        );
        let key = PlanKey::ManyBody { nu, l, l_out };
        if let Some(CachedPlan::ManyBody(p)) = self.lookup(&key) {
            return p;
        }
        let mut w = self.plans.write().unwrap();
        if let Some(CachedPlan::ManyBody(p)) = w.get(&key) {
            return p.clone();
        }
        let p = Arc::new(ManyBodyPlan::new(nu, l, l_out));
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, CachedPlan::ManyBody(p.clone()));
        p
    }

    /// Number of plans actually constructed (one per distinct key, even
    /// under contention).
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// Number of read-path hits served without building.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (outstanding `Arc`s stay valid).
    pub fn clear(&self) {
        self.plans.write().unwrap().clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

/// Batched Gaunt TP sharded across `threads` workers (`0` = all cores).
/// Row-for-row identical to [`GauntPlan::apply_batch`].
///
/// Workers share the plan's read-only tables and each own one
/// [`GauntScratch`](crate::tp::gaunt::GauntScratch) (allocated once per
/// worker via [`pool::shard_rows_with`]), so the fused per-row apply has
/// zero steady-state allocations.
pub fn gaunt_apply_batch_par(
    plan: &GauntPlan, x1: &[f64], x2: &[f64], rows: usize, threads: usize,
) -> Vec<f64> {
    let n1 = num_coeffs(plan.l1);
    let n2 = num_coeffs(plan.l2);
    let n3 = num_coeffs(plan.l3);
    debug_assert_eq!(x1.len(), rows * n1);
    debug_assert_eq!(x2.len(), rows * n2);
    let mut out = vec![0.0; rows * n3];
    let threads = pool::resolve_threads(threads);
    pool::shard_rows_with(
        &mut out,
        n3,
        threads,
        || plan.scratch(),
        |r, row, scratch| {
            plan.apply_into(
                &x1[r * n1..(r + 1) * n1],
                &x2[r * n2..(r + 1) * n2],
                row,
                scratch,
            );
        },
    );
    out
}

/// Batched sparse CG TP sharded across `threads` workers (`0` = all
/// cores).  Row-for-row identical to [`CgPlan::apply_batch`].
pub fn cg_apply_batch_par(
    plan: &CgPlan, x1: &[f64], x2: &[f64], rows: usize, threads: usize,
) -> Vec<f64> {
    let n1 = num_coeffs(plan.l1);
    let n2 = num_coeffs(plan.l2);
    let n3 = num_coeffs(plan.l3);
    debug_assert_eq!(x1.len(), rows * n1);
    debug_assert_eq!(x2.len(), rows * n2);
    let mut out = vec![0.0; rows * n3];
    let threads = pool::resolve_threads(threads);
    pool::shard_rows(&mut out, n3, threads, |r, row| {
        let y = plan
            .apply_sparse(&x1[r * n1..(r + 1) * n1], &x2[r * n2..(r + 1) * n2]);
        row.copy_from_slice(&y);
    });
    out
}

/// Batched Gaunt-accelerated edge convolution sharded across `threads`
/// workers (`0` = all cores): row `r` convolves `x[r]` along `dirs[r]`
/// with shared per-degree filter weights `h2`, through the plan's cached
/// aligned-filter spectra.  Each worker owns one
/// [`GauntConvScratch`](crate::tp::escn::GauntConvScratch), so the
/// aligned-frame contraction AND the per-edge Wigner rotation round
/// trip are allocation-free per row (only the per-row output `Vec` of
/// `apply_with` remains).
pub fn gaunt_conv_apply_batch_par(
    plan: &GauntConvPlan, x: &[f64], dirs: &[[f64; 3]], h2: &[f64],
    threads: usize,
) -> Vec<f64> {
    let n_in = num_coeffs(plan.l_in);
    let n_out = num_coeffs(plan.l_out);
    let rows = dirs.len();
    debug_assert_eq!(x.len(), rows * n_in);
    let mut out = vec![0.0; rows * n_out];
    let threads = pool::resolve_threads(threads);
    pool::shard_rows_with(
        &mut out,
        n_out,
        threads,
        || plan.scratch(),
        |r, row, scratch| {
            let y = plan.apply_with(
                &x[r * n_in..(r + 1) * n_in], dirs[r], h2, scratch,
            );
            row.copy_from_slice(&y);
        },
    );
    out
}

/// Batched eSCN edge convolution sharded across `threads` workers (`0` =
/// all cores): row `r` convolves `x[r]` along `dirs[r]` with shared path
/// weights `h`.  Row-for-row identical to [`EscnPlan::apply_batch`].
pub fn escn_apply_batch_par(
    plan: &EscnPlan, x: &[f64], dirs: &[[f64; 3]], h: &[f64], threads: usize,
) -> Vec<f64> {
    let n_in = num_coeffs(plan.l_in);
    let n_out = num_coeffs(plan.l_out);
    let rows = dirs.len();
    debug_assert_eq!(x.len(), rows * n_in);
    let mut out = vec![0.0; rows * n_out];
    let threads = pool::resolve_threads(threads);
    pool::shard_rows(&mut out, n_out, threads, |r, row| {
        let y = plan.apply(&x[r * n_in..(r + 1) * n_in], dirs[r], h);
        row.copy_from_slice(&y);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn cache_returns_shared_plans_and_counts_builds() {
        let cache = PlanCache::new();
        let a = cache.gaunt(2, 2, 2, ConvMethod::Direct);
        let b = cache.gaunt(2, 2, 2, ConvMethod::Direct);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.builds(), 1);
        assert!(cache.hits() >= 1);
        // a different method is a different key
        let c = cache.gaunt(2, 2, 2, ConvMethod::Fft);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.builds(), 2);
        let _ = cache.cg(1, 1, 2);
        let _ = cache.escn(1, 1, 1);
        assert_eq!(cache.builds(), 4);
        assert_eq!(cache.len(), 4);
        cache.clear();
        assert!(cache.is_empty());
        // outstanding Arcs survive the clear
        let mut rng = Rng::new(0);
        let x = rng.normals(num_coeffs(2));
        let y = rng.normals(num_coeffs(2));
        assert_eq!(a.apply(&x, &y).len(), num_coeffs(2));
    }

    #[test]
    fn gaunt_par_matches_serial() {
        let mut rng = Rng::new(1);
        let plan = GauntPlan::new(2, 2, 3, ConvMethod::Auto);
        let rows = 9;
        let x1 = rng.normals(rows * num_coeffs(2));
        let x2 = rng.normals(rows * num_coeffs(2));
        let serial = plan.apply_batch(&x1, &x2, rows);
        for threads in [1usize, 2, 4, 0] {
            let par = gaunt_apply_batch_par(&plan, &x1, &x2, rows, threads);
            assert!(max_abs_diff(&serial, &par) == 0.0, "threads={threads}");
        }
    }

    #[test]
    fn cg_par_matches_serial() {
        let mut rng = Rng::new(2);
        let plan = CgPlan::new(2, 2, 2);
        let rows = 7;
        let n = num_coeffs(2);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let serial = plan.apply_batch(&x1, &x2, rows);
        let par = cg_apply_batch_par(&plan, &x1, &x2, rows, 0);
        assert!(max_abs_diff(&serial, &par) == 0.0);
    }

    #[test]
    fn gaunt_conv_and_many_body_plans_are_cached() {
        let cache = PlanCache::new();
        let a = cache.gaunt_conv(2, 2, 2);
        let b = cache.gaunt_conv(2, 2, 2);
        assert!(Arc::ptr_eq(&a, &b));
        let m1 = cache.many_body(3, 1, 2);
        let m2 = cache.many_body(3, 1, 2);
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(cache.builds(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn gaunt_conv_par_matches_serial() {
        let mut rng = Rng::new(4);
        let plan = GauntConvPlan::new(2, 2, 3);
        let rows = 6;
        let n = num_coeffs(2);
        let x = rng.normals(rows * n);
        let dirs: Vec<[f64; 3]> = (0..rows).map(|_| rng.unit3()).collect();
        let h2: Vec<f64> = (0..=2).map(|_| rng.normal()).collect();
        let mut serial = vec![0.0; rows * num_coeffs(3)];
        for (r, dir) in dirs.iter().enumerate() {
            let y = plan.apply(&x[r * n..(r + 1) * n], *dir, &h2);
            serial[r * y.len()..(r + 1) * y.len()].copy_from_slice(&y);
        }
        let par = gaunt_conv_apply_batch_par(&plan, &x, &dirs, &h2, 0);
        assert!(max_abs_diff(&serial, &par) == 0.0);
    }

    #[test]
    fn escn_par_matches_serial() {
        let mut rng = Rng::new(3);
        let plan = EscnPlan::new(2, 2, 2);
        let rows = 6;
        let n = num_coeffs(2);
        let x = rng.normals(rows * n);
        let dirs: Vec<[f64; 3]> = (0..rows).map(|_| rng.unit3()).collect();
        let h: Vec<f64> = (0..plan.n_paths()).map(|_| rng.normal()).collect();
        let serial = plan.apply_batch(&x, &dirs, &h);
        let par = escn_apply_batch_par(&plan, &x, &dirs, &h, 0);
        assert!(max_abs_diff(&serial, &par) == 0.0);
    }
}
