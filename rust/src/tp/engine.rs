//! Serving-grade plan cache for the unified equivariant-op layer.
//!
//! [`PlanCache`] is a process-wide memo of built plans keyed by
//! [`OpKey`] — plan construction is the expensive part of a tensor
//! product (tables, coupling tensors: milliseconds to seconds at high
//! L); apply is microseconds.  e3nn-style systems win by compiling the
//! coupling once — this is that, with build-once-under-contention
//! semantics: concurrent requests for a missing key serialize on one
//! build and share the resulting `Arc`.
//!
//! Every cached plan implements
//! [`EquivariantOp`](crate::tp::op::EquivariantOp), so callers that
//! don't care which family they run dispatch uniformly through
//! [`PlanCache::op`] and the generic batch drivers
//! ([`crate::tp::op::apply_batch_par`]); the typed accessors remain for
//! callers (the model) that need a concrete plan's extra surface.
//!
//! The cache keeps per-key hit counters ([`PlanCache::stats`]) so the
//! serving layer can observe plan churn (cold keys, unexpected rebuild
//! storms) through its metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::tp::cg::CgPlan;
use crate::tp::escn::{EscnPlan, GauntConvPlan};
use crate::tp::gaunt::{ConvMethod, GauntPlan};
use crate::tp::gaunt32::Gaunt32Plan;
use crate::tp::many_body::ManyBodyPlan;
use crate::tp::op::EquivariantOp;
use crate::tp::vector::{VectorGauntPlan, VectorKind};

/// Arithmetic precision an op family runs its interior in.  The API
/// surface is `f64` either way; `F32` plans cast at the boundary and run
/// transforms/contractions in single precision (serve fast, train
/// exact).  Only the Gaunt family has an `F32` lowering today — see
/// [`OpKey::with_precision`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Double precision end to end (the training default).
    #[default]
    F64,
    /// Single-precision interior behind the `f64` slice API (serving).
    F32,
}

/// Cache key: op family + the degrees (and conv method) that fully
/// determine a plan's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKey {
    /// Clebsch-Gordan full TP plan.
    Cg { l1: usize, l2: usize, l3: usize },
    /// Gaunt TP plan (method changes the convolution backend).
    Gaunt { l1: usize, l2: usize, l3: usize, method: ConvMethod },
    /// Gaunt TP plan with an f32 interior (serving precision mode); the
    /// conv method is always `Auto` — precision, not backend, is the
    /// knob here.
    GauntF32 { l1: usize, l2: usize, l3: usize },
    /// eSCN SO(2)-restricted convolution plan.
    Escn { l_in: usize, l_filter: usize, l_out: usize },
    /// Gaunt-accelerated aligned-filter convolution plan (cached filter
    /// spectra live in the plan).
    GauntConv { l_in: usize, l_filter: usize, l_out: usize },
    /// Many-body Fourier-domain plan (single final-size transforms).
    ManyBody { nu: usize, l: usize, l_out: usize },
    /// Vector-signal Gaunt plan (VSH tensor products; kind picks the
    /// scalar (x) vector / dot / cross path).
    Vector { kind: VectorKind, l1: usize, l2: usize, l3: usize,
             method: ConvMethod },
}

impl OpKey {
    /// The precision this key's plan runs its interior in.
    pub fn precision(&self) -> Precision {
        match self {
            OpKey::GauntF32 { .. } => Precision::F32,
            _ => Precision::F64,
        }
    }

    /// Re-key to the requested precision where the family supports it.
    ///
    /// `F32` lowers `Gaunt` keys to `GauntF32` (dropping the method —
    /// the f32 plan picks `Auto`); families without an f32 lowering are
    /// returned unchanged.  `F64` raises `GauntF32` back to
    /// `Gaunt { method: Auto }`.
    pub fn with_precision(self, p: Precision) -> OpKey {
        match (p, self) {
            (Precision::F32, OpKey::Gaunt { l1, l2, l3, .. }) => {
                OpKey::GauntF32 { l1, l2, l3 }
            }
            (Precision::F64, OpKey::GauntF32 { l1, l2, l3 }) => {
                OpKey::Gaunt { l1, l2, l3, method: ConvMethod::Auto }
            }
            (_, key) => key,
        }
    }
}

#[derive(Clone)]
enum CachedPlan {
    Cg(Arc<CgPlan>),
    Gaunt(Arc<GauntPlan>),
    GauntF32(Arc<Gaunt32Plan>),
    Escn(Arc<EscnPlan>),
    GauntConv(Arc<GauntConvPlan>),
    ManyBody(Arc<ManyBodyPlan>),
    Vector(Arc<VectorGauntPlan>),
}

struct Entry {
    plan: CachedPlan,
    hits: AtomicUsize,
}

/// One key's row in a [`PlanCache::stats`] snapshot.
#[derive(Clone, Copy, Debug)]
pub struct KeyStats {
    pub key: OpKey,
    pub hits: usize,
}

/// Point-in-time cache statistics.
#[derive(Clone, Debug, Default)]
pub struct CacheStats {
    /// plans actually constructed (one per distinct key)
    pub builds: usize,
    /// read-path hits served without building
    pub hits: usize,
    /// cached plans currently held
    pub len: usize,
    /// per-key hit counts, hottest first
    pub per_key: Vec<KeyStats>,
}

/// Process-wide memo of tensor-product plans.
///
/// Reads take a shared lock (the hot path: one `HashMap` probe + `Arc`
/// clone).  A miss upgrades to the write lock, re-checks, and builds the
/// plan while holding it — exactly one thread builds each key under
/// contention.  Note the trade-off: a build stalls *all* cache reads for
/// its duration (high-L plans can take seconds), which is acceptable as
/// a cold-start cost today; if warm-path stalls ever matter, move to
/// per-key once-cells built outside the map lock.
pub struct PlanCache {
    plans: RwLock<HashMap<OpKey, Entry>>,
    builds: AtomicUsize,
    hits: AtomicUsize,
}

impl PlanCache {
    /// An empty cache (prefer [`PlanCache::global`] outside tests).
    pub fn new() -> PlanCache {
        PlanCache {
            plans: RwLock::new(HashMap::new()),
            builds: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
        }
    }

    /// The process-wide cache used by the coordinator, experiments, and
    /// benches.
    pub fn global() -> &'static PlanCache {
        static GLOBAL: OnceLock<PlanCache> = OnceLock::new();
        GLOBAL.get_or_init(PlanCache::new)
    }

    /// The ONE memoization body every typed accessor shares: shared-lock
    /// probe (counting the hit), write-lock re-check (ALSO counted — a
    /// request served by another thread's fresh build is a hit), build
    /// + insert otherwise.
    fn get_or_build<T>(
        &self,
        key: OpKey,
        extract: impl Fn(&CachedPlan) -> Option<Arc<T>>,
        wrap: impl FnOnce(Arc<T>) -> CachedPlan,
        build: impl FnOnce() -> T,
    ) -> Arc<T> {
        {
            let guard = self.plans.read().unwrap();
            if let Some(e) = guard.get(&key) {
                if let Some(p) = extract(&e.plan) {
                    e.hits.fetch_add(1, Ordering::Relaxed);
                    drop(guard);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return p;
                }
            }
        }
        let mut w = self.plans.write().unwrap();
        if let Some(e) = w.get(&key) {
            if let Some(p) = extract(&e.plan) {
                e.hits.fetch_add(1, Ordering::Relaxed);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return p;
            }
        }
        let p = Arc::new(build());
        self.builds.fetch_add(1, Ordering::Relaxed);
        w.insert(key, Entry {
            plan: wrap(p.clone()),
            hits: AtomicUsize::new(0),
        });
        p
    }

    /// Memoized [`CgPlan`] for `(l1, l2, l3)`.
    pub fn cg(&self, l1: usize, l2: usize, l3: usize) -> Arc<CgPlan> {
        self.get_or_build(
            OpKey::Cg { l1, l2, l3 },
            |c| match c {
                CachedPlan::Cg(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::Cg,
            || CgPlan::new(l1, l2, l3),
        )
    }

    /// Memoized [`GauntPlan`] for `(l1, l2, l3, method)`.
    pub fn gaunt(
        &self, l1: usize, l2: usize, l3: usize, method: ConvMethod,
    ) -> Arc<GauntPlan> {
        self.get_or_build(
            OpKey::Gaunt { l1, l2, l3, method },
            |c| match c {
                CachedPlan::Gaunt(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::Gaunt,
            || GauntPlan::new(l1, l2, l3, method),
        )
    }

    /// Memoized [`Gaunt32Plan`] for `(l1, l2, l3)` (always `Auto`
    /// method — the f32 serving lowering of the Gaunt family).
    pub fn gaunt_f32(
        &self, l1: usize, l2: usize, l3: usize,
    ) -> Arc<Gaunt32Plan> {
        self.get_or_build(
            OpKey::GauntF32 { l1, l2, l3 },
            |c| match c {
                CachedPlan::GauntF32(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::GauntF32,
            || Gaunt32Plan::new(l1, l2, l3, ConvMethod::Auto),
        )
    }

    /// Memoized [`EscnPlan`] for `(l_in, l_filter, l_out)`.
    pub fn escn(
        &self, l_in: usize, l_filter: usize, l_out: usize,
    ) -> Arc<EscnPlan> {
        self.get_or_build(
            OpKey::Escn { l_in, l_filter, l_out },
            |c| match c {
                CachedPlan::Escn(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::Escn,
            || EscnPlan::new(l_in, l_filter, l_out),
        )
    }

    /// Memoized [`GauntConvPlan`] for `(l_in, l_filter, l_out)`.
    pub fn gaunt_conv(
        &self, l_in: usize, l_filter: usize, l_out: usize,
    ) -> Arc<GauntConvPlan> {
        self.get_or_build(
            OpKey::GauntConv { l_in, l_filter, l_out },
            |c| match c {
                CachedPlan::GauntConv(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::GauntConv,
            || GauntConvPlan::new(l_in, l_filter, l_out),
        )
    }

    /// Memoized [`ManyBodyPlan`] for `(nu, l, l_out)`.
    pub fn many_body(
        &self, nu: usize, l: usize, l_out: usize,
    ) -> Arc<ManyBodyPlan> {
        // ManyBodyPlan::new asserts on these; fail here, BEFORE the
        // write lock, so a bad request cannot poison the shared cache
        assert!(
            nu >= 1 && l_out <= nu * l,
            "many_body plan: need nu >= 1 and l_out <= nu*l \
             (got nu={nu}, l={l}, l_out={l_out})"
        );
        self.get_or_build(
            OpKey::ManyBody { nu, l, l_out },
            |c| match c {
                CachedPlan::ManyBody(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::ManyBody,
            || ManyBodyPlan::new(nu, l, l_out),
        )
    }

    /// Memoized [`VectorGauntPlan`] for `(kind, l1, l2, l3, method)`.
    pub fn vector(
        &self, kind: VectorKind, l1: usize, l2: usize, l3: usize,
        method: ConvMethod,
    ) -> Arc<VectorGauntPlan> {
        self.get_or_build(
            OpKey::Vector { kind, l1, l2, l3, method },
            |c| match c {
                CachedPlan::Vector(p) => Some(p.clone()),
                _ => None,
            },
            CachedPlan::Vector,
            || VectorGauntPlan::new(kind, l1, l2, l3, method),
        )
    }

    /// The uniform entry point: resolve ANY key to its cached plan as a
    /// type-erased [`EquivariantOp`].  Coordinator, benches, and CLI
    /// dispatch through this; the typed accessors above remain for
    /// callers that need a concrete plan's extra surface.
    pub fn op(&self, key: &OpKey) -> Arc<dyn EquivariantOp> {
        match *key {
            OpKey::Cg { l1, l2, l3 } => self.cg(l1, l2, l3),
            OpKey::Gaunt { l1, l2, l3, method } => {
                self.gaunt(l1, l2, l3, method)
            }
            OpKey::GauntF32 { l1, l2, l3 } => self.gaunt_f32(l1, l2, l3),
            OpKey::Escn { l_in, l_filter, l_out } => {
                self.escn(l_in, l_filter, l_out)
            }
            OpKey::GauntConv { l_in, l_filter, l_out } => {
                self.gaunt_conv(l_in, l_filter, l_out)
            }
            OpKey::ManyBody { nu, l, l_out } => self.many_body(nu, l, l_out),
            OpKey::Vector { kind, l1, l2, l3, method } => {
                self.vector(kind, l1, l2, l3, method)
            }
        }
    }

    /// Number of plans actually constructed (one per distinct key, even
    /// under contention).
    pub fn builds(&self) -> usize {
        self.builds.load(Ordering::Relaxed)
    }

    /// Number of read-path hits served without building.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of builds/hits/len plus per-key hit counts (hottest
    /// first) — what the serving metrics report.
    pub fn stats(&self) -> CacheStats {
        let guard = self.plans.read().unwrap();
        let mut per_key: Vec<KeyStats> = guard
            .iter()
            .map(|(key, e)| KeyStats {
                key: *key,
                hits: e.hits.load(Ordering::Relaxed),
            })
            .collect();
        per_key.sort_by(|a, b| b.hits.cmp(&a.hits));
        CacheStats {
            builds: self.builds(),
            hits: self.hits(),
            len: guard.len(),
            per_key,
        }
    }

    /// Drop every cached plan (outstanding `Arc`s stay valid).
    pub fn clear(&self) {
        self.plans.write().unwrap().clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::num_coeffs;
    use crate::tp::op::{apply_batch_par, BatchInputs, Inputs};
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    #[test]
    fn cache_returns_shared_plans_and_counts_builds() {
        let cache = PlanCache::new();
        let a = cache.gaunt(2, 2, 2, ConvMethod::Direct);
        let b = cache.gaunt(2, 2, 2, ConvMethod::Direct);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.builds(), 1);
        assert!(cache.hits() >= 1);
        // a different method is a different key
        let c = cache.gaunt(2, 2, 2, ConvMethod::Fft);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.builds(), 2);
        let _ = cache.cg(1, 1, 2);
        let _ = cache.escn(1, 1, 1);
        assert_eq!(cache.builds(), 4);
        assert_eq!(cache.len(), 4);
        cache.clear();
        assert!(cache.is_empty());
        // outstanding Arcs survive the clear
        let mut rng = Rng::new(0);
        let x = rng.normals(num_coeffs(2));
        let y = rng.normals(num_coeffs(2));
        assert_eq!(a.apply(&x, &y).len(), num_coeffs(2));
    }

    #[test]
    fn op_entry_point_resolves_every_family_to_the_same_plan() {
        let cache = PlanCache::new();
        let keys = [
            OpKey::Cg { l1: 1, l2: 1, l3: 2 },
            OpKey::Gaunt { l1: 2, l2: 2, l3: 2, method: ConvMethod::Auto },
            OpKey::Escn { l_in: 1, l_filter: 1, l_out: 1 },
            OpKey::GauntConv { l_in: 1, l_filter: 1, l_out: 2 },
            OpKey::ManyBody { nu: 2, l: 1, l_out: 2 },
        ];
        for key in &keys {
            let op1 = cache.op(key);
            let op2 = cache.op(key);
            assert_eq!(op1.key(), *key);
            // same underlying plan (the data pointers coincide)
            assert!(std::ptr::eq(
                Arc::as_ptr(&op1) as *const u8,
                Arc::as_ptr(&op2) as *const u8,
            ));
        }
        assert_eq!(cache.builds(), keys.len());
        assert_eq!(cache.len(), keys.len());
        // dims come from the typed layout contract
        let op = cache.op(&keys[1]);
        assert_eq!(op.irreps_in().dim(), num_coeffs(2));
        assert_eq!(op.irreps_out().dim(), num_coeffs(2));
    }

    #[test]
    fn precision_rekeying_round_trips_the_gaunt_family() {
        let key = OpKey::Gaunt {
            l1: 2, l2: 3, l3: 4, method: ConvMethod::Fft,
        };
        assert_eq!(key.precision(), Precision::F64);
        let f32_key = key.with_precision(Precision::F32);
        assert_eq!(f32_key, OpKey::GauntF32 { l1: 2, l2: 3, l3: 4 });
        assert_eq!(f32_key.precision(), Precision::F32);
        // F32 → F64 lands on Auto (the method was dropped on lowering)
        assert_eq!(
            f32_key.with_precision(Precision::F64),
            OpKey::Gaunt { l1: 2, l2: 3, l3: 4, method: ConvMethod::Auto },
        );
        // families without an f32 lowering are untouched
        let cg = OpKey::Cg { l1: 1, l2: 1, l3: 2 };
        assert_eq!(cg.with_precision(Precision::F32), cg);
        // idempotent on already-lowered keys
        assert_eq!(f32_key.with_precision(Precision::F32), f32_key);
    }

    #[test]
    fn f32_keys_resolve_through_the_cache() {
        let cache = PlanCache::new();
        let key = OpKey::GauntF32 { l1: 2, l2: 2, l3: 2 };
        let a = cache.gaunt_f32(2, 2, 2);
        let op = cache.op(&key);
        assert_eq!(op.key(), key);
        assert!(std::ptr::eq(
            Arc::as_ptr(&a) as *const u8,
            Arc::as_ptr(&op) as *const u8,
        ));
        assert_eq!(cache.builds(), 1);
        // distinct key from the f64 family at the same degrees
        let _ = cache.gaunt(2, 2, 2, ConvMethod::Auto);
        assert_eq!(cache.builds(), 2);
    }

    #[test]
    fn vector_keys_resolve_through_the_cache() {
        let cache = PlanCache::new();
        let key = OpKey::Vector {
            kind: VectorKind::VectorCross,
            l1: 1, l2: 1, l3: 2,
            method: ConvMethod::Auto,
        };
        let a = cache.vector(
            VectorKind::VectorCross, 1, 1, 2, ConvMethod::Auto,
        );
        let op = cache.op(&key);
        assert_eq!(op.key(), key);
        assert!(std::ptr::eq(
            Arc::as_ptr(&a) as *const u8,
            Arc::as_ptr(&op) as *const u8,
        ));
        assert_eq!(cache.builds(), 1);
        assert_eq!(op.irreps_in().dim(), 3 * num_coeffs(1));
        assert_eq!(op.irreps_out().dim(), 3 * num_coeffs(2));
        // precision re-keying leaves the vector family unchanged
        assert_eq!(key.with_precision(Precision::F32), key);
        // a different kind at the same degrees is a different key
        let _ = cache.vector(
            VectorKind::VectorDot, 1, 1, 2, ConvMethod::Auto,
        );
        assert_eq!(cache.builds(), 2);
    }

    #[test]
    fn per_key_stats_track_hits() {
        let cache = PlanCache::new();
        let hot = OpKey::Gaunt {
            l1: 2, l2: 2, l3: 2, method: ConvMethod::Direct,
        };
        let cold = OpKey::Cg { l1: 1, l2: 1, l3: 1 };
        let _ = cache.op(&hot); // build
        let _ = cache.op(&cold); // build
        for _ in 0..5 {
            let _ = cache.op(&hot); // hits
        }
        let stats = cache.stats();
        assert_eq!(stats.builds, 2);
        assert_eq!(stats.len, 2);
        assert_eq!(stats.hits, 5);
        assert_eq!(stats.per_key.len(), 2);
        // hottest first
        assert_eq!(stats.per_key[0].key, hot);
        assert_eq!(stats.per_key[0].hits, 5);
        assert_eq!(stats.per_key[1].hits, 0);
    }

    #[test]
    fn cached_op_applies_match_the_typed_plans() {
        let cache = PlanCache::new();
        let mut rng = Rng::new(1);
        let n = num_coeffs(2);
        let x1 = rng.normals(n);
        let x2 = rng.normals(n);
        let plan = cache.gaunt(2, 2, 3, ConvMethod::Auto);
        let want = plan.apply(&x1, &x2);
        let op = cache.op(&OpKey::Gaunt {
            l1: 2, l2: 2, l3: 3, method: ConvMethod::Auto,
        });
        let got = op.apply_op(Inputs::pair(&x1, &x2));
        assert!(max_abs_diff(&got, &want) == 0.0);
    }

    #[test]
    fn generic_batch_over_cached_ops_matches_serial() {
        let cache = PlanCache::new();
        let mut rng = Rng::new(4);
        let rows = 9usize;
        let n = num_coeffs(2);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let op = cache.op(&OpKey::Gaunt {
            l1: 2, l2: 2, l3: 3, method: ConvMethod::Auto,
        });
        let plan = cache.gaunt(2, 2, 3, ConvMethod::Auto);
        let serial = plan.apply_batch(&x1, &x2, rows);
        for threads in [1usize, 2, 4, 0] {
            let par = apply_batch_par(
                op.as_ref(), &BatchInputs::pair(&x1, &x2), rows, threads,
            );
            assert!(max_abs_diff(&serial, &par) == 0.0, "threads={threads}");
        }
    }
}
