//! Vector-signal Gaunt products: O(L^3) tensor products of vector
//! spherical signals through the scalar Fourier pipeline (DESIGN.md §15).
//!
//! A *vector signal* of degree <= L is three Cartesian-component scalar
//! SH signals in the [`Irreps::spherical`]`(3, L)` layout — degree-major
//! panels `[l][c][m]`, flat index `3 l^2 + c (2l+1) + (l+m)`.  The
//! component index is in real l=1 irrep order: c=0 is the y component,
//! c=1 is z, c=2 is x ([`CART`]/[`IRR`]).  Under a rotation R the
//! degree-l panel transforms as `D^1(R) X D^l(R)^T` (components mix
//! with D^1, each degree with D^l); under an improper map `o = -R` a
//! polar signal picks up `det^{l+1}` per degree, a pseudovector signal
//! `det^l`.
//!
//! Because each component is an ordinary scalar signal, every vector
//! product reduces to component-wise *scalar* pointwise products, so the
//! whole family routes through the existing `sh2f -> packed Hermitian
//! conv -> f2sh` O(L^3) machinery of [`GauntPlan`]:
//!
//! ```text
//!   sv    : scalar (x) vector -> vector         out_c = P_l3(s v_c)
//!   dot   : vector (.) vector -> scalar         out   = sum_c P_l3(v_c w_c)
//!   cross : vector (x) vector -> pseudovector   out_k = P_l3(v_a w_b - v_b w_a)
//! ```
//!
//! with `(a, b) = (k+1, k+2) mod 3` — the Levi-Civita tensor is cyclic
//! in the irrep component order because [`CART`] is an even permutation.
//! On the FFT path the component sample arrays are produced pairwise by
//! one joint packed transform ([`ConvPlan::samples_pair_into`]) and the
//! pointwise products accumulate in sample space before ONE shared
//! back-transform per output component: 6 / 4 / 6 length-m 2D transforms
//! per sv / dot / cross apply (vs 6 / 6 / 12 via repeated pair convs).
//!
//! VJPs stay inside the family by degree rotation (all validated against
//! finite differences by `python/compile/vector_golden.py`):
//!
//! ```text
//!   sv(l1,l2,l3)^T    g = dot(l3,l2,l1)(g, x2)
//!   dot(l1,l2,l3)^T   g = sv(l3,l2,l1)(g, x2)
//!   cross(l1,l2,l3)^T g = cross(l2,l3,l1)(x2, g)
//! ```

use crate::fourier::complex::C64;
use crate::fourier::conv::conv2d_direct_into;
use crate::fourier::plan::{ConvPlan, ConvScratch};
use crate::fourier::tables::{sh2f_panels, F2shPanelsT, Sh2fPanels};
use crate::so3::gaunt::gaunt_tensor_real;
use crate::so3::rotation::{wigner_d_real, Rot3};
use crate::tp::gaunt::{ConvMethod, GauntPlan};
use crate::tp::irreps::Irreps;
use crate::num_coeffs;

/// Irrep component index -> xyz axis (c0 = y, c1 = z, c2 = x).
pub const CART: [usize; 3] = [1, 2, 0];
/// xyz axis -> irrep component index (inverse of [`CART`]).
pub const IRR: [usize; 3] = [2, 0, 1];

/// The three vector plan kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VectorKind {
    /// scalar (x) vector -> vector (polar).
    ScalarVector,
    /// vector (.) vector -> scalar.
    VectorDot,
    /// vector (x) vector -> pseudovector.
    VectorCross,
}

impl VectorKind {
    pub fn name(self) -> &'static str {
        match self {
            VectorKind::ScalarVector => "sv",
            VectorKind::VectorDot => "dot",
            VectorKind::VectorCross => "cross",
        }
    }

    pub fn from_name(s: &str) -> Option<VectorKind> {
        match s {
            "sv" => Some(VectorKind::ScalarVector),
            "dot" => Some(VectorKind::VectorDot),
            "cross" => Some(VectorKind::VectorCross),
            _ => None,
        }
    }
}

/// The vector-signal feature layout: a thin `Irreps::spherical(3, L)`
/// wrapper naming the component semantics (channel = Cartesian component
/// in irrep order) and the vector-specific helpers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VectorIrreps {
    ir: Irreps,
}

impl VectorIrreps {
    pub fn new(l_max: usize) -> VectorIrreps {
        VectorIrreps { ir: Irreps::spherical(3, l_max) }
    }

    pub fn l_max(&self) -> usize {
        self.ir.l_max()
    }

    /// Flat dimension `3 (L+1)^2`.
    pub fn dim(&self) -> usize {
        self.ir.dim()
    }

    /// The underlying typed layout.
    pub fn irreps(&self) -> &Irreps {
        &self.ir
    }

    /// Flat index of (degree l, component c, order m).
    pub fn index(&self, l: usize, c: usize, m: i64) -> usize {
        debug_assert!(l <= self.l_max() && c < 3 && m.unsigned_abs() as usize <= l);
        3 * l * l + c * (2 * l + 1) + (l as i64 + m) as usize
    }

    /// Extract component `c` as a flat scalar feature (`(L+1)^2`).
    pub fn gather(&self, x: &[f64], c: usize, out: &mut [f64]) {
        self.ir.gather_channel(x, c, out);
    }

    /// Write component `c` from a flat scalar feature.
    pub fn scatter(&self, src: &[f64], c: usize, x: &mut [f64]) {
        self.ir.scatter_channel(src, c, x);
    }

    /// Accumulate component `c` from a flat scalar feature.
    pub fn scatter_add(&self, src: &[f64], c: usize, x: &mut [f64]) {
        self.ir.scatter_channel_add(src, c, x);
    }

    /// The constant vector field `F(u) = u` as a degree-1 signal:
    /// `sqrt(4 pi / 3)` on the (c, m = c-1) diagonal of the l=1 panel.
    pub fn rhat_signal() -> Vec<f64> {
        let vir = VectorIrreps::new(1);
        let mut x = vec![0.0; vir.dim()];
        let a = (4.0 * std::f64::consts::PI / 3.0).sqrt();
        for c in 0..3 {
            x[vir.index(1, c, c as i64 - 1)] = a;
        }
        x
    }
}

/// Caller-owned scratch for [`VectorGauntPlan::apply_into`]: one per
/// worker thread, sized at construction, never resized.
pub struct VectorScratch {
    /// sh2f staging
    w: Vec<C64>,
    /// gathered operand components (scalar features)
    comp1: Vec<f64>,
    comp2: Vec<f64>,
    /// per-component output staging
    outc: Vec<f64>,
    /// operand Fourier grids (3 slots each only where a path needs all
    /// components simultaneously: the direct cross path)
    g1: Vec<C64>,
    g2: Vec<C64>,
    /// product grid(s) (2(l1+l2)+1)^2
    grid: Vec<C64>,
    grid2: Vec<C64>,
    /// FFT-path sample arrays (3 slots each for cross, 1 otherwise)
    q1: Vec<f64>,
    q2: Vec<f64>,
    qa: Vec<f64>,
    conv: ConvScratch,
}

/// Precomputed plan for one vector product kind at fixed degrees
/// (x1: deg <= l1) (op) (x2: deg <= l2) -> deg <= l3.  Read-only after
/// construction; share via `Arc`, give each worker its own
/// [`VectorScratch`].
pub struct VectorGauntPlan {
    pub kind: VectorKind,
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    pub method: ConvMethod,
    p1: Sh2fPanels,
    p2: Sh2fPanels,
    t3t: F2shPanelsT,
    conv: ConvPlan,
    n_grid: usize,
    ir1: Irreps,
    ir2: Irreps,
    ir3: Irreps,
}

impl VectorGauntPlan {
    pub fn new(
        kind: VectorKind, l1: usize, l2: usize, l3: usize, method: ConvMethod,
    ) -> VectorGauntPlan {
        let n_grid = l1 + l2;
        let (ir1, ir3) = match kind {
            VectorKind::ScalarVector => {
                (Irreps::single(l1), Irreps::spherical(3, l3))
            }
            VectorKind::VectorDot => {
                (Irreps::spherical(3, l1), Irreps::single(l3))
            }
            VectorKind::VectorCross => {
                (Irreps::spherical(3, l1), Irreps::spherical(3, l3))
            }
        };
        VectorGauntPlan {
            kind,
            l1,
            l2,
            l3,
            method,
            p1: sh2f_panels(l1),
            p2: sh2f_panels(l2),
            t3t: F2shPanelsT::build(l3, n_grid),
            conv: ConvPlan::new(2 * l1 + 1, 2 * l2 + 1),
            n_grid,
            ir1,
            ir2: Irreps::spherical(3, l2),
            ir3,
        }
    }

    /// Input-1 / input-2 / output layouts (the [`EquivariantOp`]
    /// contract).
    pub fn irreps_in(&self) -> &Irreps {
        &self.ir1
    }

    pub fn irreps_in2(&self) -> &Irreps {
        &self.ir2
    }

    pub fn irreps_out(&self) -> &Irreps {
        &self.ir3
    }

    /// Whether this plan's method resolves to the FFT backend (same
    /// crossover as the scalar plans).
    pub fn uses_fft(&self) -> bool {
        match self.method {
            ConvMethod::Direct => false,
            ConvMethod::Fft => true,
            ConvMethod::Auto => {
                self.l1 + self.l2 >= crate::tp::gaunt::AUTO_FFT_CROSSOVER
            }
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).
    pub fn scratch(&self) -> VectorScratch {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        let nu3 = 2 * self.n_grid + 1;
        let nw = (self.l1 + 1).max(self.l2 + 1);
        let fft = self.uses_fft();
        let cross = self.kind == VectorKind::VectorCross;
        let m2 = self.conv.m * self.conv.m;
        let qslots = if cross { 3 } else { 1 };
        // only the direct cross path holds all component grids at once
        let gslots = if cross && !fft { 3 } else { 1 };
        VectorScratch {
            w: vec![C64::default(); nw * nw],
            comp1: vec![0.0; num_coeffs(self.l1)],
            comp2: vec![0.0; num_coeffs(self.l2)],
            outc: vec![0.0; num_coeffs(self.l3)],
            g1: vec![C64::default(); gslots * n1 * n1],
            g2: vec![C64::default(); gslots * n2 * n2],
            grid: vec![C64::default(); nu3 * nu3],
            grid2: if !fft && self.kind != VectorKind::ScalarVector {
                vec![C64::default(); nu3 * nu3]
            } else {
                Vec::new()
            },
            q1: if fft { vec![0.0; qslots * m2] } else { Vec::new() },
            q2: if fft { vec![0.0; qslots * m2] } else { Vec::new() },
            qa: if fft { vec![0.0; m2] } else { Vec::new() },
            conv: if fft { self.conv.scratch() } else { ConvScratch::empty() },
        }
    }

    /// Flat input/output dims `(dim_x1, dim_x2, dim_out)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.ir1.dim(), self.ir2.dim(), self.ir3.dim())
    }

    /// The fused vector product of one pair of features, written into
    /// `out`, every intermediate in `scratch`: zero steady-state
    /// allocations.
    pub fn apply_into(
        &self, x1: &[f64], x2: &[f64], out: &mut [f64],
        scratch: &mut VectorScratch,
    ) {
        debug_assert_eq!(x1.len(), self.ir1.dim());
        debug_assert_eq!(x2.len(), self.ir2.dim());
        debug_assert_eq!(out.len(), self.ir3.dim());
        if self.uses_fft() {
            self.apply_fft(x1, x2, out, scratch);
        } else {
            self.apply_direct(x1, x2, out, scratch);
        }
    }

    /// Allocating convenience wrapper around [`VectorGauntPlan::apply_into`].
    pub fn apply(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.ir3.dim()];
        let mut scratch = self.scratch();
        self.apply_into(x1, x2, &mut out, &mut scratch);
        out
    }

    fn apply_direct(
        &self, x1: &[f64], x2: &[f64], out: &mut [f64],
        scratch: &mut VectorScratch,
    ) {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        let s = scratch;
        match self.kind {
            VectorKind::ScalarVector => {
                GauntPlan::sh2f_into(&self.p1, x1, &mut s.g1, &mut s.w);
                let vir2 = &self.ir2;
                let vir3 = &self.ir3;
                for c in 0..3 {
                    vir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(&self.p2, &s.comp2, &mut s.g2, &mut s.w);
                    conv2d_direct_into(&s.g1, n1, &s.g2, n2, &mut s.grid);
                    crate::fourier::tables::f2sh_contract(
                        &self.t3t, &s.grid, &mut s.outc,
                    );
                    vir3.scatter_channel(&s.outc, c, out);
                }
            }
            VectorKind::VectorDot => {
                s.grid.fill(C64::default());
                for c in 0..3 {
                    self.ir1.gather_channel(x1, c, &mut s.comp1);
                    self.ir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(&self.p1, &s.comp1, &mut s.g1, &mut s.w);
                    GauntPlan::sh2f_into(&self.p2, &s.comp2, &mut s.g2, &mut s.w);
                    conv2d_direct_into(&s.g1, n1, &s.g2, n2, &mut s.grid2);
                    for (a, b) in s.grid.iter_mut().zip(&s.grid2) {
                        *a += *b;
                    }
                }
                crate::fourier::tables::f2sh_contract(&self.t3t, &s.grid, out);
            }
            VectorKind::VectorCross => {
                // all six component grids up front, then the cyclic form
                for c in 0..3 {
                    self.ir1.gather_channel(x1, c, &mut s.comp1);
                    self.ir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(
                        &self.p1,
                        &s.comp1,
                        &mut s.g1[c * n1 * n1..(c + 1) * n1 * n1],
                        &mut s.w,
                    );
                    GauntPlan::sh2f_into(
                        &self.p2,
                        &s.comp2,
                        &mut s.g2[c * n2 * n2..(c + 1) * n2 * n2],
                        &mut s.w,
                    );
                }
                for k in 0..3 {
                    let a = (k + 1) % 3;
                    let b = (k + 2) % 3;
                    conv2d_direct_into(
                        &s.g1[a * n1 * n1..(a + 1) * n1 * n1],
                        n1,
                        &s.g2[b * n2 * n2..(b + 1) * n2 * n2],
                        n2,
                        &mut s.grid,
                    );
                    conv2d_direct_into(
                        &s.g1[b * n1 * n1..(b + 1) * n1 * n1],
                        n1,
                        &s.g2[a * n2 * n2..(a + 1) * n2 * n2],
                        n2,
                        &mut s.grid2,
                    );
                    for (p, q) in s.grid.iter_mut().zip(&s.grid2) {
                        *p -= *q;
                    }
                    crate::fourier::tables::f2sh_contract(
                        &self.t3t, &s.grid, &mut s.outc,
                    );
                    self.ir3.scatter_channel(&s.outc, k, out);
                }
            }
        }
    }

    fn apply_fft(
        &self, x1: &[f64], x2: &[f64], out: &mut [f64],
        scratch: &mut VectorScratch,
    ) {
        let m2 = self.conv.m * self.conv.m;
        let s = scratch;
        match self.kind {
            VectorKind::ScalarVector => {
                GauntPlan::sh2f_into(&self.p1, x1, &mut s.g1, &mut s.w);
                for c in 0..3 {
                    self.ir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(&self.p2, &s.comp2, &mut s.g2, &mut s.w);
                    self.conv.samples_pair_into(
                        &s.g1, &s.g2, &mut s.q1, &mut s.q2, &mut s.conv,
                    );
                    mul_into(&mut s.qa, &s.q1, &s.q2);
                    self.conv.grid_from_samples_into(
                        &s.qa, &mut s.grid, &mut s.conv,
                    );
                    crate::fourier::tables::f2sh_contract(
                        &self.t3t, &s.grid, &mut s.outc,
                    );
                    self.ir3.scatter_channel(&s.outc, c, out);
                }
            }
            VectorKind::VectorDot => {
                s.qa.fill(0.0);
                for c in 0..3 {
                    self.ir1.gather_channel(x1, c, &mut s.comp1);
                    self.ir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(&self.p1, &s.comp1, &mut s.g1, &mut s.w);
                    GauntPlan::sh2f_into(&self.p2, &s.comp2, &mut s.g2, &mut s.w);
                    self.conv.samples_pair_into(
                        &s.g1, &s.g2, &mut s.q1, &mut s.q2, &mut s.conv,
                    );
                    mul_add(&mut s.qa, &s.q1, &s.q2);
                }
                self.conv.grid_from_samples_into(&s.qa, &mut s.grid, &mut s.conv);
                crate::fourier::tables::f2sh_contract(&self.t3t, &s.grid, out);
            }
            VectorKind::VectorCross => {
                for c in 0..3 {
                    self.ir1.gather_channel(x1, c, &mut s.comp1);
                    self.ir2.gather_channel(x2, c, &mut s.comp2);
                    GauntPlan::sh2f_into(&self.p1, &s.comp1, &mut s.g1, &mut s.w);
                    GauntPlan::sh2f_into(&self.p2, &s.comp2, &mut s.g2, &mut s.w);
                    let (qa_c, qb_c) = (
                        &mut s.q1[c * m2..(c + 1) * m2],
                        &mut s.q2[c * m2..(c + 1) * m2],
                    );
                    self.conv.samples_pair_into(
                        &s.g1, &s.g2, qa_c, qb_c, &mut s.conv,
                    );
                }
                for k in 0..3 {
                    let a = (k + 1) % 3;
                    let b = (k + 2) % 3;
                    mul_into(
                        &mut s.qa,
                        &s.q1[a * m2..(a + 1) * m2],
                        &s.q2[b * m2..(b + 1) * m2],
                    );
                    mul_sub(
                        &mut s.qa,
                        &s.q1[b * m2..(b + 1) * m2],
                        &s.q2[a * m2..(a + 1) * m2],
                    );
                    self.conv.grid_from_samples_into(
                        &s.qa, &mut s.grid, &mut s.conv,
                    );
                    crate::fourier::tables::f2sh_contract(
                        &self.t3t, &s.grid, &mut s.outc,
                    );
                    self.ir3.scatter_channel(&s.outc, k, out);
                }
            }
        }
    }

    /// The degree-rotated sibling plan computing this plan's VJP w.r.t.
    /// x1: `(kind', l1', l2', l3')` such that
    /// `d<g, self(x1, x2)>/dx1 = sibling(arg_a, arg_b)` with the operand
    /// order given by [`VectorGauntPlan::vjp_operands_swapped`].
    pub fn vjp_sibling_key(&self) -> (VectorKind, usize, usize, usize) {
        match self.kind {
            VectorKind::ScalarVector => {
                (VectorKind::VectorDot, self.l3, self.l2, self.l1)
            }
            VectorKind::VectorDot => {
                (VectorKind::ScalarVector, self.l3, self.l2, self.l1)
            }
            VectorKind::VectorCross => {
                (VectorKind::VectorCross, self.l2, self.l3, self.l1)
            }
        }
    }

    /// Whether the VJP sibling takes `(x2, g)` instead of `(g, x2)`
    /// (true only for cross, whose sibling absorbs the cotangent as its
    /// second operand).
    pub fn vjp_operands_swapped(&self) -> bool {
        self.kind == VectorKind::VectorCross
    }
}

fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o = x * y;
    }
}

fn mul_add(out: &mut [f64], a: &[f64], b: &[f64]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o += x * y;
    }
}

fn mul_sub(out: &mut [f64], a: &[f64], b: &[f64]) {
    for ((o, x), y) in out.iter_mut().zip(a).zip(b) {
        *o -= x * y;
    }
}

/// O(L^6) dense Gaunt-tensor reference: the CG-style baseline the
/// conformance tests oracle against and `fig_vector` benchmarks.
pub struct NaiveVectorTp {
    pub kind: VectorKind,
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    g: Vec<f64>,
    ir1: Irreps,
    ir2: Irreps,
    ir3: Irreps,
}

impl NaiveVectorTp {
    pub fn new(kind: VectorKind, l1: usize, l2: usize, l3: usize) -> Self {
        let (ir1, ir3) = match kind {
            VectorKind::ScalarVector => {
                (Irreps::single(l1), Irreps::spherical(3, l3))
            }
            VectorKind::VectorDot => {
                (Irreps::spherical(3, l1), Irreps::single(l3))
            }
            VectorKind::VectorCross => {
                (Irreps::spherical(3, l1), Irreps::spherical(3, l3))
            }
        };
        NaiveVectorTp {
            kind,
            l1,
            l2,
            l3,
            g: gaunt_tensor_real(l1, l2, l3),
            ir1,
            ir2: Irreps::spherical(3, l2),
            ir3,
        }
    }

    fn contract(&self, s1: &[f64], s2: &[f64], out: &mut [f64], sign: f64) {
        let (n1, n2) = (num_coeffs(self.l1), num_coeffs(self.l2));
        for (k, o) in out.iter_mut().enumerate() {
            let block = &self.g[k * n1 * n2..(k + 1) * n1 * n2];
            let mut acc = 0.0;
            for (i, x) in s1.iter().enumerate() {
                if *x == 0.0 {
                    continue;
                }
                let row = &block[i * n2..(i + 1) * n2];
                for (j, y) in s2.iter().enumerate() {
                    acc += row[j] * x * y;
                }
            }
            *o += sign * acc;
        }
    }

    pub fn apply(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let (n1, n2, n3) =
            (num_coeffs(self.l1), num_coeffs(self.l2), num_coeffs(self.l3));
        let mut out = vec![0.0; self.ir3.dim()];
        let mut c1 = vec![0.0; n1];
        let mut c2 = vec![0.0; n2];
        let mut oc = vec![0.0; n3];
        match self.kind {
            VectorKind::ScalarVector => {
                for c in 0..3 {
                    self.ir2.gather_channel(x2, c, &mut c2);
                    oc.fill(0.0);
                    self.contract(x1, &c2, &mut oc, 1.0);
                    self.ir3.scatter_channel(&oc, c, &mut out);
                }
            }
            VectorKind::VectorDot => {
                for c in 0..3 {
                    self.ir1.gather_channel(x1, c, &mut c1);
                    self.ir2.gather_channel(x2, c, &mut c2);
                    self.contract(&c1, &c2, &mut out, 1.0);
                }
            }
            VectorKind::VectorCross => {
                let mut c1b = vec![0.0; n1];
                let mut c2b = vec![0.0; n2];
                for k in 0..3 {
                    let a = (k + 1) % 3;
                    let b = (k + 2) % 3;
                    self.ir1.gather_channel(x1, a, &mut c1);
                    self.ir2.gather_channel(x2, b, &mut c2);
                    self.ir1.gather_channel(x1, b, &mut c1b);
                    self.ir2.gather_channel(x2, a, &mut c2b);
                    oc.fill(0.0);
                    self.contract(&c1, &c2, &mut oc, 1.0);
                    self.contract(&c1b, &c2b, &mut oc, -1.0);
                    self.ir3.scatter_channel(&oc, k, &mut out);
                }
            }
        }
        out
    }
}

/// Scalar signal under a (possibly improper) orthogonal map `o`: each
/// degree-l block gets `det^l D^l(det * o)`.  Test/support helper shared
/// by the conformance suites.
pub fn transform_scalar(x: &[f64], l_max: usize, o: &Rot3) -> Vec<f64> {
    let det = if o.det() >= 0.0 { 1.0 } else { -1.0 };
    let r = Rot3([
        [det * o.0[0][0], det * o.0[0][1], det * o.0[0][2]],
        [det * o.0[1][0], det * o.0[1][1], det * o.0[1][2]],
        [det * o.0[2][0], det * o.0[2][1], det * o.0[2][2]],
    ]);
    let mut out = vec![0.0; x.len()];
    for l in 0..=l_max {
        let d = wigner_d_real(l, &r);
        let n = 2 * l + 1;
        let base = l * l;
        let f = if l % 2 == 1 { det } else { 1.0 };
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += d[i * n + j] * x[base + j];
            }
            out[base + i] = f * acc;
        }
    }
    out
}

/// Vector signal under `o`: components mix with D^1, each degree with
/// D^l; a polar signal picks up `det^{l+1}` per degree under improper
/// maps, a pseudovector `det^l`.
pub fn transform_vector(
    x: &[f64], l_max: usize, o: &Rot3, pseudo: bool,
) -> Vec<f64> {
    let det = if o.det() >= 0.0 { 1.0 } else { -1.0 };
    let r = Rot3([
        [det * o.0[0][0], det * o.0[0][1], det * o.0[0][2]],
        [det * o.0[1][0], det * o.0[1][1], det * o.0[1][2]],
        [det * o.0[2][0], det * o.0[2][1], det * o.0[2][2]],
    ]);
    let d1 = wigner_d_real(1, &r);
    let mut out = vec![0.0; x.len()];
    for l in 0..=l_max {
        let dl = wigner_d_real(l, &r);
        let n = 2 * l + 1;
        let base = 3 * l * l;
        let pow = if pseudo { l } else { l + 1 };
        let f = if pow % 2 == 1 { det } else { 1.0 };
        // out[c, i] = f * sum_{a, j} d1[c, a] x[a, j] dl[i, j]
        for c in 0..3 {
            for i in 0..n {
                let mut acc = 0.0;
                for a in 0..3 {
                    let xa = &x[base + a * n..base + (a + 1) * n];
                    let mut inner = 0.0;
                    for (j, xv) in xa.iter().enumerate() {
                        inner += dl[i * n + j] * xv;
                    }
                    acc += d1[c * 3 + a] * inner;
                }
                out[base + c * n + i] = f * acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::so3::sh::eval_sh_series;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    const TRIPLES: [(VectorKind, usize, usize, usize); 6] = [
        (VectorKind::ScalarVector, 2, 2, 2),
        (VectorKind::ScalarVector, 1, 2, 3),
        (VectorKind::VectorDot, 2, 2, 2),
        (VectorKind::VectorDot, 2, 1, 3),
        (VectorKind::VectorCross, 1, 1, 1),
        (VectorKind::VectorCross, 2, 1, 2),
    ];

    fn rand_inputs(
        plan: &VectorGauntPlan, rng: &mut Rng,
    ) -> (Vec<f64>, Vec<f64>) {
        let (n1, n2, _) = plan.dims();
        (rng.normals(n1), rng.normals(n2))
    }

    #[test]
    fn matches_naive_reference() {
        let mut rng = Rng::new(0);
        for &(kind, l1, l2, l3) in &TRIPLES {
            let naive = NaiveVectorTp::new(kind, l1, l2, l3);
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let plan = VectorGauntPlan::new(kind, l1, l2, l3, method);
                let (x1, x2) = rand_inputs(&plan, &mut rng);
                let got = plan.apply(&x1, &x2);
                let want = naive.apply(&x1, &x2);
                assert!(
                    max_abs_diff(&got, &want) < 1e-9,
                    "{kind:?} ({l1},{l2},{l3}) {method:?}: {}",
                    max_abs_diff(&got, &want)
                );
            }
        }
    }

    fn eval_component(x: &[f64], l: usize, c: usize, theta: f64, phi: f64) -> f64 {
        let ir = Irreps::spherical(3, l);
        let mut comp = vec![0.0; num_coeffs(l)];
        ir.gather_channel(x, c, &mut comp);
        eval_sh_series(&comp, l, theta, phi)
    }

    /// xyz value of a vector signal at a direction.
    fn eval_field(x: &[f64], l: usize, theta: f64, phi: f64) -> [f64; 3] {
        let mut v = [0.0; 3];
        for c in 0..3 {
            v[CART[c]] = eval_component(x, l, c, theta, phi);
        }
        v
    }

    #[test]
    fn pointwise_product_semantics() {
        let mut rng = Rng::new(1);
        // full-degree outputs so truncation is exact
        let dot = VectorGauntPlan::new(
            VectorKind::VectorDot, 2, 1, 3, ConvMethod::Direct,
        );
        let cross = VectorGauntPlan::new(
            VectorKind::VectorCross, 2, 1, 3, ConvMethod::Direct,
        );
        let sv = VectorGauntPlan::new(
            VectorKind::ScalarVector, 2, 1, 3, ConvMethod::Direct,
        );
        let s = rng.normals(num_coeffs(2));
        let v1 = rng.normals(3 * num_coeffs(2));
        let v2 = rng.normals(3 * num_coeffs(1));
        let y_sv = sv.apply(&s, &v2);
        let y_dot = dot.apply(&v1, &v2);
        let y_cross = cross.apply(&v1, &v2);
        for _ in 0..10 {
            let theta = rng.uniform(0.1, 3.0);
            let phi = rng.uniform(0.0, 6.28);
            let fs = eval_sh_series(&s, 2, theta, phi);
            let f1 = eval_field(&v1, 2, theta, phi);
            let f2 = eval_field(&v2, 1, theta, phi);
            let g_sv = eval_field(&y_sv, 3, theta, phi);
            for k in 0..3 {
                assert!((g_sv[k] - fs * f2[k]).abs() < 1e-9);
            }
            let g_dot = eval_sh_series(&y_dot, 3, theta, phi);
            let dot_want =
                f1[0] * f2[0] + f1[1] * f2[1] + f1[2] * f2[2];
            assert!((g_dot - dot_want).abs() < 1e-9);
            let g_cross = eval_field(&y_cross, 3, theta, phi);
            let cross_want = [
                f1[1] * f2[2] - f1[2] * f2[1],
                f1[2] * f2[0] - f1[0] * f2[2],
                f1[0] * f2[1] - f1[1] * f2[0],
            ];
            for k in 0..3 {
                assert!((g_cross[k] - cross_want[k]).abs() < 1e-9);
            }
        }
    }

    fn transform_in1(
        plan: &VectorGauntPlan, x: &[f64], o: &Rot3,
    ) -> Vec<f64> {
        match plan.kind {
            VectorKind::ScalarVector => transform_scalar(x, plan.l1, o),
            _ => transform_vector(x, plan.l1, o, false),
        }
    }

    fn transform_out(plan: &VectorGauntPlan, y: &[f64], o: &Rot3) -> Vec<f64> {
        match plan.kind {
            VectorKind::ScalarVector => transform_vector(y, plan.l3, o, false),
            VectorKind::VectorDot => transform_scalar(y, plan.l3, o),
            VectorKind::VectorCross => transform_vector(y, plan.l3, o, true),
        }
    }

    #[test]
    fn equivariance_proper_and_improper() {
        let mut rng = Rng::new(2);
        for &(kind, l1, l2, l3) in &TRIPLES {
            let plan = VectorGauntPlan::new(kind, l1, l2, l3, ConvMethod::Auto);
            let (x1, x2) = rand_inputs(&plan, &mut rng);
            let rot = Rot3::random(&mut rng);
            for improper in [false, true] {
                let o = if improper {
                    Rot3([
                        [-rot.0[0][0], -rot.0[0][1], -rot.0[0][2]],
                        [-rot.0[1][0], -rot.0[1][1], -rot.0[1][2]],
                        [-rot.0[2][0], -rot.0[2][1], -rot.0[2][2]],
                    ])
                } else {
                    rot.clone()
                };
                let tx1 = transform_in1(&plan, &x1, &o);
                let tx2 = transform_vector(&x2, l2, &o, false);
                let lhs = plan.apply(&tx1, &tx2);
                let rhs = transform_out(&plan, &plan.apply(&x1, &x2), &o);
                assert!(
                    max_abs_diff(&lhs, &rhs) < 1e-8,
                    "{kind:?} ({l1},{l2},{l3}) improper={improper}: {}",
                    max_abs_diff(&lhs, &rhs)
                );
            }
        }
    }

    #[test]
    fn vjp_siblings_match_finite_differences() {
        let mut rng = Rng::new(3);
        let h = 1e-6;
        for &(kind, l1, l2, l3) in &TRIPLES {
            let plan = VectorGauntPlan::new(kind, l1, l2, l3, ConvMethod::Auto);
            let (x1, x2) = rand_inputs(&plan, &mut rng);
            let (_, _, n3) = plan.dims();
            let g = rng.normals(n3);
            let (sk, sl1, sl2, sl3) = plan.vjp_sibling_key();
            let sib = VectorGauntPlan::new(sk, sl1, sl2, sl3, ConvMethod::Auto);
            let grad = if plan.vjp_operands_swapped() {
                sib.apply(&x2, &g)
            } else {
                sib.apply(&g, &x2)
            };
            for i in 0..x1.len().min(8) {
                let mut xp = x1.clone();
                xp[i] += h;
                let mut xm = x1.clone();
                xm[i] -= h;
                let yp = plan.apply(&xp, &x2);
                let ym = plan.apply(&xm, &x2);
                let fd: f64 = yp
                    .iter()
                    .zip(&ym)
                    .zip(&g)
                    .map(|((p, m), gv)| gv * (p - m) / (2.0 * h))
                    .sum();
                assert!(
                    (grad[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                    "{kind:?} ({l1},{l2},{l3}) i={i}: {} vs {}",
                    grad[i],
                    fd
                );
            }
        }
    }

    #[test]
    fn dot_with_rhat_extracts_radial_part() {
        // <F(u), u> of the constant field F = u is |u|^2 = 1, whose
        // degree-0 coefficient is sqrt(4 pi)
        let rhat = VectorIrreps::rhat_signal();
        let plan =
            VectorGauntPlan::new(VectorKind::VectorDot, 1, 1, 0, ConvMethod::Direct);
        let out = plan.apply(&rhat, &rhat);
        assert!((out[0] - (4.0 * std::f64::consts::PI).sqrt()).abs() < 1e-10);
    }

    #[test]
    fn cross_of_parallel_fields_vanishes() {
        let rhat = VectorIrreps::rhat_signal();
        let plan = VectorGauntPlan::new(
            VectorKind::VectorCross, 1, 1, 2, ConvMethod::Direct,
        );
        let out = plan.apply(&rhat, &rhat);
        assert!(max_abs_diff(&out, &vec![0.0; out.len()]) < 1e-10);
    }

    #[test]
    fn apply_into_scratch_reuse_is_exact() {
        let mut rng = Rng::new(4);
        for method in [ConvMethod::Direct, ConvMethod::Fft] {
            let plan = VectorGauntPlan::new(
                VectorKind::VectorCross, 2, 2, 3, method,
            );
            let (x1, x2) = rand_inputs(&plan, &mut rng);
            let want = plan.apply(&x1, &x2);
            let (y1, y2) = rand_inputs(&plan, &mut rng);
            let mut scratch = plan.scratch();
            let mut out = vec![0.0; want.len()];
            plan.apply_into(&y1, &y2, &mut out, &mut scratch);
            plan.apply_into(&x1, &x2, &mut out, &mut scratch);
            assert!(
                max_abs_diff(&out, &want) == 0.0,
                "scratch state leaked ({method:?})"
            );
        }
    }

    #[test]
    fn truncation_matches_projection() {
        let mut rng = Rng::new(5);
        let full = VectorGauntPlan::new(
            VectorKind::ScalarVector, 2, 2, 4, ConvMethod::Fft,
        );
        let trunc = VectorGauntPlan::new(
            VectorKind::ScalarVector, 2, 2, 1, ConvMethod::Fft,
        );
        let (x1, x2) = rand_inputs(&full, &mut rng);
        let y_full = full.apply(&x1, &x2);
        let y_trunc = trunc.apply(&x1, &x2);
        let ir4 = Irreps::spherical(3, 4);
        let ir1 = Irreps::spherical(3, 1);
        let mut c4 = vec![0.0; num_coeffs(4)];
        let mut c1 = vec![0.0; num_coeffs(1)];
        for c in 0..3 {
            ir4.gather_channel(&y_full, c, &mut c4);
            ir1.gather_channel(&y_trunc, c, &mut c1);
            assert!(max_abs_diff(&c1, &c4[..num_coeffs(1)]) < 1e-10);
        }
    }
}
