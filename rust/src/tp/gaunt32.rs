//! f32 serving-precision Gaunt plan (opt-in: train f64, serve f32).
//!
//! [`Gaunt32Plan`] mirrors [`super::gaunt::GauntPlan`] with an f32
//! interior: all conversion tables are built by the f64 pipeline and
//! rounded ONCE at construction, inputs are rounded at the API boundary
//! (the slice types stay `&[f64]`, so the `EquivariantOp` trait is
//! unchanged), and the sh2f -> conv -> f2sh pipeline runs entirely in
//! [`C32`] through the [`F32x8`]-vectorized `fourier::fp32` kernels.
//! The back-projection accumulates its (small) sums into the f64 output
//! slots directly, so the only precision loss is the f32 rounding of the
//! tables, inputs, and convolution interior — the op-conformance f32
//! tier pins the resulting tolerance (~1e-4 relative at bench sizes).

use crate::fourier::complex::C64;
use crate::fourier::fp32::{
    conv2d_direct32_into, Conv32Plan, Conv32Scratch, C32,
};
use crate::fourier::tables::{
    f2sh_panels, sh2f_panels, F2shPanelsT, SQRT2_OVER_2,
};
use crate::tp::gaunt::ConvMethod;
use crate::{lm_index, num_coeffs};

/// f32 copy of [`crate::fourier::tables::Sh2fPanels`].
pub struct Sh2fPanels32 {
    pub l_max: usize,
    /// panels[s] is a (2L+1) x (L+1) row-major matrix over (u, l)
    pub panels: Vec<Vec<C32>>,
}

impl Sh2fPanels32 {
    /// Build via the f64 table pipeline, rounding once.
    pub fn build(l_max: usize) -> Sh2fPanels32 {
        let p = sh2f_panels(l_max);
        Sh2fPanels32 {
            l_max,
            panels: p.panels.iter().map(|v| cast_panel(v)).collect(),
        }
    }
}

/// f32 copy of the transposed f2sh panels.
pub struct F2shPanelsT32 {
    pub l_out: usize,
    pub n_grid: usize,
    /// panels[s] is a (2N+1) x (L_out+1) row-major matrix over (u, l)
    pub panels: Vec<Vec<C32>>,
}

impl F2shPanelsT32 {
    /// Build via the f64 table pipeline, rounding once.
    pub fn build(l_out: usize, n_grid: usize) -> F2shPanelsT32 {
        let t = F2shPanelsT::from_panels(&f2sh_panels(l_out, n_grid));
        F2shPanelsT32 {
            l_out,
            n_grid,
            panels: t.panels.iter().map(|v| cast_panel(v)).collect(),
        }
    }
}

fn cast_panel(p: &[C64]) -> Vec<C32> {
    p.iter().map(|z| C32::from_c64(*z)).collect()
}

/// f32 mirror of the f2sh back-projection
/// ([`crate::fourier::tables::f2sh_contract_scalar`]): f32 products,
/// f64 accumulation into `out`, identical normalization.
pub fn f2sh_contract32(t3t: &F2shPanelsT32, grid: &[C32], out: &mut [f64]) {
    let n = t3t.n_grid;
    let l_out = t3t.l_out;
    let nu = 2 * n + 1;
    let nl = l_out + 1;
    debug_assert_eq!(grid.len(), nu * nu);
    debug_assert_eq!(out.len(), nl * nl);
    debug_assert!(l_out <= n);
    out.fill(0.0);
    for u in 0..nu {
        let grow = &grid[u * nu..(u + 1) * nu];
        let g = grow[n];
        let t0 = &t3t.panels[0][u * nl..(u + 1) * nl];
        for (l, tv) in t0.iter().enumerate() {
            out[lm_index(l, 0)] += (tv.re * g.re - tv.im * g.im) as f64;
        }
        for s in 1..=l_out {
            let gp = grow[n + s];
            let gm = grow[n - s];
            let sp = gp + gm;
            let sm = gp - gm;
            let ts = &t3t.panels[s][u * nl..(u + 1) * nl];
            for l in s..=l_out {
                let tv = ts[l];
                out[lm_index(l, s as i64)] +=
                    (tv.re * sp.re - tv.im * sp.im) as f64;
                out[lm_index(l, -(s as i64))] -=
                    (tv.im * sm.re + tv.re * sm.im) as f64;
            }
        }
    }
    let two_pi = 2.0 * std::f64::consts::PI;
    let s2pi = std::f64::consts::SQRT_2 * std::f64::consts::PI;
    for l in 0..=l_out {
        for m in -(l as i64)..=(l as i64) {
            out[lm_index(l, m)] *= if m == 0 { two_pi } else { s2pi };
        }
    }
}

/// Caller-owned scratch for the f32 pipeline: one per worker thread,
/// sized at plan granularity, never resized (steady-state applies are
/// allocation-free, same contract as [`super::gaunt::GauntScratch`]).
pub struct Gaunt32Scratch {
    /// sh2f staging W[l, s]
    w: Vec<C32>,
    /// operand Fourier grids
    g1: Vec<C32>,
    g2: Vec<C32>,
    /// product grid (2(l1+l2)+1)^2
    out_grid: Vec<C32>,
    /// planned f32 convolution workspace
    conv: Conv32Scratch,
}

/// Precomputed f32 plan for x1 (deg <= L1) (x) x2 (deg <= L2) -> L3.
pub struct Gaunt32Plan {
    pub l1: usize,
    pub l2: usize,
    pub l3: usize,
    pub method: ConvMethod,
    p1: Sh2fPanels32,
    p2: Sh2fPanels32,
    t3t: F2shPanelsT32,
    conv: Conv32Plan,
    n_grid: usize,
}

impl Gaunt32Plan {
    pub fn new(l1: usize, l2: usize, l3: usize, method: ConvMethod) -> Self {
        let n_grid = l1 + l2;
        Gaunt32Plan {
            l1,
            l2,
            l3,
            method,
            p1: Sh2fPanels32::build(l1),
            p2: Sh2fPanels32::build(l2),
            t3t: F2shPanelsT32::build(l3, n_grid),
            conv: Conv32Plan::new(2 * l1 + 1, 2 * l2 + 1),
            n_grid,
        }
    }

    /// Fresh scratch sized for this plan (one per worker thread).
    pub fn scratch(&self) -> Gaunt32Scratch {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        let nu3 = 2 * self.n_grid + 1;
        let nw = (self.l1 + 1).max(self.l2 + 1);
        Gaunt32Scratch {
            w: vec![C32::default(); nw * nw],
            g1: vec![C32::default(); n1 * n1],
            g2: vec![C32::default(); n2 * n2],
            out_grid: vec![C32::default(); nu3 * nu3],
            conv: if self.uses_fft() {
                self.conv.scratch()
            } else {
                Conv32Scratch::empty()
            },
        }
    }

    /// Same crossover policy as the f64 plan.
    pub fn uses_fft(&self) -> bool {
        match self.method {
            ConvMethod::Direct => false,
            ConvMethod::Fft => true,
            ConvMethod::Auto => {
                self.l1 + self.l2 >= super::gaunt::AUTO_FFT_CROSSOVER
            }
        }
    }

    /// f64 SH coefficients -> f32 Fourier grid (rounding at the
    /// boundary); mirror of `GauntPlan::sh2f_into`.
    fn sh2f32_into(
        panels: &Sh2fPanels32, x: &[f64], grid: &mut [C32], w: &mut [C32],
    ) {
        let l_max = panels.l_max;
        let nu = 2 * l_max + 1;
        let nl = l_max + 1;
        debug_assert_eq!(x.len(), num_coeffs(l_max));
        debug_assert_eq!(grid.len(), nu * nu);
        debug_assert!(w.len() >= nl * nl);
        let w = &mut w[..nl * nl];
        w.fill(C32::default());
        for l in 0..=l_max {
            w[l * nl] = C32::real(x[lm_index(l, 0)] as f32);
            for s in 1..=l {
                w[l * nl + s] = C32::new(
                    (SQRT2_OVER_2 * x[lm_index(l, s as i64)]) as f32,
                    (-SQRT2_OVER_2 * x[lm_index(l, -(s as i64))]) as f32,
                );
            }
        }
        grid.fill(C32::default());
        for s in 0..=l_max {
            let p = &panels.panels[s];
            for u in 0..nu {
                let row = &p[u * nl..(u + 1) * nl];
                let mut accp = C32::default();
                let mut accm = C32::default();
                for l in s..=l_max {
                    let pv = row[l];
                    if pv.norm_sqr() == 0.0 {
                        continue;
                    }
                    let wv = w[l * nl + s];
                    accp += pv * wv;
                    accm += pv * wv.conj();
                }
                grid[u * nu + (l_max + s)] = accp;
                if s > 0 {
                    grid[u * nu + (l_max - s)] = accm;
                }
            }
        }
    }

    fn convolve_into(
        &self, a: &[C32], b: &[C32], out: &mut [C32],
        conv: &mut Conv32Scratch,
    ) {
        let n1 = 2 * self.l1 + 1;
        let n2 = 2 * self.l2 + 1;
        if self.uses_fft() {
            self.conv.conv_hermitian_into(a, b, out, conv);
        } else {
            conv2d_direct32_into(a, n1, b, n2, out);
        }
    }

    /// Fused f32 Gaunt Tensor Product; f64 slice boundaries, f32
    /// interior, zero steady-state allocations.
    pub fn apply_into(
        &self, x1: &[f64], x2: &[f64], out: &mut [f64],
        scratch: &mut Gaunt32Scratch,
    ) {
        Self::sh2f32_into(&self.p1, x1, &mut scratch.g1, &mut scratch.w);
        Self::sh2f32_into(&self.p2, x2, &mut scratch.g2, &mut scratch.w);
        self.convolve_into(
            &scratch.g1,
            &scratch.g2,
            &mut scratch.out_grid,
            &mut scratch.conv,
        );
        f2sh_contract32(&self.t3t, &scratch.out_grid, out);
    }

    /// Allocating convenience wrapper.
    pub fn apply(&self, x1: &[f64], x2: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; num_coeffs(self.l3)];
        let mut scratch = self.scratch();
        self.apply_into(x1, x2, &mut out, &mut scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tp::gaunt::GauntPlan;
    use crate::util::rng::Rng;

    fn rel_err(got: &[f64], want: &[f64]) -> f64 {
        let scale = want.iter().fold(1.0f64, |a, b| a.max(b.abs()));
        got.iter()
            .zip(want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0, f64::max)
            / scale
    }

    #[test]
    fn f32_plan_tracks_f64_plan() {
        let mut rng = Rng::new(40);
        for (l1, l2, l3) in
            [(0usize, 0usize, 0usize), (1, 1, 2), (2, 2, 2), (3, 2, 4),
             (4, 4, 4), (6, 6, 6)]
        {
            let x1 = rng.normals(num_coeffs(l1));
            let x2 = rng.normals(num_coeffs(l2));
            for method in [ConvMethod::Direct, ConvMethod::Fft] {
                let p64 = GauntPlan::new(l1, l2, l3, method);
                let p32 = Gaunt32Plan::new(l1, l2, l3, method);
                let want = p64.apply(&x1, &x2);
                let got = p32.apply(&x1, &x2);
                let e = rel_err(&got, &want);
                assert!(
                    e < 5e-4,
                    "({l1},{l2},{l3}) {method:?}: rel err {e:e}"
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_is_exact() {
        let mut rng = Rng::new(41);
        let plan = Gaunt32Plan::new(3, 2, 4, ConvMethod::Fft);
        let x1 = rng.normals(num_coeffs(3));
        let x2 = rng.normals(num_coeffs(2));
        let want = plan.apply(&x1, &x2);
        let mut scratch = plan.scratch();
        let mut out = vec![0.0; num_coeffs(4)];
        let y1 = rng.normals(num_coeffs(3));
        let y2 = rng.normals(num_coeffs(2));
        plan.apply_into(&y1, &y2, &mut out, &mut scratch);
        plan.apply_into(&x1, &x2, &mut out, &mut scratch);
        assert_eq!(out, want, "scratch state leaked");
    }

    #[test]
    fn crossover_matches_f64_policy() {
        assert!(!Gaunt32Plan::new(4, 4, 4, ConvMethod::Auto).uses_fft());
        assert!(Gaunt32Plan::new(5, 5, 5, ConvMethod::Auto).uses_fft());
        assert!(Gaunt32Plan::new(3, 3, 3, ConvMethod::Fft).uses_fft());
        assert!(!Gaunt32Plan::new(8, 8, 8, ConvMethod::Direct).uses_fft());
    }
}
