//! The one interface every tensor-product flavor serves:
//! [`EquivariantOp`].
//!
//! The paper's thesis is that full TPs, equivariant convolutions, and
//! many-body contractions are all *the same operation* — multiplication
//! of sphere functions.  This module makes that uniformity an API: all
//! five plan types ([`CgPlan`], [`GauntPlan`], [`EscnPlan`],
//! [`GauntConvPlan`], [`ManyBodyPlan`]) implement one trait with
//!
//! * typed [`Irreps`] input/output layouts (the contract callers size
//!   buffers against),
//! * a uniform scratch story ([`OpScratch`]: caller-owned, one per
//!   worker, zero steady-state allocations),
//! * a uniform apply (`apply_into` over an [`Inputs`] bundle), and
//! * an **exact** VJP w.r.t. the primary operand (`vjp_into`): every
//!   backward of a Gaunt product is itself a Gaunt product with the
//!   degrees rotated (the `G[k,i,j]` permutation symmetry — see
//!   `model`'s module docs), resolved through the global
//!   [`PlanCache`]; the CG and eSCN backwards are sparse/orthogonal
//!   transposes.
//!
//! The generic [`apply_batch`] / [`apply_batch_par`] helpers replace the
//! per-family `*_apply_batch_par` free functions: one sharded driver
//! (`pool::shard_rows_with`, one scratch per worker) serves every op.

use std::f64::consts::PI;
use std::sync::Arc;

use crate::num_coeffs;
use crate::tp::cg::CgPlan;
use crate::tp::engine::{OpKey, PlanCache};
use crate::tp::escn::{EscnPlan, EscnScratch, GauntConvPlan, GauntConvScratch};
use crate::tp::gaunt::{ConvMethod, GauntPlan, GauntScratch};
use crate::tp::gaunt32::{Gaunt32Plan, Gaunt32Scratch};
use crate::tp::irreps::Irreps;
use crate::tp::many_body::{ManyBodyPlan, ManyBodyScratch};
use crate::tp::vector::{VectorGauntPlan, VectorScratch};
use crate::util::pool;

/// The operand bundle of one apply.  Which fields an op reads is part of
/// its contract: pair ops ([`CgPlan`], [`GauntPlan`]) read `x1`/`x2`;
/// edge convolutions ([`EscnPlan`], [`GauntConvPlan`]) read
/// `x1`/`dir`/`weights`; the many-body self-product reads `x1` alone.
#[derive(Clone, Copy)]
pub struct Inputs<'a> {
    /// primary operand, laid out as [`EquivariantOp::irreps_in`]
    pub x1: &'a [f64],
    /// secondary operand ([`EquivariantOp::irreps_in2`])
    pub x2: Option<&'a [f64]>,
    /// edge direction (ops with [`EquivariantOp::needs_dir`])
    pub dir: Option<[f64; 3]>,
    /// per-path weights, [`EquivariantOp::n_weights`] long
    pub weights: Option<&'a [f64]>,
}

impl<'a> Inputs<'a> {
    /// A two-operand product (CG / Gaunt TP).
    pub fn pair(x1: &'a [f64], x2: &'a [f64]) -> Inputs<'a> {
        Inputs { x1, x2: Some(x2), dir: None, weights: None }
    }

    /// An edge convolution: feature, direction, shared weights.
    pub fn edge(
        x: &'a [f64], dir: [f64; 3], weights: &'a [f64],
    ) -> Inputs<'a> {
        Inputs { x1: x, x2: None, dir: Some(dir), weights: Some(weights) }
    }

    /// A single-operand op (many-body self-product).
    pub fn single(x: &'a [f64]) -> Inputs<'a> {
        Inputs { x1: x, x2: None, dir: None, weights: None }
    }

    fn x2(&self) -> &'a [f64] {
        self.x2.expect("this op requires a second operand (Inputs::pair)")
    }

    fn dir(&self) -> [f64; 3] {
        self.dir.expect("this op requires an edge direction (Inputs::edge)")
    }

    fn weights(&self) -> &'a [f64] {
        self.weights.expect("this op requires a weights vector")
    }
}

/// Caller-owned workspace for one [`EquivariantOp`]: every buffer any
/// apply or VJP of that op touches — one per worker thread, reused
/// across calls, so steady state is allocation-free.  Forward buffers
/// are sized at [`EquivariantOp::scratch`] time; **VJP-only resources
/// (the degree-rotated sibling plans and their scratch) are created
/// lazily on the first `vjp_into` call**, so forward-only callers (the
/// batched serving drivers) never pay for a backward they don't run,
/// and repeat VJPs reuse the cached sibling `Arc` without touching the
/// global cache lock.  The fields are a union over the op families;
/// each impl fills only what it needs.
pub struct OpScratch {
    /// forward scratch of a Gaunt-family plan
    gaunt: Option<GauntScratch>,
    /// the degree-rotated VJP sibling plan (lazily resolved once)
    gaunt_vjp_plan: Option<Arc<GauntPlan>>,
    /// scratch of the VJP sibling plan (lazy)
    gaunt_vjp: Option<GauntScratch>,
    /// forward scratch of an f32 serving-mode Gaunt plan
    gaunt32: Option<Gaunt32Scratch>,
    /// degree-rotated f32 VJP sibling plan (lazily resolved once)
    gaunt32_vjp_plan: Option<Arc<Gaunt32Plan>>,
    /// scratch of the f32 VJP sibling plan (lazy)
    gaunt32_vjp: Option<Gaunt32Scratch>,
    /// Gaunt-conv forward scratch (aligned path + rotation round trip)
    conv: Option<GauntConvScratch>,
    /// many-body forward scratch
    many: Option<ManyBodyScratch>,
    /// (nu-1)-fold power plan for the many-body VJP (lazy)
    many_pow_plan: Option<Arc<ManyBodyPlan>>,
    /// (nu-1)-fold power scratch for the many-body VJP (lazy)
    many_pow: Option<ManyBodyScratch>,
    /// eSCN rotation round-trip scratch
    escn: Option<EscnScratch>,
    /// vector-plan forward scratch
    vector: Option<VectorScratch>,
    /// degree-rotated vector VJP sibling plan (lazily resolved once)
    vector_vjp_plan: Option<Arc<VectorGauntPlan>>,
    /// scratch of the vector VJP sibling plan (lazy)
    vector_vjp: Option<VectorScratch>,
    /// flat staging (filter coefficients, power features; lazy)
    buf: Vec<f64>,
    /// filter layout for per-degree reweighting (GauntConv VJP; lazy)
    filter_irreps: Option<Irreps>,
}

impl OpScratch {
    /// A scratch with no buffers (ops that need none, e.g. the sparse
    /// CG contraction).
    pub fn empty() -> OpScratch {
        OpScratch {
            gaunt: None,
            gaunt_vjp_plan: None,
            gaunt_vjp: None,
            gaunt32: None,
            gaunt32_vjp_plan: None,
            gaunt32_vjp: None,
            conv: None,
            many: None,
            many_pow_plan: None,
            many_pow: None,
            escn: None,
            vector: None,
            vector_vjp_plan: None,
            vector_vjp: None,
            buf: Vec::new(),
            filter_irreps: None,
        }
    }
}

/// One equivariant operation with a typed layout contract.
///
/// **Scratch ownership.** The op owns no mutable state; callers hold an
/// [`OpScratch`] per worker (from [`EquivariantOp::scratch`]) and thread
/// it through `apply_into`/`vjp_into`.  After a first warm call, neither
/// entry point allocates.
///
/// **Backward convention.** `vjp_into(inputs, g, scratch, grad)` writes
/// `grad = d<g, op(inputs)>/d x1` (the gradient w.r.t. the primary
/// operand, overwriting `grad`), holding every other input fixed.
pub trait EquivariantOp: Send + Sync {
    /// The cache key identifying this op (also usable with
    /// [`PlanCache::op`]).
    fn key(&self) -> OpKey;

    /// Layout of the primary operand `x1`.
    fn irreps_in(&self) -> Irreps;

    /// Layout of the output.
    fn irreps_out(&self) -> Irreps;

    /// Layout of the secondary operand, for pair ops.
    fn irreps_in2(&self) -> Option<Irreps> {
        None
    }

    /// Length of the per-apply weights vector (0 when unused).
    fn n_weights(&self) -> usize {
        0
    }

    /// Whether the op consumes an edge direction.
    fn needs_dir(&self) -> bool {
        false
    }

    /// Fresh scratch sized for this op (one per worker thread).
    fn scratch(&self) -> OpScratch;

    /// Apply into a caller buffer of `irreps_out().dim()` (overwritten).
    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    );

    /// Exact gradient of `<cotangent, op(inputs)>` w.r.t. `x1`, written
    /// into `grad` (`irreps_in().dim()`, overwritten).
    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    );

    /// Allocating convenience apply.
    fn apply_op(&self, inputs: Inputs<'_>) -> Vec<f64> {
        let mut out = vec![0.0; self.irreps_out().dim()];
        let mut scratch = self.scratch();
        self.apply_into(inputs, &mut scratch, &mut out);
        out
    }
}

// ---------------------------------------------------------------------
// impls: the five plan families
// ---------------------------------------------------------------------

impl EquivariantOp for CgPlan {
    fn key(&self) -> OpKey {
        OpKey::Cg { l1: self.l1, l2: self.l2, l3: self.l3 }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l1)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l3)
    }

    fn irreps_in2(&self) -> Option<Irreps> {
        Some(Irreps::single(self.l2))
    }

    fn scratch(&self) -> OpScratch {
        OpScratch::empty()
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, _scratch: &mut OpScratch, out: &mut [f64],
    ) {
        self.apply_sparse_into(inputs.x1, inputs.x2(), out);
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        _scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        self.vjp_x1_into(cotangent, inputs.x2(), grad);
    }
}

impl EquivariantOp for GauntPlan {
    fn key(&self) -> OpKey {
        OpKey::Gaunt {
            l1: self.l1,
            l2: self.l2,
            l3: self.l3,
            method: self.method,
        }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l1)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l3)
    }

    fn irreps_in2(&self) -> Option<Irreps> {
        Some(Irreps::single(self.l2))
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.gaunt = Some(GauntPlan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        GauntPlan::apply_into(
            self,
            inputs.x1,
            inputs.x2(),
            out,
            scratch.gaunt.as_mut().expect("GauntPlan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        // dL/dx1 = P_{L1}(f_g f_x2): same product, degrees rotated.
        // The sibling plan (L3, L2) -> L1 is resolved ONCE per scratch
        // (first call) and cached, so repeat VJPs never touch the
        // global cache lock.
        if scratch.gaunt_vjp_plan.is_none() {
            let sib = PlanCache::global()
                .gaunt(self.l3, self.l2, self.l1, self.method);
            scratch.gaunt_vjp = Some(sib.scratch());
            scratch.gaunt_vjp_plan = Some(sib);
        }
        let sib = scratch.gaunt_vjp_plan.as_ref().unwrap().clone();
        GauntPlan::apply_into(
            &sib,
            cotangent,
            inputs.x2(),
            grad,
            scratch.gaunt_vjp.as_mut().expect("GauntPlan vjp scratch"),
        );
    }
}

impl EquivariantOp for Gaunt32Plan {
    fn key(&self) -> OpKey {
        OpKey::GauntF32 { l1: self.l1, l2: self.l2, l3: self.l3 }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l1)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l3)
    }

    fn irreps_in2(&self) -> Option<Irreps> {
        Some(Irreps::single(self.l2))
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.gaunt32 = Some(Gaunt32Plan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        Gaunt32Plan::apply_into(
            self,
            inputs.x1,
            inputs.x2(),
            out,
            scratch.gaunt32.as_mut().expect("Gaunt32Plan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        // Same degree-rotation identity as the f64 Gaunt VJP; the
        // backward runs in the same precision as the forward so serving
        // force evaluations stay f32-fast.
        if scratch.gaunt32_vjp_plan.is_none() {
            let sib = PlanCache::global()
                .gaunt_f32(self.l3, self.l2, self.l1);
            scratch.gaunt32_vjp = Some(sib.scratch());
            scratch.gaunt32_vjp_plan = Some(sib);
        }
        let sib = scratch.gaunt32_vjp_plan.as_ref().unwrap().clone();
        Gaunt32Plan::apply_into(
            &sib,
            cotangent,
            inputs.x2(),
            grad,
            scratch.gaunt32_vjp.as_mut().expect("Gaunt32Plan vjp scratch"),
        );
    }
}

impl EquivariantOp for EscnPlan {
    fn key(&self) -> OpKey {
        OpKey::Escn {
            l_in: self.l_in,
            l_filter: self.l_filter,
            l_out: self.l_out,
        }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l_in)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l_out)
    }

    fn n_weights(&self) -> usize {
        self.n_paths()
    }

    fn needs_dir(&self) -> bool {
        true
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.escn = Some(EscnPlan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        EscnPlan::apply_into(
            self,
            inputs.x1,
            inputs.dir(),
            inputs.weights(),
            out,
            scratch.escn.as_mut().expect("EscnPlan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        EscnPlan::vjp_into(
            self,
            inputs.dir(),
            inputs.weights(),
            cotangent,
            grad,
            scratch.escn.as_mut().expect("EscnPlan scratch"),
        );
    }
}

impl EquivariantOp for GauntConvPlan {
    fn key(&self) -> OpKey {
        OpKey::GauntConv {
            l_in: self.l_in,
            l_filter: self.l_filter,
            l_out: self.l_out,
        }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l_in)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l_out)
    }

    fn n_weights(&self) -> usize {
        self.l_filter + 1
    }

    fn needs_dir(&self) -> bool {
        true
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.conv = Some(GauntConvPlan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        self.apply_full_into(
            inputs.x1,
            inputs.dir(),
            inputs.weights(),
            ConvMethod::Auto,
            out,
            scratch.conv.as_mut().expect("GauntConvPlan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        // the conv is the Gaunt product with the full filter f[lm] =
        // h2[l2] Y_lm(dir); its x-VJP is P_{L_in}(f_g f_filter).
        // Backward resources are built on the first call and cached in
        // the scratch.
        if scratch.gaunt_vjp_plan.is_none() {
            let sib = PlanCache::global().gaunt(
                self.l_out, self.l_filter, self.l_in, ConvMethod::Auto,
            );
            scratch.gaunt_vjp = Some(sib.scratch());
            scratch.gaunt_vjp_plan = Some(sib);
            scratch.buf = vec![0.0; num_coeffs(self.l_filter)];
            scratch.filter_irreps = Some(Irreps::single(self.l_filter));
        }
        let filt = &mut scratch.buf;
        crate::so3::sh::real_sh_all_xyz_into(
            self.l_filter, inputs.dir(), filt,
        );
        scratch
            .filter_irreps
            .as_ref()
            .expect("GauntConvPlan vjp scratch")
            .scale_paths_inplace(filt, inputs.weights());
        let sib = scratch.gaunt_vjp_plan.as_ref().unwrap().clone();
        GauntPlan::apply_into(
            &sib,
            cotangent,
            &scratch.buf,
            grad,
            scratch.gaunt_vjp.as_mut().expect("GauntConvPlan vjp scratch"),
        );
    }
}

impl ManyBodyPlan {
    /// Degree of the `x^(nu-1)` power feature the VJP contracts against:
    /// Gaunt selection rules cut everything above `l_out + l` out of the
    /// projection back onto degree `l`.
    pub fn pow_degree(&self) -> usize {
        ((self.nu - 1) * self.l).min(self.l_out + self.l)
    }
}

impl EquivariantOp for ManyBodyPlan {
    fn key(&self) -> OpKey {
        OpKey::ManyBody { nu: self.nu, l: self.l, l_out: self.l_out }
    }

    fn irreps_in(&self) -> Irreps {
        Irreps::single(self.l)
    }

    fn irreps_out(&self) -> Irreps {
        Irreps::single(self.l_out)
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.many = Some(ManyBodyPlan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        self.apply_self_into(
            inputs.x1,
            out,
            scratch.many.as_mut().expect("ManyBodyPlan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        // d<g, P(x^nu)>/dx = nu P_l(f_g f_x^{nu-1}), the power truncated
        // to pow_degree() by the selection rules.  Backward resources
        // are built on the first call and cached in the scratch.
        let lp = self.pow_degree();
        if scratch.gaunt_vjp_plan.is_none() {
            if self.nu > 2 {
                let pow = PlanCache::global()
                    .many_body(self.nu - 1, self.l, lp);
                scratch.many_pow = Some(pow.scratch());
                scratch.many_pow_plan = Some(pow);
            }
            let sib = PlanCache::global()
                .gaunt(self.l_out, lp, self.l, ConvMethod::Auto);
            scratch.gaunt_vjp = Some(sib.scratch());
            scratch.gaunt_vjp_plan = Some(sib);
            scratch.buf = vec![0.0; num_coeffs(lp)];
        }
        match self.nu {
            1 => {
                // x^0 is the constant function 1 = sqrt(4 pi) Y_00
                scratch.buf[0] = (4.0 * PI).sqrt();
            }
            2 => scratch.buf.copy_from_slice(inputs.x1),
            _ => {
                let pow = scratch.many_pow_plan.as_ref().unwrap().clone();
                pow.apply_self_into(
                    inputs.x1,
                    &mut scratch.buf,
                    scratch.many_pow.as_mut().expect("many-body pow scratch"),
                );
            }
        }
        let sib = scratch.gaunt_vjp_plan.as_ref().unwrap().clone();
        GauntPlan::apply_into(
            &sib,
            cotangent,
            &scratch.buf,
            grad,
            scratch.gaunt_vjp.as_mut().expect("many-body vjp scratch"),
        );
        let nu = self.nu as f64;
        for v in grad.iter_mut() {
            *v *= nu;
        }
    }
}

impl EquivariantOp for VectorGauntPlan {
    fn key(&self) -> OpKey {
        OpKey::Vector {
            kind: self.kind,
            l1: self.l1,
            l2: self.l2,
            l3: self.l3,
            method: self.method,
        }
    }

    fn irreps_in(&self) -> Irreps {
        VectorGauntPlan::irreps_in(self).clone()
    }

    fn irreps_out(&self) -> Irreps {
        VectorGauntPlan::irreps_out(self).clone()
    }

    fn irreps_in2(&self) -> Option<Irreps> {
        Some(VectorGauntPlan::irreps_in2(self).clone())
    }

    fn scratch(&self) -> OpScratch {
        let mut s = OpScratch::empty();
        s.vector = Some(VectorGauntPlan::scratch(self));
        s
    }

    fn apply_into(
        &self, inputs: Inputs<'_>, scratch: &mut OpScratch, out: &mut [f64],
    ) {
        VectorGauntPlan::apply_into(
            self,
            inputs.x1,
            inputs.x2(),
            out,
            scratch.vector.as_mut().expect("VectorGauntPlan scratch"),
        );
    }

    fn vjp_into(
        &self, inputs: Inputs<'_>, cotangent: &[f64],
        scratch: &mut OpScratch, grad: &mut [f64],
    ) {
        // The VJP stays inside the vector family by degree rotation
        // (sv^T = dot, dot^T = sv, cross^T = cross with the cotangent as
        // second operand); the sibling is resolved once per scratch.
        if scratch.vector_vjp_plan.is_none() {
            let (kind, l1, l2, l3) = self.vjp_sibling_key();
            let sib =
                PlanCache::global().vector(kind, l1, l2, l3, self.method);
            scratch.vector_vjp = Some(sib.scratch());
            scratch.vector_vjp_plan = Some(sib);
        }
        let sib = scratch.vector_vjp_plan.as_ref().unwrap().clone();
        let (a, b) = if self.vjp_operands_swapped() {
            (inputs.x2(), cotangent)
        } else {
            (cotangent, inputs.x2())
        };
        VectorGauntPlan::apply_into(
            &sib,
            a,
            b,
            grad,
            scratch.vector_vjp.as_mut().expect("VectorGauntPlan vjp scratch"),
        );
    }
}

// ---------------------------------------------------------------------
// generic batched drivers (replace the per-family *_apply_batch_par)
// ---------------------------------------------------------------------

/// Row-major batch operands: `x1`/`x2` hold `rows` features back to
/// back, `dirs` one direction per row, `weights` shared by every row.
#[derive(Clone, Copy)]
pub struct BatchInputs<'a> {
    pub x1: &'a [f64],
    pub x2: Option<&'a [f64]>,
    pub dirs: Option<&'a [[f64; 3]]>,
    pub weights: Option<&'a [f64]>,
}

impl<'a> BatchInputs<'a> {
    /// A batch of two-operand products.
    pub fn pair(x1: &'a [f64], x2: &'a [f64]) -> BatchInputs<'a> {
        BatchInputs { x1, x2: Some(x2), dirs: None, weights: None }
    }

    /// A batch of edge convolutions with shared weights.
    pub fn edges(
        x: &'a [f64], dirs: &'a [[f64; 3]], weights: &'a [f64],
    ) -> BatchInputs<'a> {
        BatchInputs { x1: x, x2: None, dirs: Some(dirs),
                      weights: Some(weights) }
    }

    /// A batch of single-operand ops.
    pub fn singles(x: &'a [f64]) -> BatchInputs<'a> {
        BatchInputs { x1: x, x2: None, dirs: None, weights: None }
    }
}

/// Batched apply of ANY [`EquivariantOp`], rows sharded across
/// `threads` workers (`0` = all cores) with one [`OpScratch`] per worker
/// — row-for-row identical to the serial loop.
pub fn apply_batch_par(
    op: &dyn EquivariantOp, batch: &BatchInputs<'_>, rows: usize,
    threads: usize,
) -> Vec<f64> {
    let n1 = op.irreps_in().dim();
    let n2 = op.irreps_in2().map(|ir| ir.dim()).unwrap_or(0);
    let n_out = op.irreps_out().dim();
    debug_assert_eq!(batch.x1.len(), rows * n1);
    if let Some(x2) = batch.x2 {
        debug_assert_eq!(x2.len(), rows * n2);
    }
    if op.needs_dir() {
        debug_assert_eq!(batch.dirs.map(|d| d.len()), Some(rows));
    }
    let mut out = vec![0.0; rows * n_out];
    let threads = pool::resolve_threads(threads);
    pool::shard_rows_with(
        &mut out,
        n_out,
        threads,
        || op.scratch(),
        |r, row, scratch| {
            let inputs = Inputs {
                x1: &batch.x1[r * n1..(r + 1) * n1],
                x2: batch.x2.map(|x2| &x2[r * n2..(r + 1) * n2]),
                dir: batch.dirs.map(|d| d[r]),
                weights: batch.weights,
            };
            op.apply_into(inputs, scratch, row);
        },
    );
    out
}

/// Serial batched apply (one scratch reused across rows).
pub fn apply_batch(
    op: &dyn EquivariantOp, batch: &BatchInputs<'_>, rows: usize,
) -> Vec<f64> {
    apply_batch_par(op, batch, rows, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::max_abs_diff;
    use crate::util::rng::Rng;

    /// Finite-difference check of `<g, op(x1 ...)>` against vjp_into.
    fn check_vjp(op: &dyn EquivariantOp, inputs: Inputs<'_>, seed: u64) {
        let mut rng = Rng::new(seed);
        let n1 = op.irreps_in().dim();
        let n_out = op.irreps_out().dim();
        let g = rng.normals(n_out);
        let mut scratch = op.scratch();
        let mut grad = vec![0.0; n1];
        op.vjp_into(inputs, &g, &mut scratch, &mut grad);
        let h = 1e-6;
        let mut x = inputs.x1.to_vec();
        let mut out = vec![0.0; n_out];
        for i in 0..n1 {
            let x0 = x[i];
            x[i] = x0 + h;
            op.apply_into(Inputs { x1: &x, ..inputs }, &mut scratch,
                          &mut out);
            let fp: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0 - h;
            op.apply_into(Inputs { x1: &x, ..inputs }, &mut scratch,
                          &mut out);
            let fm: f64 = g.iter().zip(&out).map(|(a, b)| a * b).sum();
            x[i] = x0;
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "component {i}: vjp {} vs fd {fd}", grad[i]
            );
        }
    }

    #[test]
    fn pair_ops_match_their_legacy_applies() {
        let mut rng = Rng::new(0);
        let (l1, l2, l3) = (2usize, 2usize, 3usize);
        let x1 = rng.normals(num_coeffs(l1));
        let x2 = rng.normals(num_coeffs(l2));
        let cg = CgPlan::new(l1, l2, l3);
        let got = EquivariantOp::apply_op(&cg, Inputs::pair(&x1, &x2));
        assert_eq!(got, cg.apply_sparse(&x1, &x2));
        let gp = GauntPlan::new(l1, l2, l3, ConvMethod::Direct);
        let got = EquivariantOp::apply_op(&gp, Inputs::pair(&x1, &x2));
        assert!(max_abs_diff(&got, &gp.apply(&x1, &x2)) == 0.0);
    }

    #[test]
    fn vjps_match_finite_differences() {
        let mut rng = Rng::new(1);
        let x = rng.normals(num_coeffs(2));
        let x2 = rng.normals(num_coeffs(2));
        let dir = rng.unit3();

        let cg = CgPlan::new(2, 2, 2);
        check_vjp(&cg, Inputs::pair(&x, &x2), 10);

        let gp = GauntPlan::new(2, 2, 3, ConvMethod::Direct);
        check_vjp(&gp, Inputs::pair(&x, &x2), 11);

        let escn = EscnPlan::new(2, 2, 2);
        let h: Vec<f64> = (0..escn.n_paths()).map(|_| rng.normal()).collect();
        check_vjp(&escn, Inputs::edge(&x, dir, &h), 12);

        let gc = GauntConvPlan::new(2, 2, 3);
        let h2: Vec<f64> = (0..=2).map(|_| rng.normal()).collect();
        check_vjp(&gc, Inputs::edge(&x, dir, &h2), 13);

        for nu in [2usize, 3] {
            let mb = ManyBodyPlan::new(nu, 2, 2);
            check_vjp(&mb, Inputs::single(&x), 14 + nu as u64);
        }

        use crate::tp::vector::VectorKind;
        let v1 = rng.normals(3 * num_coeffs(2));
        let v2 = rng.normals(3 * num_coeffs(1));
        let sv = VectorGauntPlan::new(
            VectorKind::ScalarVector, 2, 1, 2, ConvMethod::Auto,
        );
        check_vjp(&sv, Inputs::pair(&x, &v2), 17);
        let dot = VectorGauntPlan::new(
            VectorKind::VectorDot, 2, 1, 2, ConvMethod::Auto,
        );
        check_vjp(&dot, Inputs::pair(&v1, &v2), 18);
        let cross = VectorGauntPlan::new(
            VectorKind::VectorCross, 2, 1, 2, ConvMethod::Auto,
        );
        check_vjp(&cross, Inputs::pair(&v1, &v2), 19);
    }

    #[test]
    fn f32_gaunt_op_tracks_the_f64_plan() {
        let mut rng = Rng::new(5);
        let x1 = rng.normals(num_coeffs(2));
        let x2 = rng.normals(num_coeffs(2));
        let p64 = GauntPlan::new(2, 2, 3, ConvMethod::Auto);
        let p32 = Gaunt32Plan::new(2, 2, 3, ConvMethod::Auto);
        let want = p64.apply(&x1, &x2);
        let got = EquivariantOp::apply_op(&p32, Inputs::pair(&x1, &x2));
        let scale = want.iter().fold(1.0f64, |a, v| a.max(v.abs()));
        assert!(max_abs_diff(&got, &want) < 5e-4 * scale);

        // the f32 backward tracks the f64 backward (same rotation
        // identity, single-precision interior)
        let g = rng.normals(num_coeffs(3));
        let mut grad64 = vec![0.0; num_coeffs(2)];
        let mut grad32 = vec![0.0; num_coeffs(2)];
        let mut s64 = EquivariantOp::scratch(&p64);
        let mut s32 = EquivariantOp::scratch(&p32);
        let inputs = Inputs::pair(&x1, &x2);
        p64.vjp_into(inputs, &g, &mut s64, &mut grad64);
        p32.vjp_into(inputs, &g, &mut s32, &mut grad32);
        let gscale = grad64.iter().fold(1.0f64, |a, v| a.max(v.abs()));
        assert!(max_abs_diff(&grad32, &grad64) < 1e-3 * gscale);
    }

    #[test]
    fn generic_batch_par_matches_serial_for_every_family() {
        let mut rng = Rng::new(2);
        let rows = 7usize;
        let n = num_coeffs(2);

        let gp = GauntPlan::new(2, 2, 2, ConvMethod::Auto);
        let x1 = rng.normals(rows * n);
        let x2 = rng.normals(rows * n);
        let serial = apply_batch(&gp, &BatchInputs::pair(&x1, &x2), rows);
        assert!(max_abs_diff(&serial, &gp.apply_batch(&x1, &x2, rows)) == 0.0);
        for threads in [2usize, 4, 0] {
            let par = apply_batch_par(&gp, &BatchInputs::pair(&x1, &x2),
                                      rows, threads);
            assert_eq!(par, serial, "threads={threads}");
        }

        let escn = EscnPlan::new(2, 2, 2);
        let xs = rng.normals(rows * n);
        let dirs: Vec<[f64; 3]> = (0..rows).map(|_| rng.unit3()).collect();
        let h: Vec<f64> = (0..escn.n_paths()).map(|_| rng.normal()).collect();
        let serial =
            apply_batch(&escn, &BatchInputs::edges(&xs, &dirs, &h), rows);
        assert!(
            max_abs_diff(&serial, &escn.apply_batch(&xs, &dirs, &h)) < 1e-12
        );
        let par = apply_batch_par(&escn, &BatchInputs::edges(&xs, &dirs, &h),
                                  rows, 0);
        assert_eq!(par, serial);

        let mb = ManyBodyPlan::new(3, 2, 2);
        let serial = apply_batch(&mb, &BatchInputs::singles(&xs), rows);
        for r in 0..rows {
            let want = mb.apply_self(&xs[r * n..(r + 1) * n]);
            assert!(max_abs_diff(&serial[r * n..(r + 1) * n], &want) == 0.0);
        }
    }
}
